"""L1 performance model: VMEM footprint and roofline estimates per kernel.

``interpret=True`` gives CPU-numpy timings only, so TPU performance is
*estimated structurally* from the BlockSpecs (DESIGN.md §Perf): per grid
step we know exactly how many bytes move HBM→VMEM and how many FLOPs the
VPU/MXU performs, which places each kernel on the roofline.

Usage::

    python -m compile.vmem            # print the report
"""

from __future__ import annotations

from dataclasses import dataclass

from .kernels.mass import BLOCK_B, BLOCK_L

# TPU-v4-ish single-core budget (order-of-magnitude machine model; the
# ratios, not the absolutes, matter for the §Perf targets).
VMEM_BYTES = 16 * 2**20          # ~16 MiB VMEM
HBM_BW = 1.2e12                  # ~1.2 TB/s
VPU_FLOPS = 2.0e12               # ~2 TFLOP/s f32 vector
MXU_FLOPS = 137.5e12             # bf16 matmul (unused by these kernels)
DTYPE_BYTES = 4                  # f32


@dataclass
class KernelEstimate:
    """Structural performance estimate for one kernel."""

    name: str
    #: VMEM resident bytes per grid step (tiles + accumulators).
    vmem_bytes: int
    #: bytes moved from HBM per element of the (B, L) input.
    bytes_per_elem: float
    #: FLOPs per element.
    flops_per_elem: float

    @property
    def vmem_fraction(self) -> float:
        return self.vmem_bytes / VMEM_BYTES

    @property
    def arithmetic_intensity(self) -> float:
        """FLOPs per HBM byte."""
        return self.flops_per_elem / self.bytes_per_elem

    @property
    def bound(self) -> str:
        """Memory- or compute-bound on the model machine."""
        ridge = VPU_FLOPS / HBM_BW  # FLOP/byte at the roofline ridge
        return "memory" if self.arithmetic_intensity < ridge else "compute"

    @property
    def attainable_flops(self) -> float:
        return min(VPU_FLOPS, self.arithmetic_intensity * HBM_BW)

    @property
    def efficiency_vs_peak(self) -> float:
        """Attainable / VPU peak — the paper-style efficiency ratio."""
        return self.attainable_flops / VPU_FLOPS

    @property
    def streaming_throughput_geps(self) -> float:
        """Elements/second (x1e9) when running at the roofline."""
        return HBM_BW / self.bytes_per_elem / 1e9


def estimates() -> list[KernelEstimate]:
    """Estimates for every L1 kernel, derived from their BlockSpecs."""
    tile = BLOCK_B * BLOCK_L * DTYPE_BYTES
    acc = BLOCK_B * DTYPE_BYTES
    # double-buffered input stream: 2 tiles resident
    return [
        # sumup: read 1 elem, 1 add
        KernelEstimate("sumup", 2 * tile + acc, DTYPE_BYTES, 1.0),
        # mass_for: read 1, write 1, fma (2 flops)
        KernelEstimate("mass_for", 2 * tile + 2 * tile, 2 * DTYPE_BYTES, 2.0),
        # dot: read 2 elems, mul+add
        KernelEstimate("dot", 2 * 2 * tile + acc, 2 * DTYPE_BYTES, 2.0),
        # prefix: read 1, write 1, add (+carry, amortised)
        KernelEstimate("prefix", 2 * tile + 2 * tile + acc, 2 * DTYPE_BYTES, 1.0),
        # sumup_stats: read 1, sum + square-accumulate (3 flops)
        KernelEstimate("sumup_stats", 2 * tile + 3 * acc, DTYPE_BYTES, 3.0),
    ]


def report() -> str:
    lines = [
        "L1 kernel roofline estimates (structural, from BlockSpecs; see DESIGN.md §Perf)",
        f"machine model: VMEM {VMEM_BYTES >> 20} MiB, HBM {HBM_BW / 1e12:.1f} TB/s, VPU {VPU_FLOPS / 1e12:.1f} TF/s",
        f"{'kernel':>12} {'VMEM/step':>10} {'%VMEM':>7} {'AI F/B':>7} {'bound':>8} {'GF/s att.':>10} {'eff':>6} {'Gelem/s':>8}",
    ]
    for e in estimates():
        lines.append(
            f"{e.name:>12} {e.vmem_bytes:>9}B {100 * e.vmem_fraction:>6.2f}% "
            f"{e.arithmetic_intensity:>7.2f} {e.bound:>8} {e.attainable_flops / 1e9:>10.0f} "
            f"{e.efficiency_vs_peak:>6.1%} {e.streaming_throughput_geps:>8.1f}"
        )
    lines.append(
        "all kernels are HBM-streaming reductions → memory-bound by design; the"
    )
    lines.append(
        "optimisation target is VMEM residency ≪ budget (double-buffer headroom),"
    )
    lines.append("matching the paper's SUMUP insight: 1 element/clock into the adder.")
    return "\n".join(lines)


if __name__ == "__main__":
    print(report())
