"""Layer-1 Pallas kernels: the EMPA mass-processing accelerator (§3.8).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's SUMUP
mode — children streaming summands into the parent-side adder, one per
clock — maps on TPU to a reduction pipelined through VMEM. Each grid step
moves one ``(block_b, block_l)`` tile HBM→VMEM (the "child" fetching its
element) and accumulates into a VMEM accumulator (the "parent adder"); the
sequential grid dimension plays the supervisor's role of staggering the
children. FOR mode — SV-driven loop with per-element child work — maps to
an elementwise VPU kernel over tiles, the loop control being free (grid)
exactly as FOR eliminates the control instructions.

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and the AOT artifact must be loadable by the rust
runtime. Structure (BlockSpecs, accumulator layout) is what we optimise;
see DESIGN.md §Perf for the VMEM/MXU estimates.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile sizes. The lane dimension (last axis) matches the TPU VPU lane
# count; the sublane dimension is kept small so a (8, 128) f32 tile is one
# native VREG tile. VMEM footprint per grid step (see DESIGN.md §Perf):
# in-tile + accumulator = (8*128 + 8) * 4 B ≈ 4.1 KiB, far below the
# ~16 MiB VMEM budget, leaving room for double-buffering the HBM stream.
BLOCK_B = 8
BLOCK_L = 128


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _pad2d(x: jax.Array) -> jax.Array:
    """Zero-pad a (B, L) array up to the tile grid.

    Out-of-bounds block regions are undefined in interpret mode (NaN
    poison), and zero is the identity of the sum/dot reductions, so the
    kernels always see fully-defined tiles and the wrappers slice the
    payload back out.
    """
    b, l = x.shape
    pb = _ceil_div(max(b, 1), BLOCK_B) * BLOCK_B - b
    pl_ = _ceil_div(max(l, 1), BLOCK_L) * BLOCK_L - l
    if pb or pl_:
        x = jnp.pad(x, ((0, pb), (0, pl_)))
    return x


# ----------------------------------------------------------------------
# SUMUP: batched vector sum — out[b] = sum_l x[b, l]
# ----------------------------------------------------------------------

def _sumup_kernel(x_ref, o_ref):
    """Parent-adder accumulation over the L (grid) dimension."""
    l_idx = pl.program_id(1)

    @pl.when(l_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # One tile of "children" delivers its summands; the adder consumes
    # them in one vectorised step (the silicon version consumes 1/clock).
    o_ref[...] += jnp.sum(x_ref[...], axis=1)


def mass_sumup(x: jax.Array) -> jax.Array:
    """Sum each row of a (B, L) batch: the SUMUP mode of §5.2."""
    b, _ = x.shape
    xp = _pad2d(x)
    pb, pl_len = xp.shape
    grid = (pb // BLOCK_B, pl_len // BLOCK_L)
    out = pl.pallas_call(
        _sumup_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((BLOCK_B, BLOCK_L), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((BLOCK_B,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((pb,), x.dtype),
        interpret=True,
    )(xp)
    return out[:b]


# ----------------------------------------------------------------------
# FOR: elementwise child work — out[b, l] = scale * x[b, l] + bias
# ----------------------------------------------------------------------

def _axpb_kernel(x_ref, s_ref, o_ref):
    """The FOR-mode child body: pure payload, zero control overhead."""
    scale = s_ref[0]
    bias = s_ref[1]
    o_ref[...] = x_ref[...] * scale + bias


def mass_for(x: jax.Array, scale_bias: jax.Array) -> jax.Array:
    """Apply ``scale*x + bias`` elementwise over a (B, L) batch (§5.1).

    ``scale_bias`` is a (2,) array latched once — the paper's `ForChild`
    latch contents, cloned to every child.
    """
    b, l = x.shape
    xp = _pad2d(x)
    pb, pl_len = xp.shape
    grid = (pb // BLOCK_B, pl_len // BLOCK_L)
    out = pl.pallas_call(
        _axpb_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_B, BLOCK_L), lambda i, j: (i, j)),
            pl.BlockSpec((2,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_B, BLOCK_L), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((pb, pl_len), x.dtype),
        interpret=True,
    )(xp, scale_bias)
    return out[:b, :l]


# ----------------------------------------------------------------------
# DOT: per-row dot product — out[b] = sum_l a[b, l] * b[b, l]
# ----------------------------------------------------------------------

def _dot_kernel(a_ref, b_ref, o_ref):
    """Mass operating mode over two operand streams (§3.7: summing
    products "in frame of a machine instruction")."""
    l_idx = pl.program_id(1)

    @pl.when(l_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.sum(a_ref[...] * b_ref[...], axis=1)


def mass_dot(a: jax.Array, b: jax.Array) -> jax.Array:
    """Row-wise dot product of two (B, L) batches."""
    bb, _ = a.shape
    ap = _pad2d(a)
    bp = _pad2d(b)
    pb, pl_len = ap.shape
    grid = (pb // BLOCK_B, pl_len // BLOCK_L)
    spec2d = pl.BlockSpec((BLOCK_B, BLOCK_L), lambda i, j: (i, j))
    out = pl.pallas_call(
        _dot_kernel,
        grid=grid,
        in_specs=[spec2d, spec2d],
        out_specs=pl.BlockSpec((BLOCK_B,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((pb,), a.dtype),
        interpret=True,
    )(ap, bp)
    return out[:bb]


# ----------------------------------------------------------------------
# PREFIX: running partial sums — out[b, l] = sum_{l' <= l} x[b, l']
# (the FOR-mode "partial sum cloned back each iteration" made visible)
# ----------------------------------------------------------------------

def mass_prefix(x: jax.Array) -> jax.Array:
    """Row-wise prefix (cumulative) sums over a (B, L) batch.

    A single-L-block Pallas kernel composed with a jnp carry across
    blocks: the cross-block carry is exactly the FOR-mode partial sum the
    parent clones into each next child (§5.1).
    """
    b, l = x.shape
    xp = _pad2d(x)
    pb, pl_len = xp.shape
    num_blocks = pl_len // BLOCK_L

    def one_block(x_blk: jax.Array) -> jax.Array:
        return pl.pallas_call(
            lambda x_ref, o_ref: o_ref.__setitem__(Ellipsis, jnp.cumsum(x_ref[...], axis=1)),
            grid=(pb // BLOCK_B,),
            in_specs=[pl.BlockSpec((BLOCK_B, BLOCK_L), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((BLOCK_B, BLOCK_L), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((pb, BLOCK_L), x.dtype),
            interpret=True,
        )(x_blk)

    blocks = xp.reshape(pb, num_blocks, BLOCK_L).transpose(1, 0, 2)

    def scan_step(carry, blk):
        pref = one_block(blk) + carry[:, None]
        return pref[:, -1], pref

    _, prefs = jax.lax.scan(scan_step, jnp.zeros((pb,), x.dtype), blocks)
    out = prefs.transpose(1, 0, 2).reshape(pb, pl_len)
    return out[:b, :l]


@functools.lru_cache(maxsize=None)
def kernel_names() -> tuple[str, ...]:
    """Names of the exported mass operations (must match the L2 model and
    the rust runtime's artifact manifest)."""
    return ("sumup", "mass_for", "dot", "prefix")
