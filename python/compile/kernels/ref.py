"""Pure-jnp oracles for the L1 mass-processing kernels.

The correctness contract of the build: every Pallas kernel in
``mass.py`` must match its oracle here to float tolerance across the
shape/dtype sweep in ``python/tests/test_kernel.py``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def sumup(x: jax.Array) -> jax.Array:
    """out[b] = sum_l x[b, l]."""
    return jnp.sum(x, axis=-1)


def mass_for(x: jax.Array, scale_bias: jax.Array) -> jax.Array:
    """out = scale * x + bias with scale_bias = [scale, bias]."""
    return x * scale_bias[0] + scale_bias[1]


def dot(a: jax.Array, b: jax.Array) -> jax.Array:
    """out[b] = sum_l a[b, l] * b[b, l]."""
    return jnp.sum(a * b, axis=-1)


def prefix(x: jax.Array) -> jax.Array:
    """out[b, l] = sum_{l' <= l} x[b, l']."""
    return jnp.cumsum(x, axis=-1)
