"""Layer-2 accelerator model: the compute graph the rust fabric executes.

Each entry point is a jax function over fixed *bucket* shapes (the fabric
batcher pads requests into buckets), calling the L1 Pallas kernels so they
lower into the same HLO module. ``aot.py`` lowers every (entry, bucket)
pair to an HLO text artifact the rust runtime loads at startup.

The fused ``sumup_stats`` entry is the fabric's workhorse: one pass
producing per-row sum, mean and L2 norm (the norm reuses the dot kernel on
x·x), demonstrating that mass operations compose inside a single lowered
module — the accelerator-side analogue of nested QTs.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from .kernels import mass

# (B, L) buckets the fabric batcher pads into (smallest-fit selection on
# the rust side). §Perf: the 2-bucket grid padded a (32, 256) batch to
# (32, 1024) — 4x wasted elements; the 4-bucket grid caps padding waste
# at <2x for any request within range.
BUCKETS: tuple[tuple[int, int], ...] = ((8, 256), (8, 1024), (32, 256), (32, 1024))


def sumup(x: jax.Array) -> tuple[jax.Array]:
    """Batched SUMUP (§5.2): per-row sums of a (B, L) batch."""
    return (mass.mass_sumup(x),)


def mass_for(x: jax.Array, scale_bias: jax.Array) -> tuple[jax.Array]:
    """Batched FOR (§5.1): elementwise scale*x + bias."""
    return (mass.mass_for(x, scale_bias),)


def dot(a: jax.Array, b: jax.Array) -> tuple[jax.Array]:
    """Batched row-wise dot product (§3.7 mass operating mode)."""
    return (mass.mass_dot(a, b),)


def prefix(x: jax.Array) -> tuple[jax.Array]:
    """Batched prefix sums (FOR-mode partial sums, §5.1)."""
    return (mass.mass_prefix(x),)


def sumup_stats(x: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused statistics: (sum, mean, l2norm) per row in one module."""
    s = mass.mass_sumup(x)
    n = x.shape[-1]
    mean = s / jnp.asarray(n, x.dtype)
    sq = mass.mass_dot(x, x)
    return (s, mean, jnp.sqrt(sq))


#: entry name -> (function, example-args builder over a bucket)
ENTRIES: dict[str, tuple[Callable, Callable[[tuple[int, int]], tuple]] ] = {
    "sumup": (
        sumup,
        lambda bl: (jax.ShapeDtypeStruct(bl, jnp.float32),),
    ),
    "mass_for": (
        mass_for,
        lambda bl: (
            jax.ShapeDtypeStruct(bl, jnp.float32),
            jax.ShapeDtypeStruct((2,), jnp.float32),
        ),
    ),
    "dot": (
        dot,
        lambda bl: (
            jax.ShapeDtypeStruct(bl, jnp.float32),
            jax.ShapeDtypeStruct(bl, jnp.float32),
        ),
    ),
    "prefix": (
        prefix,
        lambda bl: (jax.ShapeDtypeStruct(bl, jnp.float32),),
    ),
    "sumup_stats": (
        sumup_stats,
        lambda bl: (jax.ShapeDtypeStruct(bl, jnp.float32),),
    ),
}


def artifact_name(entry: str, bucket: tuple[int, int]) -> str:
    """Canonical artifact file stem for an (entry, bucket) pair."""
    return f"{entry}_b{bucket[0]}_l{bucket[1]}"
