"""AOT lowering: jax → HLO text artifacts for the rust PJRT runtime.

HLO *text* (not serialized HloModuleProto) is the interchange format: the
image's xla_extension 0.5.1 rejects jax≥0.5 protos with 64-bit instruction
ids; the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Usage::

    python -m compile.aot --out-dir ../artifacts

Emits one ``<entry>_b<B>_l<L>.hlo.txt`` per (entry, bucket) pair plus a
``manifest.tsv`` (tab-separated: name, entry, B, L, arity, out_arity) the
rust runtime reads to know what it loaded. Python runs ONCE, at build
time; the rust binary is self-contained afterwards.
"""

from __future__ import annotations

import argparse
import pathlib

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """Lower a jitted function's StableHLO to XLA HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(entry: str, bucket: tuple[int, int]) -> str:
    fn, args_of = model.ENTRIES[entry]
    example_args = args_of(bucket)
    lowered = jax.jit(fn).lower(*example_args)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts", help="artifact output directory")
    ap.add_argument(
        "--entries",
        default=",".join(model.ENTRIES),
        help="comma-separated entry names (default: all)",
    )
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)

    manifest_rows = []
    for entry in args.entries.split(","):
        if entry not in model.ENTRIES:
            raise SystemExit(f"unknown entry {entry!r}; have {sorted(model.ENTRIES)}")
        fn, args_of = model.ENTRIES[entry]
        for bucket in model.BUCKETS:
            name = model.artifact_name(entry, bucket)
            text = lower_entry(entry, bucket)
            path = out_dir / f"{name}.hlo.txt"
            path.write_text(text)
            arity = len(args_of(bucket))
            out_arity = _out_arity(fn, args_of(bucket))
            manifest_rows.append((name, entry, bucket[0], bucket[1], arity, out_arity))
            print(f"wrote {path} ({len(text)} chars)")

    manifest = out_dir / "manifest.tsv"
    with manifest.open("w") as f:
        f.write("# name\tentry\tB\tL\tarity\tout_arity\n")
        for row in manifest_rows:
            f.write("\t".join(str(x) for x in row) + "\n")
    print(f"wrote {manifest} ({len(manifest_rows)} artifacts)")


def _out_arity(fn, example_args) -> int:
    """Number of outputs, from the abstract evaluation."""
    shapes = jax.eval_shape(fn, *example_args)
    return len(shapes) if isinstance(shapes, (tuple, list)) else 1


if __name__ == "__main__":
    main()
