"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

This is the CORE correctness signal of the python side of the build —
the AOT artifacts embed these kernels, and the rust runtime trusts them.
A hand-rolled shape sweep stands in for hypothesis (offline image).
"""

from __future__ import annotations

import numpy as np
import pytest
import jax.numpy as jnp

from compile.kernels import mass, ref

# Shape sweep: aligned, unaligned in B, unaligned in L, tiny, large.
SHAPES = [
    (1, 1),
    (1, 128),
    (3, 7),
    (8, 128),
    (8, 256),
    (5, 130),
    (9, 127),
    (16, 384),
    (32, 1024),
    (2, 2048),
]

DTYPES = [jnp.float32, jnp.int32]


def _rand(shape, dtype, seed):
    rng = np.random.default_rng(seed)
    if dtype == jnp.int32:
        return jnp.asarray(rng.integers(-1000, 1000, size=shape), dtype=dtype)
    return jnp.asarray(rng.standard_normal(shape), dtype=dtype)


def _tol(dtype, l):
    if dtype == jnp.int32:
        return dict(atol=0, rtol=0)
    # fp32 reduction error grows ~sqrt(L)
    return dict(atol=1e-4 * max(1.0, l) ** 0.5, rtol=1e-5)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_sumup_matches_ref(shape, dtype):
    x = _rand(shape, dtype, 1)
    got = mass.mass_sumup(x)
    want = ref.sumup(x)
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **_tol(dtype, shape[1]))


@pytest.mark.parametrize("shape", SHAPES)
def test_mass_for_matches_ref(shape):
    x = _rand(shape, jnp.float32, 2)
    sb = jnp.asarray([1.5, -0.25], jnp.float32)
    got = mass.mass_for(x, sb)
    want = ref.mass_for(x, sb)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_dot_matches_ref(shape, dtype):
    a = _rand(shape, dtype, 3)
    b = _rand(shape, dtype, 4)
    got = mass.mass_dot(a, b)
    want = ref.dot(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **_tol(dtype, shape[1]))


@pytest.mark.parametrize("shape", SHAPES)
def test_prefix_matches_ref(shape):
    x = _rand(shape, jnp.float32, 5)
    got = mass.mass_prefix(x)
    want = ref.prefix(x)
    assert got.shape == x.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)


def test_sumup_zero_length_rows():
    # degenerate but legal: B rows, L=0 → zeros. (The EMPA engine's N=0
    # case on the rust side mirrors this.)
    x = jnp.zeros((4, 0), jnp.float32)
    np.testing.assert_array_equal(np.asarray(ref.sumup(x)), np.zeros(4, np.float32))


@pytest.mark.parametrize("seed", range(8))
def test_sumup_randomised_property(seed):
    """Property: permuting elements within a row never changes the sum
    (int32: exact, mirroring the EMPA SUMUP order-independence)."""
    rng = np.random.default_rng(seed)
    b = int(rng.integers(1, 12))
    l = int(rng.integers(1, 400))
    x = rng.integers(-10_000, 10_000, size=(b, l)).astype(np.int32)
    perm = rng.permutation(l)
    a = np.asarray(mass.mass_sumup(jnp.asarray(x)))
    p = np.asarray(mass.mass_sumup(jnp.asarray(x[:, perm])))
    np.testing.assert_array_equal(a, p)


@pytest.mark.parametrize("seed", range(8))
def test_dot_linearity_property(seed):
    """Property: dot(a, b+c) == dot(a, b) + dot(a, c) (int32 exact)."""
    rng = np.random.default_rng(100 + seed)
    b = int(rng.integers(1, 10))
    l = int(rng.integers(1, 300))
    a = jnp.asarray(rng.integers(-100, 100, size=(b, l)), jnp.int32)
    u = rng.integers(-100, 100, size=(b, l)).astype(np.int32)
    v = rng.integers(-100, 100, size=(b, l)).astype(np.int32)
    lhs = np.asarray(mass.mass_dot(a, jnp.asarray(u + v)))
    rhs = np.asarray(mass.mass_dot(a, jnp.asarray(u))) + np.asarray(mass.mass_dot(a, jnp.asarray(v)))
    np.testing.assert_array_equal(lhs, rhs)


def test_prefix_last_column_equals_sumup():
    """Cross-kernel invariant: prefix[:, -1] == sumup (the final partial
    sum is the total — §5.2's 'the partial sum is never used, we are only
    interested in the final sum')."""
    x = _rand((6, 515), jnp.float32, 9)
    pref = np.asarray(mass.mass_prefix(x))
    s = np.asarray(mass.mass_sumup(x))
    np.testing.assert_allclose(pref[:, -1], s, rtol=1e-4, atol=1e-3)
