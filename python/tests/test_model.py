"""L2 model: entry-point shapes, fused stats, and AOT lowering sanity."""

from __future__ import annotations

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile import aot, model
from compile.kernels import ref


def test_buckets_are_tile_aligned():
    from compile.kernels.mass import BLOCK_B, BLOCK_L

    for b, l in model.BUCKETS:
        assert b % BLOCK_B == 0, f"bucket B={b} not a multiple of {BLOCK_B}"
        assert l % BLOCK_L == 0, f"bucket L={l} not a multiple of {BLOCK_L}"


@pytest.mark.parametrize("bucket", model.BUCKETS)
def test_entry_output_shapes(bucket):
    b, l = bucket
    for name, (fn, args_of) in model.ENTRIES.items():
        shapes = jax.eval_shape(fn, *args_of(bucket))
        assert isinstance(shapes, tuple), name
        for s in shapes:
            assert s.shape in [(b,), (b, l)], f"{name}: unexpected {s.shape}"


def test_sumup_stats_consistency():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((8, 256)), jnp.float32)
    s, mean, norm = model.sumup_stats(x)
    np.testing.assert_allclose(np.asarray(s), np.asarray(ref.sumup(x)), rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(mean), np.asarray(s) / 256.0, rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(norm), np.linalg.norm(np.asarray(x), axis=1), rtol=1e-4, atol=1e-4
    )


@pytest.mark.parametrize("entry", sorted(model.ENTRIES))
def test_aot_lowering_produces_hlo_text(entry):
    text = aot.lower_entry(entry, model.BUCKETS[0])
    assert "HloModule" in text, "not HLO text"
    assert "ROOT" in text
    # return_tuple=True: the module root is a tuple
    assert "tuple(" in text or "(f32[" in text


def test_artifact_names_are_unique_and_stable():
    names = [model.artifact_name(e, b) for e in model.ENTRIES for b in model.BUCKETS]
    assert len(names) == len(set(names))
    assert model.artifact_name("sumup", (8, 256)) == "sumup_b8_l256"


def test_aot_main_writes_artifacts(tmp_path):
    import sys
    from unittest import mock

    argv = ["aot", "--out-dir", str(tmp_path), "--entries", "sumup"]
    with mock.patch.object(sys, "argv", argv):
        aot.main()
    files = sorted(p.name for p in tmp_path.iterdir())
    assert "manifest.tsv" in files
    for b, l in model.BUCKETS:
        assert f"sumup_b{b}_l{l}.hlo.txt" in files
    manifest = (tmp_path / "manifest.tsv").read_text().strip().splitlines()
    assert manifest[0].startswith("#")
    rows = [line.split("\t") for line in manifest[1:]]
    assert all(row[1] == "sumup" and row[4] == "1" and row[5] == "1" for row in rows)
