"""Structural performance-model sanity: the L1 kernels must remain
VMEM-light, memory-bound streaming kernels — if a BlockSpec change makes a
kernel blow the VMEM budget or flip to compute-bound, these tests flag it
(the structural regression test for the §Perf deliverable)."""

from __future__ import annotations

from compile import vmem


def test_every_kernel_is_estimated():
    names = {e.name for e in vmem.estimates()}
    assert names == {"sumup", "mass_for", "dot", "prefix", "sumup_stats"}


def test_vmem_footprint_leaves_double_buffer_headroom():
    for e in vmem.estimates():
        assert e.vmem_fraction < 0.05, f"{e.name}: {e.vmem_fraction:.1%} of VMEM"


def test_streaming_kernels_are_memory_bound():
    for e in vmem.estimates():
        assert e.bound == "memory", f"{e.name} flipped to compute-bound"
        # attainable throughput is the bandwidth roofline
        assert abs(e.attainable_flops - e.arithmetic_intensity * vmem.HBM_BW) < 1e-6


def test_dot_moves_twice_the_bytes_of_sumup():
    by = {e.name: e for e in vmem.estimates()}
    assert by["dot"].bytes_per_elem == 2 * by["sumup"].bytes_per_elem
    # so its element throughput is half
    assert abs(by["dot"].streaming_throughput_geps - by["sumup"].streaming_throughput_geps / 2) < 1e-9


def test_report_renders():
    r = vmem.report()
    assert "sumup_stats" in r and "memory" in r and "%" in r
