//! Chaos-plane integration tests: deterministic fault plans, typed
//! outcomes for every injected fault kind, worker panic safety, the
//! retry ladder, and the registry's failover accounting under injected
//! init faults. Everything runs against a real fabric; nothing here
//! touches the network (the wire-site tests live in `serve_tcp.rs`).

use empa::accel::{Accelerator, NativeAccel};
use empa::api::{FabricError, JobRequest, Output, RequestKind, RetryPolicy};
use empa::chaos::{ChaosConfig, FaultKind, Site};
use empa::coordinator::{
    Backend, BackendClass, BackendJob, BackendRegistry, BackendReply, Fabric, FabricConfig,
    SimBackend,
};
use empa::workload::sumup::Mode;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

fn sumup(i: i32) -> JobRequest {
    JobRequest::new(RequestKind::sumup(Mode::Sumup, vec![i, i + 1, i + 2])).with_client("chaos")
}

/// Run `n` program jobs sequentially (single worker, closed loop) on a
/// chaos-armed fabric and return (fault plan, outcome transcript).
fn run_once(chaos: ChaosConfig, n: i32) -> (empa::chaos::FaultPlan, Vec<String>) {
    let cfg = FabricConfig { sim_workers: 1, chaos, ..Default::default() };
    let fabric = Fabric::start_local(cfg);
    let mut outcomes = Vec::new();
    for i in 0..n {
        let r = fabric.submit(sumup(i)).expect("submit").wait();
        outcomes.push(match r {
            Ok(c) => match c.output {
                Output::Program { eax, .. } => format!("ok:{eax}"),
                other => format!("ok:?{other:?}"),
            },
            Err(e) => format!("err:{e}"),
        });
    }
    let plan = fabric.chaos().expect("chaos armed").plan();
    fabric.shutdown();
    (plan, outcomes)
}

#[test]
fn same_seed_replays_the_identical_plan_and_outcomes() {
    // Sequential closed loop => the per-site decision counts are
    // deterministic, so the whole run — which jobs fault, with what
    // kind, and every job's outcome — must replay bit-for-bit.
    let (plan_a, out_a) = run_once(ChaosConfig::uniform(11, 0.6), 12);
    let (plan_b, out_b) = run_once(ChaosConfig::uniform(11, 0.6), 12);
    assert!(!plan_a.is_empty(), "rate 0.6 over 12 jobs must inject something");
    assert_eq!(plan_a, plan_b, "fault plan is not seed-deterministic");
    assert_eq!(out_a, out_b, "job outcomes diverged under the same plan");

    // A different seed draws a different plan (overwhelmingly likely;
    // equal plans here would mean the seed is ignored).
    let (plan_c, _) = run_once(ChaosConfig::uniform(12, 0.6), 12);
    assert_ne!(plan_a, plan_c, "seed does not influence the plan");
}

#[test]
fn chaos_off_fabric_has_no_engine_and_serves_normally() {
    let fabric = Fabric::start_local(FabricConfig { sim_workers: 1, ..Default::default() });
    assert!(fabric.chaos().is_none(), "default config must not build an engine");
    let c = fabric.submit(sumup(1)).unwrap().wait().expect("clean run completes");
    match c.output {
        Output::Program { eax, .. } => assert_eq!(eax, 6),
        other => panic!("expected program output, got {other:?}"),
    }
    fabric.shutdown();
}

#[test]
fn injected_backend_error_is_typed_and_counted() {
    let chaos = ChaosConfig::site(3, Site::Backend, 1.0, vec![FaultKind::BackendError]);
    let fabric = Fabric::start_local(FabricConfig { sim_workers: 1, chaos, ..Default::default() });
    match fabric.submit(sumup(1)).unwrap().wait() {
        Err(FabricError::Backend { msg, .. }) => {
            assert!(msg.contains("chaos"), "fault should self-identify: {msg}")
        }
        other => panic!("expected injected Backend error, got {other:?}"),
    }
    assert!(fabric.metrics.chaos_backend_faults.load(Ordering::Relaxed) >= 1);
    fabric.shutdown();
}

#[test]
fn injected_backend_panic_is_caught_and_the_lane_stays_alive() {
    let chaos = ChaosConfig::site(4, Site::Backend, 0.5, vec![FaultKind::BackendPanic]);
    let fabric = Fabric::start_local(FabricConfig { sim_workers: 1, chaos, ..Default::default() });
    let mut panicked = 0;
    let mut completed = 0;
    for i in 0..12 {
        match fabric.submit(sumup(i)).unwrap().wait() {
            Err(FabricError::Backend { msg, .. }) if msg.contains("panicked") => panicked += 1,
            Ok(_) => completed += 1,
            other => panic!("expected completion or caught panic, got {other:?}"),
        }
    }
    assert!(panicked >= 1, "rate 0.5 over 12 jobs should panic at least once");
    assert!(completed >= 1, "the worker must keep serving after a caught panic");
    assert_eq!(fabric.metrics.worker_panics.load(Ordering::Relaxed), panicked as u64);
    fabric.shutdown();
}

#[test]
fn wrong_result_fault_perturbs_but_completes() {
    let chaos = ChaosConfig::site(5, Site::Backend, 1.0, vec![FaultKind::WrongResult]);
    let fabric = Fabric::start_local(FabricConfig { sim_workers: 1, chaos, ..Default::default() });
    let c = fabric.submit(sumup(1)).unwrap().wait().expect("wrong-result still completes");
    match c.output {
        // 1+2+3 = 6; the perturbation bumps eax by one.
        Output::Program { eax, .. } => assert_eq!(eax, 7, "expected a perturbed sum"),
        other => panic!("expected program output, got {other:?}"),
    }
    fabric.shutdown();
}

#[test]
fn worker_stall_delays_but_completes_the_job() {
    let chaos = ChaosConfig::site(6, Site::Dispatch, 1.0, vec![FaultKind::WorkerStall { ms: 1 }]);
    let fabric = Fabric::start_local(FabricConfig { sim_workers: 1, chaos, ..Default::default() });
    let c = fabric.submit(sumup(1)).unwrap().wait().expect("stalled job still completes");
    match c.output {
        Output::Program { eax, .. } => assert_eq!(eax, 6),
        other => panic!("expected program output, got {other:?}"),
    }
    assert!(fabric.metrics.chaos_worker_stalls.load(Ordering::Relaxed) >= 1);
    fabric.shutdown();
}

#[test]
fn injected_guest_fault_is_typed_and_terminal() {
    let chaos = ChaosConfig::site(7, Site::Guest, 1.0, vec![FaultKind::GuestFault]);
    let fabric = Fabric::start_local(FabricConfig { sim_workers: 1, chaos, ..Default::default() });
    match fabric.submit(sumup(1)).unwrap().wait() {
        Err(e @ FabricError::GuestFault(_)) => {
            assert!(!e.retryable(), "a guest fault re-fails deterministically; never retry it");
            assert!(format!("{e}").contains("chaos"), "fault should self-identify: {e}");
        }
        other => panic!("expected injected GuestFault, got {other:?}"),
    }
    assert!(fabric.metrics.chaos_guest_faults.load(Ordering::Relaxed) >= 1);
    fabric.shutdown();
}

/// Fails its first `fail_first` executes with a retryable Backend error,
/// then serves normally — the retry ladder's happy customer.
struct FlakyBackend {
    calls: AtomicU64,
    fail_first: u64,
}

impl Backend for FlakyBackend {
    fn name(&self) -> &str {
        "flaky"
    }
    fn execute(&self, _job: BackendJob) -> Result<BackendReply, FabricError> {
        let n = self.calls.fetch_add(1, Ordering::SeqCst);
        if n < self.fail_first {
            return Err(FabricError::Backend {
                name: "flaky".into(),
                msg: format!("transient failure {n}"),
            });
        }
        Ok(BackendReply::Program { eax: 99, clocks: 1, cores: 1, data: vec![] })
    }
}

#[test]
fn call_with_retry_rides_out_transient_backend_faults() {
    let registry = BackendRegistry::new().register(
        "flaky",
        BackendClass::Program,
        Box::new(|| {
            Ok(Box::new(FlakyBackend { calls: AtomicU64::new(0), fail_first: 2 })
                as Box<dyn Backend>)
        }),
    );
    let fabric =
        Fabric::start(FabricConfig { sim_workers: 1, ..Default::default() }, registry);
    let client = fabric.client();
    let policy = RetryPolicy::default().with_attempts(5);
    let c = client.call_with_retry(sumup(1), &policy).expect("retries reach the good call");
    match c.output {
        Output::Program { eax, .. } => assert_eq!(eax, 99),
        other => panic!("expected program output, got {other:?}"),
    }
    assert_eq!(fabric.metrics.retries.load(Ordering::Relaxed), 2);
    assert_eq!(fabric.metrics.client("chaos").retries.load(Ordering::Relaxed), 2);
    assert_eq!(fabric.metrics.retry_exhausted.load(Ordering::Relaxed), 0);
    fabric.shutdown();
}

#[test]
fn retry_exhaustion_surfaces_the_last_typed_error() {
    let registry = BackendRegistry::new().register(
        "flaky",
        BackendClass::Program,
        Box::new(|| {
            Ok(Box::new(FlakyBackend { calls: AtomicU64::new(0), fail_first: u64::MAX })
                as Box<dyn Backend>)
        }),
    );
    let fabric =
        Fabric::start(FabricConfig { sim_workers: 1, ..Default::default() }, registry);
    let client = fabric.client();
    let policy = RetryPolicy::default().with_attempts(3);
    match client.call_with_retry(sumup(1), &policy) {
        Err(FabricError::Backend { name, .. }) => assert_eq!(name, "flaky"),
        other => panic!("expected exhausted Backend error, got {other:?}"),
    }
    assert_eq!(fabric.metrics.retries.load(Ordering::Relaxed), 2, "attempts 2 and 3");
    assert_eq!(fabric.metrics.retry_exhausted.load(Ordering::Relaxed), 1);
    fabric.shutdown();
}

/// Panics on every execute — the satellite regression for worker panic
/// safety: the job must resolve with a typed error (not `Shutdown` from
/// a vanished reply sender), the panic must be counted, and the lane
/// must survive to serve the next job.
struct AlwaysPanics;

impl Backend for AlwaysPanics {
    fn name(&self) -> &str {
        "grenade"
    }
    fn execute(&self, _job: BackendJob) -> Result<BackendReply, FabricError> {
        panic!("deliberate test panic");
    }
}

#[test]
fn panicking_registry_backend_yields_typed_errors_not_dead_lanes() {
    let registry = BackendRegistry::new().register(
        "grenade",
        BackendClass::Program,
        Box::new(|| Ok(Box::new(AlwaysPanics) as Box<dyn Backend>)),
    );
    let fabric =
        Fabric::start(FabricConfig { sim_workers: 1, ..Default::default() }, registry);
    for i in 0..3 {
        match fabric.submit(sumup(i)).unwrap().wait() {
            Err(FabricError::Backend { name, msg }) => {
                assert_eq!(name, "grenade");
                assert!(
                    msg.contains("panicked") && msg.contains("deliberate test panic"),
                    "payload should surface: {msg}"
                );
            }
            other => panic!("job {i}: expected typed Backend error, got {other:?}"),
        }
    }
    assert_eq!(fabric.metrics.worker_panics.load(Ordering::Relaxed), 3);
    fabric.shutdown();
}

// ----------------------------------------------------------------------
// registry failover accounting under injected init faults (satellite)
// ----------------------------------------------------------------------

fn failing_init_factory() -> empa::coordinator::BackendFactory {
    Box::new(|| anyhow::bail!("injected init fault"))
}

#[test]
fn fail_then_succeed_chain_counts_exactly_one_init_failover() {
    let empa_cfg = FabricConfig::default().empa;
    let registry = BackendRegistry::new()
        .register("bad", BackendClass::Program, failing_init_factory())
        .register(
            "sim",
            BackendClass::Program,
            Box::new(move || Ok(Box::new(SimBackend::new(empa_cfg.clone())) as Box<dyn Backend>)),
        );
    let fabric =
        Fabric::start(FabricConfig { sim_workers: 1, ..Default::default() }, registry);
    let c = fabric.submit(sumup(1)).unwrap().wait().expect("failover serves the job");
    match c.output {
        Output::Program { eax, .. } => assert_eq!(eax, 6),
        other => panic!("expected program output, got {other:?}"),
    }
    let m = &fabric.metrics;
    assert_eq!(m.backend("bad").init_failures.load(Ordering::Relaxed), 1);
    assert_eq!(m.backend("sim").init_ok.load(Ordering::Relaxed), 1);
    assert_eq!(m.failovers.load(Ordering::Relaxed), 1, "one entry failed over, once");
    fabric.shutdown();
}

#[test]
fn all_fail_chain_is_a_typed_error_not_a_failover() {
    let registry = BackendRegistry::new()
        .register("bad-a", BackendClass::Program, failing_init_factory())
        .register("bad-b", BackendClass::Program, failing_init_factory());
    let fabric =
        Fabric::start(FabricConfig { sim_workers: 1, ..Default::default() }, registry);
    match fabric.submit(sumup(1)).unwrap().wait() {
        Err(FabricError::Backend { msg, .. }) => {
            assert!(msg.contains("init"), "init failure should say so: {msg}")
        }
        other => panic!("expected typed Backend error, got {other:?}"),
    }
    let m = &fabric.metrics;
    assert_eq!(m.backend("bad-a").init_failures.load(Ordering::Relaxed), 1);
    assert_eq!(m.backend("bad-b").init_failures.load(Ordering::Relaxed), 1);
    assert_eq!(m.failovers.load(Ordering::Relaxed), 0, "nothing failed *over*");
    fabric.shutdown();
}

#[test]
fn mass_chain_failover_counts_once_per_failed_batch() {
    // Mass class: a dead-on-init entry ahead of the native accelerator.
    let registry = BackendRegistry::new()
        .register("bad-mass", BackendClass::Mass, failing_init_factory())
        .register_accel("native", || Ok(Box::new(NativeAccel) as Box<dyn Accelerator>));
    let fabric =
        Fabric::start(FabricConfig { sim_workers: 1, ..Default::default() }, registry);
    let c = fabric
        .submit(JobRequest::new(RequestKind::mass_sum(vec![2.0f32; 64])).with_client("chaos"))
        .unwrap()
        .wait()
        .expect("mass failover serves the batch");
    match &c.output {
        Output::Scalars(v) => assert!((v[0] - 128.0).abs() < 1e-3),
        other => panic!("expected scalars, got {other:?}"),
    }
    let m = &fabric.metrics;
    assert_eq!(m.backend("bad-mass").init_failures.load(Ordering::Relaxed), 1);
    assert!(m.failovers.load(Ordering::Relaxed) >= 1, "the failed entry must be counted");
    fabric.shutdown();
}
