//! Loopback integration tests for the serve plane: a real [`ServePlane`]
//! on 127.0.0.1, driven through [`WireClient`] over actual TCP sockets.
//! Covers the submit→completion happy path, quota denial, SLO shedding,
//! malformed-frame handling, and the per-tenant ledger.

use empa::api::{FabricError, JobRequest, Output, Priority, RequestKind};
use empa::coordinator::FabricConfig;
use empa::serve::wire::write_frame;
use empa::serve::{
    QuotaConfig, ServeConfig, ServePlane, SloAction, SloConfig, SloRule, WireClient, WireReply,
    MAX_FRAME,
};
use empa::workload::Mode;
use std::time::Duration;

fn plane_with(quota: QuotaConfig, slo: SloConfig) -> ServePlane {
    ServePlane::start(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        fabric: FabricConfig { sim_workers: 2, ..Default::default() },
        quota,
        slo,
        max_frame: MAX_FRAME,
        auth_token: None,
    })
    .expect("serve plane binds loopback")
}

/// An SLO config whose single rule never trips (threshold above any
/// observable value) — the tests that aren't about shedding use it so a
/// backlog spike can't turn into a surprise refusal.
fn quiet_slo() -> SloConfig {
    SloConfig {
        rules: vec![SloRule {
            name: "never",
            source: "FabricMetrics.submitted",
            query: |_, _| 0.0,
            threshold: f64::INFINITY,
            clear_below: 0.0,
            interpretation: "unreachable",
            action: SloAction::Shed,
        }],
        eval_every: Duration::ZERO,
    }
}

#[test]
fn submit_and_complete_over_tcp() {
    let plane = plane_with(QuotaConfig::default(), quiet_slo());
    let mut c = WireClient::connect(plane.local_addr()).unwrap();

    // A program job through the simulated EMPA pool…
    let sum = c
        .call(&JobRequest::new(RequestKind::sumup(Mode::Sumup, vec![1, 2, 3, 4])).with_client("it"))
        .unwrap()
        .expect("program completes");
    match &sum.output {
        Output::Program { eax, .. } => assert_eq!(*eax, 10),
        other => panic!("expected program output, got {other:?}"),
    }

    // …and a mass op through the accelerator chain, over the same socket.
    let mass = c
        .call(&JobRequest::new(RequestKind::mass_sum(vec![2.0f32; 64])).with_client("it"))
        .unwrap()
        .expect("mass op completes");
    match &mass.output {
        Output::Scalars(v) => assert!((v[0] - 128.0).abs() < 1e-3),
        other => panic!("expected scalars, got {other:?}"),
    }

    plane.shutdown();
}

#[test]
fn quota_denial_is_a_typed_wire_error_and_counted() {
    // greedy's bucket never refills and holds exactly one token; the
    // default shape is unlimited.
    let plane = plane_with(QuotaConfig::default().with_override("greedy", 0.0, 1.0), quiet_slo());
    let addr = plane.local_addr();
    let mut c = WireClient::connect(addr).unwrap();

    let job = |tag: &str| JobRequest::new(RequestKind::sumup(Mode::No, vec![1])).with_client(tag);

    assert!(c.call(&job("greedy")).unwrap().is_ok(), "first token admits");
    for _ in 0..3 {
        match c.call(&job("greedy")).unwrap() {
            Err(FabricError::QuotaExceeded { tenant }) => assert_eq!(tenant, "greedy"),
            other => panic!("expected QuotaExceeded, got {other:?}"),
        }
    }
    // Another tenant on the same plane is untouched by greedy's bucket.
    assert!(c.call(&job("patient")).unwrap().is_ok());

    let text = WireClient::connect(addr).unwrap().metrics().unwrap();
    assert!(text.contains("quota_denied=3"), "global counter in:\n{text}");
    assert!(
        text.contains("greedy[submitted=4 accepted=1 shed=0 quota_denied=3]"),
        "greedy ledger in:\n{text}"
    );
    assert!(
        text.contains("patient[submitted=1 accepted=1 shed=0 quota_denied=0]"),
        "patient ledger in:\n{text}"
    );
    plane.shutdown();
}

#[test]
fn slo_shed_refuses_by_priority_and_names_the_rule() {
    // A rule that is always tripped: observed 1.0 > threshold -1.0.
    let always = SloConfig {
        rules: vec![SloRule {
            name: "always-shed",
            source: "test",
            query: |_, _| 1.0,
            threshold: -1.0,
            clear_below: -2.0,
            interpretation: "test rule that always trips",
            action: SloAction::Shed,
        }],
        eval_every: Duration::ZERO,
    };
    let plane = plane_with(QuotaConfig::default(), always);
    let addr = plane.local_addr();
    let mut c = WireClient::connect(addr).unwrap();

    let job = |p: Priority| {
        JobRequest::new(RequestKind::sumup(Mode::No, vec![2])).with_priority(p).with_client("t")
    };

    // Shed refuses Low and Normal…
    for p in [Priority::Low, Priority::Normal] {
        match c.call(&job(p)).unwrap() {
            Err(FabricError::Overloaded { rule }) => assert_eq!(rule, "always-shed"),
            other => panic!("expected Overloaded, got {other:?}"),
        }
    }
    // …but High still lands (shed is load-shedding, not an outage).
    assert!(c.call(&job(Priority::High)).unwrap().is_ok());

    let text = WireClient::connect(addr).unwrap().metrics().unwrap();
    assert!(text.contains("slo_shed=2"), "shed counter in:\n{text}");
    assert!(text.contains("always-shed"), "rule in playbook:\n{text}");
    assert!(text.contains("TRIPPED"), "tripped state in playbook:\n{text}");
    assert!(
        text.contains("t[submitted=3 accepted=1 shed=2 quota_denied=0]"),
        "tenant ledger in:\n{text}"
    );
    plane.shutdown();
}

#[test]
fn malformed_frame_gets_a_typed_error_not_a_hang() {
    let plane = plane_with(QuotaConfig::default(), quiet_slo());
    let mut raw = std::net::TcpStream::connect(plane.local_addr()).unwrap();

    // A well-framed payload that is not a valid message.
    write_frame(&mut raw, &[0xde, 0xad, 0xbe, 0xef], MAX_FRAME).unwrap();

    // The server answers with Failed{id:0} and then closes.
    let mut reader = raw.try_clone().unwrap();
    let payload = empa::serve::wire::read_frame(&mut reader, MAX_FRAME)
        .unwrap()
        .expect("one reply before close");
    match empa::serve::wire::decode_reply(&payload).unwrap() {
        WireReply::Failed { id, error } => {
            assert_eq!(id, 0);
            assert!(matches!(error, FabricError::InvalidConfig(_)), "got {error:?}");
        }
        other => panic!("expected Failed, got {other:?}"),
    }
    assert!(
        empa::serve::wire::read_frame(&mut reader, MAX_FRAME).unwrap().is_none(),
        "connection closes after a malformed frame"
    );
    plane.shutdown();
}

#[test]
fn pipelined_submits_all_get_replies() {
    let plane = plane_with(QuotaConfig::default(), quiet_slo());
    let mut c = WireClient::connect(plane.local_addr()).unwrap();

    let n = 32;
    let mut ids = Vec::new();
    for i in 0..n {
        let req = JobRequest::new(RequestKind::sumup(Mode::For, vec![i, i + 1])).with_client("pipe");
        ids.push(c.submit(&req).unwrap());
    }
    let mut seen = std::collections::HashSet::new();
    for _ in 0..n {
        match c.recv().unwrap().expect("reply before close") {
            WireReply::Completed { id, completion } => {
                assert!(seen.insert(id), "duplicate reply id {id}");
                match completion.output {
                    Output::Program { eax, .. } => {
                        let i = ids.iter().position(|&x| x == id).unwrap() as i32;
                        assert_eq!(eax, 2 * i + 1);
                    }
                    other => panic!("expected program output, got {other:?}"),
                }
            }
            other => panic!("expected Completed, got {other:?}"),
        }
    }
    assert_eq!(seen.len(), n as usize);
    plane.shutdown();
}

#[test]
fn auth_token_gates_submits_per_tenant() {
    let plane = ServePlane::start(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        fabric: FabricConfig { sim_workers: 2, ..Default::default() },
        quota: QuotaConfig::default(),
        slo: quiet_slo(),
        max_frame: MAX_FRAME,
        auth_token: Some("hunter2".to_string()),
    })
    .expect("serve plane binds loopback");
    let addr = plane.local_addr();

    let job = |tag: &str| JobRequest::new(RequestKind::sumup(Mode::No, vec![1, 2])).with_client(tag);

    // The right token is admitted and served.
    let mut good = WireClient::connect(addr).unwrap().with_token("hunter2");
    assert!(good.call(&job("good")).unwrap().is_ok(), "token holder gets served");

    // No token and a wrong token both get the typed refusal, naming the
    // tenant that asserted itself.
    let mut naked = WireClient::connect(addr).unwrap();
    let mut wrong = WireClient::connect(addr).unwrap().with_token("hunter3");
    for c in [&mut naked, &mut wrong] {
        match c.call(&job("sneaky")).unwrap() {
            Err(FabricError::Unauthorized { tenant }) => assert_eq!(tenant, "sneaky"),
            other => panic!("expected Unauthorized, got {other:?}"),
        }
    }

    // Refusals are ledgered globally and on the tenant's row; the
    // admitted tenant's bracket stays in the original format.
    let text = plane.metrics().render();
    assert!(text.contains("unauthorized=2"), "global counter in:\n{text}");
    assert!(
        text.contains("sneaky[submitted=2 accepted=0 shed=0 quota_denied=0 unauthorized=2]"),
        "sneaky ledger in:\n{text}"
    );
    assert!(
        text.contains("good[submitted=1 accepted=1 shed=0 quota_denied=0]"),
        "good ledger in:\n{text}"
    );
    plane.shutdown();
}

#[test]
fn mid_job_connection_drop_is_reaped_not_leaked() {
    // Submit and immediately drop the socket: the job is orphaned — its
    // reply has nowhere to go — but the fabric must still run it to
    // completion and the pump must reap it (write into the dead socket,
    // shrug, move on) rather than leak the in-flight entry or hang.
    let plane = plane_with(QuotaConfig::default(), quiet_slo());
    {
        let mut c = WireClient::connect(plane.local_addr()).unwrap();
        let req = JobRequest::new(RequestKind::sumup(Mode::Sumup, (0..64).collect()))
            .with_client("ghost");
        c.submit(&req).unwrap();
        // `c` drops here; the TCP connection closes under the job.
    }
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    loop {
        let done = plane.metrics().completed.load(std::sync::atomic::Ordering::Relaxed);
        if done >= 1 {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "orphaned job never completed; the pump leaked it"
        );
        std::thread::sleep(Duration::from_millis(10));
    }
    // A clean shutdown proves the pump thread didn't die on the dead
    // socket either.
    plane.shutdown();
}

#[test]
fn shutdown_with_inflight_jobs_joins_the_pump() {
    // Submit a burst and shut down WITHOUT reading any replies: the
    // completion pump must drain its parked jobs (the fabric resolves
    // them during shutdown) and its workers must join — the old
    // detached-waiter scheme could only abandon these threads.
    let plane = plane_with(QuotaConfig::default(), quiet_slo());
    let mut c = WireClient::connect(plane.local_addr()).unwrap();
    for i in 0..16 {
        let req = JobRequest::new(RequestKind::sumup(Mode::No, (i..i + 64).collect()))
            .with_client("rush");
        c.submit(&req).unwrap();
    }
    plane.shutdown();
}
