//! E1–E4: exact reproduction of the paper's Table 1 and the asymptotic
//! behaviour of Figs. 4–6, end-to-end through the assembler + simulator.

use empa::empa::EmpaConfig;
use empa::metrics::{fig4_series, fig5_series, fig6_series, table1};
use empa::workload::sumup::Mode;

/// Table 1 of the paper, verbatim.
/// (N, mode, time_clocks, k, speedup, S/k, alpha_eff)
const PAPER_TABLE1: &[(usize, Mode, u64, usize, f64, f64, f64)] = &[
    (1, Mode::No, 52, 1, 1.0, 1.0, 1.0),
    (1, Mode::For, 31, 2, 1.68, 0.84, 0.81),
    (1, Mode::Sumup, 33, 2, 1.58, 0.79, 0.73),
    (2, Mode::No, 82, 1, 1.0, 1.0, 1.0),
    (2, Mode::For, 42, 2, 1.95, 0.98, 0.97),
    (2, Mode::Sumup, 34, 3, 2.41, 0.80, 0.87),
    (4, Mode::No, 142, 1, 1.0, 1.0, 1.0),
    (4, Mode::For, 64, 2, 2.22, 1.11, 1.10),
    (4, Mode::Sumup, 36, 5, 3.94, 0.79, 0.93),
    (6, Mode::No, 202, 1, 1.0, 1.0, 1.0),
    (6, Mode::For, 86, 2, 2.34, 1.17, 1.15),
    (6, Mode::Sumup, 38, 7, 5.31, 0.76, 0.95),
];

#[test]
fn table1_clock_counts_and_core_counts_are_exact() {
    let rows = table1(&EmpaConfig::default());
    assert_eq!(rows.len(), PAPER_TABLE1.len());
    for (row, &(n, mode, t, k, _, _, _)) in rows.iter().zip(PAPER_TABLE1) {
        assert_eq!(row.n, n);
        assert_eq!(row.mode, mode);
        assert_eq!(row.clocks, t, "N={n} {mode:?}: clocks");
        assert_eq!(row.k, k, "N={n} {mode:?}: cores");
    }
}

#[test]
fn table1_derived_metrics_match_to_printed_precision() {
    // The paper prints two decimals (speedup, S/k, α_eff) with truncation
    // in places; allow one unit in the last printed digit.
    let rows = table1(&EmpaConfig::default());
    for (row, &(n, mode, _, _, s, sk, a)) in rows.iter().zip(PAPER_TABLE1) {
        assert!((row.speedup - s).abs() < 0.011, "N={n} {mode:?}: S {} vs {s}", row.speedup);
        assert!((row.s_over_k - sk).abs() < 0.011, "N={n} {mode:?}: S/k {} vs {sk}", row.s_over_k);
        assert!((row.alpha_eff - a).abs() < 0.011, "N={n} {mode:?}: α {} vs {a}", row.alpha_eff);
    }
}

#[test]
fn closed_form_time_laws_hold_for_all_lengths() {
    // §6.1: both conventional and EMPA times increase linearly; the
    // derived laws are T_NO = 22+30N, T_FOR = 20+11N, T_SUMUP = 32+N.
    let cfg = EmpaConfig::default();
    for n in [1usize, 3, 5, 8, 13, 21, 30, 31, 47, 64, 100] {
        let t0 = empa::metrics::table::run_sumup(Mode::No, n, &cfg).clocks;
        let tf = empa::metrics::table::run_sumup(Mode::For, n, &cfg).clocks;
        let ts = empa::metrics::table::run_sumup(Mode::Sumup, n, &cfg).clocks;
        assert_eq!(t0, 22 + 30 * n as u64, "NO N={n}");
        assert_eq!(tf, 20 + 11 * n as u64, "FOR N={n}");
        assert_eq!(ts, 32 + n as u64, "SUMUP N={n}");
    }
}

#[test]
fn fig4_speedups_saturate_at_30_over_11_and_30() {
    // §6.1: "The two speedup values will saturate for high vector lengths
    // at values 30/11 and 30, respectively."
    let cfg = EmpaConfig::default();
    let pts = fig4_series(&[1, 2, 4, 6, 10, 30, 100, 300, 1000, 3000], &cfg);
    let last = pts.last().unwrap();
    assert!((last.for_value - 30.0 / 11.0).abs() < 0.01, "FOR → 30/11, got {}", last.for_value);
    assert!((last.sumup_value - 30.0).abs() < 0.35, "SUMUP → 30, got {}", last.sumup_value);
    // monotone increase towards the asymptote
    assert!(pts.windows(2).all(|w| w[1].for_value >= w[0].for_value));
    assert!(pts.windows(2).all(|w| w[1].sumup_value >= w[0].sumup_value));
    // ... and never beyond it
    assert!(pts.iter().all(|p| p.for_value < 30.0 / 11.0 && p.sumup_value < 30.0));
}

#[test]
fn fig5_for_efficiency_exceeds_unity_sumup_stays_below() {
    // §6.2: "the S/k values can even be *above* unity" for FOR (clever
    // cycle organisation, not higher PU performance); SUMUP's helper cores
    // are used briefly, so its S/k stays below 1 for short vectors.
    let cfg = EmpaConfig::default();
    let pts = fig5_series(&[1, 2, 4, 6, 10, 20], &cfg);
    assert!(pts.iter().any(|p| p.for_value > 1.0));
    assert!(pts.iter().take(4).all(|p| p.sumup_value < 1.0));
}

#[test]
fn fig6_core_count_saturates_at_31_and_alpha_approaches_one() {
    // §6.2 / Fig. 6: 1 parent + max 30 children; beyond N=30 the pool
    // recycles cores ("when the parent needs the 31st core, the 1st core
    // is available again"); α_eff → 1, S/k turns back and decays slowly.
    let cfg = EmpaConfig::default();
    let pts = fig6_series(&[1, 2, 4, 8, 16, 30, 31, 40, 64, 128, 512, 2048], &cfg);
    for p in &pts {
        assert_eq!(p.k, p.n.min(30) + 1, "N={}: k", p.n);
    }
    let last = pts.last().unwrap();
    assert!(last.alpha_eff > 0.99, "α_eff → 1, got {}", last.alpha_eff);
    // "S/k starts to decrease with increasing the number of the cores, and
    // after reaching 30 cores ... the dependence turns back and saturates
    // also at value 1, but approaches it much more slowly" (§6.2).
    let sk: Vec<f64> = pts.iter().map(|p| p.s_over_k).collect();
    let k31 = pts.iter().position(|p| p.k == 31).unwrap();
    assert!(sk[1..=k31].windows(2).all(|w| w[1] <= w[0] + 1e-12), "S/k decreases up to saturation: {sk:?}");
    assert!(sk[k31..].windows(2).all(|w| w[1] >= w[0] - 1e-12), "S/k turns back after saturation: {sk:?}");
    assert!(last.s_over_k > 0.9 && last.s_over_k < 1.0, "S/k → ~30/31, got {}", last.s_over_k);
    // α_eff approaches 1 much faster than S/k (Fig. 6's contrast).
    let alphas: Vec<f64> = pts.iter().map(|p| p.alpha_eff).collect();
    assert!(alphas.windows(2).skip(1).all(|w| w[1] >= w[0] - 1e-9));
    let n30 = pts.iter().position(|p| p.n == 30).unwrap();
    assert!(alphas[n30] > 0.9 && sk[n30] < 0.6, "α_eff high while S/k low at N=30");
}

#[test]
fn distinct_cores_bounded_by_31_for_huge_vectors() {
    // Core *reuse* (not just accounting): even a 2048-element vector only
    // ever touches 31 distinct cores.
    let cfg = EmpaConfig::default();
    let r = empa::metrics::table::run_sumup(Mode::Sumup, 2048, &cfg);
    assert_eq!(r.distinct_cores, 31);
    assert_eq!(r.max_occupied, 31);
}

#[test]
fn results_are_mode_independent() {
    // All three modes compute the same architectural result (%eax, %ecx,
    // %edx) for the same vector.
    let cfg = EmpaConfig::default();
    for n in [1usize, 2, 4, 6, 17, 33] {
        let r0 = empa::metrics::table::run_sumup(Mode::No, n, &cfg);
        let rf = empa::metrics::table::run_sumup(Mode::For, n, &cfg);
        let rs = empa::metrics::table::run_sumup(Mode::Sumup, n, &cfg);
        assert_eq!(r0.eax(), rf.eax(), "N={n} FOR sum");
        assert_eq!(r0.eax(), rs.eax(), "N={n} SUMUP sum");
        // %edx (count) ends consumed in every mode. (%ecx is program-
        // relative: the array lives at a different address per program.)
        assert_eq!(r0.regs.file[2], 0, "N={n} NO %edx consumed");
        assert_eq!(rf.regs.file[2], 0, "N={n} FOR %edx consumed");
        assert_eq!(rs.regs.file[2], 0, "N={n} SUMUP %edx consumed");
    }
}
