//! E8 substrate check: the PJRT runtime loads every AOT artifact, and the
//! XLA accelerator agrees numerically with the native oracle on every
//! mass operation — the rust-side half of the L1-vs-ref contract.
//!
//! Requires `make artifacts` (skips, loudly, when artifacts are absent so
//! `cargo test` works in a fresh checkout).

use empa::accel::{Accelerator, MassOp, MassRequest, MassResult, NativeAccel, XlaAccel};
use empa::runtime::{Runtime, Tensor};
use empa::util::Rng;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let d = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    d.join("manifest.tsv").exists().then_some(d)
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
                return;
            }
        }
    };
}

fn rows(rng: &mut Rng, n: usize, l: usize) -> Vec<Vec<f32>> {
    (0..n).map(|_| (0..l).map(|_| rng.range_f32(-1.0, 1.0)).collect()).collect()
}

fn assert_scalars_close(a: &MassResult, b: &MassResult, tol: f32) {
    let (MassResult::Scalars(x), MassResult::Scalars(y)) = (a, b) else {
        panic!("expected scalars: {a:?} vs {b:?}")
    };
    assert_eq!(x.len(), y.len());
    for (i, (u, v)) in x.iter().zip(y).enumerate() {
        assert!((u - v).abs() <= tol * (1.0 + v.abs()), "row {i}: {u} vs {v}");
    }
}

#[test]
fn runtime_loads_all_manifest_artifacts() {
    let dir = require_artifacts!();
    let rt = Runtime::load_dir(&dir).expect("load artifacts");
    let names = rt.names();
    assert_eq!(names.len(), 20, "5 entries x 4 buckets: {names:?}");
    for entry in ["sumup", "mass_for", "dot", "prefix", "sumup_stats"] {
        assert_eq!(rt.buckets(entry), vec![(8, 256), (8, 1024), (32, 256), (32, 1024)], "{entry}");
    }
    let meta = rt.meta("dot_b8_l256").unwrap();
    assert_eq!((meta.arity, meta.out_arity), (2, 1));
}

#[test]
fn runtime_executes_sumup_exactly() {
    let dir = require_artifacts!();
    let rt = Runtime::load_dir(&dir).expect("load");
    // constant rows: sums are exact in f32
    let data: Vec<f32> = (0..8 * 256).map(|i| ((i / 256) + 1) as f32).collect();
    let out = rt.execute("sumup_b8_l256", &[Tensor::matrix(8, 256, data)]).unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].dims, vec![8]);
    let want: Vec<f32> = (1..=8).map(|r| (r * 256) as f32).collect();
    assert_eq!(out[0].data, want);
}

#[test]
fn runtime_rejects_wrong_arity_and_unknown_names() {
    let dir = require_artifacts!();
    let rt = Runtime::load_dir(&dir).expect("load");
    assert!(rt.execute("nope", &[]).is_err());
    assert!(rt
        .execute("sumup_b8_l256", &[Tensor::vector(vec![0.0]), Tensor::vector(vec![0.0])])
        .is_err());
}

#[test]
fn xla_accel_matches_native_on_all_ops() {
    let dir = require_artifacts!();
    let xla = XlaAccel::new(Runtime::load_dir(&dir).expect("load"));
    let native = NativeAccel;
    let mut rng = Rng::seed_from_u64(42);

    // Sumup / Dot across row counts and (unaligned) lengths.
    for &(n, l) in &[(1usize, 1usize), (3, 100), (8, 256), (20, 700), (32, 1024)] {
        let a = rows(&mut rng, n, l);
        let b = rows(&mut rng, n, l);
        let req = MassRequest::sumup(a.clone());
        assert_scalars_close(&xla.execute(&req).unwrap(), &native.execute(&req).unwrap(), 1e-4);
        let req = MassRequest::dot(a, b);
        assert_scalars_close(&xla.execute(&req).unwrap(), &native.execute(&req).unwrap(), 1e-4);
    }

    // FOR: row results sliced back from the padded bucket.
    let a = rows(&mut rng, 5, 130);
    let req = MassRequest::for_op(a.clone(), 1.5, -0.25);
    let (MassResult::Rows(x), MassResult::Rows(y)) =
        (xla.execute(&req).unwrap(), native.execute(&req).unwrap())
    else {
        panic!("rows expected")
    };
    assert_eq!(x.len(), 5);
    for (rx, ry) in x.iter().zip(&y) {
        assert_eq!(rx.len(), 130);
        for (u, v) in rx.iter().zip(ry) {
            assert!((u - v).abs() < 1e-5);
        }
    }

    // Prefix.
    let a = rows(&mut rng, 4, 300);
    let req = MassRequest::new(MassOp::Prefix, a, Vec::<Vec<f32>>::new(), [0.0; 2]);
    let (MassResult::Rows(x), MassResult::Rows(y)) =
        (xla.execute(&req).unwrap(), native.execute(&req).unwrap())
    else {
        panic!("rows expected")
    };
    for (rx, ry) in x.iter().zip(&y) {
        for (u, v) in rx.iter().zip(ry) {
            assert!((u - v).abs() < 1e-3 * (1.0 + v.abs()), "{u} vs {v}");
        }
    }

    // Fused stats.
    let a = rows(&mut rng, 6, 200);
    let req = MassRequest::new(MassOp::SumupStats, a, Vec::<Vec<f32>>::new(), [0.0; 2]);
    let (MassResult::Stats { sum: s1, mean: m1, l2: l1 }, MassResult::Stats { sum: s2, mean: m2, l2: l2b }) =
        (xla.execute(&req).unwrap(), native.execute(&req).unwrap())
    else {
        panic!("stats expected")
    };
    for i in 0..6 {
        assert!((s1[i] - s2[i]).abs() < 1e-3);
        assert!((m1[i] - m2[i]).abs() < 1e-5);
        assert!((l1[i] - l2b[i]).abs() < 1e-3);
    }
}

#[test]
fn oversized_requests_are_rejected_not_truncated() {
    let dir = require_artifacts!();
    let xla = XlaAccel::new(Runtime::load_dir(&dir).expect("load"));
    let mut rng = Rng::seed_from_u64(1);
    // longer than the largest bucket (L=1024)
    let req = MassRequest::sumup(rows(&mut rng, 1, 2000));
    assert!(xla.execute(&req).is_err());
    // more rows than the largest bucket (B=32)
    let req = MassRequest::sumup(rows(&mut rng, 40, 8));
    assert!(xla.execute(&req).is_err());
}

#[test]
fn fabric_with_xla_accelerator_end_to_end() {
    let dir = require_artifacts!();
    use empa::api::{Output, RequestKind, Route};
    use empa::coordinator::{BackendRegistry, Fabric, FabricConfig};
    let cfg = FabricConfig::default();
    // `xla` first, `native` as failover — with the artifacts present and
    // the PJRT runtime compiled in, xla serves; otherwise the job still
    // completes via the failover chain.
    let registry =
        BackendRegistry::with_xla(cfg.empa.clone(), dir.to_str().expect("utf8 path"));
    let fabric = Fabric::start(cfg, registry);
    let mut rng = Rng::seed_from_u64(3);
    let vals: Vec<f32> = (0..512).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let want: f32 = vals.iter().sum();
    let h = fabric.submit(RequestKind::mass_sum(vals)).unwrap();
    let c = h.wait().expect("mass job completes");
    assert_eq!(c.route, Route::Accelerator);
    let Output::Scalars(got) = c.output else { panic!("{:?}", c.output) };
    assert!((got[0] - want).abs() < 1e-3);
    fabric.shutdown();
}
