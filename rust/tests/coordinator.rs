//! Fabric coordinator integration: routing, batching, ordering,
//! backpressure and failure behaviour with the native accelerator (the
//! XLA path is covered in `runtime_accel.rs`).

use empa::accel::{Accelerator, BatcherConfig, MassRequest, MassResult, NativeAccel};
use empa::coordinator::{Fabric, FabricConfig, Response};
use empa::util::Rng;
use empa::workload::sumup::Mode;
use empa::workload::{RequestKind, TraceConfig, TraceGen};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

fn native_factory() -> empa::accel::AccelFactory {
    Box::new(|| Ok(Box::new(NativeAccel) as Box<dyn Accelerator>))
}

fn fabric(cfg: FabricConfig) -> Arc<Fabric> {
    Fabric::start(cfg, native_factory())
}

#[test]
fn trace_results_match_direct_computation() {
    let f = fabric(FabricConfig::default());
    let trace = TraceGen::new(TraceConfig { num_requests: 128, seed: 9, ..Default::default() }).generate();
    let expected: Vec<Option<f32>> = trace
        .iter()
        .map(|r| match &r.kind {
            RequestKind::MassSum { values } => Some(values.iter().sum()),
            RequestKind::MassDot { a, b } => Some(a.iter().zip(b).map(|(x, y)| x * y).sum()),
            RequestKind::RunProgram { .. } => None,
        })
        .collect();
    let results = f.run_trace(trace);
    for ((_, resp, _), want) in results.iter().zip(expected) {
        match (resp, want) {
            (Response::Scalars(got), Some(w)) => {
                assert!((got[0] - w).abs() < 1e-2 * (1.0 + w.abs()), "{got:?} vs {w}")
            }
            (Response::Program { .. }, None) => {}
            other => panic!("unexpected pairing: {other:?}"),
        }
    }
    f.shutdown();
}

#[test]
fn program_responses_carry_table1_numbers() {
    let f = fabric(FabricConfig::default());
    let cases = [(Mode::No, 142u64, 1usize), (Mode::For, 64, 2), (Mode::Sumup, 36, 5)];
    for (mode, clocks, cores) in cases {
        let h = f
            .submit(RequestKind::RunProgram { mode, values: vec![0xd, 0xc0, 0xb00, 0xa000] })
            .unwrap();
        let (resp, _) = h.wait();
        assert_eq!(resp, Response::Program { eax: 0xd + 0xc0 + 0xb00 + 0xa000, clocks, cores });
    }
    f.shutdown();
}

#[test]
fn batching_aggregates_under_load() {
    let cfg = FabricConfig {
        batcher: BatcherConfig { max_rows: 8, max_wait: Duration::from_millis(5) },
        ..Default::default()
    };
    let f = fabric(cfg);
    let handles: Vec<_> = (0..64)
        .map(|i| f.submit(RequestKind::MassSum { values: vec![1.0; 100 + i] }).unwrap())
        .collect();
    for (i, h) in handles.into_iter().enumerate() {
        let (resp, _) = h.wait();
        assert_eq!(resp, Response::Scalars(vec![(100 + i) as f32]));
    }
    let batches = f.metrics.accel_batches.load(Ordering::Relaxed);
    assert!(batches >= 8, "64 rows / max 8 per batch: {batches}");
    assert!(f.metrics.mean_batch_rows() > 1.0, "batching actually aggregates");
    f.shutdown();
}

#[test]
fn responses_route_back_to_the_right_requester() {
    // Interleave many concurrent clients, each verifying its own answer.
    let f = fabric(FabricConfig::default());
    let errors = Arc::new(AtomicU64::new(0));
    std::thread::scope(|s| {
        for t in 0..8 {
            let f = Arc::clone(&f);
            let errors = Arc::clone(&errors);
            s.spawn(move || {
                let mut rng = Rng::seed_from_u64(t);
                for _ in 0..50 {
                    let len = rng.range_usize(64, 512);
                    let vals: Vec<f32> = (0..len).map(|_| rng.range_f32(-1.0, 1.0)).collect();
                    let want: f32 = vals.iter().sum();
                    let h = f.submit(RequestKind::MassSum { values: vals }).unwrap();
                    let (resp, _) = h.wait();
                    match resp {
                        Response::Scalars(got) if (got[0] - want).abs() < 1e-3 * (1.0 + want.abs()) => {}
                        _ => {
                            errors.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
            });
        }
    });
    assert_eq!(errors.load(Ordering::Relaxed), 0);
    f.shutdown();
}

#[test]
fn accelerator_failure_reports_errors_not_hangs() {
    struct Broken;
    impl Accelerator for Broken {
        fn name(&self) -> &str {
            "broken"
        }
        fn execute(&self, _req: &MassRequest) -> anyhow::Result<MassResult> {
            anyhow::bail!("simulated accelerator failure")
        }
    }
    let f = Fabric::start(
        FabricConfig::default(),
        Box::new(|| Ok(Box::new(Broken) as Box<dyn Accelerator>)),
    );
    let h = f.submit(RequestKind::MassSum { values: vec![1.0; 512] }).unwrap();
    let (resp, _) = h.wait();
    assert!(matches!(resp, Response::Error(e) if e.contains("simulated")));
    assert_eq!(f.metrics.errors.load(Ordering::Relaxed), 1);
    // subsequent small (inline) requests still work
    let h = f.submit(RequestKind::MassSum { values: vec![2.0, 3.0] }).unwrap();
    assert_eq!(h.wait().0, Response::Scalars(vec![5.0]));
    f.shutdown();
}

#[test]
fn accelerator_init_failure_degrades_gracefully() {
    let f = Fabric::start(FabricConfig::default(), Box::new(|| anyhow::bail!("no device")));
    let h = f.submit(RequestKind::MassSum { values: vec![1.0; 512] }).unwrap();
    let (resp, _) = h.wait();
    assert!(matches!(resp, Response::Error(e) if e.contains("accelerator init")));
    f.shutdown();
}

#[test]
fn shutdown_completes_inflight_work() {
    let cfg = FabricConfig {
        batcher: BatcherConfig { max_rows: 1000, max_wait: Duration::from_secs(10) },
        ..Default::default()
    };
    let f = fabric(cfg);
    // These can only flush via the shutdown drain path.
    let hs: Vec<_> = (0..5)
        .map(|_| f.submit(RequestKind::MassSum { values: vec![1.0; 256] }).unwrap())
        .collect();
    std::thread::sleep(Duration::from_millis(20));
    f.shutdown();
    for h in hs {
        let (resp, _) = h.wait();
        assert_eq!(resp, Response::Scalars(vec![256.0]));
    }
}

#[test]
fn throughput_scales_with_sim_workers() {
    // Not a benchmark — a sanity check that the pool actually runs jobs
    // in parallel (4 workers must not be slower than 1).
    let run = |workers: usize| {
        let f = fabric(FabricConfig { sim_workers: workers, ..Default::default() });
        let trace: Vec<RequestKind> = (0..64)
            .map(|_| RequestKind::RunProgram { mode: Mode::No, values: (0..400).collect() })
            .collect();
        let t0 = std::time::Instant::now();
        let hs: Vec<_> = trace.into_iter().map(|k| f.submit(k).unwrap()).collect();
        for h in hs {
            let (resp, _) = h.wait();
            assert!(matches!(resp, Response::Program { .. }));
        }
        let dt = t0.elapsed();
        f.shutdown();
        dt
    };
    let t1 = run(1);
    let t4 = run(4);
    assert!(t4 < t1 * 2, "4 workers ({t4:?}) should not be much slower than 1 ({t1:?})");
}
