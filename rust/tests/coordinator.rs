//! Fabric coordinator integration: routing, dispatch-plane staging and
//! stealing, batching, scatter/gather, ordering, backpressure, deadlines,
//! cancellation, and backend failover with the native accelerator (the
//! XLA path is covered in `runtime_accel.rs`).
//!
//! Failures are asserted on `FabricError` *variants* — the typed taxonomy
//! is the contract, not message strings.

use empa::accel::{Accelerator, BatcherConfig, MassRequest, MassResult, NativeAccel};
use empa::api::{FabricError, Job, JobRequest, Output, Priority, RequestKind, Route};
use empa::coordinator::{
    Backend, BackendClass, BackendJob, BackendReply, BackendRegistry, Fabric, FabricConfig,
    RoutePolicy, SimBackend,
};
use empa::empa::EmpaConfig;
use empa::util::Rng;
use empa::workload::family::{family_impl, synth_params, Expected, Family, Params, ALL_FAMILIES};
use empa::workload::sumup::Mode;
use empa::workload::{TraceConfig, TraceGen};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

fn fabric(cfg: FabricConfig) -> Arc<Fabric> {
    Fabric::start_local(cfg)
}

/// A registry with only the sim pool; tests append their own mass chain.
fn sim_registry(empa_cfg: EmpaConfig) -> BackendRegistry {
    BackendRegistry::new().register(
        "sim",
        BackendClass::Program,
        Box::new(move || Ok(Box::new(SimBackend::new(empa_cfg.clone())) as Box<dyn Backend>)),
    )
}

/// A program backend that sleeps `values[0]` milliseconds per job —
/// deterministic service times for the dispatch-plane tests.
struct Paced;

impl Backend for Paced {
    fn name(&self) -> &str {
        "paced"
    }
    fn execute(&self, job: BackendJob) -> Result<BackendReply, FabricError> {
        match job {
            BackendJob::Program { params, .. } => {
                let ms = match params {
                    Params::Sumup { values } => values.first().copied().unwrap_or(0).max(0) as u64,
                    _ => 0,
                };
                std::thread::sleep(Duration::from_millis(ms));
                Ok(BackendReply::Program { eax: ms as i32, clocks: ms, cores: 1, data: vec![] })
            }
            BackendJob::Mass(_) => Err(FabricError::Backend {
                name: "paced".into(),
                msg: "program backend".into(),
            }),
        }
    }
}

/// A registry whose program lane is [`Paced`] and whose mass lane is the
/// native loops.
fn paced_registry() -> BackendRegistry {
    BackendRegistry::new()
        .register(
            "paced",
            BackendClass::Program,
            Box::new(|| Ok(Box::new(Paced) as Box<dyn Backend>)),
        )
        .register_accel("native", || Ok(Box::new(NativeAccel) as Box<dyn Accelerator>))
}

fn paced_job(ms: i32) -> RequestKind {
    RequestKind::sumup(Mode::No, vec![ms])
}

#[test]
fn trace_results_match_direct_computation() {
    let f = fabric(FabricConfig::default());
    let trace =
        TraceGen::new(TraceConfig { num_requests: 128, seed: 9, ..Default::default() }).generate();
    let expected: Vec<Option<f32>> = trace
        .iter()
        .map(|r| match &r.job.kind {
            RequestKind::MassSum { values } => Some(values.iter().sum()),
            RequestKind::MassDot { a, b } => Some(a.iter().zip(b.iter()).map(|(x, y)| x * y).sum()),
            RequestKind::RunProgram { .. } => None,
        })
        .collect();
    let results = f.run_trace(trace).unwrap();
    for ((_, res), want) in results.iter().zip(expected) {
        let c = res.as_ref().expect("all jobs complete");
        match (&c.output, want) {
            (Output::Scalars(got), Some(w)) => {
                assert!((got[0] - w).abs() < 1e-2 * (1.0 + w.abs()), "{got:?} vs {w}")
            }
            (Output::Program { .. }, None) => {}
            other => panic!("unexpected pairing: {other:?}"),
        }
    }
    f.shutdown();
}

#[test]
fn program_responses_carry_table1_numbers() {
    // The compile-once pipeline serves byte-identical programs, so the
    // fabric's clock counts still reproduce Table 1 exactly.
    let f = fabric(FabricConfig::default());
    let cases = [(Mode::No, 142u64, 1usize), (Mode::For, 64, 2), (Mode::Sumup, 36, 5)];
    for (mode, clocks, cores) in cases {
        let h = f.submit(RequestKind::sumup(mode, vec![0xd, 0xc0, 0xb00, 0xa000])).unwrap();
        let c = h.wait().unwrap();
        assert_eq!(
            c.output,
            Output::Program { eax: 0xd + 0xc0 + 0xb00 + 0xa000, clocks, cores, data: vec![] }
        );
        assert_eq!((c.route, c.backend.as_str()), (Route::Simulator, "sim"));
    }
    f.shutdown();
}

/// Acceptance: every workload family is submittable through the client
/// and its completion matches the family oracle; the pipeline metrics
/// show template caching and processor reuse at work.
#[test]
fn all_families_submittable_and_verified_against_oracles() {
    // One worker → one template cache/processor: the second round's
    // hit/reuse counts are exact, not placement-dependent.
    let f = fabric(FabricConfig { sim_workers: 1, ..Default::default() });
    let client = f.client();
    let mut jobs: Vec<(Family, Mode, Params, Job)> = Vec::new();
    for round in 0..2u64 {
        for family in ALL_FAMILIES {
            let fam = family_impl(family);
            for &mode in fam.modes() {
                for n in [0usize, 1, 9] {
                    let params = synth_params(family, n, round ^ (n as u64) << 3);
                    let job = client
                        .submit(RequestKind::RunProgram { family, mode, params: params.clone() })
                        .unwrap();
                    jobs.push((family, mode, params, job));
                }
            }
        }
    }
    let total = jobs.len() as u64;
    for (family, mode, params, job) in jobs {
        let c = job.wait().unwrap_or_else(|e| panic!("{} {mode:?}: {e}", family.name()));
        let Output::Program { eax, data, .. } = &c.output else {
            panic!("{} {mode:?}: program output expected", family.name())
        };
        let want = family_impl(family).oracle(&params).unwrap();
        assert!(
            want.matches(*eax, data),
            "{} {mode:?}: want {want:?}, got eax={eax} data={data:?}",
            family.name()
        );
        if let Expected::Data(w) = &want {
            assert_eq!(data, w, "scale returns its output array");
        }
    }
    let m = &f.metrics;
    let hits = m.template_hits.load(Ordering::Relaxed);
    let misses = m.template_misses.load(Ordering::Relaxed);
    assert_eq!(hits + misses, total, "every program job went through the template cache");
    assert_eq!(hits, misses, "round 2 repeats every (family, mode, size-class) exactly");
    let reuses = m.proc_reuses.load(Ordering::Relaxed);
    let rebuilds = m.proc_rebuilds.load(Ordering::Relaxed);
    assert_eq!(rebuilds, 1, "one processor build for the single worker");
    assert_eq!(reuses, total - 1, "every later job reset the existing processor");
    assert!(m.render().contains("program pipeline"), "{}", m.render());
    f.shutdown();
}

#[test]
fn unsupported_modes_and_family_mismatch_rejected_at_submission() {
    let f = fabric(FabricConfig::default());
    let err = f.submit(RequestKind::scale(Mode::Sumup, vec![1, 2], 3)).unwrap_err();
    assert_eq!(err, FabricError::UnsupportedMode { family: Family::Scale, mode: Mode::Sumup });
    let err = f
        .submit(RequestKind::RunProgram {
            family: Family::Traces,
            mode: Mode::For,
            params: Params::Traces { ops: vec![] },
        })
        .unwrap_err();
    assert_eq!(err, FabricError::UnsupportedMode { family: Family::Traces, mode: Mode::For });
    let err = f
        .submit(RequestKind::RunProgram {
            family: Family::Sumup,
            mode: Mode::No,
            params: Params::Scale { x: vec![1], c: 2 },
        })
        .unwrap_err();
    assert_eq!(err, FabricError::FamilyMismatch { family: Family::Sumup, params: Family::Scale });
    // a mismatched dot-product *program* is rejected like the mass op
    let err = f.submit(RequestKind::dotprod(Mode::No, vec![1, 2, 3], vec![1])).unwrap_err();
    assert_eq!(err, FabricError::ShapeMismatch { a: 3, b: 1 });
    assert_eq!(f.metrics.submitted.load(Ordering::Relaxed), 0, "rejected before any queue");
    f.shutdown();
}

#[test]
fn batching_aggregates_under_load() {
    let cfg = FabricConfig {
        batcher: BatcherConfig { max_rows: 8, max_wait: Duration::from_millis(5) },
        ..Default::default()
    };
    let f = fabric(cfg);
    let handles: Vec<_> = (0..64)
        .map(|i| f.submit(RequestKind::mass_sum(vec![1.0; 100 + i])).unwrap())
        .collect();
    for (i, h) in handles.into_iter().enumerate() {
        let c = h.wait().unwrap();
        assert_eq!(c.output, Output::Scalars(vec![(100 + i) as f32].into()));
        assert!(c.batch_rows >= 1 && c.batch_rows <= 8, "batch metadata: {}", c.batch_rows);
    }
    let batches = f.metrics.accel_batches.load(Ordering::Relaxed);
    assert!(batches >= 8, "64 rows / max 8 per batch: {batches}");
    assert!(f.metrics.mean_batch_rows() > 1.0, "batching actually aggregates");
    // per-backend accounting matches the global counters
    let native = f.metrics.backend("native");
    assert_eq!(native.batches.load(Ordering::Relaxed), batches);
    assert_eq!(native.rows.load(Ordering::Relaxed), 64);
    f.shutdown();
}

#[test]
fn responses_route_back_to_the_right_requester() {
    // Interleave many concurrent clients, each verifying its own answer
    // through its own cloned FabricClient.
    let f = fabric(FabricConfig::default());
    let errors = Arc::new(AtomicU64::new(0));
    std::thread::scope(|s| {
        for t in 0..8 {
            let client = f.client().tagged(format!("t{t}"));
            let errors = Arc::clone(&errors);
            s.spawn(move || {
                let mut rng = Rng::seed_from_u64(t);
                for _ in 0..50 {
                    let len = rng.range_usize(64, 512);
                    let vals: Vec<f32> = (0..len).map(|_| rng.range_f32(-1.0, 1.0)).collect();
                    let want: f32 = vals.iter().sum();
                    let h = client.submit(RequestKind::mass_sum(vals)).unwrap();
                    match h.wait() {
                        Ok(c) => match c.output {
                            Output::Scalars(got)
                                if (got[0] - want).abs() < 1e-3 * (1.0 + want.abs()) => {}
                            _ => {
                                errors.fetch_add(1, Ordering::Relaxed);
                            }
                        },
                        Err(_) => {
                            errors.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
            });
        }
    });
    assert_eq!(errors.load(Ordering::Relaxed), 0);
    // per-client accounting saw every tagged submission and completion
    for t in 0..8 {
        let c = f.metrics.client(&format!("t{t}"));
        assert_eq!(c.submitted.load(Ordering::Relaxed), 50);
        assert_eq!(c.accepted.load(Ordering::Relaxed), 50);
    }
    f.shutdown();
}

#[test]
fn backend_failure_is_a_typed_error_not_a_hang() {
    struct Broken;
    impl Accelerator for Broken {
        fn name(&self) -> &str {
            "broken"
        }
        fn execute(&self, _req: &MassRequest) -> anyhow::Result<MassResult> {
            anyhow::bail!("simulated accelerator failure")
        }
    }
    let cfg = FabricConfig::default();
    // `broken` is the whole mass chain: no failover entry to hide behind.
    let registry = sim_registry(cfg.empa.clone())
        .register_accel("broken", || Ok(Box::new(Broken) as Box<dyn Accelerator>));
    let f = Fabric::start(cfg, registry);
    let h = f.submit(RequestKind::mass_sum(vec![1.0; 512])).unwrap();
    match h.wait() {
        Err(FabricError::Backend { name, msg }) => {
            assert_eq!(name, "broken");
            assert!(msg.contains("simulated"));
        }
        other => panic!("want Backend error, got {other:?}"),
    }
    assert_eq!(f.metrics.errors.load(Ordering::Relaxed), 1);
    // subsequent small (inline) requests still work
    let h = f.submit(RequestKind::mass_sum(vec![2.0, 3.0])).unwrap();
    assert_eq!(h.wait().unwrap().output, Output::Scalars(vec![5.0].into()));
    f.shutdown();
}

#[test]
fn xla_init_failure_fails_over_to_native() {
    // A failing `xla` factory ahead of `native`: every mass job must
    // still complete via failover, with zero error responses and the
    // degradation visible in per-backend metrics.
    let cfg = FabricConfig::default();
    // registration order is failover order: the failing xla comes first
    let registry = sim_registry(cfg.empa.clone())
        .register_accel("xla", || anyhow::bail!("no PJRT device"))
        .register_accel("native", || Ok(Box::new(NativeAccel) as Box<dyn Accelerator>));
    let f = Fabric::start(cfg, registry);
    let handles: Vec<_> = (0..32)
        .map(|i| f.submit(RequestKind::mass_sum(vec![1.0; 128 + i])).unwrap())
        .collect();
    for (i, h) in handles.into_iter().enumerate() {
        let c = h.wait().expect("failover answers every mass job");
        assert_eq!(c.output, Output::Scalars(vec![(128 + i) as f32].into()));
        assert_eq!(c.backend, "native", "served by the failover backend");
    }
    assert_eq!(f.metrics.errors.load(Ordering::Relaxed), 0);
    assert_eq!(f.metrics.backend("xla").init_failures.load(Ordering::Relaxed), 1);
    assert!(f.metrics.backend("native").batches.load(Ordering::Relaxed) >= 1);
    assert!(f.metrics.failovers.load(Ordering::Relaxed) >= 1);
    assert!(f.metrics.render().contains("backend xla"));
    f.shutdown();
}

#[test]
fn try_submit_reports_queue_full_under_saturation() {
    // Tiny queues + one worker chewing a long program: the ingress queue
    // must eventually refuse work with a typed QueueFull, not block.
    let cfg = FabricConfig {
        sim_workers: 1,
        queue_cap: 1,
        ..Default::default()
    };
    let f = fabric(cfg);
    let slow = || RequestKind::sumup(Mode::Sumup, (0..1_000).map(|i| i % 7).collect());
    let mut accepted: Vec<Job> = Vec::new();
    let mut saw_full = false;
    for _ in 0..256 {
        match f.try_submit(slow()) {
            Ok(j) => accepted.push(j),
            Err(FabricError::QueueFull) => {
                saw_full = true;
                break;
            }
            Err(other) => panic!("unexpected error: {other:?}"),
        }
    }
    assert!(saw_full, "saturated fabric must reject with QueueFull");
    assert!(f.metrics.rejected.load(Ordering::Relaxed) >= 1);
    // accepted jobs all still complete (backpressure, not loss)
    for j in accepted {
        assert!(matches!(j.wait().unwrap().output, Output::Program { .. }));
    }
    f.shutdown();
}

#[test]
fn wait_timeout_expires_then_job_completes() {
    // Batcher flushes only on shutdown: the job is parked, so a bounded
    // wait must expire with None while the handle stays usable.
    let cfg = FabricConfig {
        batcher: BatcherConfig { max_rows: 1000, max_wait: Duration::from_secs(30) },
        ..Default::default()
    };
    let f = fabric(cfg);
    let mut h = f.submit(RequestKind::mass_sum(vec![1.0; 256])).unwrap();
    assert!(h.try_wait().is_none(), "job is parked in the batcher");
    assert!(h.wait_timeout(Duration::from_millis(30)).is_none(), "bounded wait expires");
    f.shutdown(); // drains the batcher, completing the job
    match h.wait_timeout(Duration::from_secs(5)) {
        Some(Ok(c)) => assert_eq!(c.output, Output::Scalars(vec![256.0].into())),
        other => panic!("want completion after drain, got {other:?}"),
    }
}

#[test]
fn cancel_before_dispatch_resolves_cancelled() {
    let cfg = FabricConfig {
        batcher: BatcherConfig { max_rows: 1000, max_wait: Duration::from_secs(30) },
        ..Default::default()
    };
    let f = fabric(cfg);
    let h = f.submit(RequestKind::mass_sum(vec![1.0; 256])).unwrap();
    h.cancel();
    f.shutdown(); // drain observes the cancel flag before dispatch
    assert_eq!(h.wait(), Err(FabricError::Cancelled));
    assert_eq!(f.metrics.cancelled.load(Ordering::Relaxed), 1);
}

#[test]
fn missed_deadline_resolves_deadline_exceeded() {
    let cfg = FabricConfig {
        batcher: BatcherConfig { max_rows: 1000, max_wait: Duration::from_secs(30) },
        ..Default::default()
    };
    let f = fabric(cfg);
    let req = JobRequest::new(RequestKind::mass_sum(vec![1.0; 256]))
        .with_deadline(Duration::from_millis(1));
    let h = f.submit(req).unwrap();
    std::thread::sleep(Duration::from_millis(20));
    f.shutdown(); // drain happens well past the deadline
    assert_eq!(h.wait(), Err(FabricError::DeadlineExceeded));
    assert_eq!(f.metrics.deadline_missed.load(Ordering::Relaxed), 1);
}

#[test]
fn submit_batch_returns_ordered_handles() {
    let f = fabric(FabricConfig::default());
    let reqs: Vec<JobRequest> = (1..=16)
        .map(|i| JobRequest::new(RequestKind::mass_sum(vec![1.0; 64 * i])))
        .collect();
    let jobs = f.client().submit_batch(reqs).unwrap();
    assert_eq!(jobs.len(), 16);
    for (i, j) in jobs.into_iter().enumerate() {
        assert_eq!(j.wait().unwrap().output, Output::Scalars(vec![(64 * (i + 1)) as f32].into()));
    }
    f.shutdown();
}

#[test]
fn high_priority_overtakes_staged_low_priority() {
    // One worker + a stack of Low jobs, then one High: the High job's
    // handle must resolve even though it arrived last (priority staging),
    // and everything completes.
    let f = fabric(FabricConfig { sim_workers: 1, ..Default::default() });
    let low: Vec<Job> = (0..8)
        .map(|_| {
            f.submit(
                JobRequest::new(RequestKind::sumup(
                    Mode::No,
                    (0..1_000).map(|i| i % 5).collect(),
                ))
                .with_priority(Priority::Low),
            )
            .unwrap()
        })
        .collect();
    let high = f
        .submit(
            JobRequest::new(RequestKind::sumup(Mode::Sumup, vec![1, 2, 3, 4]))
                .with_priority(Priority::High),
        )
        .unwrap();
    let c = high.wait().unwrap();
    assert_eq!(c.output, Output::Program { eax: 10, clocks: 36, cores: 5, data: vec![] });
    for j in low {
        assert!(j.wait().is_ok());
    }
    f.shutdown();
}

#[test]
fn shutdown_completes_inflight_work() {
    let cfg = FabricConfig {
        batcher: BatcherConfig { max_rows: 1000, max_wait: Duration::from_secs(10) },
        ..Default::default()
    };
    let f = fabric(cfg);
    // These can only flush via the shutdown drain path.
    let hs: Vec<_> = (0..5)
        .map(|_| f.submit(RequestKind::mass_sum(vec![1.0; 256])).unwrap())
        .collect();
    std::thread::sleep(Duration::from_millis(20));
    f.shutdown();
    for h in hs {
        assert_eq!(h.wait().unwrap().output, Output::Scalars(vec![256.0].into()));
    }
}

#[test]
fn shutdown_scales_past_the_old_stop_broadcast_limit() {
    // The seed broadcast 64 Stop messages; worker counts above that used
    // to hang shutdown. Per-worker stop (sender drop) must not.
    let f = fabric(FabricConfig { sim_workers: 96, ..Default::default() });
    let h = f.submit(RequestKind::sumup(Mode::Sumup, vec![1, 2, 3, 4])).unwrap();
    assert!(h.wait().is_ok());
    f.shutdown(); // must return (joins all 96 workers)
}

#[test]
fn inline_jobs_bypass_a_saturated_program_backlog() {
    // The head-of-line-blocking regression the dispatch plane fixes: the
    // seed router stopped ingesting once its staged heap hit queue_cap,
    // so an inline mass op queued behind the whole program backlog. Now
    // program jobs stage on the plane (and then the overflow heap) while
    // inline jobs keep flowing.
    let cfg = FabricConfig { sim_workers: 1, queue_cap: 4, ..Default::default() };
    let f = Fabric::start(cfg, paced_registry());
    // 1 running + 4 on the worker's deque (= queue_cap, saturated) + 3
    // in the overflow heap — ingestion must still be live.
    let progs: Vec<Job> = (0..8).map(|_| f.submit(paced_job(200)).unwrap()).collect();
    std::thread::sleep(Duration::from_millis(50));
    assert!(
        f.metrics.worker(0).depth.load(Ordering::Relaxed) >= 3,
        "program backlog is staged on the worker's deque"
    );
    let h = f.submit(RequestKind::mass_sum(vec![1.0, 2.0, 3.0])).unwrap();
    let c = h.wait().unwrap();
    assert_eq!(c.output, Output::Scalars(vec![6.0].into()));
    assert_eq!(c.route, Route::Inline);
    assert!(
        c.latency < Duration::from_millis(150),
        "inline job must not wait out a 200 ms program slot: {:?}",
        c.latency
    );
    for p in progs {
        assert!(matches!(p.wait().unwrap().output, Output::Program { .. }));
    }
    assert_eq!(f.metrics.total_placements(), 8, "every program staged exactly once");
    f.shutdown();
}

#[test]
fn idle_worker_steals_the_busy_workers_backlog() {
    // Steal fairness: jobs staged behind a long-running job on one
    // worker's deque finish via the idle neighbour instead of
    // serialising behind it.
    let cfg = FabricConfig { sim_workers: 2, ..Default::default() };
    let f = Fabric::start(cfg, paced_registry());
    let slow = f.submit(paced_job(500)).unwrap();
    let quick: Vec<(i32, Job)> =
        (0..7).map(|_| (10, f.submit(paced_job(10)).unwrap())).collect();
    for (ms, j) in quick {
        let c = j.wait().unwrap();
        assert_eq!(c.output, Output::Program { eax: ms, clocks: ms as u64, cores: 1, data: vec![] });
    }
    assert!(matches!(slow.wait().unwrap().output, Output::Program { eax: 500, .. }));
    assert!(
        f.metrics.total_steals() >= 1,
        "the idle neighbour must have stolen staged work: {}",
        f.metrics.render()
    );
    let executed: u64 = (0..2)
        .map(|w| f.metrics.worker(w).executed.load(Ordering::Relaxed))
        .sum();
    assert_eq!(executed, 8);
    assert_eq!(f.metrics.total_queue_depth(), 0, "deques drained");
    f.shutdown();
}

#[test]
fn mass_dot_length_mismatch_is_rejected_at_submission() {
    let f = fabric(FabricConfig::default());
    // Below the accelerator threshold: used to zip-truncate inline.
    let err = f.submit(RequestKind::mass_dot(vec![1.0; 8], vec![1.0; 7])).unwrap_err();
    assert_eq!(err, FabricError::ShapeMismatch { a: 8, b: 7 });
    // Above it: used to reach the batcher with ragged rows.
    let err = f
        .try_submit(RequestKind::mass_dot(vec![1.0; 512], vec![1.0; 100]))
        .unwrap_err();
    assert!(matches!(err, FabricError::ShapeMismatch { a: 512, b: 100 }));
    assert_eq!(f.metrics.submitted.load(Ordering::Relaxed), 0, "rejected before any queue");
    // Well-formed dots still serve.
    let h = f.submit(RequestKind::mass_dot(vec![2.0; 128], vec![3.0; 128])).unwrap();
    assert_eq!(h.wait().unwrap().output, Output::Scalars(vec![768.0].into()));
    f.shutdown();
}

#[test]
fn failovers_count_only_when_a_later_entry_takes_over() {
    struct Broken;
    impl Accelerator for Broken {
        fn name(&self) -> &str {
            "broken"
        }
        fn execute(&self, _req: &MassRequest) -> anyhow::Result<MassResult> {
            anyhow::bail!("simulated accelerator failure")
        }
    }
    // Every entry fails — nothing failed *over*, so the counter must
    // stay 0 (the seed counted one per non-last failing entry).
    let registry = BackendRegistry::new()
        .register("dead-a", BackendClass::Program, Box::new(|| anyhow::bail!("a")))
        .register("dead-b", BackendClass::Program, Box::new(|| anyhow::bail!("b")))
        .register_accel("broken-1", || Ok(Box::new(Broken) as Box<dyn Accelerator>))
        .register_accel("broken-2", || Ok(Box::new(Broken) as Box<dyn Accelerator>));
    let f = Fabric::start(FabricConfig { sim_workers: 1, ..Default::default() }, registry);
    let h = f.submit(RequestKind::sumup(Mode::No, vec![1])).unwrap();
    assert!(matches!(h.wait(), Err(FabricError::Backend { .. })));
    let h = f.submit(RequestKind::mass_sum(vec![1.0; 512])).unwrap();
    assert!(matches!(h.wait(), Err(FabricError::Backend { .. })));
    assert_eq!(f.metrics.backend("dead-a").init_failures.load(Ordering::Relaxed), 1);
    assert_eq!(f.metrics.backend("dead-b").init_failures.load(Ordering::Relaxed), 1);
    assert_eq!(
        f.metrics.failovers.load(Ordering::Relaxed),
        0,
        "all-entries-failed is an error, not a failover"
    );
    f.shutdown();
}

#[test]
fn oversized_mass_ops_scatter_across_the_sim_pool() {
    let cfg = FabricConfig {
        sim_workers: 4,
        route: RoutePolicy { accel_min_len: 64, split_min_len: 256 },
        ..Default::default()
    };
    let f = fabric(cfg);
    let a: Vec<f32> = (0..512).map(|i| (i % 5) as f32).collect();
    let b: Vec<f32> = (0..512).map(|i| (i % 3) as f32).collect();
    let want: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
    let h = f.submit(RequestKind::mass_dot(a, b)).unwrap();
    let c = h.wait().unwrap();
    assert_eq!(c.route, Route::Split);
    assert_eq!(c.shards, 4, "2 * 512 / 256 capped at the pool width");
    assert_eq!(c.batch_rows, 1);
    let got = c.output.scalar().unwrap();
    assert!((got - want).abs() < 1e-2 * (1.0 + want.abs()), "{got} vs {want}");
    assert_eq!(f.metrics.routed_split.load(Ordering::Relaxed), 1);
    assert_eq!(f.metrics.split_shards.load(Ordering::Relaxed), 4);
    assert_eq!(f.metrics.total_placements(), 4, "one placement per shard");
    assert_eq!(f.metrics.total_queue_depth(), 0, "gauges return to zero");
    let executed: u64 = (0..4)
        .map(|w| f.metrics.worker(w).executed.load(Ordering::Relaxed))
        .sum();
    assert_eq!(executed, 4, "each shard executed exactly once");
    f.shutdown();
}

#[test]
fn split_falls_back_to_the_batcher_when_no_worker_is_idle() {
    // Scatter only pays when neighbours are free to help; with every
    // lane busy the oversized op takes the bounded accelerator lane.
    let cfg = FabricConfig {
        sim_workers: 1,
        route: RoutePolicy { accel_min_len: 64, split_min_len: 256 },
        ..Default::default()
    };
    let f = Fabric::start(cfg, paced_registry());
    let busy = f.submit(paced_job(300)).unwrap();
    let staged = f.submit(paced_job(300)).unwrap();
    std::thread::sleep(Duration::from_millis(30)); // one running, one staged
    let h = f.submit(RequestKind::mass_sum(vec![1.0; 512])).unwrap();
    let c = h.wait().unwrap();
    assert_eq!(c.output, Output::Scalars(vec![512.0].into()));
    assert_eq!(c.route, Route::Accelerator, "busy pool: no scatter");
    assert_eq!(c.backend, "native");
    assert_eq!(c.shards, 1);
    assert_eq!(f.metrics.routed_split.load(Ordering::Relaxed), 0);
    assert_eq!(f.metrics.routed_accel.load(Ordering::Relaxed), 1);
    for j in [busy, staged] {
        assert!(j.wait().is_ok());
    }
    f.shutdown();
}

#[test]
fn throughput_scales_with_sim_workers() {
    // Not a benchmark — a sanity check that the pool actually runs jobs
    // in parallel (4 workers must not be slower than 1).
    let run = |workers: usize| {
        let f = fabric(FabricConfig { sim_workers: workers, ..Default::default() });
        let kinds: Vec<RequestKind> = (0..64)
            .map(|_| RequestKind::sumup(Mode::No, (0..400).collect()))
            .collect();
        let t0 = std::time::Instant::now();
        let hs: Vec<_> = kinds.into_iter().map(|k| f.submit(k).unwrap()).collect();
        for h in hs {
            assert!(matches!(h.wait().unwrap().output, Output::Program { .. }));
        }
        let dt = t0.elapsed();
        f.shutdown();
        dt
    };
    let t1 = run(1);
    let t4 = run(4);
    assert!(t4 < t1 * 2, "4 workers ({t4:?}) should not be much slower than 1 ({t1:?})");
}
