//! Zero-copy data plane: the buffer a client submits is the same
//! allocation the backend chain executes — proven by `Arc` pointer
//! identity through the supervisor, the batcher, and the mass worker —
//! and the scatter/gather path computes over the submitted buffers at
//! every `split_min_len` boundary shape.

use empa::accel::{Accelerator, BatcherConfig, MassRequest, MassResult, NativeAccel};
use empa::api::{Output, RequestKind, Route};
use empa::coordinator::{
    Backend, BackendClass, Fabric, FabricConfig, FabricError, RoutePolicy, SimBackend,
};
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// `(as_ptr, len)` of every operand a backend executed.
type Seen = Arc<Mutex<Vec<(usize, usize)>>>;

/// A mass backend that records the pointer identity of every operand it
/// executes, then answers via the native loops.
struct Capture {
    seen: Seen,
}

impl Accelerator for Capture {
    fn name(&self) -> &str {
        "capture"
    }
    fn execute(&self, req: &MassRequest) -> anyhow::Result<MassResult> {
        let mut g = self.seen.lock().unwrap();
        for i in 0..req.batch_rows() {
            g.push((req.rows[i].as_ptr() as usize, req.rows[i].len()));
        }
        // The flat tile the batcher built must agree with the shared
        // rows it was built from.
        if let Some(t) = &req.tile {
            for i in 0..req.batch_rows() {
                assert_eq!(t.row(i), &req.rows[i][..], "tile row {i} mirrors the operand");
            }
        }
        NativeAccel.execute(req)
    }
}

fn capture_fabric(seen: Seen, max_rows: usize) -> Arc<Fabric> {
    // A long deadline window: the size trigger is the only flush the
    // tests should observe.
    let cfg = FabricConfig {
        sim_workers: 1,
        batcher: BatcherConfig { max_rows, max_wait: Duration::from_secs(5) },
        ..Default::default()
    };
    let empa_cfg = cfg.empa.clone();
    let registry = empa::coordinator::BackendRegistry::new()
        .register(
            "sim",
            BackendClass::Program,
            Box::new(move || Ok(Box::new(SimBackend::new(empa_cfg.clone())) as Box<dyn Backend>)),
        )
        .register_accel("capture", move || {
            Ok(Box::new(Capture { seen: Arc::clone(&seen) }) as Box<dyn Accelerator>)
        });
    Fabric::start(cfg, registry)
}

#[test]
fn the_backend_executes_the_clients_allocation() {
    // Client → supervisor → batcher → mass worker → backend chain:
    // the operand `Arc` the client submitted is the allocation the
    // backend reads — no copy anywhere on the path (the flat tile is
    // the accelerator's staging layout, built once from these rows).
    let seen = Arc::new(Mutex::new(Vec::new()));
    let f = capture_fabric(Arc::clone(&seen), 1);
    let buf: Arc<[f32]> = (0..256).map(|i| (i % 7) as f32).collect();
    let want: f32 = buf.iter().sum();
    let h = f.submit(RequestKind::MassSum { values: Arc::clone(&buf) }).unwrap();
    let c = h.wait().unwrap();
    assert_eq!(c.route, Route::Accelerator);
    assert_eq!(c.backend, "capture");
    assert_eq!(c.output.scalar(), Some(want));
    let g = seen.lock().unwrap();
    assert_eq!(
        g.as_slice(),
        &[(buf.as_ptr() as usize, buf.len())],
        "the backend saw the very allocation the client submitted"
    );
    drop(g);
    f.shutdown();
    assert_eq!(Arc::strong_count(&buf), 1, "the fabric released every handle");
}

#[test]
fn batched_rows_keep_their_identity_and_order() {
    let seen = Arc::new(Mutex::new(Vec::new()));
    let f = capture_fabric(Arc::clone(&seen), 4);
    let bufs: Vec<Arc<[f32]>> =
        (0..4).map(|k| (0..64 + k).map(|i| (i + k) as f32).collect()).collect();
    let handles: Vec<_> = bufs
        .iter()
        .map(|b| f.submit(RequestKind::MassSum { values: Arc::clone(b) }).unwrap())
        .collect();
    for (k, h) in handles.into_iter().enumerate() {
        let c = h.wait().unwrap();
        let want: f32 = bufs[k].iter().sum();
        assert_eq!(c.output.scalar(), Some(want), "row {k}");
        assert_eq!(c.batch_rows, 4, "all four rode one batch");
    }
    let g = seen.lock().unwrap();
    let want: Vec<(usize, usize)> =
        bufs.iter().map(|b| (b.as_ptr() as usize, b.len())).collect();
    assert_eq!(g.as_slice(), &want[..], "identity and submission order preserved");
    drop(g);
    f.shutdown();
    for b in &bufs {
        assert_eq!(Arc::strong_count(b), 1);
    }
}

/// The completion can race the serving thread's drop of its operand
/// handle by a few instructions; wait the handle out instead of
/// asserting a transient count.
fn settles_to_one(buf: &Arc<[f32]>) -> bool {
    for _ in 0..2000 {
        if Arc::strong_count(buf) == 1 {
            return true;
        }
        std::thread::sleep(Duration::from_micros(500));
    }
    false
}

#[test]
fn shard_gather_over_shared_operands_at_split_boundaries() {
    // split_min_len = 256; sizes 0/1 (inline), exactly at the
    // threshold, and an exact multiple of it — every shape sums the
    // shared buffer correctly and releases it afterwards.
    let cfg = FabricConfig {
        sim_workers: 4,
        route: RoutePolicy { accel_min_len: 64, split_min_len: 256 },
        ..Default::default()
    };
    let f = Fabric::start_local(cfg);
    for (len, want_route) in
        [(0usize, Route::Inline), (1, Route::Inline), (256, Route::Split), (1024, Route::Split)]
    {
        let buf: Arc<[f32]> = (0..len).map(|i| (i % 11) as f32 * 0.5).collect();
        let want: f32 = buf.iter().sum();
        let h = f.submit(RequestKind::MassSum { values: Arc::clone(&buf) }).unwrap();
        let c = h.wait().unwrap_or_else(|e| panic!("len {len}: {e}"));
        assert_eq!(c.route, want_route, "len {len}");
        if want_route == Route::Split {
            assert!(c.shards >= 2, "len {len}: fan-out {}", c.shards);
        }
        let got = c.output.scalar().unwrap();
        assert!((got - want).abs() < 1e-2 * (1.0 + want.abs()), "len {len}: {got} vs {want}");
        drop(c);
        assert!(settles_to_one(&buf), "len {len}: operand released after gather");
    }
    assert_eq!(f.metrics.routed_split.load(Ordering::Relaxed), 2);

    // A split dot at an exact multiple: both operands shared, result
    // exact against an f64 reference within gather tolerance.
    let a: Arc<[f32]> = (0..512).map(|i| (i % 5) as f32).collect();
    let b: Arc<[f32]> = (0..512).map(|i| (i % 3) as f32).collect();
    let want: f32 = a.iter().zip(b.iter()).map(|(x, y)| x * y).sum();
    let h = f.submit(RequestKind::MassDot { a: Arc::clone(&a), b: Arc::clone(&b) }).unwrap();
    let c = h.wait().unwrap();
    assert_eq!(c.route, Route::Split);
    let got = c.output.scalar().unwrap();
    assert!((got - want).abs() < 1e-2 * (1.0 + want.abs()), "{got} vs {want}");
    drop(c);
    assert!(settles_to_one(&a));
    assert!(settles_to_one(&b));
    f.shutdown();
}

#[test]
fn completions_share_their_output_buffers() {
    // Output::Scalars is a shared buffer: cloning a completion is a
    // refcount bump (the legacy owned-Vec conversion lives only in the
    // deprecated Response shim's own compatibility tests).
    let f = Fabric::start_local(FabricConfig::default());
    let h = f.submit(RequestKind::mass_sum(vec![1.0, 2.0])).unwrap();
    let c = h.wait().unwrap();
    let Output::Scalars(v) = &c.output else { panic!("scalars expected: {:?}", c.output) };
    let c2 = c.clone();
    let Output::Scalars(v2) = &c2.output else { unreachable!() };
    assert!(Arc::ptr_eq(v, v2), "completion clones share the output allocation");
    assert_eq!(c.output.scalar(), Some(3.0));
    // Shutdown still resolves submissions with typed errors.
    f.shutdown();
    assert_eq!(
        f.submit(RequestKind::mass_sum(vec![1.0])).unwrap_err(),
        FabricError::Shutdown
    );
}
