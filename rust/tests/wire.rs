//! Wire-codec integration tests: every message variant round-trips,
//! malformed input is a typed [`CodecError`] (never a panic), and the
//! frame cap holds on both directions.

use empa::api::{Completion, FabricError, JobRequest, Output, Priority, RequestKind, Route};
use empa::serve::wire::{
    decode_reply, decode_request, encode_reply, encode_request, read_frame, write_frame,
};
use empa::serve::{CodecError, WireReply, WireRequest, MAX_FRAME, WIRE_VERSION};
use empa::workload::{Family, Mode, TraceOp, TraceOpKind};
use std::sync::Arc;
use std::time::Duration;

/// One of each request kind, exercising every kind/mode/priority tag.
fn all_kinds() -> Vec<RequestKind> {
    vec![
        RequestKind::mass_sum(vec![1.0f32, -2.5, 3.25]),
        RequestKind::mass_dot(vec![1.0f32, 2.0], vec![3.0f32, 4.0]),
        RequestKind::sumup(Mode::No, vec![1, 2, 3]),
        RequestKind::sumup(Mode::For, vec![4, 5]),
        RequestKind::sumup(Mode::Sumup, vec![6]),
        RequestKind::dotprod(Mode::For, vec![1, 2], vec![3, 4]),
        RequestKind::scale(Mode::No, vec![7, 8, 9], 3),
        RequestKind::traces(vec![
            TraceOp::new(TraceOpKind::Add, 11),
            TraceOp::new(TraceOpKind::Sub, -4),
            TraceOp::new(TraceOpKind::Xor, 0x5a5a),
        ]),
        RequestKind::traces(vec![]),
    ]
}

#[test]
fn every_request_variant_round_trips() {
    let mut id = 0u64;
    for kind in all_kinds() {
        for (priority, deadline, tenant) in [
            (Priority::Low, None, None),
            (Priority::Normal, Some(Duration::from_micros(1500)), Some("acme")),
            (Priority::High, Some(Duration::from_secs(2)), Some("")),
        ] {
            id += 1;
            let mut job = JobRequest::new(kind.clone()).with_priority(priority);
            if let Some(d) = deadline {
                job = job.with_deadline(d);
            }
            if let Some(t) = tenant {
                job = job.with_client(t);
            }
            let wire = WireRequest::submit(id, &job);
            let back = decode_request(&encode_request(&wire)).unwrap();
            assert_eq!(back, wire);
            // And the server-side reconstruction matches the original job.
            assert_eq!(back.into_job().unwrap(), job);
        }
    }
    let m = WireRequest::Metrics { id: 77 };
    assert_eq!(decode_request(&encode_request(&m)).unwrap(), m);
}

/// Every error variant the wire can carry (all thirteen codes).
fn all_errors() -> Vec<FabricError> {
    vec![
        FabricError::QueueFull,
        FabricError::DeadlineExceeded,
        FabricError::Cancelled,
        FabricError::ShapeMismatch { a: 3, b: 5 },
        FabricError::UnsupportedMode { family: Family::Scale, mode: Mode::Sumup },
        FabricError::FamilyMismatch { family: Family::Sumup, params: Family::Dotprod },
        FabricError::InvalidConfig("cores=7".to_string()),
        FabricError::GuestFault("halt at 0x40".to_string()),
        FabricError::Backend { name: "xla".to_string(), msg: "load failed".to_string() },
        FabricError::Shutdown,
        FabricError::QuotaExceeded { tenant: "mallory".to_string() },
        FabricError::Overloaded { rule: "staged-backlog".to_string() },
        FabricError::Unauthorized { tenant: "mallory".to_string() },
    ]
}

#[test]
fn every_reply_variant_round_trips() {
    let outputs = vec![
        Output::Program { eax: -7, clocks: 123_456, cores: 4, data: vec![1, -2, 3] },
        Output::Program { eax: 0, clocks: 0, cores: 1, data: vec![] },
        Output::Scalars(Arc::from(vec![1.5f32, -0.25].into_boxed_slice())),
        Output::Rows(vec![
            Arc::from(vec![1.0f32].into_boxed_slice()),
            Arc::from(Vec::<f32>::new().into_boxed_slice()),
        ]),
    ];
    for (i, (output, route)) in outputs
        .into_iter()
        .zip([Route::Simulator, Route::Inline, Route::Accelerator, Route::Split])
        .enumerate()
    {
        let rep = WireReply::Completed {
            id: i as u64 + 1,
            completion: Completion {
                output,
                route,
                backend: "sim".to_string(),
                batch_rows: 8,
                shards: 3,
                queue_latency: Duration::from_micros(250),
                latency: Duration::from_micros(1999),
            },
        };
        assert_eq!(decode_reply(&encode_reply(&rep)).unwrap(), rep);
    }
    for (i, error) in all_errors().into_iter().enumerate() {
        let rep = WireReply::Failed { id: 100 + i as u64, error };
        assert_eq!(decode_reply(&encode_reply(&rep)).unwrap(), rep);
    }
    let m = WireReply::MetricsText { id: 9, text: "submitted=1\ntenants: …\n".to_string() };
    assert_eq!(decode_reply(&encode_reply(&m)).unwrap(), m);
}

#[test]
fn framing_rejects_truncation_and_oversize_with_typed_errors() {
    let payload = encode_request(&WireRequest::Metrics { id: 1 });

    // Clean EOF at a frame boundary is None, not an error.
    let mut empty: &[u8] = &[];
    assert!(read_frame(&mut empty, MAX_FRAME).unwrap().is_none());

    // EOF inside the header and inside the payload are Truncated.
    let mut framed = Vec::new();
    write_frame(&mut framed, &payload, MAX_FRAME).unwrap();
    let mut cut_header = &framed[..2];
    assert!(matches!(
        read_frame(&mut cut_header, MAX_FRAME),
        Err(CodecError::Truncated { need: 4, have: 2 })
    ));
    let mut cut_payload = &framed[..framed.len() - 1];
    assert!(matches!(read_frame(&mut cut_payload, MAX_FRAME), Err(CodecError::Truncated { .. })));

    // A header claiming more than the cap is rejected before allocation —
    // u32::MAX here would be a 4 GiB allocation if it were honoured.
    let mut hostile: &[u8] = &[0xff, 0xff, 0xff, 0xff];
    assert_eq!(
        read_frame(&mut hostile, 64).unwrap_err(),
        CodecError::Oversized { len: u32::MAX as usize, cap: 64 }
    );

    // The cap binds the writer too.
    let mut sink = Vec::new();
    assert_eq!(
        write_frame(&mut sink, &payload, 2).unwrap_err(),
        CodecError::Oversized { len: payload.len(), cap: 2 }
    );
}

#[test]
fn decode_rejects_bad_version_tag_length_and_trailing() {
    let mut p = encode_request(&WireRequest::Metrics { id: 1 });
    assert_eq!(p[0], WIRE_VERSION);
    p[0] = 42;
    assert_eq!(decode_request(&p).unwrap_err(), CodecError::BadVersion { got: 42 });

    // Unknown message tag.
    let p = vec![WIRE_VERSION, 0x7f];
    assert!(matches!(
        decode_request(&p).unwrap_err(),
        CodecError::BadTag { what: "request message", got: 0x7f }
    ));
    assert!(matches!(decode_reply(&p).unwrap_err(), CodecError::BadTag { .. }));

    // A count field claiming more elements than the payload holds is
    // BadLength — caught before any allocation sized by the claim.
    let req = WireRequest::submit(5, &JobRequest::new(RequestKind::sumup(Mode::No, vec![1, 2])));
    let good = encode_request(&req);
    let count_at = good.len() - 2 * 4 - 4; // two i32 values + u32 count
    let mut evil = good.clone();
    evil[count_at..count_at + 4].copy_from_slice(&u32::MAX.to_le_bytes());
    match decode_request(&evil) {
        Err(CodecError::BadLength { claimed, .. }) => assert_eq!(claimed, u32::MAX as usize),
        other => panic!("expected BadLength, got {other:?}"),
    }

    // Trailing garbage after a complete message.
    let mut long = good.clone();
    long.extend_from_slice(&[0, 0, 0]);
    assert_eq!(decode_request(&long).unwrap_err(), CodecError::TrailingBytes { extra: 3 });

    // Non-UTF-8 tenant bytes.
    let tagged = WireRequest::submit(
        6,
        &JobRequest::new(RequestKind::sumup(Mode::No, vec![])).with_client("zz"),
    );
    let mut bad_utf8 = encode_request(&tagged);
    let pos = bad_utf8
        .windows(2)
        .position(|w| w == b"zz")
        .expect("tenant bytes present in encoding");
    bad_utf8[pos] = 0xff;
    bad_utf8[pos + 1] = 0xfe;
    assert!(matches!(decode_request(&bad_utf8).unwrap_err(), CodecError::BadUtf8 { .. }));
}

/// Deterministic single-byte mutation sweep: whatever we do to a valid
/// payload, decoding returns `Ok` or a typed `Err` — it never panics and
/// never allocates absurdly (the suite would OOM/abort if it did).
#[test]
fn mutation_sweep_never_panics() {
    let job = JobRequest::new(RequestKind::traces(vec![
        TraceOp::new(TraceOpKind::Add, 3),
        TraceOp::new(TraceOpKind::Xor, -9),
    ]))
    .with_priority(Priority::High)
    .with_deadline(Duration::from_millis(5))
    .with_client("fuzz");
    let req = encode_request(&WireRequest::submit(1, &job));
    let rep = encode_reply(&WireReply::Failed {
        id: 1,
        error: FabricError::Backend { name: "xla".into(), msg: "m".into() },
    });

    for base in [&req, &rep] {
        for i in 0..base.len() {
            for delta in [1u8, 0x80, 0xff] {
                let mut m = base.clone();
                m[i] = m[i].wrapping_add(delta);
                let _ = decode_request(&m);
                let _ = decode_reply(&m);
            }
        }
        // Every truncation point, both decoders.
        for end in 0..base.len() {
            let _ = decode_request(&base[..end]);
            let _ = decode_reply(&base[..end]);
        }
    }
}

/// A reader that hands out its bytes in fixed chunks (never more than
/// `chunk` per `read` call) — a TCP stream under a hostile scheduler.
struct Chunked<'a> {
    data: &'a [u8],
    chunk: usize,
}

impl std::io::Read for Chunked<'_> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let n = self.data.len().min(self.chunk).min(buf.len());
        buf[..n].copy_from_slice(&self.data[..n]);
        self.data = &self.data[n..];
        Ok(n)
    }
}

/// The partial-write/short-read sweep: split a valid framed message at
/// every byte boundary. The head alone must produce a clean `None` (cut
/// at offset 0), or a typed `Truncated` — never a panic; the head
/// followed by the tail across separate `read` calls must reassemble
/// into the original payload (`read_full` keeps reading through short
/// returns).
#[test]
fn every_byte_boundary_split_is_typed_or_reassembled() {
    let payload = encode_request(&WireRequest::submit(
        9,
        &JobRequest::new(RequestKind::sumup(Mode::For, vec![4, 5, 6])).with_client("splitter"),
    ));
    let mut framed = Vec::new();
    write_frame(&mut framed, &payload, MAX_FRAME).unwrap();

    for cut in 0..=framed.len() {
        // The head alone: a short read the peer never finishes.
        let mut head = &framed[..cut];
        match read_frame(&mut head, MAX_FRAME) {
            Ok(None) => assert_eq!(cut, 0, "clean EOF only at the frame boundary"),
            Ok(Some(p)) => {
                assert_eq!(cut, framed.len());
                assert_eq!(p, payload);
            }
            Err(CodecError::Truncated { .. }) => assert!(cut > 0 && cut < framed.len()),
            Err(other) => panic!("cut {cut}: unexpected {other:?}"),
        }

        // Head + tail delivered across separate reads: must reassemble.
        let mut both = Chunked { data: &framed, chunk: cut.max(1) };
        let got = read_frame(&mut both, MAX_FRAME)
            .unwrap_or_else(|e| panic!("chunk {cut}: {e:?}"))
            .expect("full frame present");
        assert_eq!(got, payload);
    }
}

/// One byte per `read` call — the pathological drip-feed. The frame
/// still decodes, proving the length prefix and payload reads both loop
/// instead of trusting one `read` to fill the buffer.
#[test]
fn drip_fed_frame_decodes_byte_by_byte() {
    let payload = encode_request(&WireRequest::Metrics { id: 3 });
    let mut framed = Vec::new();
    write_frame(&mut framed, &payload, MAX_FRAME).unwrap();

    let mut drip = Chunked { data: &framed, chunk: 1 };
    let got = read_frame(&mut drip, MAX_FRAME).unwrap().expect("frame present");
    assert_eq!(got, payload);
    assert_eq!(decode_request(&got).unwrap(), WireRequest::Metrics { id: 3 });
}
