//! Differential stepping: the event-horizon scheduler AND the
//! host-parallel phase-A modes must be **cycle-identical** to lockstep
//! stepping — same clocks, architectural state, occupancy figures,
//! supervisor ops, bus statistics and trace — on every workload family
//! (sizes including the 0/1 edges), under interrupt servicing raised
//! mid-run, under memory-bus contention, and across randomised timing
//! models. Only the scheduler-iteration count (`events_processed`) and
//! the host-parallelism counters may differ.

use empa::empa::{EmpaConfig, EmpaProcessor, RunReport, RunState, StepMode, TimingConfig};
use empa::isa::{assemble, Reg};
use empa::mem::MemConfig;
use empa::util::Rng;
use empa::workload::family::{direct_source, family_impl, synth_params, ALL_FAMILIES};
use empa::workload::scale;
use empa::workload::sumup::{self, Mode};
use std::fmt::Write;

/// Every stepping mode that must replay lockstep bit-for-bit.
const CHALLENGERS: [StepMode; 4] = [
    StepMode::EventHorizon,
    StepMode::ParallelA { threads: 1 },
    StepMode::ParallelA { threads: 2 },
    StepMode::ParallelA { threads: 4 },
];

/// Run `image` under `step`, returning the report, the per-core
/// integrated occupancy, and the processor's final internal clock.
fn run_mode(image: &[u8], base: &EmpaConfig, step: StepMode) -> (RunReport, Vec<u64>, u64) {
    let cfg = EmpaConfig { step, trace: true, ..base.clone() };
    let mut p = EmpaProcessor::new(image, &cfg);
    let r = p.run_report();
    let busy = p.cores.iter().map(|c| c.busy_clocks).collect();
    (r, busy, p.clock)
}

/// The equivalence bar: every observable of each challenger mode must
/// match the lockstep run. Returns (lockstep, event-horizon) reports so
/// callers can keep asserting on the scheduler economics.
fn assert_identical(ctx: &str, image: &[u8], base: &EmpaConfig) -> (RunReport, RunReport) {
    let (lock, lock_busy, _) = run_mode(image, base, StepMode::Lockstep);
    assert_eq!(lock.clocks_skipped, 0, "{ctx}: lockstep never skips");
    let mut eh_report = None;
    let mut eh_events = 0u64;
    for step in CHALLENGERS {
        let (r, busy, clock) = run_mode(image, base, step);
        let ctx = format!("{ctx} [{step:?}]");
        assert_eq!(lock.clocks, r.clocks, "{ctx}: clocks");
        assert_eq!(lock.status, r.status, "{ctx}: status");
        assert_eq!(lock.regs.file, r.regs.file, "{ctx}: registers");
        assert_eq!(lock.regs.cc, r.regs.cc, "{ctx}: flags");
        assert_eq!(lock.max_occupied, r.max_occupied, "{ctx}: max_occupied");
        assert_eq!(lock.distinct_cores, r.distinct_cores, "{ctx}: distinct_cores");
        assert_eq!(lock.retired, r.retired, "{ctx}: retired");
        assert_eq!(lock.bus, r.bus, "{ctx}: bus stats");
        assert_eq!(lock.sv_ops, r.sv_ops, "{ctx}: sv_ops");
        assert_eq!(lock.fault, r.fault, "{ctx}: fault");
        assert_eq!(lock.trace.entries, r.trace.entries, "{ctx}: trace");
        assert_eq!(lock_busy, busy, "{ctx}: integrated occupancy");
        assert_eq!(
            clock,
            r.events_processed + r.clocks_skipped,
            "{ctx}: every clock is either ticked or skipped"
        );
        assert!(r.events_processed <= lock.events_processed, "{ctx}: event count");
        match step {
            StepMode::EventHorizon => {
                eh_events = r.events_processed;
                eh_report = Some(r);
            }
            StepMode::ParallelA { threads: 1 } => {
                // threads=1 IS the serial event-horizon path: same
                // scheduler iterations, no pool, no spans.
                assert_eq!(r.events_processed, eh_events, "{ctx}: serial path");
                assert_eq!(r.parallel_spans, 0, "{ctx}: no fan-out at one thread");
                assert_eq!(r.span_conflicts, 0, "{ctx}: no conflicts at one thread");
            }
            _ => {}
        }
    }
    (lock, eh_report.expect("EventHorizon is a challenger"))
}

#[test]
fn every_workload_family_steps_identically() {
    let mut rng = Rng::seed_from_u64(0x5E44);
    let base = EmpaConfig::default();
    for case in 0..3u64 {
        for family in ALL_FAMILIES {
            let fam = family_impl(family);
            for &mode in fam.modes() {
                for n in [0usize, 1, rng.range_usize(2, 48)] {
                    let params = synth_params(family, n, case.wrapping_mul(131) ^ n as u64);
                    let src = direct_source(mode, &params).unwrap();
                    let image = assemble(&src).unwrap().image;
                    let ctx = format!("{} {mode:?} N={n} case {case}", family.name());
                    assert_identical(&ctx, &image, &base);
                }
            }
        }
    }
}

#[test]
fn contended_bus_configs_step_identically() {
    for mem in [MemConfig::single_bus(), MemConfig::buses(2)] {
        for mode in [Mode::No, Mode::For, Mode::Sumup] {
            for n in [1usize, 4, 17, 40] {
                let (src, _) = sumup::program(mode, &sumup::synth_vector(n, 7));
                let image = assemble(&src).unwrap().image;
                let base = EmpaConfig { mem: mem.clone(), ..Default::default() };
                let ctx = format!("{mode:?} N={n} ports={:?}", mem.ports);
                let (lock, _) = assert_identical(&ctx, &image, &base);
                if mode == Mode::Sumup && n >= 17 && mem.ports == Some(1) {
                    assert!(lock.bus.stall_cycles > 0, "{ctx}: contention actually exercised");
                }
            }
        }
    }
}

#[test]
fn timing_sweeps_step_identically() {
    let mut rng = Rng::seed_from_u64(0x7E57);
    for case in 0..12u64 {
        let mut t = TimingConfig::paper();
        t.irmov = rng.range_u64(1, 12);
        t.alu = rng.range_u64(1, 12);
        t.mrmov = rng.range_u64(1, 16);
        t.jump = rng.range_u64(1, 10);
        t.halt = rng.range_u64(1, 6);
        t.sv_create = rng.range_u64(1, 8);
        t.sv_stagger = rng.range_u64(1, 4);
        t.sv_readout = rng.range_u64(1, 4);
        t.sumup_rent_overhead = rng.range_u64(0, 40);
        let base = EmpaConfig { timing: t, ..Default::default() };
        let n = rng.range_usize(1, 40);
        for mode in [Mode::No, Mode::For, Mode::Sumup] {
            let (src, _) = sumup::program(mode, &sumup::synth_vector(n, case));
            let image = assemble(&src).unwrap().image;
            assert_identical(&format!("timing case {case} {mode:?} N={n}"), &image, &base);
        }
    }
}

#[test]
fn core_starvation_steps_identically() {
    // Small pools exercise engine rent stalls (the `available_at`
    // wake-up source) and the SUMUP put-back administration.
    for cores in [2usize, 3, 5] {
        for mode in [Mode::For, Mode::Sumup] {
            for n in [0usize, 1, 6, 23] {
                let (src, _) = sumup::program(mode, &sumup::synth_vector(n, 3));
                let image = assemble(&src).unwrap().image;
                let base = EmpaConfig { num_cores: cores, ..Default::default() };
                assert_identical(&format!("cores={cores} {mode:?} N={n}"), &image, &base);
            }
        }
    }
}

#[test]
fn nested_qt_graphs_step_identically() {
    // A deep qcall chain: with a full pool it fans across cores, with a
    // tiny pool it falls back to §3.3 borrowing — both must step
    // identically either way.
    let depth = 20usize;
    let mut src = String::new();
    let _ = writeln!(src, "    irmovl $0, %eax");
    let _ = writeln!(src, "    qcall QT0");
    let _ = writeln!(src, "    qwait %eax");
    let _ = writeln!(src, "    halt");
    for d in 0..depth {
        let _ = writeln!(src, "QT{d}:");
        let _ = writeln!(src, "    irmovl $1, %ebx");
        let _ = writeln!(src, "    addl %ebx, %eax");
        if d + 1 < depth {
            let _ = writeln!(src, "    qcall QT{}", d + 1);
            let _ = writeln!(src, "    qwait %eax");
        }
        let _ = writeln!(src, "    qterm %eax");
    }
    let image = assemble(&src).unwrap().image;
    for cores in [1usize, 4, 32] {
        let base = EmpaConfig { num_cores: cores, ..Default::default() };
        let (lock, _) = assert_identical(&format!("qt-chain cores={cores}"), &image, &base);
        assert_eq!(lock.eax(), depth as i32);
    }
}

#[test]
fn fault_paths_step_identically() {
    // A starved FOR engine (single core, nothing rentable) deadlocks:
    // both modes must hit the runaway guard at the same clock.
    let (src, _) = sumup::for_mode_program(&[1, 2, 3]);
    let base = EmpaConfig { num_cores: 1, max_clocks: 4000, ..Default::default() };
    let (lock, eh) = assert_identical("for-mode starved", &assemble(&src).unwrap().image, &base);
    assert!(lock.fault.as_deref().unwrap_or("").contains("runaway"));
    assert_eq!(lock.clocks, 4000);
    assert!(eh.events_processed < 100, "the deadlock is skipped, not ticked through");

    // invalid instruction image
    let base = EmpaConfig { max_clocks: 4000, ..Default::default() };
    assert_identical("invalid opcode", &[0xFF, 0x00, 0x10], &base);

    // a child executing `halt` is a guest fault in both modes
    let src = "    qcall Child\n    qwait\n    halt\nChild:\n    halt\n";
    let (lock, _) = assert_identical("child halt", &assemble(src).unwrap().image, &base);
    assert!(lock.fault.is_some());
}

// ----------------------------------------------------------------------
// interrupt servicing mid-run
// ----------------------------------------------------------------------

fn irq_program() -> (empa::isa::Program, u32, u32) {
    let (mut src, _) = sumup::sumup_mode_program(&[1, 2, 3, 4, 5, 6]);
    src.push_str(
        "\nHandler:\n    mrmovl (%ebp), %edi\n    irmovl $1, %ebx\n    addl %ebx, %edi\n    rmmovl %edi, (%ebp)\n    qterm\n",
    );
    src.push_str("    .align 4\nmailbox:\n    .long 0\n");
    let prog = assemble(&src).unwrap();
    let handler = prog.symbol("Handler").unwrap();
    let mailbox = prog.symbol("mailbox").unwrap();
    (prog, handler, mailbox)
}

/// Drive the payload with interrupts raised at exact clocks, using
/// [`EmpaProcessor::set_external_wake`] so the event-horizon scheduler
/// lands on each raise clock instead of skipping it.
fn drive_irqs(step: StepMode, raise_at: &[u64], span_batch: usize) -> (Vec<(u64, u64)>, u32, u64) {
    let (prog, handler, mailbox) = irq_program();
    let cfg = EmpaConfig { step, span_batch, ..Default::default() };
    let mut p = EmpaProcessor::new(&prog.image, &cfg);
    let irq_core = p.reserve_irq_core(handler).expect("reserve");
    p.cores[irq_core].regs.file[Reg::Ebp as usize] = mailbox as i32;
    let mut pending: Vec<u64> = raise_at.to_vec();
    let mut halt_clock = 0u64;
    for _ in 0..100_000 {
        if let Some(pos) = pending.iter().position(|&t| t == p.clock) {
            pending.remove(pos);
            assert!(p.raise_irq(irq_core), "line busy at {}", p.clock);
            p.cores[irq_core].regs.file[Reg::Ebp as usize] = mailbox as i32;
        }
        p.set_external_wake(pending.iter().min().copied());
        p.step();
        if matches!(p.cores[0].run, RunState::Halted) && halt_clock == 0 {
            halt_clock = p.clock;
        }
        if halt_clock != 0 && pending.is_empty() && p.irq_log.len() >= raise_at.len() {
            break;
        }
    }
    let mbox = p.mem.read_u32(mailbox).unwrap();
    (p.irq_log.clone(), mbox, halt_clock)
}

#[test]
fn irq_servicing_steps_identically() {
    let span_batch = EmpaConfig::default().span_batch;
    for raises in [&[5u64, 50][..], &[5, 35, 90, 130][..], &[40, 80, 120][..]] {
        let (log_l, mbox_l, halt_l) = drive_irqs(StepMode::Lockstep, raises, span_batch);
        assert_eq!(log_l.len(), raises.len(), "{raises:?}: every raise serviced");
        assert_eq!(mbox_l, raises.len() as u32, "{raises:?}: mailbox counted every service");
        for step in CHALLENGERS {
            let (log_e, mbox_e, halt_e) = drive_irqs(step, raises, span_batch);
            assert_eq!(log_l, log_e, "{raises:?} [{step:?}]: per-interrupt (raised, done) clocks");
            assert_eq!(mbox_l, mbox_e, "{raises:?} [{step:?}]: handler side effects");
            assert_eq!(halt_l, halt_e, "{raises:?} [{step:?}]: payload completion clock");
        }
    }
}

// ----------------------------------------------------------------------
// effect-record paths: the scenarios host-parallel phase A must not bend
// ----------------------------------------------------------------------

/// SUMUP at this size keeps ~31 children in flight with stagger 1 and
/// per-child retirements at +8 (mrmovl) and +11 (addl), so children 3
/// apart retire on the same clock — parallel spans are guaranteed, not
/// incidental.
#[test]
fn parallel_spans_actually_fan_out_on_wide_sumup() {
    let (src, want) = sumup::sumup_mode_program(&sumup::synth_vector(128, 9));
    let image = assemble(&src).unwrap().image;
    let base = EmpaConfig::default();
    let (lock, _) = assert_identical("sumup N=128", &image, &base);
    assert_eq!(lock.eax(), want);
    for threads in [2usize, 4] {
        let (r, _, _) = run_mode(&image, &base, StepMode::ParallelA { threads });
        assert!(r.parallel_spans > 0, "t={threads}: spans actually formed");
        assert!(r.cores_per_span() >= 2.0, "t={threads}: spans hold at least two cores");
        assert_eq!(r.span_hist.iter().sum::<u64>(), r.parallel_spans, "t={threads}: histogram");
    }
}

/// Cross-shard store ordering: FOR-mode scale keeps many children
/// storing into `arrayY` (spread across the data region) while others
/// load from `arrayX` on the same clocks — the committed memory image
/// must be exactly what the serial machine writes.
#[test]
fn cross_shard_stores_commit_in_core_index_order() {
    let x: Vec<i32> = (0..96).map(|i| i * 3 - 7).collect();
    let (src, want) = scale::for_mode(&x, 5);
    let prog = assemble(&src).unwrap();
    let y_addr = prog.symbol("arrayY").unwrap();
    let base = EmpaConfig::default();
    assert_identical("scale FOR N=96", &prog.image, &base);
    for threads in [1usize, 2, 4] {
        let cfg = EmpaConfig { step: StepMode::ParallelA { threads }, ..base.clone() };
        let mut p = EmpaProcessor::new(&prog.image, &cfg);
        let r = p.run_report();
        assert_eq!(r.fault, None, "t={threads}");
        let got: Vec<i32> =
            (0..x.len()).map(|i| p.mem.read_u32(y_addr + 4 * i as u32).unwrap() as i32).collect();
        assert_eq!(got, want, "t={threads}: output array");
        if threads >= 2 {
            // body retirements at +8/+14/+22 → children 6 apart collide
            assert!(r.parallel_spans > 0, "t={threads}: stores actually overlapped in spans");
        }
    }
}

/// Two cores contending for one bus slot while a span is in flight: the
/// single-port config serialises fetches, and the bus ledger (charged at
/// fetch, never inside the span) must match lockstep exactly.
#[test]
fn bus_slot_contention_inside_spans_steps_identically() {
    let (src, _) = sumup::sumup_mode_program(&sumup::synth_vector(64, 11));
    let image = assemble(&src).unwrap().image;
    let base = EmpaConfig { mem: MemConfig::single_bus(), ..Default::default() };
    let (lock, _) = assert_identical("sumup single-bus N=64", &image, &base);
    assert!(lock.bus.stall_cycles > 0, "contention actually exercised");
    let (r, _, _) = run_mode(&image, &base, StepMode::ParallelA { threads: 4 });
    assert!(r.parallel_spans > 0, "spans formed under contention");
    assert_eq!(lock.bus, r.bus, "bus ledger identical under fan-out");
}

/// SV rent raised mid-run: a small pool forces the SUMUP engine to stall
/// on `available_at` and re-rent cores while earlier children are still
/// retiring — engine actions are sync points, so every rent lands
/// between spans at the same clock as lockstep.
#[test]
fn sv_rent_raised_mid_span_steps_identically() {
    for cores in [3usize, 5, 9] {
        let (src, _) = sumup::sumup_mode_program(&sumup::synth_vector(40, 13));
        let image = assemble(&src).unwrap().image;
        let base = EmpaConfig { num_cores: cores, ..Default::default() };
        let (lock, _) = assert_identical(&format!("sumup rent cores={cores}"), &image, &base);
        assert!(lock.sv_ops > 0, "cores={cores}: the engine actually rented");
    }
}

/// IRQ raised while a parallel span is possible: the raise is a sync
/// point, so the handler's (raised, done) clocks and side effects must
/// not shift under any thread count — covered per-mode above in
/// `irq_servicing_steps_identically`; this pins the wide-payload case
/// where spans are dense around the raise clocks.
#[test]
fn irq_raise_inside_a_parallel_span_steps_identically() {
    let raises = &[30u64, 61, 95][..];
    let span_batch = EmpaConfig::default().span_batch;
    let (log_l, mbox_l, halt_l) = drive_irqs(StepMode::Lockstep, raises, span_batch);
    for threads in [2usize, 4] {
        let (log_p, mbox_p, halt_p) =
            drive_irqs(StepMode::ParallelA { threads }, raises, span_batch);
        assert_eq!(log_l, log_p, "t={threads}: interrupt clocks");
        assert_eq!(mbox_l, mbox_p, "t={threads}: handler side effects");
        assert_eq!(halt_l, halt_p, "t={threads}: payload completion clock");
    }
}

// ----------------------------------------------------------------------
// multi-clock span batching: the sweep and its truncation scenarios
// ----------------------------------------------------------------------

/// The span-batch sweep: 1 (batching disabled), 4 (windows truncate on
/// the cap constantly) and 64 (windows end on sync points long before
/// the cap) must all replay lockstep bit-for-bit on every workload
/// shape, and span_batch=1 must never record a batched clock.
#[test]
fn span_batch_sweep_steps_identically() {
    for span_batch in [1usize, 4, 64] {
        let base = EmpaConfig { span_batch, ..Default::default() };
        for mode in [Mode::No, Mode::For, Mode::Sumup] {
            for n in [0usize, 1, 17, 48] {
                let (src, _) = sumup::program(mode, &sumup::synth_vector(n, 21));
                let image = assemble(&src).unwrap().image;
                let ctx = format!("span_batch={span_batch} {mode:?} N={n}");
                assert_identical(&ctx, &image, &base);
                for threads in [2usize, 4] {
                    let (r, _, _) = run_mode(&image, &base, StepMode::ParallelA { threads });
                    if span_batch == 1 {
                        assert_eq!(r.batched_clocks, 0, "{ctx} t={threads}: batching disabled");
                        assert_eq!(r.span_batch_hist, [0u64; 6], "{ctx} t={threads}: no batches");
                    }
                    // Every batch lands in exactly one histogram bucket,
                    // and batches are a subset of the recorded spans.
                    let batches: u64 = r.span_batch_hist.iter().sum();
                    assert!(batches <= r.parallel_spans, "{ctx} t={threads}: batch accounting");
                    assert!(r.batched_clocks >= batches, "{ctx} t={threads}: >=1 clock per batch");
                }
            }
        }
    }
}

/// Meta retirements (`qterm`) are uniform stoppers: a child chain ends
/// its batch segment at the retirement fetch and the pending apply is a
/// window bound, so starved pools that re-rent mid-run — the densest
/// mix of engine horizons and retirements — must not bend under any cap.
#[test]
fn meta_retirement_truncation_steps_identically() {
    for span_batch in [4usize, 64] {
        for cores in [3usize, 9] {
            let (src, _) = sumup::sumup_mode_program(&sumup::synth_vector(40, 13));
            let image = assemble(&src).unwrap().image;
            let base = EmpaConfig { num_cores: cores, span_batch, ..Default::default() };
            let ctx = format!("sumup rent cores={cores} span_batch={span_batch}");
            let (lock, _) = assert_identical(&ctx, &image, &base);
            assert!(lock.sv_ops > 0, "{ctx}: the engine actually rented");
        }
    }
}

/// FOR-mode stores under a batch cap: conflict detection replays the
/// serial memory image exactly, so the committed output array must be
/// byte-identical at every cap.
#[test]
fn batched_stores_commit_the_serial_image() {
    let x: Vec<i32> = (0..96).map(|i| i * 3 - 7).collect();
    let (src, want) = scale::for_mode(&x, 5);
    let prog = assemble(&src).unwrap();
    let y_addr = prog.symbol("arrayY").unwrap();
    for span_batch in [1usize, 4, 64] {
        let base = EmpaConfig { span_batch, ..Default::default() };
        assert_identical(&format!("scale FOR span_batch={span_batch}"), &prog.image, &base);
        let cfg = EmpaConfig { step: StepMode::ParallelA { threads: 4 }, ..base };
        let mut p = EmpaProcessor::new(&prog.image, &cfg);
        let r = p.run_report();
        assert_eq!(r.fault, None, "span_batch={span_batch}");
        let got: Vec<i32> =
            (0..x.len()).map(|i| p.mem.read_u32(y_addr + 4 * i as u32).unwrap() as i32).collect();
        assert_eq!(got, want, "span_batch={span_batch}: output array");
    }
}

/// An interrupt raised on a clock a batch would otherwise swallow: the
/// external wake is a hard window bound, so the handler's (raised, done)
/// clocks and side effects must not shift at any cap.
#[test]
fn irq_raised_on_a_batched_clock_steps_identically() {
    let raises = &[30u64, 61, 95][..];
    let (log_l, mbox_l, halt_l) = drive_irqs(StepMode::Lockstep, raises, 1);
    for span_batch in [1usize, 4, 64] {
        for threads in [2usize, 4] {
            let (log_p, mbox_p, halt_p) =
                drive_irqs(StepMode::ParallelA { threads }, raises, span_batch);
            let ctx = format!("t={threads} span_batch={span_batch}");
            assert_eq!(log_l, log_p, "{ctx}: interrupt clocks");
            assert_eq!(mbox_l, mbox_p, "{ctx}: handler side effects");
            assert_eq!(halt_l, halt_p, "{ctx}: payload completion clock");
        }
    }
}

// ----------------------------------------------------------------------
// span batching under contended buses + engine-inclusive windows (PR 9)
// ----------------------------------------------------------------------

/// The full ported-bus batching sweep: ports {1, 2} × span_batch
/// {1, 4, 64} × every challenger mode (threads 1, 2, 4 inside
/// `assert_identical`). The bus ledger — accesses, stalled accesses,
/// stall cycles — must close bit-identically whether the charges were
/// made serially at fetch or replayed at batch commit. (SUMUP's dense
/// `qterm` retirements bound most windows here, so batching>0 is pinned
/// by the named stall-shift scenario below, not by this sweep.)
#[test]
fn ported_bus_span_batch_sweep_steps_identically() {
    for mem in [MemConfig::single_bus(), MemConfig::buses(2)] {
        for span_batch in [1usize, 4, 64] {
            for mode in [Mode::No, Mode::Sumup] {
                for n in [1usize, 17, 48] {
                    let (src, _) = sumup::program(mode, &sumup::synth_vector(n, 29));
                    let image = assemble(&src).unwrap().image;
                    let base =
                        EmpaConfig { mem: mem.clone(), span_batch, ..Default::default() };
                    let ctx = format!(
                        "ports={:?} span_batch={span_batch} {mode:?} N={n}",
                        mem.ports
                    );
                    assert_identical(&ctx, &image, &base);
                    for threads in [2usize, 4] {
                        let (r, _, _) = run_mode(&image, &base, StepMode::ParallelA { threads });
                        if span_batch == 1 {
                            assert_eq!(r.batched_clocks, 0, "{ctx} t={threads}: cap 1");
                        }
                        assert_eq!(
                            r.batched_ported_clocks, r.batched_clocks,
                            "{ctx} t={threads}: every batched clock here ran ported"
                        );
                    }
                }
            }
        }
    }
}

/// Stall-shifted truncation: two children with different fetch periods
/// (pure mrmovl line vs mrmovl+addl) hammer one shared port, so their
/// access phases drift through every residue and some replayed charges
/// come back stalled *inside* batched windows. The stall must shift the
/// chain's apply time exactly as the serial fetch would and truncate the
/// window — observable as `bus_replay_truncations` — while clocks,
/// occupancy and the bus ledger stay bit-identical.
#[test]
fn ported_bus_stall_shift_truncates_span_batches() {
    let mut src = String::new();
    let _ = writeln!(src, "    qcall ChildA");
    let _ = writeln!(src, "    qcall ChildB");
    let _ = writeln!(src, "    qwait");
    let _ = writeln!(src, "    halt");
    let _ = writeln!(src, "ChildA:");
    let _ = writeln!(src, "    irmovl $0x400, %ecx");
    for _ in 0..24 {
        let _ = writeln!(src, "    mrmovl (%ecx), %esi");
    }
    let _ = writeln!(src, "    qterm");
    let _ = writeln!(src, "ChildB:");
    let _ = writeln!(src, "    irmovl $0x440, %edx");
    for _ in 0..24 {
        let _ = writeln!(src, "    mrmovl (%edx), %edi");
        let _ = writeln!(src, "    addl %edi, %ebx");
    }
    let _ = writeln!(src, "    qterm");
    let image = assemble(&src).unwrap().image;
    for span_batch in [1usize, 4, 64] {
        let base = EmpaConfig {
            mem: MemConfig::single_bus(),
            span_batch,
            ..Default::default()
        };
        let ctx = format!("stall-shift span_batch={span_batch}");
        let (lock, _) = assert_identical(&ctx, &image, &base);
        assert_eq!(lock.fault, None, "{ctx}");
        assert!(lock.bus.stall_cycles > 0, "{ctx}: the periods actually collide");
        if span_batch >= 4 {
            for threads in [2usize, 4] {
                let (r, _, _) = run_mode(&image, &base, StepMode::ParallelA { threads });
                assert!(r.batched_clocks > 0, "{ctx} t={threads}: windows formed");
                assert_eq!(r.batched_ported_clocks, r.batched_clocks, "{ctx} t={threads}");
                assert!(
                    r.bus_replay_truncations > 0,
                    "{ctx} t={threads}: some stall landed inside a window"
                );
            }
        }
    }
}

/// Engine-inclusive windows: a SUMUP engine stays mid-flight (two
/// streamed arrivals, then a long 32-clock readout) while two unrelated
/// compute children chain freely. Windows must keep forming with the
/// engine active — non-final `%pp` arrivals commit in-window, the final
/// arrival and the readout bound their windows — and the whole run must
/// stay cycle-identical at every cap.
#[test]
fn engine_inclusive_span_batch_windows_steps_identically() {
    let mut src = String::new();
    let _ = writeln!(src, "    qcall CompA");
    let _ = writeln!(src, "    qcall CompB");
    let _ = writeln!(src, "    irmovl $2, %edx");
    let _ = writeln!(src, "    irmovl array, %ecx");
    let _ = writeln!(src, "    qprealloc $2");
    let _ = writeln!(src, "    qmasssum Body");
    let _ = writeln!(src, "    halt");
    for (label, reg) in [("CompA", "%ecx"), ("CompB", "%edx")] {
        let _ = writeln!(src, "{label}:");
        let _ = writeln!(src, "    irmovl $3, %ebx");
        for _ in 0..40 {
            let _ = writeln!(src, "    addl %ebx, {reg}");
        }
        let _ = writeln!(src, "    qterm");
    }
    let _ = writeln!(src, "Body:");
    let _ = writeln!(src, "    mrmovl (%ecx), %esi");
    let _ = writeln!(src, "    addl %esi, %pp");
    let _ = writeln!(src, "    qterm");
    let _ = writeln!(src, "    .align 4");
    let _ = writeln!(src, "array:");
    let _ = writeln!(src, "    .long 21");
    let _ = writeln!(src, "    .long 34");
    let image = assemble(&src).unwrap().image;
    let mut timing = TimingConfig::paper();
    // A long adder readout keeps the engine mid-flight for 32 clocks
    // after the final arrival — prime window space for the compute
    // chains to batch across.
    timing.sv_readout = 32;
    for span_batch in [1usize, 4, 64] {
        let base = EmpaConfig { timing: timing.clone(), span_batch, ..Default::default() };
        let ctx = format!("engine-inclusive span_batch={span_batch}");
        let (lock, _) = assert_identical(&ctx, &image, &base);
        assert_eq!(lock.fault, None, "{ctx}");
        assert!(lock.sv_ops > 0, "{ctx}: the engine actually ran");
        if span_batch >= 4 {
            for threads in [2usize, 4] {
                let (r, _, _) = run_mode(&image, &base, StepMode::ParallelA { threads });
                assert!(
                    r.engine_batched_clocks > 0,
                    "{ctx} t={threads}: windows formed while the engine was mid-flight"
                );
                assert!(r.batched_clocks >= r.engine_batched_clocks, "{ctx} t={threads}");
            }
        }
    }
}

// ----------------------------------------------------------------------
// the acceptance bar for the scheduler's economics
// ----------------------------------------------------------------------

#[test]
fn no_mode_n4096_uses_at_least_5x_fewer_scheduler_iterations() {
    let (src, _) = sumup::no_mode_program(&sumup::synth_vector(4096, 1));
    let image = assemble(&src).unwrap().image;
    let (lock, eh) = assert_identical("NO N=4096", &image, &EmpaConfig::default());
    assert_eq!(lock.clocks, 22 + 30 * 4096, "Table 1 time law");
    assert!(
        eh.events_processed * 5 <= lock.events_processed,
        "events={} vs ticks={}: the ≥5× bar",
        eh.events_processed,
        lock.events_processed
    );
    assert!(eh.clocks_per_event() >= 5.0);
}
