//! Supervisor-state invariants over randomised QT-graph programs — the
//! proptest-style suite for the coordinator's bookkeeping (bitmasks, pool,
//! latches). After *every* run (and for SUMUP, at every clock via the
//! trace) the supervisor's view of the machine must be consistent.

use empa::empa::{AllocState, EmpaConfig, EmpaProcessor, Event, RunState};
use empa::isa::assemble;
use empa::util::Rng;
use empa::workload::sumup;
use std::fmt::Write;

/// Build a random nested QT-graph program: a tree of qcall QTs of random
/// arity/depth, every leaf doing arithmetic on the inherited %eax and
/// cloning it back; parents qwait and add the children's results.
///
/// Returns (source, expected %eax) — expected computed by mirroring the
/// tree walk.
fn random_qt_tree(rng: &mut Rng, max_depth: usize) -> (String, i32) {
    let mut src = String::new();
    let mut bodies = String::new();
    let mut label = 0usize;

    // The value function: each node adds `imm` to the inherited value and
    // returns inherited + imm + sum(children deltas). We build nodes
    // recursively and compute expected deltas alongside.
    fn gen_node(
        rng: &mut Rng,
        depth: usize,
        max_depth: usize,
        label: &mut usize,
        bodies: &mut String,
    ) -> (String, i32) {
        let my = *label;
        *label += 1;
        let imm = rng.i32() % 100;
        let n_children = if depth >= max_depth { 0 } else { rng.range_usize(0, 2) };
        let mut child_labels = Vec::new();
        let mut delta = imm;
        for _ in 0..n_children {
            let (cl, cd) = gen_node(rng, depth + 1, max_depth, label, bodies);
            delta += cd;
            child_labels.push(cl);
        }
        let name = format!("QT{my}");
        let mut b = String::new();
        let _ = writeln!(b, "{name}:");
        let _ = writeln!(b, "    irmovl ${imm}, %ebx");
        let _ = writeln!(b, "    addl %ebx, %eax");
        for cl in &child_labels {
            let _ = writeln!(b, "    qcall {cl}");
            let _ = writeln!(b, "    qwait %eax");
        }
        let _ = writeln!(b, "    qterm %eax");
        bodies.push_str(&b);
        (name, delta)
    }

    let start = rng.i32() % 1000;
    let (root_label, delta) = gen_node(rng, 0, max_depth, &mut label, &mut bodies);
    let _ = writeln!(src, "    irmovl ${start}, %eax");
    let _ = writeln!(src, "    qcall {root_label}");
    let _ = writeln!(src, "    qwait %eax");
    let _ = writeln!(src, "    halt");
    src.push_str(&bodies);
    (src, start.wrapping_add(delta))
}

#[test]
fn random_qt_trees_compute_correctly_with_plenty_of_cores() {
    let mut rng = Rng::seed_from_u64(0x71EE);
    for case in 0..120 {
        let (src, expected) = random_qt_tree(&mut rng, 3);
        let prog = assemble(&src).unwrap_or_else(|e| panic!("case {case}: {e}\n{src}"));
        let r = EmpaProcessor::new(&prog.image, &EmpaConfig::default()).run();
        assert_eq!(r.fault, None, "case {case}:\n{src}");
        assert_eq!(r.eax(), expected, "case {case}:\n{src}");
    }
}

#[test]
fn qt_trees_survive_core_starvation_via_borrowing() {
    // With very few cores the emergency mechanism (§3.3) must keep the
    // computation correct (children executed inline on the parent).
    let mut rng = Rng::seed_from_u64(0x5AAD);
    for cores in [1usize, 2, 3] {
        for case in 0..40 {
            let (src, expected) = random_qt_tree(&mut rng, 3);
            let prog = assemble(&src).unwrap();
            let cfg = EmpaConfig { num_cores: cores, ..Default::default() };
            let r = EmpaProcessor::new(&prog.image, &cfg).run();
            assert_eq!(r.fault, None, "cores={cores} case {case}:\n{src}");
            assert_eq!(r.eax(), expected, "cores={cores} case {case}:\n{src}");
            assert!(r.max_occupied <= cores, "cores={cores}: occupied {}", r.max_occupied);
        }
    }
}

/// Replay a trace and check supervisor bookkeeping invariants hold at
/// every event: a core is never double-rented, every launch has a parent
/// that is rented, every termination matches a prior launch.
#[test]
fn trace_level_pool_invariants_for_sumup() {
    for n in [1usize, 4, 17, 30, 31, 64, 200] {
        let values: Vec<i32> = (0..n as i32).collect();
        let (src, _) = sumup::sumup_mode_program(&values);
        let prog = assemble(&src).unwrap();
        let cfg = EmpaConfig { trace: true, ..Default::default() };
        let r = EmpaProcessor::new(&prog.image, &cfg).run();
        assert_eq!(r.fault, None);

        let mut live: std::collections::HashSet<usize> = std::collections::HashSet::new();
        let mut launches = 0u32;
        let mut terms = 0u32;
        for e in &r.trace.entries {
            match e.event {
                Event::Launch { parent, .. } => {
                    assert!(!live.contains(&e.core), "N={n}: core {} double-launched", e.core);
                    assert_ne!(parent, e.core, "N={n}: self-parenting");
                    live.insert(e.core);
                    launches += 1;
                }
                Event::Term { .. } => {
                    assert!(live.remove(&e.core), "N={n}: core {} terminated but not live", e.core);
                    terms += 1;
                }
                _ => {}
            }
        }
        assert_eq!(launches, n as u32, "N={n}: one launch per element");
        assert_eq!(terms, n as u32, "N={n}: one termination per element");
        assert!(live.is_empty(), "N={n}: cores leaked: {live:?}");
    }
}

#[test]
fn final_state_pool_is_clean_after_every_mode() {
    // After a run every non-root core must be back in the pool with no
    // parent/children bits set (checked through the processor's public
    // state by re-running step-by-step to completion).
    for mode in [sumup::Mode::No, sumup::Mode::For, sumup::Mode::Sumup] {
        let (src, _) = sumup::program(mode, &[5, 6, 7, 8, 9]);
        let prog = assemble(&src).unwrap();
        let mut p = EmpaProcessor::new(&prog.image, &EmpaConfig::default());
        for _ in 0..100_000 {
            p.tick();
            if matches!(p.cores[0].run, RunState::Halted) {
                break;
            }
        }
        assert!(matches!(p.cores[0].run, RunState::Halted), "{mode:?} halted");
        assert_eq!(p.cores[0].children, 0, "{mode:?}: root children mask clear");
        for c in &p.cores[1..] {
            assert_ne!(c.alloc, AllocState::Rented, "{mode:?}: core {} still rented", c.id);
            assert_eq!(c.children, 0, "{mode:?}: core {} children", c.id);
            assert!(c.parent.is_none(), "{mode:?}: core {} parent", c.id);
        }
        // Preallocated cores may remain reserved to the root (FOR/SUMUP
        // prealloc survives the program; the OS would reclaim on exit) —
        // but each reservation must be mirrored in the root's mask.
        for c in &p.cores[1..] {
            if let AllocState::PreAllocatedBy { parent } = c.alloc {
                assert_eq!(parent, 0);
                assert_ne!(p.cores[0].prealloc & c.mask(), 0, "prealloc mask mirrors");
            }
        }
    }
}

#[test]
fn occupancy_never_exceeds_prealloc_plus_parent_in_sumup() {
    for n in [4usize, 30, 64, 500] {
        let values: Vec<i32> = (0..n as i32).collect();
        let (src, _) = sumup::sumup_mode_program(&values);
        let prog = assemble(&src).unwrap();
        let r = EmpaProcessor::new(&prog.image, &EmpaConfig::default()).run();
        assert_eq!(r.fault, None);
        assert!(r.max_occupied <= n.min(30) + 1, "N={n}: {}", r.max_occupied);
        assert_eq!(r.distinct_cores, n.min(30) + 1, "N={n}");
    }
}

#[test]
fn deep_nesting_exhausts_gracefully() {
    // A pathological 40-deep chain of QTs on a 32-core processor must
    // finish via borrowing, not deadlock or fault.
    let mut src = String::new();
    let _ = writeln!(src, "    irmovl $0, %eax");
    let _ = writeln!(src, "    qcall QT0");
    let _ = writeln!(src, "    qwait %eax");
    let _ = writeln!(src, "    halt");
    let depth = 40;
    for d in 0..depth {
        let _ = writeln!(src, "QT{d}:");
        let _ = writeln!(src, "    irmovl $1, %ebx");
        let _ = writeln!(src, "    addl %ebx, %eax");
        if d + 1 < depth {
            let _ = writeln!(src, "    qcall QT{}", d + 1);
            let _ = writeln!(src, "    qwait %eax");
        }
        let _ = writeln!(src, "    qterm %eax");
    }
    let prog = assemble(&src).unwrap();
    let r = EmpaProcessor::new(&prog.image, &EmpaConfig::default()).run();
    assert_eq!(r.fault, None);
    assert_eq!(r.eax(), depth as i32);
}
