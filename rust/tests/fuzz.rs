//! Robustness fuzzing (proptest-style, in-crate PRNG): the decoder,
//! assembler and simulator must never panic on hostile input, the
//! architectural results must be invariant under timing perturbations,
//! and every workload family must match its oracle through both the
//! direct compile-once pipeline and the fabric service.

use empa::api::RequestKind;
use empa::coordinator::{Fabric, FabricConfig};
use empa::empa::{EmpaConfig, EmpaProcessor, TimingConfig};
use empa::isa::{assemble, disassemble, Insn};
use empa::util::Rng;
use empa::workload::family::{direct_source, family_impl, read_span, synth_params, ALL_FAMILIES};
use empa::workload::sumup::{self, Mode};

#[test]
fn decoder_never_panics_on_random_bytes() {
    let mut rng = Rng::seed_from_u64(0xF022);
    for _ in 0..20_000 {
        let len = rng.range_usize(0, 8);
        let bytes: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
        if let Some((insn, n)) = Insn::decode(&bytes) {
            assert!(n >= 1 && n <= 6 && n <= bytes.len());
            // decoded instructions re-encode to the same prefix
            let mut buf = Vec::new();
            insn.encode(&mut buf);
            assert_eq!(&bytes[..n], &buf[..], "{insn:?}");
        }
    }
}

#[test]
fn decode_encode_roundtrip_for_every_two_byte_prefix() {
    // Exhaustive over the first two bytes (covers every icode:ifun and
    // register-pair combination), with a fixed constant tail.
    for b0 in 0..=255u8 {
        for b1 in 0..=255u8 {
            let bytes = [b0, b1, 0x44, 0x33, 0x22, 0x11];
            if let Some((insn, n)) = Insn::decode(&bytes) {
                let mut buf = Vec::new();
                insn.encode(&mut buf);
                assert_eq!(&bytes[..n], &buf[..], "{b0:02x}{b1:02x}");
            }
        }
    }
}

#[test]
fn assembler_never_panics_on_random_text() {
    let mut rng = Rng::seed_from_u64(0xA53);
    let fragments = [
        "irmovl", "$4", "%eax", ",", "(", ")", ":", "Loop", ".pos", ".long", "0x", "-", "qmassfor",
        "qterm", "halt", "#", "mrmovl", "8(%ecx)", "%pp", ".align", "999999999999",
    ];
    for _ in 0..3_000 {
        let mut src = String::new();
        for _ in 0..rng.range_usize(1, 30) {
            src.push_str(fragments[rng.range_usize(0, fragments.len() - 1)]);
            src.push(if rng.bool(0.3) { '\n' } else { ' ' });
        }
        let _ = assemble(&src); // must return Ok or Err, never panic
    }
}

#[test]
fn disassembler_never_panics_on_random_images() {
    let mut rng = Rng::seed_from_u64(0xD15);
    for _ in 0..2_000 {
        let len = rng.range_usize(0, 64);
        let image: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
        let listing = disassemble(&image, 0);
        // listing lengths are consistent
        let mut pc = 0u32;
        for (addr, n, _) in listing {
            assert_eq!(addr, pc);
            pc += n as u32;
        }
    }
}

#[test]
fn simulator_never_panics_on_random_images() {
    // Random bytes as a program: the machine must stop with a fault or
    // halt within the guard, never panic.
    let mut rng = Rng::seed_from_u64(0x51A1);
    for _ in 0..300 {
        let len = rng.range_usize(1, 128);
        let image: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
        let cfg = EmpaConfig { max_clocks: 20_000, ..Default::default() };
        let _ = EmpaProcessor::new(&image, &cfg).run();
    }
}

/// Random (sane) timing configurations: the *results* of all three modes
/// must not depend on the cost model, only the clock counts may.
#[test]
fn results_invariant_under_timing_sweeps() {
    let mut rng = Rng::seed_from_u64(0x71E5);
    for case in 0..40 {
        let mut t = TimingConfig::paper();
        t.irmov = rng.range_u64(1, 12);
        t.alu = rng.range_u64(1, 12);
        t.mrmov = rng.range_u64(1, 16);
        t.jump = rng.range_u64(1, 10);
        t.halt = rng.range_u64(1, 6);
        t.sv_create = rng.range_u64(1, 8);
        t.sv_stagger = rng.range_u64(1, 4);
        t.sumup_rent_overhead = rng.range_u64(0, 40);
        let cfg = EmpaConfig { timing: t, ..Default::default() };
        let n = rng.range_usize(1, 40);
        let values = sumup::synth_vector(n, case);
        let expect: i32 = values.iter().fold(0i32, |a, &b| a.wrapping_add(b));
        for mode in [Mode::No, Mode::For, Mode::Sumup] {
            let (src, _) = sumup::program(mode, &values);
            let prog = assemble(&src).unwrap();
            let r = EmpaProcessor::new(&prog.image, &cfg).run();
            assert_eq!(r.fault, None, "case {case} {mode:?} N={n}");
            assert_eq!(r.eax(), expect, "case {case} {mode:?} N={n}");
        }
    }
}

/// SUMUP's 1-clock-per-extra-element law holds for any stagger=1 timing:
/// the adder consumption rate is the stagger, not the child body length.
#[test]
fn sumup_marginal_cost_equals_stagger() {
    let mut rng = Rng::seed_from_u64(0x57A6);
    for _ in 0..15 {
        let mut t = TimingConfig::paper();
        t.mrmov = rng.range_u64(2, 20);
        t.alu = rng.range_u64(1, 10);
        let stagger = rng.range_u64(1, 3);
        t.sv_stagger = stagger;
        let cfg = EmpaConfig { timing: t, ..Default::default() };
        let clocks = |n: usize| {
            let (src, _) = sumup::sumup_mode_program(&sumup::synth_vector(n, 9));
            let prog = assemble(&src).unwrap();
            EmpaProcessor::new(&prog.image, &cfg).run().clocks
        };
        // marginal cost beyond the pipeline-fill region
        let a = clocks(12);
        let b = clocks(18);
        assert_eq!(b - a, 6 * stagger, "stagger {stagger}");
    }
}

/// Differential test over every workload family (random sizes including
/// the 0 and 1 edges): the patched-template pipeline, the directly
/// generated source, and the fabric service must all agree with the
/// family oracle — and with each other, byte-for-byte at the image
/// level.
#[test]
fn workload_families_match_oracles_direct_and_via_fabric() {
    let mut rng = Rng::seed_from_u64(0xFA111);
    let cfg = EmpaConfig::default();
    let fabric = Fabric::start_local(FabricConfig { sim_workers: 2, ..Default::default() });
    let client = fabric.client();
    for case in 0..6u64 {
        for family in ALL_FAMILIES {
            let fam = family_impl(family);
            for &mode in fam.modes() {
                // always exercise the 0 and 1 edges, plus a random size
                for n in [0usize, 1, rng.range_usize(2, 40)] {
                    let params = synth_params(family, n, case.wrapping_mul(97) ^ n as u64);
                    let want = fam.oracle(&params).unwrap();

                    // --- direct pipeline: template + patch -------------
                    let sc = fam.size_class(&params).unwrap();
                    let tpl = assemble(&fam.template(mode, sc).unwrap()).unwrap();
                    let mut image = tpl.image.clone();
                    for (sym, words) in fam.data_image(&params).unwrap() {
                        tpl.patch_into(&mut image, sym, &words).unwrap();
                    }
                    // byte-identical to the pre-pipeline source path
                    let direct = assemble(&direct_source(mode, &params).unwrap()).unwrap();
                    assert_eq!(image, direct.image, "{} {mode:?} N={n}", family.name());

                    let mut proc = EmpaProcessor::new(&image, &cfg);
                    let r = proc.run_report();
                    assert_eq!(r.fault, None, "{} {mode:?} N={n}", family.name());
                    let data: Vec<i32> = match fam.readback(&params) {
                        Some((sym, words)) => read_span(&tpl, &proc.mem, sym, words).unwrap(),
                        None => Vec::new(),
                    };
                    assert!(
                        want.matches(r.eax(), &data),
                        "direct {} {mode:?} N={n}: want {want:?}, eax={} data={data:?}",
                        family.name(),
                        r.eax()
                    );

                    // --- fabric path -----------------------------------
                    let job = client
                        .submit(RequestKind::RunProgram { family, mode, params })
                        .unwrap();
                    let c = job.wait().unwrap_or_else(|e| {
                        panic!("fabric {} {mode:?} N={n}: {e}", family.name())
                    });
                    let empa::api::Output::Program { eax, data: fdata, clocks, .. } = &c.output
                    else {
                        panic!("program output expected");
                    };
                    assert!(
                        want.matches(*eax, fdata),
                        "fabric {} {mode:?} N={n}: want {want:?}, eax={eax} data={fdata:?}",
                        family.name()
                    );
                    // the two paths agree with each other, not just the oracle
                    assert_eq!((*eax, fdata), (r.eax(), &data), "{} {mode:?} N={n}", family.name());
                    assert_eq!(*clocks, r.clocks, "served run is cycle-identical");
                }
            }
        }
    }
    fabric.shutdown();
}

/// The FOR-mode marginal cost is the child body length, for any timing.
#[test]
fn for_marginal_cost_equals_child_body() {
    let mut rng = Rng::seed_from_u64(0xF0A);
    for _ in 0..15 {
        let mut t = TimingConfig::paper();
        t.mrmov = rng.range_u64(2, 20);
        t.alu = rng.range_u64(1, 10);
        let body = t.mrmov + t.alu;
        let cfg = EmpaConfig { timing: t, ..Default::default() };
        let clocks = |n: usize| {
            let (src, _) = sumup::for_mode_program(&sumup::synth_vector(n, 4));
            let prog = assemble(&src).unwrap();
            EmpaProcessor::new(&prog.image, &cfg).run().clocks
        };
        assert_eq!(clocks(9) - clocks(5), 4 * body);
    }
}
