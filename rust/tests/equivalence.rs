//! Equivalence of the two execution substrates: a program without
//! metainstructions must behave *identically* (state and clock count) on
//! the conventional CPU ([`empa::emu::Cpu`]) and on an EMPA processor
//! (§4.1.1: "For the outside world, the processor is nearly unchanged").
//!
//! Randomised straight-line programs stand in for proptest (offline
//! image): generate, run on both, compare final register file, flags,
//! memory effects, status and clocks.

use empa::emu::Cpu;
use empa::empa::{EmpaConfig, EmpaProcessor};
use empa::isa::{assemble, Status};
use empa::util::Rng;
use std::fmt::Write;

/// Generate a random straight-line program (no control flow, so it always
/// terminates) over registers %eax..%edi and a 64-byte scratch buffer.
fn random_program(rng: &mut Rng, len: usize) -> String {
    const REGS: [&str; 6] = ["%eax", "%ecx", "%edx", "%ebx", "%esi", "%edi"];
    let mut s = String::new();
    // scratch pointer in %ebp, stack in %esp
    s.push_str("    irmovl buf, %ebp\n    irmovl $0x4000, %esp\n");
    for _ in 0..len {
        let r1 = REGS[rng.range_usize(0, REGS.len() - 1)];
        let r2 = REGS[rng.range_usize(0, REGS.len() - 1)];
        let imm = rng.i32() % 1000;
        let disp = 4 * rng.range_usize(0, 15);
        match rng.below(8) {
            0 => { let _ = writeln!(s, "    irmovl ${imm}, {r1}"); }
            1 => { let _ = writeln!(s, "    addl {r1}, {r2}"); }
            2 => { let _ = writeln!(s, "    subl {r1}, {r2}"); }
            3 => { let _ = writeln!(s, "    andl {r1}, {r2}"); }
            4 => { let _ = writeln!(s, "    xorl {r1}, {r2}"); }
            5 => { let _ = writeln!(s, "    rmmovl {r1}, {disp}(%ebp)"); }
            6 => { let _ = writeln!(s, "    mrmovl {disp}(%ebp), {r1}"); }
            _ => { let _ = writeln!(s, "    rrmovl {r1}, {r2}"); }
        }
    }
    s.push_str("    halt\n    .pos 0x200\nbuf:\n");
    for _ in 0..16 {
        let _ = writeln!(s, "    .long {}", rng.i32() % 100000);
    }
    s
}

#[test]
fn random_straightline_programs_agree_on_both_substrates() {
    let mut rng = Rng::seed_from_u64(0xE117A);
    for case in 0..200 {
        let src = random_program(&mut rng, 30);
        let prog = assemble(&src).expect("assembles");

        let mut cpu = Cpu::with_image(&prog.image);
        cpu.run(1_000_000);

        let report = EmpaProcessor::new(&prog.image, &EmpaConfig::default()).run();

        assert_eq!(cpu.status, Status::Hlt, "case {case}: cpu status");
        assert_eq!(report.status, Status::Hlt, "case {case}: empa status");
        assert_eq!(cpu.regs.file, report.regs.file, "case {case}: registers\n{src}");
        assert_eq!(cpu.regs.cc, report.regs.cc, "case {case}: flags");
        assert_eq!(cpu.clock, report.clocks, "case {case}: clock count");
        assert_eq!(report.max_occupied, 1, "case {case}: no extra cores");
    }
}

#[test]
fn random_programs_with_branches_agree() {
    // Branchy but guaranteed-terminating: a countdown loop around a random
    // straight-line body.
    let mut rng = Rng::seed_from_u64(0xB0DE);
    for case in 0..100 {
        // body over registers that exclude the %edi loop counter and the
        // %ebx decrement scratch
        const BODY_REGS: [&str; 4] = ["%eax", "%ecx", "%edx", "%esi"];
        let mut body_insns = String::from("    irmovl buf, %ebp\n");
        for _ in 0..10 {
            let r1 = BODY_REGS[rng.range_usize(0, BODY_REGS.len() - 1)];
            let r2 = BODY_REGS[rng.range_usize(0, BODY_REGS.len() - 1)];
            let imm = rng.i32() % 1000;
            let disp = 4 * rng.range_usize(0, 15);
            match rng.below(7) {
                0 => { let _ = writeln!(body_insns, "    irmovl ${imm}, {r1}"); }
                1 => { let _ = writeln!(body_insns, "    addl {r1}, {r2}"); }
                2 => { let _ = writeln!(body_insns, "    subl {r1}, {r2}"); }
                3 => { let _ = writeln!(body_insns, "    xorl {r1}, {r2}"); }
                4 => { let _ = writeln!(body_insns, "    rmmovl {r1}, {disp}(%ebp)"); }
                5 => { let _ = writeln!(body_insns, "    mrmovl {disp}(%ebp), {r1}"); }
                _ => { let _ = writeln!(body_insns, "    rrmovl {r1}, {r2}"); }
            }
        }
        let iters = rng.range_u64(1, 5);
        let src = format!(
            "    irmovl ${iters}, %edi\nLoop:\n{body_insns}\n    irmovl $-1, %ebx\n    addl %ebx, %edi\n    jne Loop\n    halt\n    .pos 0x200\nbuf:\n    .long 1\n    .long 2\n    .long 3\n    .long 4\n    .long 5\n    .long 6\n    .long 7\n    .long 8\n    .long 9\n    .long 10\n    .long 11\n    .long 12\n    .long 13\n    .long 14\n    .long 15\n    .long 16\n"
        );
        let prog = assemble(&src).expect("assembles");
        let mut cpu = Cpu::with_image(&prog.image);
        cpu.run(1_000_000);
        let report = EmpaProcessor::new(&prog.image, &EmpaConfig::default()).run();
        assert_eq!(cpu.status, Status::Hlt, "case {case}");
        assert_eq!(cpu.regs.file, report.regs.file, "case {case}:\n{src}");
        assert_eq!(cpu.clock, report.clocks, "case {case}: clocks");
    }
}

#[test]
fn empa_modes_agree_with_cpu_on_random_vectors() {
    // The cross-substrate version of Table 1's correctness premise: for
    // random vectors and lengths, FOR and SUMUP compute exactly the
    // conventional CPU's sum.
    let mut rng = Rng::seed_from_u64(7);
    for _ in 0..60 {
        let n = rng.range_usize(0, 80);
        let values: Vec<i32> = (0..n).map(|_| rng.i32() % 1_000_000).collect();
        let (no_src, expected) = empa::workload::sumup::no_mode_program(&values);
        let mut cpu = Cpu::with_image(&assemble(&no_src).unwrap().image);
        cpu.run(1_000_000);
        assert_eq!(cpu.regs.file[0], expected);
        for mode in [empa::workload::sumup::Mode::For, empa::workload::sumup::Mode::Sumup] {
            let (src, _) = empa::workload::sumup::program(mode, &values);
            let r = EmpaProcessor::new(&assemble(&src).unwrap().image, &EmpaConfig::default()).run();
            assert_eq!(r.fault, None, "{mode:?} N={n}");
            assert_eq!(r.eax(), expected, "{mode:?} N={n}");
        }
    }
}

#[test]
fn timing_sweep_preserves_equivalence() {
    // Equivalence is architectural, not a timing accident: double every
    // instruction cost and both substrates still agree clock-for-clock.
    use empa::empa::TimingConfig;
    let mut t = TimingConfig::paper();
    t.irmov *= 2;
    t.alu *= 2;
    t.mrmov += 5;
    t.jump = 1;
    let mut rng = Rng::seed_from_u64(99);
    for _ in 0..40 {
        let src = random_program(&mut rng, 20);
        let prog = assemble(&src).unwrap();
        let mut cpu = Cpu::new(&prog.image, t.clone(), &empa::mem::MemConfig::ideal());
        cpu.run(1_000_000);
        let cfg = EmpaConfig { timing: t.clone(), ..Default::default() };
        let r = EmpaProcessor::new(&prog.image, &cfg).run();
        assert_eq!(cpu.regs.file, r.regs.file);
        assert_eq!(cpu.clock, r.clocks);
    }
}
