//! Determinism fingerprint: hash everything architecturally observable
//! about a run — final clock, status, registers, flags, occupancy,
//! retirement/bus/supervisor ledgers, fault, the full trace, and every
//! core's integrated busy time — into one FNV-1a 64 value, and demand
//! the value be **bit-identical** across every stepping mode and across
//! repeated runs. Scheduler economics (`events_processed`,
//! `clocks_skipped`, icache and host-parallelism counters) are
//! deliberately *excluded*: those are allowed to differ between modes;
//! nothing else is.

use empa::empa::{EmpaConfig, EmpaProcessor, StepMode};
use empa::isa::assemble;
use empa::mem::MemConfig;
use empa::workload::family::{direct_source, family_impl, synth_params, ALL_FAMILIES};
use std::fmt::Write;

const MODES: [StepMode; 5] = [
    StepMode::Lockstep,
    StepMode::EventHorizon,
    StepMode::ParallelA { threads: 1 },
    StepMode::ParallelA { threads: 2 },
    StepMode::ParallelA { threads: 4 },
];

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Run `image` under `step` with the given span-batch cap and memory
/// configuration, and fingerprint the architectural outcome. The bus
/// ledger is inside the hash (`bus={:?}`), so a ported-bus divergence —
/// a replayed charge landing out of grant order, a missed stall — flips
/// the value.
fn fingerprint_mem(image: &[u8], step: StepMode, span_batch: usize, mem: MemConfig) -> u64 {
    let cfg = EmpaConfig { step, span_batch, mem, trace: true, ..Default::default() };
    let mut p = EmpaProcessor::new(image, &cfg);
    let r = p.run_report();
    let mut s = String::new();
    let _ = write!(
        s,
        "clocks={} status={:?} regs={:?} cc={:?} occ={} cores={} retired={} bus={:?} sv={} fault={:?}",
        r.clocks,
        r.status,
        r.regs.file,
        r.regs.cc,
        r.max_occupied,
        r.distinct_cores,
        r.retired,
        r.bus,
        r.sv_ops,
        r.fault,
    );
    for e in &r.trace.entries {
        let _ = write!(s, "|{e:?}");
    }
    for c in &p.cores {
        let _ = write!(s, "|busy={}", c.busy_clocks);
    }
    fnv1a(s.as_bytes())
}

/// Run `image` under `step` with the given span-batch cap on ideal
/// memory.
fn fingerprint_batched(image: &[u8], step: StepMode, span_batch: usize) -> u64 {
    fingerprint_mem(image, step, span_batch, MemConfig::ideal())
}

/// Run `image` under `step` at the default span-batch cap.
fn fingerprint(image: &[u8], step: StepMode) -> u64 {
    fingerprint_batched(image, step, EmpaConfig::default().span_batch)
}

#[test]
fn fingerprints_are_mode_invariant_and_repeatable() {
    for family in ALL_FAMILIES {
        let fam = family_impl(family);
        for &mode in fam.modes() {
            for n in [1usize, 24] {
                let params = synth_params(family, n, 0xF1F0 ^ n as u64);
                let src = direct_source(mode, &params).unwrap();
                let image = assemble(&src).unwrap().image;
                let ctx = format!("{} {mode:?} N={n}", family.name());
                let base = fingerprint(&image, StepMode::Lockstep);
                for step in MODES {
                    assert_eq!(
                        base,
                        fingerprint(&image, step),
                        "{ctx} [{step:?}]: fingerprint drifted from lockstep"
                    );
                    assert_eq!(
                        fingerprint(&image, step),
                        fingerprint(&image, step),
                        "{ctx} [{step:?}]: fingerprint not repeatable"
                    );
                }
            }
        }
    }
}

/// Multi-clock batching must be invisible to the fingerprint: every
/// span-batch cap yields the same FNV-1a value as the lockstep run, at
/// every thread count, on every workload family.
#[test]
fn fingerprints_are_span_batch_invariant() {
    for family in ALL_FAMILIES {
        let fam = family_impl(family);
        for &mode in fam.modes() {
            let params = synth_params(family, 24, 0xBA7C);
            let src = direct_source(mode, &params).unwrap();
            let image = assemble(&src).unwrap().image;
            let ctx = format!("{} {mode:?}", family.name());
            let base = fingerprint_batched(&image, StepMode::Lockstep, 1);
            for span_batch in [1usize, 4, 64] {
                for threads in [1usize, 2, 4] {
                    let step = StepMode::ParallelA { threads };
                    assert_eq!(
                        base,
                        fingerprint_batched(&image, step, span_batch),
                        "{ctx} [t={threads} span_batch={span_batch}]: fingerprint drifted"
                    );
                }
            }
        }
    }
}

/// Batching under a ported bus (PR 9) must be just as invisible: for
/// 1- and 2-port memories, every span-batch cap × thread count yields
/// the same fingerprint as that memory's own lockstep run — including
/// the replayed `BusStats` inside the hash.
#[test]
fn fingerprints_are_ported_bus_span_batch_invariant() {
    for family in ALL_FAMILIES {
        let fam = family_impl(family);
        for &mode in fam.modes() {
            let params = synth_params(family, 24, 0x9047);
            let src = direct_source(mode, &params).unwrap();
            let image = assemble(&src).unwrap().image;
            for mem in [MemConfig::single_bus(), MemConfig::buses(2)] {
                let ctx = format!("{} {mode:?} ports={:?}", family.name(), mem.ports);
                let base = fingerprint_mem(&image, StepMode::Lockstep, 1, mem.clone());
                for span_batch in [1usize, 4, 64] {
                    for threads in [1usize, 2, 4] {
                        let step = StepMode::ParallelA { threads };
                        assert_eq!(
                            base,
                            fingerprint_mem(&image, step, span_batch, mem.clone()),
                            "{ctx} [t={threads} span_batch={span_batch}]: fingerprint drifted"
                        );
                    }
                }
            }
        }
    }
}
