//! # EMPA — the Explicitly Many-Processor Approach
//!
//! Reproduction of Végh (2016), *"A configurable accelerator for manycores:
//! the Explicitly Many-Processor Approach"*.
//!
//! The crate is organised as a three-layer system:
//!
//! - **Layer 3 (this crate)** — the paper's contribution: a cycle-stepped
//!   EMPA manycore simulator ([`empa`]) built on a Y86 toolchain substrate
//!   ([`isa`], [`emu`]), plus the *EMPA fabric* service: a typed service
//!   API ([`api`]: requests, job handles, error taxonomy) over a
//!   coordinator ([`coordinator`]) that routes work across a named
//!   registry of backends — the simulated EMPA pool (`sim`), native mass
//!   ops (`native`), and an external accelerator (`xla`) linked through
//!   the paper's §3.8 signal/data interface ([`accel`]). A network serve
//!   plane ([`serve`]) puts a TCP front door on the fabric: a hand-rolled
//!   wire protocol, per-tenant token-bucket quotas, fair-share staging,
//!   and SLO-driven load shedding.
//! - **Layer 2/1 (build-time Python)** — a JAX/Pallas mass-processing
//!   accelerator, AOT-lowered to HLO text under `artifacts/`, loaded and
//!   executed from Rust via PJRT ([`runtime`]; gated behind the
//!   `xla-runtime` feature so the crate builds without the PJRT
//!   bindings — the fabric then fails over from `xla` to `native`).
//!   Python never runs on the request path.
//!
//! See `DESIGN.md` for the full system inventory and the experiment index
//! mapping every table and figure of the paper to a module and bench.

pub mod accel;
pub mod api;
pub mod chaos;
pub mod coordinator;
pub mod emu;
pub mod empa;
pub mod isa;
pub mod kernels;
pub mod mem;
pub mod metrics;
pub mod os;
pub mod runtime;
pub mod serve;
pub mod util;
pub mod workload;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
