//! Supervisor-level state (§4.1.3).
//!
//! The SV is "a second, end-user configurable control layer ... above and
//! between the PUs". Its bookkeeping (pool, bitmasks, latch transfers) is
//! invoked synchronously from the processor tick — justified by §4.1.3:
//! the SV's "simple combinational logic can be operated at a frequency ...
//! much higher than the clock frequency needed for the cores". Only where
//! the SV's *sequential* nature matters (one core allocation per control
//! tick, §4.1.3) do we pace actions explicitly, via `sv_stagger`.
//!
//! The mass-processing engines (§5.1 FOR, §5.2 SUMUP) live here: one
//! engine per parent core, configured by the `qmassfor` / `qmasssum`
//! metainstructions.


/// Which mass-processing mode an engine implements.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MassMode {
    /// §5.1: SV takes over loop organisation; one preallocated child is
    /// re-launched per iteration, partial sum cloned back each time.
    For,
    /// §5.2: staggered one-shot children stream summands through their
    /// `ForParent` latch into the parent-side adder.
    Sum,
}

/// One active mass-processing engine.
#[derive(Debug, Clone)]
pub struct MassEngine {
    pub mode: MassMode,
    /// The stalled parent core this engine works for.
    pub parent: usize,
    /// Address of the body QT.
    pub body: u32,
    /// Address of the next vector element ("the SV calculates the address
    /// of the vector element for the next iteration", §5.1).
    pub addr: i32,
    /// Iterations not yet launched.
    pub remaining: u32,
    /// Total iterations.
    pub total: u32,
    /// SUMUP: summands received by the parent-side adder.
    pub arrived: u32,
    /// The accumulator (the "adder prepared in the parent", §5.2; the
    /// cloned-back partial sum for FOR).
    pub acc: i32,
    /// Earliest clock for the next child launch (SV sequential pacing).
    pub next_launch_at: u64,
    /// FOR: the single reused child core.
    pub child: Option<usize>,
    /// Set when all iterations completed; engine finalises (readout to the
    /// parent) once `clock >= done_at`.
    pub done_at: Option<u64>,
    /// Engine finalised; kept until the processor reaps it.
    pub finished: bool,
}

impl MassEngine {
    pub fn new(mode: MassMode, parent: usize, body: u32, addr: i32, count: u32, acc: i32, now: u64, stagger: u64) -> Self {
        MassEngine {
            mode,
            parent,
            body,
            addr,
            remaining: count,
            total: count,
            arrived: 0,
            acc,
            next_launch_at: now + stagger,
            child: None,
            done_at: if count == 0 { Some(now + stagger) } else { None },
            finished: false,
        }
    }

    /// Record a streamed summand (SUMUP arrival into the parent adder).
    /// Returns true when this was the last awaited summand.
    pub fn arrive(&mut self, value: i32) -> bool {
        self.acc = self.acc.wrapping_add(value);
        self.arrived += 1;
        self.arrived == self.total
    }
}

/// Supervisor state: the set of active mass engines.
///
/// (Pool and bitmask state lives on the cores themselves, mirroring the
/// paper's Fig. 2 where the masks are per-core storage the SV reads and
/// writes.)
#[derive(Debug, Default)]
pub struct Supervisor {
    pub engines: Vec<MassEngine>,
    /// Total SV-level operations performed (metrics: SV load, §4.1.3
    /// bottleneck analysis).
    pub ops: u64,
}

impl Supervisor {
    /// Engine driven by `parent`, if any unfinished one exists.
    pub fn engine_of_parent(&mut self, parent: usize) -> Option<&mut MassEngine> {
        self.engines.iter_mut().find(|e| e.parent == parent && !e.finished)
    }

    /// Engine whose FOR child is `core`.
    pub fn engine_of_child(&mut self, core: usize) -> Option<&mut MassEngine> {
        self.engines.iter_mut().find(|e| e.child == Some(core) && !e.finished)
    }

    /// True when `parent` still has an unfinished engine (blocks `halt`).
    pub fn parent_engine_active(&self, parent: usize) -> bool {
        self.engines.iter().any(|e| e.parent == parent && !e.finished)
    }

    /// Drop finished engines.
    pub fn reap(&mut self) {
        self.engines.retain(|e| !e.finished);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_zero_count_completes_immediately() {
        let e = MassEngine::new(MassMode::For, 0, 0x20, 0x100, 0, 0, 16, 1);
        assert_eq!(e.done_at, Some(17));
    }

    #[test]
    fn arrivals_accumulate_and_complete() {
        let mut e = MassEngine::new(MassMode::Sum, 0, 0x20, 0x100, 3, 10, 17, 1);
        assert!(!e.arrive(1));
        assert!(!e.arrive(2));
        assert!(e.arrive(3));
        assert_eq!(e.acc, 16); // initial 10 + 1+2+3
        assert_eq!(e.next_launch_at, 18);
    }

    #[test]
    fn supervisor_lookup() {
        let mut sv = Supervisor::default();
        sv.engines.push(MassEngine::new(MassMode::For, 2, 0, 0, 1, 0, 0, 1));
        sv.engines[0].child = Some(5);
        assert!(sv.engine_of_parent(2).is_some());
        assert!(sv.engine_of_parent(3).is_none());
        assert!(sv.engine_of_child(5).is_some());
        assert!(sv.parent_engine_active(2));
        sv.engines[0].finished = true;
        assert!(!sv.parent_engine_active(2));
        sv.reap();
        assert!(sv.engines.is_empty());
    }

    #[test]
    fn acc_wraps_like_hardware() {
        let mut e = MassEngine::new(MassMode::Sum, 0, 0, 0, 1, i32::MAX, 0, 1);
        e.arrive(1);
        assert_eq!(e.acc, i32::MIN);
    }
}
