//! Supervisor-level state (§4.1.3).
//!
//! The SV is "a second, end-user configurable control layer ... above and
//! between the PUs". Its bookkeeping (pool, bitmasks, latch transfers) is
//! invoked synchronously from the processor tick — justified by §4.1.3:
//! the SV's "simple combinational logic can be operated at a frequency ...
//! much higher than the clock frequency needed for the cores". Only where
//! the SV's *sequential* nature matters (one core allocation per control
//! tick, §4.1.3) do we pace actions explicitly, via `sv_stagger`.
//!
//! The mass-processing engines (§5.1 FOR, §5.2 SUMUP) live here: one
//! engine per parent core, configured by the `qmassfor` / `qmasssum`
//! metainstructions. Engines sit in a **slot arena** with per-core
//! indices (`core → engine slot` for both the parent and the FOR-child
//! role), so the per-tick lookups the processor issues on every fetch
//! and unblock — `engine_of_parent`, `engine_of_child`,
//! `parent_engine_active` — are O(1) instead of O(engines): hardware
//! would wire these as direct per-core registers, and a fabric serving
//! many concurrent mass requests must not pay a scan per core per tick.

/// Which mass-processing mode an engine implements.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MassMode {
    /// §5.1: SV takes over loop organisation; one preallocated child is
    /// re-launched per iteration, partial sum cloned back each time.
    For,
    /// §5.2: staggered one-shot children stream summands through their
    /// `ForParent` latch into the parent-side adder.
    Sum,
}

/// One active mass-processing engine.
#[derive(Debug, Clone)]
pub struct MassEngine {
    pub mode: MassMode,
    /// The stalled parent core this engine works for.
    pub parent: usize,
    /// Address of the body QT.
    pub body: u32,
    /// Address of the next vector element ("the SV calculates the address
    /// of the vector element for the next iteration", §5.1).
    pub addr: i32,
    /// Iterations not yet launched.
    pub remaining: u32,
    /// Total iterations.
    pub total: u32,
    /// SUMUP: summands received by the parent-side adder.
    pub arrived: u32,
    /// The accumulator (the "adder prepared in the parent", §5.2; the
    /// cloned-back partial sum for FOR).
    pub acc: i32,
    /// Earliest clock for the next child launch (SV sequential pacing).
    pub next_launch_at: u64,
    /// FOR: the single reused child core. Maintained through
    /// [`Supervisor::set_child`] so the per-core child index stays
    /// consistent.
    pub child: Option<usize>,
    /// Set when all iterations completed; engine finalises (readout to the
    /// parent) once `clock >= done_at`.
    pub done_at: Option<u64>,
    /// Engine finalised; kept until the supervisor reaps the slot.
    pub finished: bool,
}

impl MassEngine {
    /// Configure an engine. An empty (`count == 0`) engine finalises
    /// after the setup stagger plus, in SUMUP mode, the adder readout —
    /// the one place the empty-engine finalise cost is computed.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        mode: MassMode,
        parent: usize,
        body: u32,
        addr: i32,
        count: u32,
        acc: i32,
        now: u64,
        stagger: u64,
        readout: u64,
    ) -> Self {
        MassEngine {
            mode,
            parent,
            body,
            addr,
            remaining: count,
            total: count,
            arrived: 0,
            acc,
            next_launch_at: now + stagger,
            child: None,
            done_at: (count == 0)
                .then(|| now + stagger + match mode { MassMode::Sum => readout, MassMode::For => 0 }),
            finished: false,
        }
    }

    /// Record a streamed summand (SUMUP arrival into the parent adder).
    /// Returns true when this was the last awaited summand.
    pub fn arrive(&mut self, value: i32) -> bool {
        self.acc = self.acc.wrapping_add(value);
        self.arrived += 1;
        self.arrived == self.total
    }

    /// Earliest clock (≥ `now`) at which this engine acts **on its own**
    /// — the engine's contribution to the event-horizon scheduler: a
    /// pending finalise (`done_at`), or the next child launch, which is
    /// `next_launch_at` gated by `rent_at` — the caller-supplied earliest
    /// clock a candidate core can be rented for `parent` (`None`: no
    /// candidate exists, so only an event can unstall the launch and the
    /// engine contributes no time-driven horizon).
    pub fn earliest_due<F: Fn(usize) -> Option<u64>>(&self, now: u64, rent_at: &F) -> Option<u64> {
        if self.finished {
            return None;
        }
        if let Some(d) = self.done_at {
            return Some(d.max(now));
        }
        // SUMUP launches every remaining child; FOR launches only while
        // no child is attached (iterations relaunch combinationally at
        // the child's qterm — an apply event, not a timer).
        let launch_pending = self.remaining > 0 && (self.mode == MassMode::Sum || self.child.is_none());
        if !launch_pending {
            return None;
        }
        rent_at(self.parent).map(|r| self.next_launch_at.max(r).max(now))
    }
}

/// Supervisor state: the mass-engine slot arena plus the per-core
/// indices that make the hot-path lookups O(1).
///
/// (Pool and bitmask state lives on the cores themselves, mirroring the
/// paper's Fig. 2 where the masks are per-core storage the SV reads and
/// writes.)
#[derive(Debug, Default)]
pub struct Supervisor {
    /// Engine slot arena; `None` marks a reaped (free) slot.
    slots: Vec<Option<MassEngine>>,
    /// Free slots, reused before the arena grows.
    free: Vec<usize>,
    /// Unfinished engines (gates the processor's per-tick engine phase).
    active: usize,
    /// core id → slot of the unfinished engine it parents.
    parent_idx: Vec<Option<usize>>,
    /// core id → slot of the unfinished FOR engine it serves as child.
    child_idx: Vec<Option<usize>>,
    /// Total SV-level operations performed (metrics: SV load, §4.1.3
    /// bottleneck analysis).
    pub ops: u64,
}

impl Supervisor {
    fn ensure_core(&mut self, core: usize) {
        if core >= self.parent_idx.len() {
            self.parent_idx.resize(core + 1, None);
            self.child_idx.resize(core + 1, None);
        }
    }

    /// Register a freshly configured engine; returns its slot.
    pub fn add(&mut self, engine: MassEngine) -> usize {
        let parent = engine.parent;
        self.ensure_core(parent);
        debug_assert!(
            self.parent_idx[parent].is_none(),
            "one engine per parent (the parent stalls on qmass*)"
        );
        let slot = match self.free.pop() {
            Some(s) => {
                self.slots[s] = Some(engine);
                s
            }
            None => {
                self.slots.push(Some(engine));
                self.slots.len() - 1
            }
        };
        self.parent_idx[parent] = Some(slot);
        self.active += 1;
        slot
    }

    /// Engine in `slot`, if the slot is live.
    pub fn get(&self, slot: usize) -> Option<&MassEngine> {
        self.slots.get(slot)?.as_ref()
    }

    /// Mutable engine in `slot`. Do not flip `finished` or `child`
    /// through this — use [`Supervisor::finish`] / [`Supervisor::set_child`]
    /// so the indices stay consistent.
    pub fn get_mut(&mut self, slot: usize) -> Option<&mut MassEngine> {
        self.slots.get_mut(slot)?.as_mut()
    }

    /// Arena size (iteration bound for the processor's engine phase).
    pub fn slot_count(&self) -> usize {
        self.slots.len()
    }

    /// Whether any engine is still unfinished.
    pub fn any_active(&self) -> bool {
        self.active > 0
    }

    /// Slot of the unfinished engine driven by `parent`, if any. O(1).
    pub fn engine_of_parent(&self, parent: usize) -> Option<usize> {
        self.parent_idx.get(parent).copied().flatten()
    }

    /// Unfinished engine driven by `parent`. O(1).
    pub fn engine_of_parent_mut(&mut self, parent: usize) -> Option<&mut MassEngine> {
        let slot = self.engine_of_parent(parent)?;
        self.slots[slot].as_mut()
    }

    /// Slot of the unfinished FOR engine whose child is `core`. O(1).
    pub fn engine_of_child(&self, core: usize) -> Option<usize> {
        self.child_idx.get(core).copied().flatten()
    }

    /// Route a child's `%pp` stream into `parent`'s engine (§5.2: the
    /// SUMUP adder arrival; the last awaited summand schedules the
    /// readout). Returns true when an engine consumed the value — the
    /// caller then records the Stream trace event and charges an SV op.
    /// Outside mass mode the latch write alone suffices and nothing
    /// happens here. Shared by the serial apply path and the parallel
    /// span commit so both charge identical supervisor work.
    pub fn sum_stream(&mut self, parent: usize, value: i32, now: u64, readout: u64) -> bool {
        let Some(e) = self.engine_of_parent_mut(parent) else { return false };
        if e.mode == MassMode::Sum && e.arrive(value) {
            e.done_at = Some(now + readout);
        }
        true
    }

    /// True when `parent` still has an unfinished engine (blocks `halt`).
    /// O(1).
    pub fn parent_engine_active(&self, parent: usize) -> bool {
        self.engine_of_parent(parent).is_some()
    }

    /// How many more `%pp` arrivals `parent`'s SUMUP engine needs
    /// *including* the final one that schedules the readout
    /// (`Some(1)` = the very next stream is final). `None` when the
    /// parent drives no unfinished SUM engine — FOR engines consume
    /// streams without ever finalising, so they never bound a batched
    /// window through this. Used by the span batcher to let non-final
    /// arrivals commit in-window: they only mutate the accumulator and
    /// arrival count, which `earliest_due` never reads, so the window
    /// bounds computed at entry stay valid.
    pub fn arrivals_to_final(&self, parent: usize) -> Option<u32> {
        let e = self.slots[self.engine_of_parent(parent)?].as_ref()?;
        (e.mode == MassMode::Sum).then(|| e.total.saturating_sub(e.arrived))
    }

    /// (Re)assign the FOR engine's child core, keeping the child index
    /// consistent.
    pub fn set_child(&mut self, slot: usize, child: Option<usize>) {
        let e = self.slots[slot].as_mut().expect("live engine slot");
        if let Some(old) = e.child.take() {
            self.child_idx[old] = None;
        }
        e.child = child;
        if let Some(c) = child {
            self.ensure_core(c);
            debug_assert!(self.child_idx[c].is_none(), "a core serves one engine");
            self.child_idx[c] = Some(slot);
        }
    }

    /// Mark the engine finished and drop it from the per-core indices
    /// (its parent may halt, its child core is released). The slot is
    /// freed by the next [`Supervisor::reap`].
    pub fn finish(&mut self, slot: usize) {
        let e = self.slots[slot].as_mut().expect("live engine slot");
        if e.finished {
            return;
        }
        e.finished = true;
        self.active -= 1;
        let parent = e.parent;
        let child = e.child.take();
        self.parent_idx[parent] = None;
        if let Some(c) = child {
            self.child_idx[c] = None;
        }
    }

    /// Free the slots of finished engines.
    pub fn reap(&mut self) {
        for s in 0..self.slots.len() {
            if self.slots[s].as_ref().is_some_and(|e| e.finished) {
                self.slots[s] = None;
                self.free.push(s);
            }
        }
    }

    /// Earliest clock (≥ `now`) at which **any** unfinished engine acts
    /// on its own (launch stagger, readout, finalise) — the supervisor's
    /// contribution to the event-horizon scheduler. `rent_at(parent)` is
    /// the processor-supplied earliest clock a candidate core can be
    /// rented for `parent` (see [`MassEngine::earliest_due`]).
    pub fn earliest_due<F: Fn(usize) -> Option<u64>>(&self, now: u64, rent_at: F) -> Option<u64> {
        self.slots.iter().flatten().filter_map(|e| e.earliest_due(now, &rent_at)).min()
    }

    /// Reset for processor reuse: drop all engines and indices, zero the
    /// op counter, keep the allocations.
    pub fn reset(&mut self) {
        self.slots.clear();
        self.free.clear();
        self.active = 0;
        self.parent_idx.clear();
        self.child_idx.clear();
        self.ops = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_zero_count_completes_immediately() {
        let e = MassEngine::new(MassMode::For, 0, 0x20, 0x100, 0, 0, 16, 1, 1);
        assert_eq!(e.done_at, Some(17));
        // an empty SUMUP engine additionally pays the adder readout
        let e = MassEngine::new(MassMode::Sum, 0, 0x20, 0x100, 0, 0, 16, 1, 2);
        assert_eq!(e.done_at, Some(19));
    }

    #[test]
    fn arrivals_accumulate_and_complete() {
        let mut e = MassEngine::new(MassMode::Sum, 0, 0x20, 0x100, 3, 10, 17, 1, 1);
        assert!(!e.arrive(1));
        assert!(!e.arrive(2));
        assert!(e.arrive(3));
        assert_eq!(e.acc, 16); // initial 10 + 1+2+3
        assert_eq!(e.next_launch_at, 18);
    }

    #[test]
    fn indexed_lookup_tracks_parent_and_child() {
        let mut sv = Supervisor::default();
        let slot = sv.add(MassEngine::new(MassMode::For, 2, 0, 0, 1, 0, 0, 1, 1));
        sv.set_child(slot, Some(5));
        assert_eq!(sv.engine_of_parent(2), Some(slot));
        assert_eq!(sv.engine_of_parent(3), None);
        assert_eq!(sv.engine_of_child(5), Some(slot));
        assert_eq!(sv.engine_of_child(2), None);
        assert!(sv.parent_engine_active(2));
        assert!(sv.any_active());
        // reassigning the child clears the old index entry
        sv.set_child(slot, Some(7));
        assert_eq!(sv.engine_of_child(5), None);
        assert_eq!(sv.engine_of_child(7), Some(slot));
        // finishing clears both indices immediately; reap frees the slot
        sv.finish(slot);
        assert!(!sv.parent_engine_active(2));
        assert_eq!(sv.engine_of_child(7), None);
        assert!(!sv.any_active());
        assert!(sv.get(slot).is_some(), "slot lives until reap");
        sv.reap();
        assert!(sv.get(slot).is_none());
    }

    #[test]
    fn reaped_slots_are_reused() {
        let mut sv = Supervisor::default();
        let a = sv.add(MassEngine::new(MassMode::Sum, 0, 0, 0, 1, 0, 0, 1, 1));
        sv.finish(a);
        sv.reap();
        let b = sv.add(MassEngine::new(MassMode::Sum, 1, 0, 0, 1, 0, 0, 1, 1));
        assert_eq!(a, b, "freed slot reused before growing the arena");
        assert_eq!(sv.slot_count(), 1);
    }

    #[test]
    fn many_engines_coexist_with_independent_indices() {
        let mut sv = Supervisor::default();
        let slots: Vec<usize> = (0..16)
            .map(|p| sv.add(MassEngine::new(MassMode::Sum, p, 0, 0, 2, 0, 0, 1, 1)))
            .collect();
        for (p, &s) in slots.iter().enumerate() {
            assert_eq!(sv.engine_of_parent(p), Some(s));
        }
        sv.finish(slots[7]);
        assert_eq!(sv.engine_of_parent(7), None);
        assert_eq!(sv.engine_of_parent(8), Some(slots[8]), "neighbours unaffected");
        assert!(sv.any_active());
    }

    #[test]
    fn reset_drops_everything() {
        let mut sv = Supervisor::default();
        let s = sv.add(MassEngine::new(MassMode::For, 1, 0, 0, 1, 0, 0, 1, 1));
        sv.set_child(s, Some(2));
        sv.ops = 9;
        sv.reset();
        assert!(!sv.any_active());
        assert_eq!(sv.slot_count(), 0);
        assert_eq!(sv.engine_of_parent(1), None);
        assert_eq!(sv.engine_of_child(2), None);
        assert_eq!(sv.ops, 0);
    }

    #[test]
    fn sum_stream_feeds_the_adder_and_reports_consumption() {
        let mut sv = Supervisor::default();
        assert!(!sv.sum_stream(0, 5, 10, 2), "no engine: latch-only stream");
        sv.add(MassEngine::new(MassMode::Sum, 0, 0, 0, 2, 0, 10, 1, 2));
        assert!(sv.sum_stream(0, 5, 12, 2));
        assert_eq!(sv.engine_of_parent_mut(0).unwrap().done_at, None);
        assert!(sv.sum_stream(0, 7, 14, 2));
        let e = sv.engine_of_parent_mut(0).unwrap();
        assert_eq!(e.acc, 12);
        assert_eq!(e.done_at, Some(16), "last arrival schedules the readout");
        // a FOR engine consumes the stream event but never sums
        let mut sv = Supervisor::default();
        sv.add(MassEngine::new(MassMode::For, 1, 0, 0, 2, 0, 10, 1, 2));
        assert!(sv.sum_stream(1, 9, 12, 2));
        assert_eq!(sv.engine_of_parent_mut(1).unwrap().acc, 0);
    }

    #[test]
    fn arrivals_to_final_counts_down_sum_engines_only() {
        let mut sv = Supervisor::default();
        assert_eq!(sv.arrivals_to_final(0), None, "no engine");
        sv.add(MassEngine::new(MassMode::Sum, 0, 0, 0, 3, 0, 10, 1, 2));
        assert_eq!(sv.arrivals_to_final(0), Some(3));
        assert!(sv.sum_stream(0, 1, 12, 2));
        assert!(sv.sum_stream(0, 2, 13, 2));
        assert_eq!(sv.arrivals_to_final(0), Some(1), "next stream is final");
        assert!(sv.sum_stream(0, 3, 14, 2));
        assert_eq!(sv.arrivals_to_final(0), Some(0), "all arrived, readout pending");
        // FOR engines consume streams but never finalise through them
        sv.add(MassEngine::new(MassMode::For, 1, 0, 0, 3, 0, 10, 1, 2));
        assert_eq!(sv.arrivals_to_final(1), None);
    }

    #[test]
    fn acc_wraps_like_hardware() {
        let mut e = MassEngine::new(MassMode::Sum, 0, 0, 0, 1, i32::MAX, 0, 1, 1);
        e.arrive(1);
        assert_eq!(e.acc, i32::MIN);
    }

    #[test]
    fn earliest_due_reports_finalise_and_gated_launches() {
        // pending finalise wins outright (and is clamped to `now`)
        let mut e = MassEngine::new(MassMode::Sum, 0, 0, 0, 0, 0, 10, 1, 1);
        assert_eq!(e.done_at, Some(12));
        assert_eq!(e.earliest_due(11, &|_| Some(0)), Some(12));
        assert_eq!(e.earliest_due(20, &|_| Some(0)), Some(20), "clamped to now");
        // a launch-pending engine is gated by both the stagger and the
        // earliest rentable core
        let e = MassEngine::new(MassMode::Sum, 0, 0, 0, 3, 0, 10, 2, 1);
        assert_eq!(e.earliest_due(10, &|_| Some(0)), Some(12), "stagger gates");
        assert_eq!(e.earliest_due(10, &|_| Some(30)), Some(30), "rent gates");
        assert_eq!(e.earliest_due(10, &|_| None), None, "no candidate: event-driven");
        // a FOR engine with its child attached is driven by the child's
        // applies, never by a timer
        let mut f = MassEngine::new(MassMode::For, 1, 0, 0, 4, 0, 0, 1, 1);
        f.child = Some(3);
        assert_eq!(f.earliest_due(5, &|_| Some(0)), None);
        f.child = None;
        assert_eq!(f.earliest_due(5, &|_| Some(0)), Some(5));
        // finished engines contribute nothing
        let mut done = MassEngine::new(MassMode::Sum, 0, 0, 0, 1, 0, 0, 1, 1);
        done.finished = true;
        assert_eq!(done.earliest_due(0, &|_| Some(0)), None);
    }

    #[test]
    fn supervisor_earliest_due_is_the_min_over_live_engines() {
        let mut sv = Supervisor::default();
        assert_eq!(sv.earliest_due(0, |_| Some(0)), None, "no engines");
        let a = sv.add(MassEngine::new(MassMode::Sum, 0, 0, 0, 2, 0, 10, 5, 1)); // due 15
        let b = sv.add(MassEngine::new(MassMode::Sum, 1, 0, 0, 2, 0, 10, 2, 1)); // due 12
        assert_eq!(sv.earliest_due(10, |_| Some(0)), Some(12));
        sv.finish(b);
        assert_eq!(sv.earliest_due(10, |_| Some(0)), Some(15));
        sv.finish(a);
        assert_eq!(sv.earliest_due(10, |_| Some(0)), None);
    }
}
