//! An EMPA core (§4.1.2): "mostly similar to the present single-core
//! processor, with some extra functionality" — the extra signals towards
//! the supervisor (`Availability`, `Enabled`, `Waiting`, `Meta`), the
//! identity/parent/children/preallocated bitmasks, the QT offset, and the
//! four latch registers behind the pseudo-registers of §4.6.

use super::effects::{PendingEffects, PhaseTask};
use crate::emu::CoreRegs;
use crate::isa::Insn;
use crate::mem::MemView;

/// Allocation state as seen by the supervisor's pool (§4.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AllocState {
    /// In the pool of sharable PUs, available for renting.
    Free,
    /// Reserved for a future QT of core `parent` (§5.1 preallocation).
    PreAllocatedBy { parent: usize },
    /// Rented, running (or blocked on) a QT.
    Rented,
}

/// Why a rented core is not fetching.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BlockReason {
    /// `qwait` (or implicit wait at `qterm`/`halt`) until the children
    /// bitmask clears (§4.3). `drain_to` receives the `FromChild` latch.
    WaitChildren { drain_to: Option<crate::isa::Reg> },
    /// Parent stalled while one of the SV mass-processing engines drives
    /// its children (§5.1, §5.2: "the PC of the parent might stall at the
    /// address where mass processing begins").
    MassEngine,
    /// `halt` fetched while children are outstanding — the SV "blocks the
    /// termination of a parent QT until its children mask gets cleared".
    HaltPending,
    /// Reserved interrupt-service core parked "in power economy mode"
    /// (§3.6), waiting for its interrupt line; woken by the SV on
    /// [`raise_irq`](super::EmpaProcessor::raise_irq).
    IrqWait,
}

/// Execution state of a core.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RunState {
    /// Enabled, ready to fetch at `pc`.
    Idle,
    /// Executing `insn`; architectural effect applies at clock `apply_at`.
    Exec { insn: Insn, apply_at: u64 },
    /// Enabled but waiting on an SV condition.
    Blocked(BlockReason),
    /// `halt` retired (only meaningful for the root core).
    Halted,
    /// QT terminated; core being returned to the pool.
    Terminated,
}

/// The latch registers of §4.6 / Fig. 2. `None` = latch empty.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Latches {
    /// Written by the parent (via its `%pc` pseudo-register) before/at QT
    /// creation; read by the child via `%pc`.
    pub from_parent: Option<i32>,
    /// Written by the child via `%pp`; transferred on termination to the
    /// parent's `from_child`.
    pub for_parent: Option<i32>,
    /// Landing latch in the parent for a terminating child's data.
    pub from_child: Option<i32>,
    /// Staging latch in the parent for the next child's `from_parent`.
    pub for_child: Option<i32>,
}

/// One EMPA core.
#[derive(Debug, Clone)]
pub struct Core {
    /// Index; the paper's "one-hot bitmask" identity is `1 << id`.
    pub id: usize,
    pub alloc: AllocState,
    pub run: RunState,
    /// Architectural "glue": register file + condition codes (§3.2).
    pub regs: CoreRegs,
    pub pc: u32,
    /// Identifying bit of the parent core, if any.
    pub parent: Option<usize>,
    /// ORed bitmasks of cores running child QTs of this core.
    pub children: u64,
    /// ORed bitmasks of cores preallocated for this core.
    pub prealloc: u64,
    /// Memory address of the QT this core runs (§4.1.2 "Offset").
    pub offset: u32,
    /// Latch registers (§4.6).
    pub latch: Latches,
    /// Emergency mode (§3.3): continuations pushed when this core lends
    /// its own resources to a child QT executed inline.
    pub borrow_stack: Vec<u32>,
    /// Pool put-back administration completes at this clock; the core may
    /// not be re-rented earlier (drives the §6.2 rent-period core cap).
    pub available_at: u64,
    /// Instructions retired by this core.
    pub retired: u64,
    /// Clocks this core spent rented (occupancy accounting).
    pub busy_clocks: u64,
}

impl Core {
    pub fn new(id: usize) -> Self {
        Core {
            id,
            alloc: AllocState::Free,
            run: RunState::Idle,
            regs: CoreRegs::default(),
            pc: 0,
            parent: None,
            children: 0,
            prealloc: 0,
            offset: 0,
            latch: Latches::default(),
            borrow_stack: Vec::new(),
            available_at: 0,
            retired: 0,
            busy_clocks: 0,
        }
    }

    /// The paper's one-hot identity mask.
    pub fn mask(&self) -> u64 {
        1u64 << self.id
    }

    /// `Availability` signal: in the pool, not preallocated, administration
    /// finished (§4.1.2).
    pub fn available(&self, now: u64) -> bool {
        self.alloc == AllocState::Free && self.available_at <= now
    }

    /// Reset the QT-execution state when (re)rented; the glue is cloned in
    /// by the SV separately.
    pub fn reset_for_qt(&mut self, pc: u32) {
        self.run = RunState::Idle;
        self.pc = pc;
        self.offset = pc;
        self.children = 0;
        self.latch = Latches::default();
        self.borrow_stack.clear();
    }

    /// Whether this core is occupying a PU right now (rented or reserved)
    /// — the quantity `k` of Table 1 counts the maximum of these.
    pub fn occupied(&self) -> bool {
        !matches!(self.alloc, AllocState::Free)
    }

    /// The earliest clock at which this core, on its own, needs a
    /// scheduler step — its contribution to the event-horizon scheduler:
    /// `Some(now)` when ready to fetch or (per `block_clear`, computed by
    /// the processor since it needs supervisor state) to unblock,
    /// `Some(apply_at)` for a pending retirement, and `None` when only an
    /// external event can wake it (blocked on children, a mass engine, or
    /// the interrupt line).
    pub fn wake_at(&self, now: u64, block_clear: bool) -> Option<u64> {
        match self.run {
            RunState::Idle => Some(now),
            RunState::Exec { apply_at, .. } => Some(apply_at.max(now)),
            RunState::Blocked(BlockReason::WaitChildren { .. } | BlockReason::HaltPending)
                if block_clear =>
            {
                Some(now)
            }
            RunState::Blocked(_) | RunState::Halted | RunState::Terminated => None,
        }
    }

    /// Snapshot the inputs of this core's pending phase-A apply. The
    /// core must be in [`RunState::Exec`].
    pub(crate) fn phase_task(&self) -> PhaseTask {
        let RunState::Exec { insn, .. } = self.run else {
            unreachable!("phase_task on a non-executing core")
        };
        PhaseTask { id: self.id, insn, pc: self.pc, regs: self.regs.clone(), latch: self.latch }
    }

    /// Pure phase-A step: `&Core, &MemView -> PendingEffects`. Nothing
    /// shared is touched — every cross-core consequence of the retiring
    /// instruction (the data store, the `%pp` stream, the fault) comes
    /// back as an ordered effect record for the processor's serial
    /// commit. This is the function the parallel stepping mode fans out
    /// over host threads; it is also how a conflicted speculation is
    /// re-executed in place against the live bytes.
    pub(crate) fn step_phase_a(&self, view: &MemView<'_>) -> PendingEffects {
        self.phase_task().run(view)
    }

    /// Return the core to its just-constructed state, reusing the
    /// allocation (processor reuse across program runs): back in the
    /// pool, no parent/children/prealloc, zeroed glue and counters.
    pub fn reset_full(&mut self) {
        self.alloc = AllocState::Free;
        self.regs = CoreRegs::default();
        self.parent = None;
        self.prealloc = 0;
        self.available_at = 0;
        self.retired = 0;
        self.busy_clocks = 0;
        self.reset_for_qt(0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn one_hot_masks() {
        assert_eq!(Core::new(0).mask(), 1);
        assert_eq!(Core::new(5).mask(), 32);
    }

    #[test]
    fn availability_honours_putback_admin() {
        let mut c = Core::new(1);
        assert!(c.available(0));
        c.available_at = 10;
        assert!(!c.available(9));
        assert!(c.available(10));
        c.alloc = AllocState::PreAllocatedBy { parent: 0 };
        assert!(!c.available(10));
        assert!(c.occupied());
    }

    #[test]
    fn reset_full_returns_the_core_to_pool_state() {
        let mut c = Core::new(3);
        c.alloc = AllocState::Rented;
        c.run = RunState::Halted;
        c.regs.file[0] = 42;
        c.parent = Some(1);
        c.prealloc = 0b10;
        c.available_at = 99;
        c.retired = 7;
        c.busy_clocks = 11;
        c.reset_full();
        assert_eq!(c.alloc, AllocState::Free);
        assert_eq!(c.run, RunState::Idle);
        assert_eq!(c.regs, CoreRegs::default());
        assert_eq!((c.parent, c.prealloc, c.available_at), (None, 0, 0));
        assert_eq!((c.retired, c.busy_clocks), (0, 0));
        assert!(c.available(0));
    }

    #[test]
    fn step_phase_a_is_pure_over_the_shard() {
        use crate::isa::Reg;
        let mut mem = crate::mem::Memory::new(64);
        mem.write_u32(0x20, 9).unwrap();
        let mut c = Core::new(4);
        c.regs.file[Reg::Ecx as usize] = 0x20;
        c.pc = 0x8;
        c.run = RunState::Exec {
            insn: Insn::MrMov { ra: Reg::Eax, rb: Reg::Ecx, disp: 0 },
            apply_at: 11,
        };
        let before = c.clone();
        let eff = c.step_phase_a(&mem.view());
        assert_eq!(eff.id, 4);
        assert_eq!(eff.read, Some(0x20));
        assert_eq!(eff.regs.file[Reg::Eax as usize], 9);
        // purity: neither the core nor the memory moved
        assert_eq!(c.regs, before.regs);
        assert_eq!(c.run, before.run);
        assert_eq!(mem.read_u32(0x20).unwrap(), 9);
    }

    #[test]
    fn reset_clears_qt_state() {
        let mut c = Core::new(2);
        c.children = 0b111;
        c.latch.for_parent = Some(9);
        c.borrow_stack.push(0x40);
        c.reset_for_qt(0x20);
        assert_eq!(c.pc, 0x20);
        assert_eq!(c.offset, 0x20);
        assert_eq!(c.children, 0);
        assert_eq!(c.latch, Latches::default());
        assert!(c.borrow_stack.is_empty());
        assert_eq!(c.run, RunState::Idle);
    }
}
