//! The EMPA processor (§3–§5 of the paper): cores with outsourcing
//! ability, the supervisor control layer, quasi-threads, mass-processing
//! engines, pseudo-registers and the calibrated timing model.

pub mod core;
mod effects;
pub mod gantt;
#[cfg(test)]
mod irq_tests;
mod pool;
pub mod processor;
pub mod sv;
pub mod timing;
pub mod trace;

pub use core::{AllocState, BlockReason, Core, Latches, RunState};
pub use processor::{ConfigError, EmpaConfig, EmpaProcessor, RunReport, StepMode};
pub use sv::{MassEngine, MassMode, Supervisor};
pub use timing::TimingConfig;
pub use trace::{Event, Trace, TraceEntry};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::assemble;
    use crate::workload::sumup;

    fn run(src: &str) -> RunReport {
        let p = assemble(src).unwrap();
        let cfg = EmpaConfig::default();
        EmpaProcessor::new(&p.image, &cfg).run()
    }

    #[test]
    fn no_mode_matches_conventional_timing() {
        // Listing 1 (N=4) on the EMPA processor with no metainstructions
        // behaves exactly like the conventional machine: 142 clocks, k=1.
        let r = run(crate::isa::asm::LISTING1);
        assert_eq!(r.status, crate::isa::Status::Hlt);
        assert_eq!(r.eax(), 0xd + 0xc0 + 0xb00 + 0xa000);
        assert_eq!(r.clocks, 142);
        assert_eq!(r.max_occupied, 1);
        assert_eq!(r.distinct_cores, 1);
    }

    #[test]
    fn for_mode_n4_is_64_clocks_2_cores() {
        let (src, expected) = sumup::for_mode_program(&[0xd, 0xc0, 0xb00, 0xa000]);
        let r = run(&src);
        assert_eq!(r.fault, None);
        assert_eq!(r.eax(), expected);
        assert_eq!(r.clocks, 64); // Table 1, N=4 FOR
        assert_eq!(r.max_occupied, 2);
    }

    #[test]
    fn sumup_mode_n4_is_36_clocks_5_cores() {
        let (src, expected) = sumup::sumup_mode_program(&[0xd, 0xc0, 0xb00, 0xa000]);
        let r = run(&src);
        assert_eq!(r.fault, None);
        assert_eq!(r.eax(), expected);
        assert_eq!(r.clocks, 36); // Table 1, N=4 SUMUP
        assert_eq!(r.max_occupied, 5);
    }

    #[test]
    fn qcreate_qwait_roundtrip() {
        // Parent creates an embedded QT that doubles %eax; waits for it.
        let src = "\
    irmovl $21, %eax
    qcreate Cont
    addl %eax, %eax    # child body (embedded in the flow)
    qterm %eax
Cont:
    qwait %eax
    halt
";
        let r = run(src);
        assert_eq!(r.fault, None);
        assert_eq!(r.eax(), 42);
        assert_eq!(r.max_occupied, 2);
    }

    #[test]
    fn qcall_subroutine_style() {
        let src = "\
    irmovl $5, %eax
    qcall Triple
    qwait %eax
    halt
Triple:
    irmovl $3, %ebx
    irmovl $0, %ecx
Loop:
    addl %eax, %ecx
    irmovl $-1, %esi
    addl %esi, %ebx
    jne Loop
    qterm %ecx
";
        let r = run(src);
        assert_eq!(r.fault, None);
        assert_eq!(r.eax(), 15);
    }

    #[test]
    fn nested_qts_form_a_graph() {
        // parent -> child -> grandchild, each adds 1 to the inherited %eax.
        let src = "\
    irmovl $1, %eax
    qcall Child
    qwait %eax
    halt
Child:
    irmovl $1, %ebx
    addl %ebx, %eax
    qcall GrandChild
    qwait %eax
    qterm %eax
GrandChild:
    irmovl $1, %ebx
    addl %ebx, %eax
    qterm %eax
";
        let r = run(src);
        assert_eq!(r.fault, None);
        assert_eq!(r.eax(), 3);
        assert_eq!(r.max_occupied, 3);
    }

    #[test]
    fn emergency_borrowing_when_pool_exhausted() {
        // Single-core processor: qcreate must fall back to inline
        // execution (§3.3) and still compute the right value.
        let src = "\
    irmovl $21, %eax
    qcreate Cont
    addl %eax, %eax
    qterm %eax
Cont:
    qwait %eax
    halt
";
        let p = assemble(src).unwrap();
        let cfg = EmpaConfig { num_cores: 1, ..Default::default() };
        let r = EmpaProcessor::new(&p.image, &cfg).run();
        assert_eq!(r.fault, None);
        assert_eq!(r.eax(), 42);
        assert_eq!(r.max_occupied, 1);
        assert_eq!(r.trace.entries.len(), 0); // trace disabled by default
    }

    #[test]
    fn pseudo_register_handoff_parent_to_child() {
        // Parent stages a value in ForChild via %pc; child reads it via %pc.
        let src = "\
    irmovl $99, %pc     # stage ForChild
    qcall Child
    qwait %eax
    halt
Child:
    rrmovl %pc, %eax    # read FromParent latch
    qterm %eax
";
        let r = run(src);
        assert_eq!(r.fault, None);
        assert_eq!(r.eax(), 99);
    }

    #[test]
    fn qcopy_forwards_through_a_qt_pipeline() {
        // §4.6 forwarding: a middle QT copies its input latch to its
        // output latch ("to forward data ... the core needs to use an
        // explicit copying from the input pseudoregister to the output
        // pseudoregister instruction"). parent → mid → leaf and back.
        let src = "\
    irmovl $7, %pc      # stage ForChild for the mid QT
    qcall Mid
    qwait %eax
    halt
Mid:
    qcopy               # FromParent -> ForParent staging
    rrmovl %pc, %ecx    # also read it architecturally
    qcall Leaf
    qwait %ebx          # collect leaf result
    addl %ecx, %ebx     # 7 (forwarded) + 70 (leaf)
    qterm %ebx
Leaf:
    irmovl $70, %esi
    qterm %esi
";
        let r = run(src);
        assert_eq!(r.fault, None);
        assert_eq!(r.eax(), 77);
    }

    #[test]
    fn prealloc_more_than_pool_is_not_fatal() {
        let src = "\
    irmovl $3, %edx
    irmovl $0x300, %ecx
    xorl %eax, %eax
    qprealloc $500      # far more than exists
    qmassfor Body
    halt
Body:
    mrmovl (%ecx), %esi
    addl %esi, %eax
    qterm %eax
";
        let p = assemble(src).unwrap();
        let cfg = EmpaConfig { num_cores: 4, ..Default::default() };
        let mut proc = EmpaProcessor::new(&p.image, &cfg);
        proc.mem.write_words(0x300, &[10, 20, 30]).unwrap();
        let r = proc.run();
        assert_eq!(r.fault, None);
        assert_eq!(r.eax(), 60);
        assert!(r.max_occupied <= 4);
    }

    #[test]
    fn child_halt_is_a_fault() {
        let src = "\
    qcall Child
    qwait
    halt
Child:
    halt
";
        let r = run(src);
        assert!(r.fault.is_some());
    }

    #[test]
    fn runaway_guard() {
        let p = assemble("Loop: jmp Loop\n").unwrap();
        let cfg = EmpaConfig { max_clocks: 500, ..Default::default() };
        let r = EmpaProcessor::new(&p.image, &cfg).run();
        assert!(r.fault.unwrap().contains("runaway"));
    }

    #[test]
    fn trace_records_mass_lifecycle() {
        let (src, _) = sumup::sumup_mode_program(&[1, 2, 3]);
        let p = assemble(&src).unwrap();
        let cfg = EmpaConfig { trace: true, ..Default::default() };
        let r = EmpaProcessor::new(&p.image, &cfg).run();
        assert_eq!(r.trace.count(|e| matches!(e, Event::Launch { .. })), 3);
        assert_eq!(r.trace.count(|e| matches!(e, Event::Stream { .. })), 3);
        assert_eq!(r.trace.count(|e| matches!(e, Event::MassDone { .. })), 1);
    }
}
