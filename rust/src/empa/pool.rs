//! Persistent phase-A worker pool.
//!
//! One pool serves one [`super::processor::EmpaProcessor`] for its whole
//! life (it survives `reset_with`/`reset_reusing`). `threads` counts the
//! *total* participants including the stepping thread itself:
//! `ParallelA { threads: 4 }` spawns 3 workers and the stepping thread
//! computes the first chunk of every span in place. Workers park on a
//! condvar between spans; a span hands them owned tasks plus a shared
//! read-only byte slice of the pre-phase memory, and the `run_*` entry
//! points block until every chunk is back — so the effect records always
//! come home before the serial commit starts.
//!
//! Two kinds of span ride the same epoch protocol:
//! - [`PhasePool::run_span`] — one clock of same-clock phase-A applies
//!   ([`PhaseTask`] → [`PendingEffects`]);
//! - [`PhasePool::run_batch`] — multi-clock apply→fetch chains
//!   ([`ChainTask`] → [`ChainResult`]) for span batching.
//!
//! Chunking is *cost-weighted*, not even: cores about to stream a SUMUP
//! partial (`%pp` write) or touch memory (staged store / load) carry
//! weight 2, plain ALU/control flow weight 1, and the contiguous chunk
//! boundaries balance the weight prefix sums. The boundaries are
//! computed once on the stepping thread and published with the span, so
//! every participant sees the same deterministic partition and results
//! still come home in task (= core-index = commit) order.

use super::effects::{ChainResult, ChainTask, PendingEffects, PhaseTask};
use super::timing::TimingConfig;
use crate::isa::{Insn, Reg};
use crate::mem::{MemView, Memory};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;

/// The pre-phase memory bytes, smuggled across the thread boundary as a
/// raw slice.
///
/// SAFETY invariant: set under the state lock by the `run_*` entry
/// points, which do not return until `outstanding == 0` — the `&Memory`
/// borrow it was taken from therefore outlives every worker dereference,
/// and the bytes are never written while a span is in flight (speculated
/// stores are staged in the effect records; the commit runs only after
/// the join). Workers never touch the slice outside a span.
#[derive(Clone, Copy)]
struct SpanBytes {
    ptr: *const u8,
    len: usize,
}

unsafe impl Send for SpanBytes {}

impl SpanBytes {
    fn empty() -> Self {
        SpanBytes { ptr: std::ptr::NonNull::<u8>::dangling().as_ptr(), len: 0 }
    }
}

/// What the published span asks the workers to compute.
#[derive(Clone, Copy, PartialEq, Eq)]
enum WorkKind {
    /// One clock of phase-A applies (`tasks` → `results`).
    Span,
    /// Multi-clock apply→fetch chains (`chain_tasks` → `chain_results`).
    Batch,
}

struct State {
    /// Monotonic span counter: a worker computes its chunk of span
    /// `epoch` exactly once (guards against spurious condvar wakeups).
    epoch: u64,
    shutdown: bool,
    bytes: SpanBytes,
    kind: WorkKind,
    /// Cost-weighted contiguous chunk `[lo, hi)` per participant slot,
    /// computed once by the publisher.
    bounds: Vec<(usize, usize)>,
    tasks: Vec<PhaseTask>,
    results: Vec<Option<PendingEffects>>,
    chain_tasks: Vec<ChainTask>,
    chain_results: Vec<Option<ChainResult>>,
    /// Batch window end (exclusive) and instruction timing for the
    /// chained fetches; `timing` is only `Some` while a batch is live.
    chain_end: u64,
    timing: Option<TimingConfig>,
    /// Workers still computing the current span.
    outstanding: usize,
}

struct Shared {
    state: Mutex<State>,
    /// Signalled when a new span (or shutdown) is published.
    work: Condvar,
    /// Signalled when the last worker finishes its chunk.
    done: Condvar,
}

impl Shared {
    /// A worker panic poisons the lock with the pool mid-span; the
    /// stepping thread would deadlock waiting for `outstanding` anyway,
    /// so recovering the guard (for shutdown paths) is strictly better
    /// than a second panic.
    fn lock(&self) -> MutexGuard<'_, State> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }
}

/// Persistent scoped worker pool for parallel phase-A speculation.
pub(crate) struct PhasePool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    /// Total participants, including the stepping thread.
    threads: usize,
}

/// Relative cost of speculating one pending instruction: memory traffic
/// and SUMUP streaming (`%pp` writes) dominate a span's critical path,
/// plain register ops are cheap. The absolute values only matter
/// relative to each other.
fn task_weight(insn: &Insn) -> u64 {
    match insn {
        Insn::MrMov { .. } | Insn::RmMov { .. } => 2,
        Insn::Op { rb: Reg::PseudoP, .. } => 2,
        _ => 1,
    }
}

/// Contiguous weight-balanced partition of `weights` into `parts`
/// chunks: chunk `k` ends where the cumulative weight first reaches
/// `total * (k+1) / parts`. Deterministic, covers exactly `[0, n)`,
/// and reduces to the even split when all weights are equal.
fn weighted_bounds(weights: &[u64], parts: usize) -> Vec<(usize, usize)> {
    let total: u64 = weights.iter().sum();
    let mut bounds = Vec::with_capacity(parts);
    let mut acc = 0u64;
    let mut i = 0usize;
    for slot in 0..parts {
        let lo = i;
        let target = total * (slot as u64 + 1) / parts as u64;
        while i < weights.len() && acc < target {
            acc += weights[i];
            i += 1;
        }
        if slot + 1 == parts {
            // Zero-weight tails (there are none today, but the partition
            // must stay total) land on the last chunk.
            i = weights.len();
        }
        bounds.push((lo, i));
    }
    bounds
}

impl PhasePool {
    /// Build a pool with `threads` total participants (>= 2; a serial
    /// mode needs no pool at all).
    pub fn new(threads: usize) -> Self {
        debug_assert!(threads >= 2, "threads=1 is the serial path, no pool");
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                epoch: 0,
                shutdown: false,
                bytes: SpanBytes::empty(),
                kind: WorkKind::Span,
                bounds: Vec::new(),
                tasks: Vec::new(),
                results: Vec::new(),
                chain_tasks: Vec::new(),
                chain_results: Vec::new(),
                chain_end: 0,
                timing: None,
                outstanding: 0,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let handles = (1..threads)
            .map(|slot| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("empa-phase-a-{slot}"))
                    .spawn(move || worker_loop(shared, slot))
                    .expect("spawn phase-A worker")
            })
            .collect();
        PhasePool { shared, handles, threads }
    }

    /// Total participants, including the stepping thread.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Speculate one span: fan `tasks` out over the participants against
    /// the pre-phase `mem` bytes, block until every chunk is computed,
    /// and return the effect records in task order (= core-index order,
    /// the commit order).
    pub fn run_span(&self, mem: &Memory, tasks: Vec<PhaseTask>) -> Vec<PendingEffects> {
        let n = tasks.len();
        let weights: Vec<u64> = tasks.iter().map(|t| task_weight(&t.insn)).collect();
        let bounds = weighted_bounds(&weights, self.threads);
        let (lo0, hi0) = bounds[0];
        // The stepping thread's own chunk, cloned before publication so
        // it can compute outside the lock alongside the workers.
        let mine: Vec<PhaseTask> = tasks[lo0..hi0].to_vec();
        {
            let mut st = self.shared.lock();
            debug_assert_eq!(st.outstanding, 0, "spans never overlap");
            let raw = mem.raw_bytes();
            st.bytes = SpanBytes { ptr: raw.as_ptr(), len: raw.len() };
            st.kind = WorkKind::Span;
            st.bounds = bounds;
            st.tasks = tasks;
            st.results.clear();
            st.results.resize_with(n, || None);
            st.outstanding = self.handles.len();
            st.epoch += 1;
            self.shared.work.notify_all();
        }
        let view = mem.view();
        let computed: Vec<PendingEffects> = mine.iter().map(|t| t.run(&view)).collect();
        let mut st = self.shared.lock();
        for (k, eff) in computed.into_iter().enumerate() {
            st.results[lo0 + k] = Some(eff);
        }
        while st.outstanding > 0 {
            st = self.shared.done.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        // Drop the borrow markers before the `&Memory` borrow ends.
        st.tasks.clear();
        st.bytes = SpanBytes::empty();
        st.results.drain(..).map(|r| r.expect("every chunk computed")).collect()
    }

    /// Speculate one multi-clock batch: each chain steps its core
    /// through consecutive clocks `< end` against the pre-window `mem`
    /// bytes (see [`ChainTask::run`]). Blocks until every chain is back;
    /// results return in task order.
    pub fn run_batch(
        &self,
        mem: &Memory,
        timing: &TimingConfig,
        tasks: Vec<ChainTask>,
        end: u64,
    ) -> Vec<ChainResult> {
        let n = tasks.len();
        let weights: Vec<u64> = tasks.iter().map(|t| task_weight(&t.insn)).collect();
        let bounds = weighted_bounds(&weights, self.threads);
        let (lo0, hi0) = bounds[0];
        let mine: Vec<ChainTask> = tasks[lo0..hi0].to_vec();
        {
            let mut st = self.shared.lock();
            debug_assert_eq!(st.outstanding, 0, "spans never overlap");
            let raw = mem.raw_bytes();
            st.bytes = SpanBytes { ptr: raw.as_ptr(), len: raw.len() };
            st.kind = WorkKind::Batch;
            st.bounds = bounds;
            st.chain_tasks = tasks;
            st.chain_results.clear();
            st.chain_results.resize_with(n, || None);
            st.chain_end = end;
            st.timing = Some(timing.clone());
            st.outstanding = self.handles.len();
            st.epoch += 1;
            self.shared.work.notify_all();
        }
        let view = mem.view();
        let computed: Vec<ChainResult> = mine.iter().map(|t| t.run(&view, timing, end)).collect();
        let mut st = self.shared.lock();
        for (k, r) in computed.into_iter().enumerate() {
            st.chain_results[lo0 + k] = Some(r);
        }
        while st.outstanding > 0 {
            st = self.shared.done.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        st.chain_tasks.clear();
        st.timing = None;
        st.bytes = SpanBytes::empty();
        st.chain_results.drain(..).map(|r| r.expect("every chain computed")).collect()
    }
}

impl Drop for PhasePool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.lock();
            st.shutdown = true;
            self.shared.work.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: Arc<Shared>, slot: usize) {
    let mut seen = 0u64;
    loop {
        enum Work {
            Span(Vec<PhaseTask>),
            Batch(Vec<ChainTask>, TimingConfig, u64),
        }
        let (bytes, work, base) = {
            let mut st = shared.lock();
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    break;
                }
                st = shared.work.wait(st).unwrap_or_else(|e| e.into_inner());
            }
            seen = st.epoch;
            let (lo, hi) = st.bounds[slot];
            let work = match st.kind {
                WorkKind::Span => Work::Span(st.tasks[lo..hi].to_vec()),
                WorkKind::Batch => Work::Batch(
                    st.chain_tasks[lo..hi].to_vec(),
                    st.timing.clone().expect("batch publishes timing"),
                    st.chain_end,
                ),
            };
            (st.bytes, work, lo)
        };
        // SAFETY: see `SpanBytes` — the publishing `run_*` call keeps the
        // backing memory alive and unwritten until this worker decrements
        // `outstanding`.
        let slice: &[u8] = unsafe { std::slice::from_raw_parts(bytes.ptr, bytes.len) };
        let view = MemView::new(slice);
        match work {
            Work::Span(mine) => {
                let computed: Vec<PendingEffects> = mine.iter().map(|t| t.run(&view)).collect();
                let mut st = shared.lock();
                for (k, eff) in computed.into_iter().enumerate() {
                    st.results[base + k] = Some(eff);
                }
                st.outstanding -= 1;
                if st.outstanding == 0 {
                    shared.done.notify_all();
                }
            }
            Work::Batch(mine, timing, end) => {
                let computed: Vec<ChainResult> =
                    mine.iter().map(|t| t.run(&view, &timing, end)).collect();
                let mut st = shared.lock();
                for (k, r) in computed.into_iter().enumerate() {
                    st.chain_results[base + k] = Some(r);
                }
                st.outstanding -= 1;
                if st.outstanding == 0 {
                    shared.done.notify_all();
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::emu::CoreRegs;
    use crate::empa::core::Latches;
    use crate::isa::{Insn, OpFn, Reg};

    fn load_task(id: usize, addr: i32) -> PhaseTask {
        let mut regs = CoreRegs::default();
        regs.file[Reg::Ecx as usize] = addr;
        PhaseTask {
            id,
            insn: Insn::MrMov { ra: Reg::Eax, rb: Reg::Ecx, disp: 0 },
            pc: 0,
            regs,
            latch: Latches::default(),
        }
    }

    #[test]
    fn weighted_bounds_partition_without_gaps() {
        // Uniform weights: behaves like the old even split.
        for n in 0..40usize {
            for parts in 1..6 {
                let weights = vec![1u64; n];
                let bounds = weighted_bounds(&weights, parts);
                assert_eq!(bounds.len(), parts);
                let mut next = 0;
                for (slot, &(lo, hi)) in bounds.iter().enumerate() {
                    assert_eq!(lo, next, "n={n} parts={parts} slot={slot}");
                    assert!(hi >= lo);
                    next = hi;
                }
                assert_eq!(next, n, "chunks cover exactly [0, n)");
            }
        }
        // Mixed weights: the partition still covers [0, n) and no chunk
        // exceeds its fair share of total weight by more than one task.
        let weights = [2u64, 1, 1, 2, 2, 1, 2, 2, 1, 1, 2, 2];
        let total: u64 = weights.iter().sum();
        for parts in 1..6 {
            let bounds = weighted_bounds(&weights, parts);
            let mut next = 0;
            for &(lo, hi) in &bounds {
                assert_eq!(lo, next);
                let w: u64 = weights[lo..hi].iter().sum();
                assert!(w <= total.div_ceil(parts as u64) + 2, "chunk weight {w} balanced");
                next = hi;
            }
            assert_eq!(next, weights.len());
        }
    }

    #[test]
    fn heavy_tasks_shrink_their_chunk() {
        // 4 heavy stores then 8 cheap ALU ops, 2 participants: the
        // boundary must land before the even-split midpoint 6.
        let mut weights = vec![2u64; 4];
        weights.extend([1u64; 8]);
        let bounds = weighted_bounds(&weights, 2);
        assert!(bounds[0].1 < 6, "store-heavy prefix got a shorter chunk: {bounds:?}");
        assert_eq!(bounds[1].1, 12);
    }

    #[test]
    fn task_weights_follow_the_instruction_class() {
        assert_eq!(task_weight(&Insn::MrMov { ra: Reg::Eax, rb: Reg::Ecx, disp: 0 }), 2);
        assert_eq!(task_weight(&Insn::RmMov { ra: Reg::Eax, rb: Reg::Ecx, disp: 0 }), 2);
        assert_eq!(task_weight(&Insn::Op { op: OpFn::Add, ra: Reg::Eax, rb: Reg::PseudoP }), 2);
        assert_eq!(task_weight(&Insn::Op { op: OpFn::Add, ra: Reg::Eax, rb: Reg::Ebx }), 1);
        assert_eq!(task_weight(&Insn::Nop), 1);
    }

    #[test]
    fn spans_come_back_in_task_order_across_reuse() {
        let mut mem = Memory::new(256);
        for i in 0..32 {
            mem.write_u32(4 * i, 100 + i).unwrap();
        }
        let pool = PhasePool::new(3);
        assert_eq!(pool.threads(), 3);
        for _round in 0..50 {
            let tasks: Vec<PhaseTask> = (0..32).map(|i| load_task(i, 4 * i as i32)).collect();
            let effs = pool.run_span(&mem, tasks);
            assert_eq!(effs.len(), 32);
            for (i, e) in effs.iter().enumerate() {
                assert_eq!(e.id, i, "records come back in submission order");
                assert_eq!(e.regs.file[Reg::Eax as usize], 100 + i as u32 as i32);
                assert_eq!(e.read, Some(4 * i as u32));
            }
        }
    }

    #[test]
    fn tiny_and_empty_spans_are_fine() {
        let mem = Memory::new(64);
        let pool = PhasePool::new(4);
        assert_eq!(pool.run_span(&mem, Vec::new()).len(), 0);
        let effs = pool.run_span(&mem, vec![load_task(7, 8)]);
        assert_eq!(effs.len(), 1);
        assert_eq!(effs[0].id, 7);
    }

    #[test]
    fn batches_chain_applies_and_fetches_in_task_order() {
        // Straight-line code at pc 0: a run of conventional ALU ops each
        // core walks privately against the shared read-only bytes.
        let op = Insn::Op { op: OpFn::Add, ra: Reg::Eax, rb: Reg::Ebx };
        let mut img = Vec::new();
        for _ in 0..8 {
            op.encode(&mut img);
        }
        let mem = Memory::with_image(256, &img);
        let timing = TimingConfig::paper();
        let cost = timing.insn_cost(&op);
        let pool = PhasePool::new(2);
        let tasks: Vec<ChainTask> = (0..3)
            .map(|id| {
                let mut regs = CoreRegs::default();
                regs.file[Reg::Eax as usize] = 1;
                ChainTask {
                    id,
                    insn: op,
                    apply_at: 10,
                    pc: 0,
                    regs,
                    latch: Latches::default(),
                }
            })
            .collect();
        let rs = pool.run_batch(&mem, &timing, tasks, 10 + 2 * cost);
        assert_eq!(rs.len(), 3);
        for (i, r) in rs.iter().enumerate() {
            assert_eq!(r.id, i);
            assert_eq!(r.steps.len(), 2, "two applies fit the window");
            assert_eq!(r.steps[0].t, 10);
            assert_eq!(r.steps[1].t, 10 + cost);
            assert_eq!(r.stop_at, None);
        }
    }

    #[test]
    fn drop_joins_the_workers() {
        let pool = PhasePool::new(2);
        let mem = Memory::new(16);
        let _ = pool.run_span(&mem, vec![load_task(0, 0)]);
        drop(pool); // must not hang
    }
}
