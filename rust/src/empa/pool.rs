//! Persistent phase-A worker pool.
//!
//! One pool serves one [`super::processor::EmpaProcessor`] for its whole
//! life (it survives `reset_with`/`reset_reusing`). `threads` counts the
//! *total* participants including the stepping thread itself:
//! `ParallelA { threads: 4 }` spawns 3 workers and the stepping thread
//! computes the first chunk of every span in place. Workers park on a
//! condvar between spans; a span hands them owned [`PhaseTask`]s plus a
//! shared read-only byte slice of the pre-phase memory, and
//! [`PhasePool::run_span`] blocks until every chunk is back — so the
//! effect records always come home before the serial commit starts.

use super::effects::{PendingEffects, PhaseTask};
use crate::mem::{MemView, Memory};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;

/// The pre-phase memory bytes, smuggled across the thread boundary as a
/// raw slice.
///
/// SAFETY invariant: set under the state lock by [`PhasePool::run_span`],
/// which does not return until `outstanding == 0` — the `&Memory` borrow
/// it was taken from therefore outlives every worker dereference, and
/// the bytes are never written while a span is in flight (speculated
/// stores are staged in the effect records; the commit runs only after
/// the join). Workers never touch the slice outside a span.
#[derive(Clone, Copy)]
struct SpanBytes {
    ptr: *const u8,
    len: usize,
}

unsafe impl Send for SpanBytes {}

impl SpanBytes {
    fn empty() -> Self {
        SpanBytes { ptr: std::ptr::NonNull::<u8>::dangling().as_ptr(), len: 0 }
    }
}

struct State {
    /// Monotonic span counter: a worker computes its chunk of span
    /// `epoch` exactly once (guards against spurious condvar wakeups).
    epoch: u64,
    shutdown: bool,
    bytes: SpanBytes,
    tasks: Vec<PhaseTask>,
    results: Vec<Option<PendingEffects>>,
    /// Workers still computing the current span.
    outstanding: usize,
}

struct Shared {
    state: Mutex<State>,
    /// Signalled when a new span (or shutdown) is published.
    work: Condvar,
    /// Signalled when the last worker finishes its chunk.
    done: Condvar,
}

impl Shared {
    /// A worker panic poisons the lock with the pool mid-span; the
    /// stepping thread would deadlock waiting for `outstanding` anyway,
    /// so recovering the guard (for shutdown paths) is strictly better
    /// than a second panic.
    fn lock(&self) -> MutexGuard<'_, State> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }
}

/// Persistent scoped worker pool for parallel phase-A speculation.
pub(crate) struct PhasePool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    /// Total participants, including the stepping thread.
    threads: usize,
}

/// Contiguous chunk `[lo, hi)` of `n` items for participant `slot` of
/// `parts` (slot 0 is the stepping thread). Sizes differ by at most one.
fn chunk(n: usize, parts: usize, slot: usize) -> (usize, usize) {
    let per = n / parts;
    let rem = n % parts;
    let lo = slot * per + slot.min(rem);
    (lo, lo + per + usize::from(slot < rem))
}

impl PhasePool {
    /// Build a pool with `threads` total participants (>= 2; a serial
    /// mode needs no pool at all).
    pub fn new(threads: usize) -> Self {
        debug_assert!(threads >= 2, "threads=1 is the serial path, no pool");
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                epoch: 0,
                shutdown: false,
                bytes: SpanBytes::empty(),
                tasks: Vec::new(),
                results: Vec::new(),
                outstanding: 0,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let handles = (1..threads)
            .map(|slot| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("empa-phase-a-{slot}"))
                    .spawn(move || worker_loop(shared, threads, slot))
                    .expect("spawn phase-A worker")
            })
            .collect();
        PhasePool { shared, handles, threads }
    }

    /// Total participants, including the stepping thread.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Speculate one span: fan `tasks` out over the participants against
    /// the pre-phase `mem` bytes, block until every chunk is computed,
    /// and return the effect records in task order (= core-index order,
    /// the commit order).
    pub fn run_span(&self, mem: &Memory, tasks: Vec<PhaseTask>) -> Vec<PendingEffects> {
        let n = tasks.len();
        let (lo0, hi0) = chunk(n, self.threads, 0);
        // The stepping thread's own chunk, cloned before publication so
        // it can compute outside the lock alongside the workers.
        let mine: Vec<PhaseTask> = tasks[lo0..hi0].to_vec();
        {
            let mut st = self.shared.lock();
            debug_assert_eq!(st.outstanding, 0, "spans never overlap");
            let raw = mem.raw_bytes();
            st.bytes = SpanBytes { ptr: raw.as_ptr(), len: raw.len() };
            st.tasks = tasks;
            st.results.clear();
            st.results.resize_with(n, || None);
            st.outstanding = self.handles.len();
            st.epoch += 1;
            self.shared.work.notify_all();
        }
        let view = mem.view();
        let computed: Vec<PendingEffects> = mine.iter().map(|t| t.run(&view)).collect();
        let mut st = self.shared.lock();
        for (k, eff) in computed.into_iter().enumerate() {
            st.results[lo0 + k] = Some(eff);
        }
        while st.outstanding > 0 {
            st = self.shared.done.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        // Drop the borrow markers before the `&Memory` borrow ends.
        st.tasks.clear();
        st.bytes = SpanBytes::empty();
        st.results.drain(..).map(|r| r.expect("every chunk computed")).collect()
    }
}

impl Drop for PhasePool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.lock();
            st.shutdown = true;
            self.shared.work.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: Arc<Shared>, parts: usize, slot: usize) {
    let mut seen = 0u64;
    loop {
        let (bytes, mine, base) = {
            let mut st = shared.lock();
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    break;
                }
                st = shared.work.wait(st).unwrap_or_else(|e| e.into_inner());
            }
            seen = st.epoch;
            let (lo, hi) = chunk(st.tasks.len(), parts, slot);
            (st.bytes, st.tasks[lo..hi].to_vec(), lo)
        };
        // SAFETY: see `SpanBytes` — `run_span` keeps the backing memory
        // alive and unwritten until this worker decrements `outstanding`.
        let slice: &[u8] = unsafe { std::slice::from_raw_parts(bytes.ptr, bytes.len) };
        let view = MemView::new(slice);
        let computed: Vec<PendingEffects> = mine.iter().map(|t| t.run(&view)).collect();
        let mut st = shared.lock();
        for (k, eff) in computed.into_iter().enumerate() {
            st.results[base + k] = Some(eff);
        }
        st.outstanding -= 1;
        if st.outstanding == 0 {
            shared.done.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::emu::CoreRegs;
    use crate::empa::core::Latches;
    use crate::isa::{Insn, Reg};

    fn load_task(id: usize, addr: i32) -> PhaseTask {
        let mut regs = CoreRegs::default();
        regs.file[Reg::Ecx as usize] = addr;
        PhaseTask {
            id,
            insn: Insn::MrMov { ra: Reg::Eax, rb: Reg::Ecx, disp: 0 },
            pc: 0,
            regs,
            latch: Latches::default(),
        }
    }

    #[test]
    fn chunks_partition_without_gaps() {
        for n in 0..40 {
            for parts in 1..6 {
                let mut next = 0;
                for slot in 0..parts {
                    let (lo, hi) = chunk(n, parts, slot);
                    assert_eq!(lo, next, "n={n} parts={parts} slot={slot}");
                    assert!(hi - lo <= n / parts + 1);
                    next = hi;
                }
                assert_eq!(next, n, "chunks cover exactly [0, n)");
            }
        }
    }

    #[test]
    fn spans_come_back_in_task_order_across_reuse() {
        let mut mem = Memory::new(256);
        for i in 0..32 {
            mem.write_u32(4 * i, 100 + i).unwrap();
        }
        let pool = PhasePool::new(3);
        assert_eq!(pool.threads(), 3);
        for _round in 0..50 {
            let tasks: Vec<PhaseTask> = (0..32).map(|i| load_task(i, 4 * i as i32)).collect();
            let effs = pool.run_span(&mem, tasks);
            assert_eq!(effs.len(), 32);
            for (i, e) in effs.iter().enumerate() {
                assert_eq!(e.id, i, "records come back in submission order");
                assert_eq!(e.regs.file[Reg::Eax as usize], 100 + i as u32 as i32);
                assert_eq!(e.read, Some(4 * i as u32));
            }
        }
    }

    #[test]
    fn tiny_and_empty_spans_are_fine() {
        let mem = Memory::new(64);
        let pool = PhasePool::new(4);
        assert_eq!(pool.run_span(&mem, Vec::new()).len(), 0);
        let effs = pool.run_span(&mem, vec![load_task(7, 8)]);
        assert_eq!(effs.len(), 1);
        assert_eq!(effs[0].id, 7);
    }

    #[test]
    fn drop_joins_the_workers() {
        let pool = PhasePool::new(2);
        let mem = Memory::new(16);
        let _ = pool.run_span(&mem, vec![load_task(0, 0)]);
        drop(pool); // must not hang
    }
}
