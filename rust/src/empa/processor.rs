//! The cycle-stepped EMPA processor: cores + supervisor + memory.
//!
//! Operation follows Fig. 3 of the paper: the SV "creates" the cores into
//! a pool; one core is allocated and enabled as the root; cores run
//! conventionally until the pre-fetch recognises a metainstruction (`Meta`
//! signal), which the SV executes at the supervisor level — renting cores,
//! cloning glue, administering terminations, driving the mass-processing
//! engines and the latch-register transfers.
//!
//! Each tick runs four phases:
//!  A. *apply*   — retire instructions whose latency elapsed (architectural
//!                 effects become visible, including SV effects of metas);
//!  B. *engines* — mass engines launch due child QTs (one allocation per
//!                 SV tick) and finalise;
//!  C. *unblock* — blocked cores whose condition cleared return to Idle;
//!  D. *fetch*   — idle cores fetch, with engine-intercepted `qterm`s
//!                 handled combinationally (§3.4: synchronisation "in one
//!                 clock cycle ... no time is used when there is no need
//!                 to wait").
//!
//! Time advances through the **event-horizon scheduler** ([`StepMode`]):
//! every instruction costs 3–8+ clocks, so most clocks are dead — no
//! retirement, no engine action, no unblock. [`EmpaProcessor::step`] runs
//! one full tick, then jumps the clock straight to the next interesting
//! time (integrating occupancy over the skipped span) and chains
//! single-core apply→fetch sequences inline when nothing else can run.
//! Lockstep stepping is kept as a [`StepMode::Lockstep`] knob for
//! differential testing; the two modes are cycle-identical by
//! construction (see `rust/tests/stepping.rs` and EXPERIMENTS.md §Perf).

use super::core::{AllocState, BlockReason, Core, RunState};
use super::effects::{words_overlap, ChainTask, EffectOutcome, LatchPort, PendingEffects, PhaseTask};
use super::pool::PhasePool;
use super::sv::{MassEngine, MassMode, Supervisor};
use super::timing::TimingConfig;
use super::trace::{Event, Trace};
use crate::emu::{execute, CoreRegs, ExecEffect};
use crate::isa::{Insn, MetaFn, Reg, Status};
use crate::mem::{bus::MemoryBus, MemConfig, Memory};

/// How the simulator advances time.
///
/// Both modes are **cycle-identical**: every architectural effect, trace
/// event, bus reservation and occupancy figure lands on the same clock.
/// They differ only in how many scheduler iterations it takes to get
/// there — `EventHorizon` jumps over the dead clocks between events
/// instead of ticking through them.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum StepMode {
    /// One clock per scheduler iteration — the original cycle-stepped
    /// engine, kept for differential testing.
    Lockstep,
    /// Jump the clock to the next interesting time (a retirement, an
    /// engine launch/readout/finalise, a core becoming rentable), with
    /// occupancy accounting integrated over the skipped interval. §3.4's
    /// licence: the SV synchronises combinationally and "no time is used
    /// when there is no need to wait".
    #[default]
    EventHorizon,
    /// Event-horizon scheduling plus **host-parallel phase A**: between
    /// two supervisor sync points (metainstruction retirements, engine
    /// actions, IRQ raises), same-clock conventional retirements are
    /// speculated on `threads` host threads against a read-only view of
    /// the pre-phase memory, then their effect records are committed
    /// serially in core-index order — the order the lockstep loop uses —
    /// with conflicting reads re-executed in place. When the next
    /// supervisor sync point is provably more than one clock away, the
    /// fan-out covers up to [`EmpaConfig::span_batch`] *consecutive*
    /// clocks per span (multi-clock span batching — each worker chains
    /// its cores' apply→fetch sequences privately). Bit-identical to the
    /// other modes; `threads: 1` *is* the serial event-horizon path (no
    /// worker pool is built at all).
    ParallelA {
        /// Total host threads, including the stepping thread (1..=64).
        threads: usize,
    },
}

/// Why an [`EmpaConfig`] cannot be instantiated. Surfaced as a typed
/// error (not a panic) so a bad fabric configuration degrades to a
/// failed backend init instead of aborting the serving process.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ConfigError {
    /// `num_cores` outside the supported range: the supervisor's
    /// identity/children/preallocation bitmasks are 64-bit one-hot sets.
    CoreCount { requested: usize },
    /// `ParallelA` thread count outside the supported range (more host
    /// threads than simulated cores can never all be busy; 64 is the
    /// core-count ceiling).
    HostThreads { requested: usize },
    /// `span_batch` of 0: the window length is a clock *count*, and
    /// "batch zero clocks" has no meaning — 1 is the explicit way to
    /// disable batching while keeping the single-clock fan-out.
    SpanBatch { requested: usize },
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::CoreCount { requested } => {
                write!(f, "num_cores={requested} unsupported (this supervisor models 1..=64 cores)")
            }
            ConfigError::HostThreads { requested } => {
                write!(f, "ParallelA threads={requested} unsupported (1..=64 host threads)")
            }
            ConfigError::SpanBatch { requested } => {
                write!(f, "span_batch={requested} unsupported (must be >= 1; 1 disables batching)")
            }
        }
    }
}

impl std::error::Error for ConfigError {}

/// Processor configuration.
#[derive(Debug, Clone)]
pub struct EmpaConfig {
    /// Number of physical cores (the paper's SUMUP saturation needs 31).
    pub num_cores: usize,
    pub timing: TimingConfig,
    pub mem: MemConfig,
    /// Record a full event trace (debugging / occupancy plots).
    pub trace: bool,
    /// Runaway guard.
    pub max_clocks: u64,
    /// How the scheduler advances time (cycle-identical either way).
    pub step: StepMode,
    /// Maximum consecutive clocks one `ParallelA` span may batch (the
    /// multi-clock window length). 1 disables batching — every span
    /// covers a single clock, the pre-batching behaviour. Ignored by the
    /// serial modes. Must be >= 1 ([`ConfigError::SpanBatch`]).
    pub span_batch: usize,
}

impl Default for EmpaConfig {
    fn default() -> Self {
        EmpaConfig {
            num_cores: 32,
            timing: TimingConfig::paper(),
            mem: MemConfig::ideal(),
            trace: false,
            max_clocks: 10_000_000,
            step: StepMode::EventHorizon,
            span_batch: 16,
        }
    }
}

impl EmpaConfig {
    /// Validate the configuration; the rule set behind
    /// [`EmpaProcessor::try_new`] and the fabric's `sim` backend init.
    pub fn validate(&self) -> Result<(), ConfigError> {
        if !(1..=64).contains(&self.num_cores) {
            return Err(ConfigError::CoreCount { requested: self.num_cores });
        }
        if let StepMode::ParallelA { threads } = self.step {
            if !(1..=64).contains(&threads) {
                return Err(ConfigError::HostThreads { requested: threads });
            }
        }
        if self.span_batch == 0 {
            return Err(ConfigError::SpanBatch { requested: 0 });
        }
        Ok(())
    }
}

/// Result of running one program to completion.
#[derive(Debug, Clone)]
pub struct RunReport {
    /// Total execution time in core clocks (time of the root `halt`).
    pub clocks: u64,
    pub status: Status,
    /// Final architectural state of the root core.
    pub regs: CoreRegs,
    /// Maximum simultaneously occupied PUs — the `k` of Table 1
    /// (rented + preallocated, §4.1.2 availability definition).
    pub max_occupied: usize,
    /// Number of distinct cores that were ever occupied.
    pub distinct_cores: usize,
    /// Instructions retired across all cores.
    pub retired: u64,
    /// Memory port contention statistics (E7).
    pub bus: crate::mem::BusStats,
    /// Supervisor operations performed.
    pub sv_ops: u64,
    /// Scheduler iterations actually executed (full four-phase ticks) —
    /// the event-horizon scheduler's "events". In lockstep mode this
    /// equals the clocks simulated.
    pub events_processed: u64,
    /// Clocks advanced **without** a full scheduler iteration: dead
    /// clocks jumped over plus single-core burst clocks. Always 0 in
    /// lockstep mode; `events_processed + clocks_skipped` is the total
    /// clock advance.
    pub clocks_skipped: u64,
    /// Fetches served from the decoded-instruction cache (host-perf
    /// observability; modeled clocks are unaffected either way).
    pub icache_hits: u64,
    /// Fetches that had to decode from memory bytes.
    pub icache_misses: u64,
    /// Host threads stepping this run (1 for the serial modes and for
    /// `ParallelA { threads: 1 }`).
    pub host_threads: usize,
    /// Ticks whose phase A was fanned out over the worker pool (≥2
    /// same-clock conventional retirements, no metainstruction pending).
    /// Host-perf observability only — modeled clocks are unaffected.
    pub parallel_spans: u64,
    /// Retirements speculated inside those spans (`/ parallel_spans` =
    /// achieved fan-out width; see [`RunReport::cores_per_span`]).
    pub parallel_cores: u64,
    /// Speculations whose read overlapped an earlier core's same-clock
    /// store and were re-executed serially against the live memory.
    pub span_conflicts: u64,
    /// Span-size histogram: buckets 2, 3, 4, 5–8, 9–16, 17+ cores.
    pub span_hist: [u64; 6],
    /// Clocks advanced through multi-clock span batches (subset of
    /// `clocks_skipped`): consecutive clocks committed from chained
    /// apply→fetch records instead of individual ticks. 0 when
    /// `span_batch == 1` or in the serial modes. Host-perf observability
    /// only — modeled clocks are unaffected.
    pub batched_clocks: u64,
    /// Subset of `batched_clocks` advanced while the memory bus carried
    /// a port reservation table: the windows whose fetch charges were
    /// replayed in lockstep grant order instead of charged serially.
    /// 0 on ideal memory.
    pub batched_ported_clocks: u64,
    /// Batched windows truncated because a replayed bus charge came back
    /// stalled (the queueing delay shifted a chain's apply time, so the
    /// speculation beyond that clock was discarded and re-planned).
    pub bus_replay_truncations: u64,
    /// Subset of `batched_clocks` advanced while a mass engine was
    /// mid-flight (engine-inclusive windows: non-final `%pp` arrivals
    /// commit in-window; launches/readouts/finalises still bound the
    /// window through the engine horizon). 0 without mass engines.
    pub engine_batched_clocks: u64,
    /// Batch-length histogram in clocks, same buckets as `span_hist`
    /// (1–2, 3, 4, 5–8, 9–16, 17+); one entry per batched span.
    pub span_batch_hist: [u64; 6],
    /// Simulation-level fault (runaway, child halt, invalid meta use).
    pub fault: Option<String>,
    /// Event trace, when enabled.
    pub trace: Trace,
}

impl RunReport {
    /// Value of `%eax` — the sum in the paper's running example.
    pub fn eax(&self) -> i32 {
        self.regs.file[0]
    }

    /// Effective simulated clocks per scheduler iteration (1.0 in
    /// lockstep; the event-horizon scheduler's skip ratio).
    pub fn clocks_per_event(&self) -> f64 {
        if self.events_processed == 0 {
            0.0
        } else {
            (self.events_processed + self.clocks_skipped) as f64 / self.events_processed as f64
        }
    }

    /// Mean fan-out width of the parallel spans (0.0 when phase A never
    /// fanned out).
    pub fn cores_per_span(&self) -> f64 {
        if self.parallel_spans == 0 {
            0.0
        } else {
            self.parallel_cores as f64 / self.parallel_spans as f64
        }
    }

    /// Fraction of all simulated clocks advanced through multi-clock
    /// span batches (0.0 outside `ParallelA` or with `span_batch == 1`).
    pub fn batched_share(&self) -> f64 {
        let total = self.events_processed + self.clocks_skipped;
        if total == 0 {
            0.0
        } else {
            self.batched_clocks as f64 / total as f64
        }
    }
}

/// The EMPA processor.
pub struct EmpaProcessor {
    pub cores: Vec<Core>,
    pub sv: Supervisor,
    pub mem: Memory,
    pub bus: MemoryBus,
    pub timing: TimingConfig,
    pub clock: u64,
    pub trace: Trace,
    root: usize,
    max_occupied: usize,
    ever_occupied: u64,
    /// Completed interrupt services: (raised_at, handler_done_at).
    pub irq_log: Vec<(u64, u64)>,
    /// Raise clock of the in-flight interrupt per reserved core.
    irq_inflight: Vec<Option<u64>>,
    /// Superset of currently-rented cores (refreshed each tick; rent
    /// transitions set bits eagerly so same-tick launches are seen).
    rented_mask: u64,
    /// Reused phase-D worklist buffer (hot-loop allocation avoidance).
    worklist_buf: Vec<usize>,
    /// Direct-mapped decoded-instruction cache: `(pc, mem version, insn)`;
    /// invalidated implicitly when memory is written (version bump).
    /// Loops re-fetch the same handful of PCs — see EXPERIMENTS.md §Perf.
    /// The pc and the *full* version are stored side by side: the old
    /// packed tag (`pc << 24 | version & 0xFFFFFF`) silently aliased once
    /// the version wrapped past 2^24 writes, letting a stale entry
    /// validate against self-modified code.
    icache: Vec<(u32, u64, Insn)>,
    fault: Option<String>,
    halted: bool,
    /// Clock at which the root `halt` completed (the reported run time).
    halt_at: u64,
    max_clocks: u64,
    /// Configured memory size (`reset_with` restores it, so a previous
    /// oversized image cannot widen later programs' address space).
    mem_size: usize,
    /// How the scheduler advances time.
    step_mode: StepMode,
    /// Phase-A worker pool: `Some` iff `ParallelA { threads >= 2 }`.
    /// Survives `reset_with`/`reset_reusing` — the fabric's processor
    /// pool must not respawn host threads per request.
    pool: Option<PhasePool>,
    /// Host threads stepping this processor (1 for the serial modes).
    host_threads: usize,
    /// Ticks whose phase A fanned out over the pool.
    parallel_spans: u64,
    /// Retirements speculated inside those spans.
    parallel_cores: u64,
    /// Conflicting speculations re-executed serially.
    span_conflicts: u64,
    /// Span-size histogram (buckets 2, 3, 4, 5–8, 9–16, 17+).
    span_hist: [u64; 6],
    /// Multi-clock window limit ([`EmpaConfig::span_batch`]; 1 = off).
    span_batch: usize,
    /// Clocks advanced through multi-clock batches.
    batched_clocks: u64,
    /// Batched clocks advanced under a ported (non-ideal) bus.
    batched_ported_clocks: u64,
    /// Windows truncated by a stalled replayed bus charge.
    bus_replay_truncations: u64,
    /// Batched clocks advanced while a mass engine was mid-flight.
    engine_batched_clocks: u64,
    /// Batch-length histogram in clocks (same buckets as `span_hist`).
    span_batch_hist: [u64; 6],
    /// Reused phase-A pending buffer (hot-loop allocation avoidance).
    span_buf: Vec<(usize, Insn)>,
    /// Reused commit-time write-set buffer.
    span_writes: Vec<u32>,
    /// Full ticks executed by [`EmpaProcessor::step`].
    events_processed: u64,
    /// Clocks advanced without a full tick (skips + bursts).
    clocks_skipped: u64,
    /// Decode-cache hits/misses (see [`EmpaProcessor::decode_cached`]).
    icache_hits: u64,
    icache_misses: u64,
    /// Event-horizon bound for external drivers (interrupt raisers): the
    /// scheduler never skips past this clock, so a driver acting "at
    /// clock T" observes `clock == T` exactly as it would in lockstep.
    external_wake_at: Option<u64>,
}

impl EmpaProcessor {
    /// Build a processor with the program image at address 0; the root
    /// core is rented and enabled at the entry point.
    ///
    /// Returns a typed [`ConfigError`] for an invalid configuration
    /// instead of panicking — the fabric surfaces it through backend
    /// init / [`crate::api::FabricError::InvalidConfig`].
    pub fn try_new(image: &[u8], cfg: &EmpaConfig) -> Result<Self, ConfigError> {
        cfg.validate()?;
        let host_threads = match cfg.step {
            StepMode::ParallelA { threads } => threads,
            _ => 1,
        };
        let mut cores: Vec<Core> = (0..cfg.num_cores).map(Core::new).collect();
        cores[0].alloc = AllocState::Rented;
        cores[0].reset_for_qt(0);
        let mut p = EmpaProcessor {
            cores,
            sv: Supervisor::default(),
            mem: Memory::with_image(cfg.mem.size, image),
            bus: MemoryBus::new(&cfg.mem),
            timing: cfg.timing.clone(),
            clock: 0,
            trace: Trace::new(cfg.trace),
            root: 0,
            max_occupied: 1,
            ever_occupied: 1,
            irq_log: Vec::new(),
            irq_inflight: vec![None; cfg.num_cores],
            rented_mask: 1,
            worklist_buf: Vec::new(),
            // Virgin entries carry version u64::MAX, which the monotonic
            // write counter can never reach: a fetch of pc == u32::MAX on
            // never-written memory (version 0) must miss and fault, not
            // hit the sentinel and execute a phantom Nop.
            icache: vec![(u32::MAX, u64::MAX, Insn::Nop); 128],
            fault: None,
            halted: false,
            halt_at: 0,
            max_clocks: cfg.max_clocks,
            mem_size: cfg.mem.size,
            step_mode: cfg.step,
            pool: (host_threads >= 2).then(|| PhasePool::new(host_threads)),
            host_threads,
            parallel_spans: 0,
            parallel_cores: 0,
            span_conflicts: 0,
            span_hist: [0; 6],
            span_batch: cfg.span_batch,
            batched_clocks: 0,
            batched_ported_clocks: 0,
            bus_replay_truncations: 0,
            engine_batched_clocks: 0,
            span_batch_hist: [0; 6],
            span_buf: Vec::new(),
            span_writes: Vec::new(),
            events_processed: 0,
            clocks_skipped: 0,
            icache_hits: 0,
            icache_misses: 0,
            external_wake_at: None,
        };
        p.trace.push(0, 0, Event::Rent { parent: None });
        Ok(p)
    }

    /// Panicking convenience constructor for tests and direct embedding;
    /// serving paths use [`EmpaProcessor::try_new`].
    pub fn new(image: &[u8], cfg: &EmpaConfig) -> Self {
        Self::try_new(image, cfg).unwrap_or_else(|e| panic!("invalid EmpaConfig: {e}"))
    }

    /// Run to completion and report.
    pub fn run(mut self) -> RunReport {
        self.run_report()
    }

    /// Run to completion without consuming the processor, so it can be
    /// reset and reused for the next program ([`EmpaProcessor::reset_with`]
    /// — the compile-once pipeline's processor pool). Memory stays
    /// readable afterwards for result read-back.
    pub fn run_report(&mut self) -> RunReport {
        while !self.halted && self.fault.is_none() {
            if self.clock >= self.max_clocks {
                self.fault = Some(format!("runaway: exceeded {} clocks", self.max_clocks));
                break;
            }
            self.step();
        }
        let status = if self.fault.is_some() {
            Status::Ins
        } else {
            Status::Hlt
        };
        let retired = self.cores.iter().map(|c| c.retired).sum();
        // Move the trace out instead of cloning it (it can be large when
        // enabled — the next run replaces it anyway); the replacement
        // keeps the enabled flag so a reused processor keeps tracing.
        let enabled = self.trace.is_enabled();
        let trace = std::mem::replace(&mut self.trace, Trace::new(enabled));
        RunReport {
            clocks: if self.halted { self.halt_at } else { self.clock },
            status,
            regs: self.cores[self.root].regs.clone(),
            max_occupied: self.max_occupied,
            distinct_cores: self.ever_occupied.count_ones() as usize,
            retired,
            bus: self.bus.stats(),
            sv_ops: self.sv.ops,
            events_processed: self.events_processed,
            clocks_skipped: self.clocks_skipped,
            icache_hits: self.icache_hits,
            icache_misses: self.icache_misses,
            host_threads: self.host_threads,
            parallel_spans: self.parallel_spans,
            parallel_cores: self.parallel_cores,
            span_conflicts: self.span_conflicts,
            span_hist: self.span_hist,
            batched_clocks: self.batched_clocks,
            batched_ported_clocks: self.batched_ported_clocks,
            bus_replay_truncations: self.bus_replay_truncations,
            engine_batched_clocks: self.engine_batched_clocks,
            span_batch_hist: self.span_batch_hist,
            fault: self.fault.clone(),
            trace,
        }
    }

    /// Reset for a new program image, **reusing** the allocated cores,
    /// memory, bus and decode cache instead of rebuilding them — the hot
    /// path of the fabric's compile-once pipeline. Equivalent to
    /// `EmpaProcessor::new(image, &same_cfg)` observationally: the root
    /// core is rented at entry 0 and every [`RunReport`] field starts
    /// from the same state. The decode cache is *not* cleared: its
    /// entries carry the memory version, which `reload` keeps monotonic,
    /// so entries from the previous program can never validate.
    pub fn reset_with(&mut self, image: &[u8]) {
        self.mem.reload(image, self.mem_size);
        self.reset_state();
    }

    /// Reset for a new run of the **same** image the memory was last
    /// loaded with: instead of copying the whole image back in, only the
    /// bytes the previous run wrote (the memory's dirty window) are
    /// restored — the fabric's program pipeline calls this when a worker
    /// serves consecutive requests of one cached template, then patches
    /// just the data spans. Observationally identical to
    /// [`EmpaProcessor::reset_with`] of the same image; cached decodes
    /// stay valid when the previous run only wrote data (see
    /// [`crate::mem::Memory::restore_from`]).
    pub fn reset_reusing(&mut self, image: &[u8]) {
        self.mem.restore_from(image, self.mem_size);
        self.reset_state();
    }

    /// Forward the program's code/data boundary to the memory's decode
    /// cache versioning (see [`crate::mem::Memory::set_code_limit`]).
    pub fn set_code_limit(&mut self, limit: u32) {
        self.mem.set_code_limit(limit);
    }

    /// Everything [`EmpaProcessor::reset_with`] resets besides memory.
    fn reset_state(&mut self) {
        self.bus.reset();
        self.sv.reset();
        for c in &mut self.cores {
            c.reset_full();
        }
        self.cores[0].alloc = AllocState::Rented;
        self.cores[0].reset_for_qt(0);
        self.clock = 0;
        self.trace = Trace::new(self.trace.is_enabled());
        self.root = 0;
        self.max_occupied = 1;
        self.ever_occupied = 1;
        self.irq_log.clear();
        self.irq_inflight.iter_mut().for_each(|x| *x = None);
        self.rented_mask = 1;
        self.fault = None;
        self.halted = false;
        self.halt_at = 0;
        self.events_processed = 0;
        self.clocks_skipped = 0;
        self.icache_hits = 0;
        self.icache_misses = 0;
        // span counters restart per run; the pool itself is kept warm
        self.parallel_spans = 0;
        self.parallel_cores = 0;
        self.span_conflicts = 0;
        self.span_hist = [0; 6];
        self.batched_clocks = 0;
        self.batched_ported_clocks = 0;
        self.bus_replay_truncations = 0;
        self.engine_batched_clocks = 0;
        self.span_batch_hist = [0; 6];
        self.external_wake_at = None;
        self.trace.push(0, 0, Event::Rent { parent: None });
    }

    /// Reserve a core for interrupt servicing (§3.6): rent it from the
    /// pool, point it at the handler QT and park it "in power economy
    /// mode". The handler must end with `qterm`; the core then re-parks
    /// itself, re-armed for the next interrupt.
    pub fn reserve_irq_core(&mut self, handler: u32) -> Option<usize> {
        let now = self.clock;
        let id = (0..self.cores.len()).find(|&cid| cid != self.root && self.cores[cid].available(now))?;
        self.rented_mask |= 1u64 << id;
        let c = &mut self.cores[id];
        c.alloc = AllocState::Rented;
        c.reset_for_qt(handler);
        c.run = RunState::Blocked(BlockReason::IrqWait);
        self.trace.push(now, id, Event::Rent { parent: None });
        Some(id)
    }

    /// Raise the interrupt line of a reserved core. The core wakes
    /// immediately — "without any duty to save and restore" — and starts
    /// fetching its handler on the next tick. Returns false when the core
    /// is still busy with the previous interrupt (the raise is lost, as
    /// on real edge-triggered lines).
    pub fn raise_irq(&mut self, core: usize) -> bool {
        let now = self.clock;
        if self.cores[core].run != RunState::Blocked(BlockReason::IrqWait) {
            return false;
        }
        self.cores[core].pc = self.cores[core].offset;
        self.cores[core].run = RunState::Idle;
        self.irq_inflight[core] = Some(now);
        self.trace.push(now, core, Event::Unblock);
        true
    }

    /// True when no interrupt is currently being serviced.
    pub fn irq_inflight_empty(&self) -> bool {
        self.irq_inflight.iter().all(|x| x.is_none())
    }

    /// Bound the event-horizon scheduler for an external driver: the
    /// clock will pass through `Some(t)` exactly (never be skipped over),
    /// so a driver that raises an interrupt "at clock t" behaves
    /// identically in both [`StepMode`]s. `None` removes the bound.
    /// Ignored in lockstep, where every clock is visited anyway.
    pub fn set_external_wake(&mut self, at: Option<u64>) {
        self.external_wake_at = at;
    }

    // ------------------------------------------------------------------
    // the event-horizon scheduler
    // ------------------------------------------------------------------

    /// One scheduler iteration: a full [`EmpaProcessor::tick`], then — in
    /// [`StepMode::EventHorizon`] — the single-core burst fast path and a
    /// jump straight to the next interesting clock. Cycle-identical to
    /// calling `tick()` in a loop; only the iteration count differs.
    pub fn step(&mut self) {
        self.tick();
        self.events_processed += 1;
        if self.step_mode == StepMode::Lockstep || self.halted || self.fault.is_some() {
            return;
        }
        self.burst();
        if self.halted || self.fault.is_some() {
            return;
        }
        let mut h = self.next_event().min(self.max_clocks.max(self.clock));
        if let Some(w) = self.external_wake_at {
            h = h.min(w.max(self.clock));
        }
        if h > self.clock {
            self.advance_to(h);
        }
        self.try_batch();
    }

    /// The next clock (≥ now) at which `tick()` would do *anything*:
    /// the minimum over core retirements (`apply_at`), cores ready to
    /// fetch or unblock (now), engine launches/readouts/finalises
    /// (including the `available_at` of the cores a stalled launch is
    /// waiting to rent), capped by the runaway guard. Every state change
    /// in `tick()` traces back to one of these sources, which is the
    /// skip invariant: all clocks strictly before the returned horizon
    /// are provably dead.
    fn next_event(&self) -> u64 {
        let now = self.clock;
        let mut h = self.max_clocks.max(now);
        let mut bits = self.rented_mask;
        while bits != 0 {
            let id = bits.trailing_zeros() as usize;
            bits &= bits - 1;
            let c = &self.cores[id];
            let block_clear = matches!(
                c.run,
                RunState::Blocked(BlockReason::WaitChildren { .. })
                    | RunState::Blocked(BlockReason::HaltPending)
            ) && c.children == 0
                && !self.sv.parent_engine_active(id);
            if let Some(t) = c.wake_at(now, block_clear) {
                h = h.min(t);
            }
        }
        let engine_due = self
            .sv
            .any_active()
            .then(|| self.sv.earliest_due(now, |parent| self.earliest_mass_rent_at(parent)))
            .flatten();
        if let Some(t) = engine_due {
            h = h.min(t);
        }
        h.max(now)
    }

    /// Earliest clock a mass engine of `parent` could rent a core —
    /// mirrors the candidate set of [`EmpaProcessor::rent_for_mass`]
    /// (preallocated set when the parent has one, else the pool), but
    /// over `available_at` instead of availability-now. `None` when no
    /// candidate core exists at all (only an event can free one).
    fn earliest_mass_rent_at(&self, parent: usize) -> Option<u64> {
        let prealloc = self.cores[parent].prealloc;
        if prealloc != 0 {
            self.cores
                .iter()
                .filter(|c| {
                    matches!(c.alloc, AllocState::PreAllocatedBy { parent: p } if p == parent)
                        && prealloc & c.mask() != 0
                })
                .map(|c| c.available_at)
                .min()
        } else {
            self.cores
                .iter()
                .filter(|c| c.id != parent && c.alloc == AllocState::Free)
                .map(|c| c.available_at)
                .min()
        }
    }

    /// Jump the clock to `h`, integrating the occupancy accounting the
    /// skipped lockstep ticks would have performed: every rented core
    /// accrues the whole span at once. Nothing else can change during
    /// the span (that is [`EmpaProcessor::next_event`]'s invariant), so
    /// `rented_mask`, `max_occupied` and `ever_occupied` are already
    /// correct.
    fn advance_to(&mut self, h: u64) {
        let delta = h - self.clock;
        if delta == 0 {
            return;
        }
        let mut bits = self.rented_mask;
        while bits != 0 {
            let id = bits.trailing_zeros() as usize;
            bits &= bits - 1;
            self.cores[id].busy_clocks += delta;
        }
        self.clocks_skipped += delta;
        self.clock = h;
    }

    /// Single-core fast path: while the machine is quiescent except for
    /// exactly one executing core — no mass engine active, every other
    /// rented core blocked on a condition only a metainstruction could
    /// clear — chain that core's apply→fetch sequence inline instead of
    /// paying a full four-phase tick per instruction. Metainstructions
    /// and `halt` break the burst (they touch supervisor state that the
    /// full tick owns). State evolution — clocks, bus reservations,
    /// trace times, occupancy — is identical to lockstep; only the
    /// scheduler-iteration count drops.
    fn burst(&mut self) {
        loop {
            if self.fault.is_some() || self.halted || self.sv.any_active() {
                return;
            }
            let mut exec = None;
            let mut bits = self.rented_mask;
            while bits != 0 {
                let id = bits.trailing_zeros() as usize;
                bits &= bits - 1;
                match self.cores[id].run {
                    RunState::Exec { .. } => {
                        if exec.replace(id).is_some() {
                            return; // two runnable cores: full ticks
                        }
                    }
                    RunState::Blocked(BlockReason::IrqWait) => {}
                    RunState::Blocked(
                        BlockReason::WaitChildren { .. } | BlockReason::HaltPending,
                    ) => {
                        // no engine is active, so children == 0 means a
                        // pending unblock the next full tick must run
                        if self.cores[id].children == 0 {
                            return;
                        }
                    }
                    _ => return,
                }
            }
            let Some(id) = exec else { return };
            let RunState::Exec { insn, apply_at } = self.cores[id].run else { unreachable!() };
            if matches!(insn, Insn::Meta { .. } | Insn::Halt) {
                return;
            }
            let t = apply_at.max(self.clock);
            if t >= self.max_clocks {
                return; // the runaway guard fires before this apply
            }
            if self.external_wake_at.is_some_and(|w| w <= t) {
                return; // an external driver wants the clock at w exactly
            }
            // Lockstep would run (t - clock) dead ticks plus the applying
            // tick itself; account the whole rented span, then replay the
            // apply and the same-tick fetch inline. A conventional apply
            // cannot change allocation state, so the rented set is
            // constant across the span.
            self.advance_to(t + 1);
            self.apply(id, insn, t);
            if self.fault.is_some() {
                return;
            }
            if self.cores[id].run == RunState::Idle {
                let mut worklist = std::mem::take(&mut self.worklist_buf);
                worklist.clear();
                self.fetch(id, t, &mut worklist);
                debug_assert!(worklist.is_empty(), "no engine paths inside a burst");
                self.worklist_buf = worklist;
            }
        }
    }

    /// One core clock.
    ///
    /// Hot loop: phases iterate only the bits of `rented_mask` (a
    /// superset of rented cores, refreshed in the single end-of-tick
    /// accounting pass) instead of scanning every core — see
    /// EXPERIMENTS.md §Perf for the before/after.
    pub fn tick(&mut self) {
        let now = self.clock;
        // ---- A: apply retiring instructions ---------------------------
        if self.pool.is_some() {
            self.phase_a_span(now);
        } else {
            let mut bits = self.rented_mask;
            while bits != 0 {
                let id = bits.trailing_zeros() as usize;
                bits &= bits - 1;
                if let RunState::Exec { insn, apply_at } = self.cores[id].run {
                    if apply_at <= now {
                        self.apply(id, insn, now);
                    }
                }
            }
        }
        // ---- B: engines launch / finalise -----------------------------
        if self.sv.any_active() {
            self.engines_tick(now);
        }
        // ---- C: unblock ------------------------------------------------
        let mut bits = self.rented_mask;
        while bits != 0 {
            let id = bits.trailing_zeros() as usize;
            bits &= bits - 1;
            if let RunState::Blocked(reason) = self.cores[id].run {
                let clear = match reason {
                    BlockReason::WaitChildren { .. } | BlockReason::HaltPending => {
                        self.cores[id].children == 0 && !self.sv.parent_engine_active(id)
                    }
                    BlockReason::MassEngine => false, // engine finalise unblocks
                    BlockReason::IrqWait => false,     // raise_irq wakes
                };
                if clear {
                    self.cores[id].run = RunState::Idle;
                    self.trace.push(now, id, Event::Unblock);
                }
            }
        }
        // ---- D: fetch ---------------------------------------------------
        let mut worklist = std::mem::take(&mut self.worklist_buf);
        worklist.clear();
        let mut bits = self.rented_mask;
        while bits != 0 {
            let id = bits.trailing_zeros() as usize;
            bits &= bits - 1;
            if self.cores[id].alloc == AllocState::Rented && self.cores[id].run == RunState::Idle {
                worklist.push(id);
            }
        }
        while let Some(id) = worklist.pop() {
            if self.cores[id].alloc == AllocState::Rented && self.cores[id].run == RunState::Idle {
                self.fetch(id, now, &mut worklist);
            }
        }
        self.worklist_buf = worklist;
        // ---- accounting (single pass) -----------------------------------
        let mut occ = 0usize;
        let mut rented = 0u64;
        for c in &mut self.cores {
            if c.occupied() {
                occ += 1;
                self.ever_occupied |= 1u64 << c.id;
                if c.alloc == AllocState::Rented {
                    rented |= 1u64 << c.id;
                    c.busy_clocks += 1;
                }
            }
        }
        self.rented_mask = rented;
        self.max_occupied = self.max_occupied.max(occ);
        self.clock += 1;
    }

    // ------------------------------------------------------------------
    // parallel phase A (StepMode::ParallelA, threads >= 2)
    // ------------------------------------------------------------------

    /// Phase A with the host-parallel fan-out. Gathers the tick's pending
    /// retirements in ascending core-index order, then:
    ///
    /// - **sync point** (any metainstruction pending, or fewer than two
    ///   retirements): the plain serial loop. A meta's supervisor-level
    ///   apply may mutate *other* cores (a `qterm` writes the parent's
    ///   `FromChild` latch), so same-clock speculation against pre-phase
    ///   snapshots would read stale inputs — metas are exactly the
    ///   supervisor sync points of arXiv 1608.07155.
    /// - **fan-out** otherwise: speculate every retirement on the worker
    ///   pool against the pre-phase memory, then commit the effect
    ///   records serially in core-index order. A record whose load
    ///   overlaps an earlier core's same-clock store is stale and is
    ///   re-executed in place against the live memory (a pure apply
    ///   never mutates another core, so the re-run's inputs are intact).
    ///
    /// Either way the result is bit-identical to the lockstep loop.
    fn phase_a_span(&mut self, now: u64) {
        let mut pending = std::mem::take(&mut self.span_buf);
        pending.clear();
        let mut any_meta = false;
        let mut bits = self.rented_mask;
        while bits != 0 {
            let id = bits.trailing_zeros() as usize;
            bits &= bits - 1;
            if let RunState::Exec { insn, apply_at } = self.cores[id].run {
                if apply_at <= now {
                    any_meta |= matches!(insn, Insn::Meta { .. });
                    pending.push((id, insn));
                }
            }
        }
        if pending.len() < 2 || any_meta {
            for &(id, insn) in &pending {
                self.apply(id, insn, now);
            }
            self.span_buf = pending;
            return;
        }
        self.parallel_spans += 1;
        self.parallel_cores += pending.len() as u64;
        self.span_hist[span_bucket(pending.len())] += 1;
        let tasks: Vec<PhaseTask> =
            pending.iter().map(|&(id, _)| self.cores[id].phase_task()).collect();
        let effects =
            self.pool.as_ref().expect("parallel phase A has a pool").run_span(&self.mem, tasks);
        let mut writes = std::mem::take(&mut self.span_writes);
        writes.clear();
        for mut eff in effects {
            let stale = eff.read.is_some_and(|r| writes.iter().any(|&w| words_overlap(r, w)));
            if stale {
                self.span_conflicts += 1;
                eff = self.cores[eff.id].step_phase_a(&self.mem.view());
            }
            if let Some((addr, _)) = eff.write {
                writes.push(addr);
            }
            self.commit_effect(eff, now);
        }
        self.span_writes = writes;
        self.span_buf = pending;
    }

    /// Serially commit one speculated retirement — the exact state
    /// transitions of [`EmpaProcessor::apply`]'s conventional arm, driven
    /// from the effect record instead of a live execution.
    fn commit_effect(&mut self, eff: PendingEffects, now: u64) {
        let id = eff.id;
        self.cores[id].retired += 1;
        if let Some((addr, value)) = eff.write {
            // Through the live memory so decode-cache versioning and
            // dirty-window accounting stay identical to the serial path.
            self.mem.write_u32(addr, value).expect("speculation bounds-probed this store");
        }
        self.cores[id].regs = eff.regs;
        self.cores[id].latch = eff.latch;
        if let Some(v) = eff.streamed {
            self.stream_to_parent(id, v, now);
        }
        match eff.outcome {
            EffectOutcome::Continue { next_pc } => {
                self.cores[id].pc = next_pc;
                self.cores[id].run = RunState::Idle;
            }
            EffectOutcome::Stop(Status::Hlt) => {
                if id == self.root {
                    self.cores[id].run = RunState::Halted;
                    self.halted = true;
                    self.halt_at = now;
                    self.trace.push(now, id, Event::Halt);
                } else {
                    self.fault = Some(format!("core {id}: halt inside a QT (use qterm)"));
                }
            }
            EffectOutcome::Stop(s) => {
                self.fault =
                    Some(format!("core {id}: stopped with {s:?} at {:#x}", self.cores[id].pc));
            }
        }
    }

    // ------------------------------------------------------------------
    // multi-clock span batching (StepMode::ParallelA, span_batch >= 2)
    // ------------------------------------------------------------------

    /// Try to batch the next window of consecutive clocks through the
    /// worker pool. Called at the end of [`EmpaProcessor::step`], after
    /// the horizon jump: if the window `[clock, e)` provably contains no
    /// supervisor sync point, every pending conventional execution is
    /// chained privately on a worker ([`ChainTask::run`]) and the
    /// resulting apply+fetch records are committed serially, clock by
    /// clock in core-index order — exactly the order the lockstep loop
    /// uses. Cycle-identical by construction; only `events_processed`
    /// drops (batched clocks count as skipped, like bursts).
    ///
    /// The window end is the minimum over every event source the chains
    /// cannot reproduce: pending metainstruction retirements (supervisor
    /// applies), pending `halt` retirements (machine stop), the engine
    /// horizon ([`crate::empa::sv::Supervisor::earliest_due`]) when any mass engine is
    /// active, the external IRQ wake bound, the runaway guard, and
    /// `clock + span_batch`. A rented core the serial tick must touch
    /// *now* — idle (fetch pending) or blocked with its condition
    /// already clear (unblock pending) — aborts the batch entirely.
    ///
    /// Inside the window the chains are speculated against the
    /// pre-window memory, so the commit loop re-validates every clock:
    /// a load overlapping any earlier committed store (earlier clock, or
    /// same clock from a lower core index) and a fetch window `[pc,
    /// pc+6)` overlapping any store up to and including its clock are
    /// conflicts — the batch truncates *before* that clock and the
    /// serial tick redoes it.
    ///
    /// `%pp` streams commit in-window as engine events: a non-final
    /// arrival only mutates the parent Sum engine's accumulator and
    /// arrival count (plus the streaming core's own latch, which its
    /// chain carries forward) — state no chain and no window bound
    /// reads — so batching continues. The *final* arrival arms the
    /// readout (`done_at`, invisible to the entry-time engine horizon),
    /// so it truncates *after* its clock (or *before* it when
    /// `sv_readout == 0`, since the finalise would land in phase B of
    /// that very clock). [`crate::empa::sv::Supervisor::arrivals_to_final`]
    /// tells the two apart.
    ///
    /// Bus charges under a ported memory are replayed at commit: chains
    /// record each fetch's bus-access intent (`FetchRecord::bus_access`)
    /// without touching the shared reservation table, and pass 2 replays the
    /// charges through [`crate::mem::bus::MemoryBus::replay_access`] in
    /// lockstep's grant order — ascending clock, *descending core index*
    /// within a clock (the serial phase-D fetch worklist is drained
    /// LIFO) — so `BusStats` stays bit-identical. A replayed charge that
    /// comes back stalled shifts that core's apply time by the delay
    /// (exactly as the serial fetch would have) and truncates the window
    /// after its clock: every later speculated record of that chain sits
    /// at a stale clock.
    ///
    /// The decode-cache counters are *not* replayed for batched fetches
    /// (chains decode the raw bytes) — `icache_hits`/`icache_misses` are
    /// host observability and excluded from the identity contract.
    fn try_batch(&mut self) {
        if self.span_batch < 2 || self.pool.is_none() {
            return;
        }
        if self.halted || self.fault.is_some() {
            return;
        }
        let ported = !self.bus.is_ideal();
        let engine_active = self.sv.any_active();
        let h = self.clock;
        if h >= self.max_clocks {
            return;
        }
        let mut e = self.max_clocks;
        if let Some(w) = self.external_wake_at {
            if w <= h {
                return;
            }
            e = e.min(w);
        }
        if engine_active {
            match self.sv.earliest_due(h, |p| self.earliest_mass_rent_at(p)) {
                Some(t) if t <= h => return,
                Some(t) => e = e.min(t),
                // No engine action is reachable until some chain-side
                // event (e.g. a child qterm) — chains stop on those.
                None => {}
            }
        }
        let mut tasks: Vec<ChainTask> = Vec::new();
        let mut bits = self.rented_mask;
        while bits != 0 {
            let id = bits.trailing_zeros() as usize;
            bits &= bits - 1;
            let c = &self.cores[id];
            match c.run {
                RunState::Exec { insn, apply_at } => {
                    debug_assert!(apply_at >= h, "horizon jump never passes a retirement");
                    if matches!(insn, Insn::Meta { .. } | Insn::Halt) {
                        // Supervisor apply / machine stop: window bound.
                        if apply_at <= h {
                            return;
                        }
                        e = e.min(apply_at);
                    } else {
                        tasks.push(ChainTask {
                            id,
                            insn,
                            apply_at,
                            pc: c.pc,
                            regs: c.regs.clone(),
                            latch: c.latch,
                        });
                    }
                }
                RunState::Blocked(
                    BlockReason::WaitChildren { .. } | BlockReason::HaltPending,
                ) => {
                    // A pending unblock belongs to the next serial tick.
                    if c.children == 0 && !self.sv.parent_engine_active(id) {
                        return;
                    }
                    // Otherwise frozen: the mask only clears through a
                    // child qterm or an engine finalise, both of which
                    // stop/bound the window.
                }
                RunState::Blocked(BlockReason::MassEngine) => {} // engine horizon bounds e
                RunState::Blocked(BlockReason::IrqWait) => {}    // external wake bounds e
                _ => return, // Idle (fetch pending) or Halted: serial tick owns it
            }
        }
        e = e.min(h + self.span_batch as u64);
        if e <= h + 1 || tasks.len() < 2 {
            return; // a 1-clock window is the existing single-clock span path
        }
        let ntasks = tasks.len();
        let results =
            self.pool.as_ref().expect("checked above").run_batch(&self.mem, &self.timing, tasks, e);
        // Truncate to the earliest chain stop: records at that clock are
        // discarded and the serial tick redoes it with full supervisor
        // semantics (meta/halt fetch, engine intercept, decode fault...).
        let mut e_trunc = e;
        for r in &results {
            if let Some(t) = r.stop_at {
                e_trunc = e_trunc.min(t);
            }
        }
        // Commit clock by clock in ascending order, core-index order
        // within a clock — the lockstep order. `writes` accumulates every
        // committed store for the cross-clock staleness checks.
        let mut idx = vec![0usize; results.len()];
        let mut writes = std::mem::take(&mut self.span_writes);
        writes.clear();
        let mut prefix: Vec<u32> = Vec::new();
        let mut all_t: Vec<u32> = Vec::new();
        let mut fetches: Vec<usize> = Vec::new();
        let mut stream_counts: Vec<(usize, u32)> = Vec::new();
        'clocks: while e_trunc > h {
            // next clock with any pending record
            let mut t = u64::MAX;
            for (k, r) in results.iter().enumerate() {
                if let Some(s) = r.steps.get(idx[k]) {
                    t = t.min(s.t);
                }
            }
            if t >= e_trunc {
                break;
            }
            // Pass 1 — validate every record at `t` before committing
            // any of them, so a conflict can truncate the whole clock.
            // `prefix` holds same-clock stores of lower-index cores (the
            // serial phase-A order); `all_t` holds every store at `t`
            // (phase A fully precedes phase D, so a fetch at `t` sees
            // them all — including the fetching core's own).
            prefix.clear();
            all_t.clear();
            stream_counts.clear();
            let mut final_stream = false;
            for (k, r) in results.iter().enumerate() {
                if let Some(s) = r.steps.get(idx[k]) {
                    if s.t == t {
                        if let Some((addr, _)) = s.eff.write {
                            all_t.push(addr);
                        }
                    }
                }
            }
            for (k, r) in results.iter().enumerate() {
                let Some(s) = r.steps.get(idx[k]) else { continue };
                if s.t != t {
                    continue;
                }
                if let Some(rd) = s.eff.read {
                    if writes.iter().chain(prefix.iter()).any(|&w| words_overlap(rd, w)) {
                        self.span_conflicts += 1;
                        e_trunc = t;
                        break 'clocks;
                    }
                }
                let pc = s.fetch.pc as u64;
                if writes
                    .iter()
                    .chain(all_t.iter())
                    .any(|&w| (w as u64) + 4 > pc && (w as u64) < pc + 6)
                {
                    self.span_conflicts += 1;
                    e_trunc = t;
                    break 'clocks;
                }
                // Engine-inclusive windows: a `%pp` stream is a window
                // event only when it is the *final* arrival of an
                // unfinished Sum engine (it arms `done_at`, which the
                // entry-time horizon could not see). Non-final arrivals
                // and latch-only streams commit and the window rolls on.
                if s.eff.streamed.is_some() {
                    if let Some(parent) = self.cores[s.eff.id].parent {
                        if let Some(remaining) = self.sv.arrivals_to_final(parent) {
                            let seen = match stream_counts
                                .iter_mut()
                                .find(|(p, _)| *p == parent)
                            {
                                Some((_, c)) => {
                                    *c += 1;
                                    *c
                                }
                                None => {
                                    stream_counts.push((parent, 1));
                                    1
                                }
                            };
                            final_stream |= seen >= remaining;
                        }
                    }
                }
                if let Some((addr, _)) = s.eff.write {
                    prefix.push(addr);
                }
            }
            if final_stream && self.timing.sv_readout == 0 {
                // A zero-latency readout would finalise in phase B of
                // this very clock — only the serial tick can replay that.
                e_trunc = t;
                break;
            }
            // Pass 2a — commit the clock in ascending core-index order
            // (the serial phase-A order): apply effect, install the next
            // Exec, stage bus-accessing fetches for the replay below.
            fetches.clear();
            for (k, r) in results.iter().enumerate() {
                let Some(s) = r.steps.get(idx[k]) else { continue };
                if s.t != t {
                    continue;
                }
                idx[k] += 1;
                if let Some((addr, _)) = s.eff.write {
                    writes.push(addr);
                }
                let id = s.eff.id;
                self.commit_effect(s.eff.clone(), t);
                debug_assert!(self.cores[id].run == RunState::Idle && self.fault.is_none());
                self.cores[id].run =
                    RunState::Exec { insn: s.fetch.insn, apply_at: s.fetch.apply_at };
                if s.fetch.bus_access {
                    fetches.push(id);
                }
            }
            // Pass 2b — replay the staged bus charges in lockstep's
            // phase-D grant order: the serial fetch worklist is pushed
            // ascending and drained LIFO, so within one clock charges
            // land in descending core index. `fetches` is ascending, so
            // iterate it reversed. A stalled charge shifts that core's
            // apply time by the queueing delay (the serial fetch folds
            // the delay into `apply_at`) and poisons every later
            // speculated clock of its chain — truncate after `t`.
            let mut stalled = false;
            for &id in fetches.iter().rev() {
                let delay = self.bus.replay_access(t);
                if delay > 0 {
                    stalled = true;
                    if let RunState::Exec { apply_at, .. } = &mut self.cores[id].run {
                        *apply_at += delay;
                    }
                }
            }
            if stalled {
                self.bus_replay_truncations += 1;
            }
            if stalled || final_stream {
                // Stall-shifted apply times and a freshly armed readout
                // (`done_at = t + sv_readout`) both invalidate the
                // speculation beyond this clock: later clocks must be
                // re-planned from serial state.
                e_trunc = t + 1;
                break;
            }
        }
        self.span_writes = writes;
        if e_trunc <= h {
            return; // nothing committed; serial stepping continues at h
        }
        // Account the window exactly as `advance_to` + per-tick busy
        // accounting would have: allocation is frozen inside the window,
        // so every rented core accrues the whole span.
        let n = e_trunc - h;
        let mut bits = self.rented_mask;
        while bits != 0 {
            let id = bits.trailing_zeros() as usize;
            bits &= bits - 1;
            self.cores[id].busy_clocks += n;
        }
        self.clock = e_trunc;
        self.clocks_skipped += n;
        self.batched_clocks += n;
        if ported {
            self.batched_ported_clocks += n;
        }
        if engine_active {
            self.engine_batched_clocks += n;
        }
        self.span_batch_hist[span_bucket(n as usize)] += 1;
        self.parallel_spans += 1;
        self.parallel_cores += ntasks as u64;
        self.span_hist[span_bucket(ntasks)] += 1;
    }

    /// A `%pp` write by a SUMUP child streams into the parent adder
    /// (§5.2: "executing addl to a special pseudo register ... triggers
    /// transferring to FromChild in the parent"). Shared by the serial
    /// apply and the parallel commit; outside mass mode the latch write
    /// alone suffices and nothing happens here.
    fn stream_to_parent(&mut self, id: usize, v: i32, now: u64) {
        let Some(parent) = self.cores[id].parent else { return };
        if self.sv.sum_stream(parent, v, now, self.timing.sv_readout) {
            self.trace.push(now, id, Event::Stream { value: v });
            self.sv.ops += 1;
        }
    }

    // ------------------------------------------------------------------
    // fetch (phase D)
    // ------------------------------------------------------------------

    fn fetch(&mut self, id: usize, now: u64, worklist: &mut Vec<usize>) {
        // A core may pass through several combinational engine intercepts
        // in one clock (qterm → relaunch → real fetch); bound the loop.
        for _ in 0..8 {
            let pc = self.cores[id].pc;
            let insn = match self.decode_cached(pc) {
                Some(i) => i,
                None => {
                    self.fault = Some(format!("core {id}: invalid instruction at {pc:#x}"));
                    return;
                }
            };
            match insn {
                // -- engine-intercepted child termination (zero cost) ----
                Insn::Meta { meta: MetaFn::QTerm, .. } if self.sv.engine_of_child(id).is_some() => {
                    if self.for_engine_iter_done(id, now, worklist) {
                        continue; // relaunched: fetch body insn this tick
                    }
                    return; // engine done or child released
                }
                Insn::Meta { meta: MetaFn::QTerm, .. }
                    if self.cores[id].parent.is_some()
                        && self.parent_engine_mode(id) == Some(MassMode::Sum) =>
                {
                    self.sum_child_release(id, now);
                    return;
                }
                // -- halt: the SV blocks parent termination until the
                //    children mask clears (§4.3) -------------------------
                Insn::Halt => {
                    if self.cores[id].children != 0 || self.sv.parent_engine_active(id) {
                        self.cores[id].run = RunState::Blocked(BlockReason::HaltPending);
                        self.trace.push(now, id, Event::Block { why: "halt/children" });
                        return;
                    }
                }
                // -- qwait blocks combinationally while children run -----
                Insn::Meta { meta: MetaFn::QWait, ra, .. } => {
                    if self.cores[id].children != 0 || self.sv.parent_engine_active(id) {
                        self.cores[id].run =
                            RunState::Blocked(BlockReason::WaitChildren { drain_to: (ra != Reg::None).then_some(ra) });
                        self.trace.push(now, id, Event::Block { why: "qwait" });
                        return;
                    }
                }
                _ => {}
            }
            // -- ordinary issue: charge latency, apply later -------------
            let cost = match insn {
                Insn::Meta { meta, .. } => {
                    self.sv.ops += 1;
                    self.timing.meta_dispatch + self.timing.meta_cost(meta)
                }
                Insn::MrMov { .. } | Insn::RmMov { .. } => {
                    self.timing.insn_cost(&insn) + self.bus.access(now)
                }
                _ => self.timing.insn_cost(&insn),
            };
            self.cores[id].run = RunState::Exec { insn, apply_at: now + cost };
            return;
        }
        self.fault = Some(format!("core {id}: combinational intercept loop at {:#x}", self.cores[id].pc));
    }

    /// Decode through the direct-mapped cache. An entry hits only when
    /// both its pc and its full memory version match — a wrapped or
    /// truncated version can never validate a stale entry. Fetches whose
    /// 6-byte decode window could reach the *data region* (at or above
    /// the code limit) bypass the cache entirely: writes there no longer
    /// bump the version, so both a guest that executes from its data
    /// segment and an instruction whose operand bytes straddle the
    /// boundary must always decode the live bytes. Cached entries thus
    /// only ever cover windows fully below the limit, where every write
    /// is version-visible.
    #[inline]
    fn decode_cached(&mut self, pc: u32) -> Option<Insn> {
        if pc >= self.mem.code_limit().saturating_sub(5) {
            self.icache_misses += 1;
            return Insn::decode(self.mem.fetch_window(pc)).map(|(i, _len)| i);
        }
        let version = self.mem.version();
        let slot = (pc as usize) & (self.icache.len() - 1);
        let (cpc, cver, insn) = self.icache[slot];
        if cpc == pc && cver == version {
            self.icache_hits += 1;
            return Some(insn);
        }
        self.icache_misses += 1;
        let (insn, _len) = Insn::decode(self.mem.fetch_window(pc))?;
        self.icache[slot] = (pc, version, insn);
        Some(insn)
    }

    fn parent_engine_mode(&self, child: usize) -> Option<MassMode> {
        let parent = self.cores[child].parent?;
        let slot = self.sv.engine_of_parent(parent)?;
        self.sv.get(slot).map(|e| e.mode)
    }

    // ------------------------------------------------------------------
    // apply (phase A)
    // ------------------------------------------------------------------

    fn apply(&mut self, id: usize, insn: Insn, now: u64) {
        self.cores[id].retired += 1;
        if let Insn::Meta { meta, ra, value, .. } = insn {
            self.apply_meta(id, meta, ra, value, now);
            return;
        }
        // Execute through the shared Y86 semantics with this core's
        // latch-backed pseudo-register port.
        let mut streamed: Option<i32> = None;
        let effect = {
            let core = &mut self.cores[id];
            let mut port = LatchPort { latch: &mut core.latch, streamed: &mut streamed };
            execute(&insn, core.pc, &mut core.regs, &mut self.mem, &mut port)
        };
        if let Some(v) = streamed {
            self.stream_to_parent(id, v, now);
        }
        match effect {
            ExecEffect::Continue { next_pc } => {
                self.cores[id].pc = next_pc;
                self.cores[id].run = RunState::Idle;
            }
            ExecEffect::Stop(Status::Hlt) => {
                if id == self.root {
                    self.cores[id].run = RunState::Halted;
                    self.halted = true;
                    self.halt_at = now;
                    self.trace.push(now, id, Event::Halt);
                } else {
                    self.fault = Some(format!("core {id}: halt inside a QT (use qterm)"));
                }
            }
            ExecEffect::Stop(s) => {
                self.fault = Some(format!("core {id}: stopped with {s:?} at {:#x}", self.cores[id].pc));
            }
        }
    }

    fn apply_meta(&mut self, id: usize, meta: MetaFn, ra: Reg, value: u32, now: u64) {
        let next_pc = self.cores[id].pc + Insn::Meta { meta, ra, rb: Reg::None, value }.len() as u32;
        match meta {
            MetaFn::QPreAlloc => {
                let want = value as usize;
                let mut got = 0;
                for cid in 0..self.cores.len() {
                    if got == want {
                        break;
                    }
                    if cid != id && self.cores[cid].available(now) {
                        self.cores[cid].alloc = AllocState::PreAllocatedBy { parent: id };
                        let m = self.cores[cid].mask();
                        self.cores[id].prealloc |= m;
                        got += 1;
                        self.trace.push(now, cid, Event::PreAlloc { parent: id });
                    }
                }
                // Renting fewer than requested is not fatal: the engines
                // fall back to pool renting / waiting.
                self.cores[id].pc = next_pc;
                self.cores[id].run = RunState::Idle;
            }
            MetaFn::QCreate | MetaFn::QCall => {
                // qcreate Lcont: child body = next insn, parent resumes at Lcont.
                // qcall  Lsub : child body = Lsub,     parent resumes at next.
                let (body, cont) = if meta == MetaFn::QCreate { (next_pc, value) } else { (value, next_pc) };
                match self.rent_for(id, now) {
                    Some(child) => {
                        self.launch_child(id, child, body, now);
                        self.cores[id].pc = cont;
                        self.cores[id].run = RunState::Idle;
                    }
                    None => {
                        // Emergency mechanism (§3.3): "the cores can suspend
                        // processing their own QTs, borrowing their own
                        // resources to their child-QTs".
                        self.cores[id].borrow_stack.push(cont);
                        self.cores[id].pc = body;
                        self.cores[id].run = RunState::Idle;
                        self.trace.push(now, id, Event::Borrow { body });
                    }
                }
            }
            MetaFn::QTerm => {
                if let Some(cont) = self.cores[id].borrow_stack.pop() {
                    // End of an inlined (borrowed) QT: deliver own latch to
                    // own FromChild, resume the suspended QT.
                    if ra != Reg::None {
                        let v = self.cores[id].regs.get(ra).unwrap_or(0);
                        self.cores[id].latch.from_child = Some(v);
                    } else if let Some(v) = self.cores[id].latch.for_parent.take() {
                        self.cores[id].latch.from_child = Some(v);
                    }
                    self.cores[id].pc = cont;
                    self.cores[id].run = RunState::Idle;
                    return;
                }
                if id == self.root {
                    self.fault = Some("root QT executed qterm (use halt)".to_string());
                    return;
                }
                if self.cores[id].parent.is_none() {
                    // Reserved interrupt core finished its handler: log the
                    // service and re-park, re-armed (§3.6) — no state to
                    // save or restore, the payload cores never noticed.
                    if let Some(raised) = self.irq_inflight[id].take() {
                        self.irq_log.push((raised, now));
                    }
                    let handler = self.cores[id].offset;
                    self.cores[id].reset_for_qt(handler);
                    self.cores[id].run = RunState::Blocked(BlockReason::IrqWait);
                    self.trace.push(now, id, Event::Block { why: "irq re-arm" });
                    return;
                }
                self.terminate_child(id, ra, now);
            }
            MetaFn::QWait => {
                // children already clear (checked at fetch); drain latch.
                if ra != Reg::None {
                    if let Some(v) = self.cores[id].latch.from_child.take() {
                        let _ = self.cores[id].regs.set(ra, v);
                    }
                }
                self.cores[id].pc = next_pc;
                self.cores[id].run = RunState::Idle;
            }
            MetaFn::QCopy => {
                // Forwarding: input latch → output latch (§4.6).
                let v = self.cores[id].latch.from_parent;
                self.cores[id].latch.for_parent = v;
                self.cores[id].pc = next_pc;
                self.cores[id].run = RunState::Idle;
            }
            MetaFn::QMassFor | MetaFn::QMassSum => {
                let mode = if meta == MetaFn::QMassFor { MassMode::For } else { MassMode::Sum };
                let core = &self.cores[id];
                let count = core.regs.file[Reg::Edx as usize].max(0) as u32;
                let addr = core.regs.file[Reg::Ecx as usize];
                let acc = core.regs.file[Reg::Eax as usize];
                let engine = MassEngine::new(
                    mode,
                    id,
                    value,
                    addr,
                    count,
                    acc,
                    now,
                    self.timing.sv_stagger,
                    self.timing.sv_readout,
                );
                self.sv.add(engine);
                self.sv.ops += 1;
                self.cores[id].pc = next_pc;
                self.cores[id].run = RunState::Blocked(BlockReason::MassEngine);
                self.trace.push(now, id, Event::MassStart { mode, count });
            }
        }
    }

    // ------------------------------------------------------------------
    // child lifecycle
    // ------------------------------------------------------------------

    /// Rent a core for `parent`: preallocated cores first, then the pool.
    fn rent_for(&mut self, parent: usize, now: u64) -> Option<usize> {
        self.rent_prealloc(parent, now)
            .or_else(|| (0..self.cores.len()).find(|&cid| cid != parent && self.cores[cid].available(now)))
    }

    /// A free core from `parent`'s preallocated set.
    fn rent_prealloc(&mut self, parent: usize, now: u64) -> Option<usize> {
        let prealloc = self.cores[parent].prealloc;
        (0..self.cores.len()).find(|&cid| {
            let c = &self.cores[cid];
            c.available_at <= now
                && match c.alloc {
                    AllocState::PreAllocatedBy { parent: p } => p == parent && prealloc & c.mask() != 0,
                    _ => false,
                }
        })
    }

    /// Rent for a mass engine: §5.1's preallocation guarantee is also the
    /// compiler's cap (§6.2 — "it should not allocate more than that
    /// number of cores"), so an engine whose parent preallocated cores
    /// waits for one of *those* to free instead of raiding the pool. Only
    /// a parent with no preallocation at all falls back to the pool.
    fn rent_for_mass(&mut self, parent: usize, now: u64) -> Option<usize> {
        if self.cores[parent].prealloc != 0 {
            self.rent_prealloc(parent, now)
        } else {
            self.rent_for(parent, now)
        }
    }

    /// Clone the parent's glue into `child` and enable it at `body`
    /// (§4.4: "the child core commences its life after it received the
    /// needed data").
    fn launch_child(&mut self, parent: usize, child: usize, body: u32, now: u64) {
        let glue = self.cores[parent].regs.clone();
        let handoff = self.cores[parent].latch.for_child.take();
        self.rented_mask |= 1u64 << child;
        let c = &mut self.cores[child];
        c.alloc = AllocState::Rented;
        c.reset_for_qt(body);
        c.regs = glue;
        c.parent = Some(parent);
        c.latch.from_parent = handoff;
        let m = c.mask();
        self.cores[parent].children |= m;
        self.sv.ops += 1;
        self.trace.push(now, child, Event::Launch { parent, body });
    }

    /// Ordinary (non-engine) child termination: clone-back, clear masks,
    /// return the core to the pool.
    fn terminate_child(&mut self, id: usize, link: Reg, now: u64) {
        let parent = self.cores[id].parent.expect("child has parent");
        // Clone-back: explicit link register wins, else a pending %pp write.
        let value = if link != Reg::None {
            self.cores[id].regs.get(link)
        } else {
            self.cores[id].latch.for_parent.take()
        };
        if let Some(v) = value {
            self.cores[parent].latch.from_child = Some(v);
        }
        let m = self.cores[id].mask();
        self.cores[parent].children &= !m;
        self.cores[parent].prealloc &= !m;
        let c = &mut self.cores[id];
        c.alloc = AllocState::Free;
        c.parent = None;
        c.run = RunState::Terminated;
        c.available_at = now;
        self.sv.ops += 1;
        self.trace.push(now, id, Event::Term { parent });
    }

    // ------------------------------------------------------------------
    // mass engines
    // ------------------------------------------------------------------

    fn engines_tick(&mut self, now: u64) {
        for eidx in 0..self.sv.slot_count() {
            let Some((mode, parent, finished)) =
                self.sv.get(eidx).map(|e| (e.mode, e.parent, e.finished))
            else {
                continue; // reaped slot
            };
            if finished {
                continue;
            }
            // finalise?
            if let Some(done_at) = self.sv.get(eidx).expect("live slot").done_at {
                if done_at <= now {
                    self.finalize_engine(eidx, now);
                    continue;
                }
            }
            match mode {
                MassMode::Sum => {
                    // Launch one due child per SV tick (§4.1.3: the SV is
                    // sequential — one allocation at a time).
                    let due = {
                        let e = self.sv.get(eidx).expect("live slot");
                        e.remaining > 0 && e.next_launch_at <= now
                    };
                    if due {
                        if let Some(child) = self.rent_for_mass(parent, now) {
                            let (body, addr) = {
                                let e = self.sv.get_mut(eidx).expect("live slot");
                                let a = e.addr;
                                e.addr = e.addr.wrapping_add(4);
                                e.remaining -= 1;
                                e.next_launch_at = now + self.timing.sv_stagger;
                                (e.body, a)
                            };
                            self.launch_child(parent, child, body, now);
                            self.cores[child].regs.file[Reg::Ecx as usize] = addr;
                        }
                    }
                }
                MassMode::For => {
                    // First launch only; iterations relaunch combinationally
                    // at the child's qterm.
                    let due = {
                        let e = self.sv.get(eidx).expect("live slot");
                        e.child.is_none() && e.remaining > 0 && e.next_launch_at <= now
                    };
                    if due {
                        let Some(child) = self.rent_for_mass(parent, now) else { continue };
                        self.sv.set_child(eidx, Some(child));
                        let (body, addr, acc) = {
                            let e = self.sv.get(eidx).expect("live slot");
                            (e.body, e.addr, e.acc)
                        };
                        self.launch_child(parent, child, body, now);
                        self.cores[child].regs.file[Reg::Ecx as usize] = addr;
                        self.cores[child].regs.file[Reg::Eax as usize] = acc;
                    }
                }
            }
        }
        self.sv.reap();
    }

    /// FOR engine: one iteration finished (child fetched `qterm`).
    /// Returns true when the child was relaunched (caller refetches).
    fn for_engine_iter_done(&mut self, child: usize, now: u64, worklist: &mut Vec<usize>) -> bool {
        let eidx = self.sv.engine_of_child(child).expect("engine of child");
        let parent = self.sv.get(eidx).expect("live slot").parent;
        // Clone back the partial sum (§5.1: "the new partial sum is cloned
        // back to the parent also in %eax").
        let partial = self.cores[child].regs.file[Reg::Eax as usize];
        {
            let e = self.sv.get_mut(eidx).expect("live slot");
            e.acc = partial;
            e.remaining -= 1;
            e.addr = e.addr.wrapping_add(4);
        }
        self.sv.ops += 1;
        if self.sv.get(eidx).expect("live slot").remaining > 0 {
            // Relaunch on the same rented child, same clock: the SV's
            // combinational termination+restart (§3.4).
            let (body, addr, acc) = {
                let e = self.sv.get(eidx).expect("live slot");
                (e.body, e.addr, e.acc)
            };
            let glue = self.cores[parent].regs.clone();
            let c = &mut self.cores[child];
            c.regs = glue;
            c.regs.file[Reg::Ecx as usize] = addr;
            c.regs.file[Reg::Eax as usize] = acc;
            c.pc = body;
            c.run = RunState::Idle;
            self.trace.push(now, child, Event::Relaunch { iteration_addr: addr });
            true
        } else {
            // Engine complete: release the child back to preallocation,
            // deliver results, unblock the parent this clock.
            let m = self.cores[child].mask();
            self.cores[parent].children &= !m;
            let c = &mut self.cores[child];
            c.alloc = AllocState::PreAllocatedBy { parent };
            c.parent = None;
            c.run = RunState::Terminated;
            c.available_at = now;
            self.sv.set_child(eidx, None);
            self.sv.get_mut(eidx).expect("live slot").done_at = Some(now);
            self.finalize_engine(eidx, now);
            worklist.push(parent);
            false
        }
    }

    /// SUMUP child fetched its `qterm`: release the core back to the
    /// parent's preallocated set; put-back administration keeps it
    /// unavailable for `sumup_rent_overhead` clocks (the §6.2 rent period
    /// that caps useful children at 30).
    fn sum_child_release(&mut self, id: usize, now: u64) {
        let parent = self.cores[id].parent.expect("sum child has parent");
        let m = self.cores[id].mask();
        self.cores[parent].children &= !m;
        let c = &mut self.cores[id];
        c.alloc = AllocState::PreAllocatedBy { parent };
        c.parent = None;
        c.run = RunState::Terminated;
        c.available_at = now + self.timing.sumup_rent_overhead;
        self.sv.ops += 1;
        self.trace.push(now, id, Event::Term { parent });
    }

    /// Deliver engine results to the parent and unblock it.
    fn finalize_engine(&mut self, eidx: usize, now: u64) {
        let (parent, acc, addr, mode) = {
            let e = self.sv.get(eidx).expect("live slot");
            (e.parent, e.acc, e.addr, e.mode)
        };
        self.sv.finish(eidx);
        let p = &mut self.cores[parent];
        // Leave the architectural state as the conventional loop would:
        // %eax = sum, %ecx = one past the vector, %edx = 0.
        p.regs.file[Reg::Eax as usize] = acc;
        p.regs.file[Reg::Ecx as usize] = addr;
        p.regs.file[Reg::Edx as usize] = 0;
        if p.run == RunState::Blocked(BlockReason::MassEngine) {
            p.run = RunState::Idle;
        }
        self.sv.ops += 1;
        self.trace.push(now, parent, Event::MassDone { mode, sum: acc });
    }
}

/// Histogram bucket of a parallel span of `n` cores (`n >= 2`):
/// 2, 3, 4, 5–8, 9–16, 17+.
fn span_bucket(n: usize) -> usize {
    match n {
        0..=2 => 0,
        3 => 1,
        4 => 2,
        5..=8 => 3,
        9..=16 => 4,
        _ => 5,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::assemble;
    use crate::workload::sumup;

    #[test]
    fn icache_cannot_validate_stale_entries_after_version_wrap() {
        // Regression for the packed-tag hazard: with
        // `tag = pc << 24 | version & 0xFFFFFF`, a version advanced by
        // exactly 2^24 writes produced the same tag, so a stale decode of
        // self-modified code validated. The (pc, full version) tag must
        // decode the new bytes.
        let mut p = EmpaProcessor::new(&[0x00], &EmpaConfig::default()); // halt at 0
        let v0 = p.mem.version();
        assert_eq!(p.decode_cached(0), Some(Insn::Halt));
        p.mem.write_u32(0, 0x1010_1010).unwrap(); // overwrite with nops
        p.mem.force_version(v0 + (1 << 24)); // same low 24 bits as v0
        assert_eq!(p.decode_cached(0), Some(Insn::Nop), "stale entry must not validate");
    }

    #[test]
    fn icache_still_hits_on_unchanged_memory() {
        let mut p = EmpaProcessor::new(&[0x00], &EmpaConfig::default());
        assert_eq!(p.decode_cached(0), Some(Insn::Halt));
        assert_eq!((p.icache_hits, p.icache_misses), (0, 1));
        assert_eq!(p.decode_cached(0), Some(Insn::Halt));
        assert_eq!((p.icache_hits, p.icache_misses), (1, 1), "second fetch hits");
    }

    /// A guest loop that stores every iteration. Without a code limit
    /// every store bumps the version and poisons the whole decode cache;
    /// with the limit set at the program's code extent the loop body
    /// stays cached.
    fn store_heavy_loop() -> crate::isa::Program {
        let src = "    irmovl $64, %edx
    irmovl buf, %ecx
Loop:
    rmmovl %edx, (%ecx)
    irmovl $-1, %edi
    addl %edi, %edx
    jne Loop
    halt
    .align 4
buf:
    .long 0
";
        assemble(src).unwrap()
    }

    #[test]
    fn store_heavy_loops_still_hit_the_icache_with_a_code_limit() {
        let prog = store_heavy_loop();
        let cfg = EmpaConfig::default();

        // Without the boundary: every store invalidates, ~every fetch
        // misses (the perf bug this PR fixes).
        let mut poisoned = EmpaProcessor::new(&prog.image, &cfg);
        let rp = poisoned.run_report();
        assert_eq!(rp.fault, None);
        assert!(
            rp.icache_misses > rp.icache_hits,
            "unbounded versioning decodes on (almost) every fetch: {} hits / {} misses",
            rp.icache_hits,
            rp.icache_misses
        );

        // With the boundary: after the first lap the loop body is
        // entirely cached — misses stay at the handful of distinct PCs.
        let mut fixed = EmpaProcessor::new(&prog.image, &cfg);
        fixed.set_code_limit(prog.code_end);
        let rf = fixed.run_report();
        assert_eq!(rf.fault, None);
        assert_eq!((rf.clocks, rf.regs.file), (rp.clocks, rp.regs.file), "host-only change");
        assert!(
            rf.icache_misses <= 8,
            "store-heavy loop must decode each pc once: {} misses",
            rf.icache_misses
        );
        assert!(rf.icache_hits > 4 * rf.icache_misses, "{rf:?}");
    }

    #[test]
    fn data_region_fetches_always_decode_live_bytes() {
        // Writes at or above the code limit do not bump the version, so
        // a guest that stores instruction bytes into its data segment
        // and executes them must bypass the cache, not hit a stale entry.
        let mut p = EmpaProcessor::new(&[0x00], &EmpaConfig::default());
        p.set_code_limit(0); // the whole address space is "data"
        assert_eq!(p.decode_cached(0), Some(Insn::Halt));
        p.mem.write_u32(0, 0x1010_1010).unwrap(); // no version bump
        assert_eq!(p.decode_cached(0), Some(Insn::Nop), "live bytes, not a stale decode");
        assert_eq!(p.icache_hits, 0, "data-region fetches never hit the cache");
    }

    #[test]
    fn self_modifying_code_still_invalidates_below_the_code_limit() {
        // pc 0 sits well below the boundary's 6-byte guard band, so the
        // fetch genuinely goes through the cache — the store must
        // invalidate via the version, not via a bypass.
        let mut p = EmpaProcessor::new(&[0x00; 16], &EmpaConfig::default());
        p.set_code_limit(16);
        assert_eq!(p.decode_cached(0), Some(Insn::Halt));
        assert_eq!(p.decode_cached(0), Some(Insn::Halt));
        assert_eq!((p.icache_hits, p.icache_misses), (1, 1), "cached path exercised");
        p.mem.write_u32(0, 0x1010_1010).unwrap(); // overwrite with nops
        assert_eq!(p.decode_cached(0), Some(Insn::Nop), "code store invalidates");
    }

    #[test]
    fn boundary_straddling_fetches_bypass_the_cache() {
        // An instruction at pc >= code_limit - 5 could decode operand
        // bytes from the data region, whose writes are version-invisible
        // — such fetches must re-decode live bytes every time.
        let mut p = EmpaProcessor::new(&[0x10; 16], &EmpaConfig::default());
        p.set_code_limit(8);
        assert_eq!(p.decode_cached(3), Some(Insn::Nop), "pc 3 straddles: bypass");
        assert_eq!(p.decode_cached(3), Some(Insn::Nop));
        assert_eq!(p.icache_hits, 0, "straddling fetches never hit");
        assert_eq!(p.decode_cached(2), Some(Insn::Nop), "pc 2's window ends at 8: cached");
        assert_eq!(p.decode_cached(2), Some(Insn::Nop));
        assert_eq!(p.icache_hits, 1);
    }

    #[test]
    fn reset_reusing_is_cycle_identical_and_keeps_the_icache_warm() {
        let cfg = EmpaConfig::default();
        let prog = store_heavy_loop();
        let mut p = EmpaProcessor::new(&prog.image, &cfg);
        p.set_code_limit(prog.code_end);
        let r1 = p.run_report();
        assert_eq!(r1.fault, None);

        p.reset_reusing(&prog.image);
        let r2 = p.run_report();
        assert_eq!(r2.fault, None);
        assert_eq!(r1.clocks, r2.clocks, "reused-image run is cycle-identical");
        assert_eq!(r1.regs.file, r2.regs.file);
        assert_eq!(r1.retired, r2.retired);
        // The previous run only wrote data, so the decode cache survived
        // the reset: the second run re-decodes only the boundary-band
        // fetch (the final `halt` sits within 6 bytes of `code_end` and
        // always bypasses the cache).
        assert!(r2.icache_misses <= 1, "warm decode cache across reuse: {r2:?}");
        assert!(r2.icache_hits >= r1.icache_hits);

        // And memory was genuinely rolled back: the guest observes the
        // template's pristine data (buf reads 0 again before the run).
        p.reset_reusing(&prog.image);
        let buf = prog.symbol("buf").unwrap();
        assert_eq!(p.mem.read_u32(buf).unwrap(), 0, "dirty window restored");
    }

    #[test]
    fn reset_with_reuses_the_processor_across_programs() {
        let cfg = EmpaConfig::default();
        let (src_a, want_a) = sumup::sumup_mode_program(&[1, 2, 3, 4]);
        let (src_b, want_b) = sumup::for_mode_program(&[10, 20, 30]);
        let prog_a = assemble(&src_a).unwrap();
        let prog_b = assemble(&src_b).unwrap();

        // fresh runs, for reference
        let fresh_a = EmpaProcessor::new(&prog_a.image, &cfg).run();
        let fresh_b = EmpaProcessor::new(&prog_b.image, &cfg).run();

        let mut p = EmpaProcessor::new(&prog_a.image, &cfg);
        let r_a = p.run_report();
        assert_eq!(r_a.fault, None);
        assert_eq!(r_a.eax(), want_a);
        assert_eq!(r_a.clocks, fresh_a.clocks);

        p.reset_with(&prog_b.image);
        let r_b = p.run_report();
        assert_eq!(r_b.fault, None);
        assert_eq!(r_b.eax(), want_b);
        assert_eq!(r_b.clocks, fresh_b.clocks, "reset run is cycle-identical to a fresh one");
        assert_eq!(r_b.max_occupied, fresh_b.max_occupied);
        assert_eq!(r_b.retired, fresh_b.retired);
        assert_eq!(r_b.sv_ops, fresh_b.sv_ops);

        // and back to the first program: the reused pool stays clean
        p.reset_with(&prog_a.image);
        let r_a2 = p.run_report();
        assert_eq!(r_a2.fault, None);
        assert_eq!(r_a2.eax(), want_a);
        assert_eq!(r_a2.clocks, fresh_a.clocks);
    }

    #[test]
    fn reset_with_clears_a_faulted_processor() {
        let cfg = EmpaConfig { max_clocks: 200, ..Default::default() };
        let looping = assemble("Loop: jmp Loop\n").unwrap();
        let mut p = EmpaProcessor::new(&looping.image, &cfg);
        let r = p.run_report();
        assert!(r.fault.is_some(), "runaway fault expected");

        let (src, want) = sumup::no_mode_program(&[5, 6]);
        let prog = assemble(&src).unwrap();
        p.reset_with(&prog.image);
        let r = p.run_report();
        assert_eq!(r.fault, None, "fault cleared by reset");
        assert_eq!(r.eax(), want);
    }

    #[test]
    fn config_validation_is_typed_not_a_panic() {
        for bad in [0usize, 65, 1000] {
            let cfg = EmpaConfig { num_cores: bad, ..Default::default() };
            assert_eq!(cfg.validate(), Err(ConfigError::CoreCount { requested: bad }));
            assert_eq!(
                EmpaProcessor::try_new(&[0x00], &cfg).err(),
                Some(ConfigError::CoreCount { requested: bad })
            );
        }
        for good in [1usize, 32, 64] {
            let cfg = EmpaConfig { num_cores: good, ..Default::default() };
            assert!(EmpaProcessor::try_new(&[0x00], &cfg).is_ok());
        }
        assert!(ConfigError::CoreCount { requested: 0 }.to_string().contains("num_cores=0"));
    }

    fn run_in(mode: StepMode, image: &[u8]) -> RunReport {
        let cfg = EmpaConfig { step: mode, ..Default::default() };
        EmpaProcessor::new(image, &cfg).run()
    }

    #[test]
    fn event_horizon_skips_dead_clocks_but_keeps_the_clock_count() {
        let (src, want) = sumup::no_mode_program(&[3, 5, 7, 9]);
        let image = assemble(&src).unwrap().image;
        let lock = run_in(StepMode::Lockstep, &image);
        let eh = run_in(StepMode::EventHorizon, &image);
        assert_eq!(lock.clocks, 142, "Table 1, N=4 NO");
        assert_eq!(eh.clocks, lock.clocks);
        assert_eq!(eh.eax(), want);
        assert_eq!(eh.regs.file, lock.regs.file);
        assert_eq!(eh.retired, lock.retired);
        assert_eq!(lock.clocks_skipped, 0);
        assert_eq!(lock.events_processed, lock.clocks + 1, "lockstep ticks every clock");
        assert!(
            eh.events_processed * 5 <= lock.events_processed,
            "straight-line code bursts: {} events vs {} ticks",
            eh.events_processed,
            lock.events_processed
        );
        assert!((eh.clocks_per_event() - 1.0).abs() > 1.0, "ratio is published");
        assert!((lock.clocks_per_event() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn event_horizon_and_lockstep_agree_on_mass_modes() {
        for (src, want) in [
            sumup::for_mode_program(&[0xd, 0xc0, 0xb00, 0xa000]),
            sumup::sumup_mode_program(&[0xd, 0xc0, 0xb00, 0xa000]),
            sumup::sumup_mode_program(&(0..200).collect::<Vec<i32>>()),
        ] {
            let image = assemble(&src).unwrap().image;
            let lock = run_in(StepMode::Lockstep, &image);
            let eh = run_in(StepMode::EventHorizon, &image);
            assert_eq!(eh.eax(), want);
            assert_eq!(eh.clocks, lock.clocks);
            assert_eq!(eh.max_occupied, lock.max_occupied);
            assert_eq!(eh.distinct_cores, lock.distinct_cores);
            assert_eq!(eh.retired, lock.retired);
            assert_eq!(eh.sv_ops, lock.sv_ops);
            assert!(eh.events_processed < lock.events_processed);
        }
    }

    #[test]
    fn event_horizon_runaway_faults_at_the_same_clock() {
        let looping = assemble("Loop: jmp Loop\n").unwrap();
        let cfg = |mode| EmpaConfig { max_clocks: 333, step: mode, ..Default::default() };
        let lock = EmpaProcessor::new(&looping.image, &cfg(StepMode::Lockstep)).run();
        let eh = EmpaProcessor::new(&looping.image, &cfg(StepMode::EventHorizon)).run();
        assert_eq!(lock.fault, eh.fault);
        assert_eq!(lock.clocks, eh.clocks);
        assert_eq!(lock.clocks, 333);
    }

    #[test]
    fn external_wake_bounds_the_skip() {
        let (src, _) = sumup::no_mode_program(&[1, 2, 3, 4, 5, 6, 7, 8]);
        let image = assemble(&src).unwrap().image;
        let mut p = EmpaProcessor::new(&image, &EmpaConfig::default());
        p.set_external_wake(Some(100));
        let mut visited_100 = false;
        for _ in 0..100_000 {
            if p.clock == 100 {
                visited_100 = true;
                p.set_external_wake(None);
            }
            if matches!(p.cores[0].run, RunState::Halted) {
                break;
            }
            p.step();
        }
        assert!(visited_100, "the scheduler must not skip past an external wake");
    }

    #[test]
    fn reset_with_clears_scheduler_counters() {
        let (src, _) = sumup::no_mode_program(&[1, 2, 3]);
        let prog = assemble(&src).unwrap();
        let mut p = EmpaProcessor::new(&prog.image, &EmpaConfig::default());
        let r1 = p.run_report();
        assert!(r1.events_processed > 0 && r1.clocks_skipped > 0);
        p.reset_with(&prog.image);
        let r2 = p.run_report();
        assert_eq!(r1.events_processed, r2.events_processed);
        assert_eq!(r1.clocks_skipped, r2.clocks_skipped);
        assert_eq!(r1.clocks, r2.clocks);
    }

    #[test]
    fn reset_with_grows_for_large_images_but_never_carries_growth_over() {
        let cfg = EmpaConfig {
            mem: crate::mem::MemConfig { size: 64, ..crate::mem::MemConfig::ideal() },
            ..Default::default()
        };
        let mut p = EmpaProcessor::new(&[0x00], &cfg);
        let _ = p.run_report();
        let big = vec![0x10u8; 128]; // nops past the configured size
        p.reset_with(&big);
        assert!(p.mem.len() >= 128);
        // The next program sees the *configured* address space again: an
        // out-of-bounds access faults exactly as on a fresh processor.
        p.reset_with(&[0x00]);
        assert_eq!(p.mem.len(), 64, "previous growth must not widen later programs");
        assert!(p.mem.read_u32(64).is_err());
    }

    #[test]
    fn host_thread_validation_is_typed() {
        for bad in [0usize, 65, 1000] {
            let cfg =
                EmpaConfig { step: StepMode::ParallelA { threads: bad }, ..Default::default() };
            assert_eq!(cfg.validate(), Err(ConfigError::HostThreads { requested: bad }));
        }
        for good in [1usize, 2, 64] {
            let cfg =
                EmpaConfig { step: StepMode::ParallelA { threads: good }, ..Default::default() };
            assert!(EmpaProcessor::try_new(&[0x00], &cfg).is_ok());
        }
        assert!(ConfigError::HostThreads { requested: 65 }.to_string().contains("threads=65"));
    }

    #[test]
    fn span_buckets_cover_the_ranges() {
        assert_eq!(span_bucket(2), 0);
        assert_eq!(span_bucket(3), 1);
        assert_eq!(span_bucket(4), 2);
        assert_eq!((span_bucket(5), span_bucket(8)), (3, 3));
        assert_eq!((span_bucket(9), span_bucket(16)), (4, 4));
        assert_eq!((span_bucket(17), span_bucket(64)), (5, 5));
    }

    #[test]
    fn parallel_one_thread_is_the_serial_event_horizon_path() {
        let (src, want) = sumup::sumup_mode_program(&[1, 2, 3, 4]);
        let image = assemble(&src).unwrap().image;
        let eh = run_in(StepMode::EventHorizon, &image);
        // Even with a wide batching window configured, threads=1 must
        // remain literally the serial path: no pool, no spans, no
        // batches, identical scheduler iterations.
        for span_batch in [1usize, 16, 64] {
            let cfg = EmpaConfig {
                step: StepMode::ParallelA { threads: 1 },
                span_batch,
                ..Default::default()
            };
            let p1 = EmpaProcessor::new(&image, &cfg).run();
            assert_eq!(p1.eax(), want);
            assert_eq!(p1.clocks, eh.clocks);
            assert_eq!(p1.events_processed, eh.events_processed, "identical scheduler path");
            assert_eq!(p1.clocks_skipped, eh.clocks_skipped);
            assert_eq!(p1.parallel_spans, 0, "no pool is built for threads=1");
            assert_eq!(p1.batched_clocks, 0, "no batches without a pool");
            assert_eq!(p1.span_batch_hist, [0; 6]);
            assert_eq!((p1.host_threads, eh.host_threads), (1, 1));
        }
    }

    #[test]
    fn span_batch_zero_is_a_typed_config_error() {
        let cfg = EmpaConfig { span_batch: 0, ..Default::default() };
        assert_eq!(cfg.validate(), Err(ConfigError::SpanBatch { requested: 0 }));
        assert_eq!(
            EmpaProcessor::try_new(&[0x00], &cfg).err(),
            Some(ConfigError::SpanBatch { requested: 0 })
        );
        assert!(ConfigError::SpanBatch { requested: 0 }.to_string().contains("span_batch=0"));
        for good in [1usize, 16, 4096] {
            let cfg = EmpaConfig { span_batch: good, ..Default::default() };
            assert!(EmpaProcessor::try_new(&[0x00], &cfg).is_ok());
        }
    }

    #[test]
    fn no_mode_span_batch_sweep_stays_cycle_identical() {
        // NO-mode at this size is one long conventional stretch per core:
        // with a window of 64 the batcher should cover most clocks.
        let (src, want) = sumup::no_mode_program(&sumup::synth_vector(64, 5));
        let image = assemble(&src).unwrap().image;
        let lock = run_in(StepMode::Lockstep, &image);
        for span_batch in [1usize, 4, 64] {
            let cfg = EmpaConfig {
                step: StepMode::ParallelA { threads: 2 },
                span_batch,
                ..Default::default()
            };
            let r = EmpaProcessor::new(&image, &cfg).run();
            assert_eq!(r.eax(), want, "span_batch={span_batch}");
            assert_eq!(r.clocks, lock.clocks, "span_batch={span_batch}");
            assert_eq!(r.regs.file, lock.regs.file, "span_batch={span_batch}");
            assert_eq!(r.retired, lock.retired, "span_batch={span_batch}");
            assert_eq!(r.sv_ops, lock.sv_ops, "span_batch={span_batch}");
            assert_eq!(r.bus, lock.bus, "span_batch={span_batch}");
            // every span — single-clock or batched — lands in span_hist;
            // batched ones additionally record their length
            assert_eq!(r.span_hist.iter().sum::<u64>(), r.parallel_spans);
            assert!(r.span_batch_hist.iter().sum::<u64>() <= r.parallel_spans);
            if span_batch == 1 {
                assert_eq!(r.batched_clocks, 0, "span_batch=1 disables batching");
                assert_eq!(r.span_batch_hist, [0; 6]);
            }
        }
    }

    #[test]
    fn mass_mode_span_batch_sweep_stays_cycle_identical() {
        // SUMUP interleaves engine actions (window bounds), %pp streams
        // (chain stoppers) and staggered conventional bodies — the
        // hardest mix for the window rule. Every window length must
        // replay lockstep bit-for-bit.
        let (src, want) = sumup::sumup_mode_program(&sumup::synth_vector(128, 9));
        let image = assemble(&src).unwrap().image;
        let lock = run_in(StepMode::Lockstep, &image);
        for span_batch in [1usize, 4, 64] {
            let cfg = EmpaConfig {
                step: StepMode::ParallelA { threads: 4 },
                span_batch,
                ..Default::default()
            };
            let r = EmpaProcessor::new(&image, &cfg).run();
            assert_eq!(r.eax(), want, "span_batch={span_batch}");
            assert_eq!(r.clocks, lock.clocks, "span_batch={span_batch}");
            assert_eq!(r.regs.file, lock.regs.file, "span_batch={span_batch}");
            assert_eq!(r.retired, lock.retired, "span_batch={span_batch}");
            assert_eq!(r.sv_ops, lock.sv_ops, "span_batch={span_batch}");
            assert_eq!(r.max_occupied, lock.max_occupied, "span_batch={span_batch}");
            if span_batch == 1 {
                assert_eq!(r.batched_clocks, 0, "span_batch=1 never batches");
            }
        }
    }

    #[test]
    fn two_conventional_chains_batch_multiple_clocks() {
        // Root runs a straight ALU line to `halt`; a hand-rented second
        // core spins a conventional loop. No engine, no metas, no IRQs —
        // the window rule has nothing to bound it except the root's
        // eventual halt fetch, so multi-clock batches are structural.
        let src = "    irmovl $1, %ebx
    irmovl $0, %eax
    addl %ebx, %eax
    addl %ebx, %eax
    addl %ebx, %eax
    addl %ebx, %eax
    addl %ebx, %eax
    addl %ebx, %eax
    addl %ebx, %eax
    addl %ebx, %eax
    halt
Side:
    irmovl $2, %ecx
Spin:
    addl %ecx, %edx
    addl %ecx, %edx
    addl %ecx, %edx
    jmp Spin
";
        let prog = assemble(src).unwrap();
        let side = prog.symbol("Side").unwrap();
        let run = |step, span_batch| {
            let cfg = EmpaConfig { num_cores: 4, step, span_batch, ..Default::default() };
            let mut p = EmpaProcessor::new(&prog.image, &cfg);
            p.cores[1].alloc = AllocState::Rented;
            p.cores[1].reset_for_qt(side);
            p.rented_mask |= 0b10;
            let r = p.run_report();
            let busy: Vec<u64> = p.cores.iter().map(|c| c.busy_clocks).collect();
            (r, busy)
        };
        let (lock, lock_busy) = run(StepMode::Lockstep, 16);
        assert_eq!(lock.fault, None, "the root halt ends the run");
        for span_batch in [1usize, 4, 64] {
            let (r, busy) = run(StepMode::ParallelA { threads: 2 }, span_batch);
            assert_eq!(r.clocks, lock.clocks, "span_batch={span_batch}");
            assert_eq!(r.regs.file, lock.regs.file, "span_batch={span_batch}");
            assert_eq!(r.retired, lock.retired, "span_batch={span_batch}");
            assert_eq!(busy, lock_busy, "span_batch={span_batch}: integrated occupancy");
            if span_batch == 1 {
                assert_eq!(r.batched_clocks, 0);
            } else {
                assert!(
                    r.batched_clocks > 0,
                    "span_batch={span_batch}: two unbounded conventional chains must batch"
                );
                assert!(r.span_batch_hist.iter().sum::<u64>() > 0);
            }
        }
    }

    #[test]
    fn ported_bus_batches_with_replayed_charges() {
        // The PR-9 gate lift: two conventional chains — one of them
        // loading through the single shared bus every loop iteration —
        // must still form multi-clock batches, with the in-window bus
        // charges replayed at commit. The accesses are spaced wider than
        // the 4-cycle port hold, so the ledger must close with zero
        // stalls and zero replay truncations.
        let src = "    irmovl $1, %ebx
    irmovl $0, %eax
    addl %ebx, %eax
    addl %ebx, %eax
    addl %ebx, %eax
    addl %ebx, %eax
    addl %ebx, %eax
    addl %ebx, %eax
    addl %ebx, %eax
    addl %ebx, %eax
    halt
Side:
    irmovl $0x80, %ecx
Spin:
    mrmovl (%ecx), %edx
    addl %edx, %esi
    jmp Spin
";
        let prog = assemble(src).unwrap();
        let side = prog.symbol("Side").unwrap();
        let run = |step, span_batch| {
            let cfg = EmpaConfig {
                num_cores: 4,
                mem: crate::mem::MemConfig::single_bus(),
                step,
                span_batch,
                ..Default::default()
            };
            let mut p = EmpaProcessor::new(&prog.image, &cfg);
            p.cores[1].alloc = AllocState::Rented;
            p.cores[1].reset_for_qt(side);
            p.rented_mask |= 0b10;
            let r = p.run_report();
            let busy: Vec<u64> = p.cores.iter().map(|c| c.busy_clocks).collect();
            (r, busy)
        };
        let (lock, lock_busy) = run(StepMode::Lockstep, 64);
        assert_eq!(lock.fault, None, "the root halt ends the run");
        assert!(lock.bus.accesses > 0, "the side loop loads through the bus");
        let (r, busy) = run(StepMode::ParallelA { threads: 2 }, 64);
        assert_eq!(r.clocks, lock.clocks);
        assert_eq!(r.regs.file, lock.regs.file);
        assert_eq!(r.retired, lock.retired);
        assert_eq!(busy, lock_busy);
        assert_eq!(r.bus, lock.bus, "replayed charges keep the ledger bit-identical");
        assert!(r.batched_clocks > 0, "the ported bus no longer gates batching off");
        assert_eq!(r.batched_ported_clocks, r.batched_clocks, "every window ran ported");
        assert_eq!(r.bus_replay_truncations, 0, "spaced accesses never stall");
    }

    #[test]
    fn sumup_on_single_bus_batches_and_stays_identical() {
        // The old gate made this configuration fall back to single-clock
        // spans; now the full SUMUP run — staggered children all loading
        // their element through one contended port — batches wherever
        // the window rule allows and must stay cycle-identical anyway.
        let (src, want) = sumup::sumup_mode_program(&sumup::synth_vector(64, 11));
        let image = assemble(&src).unwrap().image;
        let base = crate::mem::MemConfig::single_bus();
        let lock_cfg =
            EmpaConfig { mem: base.clone(), step: StepMode::Lockstep, ..Default::default() };
        let lock = EmpaProcessor::new(&image, &lock_cfg).run();
        let par_cfg = EmpaConfig {
            mem: base,
            step: StepMode::ParallelA { threads: 4 },
            span_batch: 64,
            ..Default::default()
        };
        let r = EmpaProcessor::new(&image, &par_cfg).run();
        assert_eq!(r.eax(), want);
        assert_eq!(r.clocks, lock.clocks);
        assert_eq!(r.regs.file, lock.regs.file);
        assert_eq!(r.retired, lock.retired);
        assert_eq!(r.sv_ops, lock.sv_ops);
        assert_eq!(r.bus, lock.bus, "the bus ledger stays bit-identical");
        assert_eq!(r.batched_ported_clocks, r.batched_clocks);
    }

    #[test]
    fn same_clock_store_load_conflict_commits_in_core_index_order() {
        // Hand-built span: core 0 stores 77 → 0x40 while core 1 loads
        // 0x40, both retiring on the same clock. Serial order says the
        // load sees the store; the speculated load read the pre-phase
        // bytes and must be detected and re-executed.
        let setup = |step| {
            let cfg = EmpaConfig { num_cores: 4, step, ..Default::default() };
            let mut p = EmpaProcessor::new(&[0x00; 16], &cfg);
            p.cores[0].regs.file[Reg::Esi as usize] = 77;
            p.cores[0].regs.file[Reg::Ecx as usize] = 0x40;
            p.cores[0].run = RunState::Exec {
                insn: Insn::RmMov { ra: Reg::Esi, rb: Reg::Ecx, disp: 0 },
                apply_at: 0,
            };
            p.cores[1].alloc = AllocState::Rented;
            p.cores[1].regs.file[Reg::Ecx as usize] = 0x40;
            p.cores[1].run = RunState::Exec {
                insn: Insn::MrMov { ra: Reg::Eax, rb: Reg::Ecx, disp: 0 },
                apply_at: 0,
            };
            p.rented_mask |= 0b10;
            p.tick();
            p
        };
        let lock = setup(StepMode::Lockstep);
        let par = setup(StepMode::ParallelA { threads: 2 });
        assert_eq!(par.parallel_spans, 1);
        assert_eq!(par.span_conflicts, 1, "the load overlapped the earlier store");
        assert_eq!(par.span_hist, [1, 0, 0, 0, 0, 0]);
        assert_eq!(par.cores[1].regs.file[0], 77, "serial order: the load sees the store");
        for (a, b) in lock.cores.iter().zip(&par.cores) {
            assert_eq!(a.regs, b.regs, "core {} regs", a.id);
            assert_eq!((a.pc, a.run, a.retired), (b.pc, b.run, b.retired));
        }
        assert_eq!(lock.mem.read_u32(0x40).unwrap(), 77);
        assert_eq!(par.mem.read_u32(0x40).unwrap(), 77);
        assert_eq!(par.mem.version(), lock.mem.version(), "commit writes bump the version too");
    }

    #[test]
    fn parallel_sumup_fans_out_and_stays_cycle_identical() {
        let (src, want) = sumup::sumup_mode_program(&(0..64).collect::<Vec<i32>>());
        let image = assemble(&src).unwrap().image;
        let lock = run_in(StepMode::Lockstep, &image);
        for threads in [2usize, 4] {
            let par = run_in(StepMode::ParallelA { threads }, &image);
            assert_eq!(par.eax(), want);
            assert_eq!(par.clocks, lock.clocks);
            assert_eq!(par.regs.file, lock.regs.file);
            assert_eq!(par.retired, lock.retired);
            assert_eq!(par.sv_ops, lock.sv_ops);
            assert_eq!(par.max_occupied, lock.max_occupied);
            assert_eq!(par.distinct_cores, lock.distinct_cores);
            assert!(par.parallel_spans > 0, "staggered SUMUP children collide: {par:?}");
            assert_eq!(par.span_hist.iter().sum::<u64>(), par.parallel_spans);
            assert!(par.cores_per_span() >= 2.0);
            assert_eq!(par.host_threads, threads);
        }
        assert!((lock.cores_per_span() - 0.0).abs() < 1e-12, "serial modes never span");
    }

    #[test]
    fn reset_keeps_the_pool_but_clears_span_counters() {
        let (src, _) = sumup::sumup_mode_program(&(0..32).collect::<Vec<i32>>());
        let prog = assemble(&src).unwrap();
        let cfg = EmpaConfig { step: StepMode::ParallelA { threads: 2 }, ..Default::default() };
        let mut p = EmpaProcessor::new(&prog.image, &cfg);
        let r1 = p.run_report();
        assert!(r1.parallel_spans > 0);
        p.reset_with(&prog.image);
        assert!(p.pool.is_some(), "the worker pool survives reuse");
        let r2 = p.run_report();
        assert_eq!(r1.clocks, r2.clocks);
        assert_eq!(r1.parallel_spans, r2.parallel_spans, "counters restart per run");
        assert_eq!(r1.span_hist, r2.span_hist);
    }
}
