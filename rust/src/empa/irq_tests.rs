//! In-simulator interrupt servicing tests (§3.6): a reserved core wakes
//! on the line, runs its handler QT, re-parks — while the payload program
//! runs to completion in exactly its undisturbed time.

use super::{BlockReason, EmpaConfig, EmpaProcessor, RunState};
use crate::isa::assemble;
use crate::workload::sumup;

/// Payload (sumup over 6 elements) + a handler QT that counts services
/// into a mailbox word.
fn program_with_handler() -> (crate::isa::Program, u32) {
    let values = [1, 2, 3, 4, 5, 6];
    let (mut src, _) = sumup::sumup_mode_program(&values);
    src.push_str(
        "\nHandler:\n    mrmovl (%ebp), %edi   # mailbox++\n    irmovl $1, %ebx\n    addl %ebx, %edi\n    rmmovl %edi, (%ebp)\n    qterm\n",
    );
    src.push_str("    .align 4\nmailbox:\n    .long 0\n");
    let prog = assemble(&src).unwrap();
    let mailbox = prog.symbol("mailbox").unwrap();
    (prog, mailbox)
}

fn run_with_irqs(raise_at: &[u64]) -> (EmpaProcessor, u64, u32) {
    let (prog, mailbox) = program_with_handler();
    let handler = prog.symbol("Handler").unwrap();
    let mut p = EmpaProcessor::new(&prog.image, &EmpaConfig::default());
    let irq_core = p.reserve_irq_core(handler).expect("reserve");
    // Handler uses %ebp as the mailbox pointer: preload the parked core.
    p.cores[irq_core].regs.file[crate::isa::Reg::Ebp as usize] = mailbox as i32;
    let mut raises = raise_at.to_vec();
    let mut halt_clock = 0u64;
    for _ in 0..100_000 {
        if let Some(pos) = raises.iter().position(|&t| t == p.clock) {
            raises.remove(pos);
            assert!(p.raise_irq(irq_core), "line busy at {}", p.clock);
            // re-arm %ebp for the next service (reset_for_qt clears latches
            // but the register file persists — set it once more for safety)
            p.cores[irq_core].regs.file[crate::isa::Reg::Ebp as usize] = mailbox as i32;
        }
        p.tick();
        if matches!(p.cores[0].run, RunState::Halted) && halt_clock == 0 {
            halt_clock = p.clock;
        }
        if halt_clock != 0 && raises.is_empty() && p.irq_log.len() >= raise_at.len() {
            break;
        }
    }
    (p, halt_clock, mailbox)
}

#[test]
fn payload_time_is_untouched_by_interrupts() {
    // sumup N=6 takes 38 clocks undisturbed (Table 1). Firing interrupts
    // mid-run must not change that: "the processor need not be stolen
    // from the running main process" (§7).
    // handler service takes ~26 clocks, so space the raises past it
    let (p, halt_clock, _) = run_with_irqs(&[5, 35]);
    assert!(matches!(p.cores[0].run, RunState::Halted));
    // sumup N=6 completes at 38 clocks (Table 1); the +1 is the tick in
    // which the halt's retirement becomes observable to this driver.
    assert!(halt_clock <= 39, "payload delayed: {halt_clock}");
    assert_eq!(p.irq_log.len(), 2);
    assert!(p.irq_inflight_empty());
}

#[test]
fn handler_actually_runs_and_counts() {
    let (p, _, mailbox) = run_with_irqs(&[5, 50, 90, 130]);
    assert_eq!(p.mem.read_u32(mailbox).unwrap(), 4, "mailbox counted every service");
    assert_eq!(p.irq_log.len(), 4);
}

#[test]
fn service_latency_is_small_and_deterministic() {
    let (p, _, _) = run_with_irqs(&[40, 80, 120]);
    let lats: Vec<u64> = p.irq_log.iter().map(|(r, d)| d - r).collect();
    assert_eq!(lats.len(), 3);
    // identical latency every time — zero jitter (§7: predictable)
    assert!(lats.windows(2).all(|w| w[0] == w[1]), "{lats:?}");
    // handler: mrmovl(8)+irmovl(4)+addl(3)+rmmovl(8) + 1 tick wake = 24ish;
    // vastly below the conventional context-change path (~12000).
    assert!(lats[0] < 40, "latency {lats:?}");
}

#[test]
fn busy_line_drops_the_raise() {
    let (prog, _) = program_with_handler();
    let handler = prog.symbol("Handler").unwrap();
    let mut p = EmpaProcessor::new(&prog.image, &EmpaConfig::default());
    let irq_core = p.reserve_irq_core(handler).unwrap();
    assert!(p.raise_irq(irq_core));
    // immediately raising again while the handler runs: edge lost
    p.tick();
    assert!(!p.raise_irq(irq_core));
}

#[test]
fn reserved_core_is_not_available_to_the_pool() {
    let (prog, _) = program_with_handler();
    let handler = prog.symbol("Handler").unwrap();
    let cfg = EmpaConfig { num_cores: 8, ..Default::default() };
    let mut p = EmpaProcessor::new(&prog.image, &cfg);
    let irq_core = p.reserve_irq_core(handler).unwrap();
    assert!(matches!(p.cores[irq_core].run, RunState::Blocked(BlockReason::IrqWait)));
    assert!(!p.cores[irq_core].available(0));
    // a second reservation takes a *different* core
    let second = p.reserve_irq_core(handler).unwrap();
    assert_ne!(second, irq_core);
}
