//! Instruction and supervisor timing model.
//!
//! The paper's simulator "uses arbitrary, but reasonable execution times,
//! expressed in units of the control clock driving the SV" (§6). The
//! defaults below are calibrated so that the cycle-stepped simulation
//! reproduces **Table 1 exactly**:
//!
//! - NO mode:    `T(N) = 22 + 30·N`  → 52 / 82 / 142 / 202 for N=1,2,4,6
//! - FOR mode:   `T(N) = 20 + 11·N`  → 31 / 42 /  64 /  86
//! - SUMUP mode: `T(N) = 32 +    N`  → 33 / 34 /  36 /  38
//!
//! Derivation (checked by `rust/tests/table1.rs`): the Listing-1 loop body
//! `mrmovl+addl+irmovl+addl+irmovl+addl+jne` must cost 30 clocks and the
//! prologue+halt 22; the FOR-mode child body `mrmovl+addl` costs 11. All
//! constants are plain fields so benches can sweep them (the paper notes
//! "the actual values might change when an electronic version allows to
//! provide more accurate data").

use crate::isa::{Insn, MetaFn};

/// Per-instruction-class and supervisor-operation costs, in core clocks.
#[derive(Debug, Clone, PartialEq)]
pub struct TimingConfig {
    // ---- core instruction classes -------------------------------------
    /// `halt`
    pub halt: u64,
    /// `nop`
    pub nop: u64,
    /// `rrmovl`/`cmovXX`
    pub cmov: u64,
    /// `irmovl`
    pub irmov: u64,
    /// `rmmovl` (includes the memory write)
    pub rmmov: u64,
    /// `mrmovl` (includes the memory read)
    pub mrmov: u64,
    /// `addl`/`subl`/`andl`/`xorl`
    pub alu: u64,
    /// `mull` (the EMPAthY86 multiply extension; multi-cycle ALU op)
    pub mul: u64,
    /// `jXX`
    pub jump: u64,
    /// `call`
    pub call: u64,
    /// `ret`
    pub ret: u64,
    /// `pushl`/`popl`
    pub stack: u64,
    // ---- supervisor-level costs (charged on the issuing core's clock) --
    /// Recognising + PC-advance for any metainstruction during pre-fetch
    /// (§4.5: the SV takes over; one control clock).
    pub meta_dispatch: u64,
    /// `qprealloc` administration.
    pub sv_prealloc: u64,
    /// Renting a core + cloning the parent's "glue" (register file, flags,
    /// PC) over the dedicated wiring (§4.4: "can take somewhat longer time
    /// than the other SV operations").
    pub sv_create: u64,
    /// Entering FOR mass-processing mode (configuring the SV loop engine).
    pub sv_mass_setup_for: u64,
    /// Entering SUMUP mass-processing mode: loop engine plus the
    /// parent-side adder of §5.2 ("an adder is prepared in the parent").
    pub sv_mass_setup_sum: u64,
    /// Terminating a QT: latch clone-back + bitmask administration.
    pub sv_term: u64,
    /// Draining a latched value into a parent register on wait/readout.
    pub sv_readout: u64,
    /// SUMUP stagger: clocks between successive child QT launches (the SV
    /// is sequential — one allocation per control clock, §4.1.3).
    pub sv_stagger: u64,
    /// Clocks a SUMUP child core stays rented beyond its payload work
    /// (creation + termination administration as seen by the pool). §6.2
    /// sizes the pool from this rent period.
    pub sumup_rent_overhead: u64,
}

impl Default for TimingConfig {
    fn default() -> Self {
        Self::paper()
    }
}

impl TimingConfig {
    /// The calibrated paper defaults (see module docs).
    pub fn paper() -> Self {
        TimingConfig {
            halt: 3,
            nop: 1,
            cmov: 3,
            irmov: 4,
            rmmov: 8,
            mrmov: 8,
            alu: 3,
            mul: 6,
            jump: 5,
            call: 5,
            ret: 5,
            stack: 6,
            meta_dispatch: 1,
            sv_prealloc: 1,
            sv_create: 3,
            sv_mass_setup_for: 2,
            sv_mass_setup_sum: 3,
            sv_term: 1,
            sv_readout: 1,
            sv_stagger: 1,
            sumup_rent_overhead: 19,
        }
    }

    /// Cost of a conventional (non-meta) instruction.
    pub fn insn_cost(&self, i: &Insn) -> u64 {
        match i {
            Insn::Halt => self.halt,
            Insn::Nop => self.nop,
            Insn::CMov { .. } => self.cmov,
            Insn::IrMov { .. } => self.irmov,
            Insn::RmMov { .. } => self.rmmov,
            Insn::MrMov { .. } => self.mrmov,
            Insn::Op { op: crate::isa::OpFn::Mul, .. } => self.mul,
            Insn::Op { .. } => self.alu,
            Insn::Jump { .. } => self.jump,
            Insn::Call { .. } => self.call,
            Insn::Ret => self.ret,
            Insn::Push { .. } | Insn::Pop { .. } => self.stack,
            Insn::Meta { .. } => self.meta_dispatch,
        }
    }

    /// SV-level cost charged for a metainstruction (on top of dispatch).
    pub fn meta_cost(&self, m: MetaFn) -> u64 {
        match m {
            MetaFn::QCreate | MetaFn::QCall => self.sv_create,
            MetaFn::QTerm => self.sv_term,
            MetaFn::QWait => self.sv_readout,
            MetaFn::QPreAlloc => self.sv_prealloc,
            MetaFn::QMassFor => self.sv_mass_setup_for,
            MetaFn::QMassSum => self.sv_mass_setup_sum,
            MetaFn::QCopy => self.sv_readout,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{CondFn, OpFn, Reg};

    /// The closed-form cross-check from the module docs: the Listing-1
    /// instruction mix must produce the paper's linear time laws.
    #[test]
    fn paper_costs_reproduce_closed_forms() {
        let t = TimingConfig::paper();
        // NO-mode prologue: irmovl+irmovl+xorl+andl+je, epilogue halt.
        let prologue = t.irmov + t.irmov + t.alu + t.alu + t.jump;
        let epilogue = t.halt;
        assert_eq!(prologue + epilogue, 22);
        // NO-mode loop body: mrmovl,addl,irmovl,addl,irmovl,addl,jne.
        let body = t.mrmov + t.alu + t.irmov + t.alu + t.irmov + t.alu + t.jump;
        assert_eq!(body, 30);
        // FOR-mode child payload: mrmovl+addl.
        assert_eq!(t.mrmov + t.alu, 11);
    }

    #[test]
    fn insn_cost_dispatch() {
        let t = TimingConfig::paper();
        assert_eq!(t.insn_cost(&Insn::Halt), 3);
        assert_eq!(t.insn_cost(&Insn::IrMov { imm: 0, rb: Reg::Eax }), 4);
        assert_eq!(t.insn_cost(&Insn::MrMov { ra: Reg::Esi, rb: Reg::Ecx, disp: 0 }), 8);
        assert_eq!(t.insn_cost(&Insn::Op { op: OpFn::Add, ra: Reg::Eax, rb: Reg::Eax }), 3);
        assert_eq!(t.insn_cost(&Insn::Jump { cond: CondFn::Ne, dest: 0 }), 5);
    }

    #[test]
    fn default_is_paper() {
        assert_eq!(TimingConfig::default(), TimingConfig::paper());
    }
}
