//! Event trace for debugging, tests and the occupancy plots.

use super::sv::MassMode;

/// Supervisor/core-level events.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Event {
    /// Core rented from the pool.
    Rent { parent: Option<usize> },
    /// Core preallocated for `parent` (§5.1).
    PreAlloc { parent: usize },
    /// Child QT launched at `body` (glue cloned in).
    Launch { parent: usize, body: u32 },
    /// FOR engine relaunched the child for the next iteration.
    Relaunch { iteration_addr: i32 },
    /// QT terminated; core returned towards the pool.
    Term { parent: usize },
    /// Core blocked by the SV.
    Block { why: &'static str },
    /// Blocked condition cleared.
    Unblock,
    /// Emergency inline execution of a child QT (§3.3).
    Borrow { body: u32 },
    /// SUMUP child streamed a summand into the parent adder.
    Stream { value: i32 },
    /// Mass engine configured.
    MassStart { mode: MassMode, count: u32 },
    /// Mass engine finalised.
    MassDone { mode: MassMode, sum: i32 },
    /// Root core halted.
    Halt,
}

/// A recorded `(clock, core, event)` triple.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceEntry {
    pub clock: u64,
    pub core: usize,
    pub event: Event,
}

/// Bounded event recorder; disabled by default for speed.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    enabled: bool,
    pub entries: Vec<TraceEntry>,
}

impl Trace {
    pub fn new(enabled: bool) -> Self {
        Trace { enabled, entries: Vec::new() }
    }

    #[inline]
    pub fn push(&mut self, clock: u64, core: usize, event: Event) {
        if self.enabled {
            self.entries.push(TraceEntry { clock, core, event });
        }
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Events of one kind, for assertions in tests.
    pub fn count(&self, pred: impl Fn(&Event) -> bool) -> usize {
        self.entries.iter().filter(|e| pred(&e.event)).count()
    }

    /// Render a human-readable log.
    pub fn render(&self) -> String {
        use std::fmt::Write;
        let mut s = String::new();
        for e in &self.entries {
            let _ = writeln!(s, "[{:>6}] core {:>2}: {:?}", e.clock, e.core, e.event);
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_trace_records_nothing() {
        let mut t = Trace::new(false);
        t.push(1, 0, Event::Halt);
        assert!(t.entries.is_empty());
        assert!(!t.is_enabled());
    }

    #[test]
    fn enabled_trace_records_and_counts() {
        let mut t = Trace::new(true);
        t.push(1, 0, Event::Halt);
        t.push(2, 1, Event::Unblock);
        assert_eq!(t.entries.len(), 2);
        assert_eq!(t.count(|e| matches!(e, Event::Halt)), 1);
        assert!(t.render().contains("core  1"));
    }
}
