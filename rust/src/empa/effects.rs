//! Pure phase-A speculation: the effect-record layer of the parallel
//! stepping core.
//!
//! Between two supervisor sync points (metainstruction retirements,
//! engine rents, IRQ raises — the boundaries arXiv 1608.07155 identifies
//! as the safe fan-out window), every retiring *conventional*
//! instruction touches only its own core's registers/latches plus at
//! most one data word. [`PhaseTask`] snapshots those inputs so a worker
//! thread can execute the instruction against a read-only [`MemView`] of
//! the pre-phase memory; [`PendingEffects`] records everything the
//! instruction *would* have done. The commit loop in
//! [`super::processor::EmpaProcessor`] then replays the records serially
//! in core-index order — the same order the lockstep phase-A loop uses —
//! which is what keeps the parallel mode bit-identical.

use super::core::Latches;
use super::timing::TimingConfig;
use crate::emu::{execute, CoreRegs, ExecEffect, PseudoPort};
use crate::isa::{Insn, Reg, Status};
use crate::mem::{AddrError, DataPort, MemView};

/// Inputs of one core's pending phase-A apply, cloned out so a worker
/// thread can speculate without borrowing the processor.
#[derive(Debug, Clone)]
pub(crate) struct PhaseTask {
    pub id: usize,
    pub insn: Insn,
    pub pc: u32,
    pub regs: CoreRegs,
    pub latch: Latches,
}

/// Everything one speculated instruction would do to the machine —
/// an ordered effect record.
#[derive(Debug, Clone)]
pub(crate) struct PendingEffects {
    pub id: usize,
    /// Post-execution register file (including condition codes).
    pub regs: CoreRegs,
    /// Post-execution latches.
    pub latch: Latches,
    /// `%pp` stream value (SUMUP adder traffic, §5.2) — routed to the
    /// parent's engine at commit, in core-index order.
    pub streamed: Option<i32>,
    /// Word address of a successful data load — the read set for
    /// conflict detection (a Y86 instruction loads at most one word).
    pub read: Option<u32>,
    /// Staged data store `(addr, value)` (at most one per instruction);
    /// performed at commit through the live memory so decode-cache
    /// versioning and dirty-window accounting stay identical.
    pub write: Option<(u32, u32)>,
    pub outcome: EffectOutcome,
}

/// [`ExecEffect`], detached from the borrow of the live machine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum EffectOutcome {
    Continue { next_pc: u32 },
    Stop(Status),
}

/// Two word accesses overlap iff their 4-byte ranges intersect.
#[inline]
pub(crate) fn words_overlap(a: u32, b: u32) -> bool {
    a.abs_diff(b) < 4
}

/// Staging [`DataPort`]: loads read the pre-phase view and are recorded
/// into the read set; stores are bounds-probed and held back for the
/// serial commit. No instruction both loads and stores (see
/// [`crate::emu::execute`]), so one slot of each suffices.
struct StagedMem<'a> {
    view: &'a MemView<'a>,
    read: Option<u32>,
    write: Option<(u32, u32)>,
}

impl DataPort for StagedMem<'_> {
    fn load(&mut self, addr: u32) -> Result<u32, AddrError> {
        let v = self.view.read_u32(addr)?;
        debug_assert!(self.read.is_none(), "one load per Y86 instruction");
        self.read = Some(addr);
        Ok(v)
    }

    fn store(&mut self, addr: u32, value: u32) -> Result<(), AddrError> {
        self.view.probe_write(addr)?;
        debug_assert!(self.write.is_none(), "one store per Y86 instruction");
        self.write = Some((addr, value));
        Ok(())
    }
}

/// Pseudo-register port backed by a core's latch registers (§4.6).
///
/// Context-dependent directions: reading `%pc` takes the `FromParent`
/// latch; writing `%pc` stages `ForChild`. Reading `%pp` peeks
/// `FromChild`; writing `%pp` latches `ForParent` (and, in SUMUP mode,
/// streams to the parent adder — handled by the caller through
/// `streamed`). Empty latches read as 0. Shared by the serial apply path
/// and the speculated phase-A path: both operate on a plain
/// `&mut Latches`, live or cloned.
pub(crate) struct LatchPort<'a> {
    pub latch: &'a mut Latches,
    pub streamed: &'a mut Option<i32>,
}

impl PseudoPort for LatchPort<'_> {
    fn read(&mut self, r: Reg) -> Option<i32> {
        Some(match r {
            Reg::PseudoC => self.latch.from_parent.unwrap_or(0),
            Reg::PseudoP => self.latch.from_child.unwrap_or(0),
            _ => return None,
        })
    }

    fn write(&mut self, r: Reg, v: i32) -> Option<()> {
        match r {
            Reg::PseudoC => self.latch.for_child = Some(v),
            Reg::PseudoP => {
                self.latch.for_parent = Some(v);
                *self.streamed = Some(v);
            }
            _ => return None,
        }
        Some(())
    }
}

impl PhaseTask {
    /// Speculate the task against `view`. Pure: no processor, supervisor
    /// or memory state is touched — everything comes back in the record.
    pub fn run(&self, view: &MemView<'_>) -> PendingEffects {
        let mut regs = self.regs.clone();
        let mut latch = self.latch;
        let mut streamed = None;
        let mut mem = StagedMem { view, read: None, write: None };
        let effect = {
            let mut port = LatchPort { latch: &mut latch, streamed: &mut streamed };
            execute(&self.insn, self.pc, &mut regs, &mut mem, &mut port)
        };
        PendingEffects {
            id: self.id,
            regs,
            latch,
            streamed,
            read: mem.read,
            write: mem.write,
            outcome: match effect {
                ExecEffect::Continue { next_pc } => EffectOutcome::Continue { next_pc },
                ExecEffect::Stop(s) => EffectOutcome::Stop(s),
            },
        }
    }
}

// ----------------------------------------------------------------------
// Multi-clock span batching: per-core apply→fetch chains
// ----------------------------------------------------------------------

/// One core's starting state for a multi-clock batch window: its pending
/// `Exec` instruction plus the snapshot a worker needs to keep stepping
/// that core — apply, same-clock fetch-decode, next apply — entirely
/// against the read-only [`MemView`], until the window ends or the chain
/// hits something only the serial tick may handle.
#[derive(Debug, Clone)]
pub(crate) struct ChainTask {
    pub id: usize,
    /// Pending instruction (never a metainstruction — the window-end
    /// computation excludes cores with pending metas).
    pub insn: Insn,
    /// Clock at which `insn` retires.
    pub apply_at: u64,
    pub pc: u32,
    pub regs: CoreRegs,
    pub latch: Latches,
}

/// The fetch half of a chained clock: the next instruction decoded from
/// the pre-window bytes, plus everything the commit loop must replay.
#[derive(Debug, Clone)]
pub(crate) struct FetchRecord {
    /// Fetch pc — the 6-byte decode window `[pc, pc+6)` is re-checked at
    /// commit against every store in the batch (self-modifying code).
    pub pc: u32,
    pub insn: Insn,
    /// Retirement clock of the fetched instruction, speculated as
    /// `t + insn_cost` — i.e. assuming a contention-free bus. On a
    /// ported memory the commit loop's grant-order replay corrects this:
    /// a stalled charge adds its queueing delay to the installed
    /// `apply_at` and truncates the window after that clock.
    pub apply_at: u64,
    /// Memory instruction: the chain records the bus-access *intent*
    /// (never touching the shared reservation table) and the commit loop
    /// replays the charge via `MemoryBus::replay_access(t)` in lockstep's
    /// phase-D grant order — descending core index within a clock — so
    /// [`crate::mem::BusStats`] stay bit-identical to lockstep.
    pub bus_access: bool,
}

/// One committed-clock candidate of a chain: the apply's effect record
/// and the same-clock fetch that followed it.
#[derive(Debug, Clone)]
pub(crate) struct ChainStep {
    /// Clock this apply retires at (strictly increasing along a chain).
    pub t: u64,
    pub eff: PendingEffects,
    pub fetch: FetchRecord,
}

/// A chain's output: complete apply+fetch records for every clock it
/// covered, plus where (if anywhere) it hit a non-batchable event.
#[derive(Debug, Clone)]
pub(crate) struct ChainResult {
    pub id: usize,
    pub steps: Vec<ChainStep>,
    /// Clock of the first event only the serial tick may handle: a
    /// `Stop` outcome (halt/fault), a fetched metainstruction or `halt`,
    /// or an undecodable fetch window. The processor truncates the whole
    /// batch to the minimum stop over all chains; records at that clock
    /// are discarded and the serial tick redoes it with full supervisor
    /// semantics. `None`: the chain ran to the window end.
    pub stop_at: Option<u64>,
}

impl ChainTask {
    /// Step this core through consecutive clocks `< end`, speculating
    /// each apply with [`PhaseTask::run`] and decoding each same-clock
    /// fetch from the pre-window bytes. Pure, like the single-clock path.
    ///
    /// The uniform stopper rule: anything that is not "conventional
    /// apply then conventional fetch" stops the chain *at* that clock,
    /// and the records for that clock are not produced — the serial tick
    /// owns it. That covers halt retirement and faults (`Stop`
    /// outcomes), metainstruction and `halt` fetches (supervisor ops,
    /// blocking decisions), and decode failures.
    pub fn run(&self, view: &MemView<'_>, timing: &TimingConfig, end: u64) -> ChainResult {
        let mut steps = Vec::new();
        let mut insn = self.insn;
        let mut apply_at = self.apply_at;
        let mut pc = self.pc;
        let mut regs = self.regs.clone();
        let mut latch = self.latch;
        loop {
            let t = apply_at;
            if t >= end {
                return ChainResult { id: self.id, steps, stop_at: None };
            }
            let task = PhaseTask { id: self.id, insn, pc, regs: regs.clone(), latch };
            let eff = task.run(view);
            let EffectOutcome::Continue { next_pc } = eff.outcome else {
                return ChainResult { id: self.id, steps, stop_at: Some(t) };
            };
            regs = eff.regs.clone();
            latch = eff.latch;
            pc = next_pc;
            // The same-clock fetch (phase D of the tick this apply
            // belongs to). Engine-intercepted qterm, halt blocking, and
            // supervisor dispatch all live behind Meta/Halt — stoppers.
            let Some((next, _len)) = Insn::decode(view.fetch_window(pc)) else {
                return ChainResult { id: self.id, steps, stop_at: Some(t) };
            };
            if matches!(next, Insn::Meta { .. } | Insn::Halt) {
                return ChainResult { id: self.id, steps, stop_at: Some(t) };
            }
            let bus_access = matches!(next, Insn::MrMov { .. } | Insn::RmMov { .. });
            apply_at = t + timing.insn_cost(&next);
            steps.push(ChainStep {
                t,
                eff,
                fetch: FetchRecord { pc, insn: next, apply_at, bus_access },
            });
            insn = next;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::OpFn;
    use crate::mem::Memory;

    fn task(insn: Insn) -> PhaseTask {
        PhaseTask { id: 3, insn, pc: 0x10, regs: CoreRegs::default(), latch: Latches::default() }
    }

    #[test]
    fn a_store_is_staged_not_performed() {
        let mem = Memory::new(64);
        let mut t = task(Insn::RmMov { ra: Reg::Esi, rb: Reg::Ecx, disp: 4 });
        t.regs.file[Reg::Esi as usize] = 77;
        t.regs.file[Reg::Ecx as usize] = 0x20;
        let eff = t.run(&mem.view());
        assert_eq!(eff.write, Some((0x24, 77)));
        assert_eq!(eff.read, None);
        assert_eq!(eff.outcome, EffectOutcome::Continue { next_pc: 0x10 + 6 });
        assert_eq!(mem.read_u32(0x24).unwrap(), 0, "view is read-only");
    }

    #[test]
    fn a_load_is_recorded_in_the_read_set() {
        let mut mem = Memory::new(64);
        mem.write_u32(0x24, 1234).unwrap();
        let mut t = task(Insn::MrMov { ra: Reg::Edi, rb: Reg::Ecx, disp: 4 });
        t.regs.file[Reg::Ecx as usize] = 0x20;
        let eff = t.run(&mem.view());
        assert_eq!(eff.read, Some(0x24));
        assert_eq!(eff.write, None);
        assert_eq!(eff.regs.file[Reg::Edi as usize], 1234);
    }

    #[test]
    fn out_of_bounds_accesses_stop_with_adr_like_the_live_memory() {
        let mem = Memory::new(16);
        let mut t = task(Insn::RmMov { ra: Reg::Esi, rb: Reg::Ecx, disp: 0 });
        t.regs.file[Reg::Ecx as usize] = 1000;
        assert_eq!(t.run(&mem.view()).outcome, EffectOutcome::Stop(Status::Adr));
        let mut t = task(Insn::MrMov { ra: Reg::Esi, rb: Reg::Ecx, disp: 0 });
        t.regs.file[Reg::Ecx as usize] = 1000;
        assert_eq!(t.run(&mem.view()).outcome, EffectOutcome::Stop(Status::Adr));
    }

    #[test]
    fn pp_writes_stream_and_latch() {
        let mem = Memory::new(16);
        let mut t = task(Insn::Op { op: OpFn::Add, ra: Reg::Eax, rb: Reg::PseudoP });
        t.regs.file[Reg::Eax as usize] = 5;
        t.latch.from_child = Some(37);
        let eff = t.run(&mem.view());
        assert_eq!(eff.streamed, Some(42), "read %pp (37) + %eax (5), streamed back");
        assert_eq!(eff.latch.for_parent, Some(42));
        assert_eq!(t.latch.for_parent, None, "the task's own snapshot is untouched");
    }

    #[test]
    fn halt_and_alu_outcomes_round_trip() {
        let mem = Memory::new(16);
        assert_eq!(task(Insn::Halt).run(&mem.view()).outcome, EffectOutcome::Stop(Status::Hlt));
        let mut t = task(Insn::Op { op: OpFn::Sub, ra: Reg::Eax, rb: Reg::Ebx });
        t.regs.file[0] = 5;
        t.regs.file[3] = 5;
        let eff = t.run(&mem.view());
        assert!(eff.regs.cc.zf, "condition codes travel in the record");
        assert_eq!(eff.regs.file[3], 0);
    }

    #[test]
    fn word_overlap_is_symmetric_and_tight() {
        assert!(words_overlap(100, 100));
        assert!(words_overlap(100, 103));
        assert!(words_overlap(103, 100));
        assert!(!words_overlap(100, 104));
        assert!(!words_overlap(104, 100));
    }
}
