//! ASCII occupancy timeline ("Gantt") rendered from an event trace —
//! visualises how the SV maps the processing graph onto the cores
//! (Fig. 3's two-level operation, per clock).
//!
//! Legend: `█` running a QT, `▒` preallocated/parked, `·` in the pool.

use super::trace::{Event, Trace};
use std::fmt::Write;

/// Per-core occupancy states over time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum CellState {
    Free,
    Reserved,
    Running,
}

/// Reconstruct per-core occupancy from the trace.
///
/// `until` bounds the timeline (usually the run's final clock).
fn occupancy(trace: &Trace, cores: usize, until: u64) -> Vec<Vec<CellState>> {
    let mut grid = vec![vec![CellState::Free; until as usize + 1]; cores];
    // Sort-stable walk: apply each event from its clock onwards.
    for e in &trace.entries {
        let t = e.clock as usize;
        if e.core >= cores || t >= grid[0].len() {
            continue;
        }
        let paint = |grid: &mut Vec<Vec<CellState>>, core: usize, from: usize, s: CellState| {
            for cell in grid[core][from..].iter_mut() {
                *cell = s;
            }
        };
        match e.event {
            Event::Rent { .. } | Event::Launch { .. } | Event::Relaunch { .. } | Event::Unblock => {
                paint(&mut grid, e.core, t, CellState::Running)
            }
            Event::PreAlloc { .. } | Event::Block { .. } => paint(&mut grid, e.core, t, CellState::Reserved),
            Event::Term { .. } => paint(&mut grid, e.core, t, CellState::Reserved),
            Event::Halt => paint(&mut grid, e.core, t, CellState::Free),
            Event::Stream { .. } | Event::MassStart { .. } | Event::MassDone { .. } | Event::Borrow { .. } => {}
        }
    }
    grid
}

/// Render the timeline; one row per core that was ever occupied.
pub fn render(trace: &Trace, cores: usize, until: u64) -> String {
    let grid = occupancy(trace, cores, until);
    let mut out = String::new();
    let _ = writeln!(out, "clock  0{:>width$}", until, width = until as usize);
    for (id, row) in grid.iter().enumerate() {
        if row.iter().all(|&c| c == CellState::Free) && id != 0 {
            continue;
        }
        let line: String = row
            .iter()
            .map(|c| match c {
                CellState::Free => '·',
                CellState::Reserved => '▒',
                CellState::Running => '█',
            })
            .collect();
        let _ = writeln!(out, "core{id:>3} {line}");
    }
    out.push_str("legend: █ running QT   ▒ preallocated/blocked   · pool\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::empa::{EmpaConfig, EmpaProcessor};
    use crate::isa::assemble;
    use crate::workload::sumup;

    fn traced_run(src: &str) -> (Trace, u64, usize) {
        let p = assemble(src).unwrap();
        let cfg = EmpaConfig { trace: true, ..Default::default() };
        let n = cfg.num_cores;
        let r = EmpaProcessor::new(&p.image, &cfg).run();
        (r.trace, r.clocks, n)
    }

    #[test]
    fn sumup_gantt_shows_the_staggered_children() {
        let (trace, clocks, cores) = traced_run(&sumup::sumup_mode_program(&[1, 2, 3, 4]).0);
        let g = render(&trace, cores, clocks);
        // root row plus 4 child rows
        assert!(g.contains("core  0"));
        assert!(g.contains("core  4"));
        assert!(!g.contains("core  9"), "only occupied cores are shown:\n{g}");
        assert!(g.contains('█') && g.contains('▒'));
    }

    #[test]
    fn no_mode_gantt_is_single_row() {
        let (trace, clocks, cores) = traced_run(&sumup::no_mode_program(&[1, 2, 3, 4]).0);
        let g = render(&trace, cores, clocks);
        let rows = g.lines().filter(|l| l.starts_with("core")).count();
        assert_eq!(rows, 1, "{g}");
    }

    #[test]
    fn render_is_bounded_by_until() {
        let (trace, clocks, cores) = traced_run(&sumup::sumup_mode_program(&[1, 2]).0);
        let g = render(&trace, cores, clocks);
        for l in g.lines().filter(|l| l.starts_with("core")) {
            // prefix is `core{id:>3} ` = 8 chars
            let cells = l.chars().skip(8).count();
            assert_eq!(cells as u64, clocks + 1, "{l}");
        }
    }
}
