//! Shared f32 mass kernels with a *fixed reduction order*.
//!
//! Every mass route in the fabric — the inline lane, the accelerator
//! batcher (`NativeAccel` over pooled tiles), and the scatter/gather
//! split lane — computes through these functions, so the same
//! `MassSum`/`MassDot` job returns the **bit-identical** answer no
//! matter how it was routed. That only works because the reduction
//! order is pinned, not left to whatever the implementation finds
//! convenient:
//!
//! - A slice is reduced in *blocks* of [`BLOCK`] = 64 elements.
//! - A block is reduced into 8 lane accumulators: lane `j` left-folds
//!   elements `8i + j` (a trailing partial chunk of `r < 8` elements
//!   adds element `8i + j` into lane `j` scalar-wise, same lanes).
//! - The 8 lanes collapse with the fixed tree
//!   `((l0+l1)+(l2+l3)) + ((l4+l5)+(l6+l7))`.
//! - Block partials are left-folded scalar, first block first.
//!
//! The SIMD paths (AVX2: one 8-lane register; SSE2: two 4-lane
//! registers pinned to lanes 0–3 / 4–7) perform *exactly* the same
//! per-lane IEEE-754 additions as the portable 8-float loop, so scalar
//! and SIMD agree bit-for-bit. Dot products multiply then add as two
//! rounded operations — never FMA, which would contract the rounding
//! and break the contract (Rust itself never auto-contracts float
//! math). `scale` is elementwise (`x*s + c`), so SIMD equality is free.
//!
//! The block granularity is also the split contract: shard a slice at
//! any multiple of `BLOCK`, reduce each shard to block partials with
//! [`sum_block_partials`], place them by *global block index*, and
//! [`fold_partials`] over the assembled vector equals [`sum`] of the
//! whole slice, bit-exact — regardless of shard completion order. The
//! coordinator's `ShardGather` relies on this.

use std::sync::OnceLock;

/// Reduction block size in elements. Shard boundaries must be
/// multiples of this for split results to compose bit-exactly.
pub const BLOCK: usize = 64;

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Isa {
    #[cfg_attr(not(target_arch = "x86_64"), allow(dead_code))]
    Avx2,
    #[cfg_attr(not(target_arch = "x86_64"), allow(dead_code))]
    Sse2,
    Portable,
}

/// Runtime-detected widest usable ISA, cached after the first probe.
fn isa() -> Isa {
    static ISA: OnceLock<Isa> = OnceLock::new();
    *ISA.get_or_init(|| {
        #[cfg(target_arch = "x86_64")]
        {
            if std::arch::is_x86_feature_detected!("avx2") {
                return Isa::Avx2;
            }
            if std::arch::is_x86_feature_detected!("sse2") {
                return Isa::Sse2;
            }
            Isa::Portable
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            Isa::Portable
        }
    })
}

/// The fixed lane-collapse tree shared by every implementation.
#[inline]
fn collapse(l: [f32; 8]) -> f32 {
    ((l[0] + l[1]) + (l[2] + l[3])) + ((l[4] + l[5]) + (l[6] + l[7]))
}

// ---------------------------------------------------------------- portable

/// Canonical block sum: 8 lane accumulators, `x.len() <= BLOCK`.
#[inline]
fn block_sum_portable(x: &[f32]) -> f32 {
    let mut lanes = [0.0f32; 8];
    let mut chunks = x.chunks_exact(8);
    for ch in &mut chunks {
        for j in 0..8 {
            lanes[j] += ch[j];
        }
    }
    for (j, &v) in chunks.remainder().iter().enumerate() {
        lanes[j] += v;
    }
    collapse(lanes)
}

#[inline]
fn block_dot_portable(a: &[f32], b: &[f32]) -> f32 {
    let mut lanes = [0.0f32; 8];
    let (mut ca, mut cb) = (a.chunks_exact(8), b.chunks_exact(8));
    for (xa, xb) in (&mut ca).zip(&mut cb) {
        for j in 0..8 {
            lanes[j] += xa[j] * xb[j];
        }
    }
    for (j, (&va, &vb)) in ca.remainder().iter().zip(cb.remainder()).enumerate() {
        lanes[j] += va * vb;
    }
    collapse(lanes)
}

#[inline]
fn scale_portable(x: &[f32], s: f32, c: f32, out: &mut [f32]) {
    for (o, &v) in out.iter_mut().zip(x) {
        *o = v * s + c;
    }
}

// ---------------------------------------------------------------- x86_64

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::collapse;
    use std::arch::x86_64::*;

    /// # Safety
    /// Caller verified AVX2 via `is_x86_feature_detected!`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn block_sum_avx2(x: &[f32]) -> f32 {
        let mut acc = _mm256_setzero_ps();
        let mut chunks = x.chunks_exact(8);
        for ch in &mut chunks {
            acc = _mm256_add_ps(acc, _mm256_loadu_ps(ch.as_ptr()));
        }
        let mut lanes = [0.0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        for (j, &v) in chunks.remainder().iter().enumerate() {
            lanes[j] += v;
        }
        collapse(lanes)
    }

    /// # Safety
    /// Caller verified AVX2 via `is_x86_feature_detected!`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn block_dot_avx2(a: &[f32], b: &[f32]) -> f32 {
        let mut acc = _mm256_setzero_ps();
        let (mut ca, mut cb) = (a.chunks_exact(8), b.chunks_exact(8));
        for (xa, xb) in (&mut ca).zip(&mut cb) {
            // Multiply then add as two rounded ops — no FMA, matching scalar.
            let prod = _mm256_mul_ps(_mm256_loadu_ps(xa.as_ptr()), _mm256_loadu_ps(xb.as_ptr()));
            acc = _mm256_add_ps(acc, prod);
        }
        let mut lanes = [0.0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        for (j, (&va, &vb)) in ca.remainder().iter().zip(cb.remainder()).enumerate() {
            lanes[j] += va * vb;
        }
        collapse(lanes)
    }

    /// # Safety
    /// Caller verified AVX2 via `is_x86_feature_detected!`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn scale_avx2(x: &[f32], s: f32, c: f32, out: &mut [f32]) {
        let (vs, vc) = (_mm256_set1_ps(s), _mm256_set1_ps(c));
        let n = x.len() & !7;
        let mut i = 0;
        while i < n {
            let v = _mm256_loadu_ps(x.as_ptr().add(i));
            _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_add_ps(_mm256_mul_ps(v, vs), vc));
            i += 8;
        }
        for j in n..x.len() {
            out[j] = x[j] * s + c;
        }
    }

    /// # Safety
    /// Caller verified SSE2 via `is_x86_feature_detected!`.
    #[target_feature(enable = "sse2")]
    pub unsafe fn block_sum_sse2(x: &[f32]) -> f32 {
        // Two 4-lane registers pinned to lanes 0–3 and 4–7.
        let (mut lo, mut hi) = (_mm_setzero_ps(), _mm_setzero_ps());
        let mut chunks = x.chunks_exact(8);
        for ch in &mut chunks {
            lo = _mm_add_ps(lo, _mm_loadu_ps(ch.as_ptr()));
            hi = _mm_add_ps(hi, _mm_loadu_ps(ch.as_ptr().add(4)));
        }
        let mut lanes = [0.0f32; 8];
        _mm_storeu_ps(lanes.as_mut_ptr(), lo);
        _mm_storeu_ps(lanes.as_mut_ptr().add(4), hi);
        for (j, &v) in chunks.remainder().iter().enumerate() {
            lanes[j] += v;
        }
        collapse(lanes)
    }

    /// # Safety
    /// Caller verified SSE2 via `is_x86_feature_detected!`.
    #[target_feature(enable = "sse2")]
    pub unsafe fn block_dot_sse2(a: &[f32], b: &[f32]) -> f32 {
        let (mut lo, mut hi) = (_mm_setzero_ps(), _mm_setzero_ps());
        let (mut ca, mut cb) = (a.chunks_exact(8), b.chunks_exact(8));
        for (xa, xb) in (&mut ca).zip(&mut cb) {
            lo = _mm_add_ps(lo, _mm_mul_ps(_mm_loadu_ps(xa.as_ptr()), _mm_loadu_ps(xb.as_ptr())));
            hi = _mm_add_ps(
                hi,
                _mm_mul_ps(_mm_loadu_ps(xa.as_ptr().add(4)), _mm_loadu_ps(xb.as_ptr().add(4))),
            );
        }
        let mut lanes = [0.0f32; 8];
        _mm_storeu_ps(lanes.as_mut_ptr(), lo);
        _mm_storeu_ps(lanes.as_mut_ptr().add(4), hi);
        for (j, (&va, &vb)) in ca.remainder().iter().zip(cb.remainder()).enumerate() {
            lanes[j] += va * vb;
        }
        collapse(lanes)
    }

    /// # Safety
    /// Caller verified SSE2 via `is_x86_feature_detected!`.
    #[target_feature(enable = "sse2")]
    pub unsafe fn scale_sse2(x: &[f32], s: f32, c: f32, out: &mut [f32]) {
        let (vs, vc) = (_mm_set1_ps(s), _mm_set1_ps(c));
        let n = x.len() & !3;
        let mut i = 0;
        while i < n {
            let v = _mm_loadu_ps(x.as_ptr().add(i));
            _mm_storeu_ps(out.as_mut_ptr().add(i), _mm_add_ps(_mm_mul_ps(v, vs), vc));
            i += 4;
        }
        for j in n..x.len() {
            out[j] = x[j] * s + c;
        }
    }
}

// ---------------------------------------------------------------- dispatch

/// One block (`x.len() <= BLOCK`) reduced in the canonical lane order.
#[inline]
fn block_sum(x: &[f32]) -> f32 {
    match isa() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `isa()` returned this variant only after runtime detection.
        Isa::Avx2 => unsafe { x86::block_sum_avx2(x) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as above.
        Isa::Sse2 => unsafe { x86::block_sum_sse2(x) },
        _ => block_sum_portable(x),
    }
}

#[inline]
fn block_dot(a: &[f32], b: &[f32]) -> f32 {
    match isa() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `isa()` returned this variant only after runtime detection.
        Isa::Avx2 => unsafe { x86::block_dot_avx2(a, b) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as above.
        Isa::Sse2 => unsafe { x86::block_dot_sse2(a, b) },
        _ => block_dot_portable(a, b),
    }
}

// ---------------------------------------------------------------- public API

/// Deterministic slice sum: left fold of the canonical block partials.
pub fn sum(x: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for b in x.chunks(BLOCK) {
        acc += block_sum(b);
    }
    acc
}

/// Deterministic dot product over `min(a.len(), b.len())` elements.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let mut acc = 0.0f32;
    for (ba, bb) in a[..n].chunks(BLOCK).zip(b[..n].chunks(BLOCK)) {
        acc += block_dot(ba, bb);
    }
    acc
}

/// Elementwise `x*s + c`. Order-insensitive, so SIMD equality is exact.
pub fn scale(x: &[f32], s: f32, c: f32) -> Vec<f32> {
    let mut out = vec![0.0f32; x.len()];
    match isa() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `isa()` returned this variant only after runtime detection.
        Isa::Avx2 => unsafe { x86::scale_avx2(x, s, c, &mut out) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as above.
        Isa::Sse2 => unsafe { x86::scale_sse2(x, s, c, &mut out) },
        _ => scale_portable(x, s, c, &mut out),
    }
    out
}

/// Append one canonical partial per [`BLOCK`]-sized chunk of `x`.
///
/// `fold_partials` over partials assembled by global block index equals
/// `sum` of the concatenation, provided every producer sliced at
/// `BLOCK` multiples.
pub fn sum_block_partials(x: &[f32], out: &mut Vec<f32>) {
    out.reserve(x.len().div_ceil(BLOCK));
    for b in x.chunks(BLOCK) {
        out.push(block_sum(b));
    }
}

/// Dot-product analogue of [`sum_block_partials`].
pub fn dot_block_partials(a: &[f32], b: &[f32], out: &mut Vec<f32>) {
    let n = a.len().min(b.len());
    out.reserve(n.div_ceil(BLOCK));
    for (ba, bb) in a[..n].chunks(BLOCK).zip(b[..n].chunks(BLOCK)) {
        out.push(block_dot(ba, bb));
    }
}

/// The canonical scalar left fold over block partials.
pub fn fold_partials(partials: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for &p in partials {
        acc += p;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic value stream with mixed magnitudes so reduction
    /// order actually matters in f32.
    fn noisy(n: usize, seed: u64) -> Vec<f32> {
        let mut s = seed | 1;
        (0..n)
            .map(|_| {
                s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                let mant = ((s >> 33) & 0xffff) as f32;
                let exp = ((s >> 49) % 29) as i32 - 14;
                mant * 2f32.powi(exp)
            })
            .collect()
    }

    /// Pure-portable whole-slice sum: the executable statement of the
    /// reduction-order contract the SIMD paths must match bit-for-bit.
    fn reference_sum(x: &[f32]) -> f32 {
        x.chunks(BLOCK).fold(0.0f32, |a, b| a + block_sum_portable(b))
    }

    fn reference_dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        a[..n]
            .chunks(BLOCK)
            .zip(b[..n].chunks(BLOCK))
            .fold(0.0f32, |acc, (ba, bb)| acc + block_dot_portable(ba, bb))
    }

    #[test]
    fn dispatched_sum_is_bit_identical_to_portable() {
        for n in [0, 1, 7, 8, 9, 63, 64, 65, 127, 128, 1000, 4096, 4099] {
            let x = noisy(n, n as u64 + 3);
            assert_eq!(
                sum(&x).to_bits(),
                reference_sum(&x).to_bits(),
                "n={n} isa={:?}",
                isa()
            );
        }
    }

    #[test]
    fn dispatched_dot_is_bit_identical_to_portable() {
        for n in [0, 1, 9, 64, 65, 513, 4096] {
            let a = noisy(n, 11);
            let b = noisy(n, 77);
            assert_eq!(dot(&a, &b).to_bits(), reference_dot(&a, &b).to_bits(), "n={n}");
        }
    }

    #[test]
    fn dispatched_scale_is_bit_identical_to_portable() {
        for n in [0, 1, 5, 64, 131] {
            let x = noisy(n, 5);
            let got = scale(&x, 1.25, -3.5);
            let want: Vec<f32> = x.iter().map(|v| v * 1.25 + -3.5).collect();
            assert_eq!(got.len(), want.len());
            for (g, w) in got.iter().zip(&want) {
                assert_eq!(g.to_bits(), w.to_bits(), "n={n}");
            }
        }
    }

    #[test]
    fn block_partials_compose_bit_exactly_at_any_block_split() {
        let x = noisy(4096 + 17, 99);
        let whole = sum(&x);
        for cut_blocks in [1, 2, 3, 7, 32, 64] {
            let cut = (cut_blocks * BLOCK).min(x.len());
            let mut parts = Vec::new();
            sum_block_partials(&x[..cut], &mut parts);
            // Second producer starts at a BLOCK multiple: partials line
            // up with the whole-slice block grid.
            sum_block_partials(&x[cut..], &mut parts);
            assert_eq!(
                fold_partials(&parts).to_bits(),
                whole.to_bits(),
                "split at {cut_blocks} blocks"
            );
        }
    }

    #[test]
    fn non_block_splits_would_not_compose() {
        // Sanity check that the contract is load-bearing: splitting off
        // a non-BLOCK prefix genuinely changes the reduction tree for
        // this magnitude-diverse input (if it didn't, the alignment
        // rule would be untestable dead weight).
        let x = noisy(1000, 123);
        let mut parts = Vec::new();
        sum_block_partials(&x[..97], &mut parts);
        sum_block_partials(&x[97..], &mut parts);
        assert_ne!(fold_partials(&parts).to_bits(), sum(&x).to_bits());
    }

    #[test]
    fn dot_partials_compose_like_sum_partials() {
        let a = noisy(3000, 1);
        let b = noisy(3000, 2);
        let whole = dot(&a, &b);
        let cut = 8 * BLOCK;
        let mut parts = Vec::new();
        dot_block_partials(&a[..cut], &b[..cut], &mut parts);
        dot_block_partials(&a[cut..], &b[cut..], &mut parts);
        assert_eq!(fold_partials(&parts).to_bits(), whole.to_bits());
    }

    #[test]
    fn exact_integer_sums_match_naive_iteration() {
        // Integer-valued f32 sums below 2^24 are exact in any order, so
        // the canonical order must agree with a plain fold.
        let x: Vec<f32> = (0..1027).map(|i| (i % 97) as f32).collect();
        let naive: f32 = x.iter().sum();
        assert_eq!(sum(&x).to_bits(), naive.to_bits());
    }
}
