//! Y86 instruction-set substrate, extended with EMPA metainstructions.
//!
//! The paper's toolchain (ref [31]/[32], "EMPAthY86") extends the Y86
//! educational ISA (Bryant & O'Hallaron, *CS:APP*) with metainstructions
//! that carry the compiler's parallelization suggestions to the supervisor.
//! This module provides the full substrate: register/condition-code model,
//! instruction encode/decode ([`insn`]), a two-pass assembler with labels
//! and directives ([`asm`]), a disassembler ([`disasm`]), and a `.yo`
//! object-file loader ([`loader`]).

pub mod asm;
pub mod disasm;
pub mod insn;
pub mod loader;

pub use asm::{assemble, AsmError, DataSpan, PatchError, Program};
pub use disasm::disassemble;
pub use insn::{CondFn, Insn, MetaFn, OpFn, Reg, DECODE_ERROR};

/// Y86 program-visible register file: 8 architectural registers plus the
/// EMPA pseudo-registers (§4.6) which have register *addresses* but
/// context-dependent latch semantics.
pub const NUM_ARCH_REGS: usize = 8;

/// Machine status, mirroring Y86's `STAT` plus EMPA-specific states.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Status {
    /// Normal operation.
    Aok,
    /// `halt` executed.
    Hlt,
    /// Invalid memory address touched.
    Adr,
    /// Invalid instruction byte fetched.
    Ins,
    /// EMPA: QT terminated via `qterm` (core returns to the pool).
    Qtrm,
}

impl Status {
    /// True while the machine may continue stepping.
    pub fn running(self) -> bool {
        self == Status::Aok
    }
}

/// Condition codes produced by the arithmetic/logic instructions.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CondCodes {
    pub zf: bool,
    pub sf: bool,
    pub of: bool,
}

impl CondCodes {
    /// Evaluate a Y86 condition function against the current codes.
    pub fn eval(&self, cond: CondFn) -> bool {
        let CondCodes { zf, sf, of } = *self;
        match cond {
            CondFn::Always => true,
            CondFn::Le => (sf ^ of) || zf,
            CondFn::L => sf ^ of,
            CondFn::E => zf,
            CondFn::Ne => !zf,
            CondFn::Ge => !(sf ^ of),
            CondFn::G => !(sf ^ of) && !zf,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cond_eval_matrix() {
        let mk = |zf, sf, of| CondCodes { zf, sf, of };
        // zero result
        assert!(mk(true, false, false).eval(CondFn::E));
        assert!(mk(true, false, false).eval(CondFn::Le));
        assert!(mk(true, false, false).eval(CondFn::Ge));
        assert!(!mk(true, false, false).eval(CondFn::Ne));
        assert!(!mk(true, false, false).eval(CondFn::L));
        assert!(!mk(true, false, false).eval(CondFn::G));
        // negative result, no overflow
        assert!(mk(false, true, false).eval(CondFn::L));
        assert!(mk(false, true, false).eval(CondFn::Le));
        assert!(!mk(false, true, false).eval(CondFn::Ge));
        // negative flag + overflow => logically non-negative
        assert!(mk(false, true, true).eval(CondFn::Ge));
        assert!(mk(false, true, true).eval(CondFn::G));
        // Always
        assert!(mk(false, false, false).eval(CondFn::Always));
    }

    #[test]
    fn status_running() {
        assert!(Status::Aok.running());
        for s in [Status::Hlt, Status::Adr, Status::Ins, Status::Qtrm] {
            assert!(!s.running());
        }
    }
}
