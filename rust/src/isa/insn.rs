//! Y86 + EMPA instruction encoding and decoding.
//!
//! Standard Y86 encoding: one `icode:ifun` byte, optionally a `rA:rB`
//! register byte, optionally a 4-byte little-endian immediate/displacement.
//! EMPA metainstructions occupy the otherwise-unused icode `0xE` — during
//! pre-fetch a core recognises the icode, raises its `Meta` signal and the
//! supervisor "executes" the instruction at the supervisor level (§4.5).

use std::fmt;

/// Sentinel returned by the fetch stage for undecodable bytes.
pub const DECODE_ERROR: &str = "invalid instruction";

/// Y86 architectural registers (32-bit flavour), plus the EMPA
/// pseudo-registers of §4.6. The pseudo-registers have ordinary register
/// *addresses* (0x8/0x9) but are mapped to the core's latch registers; the
/// value 0xF means "no register" as in standard Y86.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum Reg {
    Eax = 0x0,
    Ecx = 0x1,
    Edx = 0x2,
    Ebx = 0x3,
    Esp = 0x4,
    Ebp = 0x5,
    Esi = 0x6,
    Edi = 0x7,
    /// Pseudo-register towards the *parent* side of the link: written by a
    /// child it lands in its `ForParent` latch; read by a parent it drains
    /// the `FromChild` latch (§4.6, §5.2).
    PseudoP = 0x8,
    /// Pseudo-register towards the *child* side of the link: written by a
    /// parent it lands in `ForChild`; read by a child it reads the
    /// `FromParent` latch (§4.6, §5.1).
    PseudoC = 0x9,
    /// "No register" marker (0xF in the encoding).
    None = 0xF,
}

impl Reg {
    /// Decode a register nibble.
    pub fn from_nibble(n: u8) -> Option<Reg> {
        Some(match n {
            0x0 => Reg::Eax,
            0x1 => Reg::Ecx,
            0x2 => Reg::Edx,
            0x3 => Reg::Ebx,
            0x4 => Reg::Esp,
            0x5 => Reg::Ebp,
            0x6 => Reg::Esi,
            0x7 => Reg::Edi,
            0x8 => Reg::PseudoP,
            0x9 => Reg::PseudoC,
            0xF => Reg::None,
            _ => return None,
        })
    }

    /// Index into an architectural register file; pseudo-registers and
    /// `None` are not backed by the file.
    pub fn file_index(self) -> Option<usize> {
        let n = self as u8;
        (n < 8).then_some(n as usize)
    }

    /// True for the EMPA latch-backed pseudo-registers.
    pub fn is_pseudo(self) -> bool {
        matches!(self, Reg::PseudoP | Reg::PseudoC)
    }

    /// Assembly spelling (`%eax` ... / `%pp` / `%pc`).
    pub fn name(self) -> &'static str {
        match self {
            Reg::Eax => "%eax",
            Reg::Ecx => "%ecx",
            Reg::Edx => "%edx",
            Reg::Ebx => "%ebx",
            Reg::Esp => "%esp",
            Reg::Ebp => "%ebp",
            Reg::Esi => "%esi",
            Reg::Edi => "%edi",
            Reg::PseudoP => "%pp",
            Reg::PseudoC => "%pc",
            Reg::None => "%none",
        }
    }
}

impl fmt::Display for Reg {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// ALU operations (icode 0x6).
///
/// `Mul` (ifun 0x4) is the EMPAthY86 extension beyond CS:APP Y86 — the
/// dot-product workloads of §3.7's "mass operating mode" need it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum OpFn {
    Add = 0x0,
    Sub = 0x1,
    And = 0x2,
    Xor = 0x3,
    Mul = 0x4,
}

impl OpFn {
    pub fn from_nibble(n: u8) -> Option<OpFn> {
        Some(match n {
            0x0 => OpFn::Add,
            0x1 => OpFn::Sub,
            0x2 => OpFn::And,
            0x3 => OpFn::Xor,
            0x4 => OpFn::Mul,
            _ => return None,
        })
    }

    /// Apply the operation, returning (result, overflow).
    pub fn apply(self, a: i32, b: i32) -> (i32, bool) {
        match self {
            OpFn::Add => {
                let (r, of) = b.overflowing_add(a);
                (r, of)
            }
            OpFn::Sub => {
                let (r, of) = b.overflowing_sub(a);
                (r, of)
            }
            OpFn::And => (b & a, false),
            OpFn::Xor => (b ^ a, false),
            OpFn::Mul => {
                let (r, of) = b.overflowing_mul(a);
                (r, of)
            }
        }
    }

    pub fn mnemonic(self) -> &'static str {
        match self {
            OpFn::Add => "addl",
            OpFn::Sub => "subl",
            OpFn::And => "andl",
            OpFn::Xor => "xorl",
            OpFn::Mul => "mull",
        }
    }
}

/// Condition functions shared by `jXX` and `cmovXX` (ifun nibble).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum CondFn {
    Always = 0x0,
    Le = 0x1,
    L = 0x2,
    E = 0x3,
    Ne = 0x4,
    Ge = 0x5,
    G = 0x6,
}

impl CondFn {
    pub fn from_nibble(n: u8) -> Option<CondFn> {
        Some(match n {
            0x0 => CondFn::Always,
            0x1 => CondFn::Le,
            0x2 => CondFn::L,
            0x3 => CondFn::E,
            0x4 => CondFn::Ne,
            0x5 => CondFn::Ge,
            0x6 => CondFn::G,
            _ => return None,
        })
    }

    pub fn jump_mnemonic(self) -> &'static str {
        match self {
            CondFn::Always => "jmp",
            CondFn::Le => "jle",
            CondFn::L => "jl",
            CondFn::E => "je",
            CondFn::Ne => "jne",
            CondFn::Ge => "jge",
            CondFn::G => "jg",
        }
    }

    pub fn move_mnemonic(self) -> &'static str {
        match self {
            CondFn::Always => "rrmovl",
            CondFn::Le => "cmovle",
            CondFn::L => "cmovl",
            CondFn::E => "cmove",
            CondFn::Ne => "cmovne",
            CondFn::Ge => "cmovge",
            CondFn::G => "cmovg",
        }
    }
}

/// EMPA metainstruction functions (icode 0xE, §4.5, §5).
///
/// Metainstructions are *executed by the supervisor*: the core raises its
/// `Meta` signal during pre-fetch, the SV advances the core's PC and
/// performs the operation at the supervisor level.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum MetaFn {
    /// `qcreate Lcont` — rent a core, clone the glue, the child starts at
    /// the next address (the QT body is embedded in the calling flow),
    /// the parent continues at `Lcont` (§3.6).
    QCreate = 0x0,
    /// `qcall Lsub` — subroutine-style QT: the child starts at `Lsub`,
    /// the parent continues at the next address (§3.6).
    QCall = 0x1,
    /// `qterm` — terminate the running QT; clone-back the link register,
    /// return the core to the pool (§4.3).
    QTerm = 0x2,
    /// `qwait` — block until all child QTs of this core terminated; drains
    /// the `FromChild` latch into the designated register (§4.4).
    QWait = 0x3,
    /// `qprealloc $n` — preallocate `n` cores for this core, guaranteeing
    /// availability for the coming iterations (§5.1).
    QPreAlloc = 0x4,
    /// `qmassfor Lbody` — enter FOR mass-processing mode: the SV takes
    /// over loop organisation (address advancing, counting, jumping) and
    /// repeatedly runs the body QT on a preallocated child (§5.1).
    QMassFor = 0x5,
    /// `qmasssum Lbody` — enter SUMUP mass-processing mode: staggered
    /// child QTs stream their summands through the `ForParent` latch into
    /// the parent-side adder (§5.2).
    QMassSum = 0x6,
    /// `qcopy` — explicit copy from the input pseudo-register latch to the
    /// output pseudo-register latch (data forwarding, §4.6).
    QCopy = 0x7,
}

impl MetaFn {
    pub fn from_nibble(n: u8) -> Option<MetaFn> {
        Some(match n {
            0x0 => MetaFn::QCreate,
            0x1 => MetaFn::QCall,
            0x2 => MetaFn::QTerm,
            0x3 => MetaFn::QWait,
            0x4 => MetaFn::QPreAlloc,
            0x5 => MetaFn::QMassFor,
            0x6 => MetaFn::QMassSum,
            0x7 => MetaFn::QCopy,
            _ => return None,
        })
    }

    pub fn mnemonic(self) -> &'static str {
        match self {
            MetaFn::QCreate => "qcreate",
            MetaFn::QCall => "qcall",
            MetaFn::QTerm => "qterm",
            MetaFn::QWait => "qwait",
            MetaFn::QPreAlloc => "qprealloc",
            MetaFn::QMassFor => "qmassfor",
            MetaFn::QMassSum => "qmasssum",
            MetaFn::QCopy => "qcopy",
        }
    }

    /// True when the encoding carries a 4-byte address/immediate.
    pub fn has_value(self) -> bool {
        matches!(
            self,
            MetaFn::QCreate | MetaFn::QCall | MetaFn::QPreAlloc | MetaFn::QMassFor | MetaFn::QMassSum
        )
    }
}

/// A decoded Y86/EMPA instruction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Insn {
    /// `halt` (icode 0x0)
    Halt,
    /// `nop` (icode 0x1)
    Nop,
    /// `rrmovl`/`cmovXX rA, rB` (icode 0x2)
    CMov { cond: CondFn, ra: Reg, rb: Reg },
    /// `irmovl $V, rB` (icode 0x3)
    IrMov { imm: i32, rb: Reg },
    /// `rmmovl rA, D(rB)` (icode 0x4)
    RmMov { ra: Reg, rb: Reg, disp: i32 },
    /// `mrmovl D(rB), rA` (icode 0x5)
    MrMov { ra: Reg, rb: Reg, disp: i32 },
    /// `OPl rA, rB` (icode 0x6)
    Op { op: OpFn, ra: Reg, rb: Reg },
    /// `jXX Dest` (icode 0x7)
    Jump { cond: CondFn, dest: u32 },
    /// `call Dest` (icode 0x8)
    Call { dest: u32 },
    /// `ret` (icode 0x9)
    Ret,
    /// `pushl rA` (icode 0xA)
    Push { ra: Reg },
    /// `popl rA` (icode 0xB)
    Pop { ra: Reg },
    /// EMPA metainstruction (icode 0xE)
    Meta { meta: MetaFn, ra: Reg, rb: Reg, value: u32 },
}

impl Insn {
    /// Encoded byte length of the instruction.
    pub fn len(&self) -> usize {
        match self {
            Insn::Halt | Insn::Nop | Insn::Ret => 1,
            Insn::CMov { .. } | Insn::Op { .. } | Insn::Push { .. } | Insn::Pop { .. } => 2,
            Insn::Jump { .. } | Insn::Call { .. } => 5,
            Insn::IrMov { .. } | Insn::RmMov { .. } | Insn::MrMov { .. } => 6,
            Insn::Meta { meta, .. } => {
                if meta.has_value() {
                    6
                } else {
                    2
                }
            }
        }
    }

    /// True when the instruction, at the architecture level, is recognised
    /// by the core's pre-fetch as a metainstruction and handed to the SV.
    pub fn is_meta(&self) -> bool {
        matches!(self, Insn::Meta { .. })
    }

    /// Encode into bytes (inverse of [`Insn::decode`]).
    pub fn encode(&self, out: &mut Vec<u8>) {
        match *self {
            Insn::Halt => out.push(0x00),
            Insn::Nop => out.push(0x10),
            Insn::CMov { cond, ra, rb } => {
                out.push(0x20 | cond as u8);
                out.push(((ra as u8) << 4) | rb as u8);
            }
            Insn::IrMov { imm, rb } => {
                out.push(0x30);
                out.push(0xF0 | rb as u8);
                out.extend_from_slice(&imm.to_le_bytes());
            }
            Insn::RmMov { ra, rb, disp } => {
                out.push(0x40);
                out.push(((ra as u8) << 4) | rb as u8);
                out.extend_from_slice(&disp.to_le_bytes());
            }
            Insn::MrMov { ra, rb, disp } => {
                out.push(0x50);
                out.push(((ra as u8) << 4) | rb as u8);
                out.extend_from_slice(&disp.to_le_bytes());
            }
            Insn::Op { op, ra, rb } => {
                out.push(0x60 | op as u8);
                out.push(((ra as u8) << 4) | rb as u8);
            }
            Insn::Jump { cond, dest } => {
                out.push(0x70 | cond as u8);
                out.extend_from_slice(&dest.to_le_bytes());
            }
            Insn::Call { dest } => {
                out.push(0x80);
                out.extend_from_slice(&dest.to_le_bytes());
            }
            Insn::Ret => out.push(0x90),
            Insn::Push { ra } => {
                out.push(0xA0);
                out.push(((ra as u8) << 4) | 0x0F);
            }
            Insn::Pop { ra } => {
                out.push(0xB0);
                out.push(((ra as u8) << 4) | 0x0F);
            }
            Insn::Meta { meta, ra, rb, value } => {
                out.push(0xE0 | meta as u8);
                out.push(((ra as u8) << 4) | rb as u8);
                if meta.has_value() {
                    out.extend_from_slice(&value.to_le_bytes());
                }
            }
        }
    }

    /// Decode the instruction at `bytes[0..]`. Returns the instruction and
    /// its length, or `None` on an invalid encoding / truncated fetch.
    pub fn decode(bytes: &[u8]) -> Option<(Insn, usize)> {
        let b0 = *bytes.first()?;
        let icode = b0 >> 4;
        let ifun = b0 & 0x0F;
        let regs = |i: usize| -> Option<(Reg, Reg)> {
            let b = *bytes.get(i)?;
            Some((Reg::from_nibble(b >> 4)?, Reg::from_nibble(b & 0x0F)?))
        };
        let word = |i: usize| -> Option<u32> {
            let w = bytes.get(i..i + 4)?;
            Some(u32::from_le_bytes([w[0], w[1], w[2], w[3]]))
        };
        let insn = match icode {
            0x0 if ifun == 0 => (Insn::Halt, 1),
            0x1 if ifun == 0 => (Insn::Nop, 1),
            0x2 => {
                let cond = CondFn::from_nibble(ifun)?;
                let (ra, rb) = regs(1)?;
                (Insn::CMov { cond, ra, rb }, 2)
            }
            0x3 if ifun == 0 => {
                let (ra, rb) = regs(1)?;
                if ra != Reg::None {
                    return None;
                }
                (Insn::IrMov { imm: word(2)? as i32, rb }, 6)
            }
            0x4 if ifun == 0 => {
                let (ra, rb) = regs(1)?;
                (Insn::RmMov { ra, rb, disp: word(2)? as i32 }, 6)
            }
            0x5 if ifun == 0 => {
                let (ra, rb) = regs(1)?;
                (Insn::MrMov { ra, rb, disp: word(2)? as i32 }, 6)
            }
            0x6 => {
                let op = OpFn::from_nibble(ifun)?;
                let (ra, rb) = regs(1)?;
                (Insn::Op { op, ra, rb }, 2)
            }
            0x7 => {
                let cond = CondFn::from_nibble(ifun)?;
                (Insn::Jump { cond, dest: word(1)? }, 5)
            }
            0x8 if ifun == 0 => (Insn::Call { dest: word(1)? }, 5),
            0x9 if ifun == 0 => (Insn::Ret, 1),
            0xA if ifun == 0 => {
                let (ra, rb) = regs(1)?;
                if rb != Reg::None {
                    return None;
                }
                (Insn::Push { ra }, 2)
            }
            0xB if ifun == 0 => {
                let (ra, rb) = regs(1)?;
                if rb != Reg::None {
                    return None;
                }
                (Insn::Pop { ra }, 2)
            }
            0xE => {
                let meta = MetaFn::from_nibble(ifun)?;
                let (ra, rb) = regs(1)?;
                if meta.has_value() {
                    (Insn::Meta { meta, ra, rb, value: word(2)? }, 6)
                } else {
                    (Insn::Meta { meta, ra, rb, value: 0 }, 2)
                }
            }
            _ => return None,
        };
        Some(insn)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(i: Insn) {
        let mut buf = Vec::new();
        i.encode(&mut buf);
        assert_eq!(buf.len(), i.len(), "length mismatch for {i:?}");
        let (d, n) = Insn::decode(&buf).expect("decode");
        assert_eq!(d, i);
        assert_eq!(n, buf.len());
    }

    #[test]
    fn encode_decode_roundtrip_all_forms() {
        roundtrip(Insn::Halt);
        roundtrip(Insn::Nop);
        roundtrip(Insn::Ret);
        roundtrip(Insn::CMov { cond: CondFn::Ne, ra: Reg::Eax, rb: Reg::Ebx });
        roundtrip(Insn::IrMov { imm: -4, rb: Reg::Edx });
        roundtrip(Insn::RmMov { ra: Reg::Esi, rb: Reg::Ecx, disp: 0x40 });
        roundtrip(Insn::MrMov { ra: Reg::Esi, rb: Reg::Ecx, disp: 0 });
        roundtrip(Insn::Op { op: OpFn::Xor, ra: Reg::Eax, rb: Reg::Eax });
        roundtrip(Insn::Jump { cond: CondFn::E, dest: 0x32 });
        roundtrip(Insn::Call { dest: 0x100 });
        roundtrip(Insn::Push { ra: Reg::Ebp });
        roundtrip(Insn::Pop { ra: Reg::Ebp });
        for meta in [
            MetaFn::QCreate,
            MetaFn::QCall,
            MetaFn::QTerm,
            MetaFn::QWait,
            MetaFn::QPreAlloc,
            MetaFn::QMassFor,
            MetaFn::QMassSum,
            MetaFn::QCopy,
        ] {
            roundtrip(Insn::Meta { meta, ra: Reg::PseudoP, rb: Reg::Eax, value: if meta.has_value() { 42 } else { 0 } });
        }
    }

    #[test]
    fn listing1_opcode_bytes_match_paper() {
        // Listing 1 of the paper shows the exact encodings; spot-check a few.
        let mut buf = Vec::new();
        Insn::IrMov { imm: 4, rb: Reg::Edx }.encode(&mut buf);
        assert_eq!(buf, [0x30, 0xF2, 0x04, 0x00, 0x00, 0x00]); // 30f204000000
        buf.clear();
        Insn::Op { op: OpFn::Xor, ra: Reg::Eax, rb: Reg::Eax }.encode(&mut buf);
        assert_eq!(buf, [0x63, 0x00]); // 6300
        buf.clear();
        Insn::MrMov { ra: Reg::Esi, rb: Reg::Ecx, disp: 0 }.encode(&mut buf);
        assert_eq!(buf, [0x50, 0x61, 0x00, 0x00, 0x00, 0x00]); // 506100000000
        buf.clear();
        Insn::Jump { cond: CondFn::Ne, dest: 0x15 }.encode(&mut buf);
        assert_eq!(buf, [0x74, 0x15, 0x00, 0x00, 0x00, 0x00][..5]); // 7415000000
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(Insn::decode(&[0xFF]).is_none());
        assert!(Insn::decode(&[0xC0]).is_none());
        assert!(Insn::decode(&[]).is_none());
        // truncated irmovl
        assert!(Insn::decode(&[0x30, 0xF0, 0x01]).is_none());
        // irmovl with rA != none
        assert!(Insn::decode(&[0x30, 0x10, 0, 0, 0, 0]).is_none());
        // bad register nibble
        assert!(Insn::decode(&[0x60, 0xA0]).is_none());
    }

    #[test]
    fn pseudo_registers_have_no_file_slot() {
        assert_eq!(Reg::PseudoP.file_index(), None);
        assert_eq!(Reg::PseudoC.file_index(), None);
        assert_eq!(Reg::None.file_index(), None);
        assert_eq!(Reg::Esi.file_index(), Some(6));
        assert!(Reg::PseudoP.is_pseudo() && Reg::PseudoC.is_pseudo());
        assert!(!Reg::Eax.is_pseudo());
    }
}
