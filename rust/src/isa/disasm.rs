//! Y86/EMPA disassembler — the inverse of the assembler, used by the
//! tracing facilities and the `empa asm --dis` CLI verb.

use super::insn::{Insn, MetaFn};

/// Render one decoded instruction in assembler syntax.
pub fn format_insn(i: &Insn) -> String {
    match *i {
        Insn::Halt => "halt".into(),
        Insn::Nop => "nop".into(),
        Insn::Ret => "ret".into(),
        Insn::CMov { cond, ra, rb } => format!("{} {}, {}", cond.move_mnemonic(), ra, rb),
        Insn::IrMov { imm, rb } => format!("irmovl ${imm}, {rb}"),
        Insn::RmMov { ra, rb, disp } => format!("rmmovl {ra}, {disp}({rb})"),
        Insn::MrMov { ra, rb, disp } => format!("mrmovl {disp}({rb}), {ra}"),
        Insn::Op { op, ra, rb } => format!("{} {}, {}", op.mnemonic(), ra, rb),
        Insn::Jump { cond, dest } => format!("{} 0x{dest:x}", cond.jump_mnemonic()),
        Insn::Call { dest } => format!("call 0x{dest:x}"),
        Insn::Push { ra } => format!("pushl {ra}"),
        Insn::Pop { ra } => format!("popl {ra}"),
        Insn::Meta { meta, ra, value, .. } => match meta {
            MetaFn::QCreate | MetaFn::QCall | MetaFn::QMassFor | MetaFn::QMassSum => {
                format!("{} 0x{value:x}", meta.mnemonic())
            }
            MetaFn::QPreAlloc => format!("qprealloc ${value}"),
            MetaFn::QTerm | MetaFn::QWait => {
                if ra == super::Reg::None {
                    meta.mnemonic().to_string()
                } else {
                    format!("{} {}", meta.mnemonic(), ra)
                }
            }
            MetaFn::QCopy => "qcopy".into(),
        },
    }
}

/// Disassemble a memory image from `start`, stopping at the first
/// undecodable byte. Returns `(addr, length, text)` triples.
pub fn disassemble(image: &[u8], start: u32) -> Vec<(u32, usize, String)> {
    let mut out = Vec::new();
    let mut pc = start as usize;
    while pc < image.len() {
        match Insn::decode(&image[pc..]) {
            Some((insn, len)) => {
                out.push((pc as u32, len, format_insn(&insn)));
                if matches!(insn, Insn::Halt) {
                    break;
                }
                pc += len;
            }
            None => break,
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::assemble;

    #[test]
    fn disasm_roundtrips_through_assembler() {
        let src = "\
    irmovl $4, %edx
    irmovl $52, %ecx
    xorl %eax, %eax
    andl %edx, %edx
    je 0x32
Loop:
    mrmovl (%ecx), %esi
    addl %esi, %eax
    jne Loop
    halt
";
        let p1 = assemble(src).unwrap();
        let listing = disassemble(&p1.image, 0);
        assert!(!listing.is_empty());
        // Re-assemble the disassembly (labels become absolute targets which
        // the assembler does not accept for jumps, so compare text forms).
        let texts: Vec<&str> = listing.iter().map(|(_, _, t)| t.as_str()).collect();
        assert_eq!(texts[0], "irmovl $4, %edx");
        assert_eq!(texts[4], "je 0x32");
        assert_eq!(texts[5], "mrmovl 0(%ecx), %esi");
        assert_eq!(*texts.last().unwrap(), "halt");
    }

    #[test]
    fn disasm_stops_at_garbage() {
        let image = [0x10, 0xFF, 0x00];
        let l = disassemble(&image, 0);
        assert_eq!(l.len(), 1);
        assert_eq!(l[0].2, "nop");
    }

    #[test]
    fn meta_formatting() {
        let p = assemble("qprealloc $30\nqmasssum 0x20\nqterm %eax\nqwait\nqcopy\n").unwrap();
        let l = disassemble(&p.image, 0);
        let texts: Vec<&str> = l.iter().map(|(_, _, t)| t.as_str()).collect();
        assert_eq!(texts[0], "qprealloc $30");
        assert_eq!(texts[1], "qmasssum 0x20");
        assert_eq!(texts[2], "qterm %eax");
        assert_eq!(texts[3], "qwait");
        assert_eq!(texts[4], "qcopy");
    }
}
