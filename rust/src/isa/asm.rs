//! Two-pass Y86/EMPA assembler.
//!
//! Accepts the dialect of the paper's Listing 1 (CS:APP `yas` syntax):
//! labels, `.pos`/`.align`/`.long` directives, `#` comments, `$imm`
//! immediates (decimal or `0x` hex, label names allowed), `D(%reg)` memory
//! operands — plus the EMPA metainstruction mnemonics (`qcreate`, `qcall`,
//! `qterm`, `qwait`, `qprealloc`, `qmassfor`, `qmasssum`, `qcopy`).

use super::insn::{CondFn, Insn, MetaFn, OpFn, Reg};
use std::collections::HashMap;

/// Assembler errors, with 1-based source line numbers.
///
/// (Hand-rolled `Display`/`Error` impls: the build is fully offline and
/// `thiserror` is not among the vendored dependencies.)
#[derive(Debug)]
pub enum AsmError {
    UnknownMnemonic { line: usize, mnemonic: String },
    BadOperand { line: usize, operand: String, reason: String },
    OperandCount { line: usize, mnemonic: String, got: usize, want: usize },
    UndefinedLabel { line: usize, label: String },
    DuplicateLabel { line: usize, label: String },
    BadDirective { line: usize, reason: String },
}

impl std::fmt::Display for AsmError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AsmError::UnknownMnemonic { line, mnemonic } => {
                write!(f, "line {line}: unknown mnemonic `{mnemonic}`")
            }
            AsmError::BadOperand { line, operand, reason } => {
                write!(f, "line {line}: bad operand `{operand}`: {reason}")
            }
            AsmError::OperandCount { line, mnemonic, got, want } => write!(
                f,
                "line {line}: wrong operand count for `{mnemonic}` (got {got}, want {want})"
            ),
            AsmError::UndefinedLabel { line, label } => {
                write!(f, "line {line}: undefined label `{label}`")
            }
            AsmError::DuplicateLabel { line, label } => {
                write!(f, "line {line}: duplicate label `{label}`")
            }
            AsmError::BadDirective { line, reason } => {
                write!(f, "line {line}: bad directive: {reason}")
            }
        }
    }
}

impl std::error::Error for AsmError {}

/// One labelled run of `.long` words in the data segment: the unit of
/// per-request data patching in the compile-once pipeline. A span ends at
/// the first non-contiguous word **or the next label**, so patching one
/// symbol can never spill into a neighbouring array (or into code).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DataSpan {
    /// Address of the first word.
    pub addr: u32,
    /// Extent in 32-bit words.
    pub words: u32,
}

/// Data-patch failure: the write would leave the span's recorded extent.
#[derive(Debug, PartialEq, Eq)]
pub enum PatchError {
    NoSpan(String),
    Oversized { symbol: String, words: u32, got: u32 },
    OutOfImage { symbol: String, addr: u32, words: u32 },
}

impl std::fmt::Display for PatchError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PatchError::NoSpan(s) => write!(f, "no data span recorded for symbol `{s}`"),
            PatchError::Oversized { symbol, words, got } => {
                write!(f, "patch of {got} words exceeds span `{symbol}` ({words} words)")
            }
            PatchError::OutOfImage { symbol, addr, words } => {
                write!(f, "span `{symbol}` at {addr:#x}+{words} words leaves the image")
            }
        }
    }
}

impl std::error::Error for PatchError {}

/// An assembled program: a flat image plus symbol and line metadata.
#[derive(Debug, Clone, Default)]
pub struct Program {
    /// Memory image, starting at address 0.
    pub image: Vec<u8>,
    /// Label → address.
    pub symbols: HashMap<String, u32>,
    /// Label → its `.long` run, for labels that name data (the
    /// data-segment layout the compile-once pipeline patches through).
    pub data_layout: HashMap<String, DataSpan>,
    /// (address, source line, source text) for listing/disassembly.
    pub lines: Vec<(u32, usize, String)>,
    /// Entry point (address of the first emitted instruction; 0 unless a
    /// `.pos` moved it).
    pub entry: u32,
    /// One past the last instruction byte — the code/data boundary the
    /// simulator's decode cache uses (`Memory::set_code_limit`): stores
    /// at or above this address cannot alter code, so they need not
    /// invalidate cached decodes.
    pub code_end: u32,
}

impl Program {
    /// Look up a symbol's address.
    pub fn symbol(&self, name: &str) -> Option<u32> {
        self.symbols.get(name).copied()
    }

    /// Look up a data symbol's span.
    pub fn data_span(&self, name: &str) -> Option<DataSpan> {
        self.data_layout.get(name).copied()
    }

    /// Look up `symbol`'s span and check `words` fits it — the single
    /// validation both patch paths share.
    fn checked_span(&self, symbol: &str, words: &[i32]) -> Result<DataSpan, PatchError> {
        let span = self
            .data_span(symbol)
            .ok_or_else(|| PatchError::NoSpan(symbol.to_string()))?;
        if words.len() as u32 > span.words {
            return Err(PatchError::Oversized {
                symbol: symbol.to_string(),
                words: span.words,
                got: words.len() as u32,
            });
        }
        Ok(span)
    }

    /// Patch `words` into `image` at `symbol`'s data span. `image` is a
    /// copy of (or at least as large as) this program's image; the write
    /// is bounds-checked against the recorded extent, so data patching
    /// can never corrupt code or a neighbouring span.
    pub fn patch_into(
        &self,
        image: &mut [u8],
        symbol: &str,
        words: &[i32],
    ) -> Result<(), PatchError> {
        let span = self.checked_span(symbol, words)?;
        let start = span.addr as usize;
        let end = start + 4 * words.len();
        if end > image.len() {
            return Err(PatchError::OutOfImage {
                symbol: symbol.to_string(),
                addr: span.addr,
                words: span.words,
            });
        }
        for (i, w) in words.iter().enumerate() {
            image[start + 4 * i..start + 4 * i + 4].copy_from_slice(&w.to_le_bytes());
        }
        Ok(())
    }

    /// Patch `words` directly into a live [`Memory`] at `symbol`'s data
    /// span — the zero-copy sibling of [`Program::patch_into`]: the
    /// template image stays untouched and unduplicated; only the data
    /// words land in the guest memory. Same bounds rules: the write can
    /// never leave the recorded span, so it cannot corrupt code or a
    /// neighbouring array.
    pub fn patch_mem(
        &self,
        mem: &mut crate::mem::Memory,
        symbol: &str,
        words: &[i32],
    ) -> Result<(), PatchError> {
        let span = self.checked_span(symbol, words)?;
        mem.write_words(span.addr, words).map_err(|_| PatchError::OutOfImage {
            symbol: symbol.to_string(),
            addr: span.addr,
            words: span.words,
        })
    }
}

#[derive(Debug, Clone)]
enum Item {
    Insn { insn: PendingInsn, line: usize },
    Long { value: PendingValue, line: usize },
}

/// An instruction whose immediate operands may still reference labels.
#[derive(Debug, Clone)]
enum PendingInsn {
    Ready(Insn),
    IrMov { value: PendingValue, rb: Reg },
    Jump { cond: CondFn, dest: PendingValue },
    Call { dest: PendingValue },
    Meta { meta: MetaFn, ra: Reg, rb: Reg, value: PendingValue },
}

#[derive(Debug, Clone)]
enum PendingValue {
    Lit(i64),
    Label(String),
}

impl PendingValue {
    fn resolve(&self, symbols: &HashMap<String, u32>, line: usize) -> Result<i64, AsmError> {
        match self {
            PendingValue::Lit(v) => Ok(*v),
            PendingValue::Label(l) => symbols
                .get(l)
                .map(|&a| a as i64)
                .ok_or_else(|| AsmError::UndefinedLabel { line, label: l.clone() }),
        }
    }
}

fn pending_len(p: &PendingInsn) -> usize {
    match p {
        PendingInsn::Ready(i) => i.len(),
        PendingInsn::IrMov { .. } => 6,
        PendingInsn::Jump { .. } | PendingInsn::Call { .. } => 5,
        PendingInsn::Meta { meta, .. } => {
            if meta.has_value() {
                6
            } else {
                2
            }
        }
    }
}

/// Assemble Y86/EMPA source into a [`Program`].
pub fn assemble(src: &str) -> Result<Program, AsmError> {
    let mut symbols: HashMap<String, u32> = HashMap::new();
    let mut items: Vec<(u32, Item)> = Vec::new();
    let mut lines_meta: Vec<(u32, usize, String)> = Vec::new();
    let mut addr: u32 = 0;
    let mut entry: Option<u32> = None;

    // ---- pass 1: lexing, layout, symbol table -------------------------
    for (lineno0, raw) in src.lines().enumerate() {
        let line = lineno0 + 1;
        let mut text = raw;
        if let Some(pos) = text.find('#') {
            text = &text[..pos];
        }
        let mut text = text.trim();
        // labels (possibly several on one line)
        while let Some(colon) = text.find(':') {
            let (label, rest) = text.split_at(colon);
            let label = label.trim();
            if label.is_empty() || !label.chars().all(|c| c.is_ascii_alphanumeric() || c == '_') {
                break; // not a label, e.g. stray `:` — let operand parsing complain
            }
            if symbols.insert(label.to_string(), addr).is_some() {
                return Err(AsmError::DuplicateLabel { line, label: label.to_string() });
            }
            text = rest[1..].trim();
        }
        if text.is_empty() {
            continue;
        }
        let (mnemonic, rest) = match text.find(char::is_whitespace) {
            Some(i) => (&text[..i], text[i..].trim()),
            None => (text, ""),
        };
        let operands: Vec<String> = if rest.is_empty() {
            Vec::new()
        } else {
            rest.split(',').map(|s| s.trim().to_string()).collect()
        };

        if let Some(directive) = mnemonic.strip_prefix('.') {
            match directive {
                "pos" => {
                    let v = parse_int(rest, line)?;
                    if v < addr as i64 {
                        return Err(AsmError::BadDirective {
                            line,
                            reason: format!(".pos {v} moves backwards (at {addr})"),
                        });
                    }
                    addr = v as u32;
                }
                "align" => {
                    let v = parse_int(rest, line)?;
                    if v <= 0 || (v & (v - 1)) != 0 {
                        return Err(AsmError::BadDirective { line, reason: format!(".align {v}: not a power of two") });
                    }
                    let a = v as u32;
                    addr = (addr + a - 1) & !(a - 1);
                }
                "long" => {
                    let value = parse_value(rest, line)?;
                    items.push((addr, Item::Long { value, line }));
                    lines_meta.push((addr, line, raw.trim().to_string()));
                    addr += 4;
                }
                other => {
                    return Err(AsmError::BadDirective { line, reason: format!("unknown directive .{other}") });
                }
            }
            continue;
        }

        let pending = parse_insn(mnemonic, &operands, line)?;
        if entry.is_none() {
            entry = Some(addr);
        }
        let len = pending_len(&pending) as u32;
        items.push((addr, Item::Insn { insn: pending, line }));
        lines_meta.push((addr, line, raw.trim().to_string()));
        addr += len;
    }

    // ---- pass 2: resolve labels, emit image ---------------------------
    let mut image = vec![0u8; addr as usize];
    let mut buf = Vec::with_capacity(8);
    let mut code_end = 0u32;
    for (at, item) in &items {
        buf.clear();
        match item {
            Item::Long { value, line } => {
                let v = value.resolve(&symbols, *line)? as i32;
                buf.extend_from_slice(&v.to_le_bytes());
            }
            Item::Insn { insn, line } => {
                let ready = match insn {
                    PendingInsn::Ready(i) => *i,
                    PendingInsn::IrMov { value, rb } => {
                        Insn::IrMov { imm: value.resolve(&symbols, *line)? as i32, rb: *rb }
                    }
                    PendingInsn::Jump { cond, dest } => {
                        Insn::Jump { cond: *cond, dest: dest.resolve(&symbols, *line)? as u32 }
                    }
                    PendingInsn::Call { dest } => {
                        Insn::Call { dest: dest.resolve(&symbols, *line)? as u32 }
                    }
                    PendingInsn::Meta { meta, ra, rb, value } => Insn::Meta {
                        meta: *meta,
                        ra: *ra,
                        rb: *rb,
                        value: value.resolve(&symbols, *line)? as u32,
                    },
                };
                ready.encode(&mut buf);
                code_end = code_end.max(*at + buf.len() as u32);
            }
        }
        image[*at as usize..*at as usize + buf.len()].copy_from_slice(&buf);
    }

    // ---- data-segment layout: label → contiguous `.long` run ----------
    // `.long` items were appended in address order (`.pos` only moves
    // forward), so the collected addresses are sorted.
    let long_addrs: Vec<u32> = items
        .iter()
        .filter(|(_, it)| matches!(it, Item::Long { .. }))
        .map(|(a, _)| *a)
        .collect();
    let label_addrs: std::collections::HashSet<u32> = symbols.values().copied().collect();
    let mut data_layout = HashMap::new();
    for (name, &addr) in &symbols {
        let Ok(start) = long_addrs.binary_search(&addr) else { continue };
        let mut words = 1u32;
        let mut i = start;
        while i + 1 < long_addrs.len()
            && long_addrs[i + 1] == long_addrs[i] + 4
            && !label_addrs.contains(&long_addrs[i + 1])
        {
            words += 1;
            i += 1;
        }
        data_layout.insert(name.clone(), DataSpan { addr, words });
    }

    Ok(Program {
        image,
        symbols,
        data_layout,
        lines: lines_meta,
        entry: entry.unwrap_or(0),
        code_end,
    })
}

fn parse_int(s: &str, line: usize) -> Result<i64, AsmError> {
    let s = s.trim();
    let (neg, body) = match s.strip_prefix('-') {
        Some(b) => (true, b),
        None => (false, s),
    };
    let v = if let Some(hex) = body.strip_prefix("0x").or_else(|| body.strip_prefix("0X")) {
        i64::from_str_radix(hex, 16)
    } else {
        body.parse::<i64>()
    }
    .map_err(|e| AsmError::BadOperand { line, operand: s.to_string(), reason: e.to_string() })?;
    Ok(if neg { -v } else { v })
}

fn parse_value(s: &str, line: usize) -> Result<PendingValue, AsmError> {
    let s = s.trim();
    if s.is_empty() {
        return Err(AsmError::BadOperand { line, operand: s.to_string(), reason: "empty value".into() });
    }
    let body = s.strip_prefix('$').unwrap_or(s);
    if body.starts_with(|c: char| c.is_ascii_alphabetic() || c == '_') {
        Ok(PendingValue::Label(body.to_string()))
    } else {
        Ok(PendingValue::Lit(parse_int(body, line)?))
    }
}

fn parse_reg(s: &str, line: usize) -> Result<Reg, AsmError> {
    match s.trim() {
        "%eax" => Ok(Reg::Eax),
        "%ecx" => Ok(Reg::Ecx),
        "%edx" => Ok(Reg::Edx),
        "%ebx" => Ok(Reg::Ebx),
        "%esp" => Ok(Reg::Esp),
        "%ebp" => Ok(Reg::Ebp),
        "%esi" => Ok(Reg::Esi),
        "%edi" => Ok(Reg::Edi),
        "%pp" => Ok(Reg::PseudoP),
        "%pc" => Ok(Reg::PseudoC),
        other => Err(AsmError::BadOperand { line, operand: other.to_string(), reason: "not a register".into() }),
    }
}

/// Parse a `D(%reg)` or `(%reg)` memory operand.
fn parse_mem(s: &str, line: usize) -> Result<(i32, Reg), AsmError> {
    let s = s.trim();
    let open = s.find('(').ok_or_else(|| AsmError::BadOperand {
        line,
        operand: s.to_string(),
        reason: "expected D(%reg)".into(),
    })?;
    if !s.ends_with(')') {
        return Err(AsmError::BadOperand { line, operand: s.to_string(), reason: "missing `)`".into() });
    }
    let disp = if open == 0 { 0 } else { parse_int(&s[..open], line)? as i32 };
    let reg = parse_reg(&s[open + 1..s.len() - 1], line)?;
    Ok((disp, reg))
}

fn expect_count(mn: &str, ops: &[String], want: usize, line: usize) -> Result<(), AsmError> {
    if ops.len() != want {
        Err(AsmError::OperandCount { line, mnemonic: mn.to_string(), got: ops.len(), want })
    } else {
        Ok(())
    }
}

fn parse_insn(mn: &str, ops: &[String], line: usize) -> Result<PendingInsn, AsmError> {
    let cmov = |cond: CondFn| -> Result<PendingInsn, AsmError> {
        expect_count(mn, ops, 2, line)?;
        Ok(PendingInsn::Ready(Insn::CMov { cond, ra: parse_reg(&ops[0], line)?, rb: parse_reg(&ops[1], line)? }))
    };
    let jump = |cond: CondFn| -> Result<PendingInsn, AsmError> {
        expect_count(mn, ops, 1, line)?;
        Ok(PendingInsn::Jump { cond, dest: parse_value(&ops[0], line)? })
    };
    let alu = |op: OpFn| -> Result<PendingInsn, AsmError> {
        expect_count(mn, ops, 2, line)?;
        Ok(PendingInsn::Ready(Insn::Op { op, ra: parse_reg(&ops[0], line)?, rb: parse_reg(&ops[1], line)? }))
    };
    match mn {
        "halt" => Ok(PendingInsn::Ready(Insn::Halt)),
        "nop" => Ok(PendingInsn::Ready(Insn::Nop)),
        "ret" => Ok(PendingInsn::Ready(Insn::Ret)),
        "rrmovl" => cmov(CondFn::Always),
        "cmovle" => cmov(CondFn::Le),
        "cmovl" => cmov(CondFn::L),
        "cmove" => cmov(CondFn::E),
        "cmovne" => cmov(CondFn::Ne),
        "cmovge" => cmov(CondFn::Ge),
        "cmovg" => cmov(CondFn::G),
        "irmovl" => {
            expect_count(mn, ops, 2, line)?;
            Ok(PendingInsn::IrMov { value: parse_value(&ops[0], line)?, rb: parse_reg(&ops[1], line)? })
        }
        "rmmovl" => {
            expect_count(mn, ops, 2, line)?;
            let ra = parse_reg(&ops[0], line)?;
            let (disp, rb) = parse_mem(&ops[1], line)?;
            Ok(PendingInsn::Ready(Insn::RmMov { ra, rb, disp }))
        }
        "mrmovl" => {
            expect_count(mn, ops, 2, line)?;
            let (disp, rb) = parse_mem(&ops[0], line)?;
            let ra = parse_reg(&ops[1], line)?;
            Ok(PendingInsn::Ready(Insn::MrMov { ra, rb, disp }))
        }
        "addl" => alu(OpFn::Add),
        "subl" => alu(OpFn::Sub),
        "andl" => alu(OpFn::And),
        "xorl" => alu(OpFn::Xor),
        "mull" => alu(OpFn::Mul),
        "jmp" => jump(CondFn::Always),
        "jle" => jump(CondFn::Le),
        "jl" => jump(CondFn::L),
        "je" => jump(CondFn::E),
        "jne" => jump(CondFn::Ne),
        "jge" => jump(CondFn::Ge),
        "jg" => jump(CondFn::G),
        "call" => {
            expect_count(mn, ops, 1, line)?;
            Ok(PendingInsn::Call { dest: parse_value(&ops[0], line)? })
        }
        "pushl" => {
            expect_count(mn, ops, 1, line)?;
            Ok(PendingInsn::Ready(Insn::Push { ra: parse_reg(&ops[0], line)? }))
        }
        "popl" => {
            expect_count(mn, ops, 1, line)?;
            Ok(PendingInsn::Ready(Insn::Pop { ra: parse_reg(&ops[0], line)? }))
        }
        // ---- EMPA metainstructions ------------------------------------
        "qcreate" | "qcall" | "qmassfor" | "qmasssum" => {
            expect_count(mn, ops, 1, line)?;
            let meta = match mn {
                "qcreate" => MetaFn::QCreate,
                "qcall" => MetaFn::QCall,
                "qmassfor" => MetaFn::QMassFor,
                _ => MetaFn::QMassSum,
            };
            Ok(PendingInsn::Meta { meta, ra: Reg::None, rb: Reg::None, value: parse_value(&ops[0], line)? })
        }
        "qprealloc" => {
            expect_count(mn, ops, 1, line)?;
            Ok(PendingInsn::Meta {
                meta: MetaFn::QPreAlloc,
                ra: Reg::None,
                rb: Reg::None,
                value: parse_value(&ops[0], line)?,
            })
        }
        "qterm" => {
            // optional link register: `qterm %eax` clones %eax back (§3.5)
            let ra = if ops.is_empty() { Reg::None } else { parse_reg(&ops[0], line)? };
            Ok(PendingInsn::Meta { meta: MetaFn::QTerm, ra, rb: Reg::None, value: PendingValue::Lit(0) })
        }
        "qwait" => {
            // optional destination register: `qwait %eax` drains FromChild
            let ra = if ops.is_empty() { Reg::None } else { parse_reg(&ops[0], line)? };
            Ok(PendingInsn::Meta { meta: MetaFn::QWait, ra, rb: Reg::None, value: PendingValue::Lit(0) })
        }
        "qcopy" => Ok(PendingInsn::Meta { meta: MetaFn::QCopy, ra: Reg::None, rb: Reg::None, value: PendingValue::Lit(0) }),
        other => Err(AsmError::UnknownMnemonic { line, mnemonic: other.to_string() }),
    }
}

/// Listing 1 of the paper, verbatim layout (used by tests across the
/// crate as the canonical N=4 conventional program).
pub const LISTING1: &str = r#"
# This is summing up elements of vector
    .pos 0
    irmovl $4, %edx      # No of items to sum
    irmovl array, %ecx   # Array address
    xorl %eax, %eax      # sum = 0
    andl %edx, %edx      # Set condition codes
    je End
Loop:
    mrmovl (%ecx), %esi  # get *Start
    addl %esi, %eax      # add to sum
    irmovl $4, %ebx
    addl %ebx, %ecx      # Start++
    irmovl $-1, %ebx
    addl %ebx, %edx      # Count--
    jne Loop             # Stop when 0
End:
    halt
    .align 4
array:
    .long 0xd
    .long 0xc0
    .long 0x0b00
    .long 0xa000
"#;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn listing1_layout_matches_paper_addresses() {
        let p = assemble(LISTING1).unwrap();
        // Addresses printed in Listing 1.
        assert_eq!(p.symbol("Loop"), Some(0x015));
        assert_eq!(p.symbol("End"), Some(0x032));
        assert_eq!(p.symbol("array"), Some(0x034));
        assert_eq!(p.entry, 0);
        // Byte-exact encodings from the listing.
        assert_eq!(&p.image[0x000..0x006], &[0x30, 0xF2, 0x04, 0, 0, 0]);
        assert_eq!(&p.image[0x006..0x00c], &[0x30, 0xF1, 0x34, 0, 0, 0]);
        assert_eq!(&p.image[0x00c..0x00e], &[0x63, 0x00]);
        assert_eq!(&p.image[0x00e..0x010], &[0x62, 0x22]);
        assert_eq!(&p.image[0x010..0x015], &[0x73, 0x32, 0, 0, 0]);
        assert_eq!(&p.image[0x015..0x01b], &[0x50, 0x61, 0, 0, 0, 0]);
        assert_eq!(p.image[0x032], 0x00); // halt
        assert_eq!(&p.image[0x034..0x038], &0x0d_i32.to_le_bytes());
        assert_eq!(&p.image[0x040..0x044], &0xa000_i32.to_le_bytes());
    }

    #[test]
    fn empa_mnemonics_assemble() {
        let src = r#"
    qprealloc $1
    qmassfor Body
    halt
Body:
    mrmovl (%ecx), %esi
    addl %esi, %eax
    qterm %eax
"#;
        let p = assemble(src).unwrap();
        let body = p.symbol("Body").unwrap();
        // qprealloc: E4 FF + value 1
        assert_eq!(&p.image[0..2], &[0xE4, 0xFF]);
        assert_eq!(&p.image[2..6], &1u32.to_le_bytes());
        // qmassfor: E5 FF + Body addr
        assert_eq!(p.image[6], 0xE5);
        assert_eq!(&p.image[8..12], &body.to_le_bytes());
        // qterm %eax: E2 0F
        let qterm_at = body as usize + 6 + 2;
        assert_eq!(&p.image[qterm_at..qterm_at + 2], &[0xE2, 0x0F]);
    }

    #[test]
    fn pseudo_register_operands() {
        let p = assemble("addl %esi, %pp\n").unwrap();
        assert_eq!(&p.image[..2], &[0x60, 0x68]);
        let p = assemble("rrmovl %pc, %eax\n").unwrap();
        assert_eq!(&p.image[..2], &[0x20, 0x90]);
    }

    #[test]
    fn errors_are_reported_with_lines() {
        assert!(matches!(
            assemble("bogus %eax\n").unwrap_err(),
            AsmError::UnknownMnemonic { line: 1, .. }
        ));
        assert!(matches!(
            assemble("\n jmp Nowhere\n").unwrap_err(),
            AsmError::UndefinedLabel { line: 2, .. }
        ));
        assert!(matches!(
            assemble("a:\na:\n").unwrap_err(),
            AsmError::DuplicateLabel { line: 2, .. }
        ));
        assert!(matches!(
            assemble(".pos 8\n.pos 4\n").unwrap_err(),
            AsmError::BadDirective { line: 2, .. }
        ));
        assert!(matches!(
            assemble("addl %eax\n").unwrap_err(),
            AsmError::OperandCount { line: 1, .. }
        ));
    }

    #[test]
    fn align_and_pos() {
        let p = assemble(".pos 3\n.align 4\nx: .long 7\n").unwrap();
        assert_eq!(p.symbol("x"), Some(4));
        assert_eq!(&p.image[4..8], &7i32.to_le_bytes());
    }

    #[test]
    fn data_layout_records_long_runs_split_at_labels() {
        let p = assemble(
            "    halt\n    .align 4\na:\n    .long 1\n    .long 2\nb:\n    .long 3\n",
        )
        .unwrap();
        let a = p.data_span("a").unwrap();
        assert_eq!((a.addr, a.words), (4, 2), "run stops at label b");
        let b = p.data_span("b").unwrap();
        assert_eq!((b.addr, b.words), (12, 1));
        // code labels carry no data span
        let p = assemble("Loop:\n    jmp Loop\n").unwrap();
        assert_eq!(p.data_span("Loop"), None);
    }

    #[test]
    fn data_layout_splits_non_contiguous_runs() {
        let p = assemble("x:\n    .long 1\n    .pos 16\n    .long 2\n").unwrap();
        let x = p.data_span("x").unwrap();
        assert_eq!((x.addr, x.words), (0, 1), "gap ends the run");
    }

    #[test]
    fn patch_into_rewrites_data_only_within_the_span() {
        let p = assemble(
            "    halt\n    .align 4\narray:\n    .long 0\n    .long 0\nnext:\n    .long 9\n",
        )
        .unwrap();
        let mut image = p.image.clone();
        p.patch_into(&mut image, "array", &[5, -6]).unwrap();
        assert_eq!(&image[4..8], &5i32.to_le_bytes());
        assert_eq!(&image[8..12], &(-6i32).to_le_bytes());
        assert_eq!(image[0], p.image[0], "code untouched");
        assert_eq!(&image[12..16], &9i32.to_le_bytes(), "neighbour span untouched");
        // partial patches are fine; oversized ones are typed errors
        p.patch_into(&mut image, "array", &[1]).unwrap();
        assert_eq!(
            p.patch_into(&mut image, "array", &[1, 2, 3]),
            Err(PatchError::Oversized { symbol: "array".into(), words: 2, got: 3 })
        );
        assert_eq!(
            p.patch_into(&mut image, "nowhere", &[1]),
            Err(PatchError::NoSpan("nowhere".into()))
        );
        // an image shorter than the span is refused, not sliced OOB
        let mut short = vec![0u8; 6];
        assert!(matches!(
            p.patch_into(&mut short, "array", &[1, 2]),
            Err(PatchError::OutOfImage { .. })
        ));
    }

    #[test]
    fn code_end_marks_the_last_instruction_byte() {
        let p = assemble("    halt\n    .align 4\narray:\n    .long 1\n    .long 2\n").unwrap();
        assert_eq!(p.code_end, 1, "one-byte halt");
        assert!(p.data_span("array").unwrap().addr >= p.code_end, "data sits above code");
        let p = assemble("    irmovl $7, %eax\n    halt\n").unwrap();
        assert_eq!(p.code_end, 7, "6-byte irmovl + 1-byte halt");
        let p = assemble("x:\n    .long 3\n").unwrap();
        assert_eq!(p.code_end, 0, "no instructions, no code");
    }

    #[test]
    fn patch_mem_writes_the_span_into_live_memory() {
        use crate::mem::Memory;
        let p = assemble(
            "    halt\n    .align 4\narray:\n    .long 0\n    .long 0\nnext:\n    .long 9\n",
        )
        .unwrap();
        let mut mem = Memory::with_image(64, &p.image);
        p.patch_mem(&mut mem, "array", &[5, -6]).unwrap();
        assert_eq!(mem.read_u32(4).unwrap(), 5);
        assert_eq!(mem.read_u32(8).unwrap(), -6i32 as u32);
        assert_eq!(mem.read_u32(12).unwrap(), 9, "neighbour span untouched");
        assert_eq!(
            p.patch_mem(&mut mem, "array", &[1, 2, 3]),
            Err(PatchError::Oversized { symbol: "array".into(), words: 2, got: 3 })
        );
        assert_eq!(
            p.patch_mem(&mut mem, "nowhere", &[1]),
            Err(PatchError::NoSpan("nowhere".into()))
        );
        // a memory shorter than the span is refused, not sliced OOB
        let mut short = Memory::new(6);
        assert!(matches!(
            p.patch_mem(&mut short, "array", &[1, 2]),
            Err(PatchError::OutOfImage { .. })
        ));
        // patching through memory matches patching through the image
        let mut image = p.image.clone();
        p.patch_into(&mut image, "array", &[5, -6]).unwrap();
        let direct = Memory::with_image(64, &image);
        for a in (0..16).step_by(4) {
            assert_eq!(mem.read_u32(a).unwrap(), direct.read_u32(a).unwrap());
        }
    }

    #[test]
    fn patched_placeholder_equals_direct_assembly() {
        // The compile-once invariant at the assembler level: zero
        // placeholders patched with values give the same bytes as
        // assembling the values directly.
        let tpl = assemble("    halt\n    .align 4\nv:\n    .long 0\n    .long 0\n").unwrap();
        let direct =
            assemble("    halt\n    .align 4\nv:\n    .long 13\n    .long -2\n").unwrap();
        let mut image = tpl.image.clone();
        tpl.patch_into(&mut image, "v", &[13, -2]).unwrap();
        assert_eq!(image, direct.image);
    }
}
