//! `.yo` object-file reader/writer (the CS:APP listing format the paper's
//! Listing 1 is printed in: `0xADDR: BYTES | source`).

use super::asm::Program;
use std::fmt::Write as _;

/// Serialise a [`Program`] into `.yo` listing text.
pub fn to_yo(p: &Program) -> String {
    let mut out = String::new();
    for (addr, _line, text) in &p.lines {
        // find extent: bytes until next line's address (or image end)
        let next = p
            .lines
            .iter()
            .map(|(a, _, _)| *a)
            .filter(|a| a > addr)
            .min()
            .unwrap_or(p.image.len() as u32);
        let bytes = &p.image[*addr as usize..(next as usize).min(p.image.len())];
        let hex: String = bytes.iter().fold(String::new(), |mut s, b| {
            let _ = write!(s, "{b:02x}");
            s
        });
        let _ = writeln!(out, "0x{addr:03x}: {hex:<14} | {text}");
    }
    out
}

/// Parse `.yo` listing text back into a memory image.
///
/// Lines look like `0x015: 506100000000 | mrmovl (%ecx), %esi`; lines
/// without a `0xADDR:` prefix are ignored (comments, blank separator rows).
pub fn from_yo(text: &str) -> Result<Vec<u8>, String> {
    let mut image = Vec::new();
    for (lineno0, line) in text.lines().enumerate() {
        let line = line.trim();
        let Some(rest) = line.strip_prefix("0x") else { continue };
        let Some(colon) = rest.find(':') else { continue };
        let addr = u32::from_str_radix(&rest[..colon], 16)
            .map_err(|e| format!("line {}: bad address: {e}", lineno0 + 1))?;
        let bytes_part = rest[colon + 1..].split('|').next().unwrap_or("").trim();
        if bytes_part.is_empty() {
            continue;
        }
        if bytes_part.len() % 2 != 0 {
            return Err(format!("line {}: odd hex digit count", lineno0 + 1));
        }
        let end = addr as usize + bytes_part.len() / 2;
        if image.len() < end {
            image.resize(end, 0);
        }
        for (i, chunk) in bytes_part.as_bytes().chunks(2).enumerate() {
            let s = std::str::from_utf8(chunk).unwrap();
            let b = u8::from_str_radix(s, 16).map_err(|e| format!("line {}: bad hex: {e}", lineno0 + 1))?;
            image[addr as usize + i] = b;
        }
    }
    Ok(image)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::assemble;

    #[test]
    fn yo_roundtrip() {
        let src = "\
    irmovl $4, %edx
    irmovl array, %ecx
    xorl %eax, %eax
    halt
    .align 4
array:
    .long 0xd
    .long 0xc0
";
        let p = assemble(src).unwrap();
        let yo = to_yo(&p);
        let image = from_yo(&yo).unwrap();
        assert_eq!(image.len(), p.image.len());
        assert_eq!(image, p.image);
    }

    #[test]
    fn from_yo_ignores_prose_lines() {
        let text = "# a comment\n\n0x000: 10 | nop\nnot a record\n0x001: 00 | halt\n";
        let image = from_yo(text).unwrap();
        assert_eq!(image, vec![0x10, 0x00]);
    }

    #[test]
    fn from_yo_rejects_bad_hex() {
        assert!(from_yo("0x000: 1g | nop\n").is_err());
        assert!(from_yo("0x000: 123 | nop\n").is_err());
        assert!(from_yo("0xzz: 10 | nop\n").is_err());
    }
}
