//! `loadgen` — open-loop, multi-tenant load generator for the serve plane.
//!
//! Spawns one sender/receiver pair per simulated tenant, each on its own
//! TCP connection, and replays a [`TraceGen`] arrival schedule (mixed
//! mass ops and program runs) against the wire protocol. Tenant 0 is
//! "hot": it arrives at `--hot-factor` times the base rate, so with a
//! server-side quota between the two rates it demonstrates per-tenant
//! isolation — the hot tenant eats quota denials while the in-SLO
//! tenants keep completing.
//!
//! Open loop means arrivals follow the schedule regardless of
//! completions: latency under overload is measured honestly instead of
//! being hidden by closed-loop self-throttling.
//!
//! ```text
//! loadgen [--addr HOST:PORT] [--tenants N] [--rate R] [--hot-factor F]
//!         [--secs S] [--seed SEED] [--workers N] [--queue-cap N]
//!         [--quota RATE[:BURST]] [--quick]
//!         [--chaos SEED [--fault-rate P]]
//! ```
//!
//! Without `--addr` an in-process [`ServePlane`] is spawned on an
//! ephemeral loopback port — still exercised over real TCP. `--quick`
//! applies a small CI preset and asserts the accounting invariants
//! (every submit answered; hot tenant denied; in-SLO tenants complete),
//! exiting nonzero on violation.
//!
//! `--chaos SEED` switches to **chaosgen**: a loopback server is armed
//! with deterministic fault injection at every site (`--fault-rate P`,
//! default 0.1) and each tenant drives a closed loop through the typed
//! retry machinery ([`WireClient::call_with_retry`]). The run asserts
//! the accounting identity closes — every submitted job ends in exactly
//! one of {completed first try, retried-then-completed, typed error} —
//! with zero hangs and zero escaped panics, and prints the server's
//! [`FaultPlan`](empa::chaos::FaultPlan) summary for replay.

use empa::api::{FabricError, RetryPolicy};
use empa::chaos::ChaosConfig;
use empa::coordinator::FabricConfig;
use empa::serve::{QuotaConfig, ServeConfig, ServePlane, SloConfig, WireClient, WireReply};
use empa::util::Summary;
use empa::workload::{Request, TraceConfig, TraceGen};
use std::process::ExitCode;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

fn main() -> ExitCode {
    match run(std::env::args().skip(1).collect()) {
        Ok(true) => ExitCode::SUCCESS,
        Ok(false) => ExitCode::FAILURE,
        Err(e) => {
            eprintln!("loadgen: {e:#}");
            ExitCode::FAILURE
        }
    }
}

struct Opts {
    addr: Option<String>,
    tenants: usize,
    rate: f64,
    hot_factor: f64,
    secs: f64,
    seed: u64,
    workers: usize,
    queue_cap: usize,
    quota: Option<(f64, f64)>,
    quick: bool,
    chaos: Option<u64>,
    fault_rate: f64,
}

impl Default for Opts {
    fn default() -> Self {
        Opts {
            addr: None,
            tenants: 3,
            rate: 200.0,
            hot_factor: 4.0,
            secs: 2.0,
            seed: 42,
            workers: 4,
            queue_cap: 256,
            quota: None,
            quick: false,
            chaos: None,
            fault_rate: 0.1,
        }
    }
}

/// `RATE[:BURST]` — burst defaults to the rate.
fn parse_shape(s: &str) -> anyhow::Result<(f64, f64)> {
    let (rate, burst) = match s.split_once(':') {
        Some((r, b)) => (r.parse::<f64>()?, b.parse::<f64>()?),
        None => {
            let r = s.parse::<f64>()?;
            (r, r)
        }
    };
    anyhow::ensure!(rate >= 0.0 && burst >= 0.0, "quota shape must be non-negative");
    Ok((rate, burst))
}

fn parse(args: Vec<String>) -> anyhow::Result<Option<Opts>> {
    let mut o = Opts::default();
    let mut it = args.into_iter();
    while let Some(flag) = it.next() {
        let mut val =
            || it.next().ok_or_else(|| anyhow::anyhow!("flag `{flag}` needs a value"));
        match flag.as_str() {
            "--addr" => o.addr = Some(val()?),
            "--tenants" => o.tenants = val()?.parse()?,
            "--rate" => o.rate = val()?.parse()?,
            "--hot-factor" => o.hot_factor = val()?.parse()?,
            "--secs" => o.secs = val()?.parse()?,
            "--seed" => o.seed = val()?.parse()?,
            "--workers" => o.workers = val()?.parse()?,
            "--queue-cap" => o.queue_cap = val()?.parse()?,
            "--quota" => o.quota = Some(parse_shape(&val()?)?),
            "--chaos" => o.chaos = Some(val()?.parse()?),
            "--fault-rate" => o.fault_rate = val()?.parse()?,
            "--quick" => {
                // CI smoke preset: ~1 s window, small payloads, a quota
                // that admits the base rate but not the hot tenant.
                o.quick = true;
                o.tenants = 3;
                o.rate = 150.0;
                o.hot_factor = 4.0;
                o.secs = 1.0;
            }
            "--help" | "-h" => {
                println!(
                    "loadgen [--addr HOST:PORT] [--tenants N] [--rate R] \
                     [--hot-factor F] [--secs S] [--seed SEED] [--workers N] \
                     [--queue-cap N] [--quota RATE[:BURST]] [--quick] \
                     [--chaos SEED [--fault-rate P]]"
                );
                return Ok(None);
            }
            other => anyhow::bail!("unknown flag `{other}`; try --help"),
        }
    }
    anyhow::ensure!(o.tenants >= 1, "--tenants must be at least 1");
    anyhow::ensure!(o.rate > 0.0 && o.secs > 0.0, "--rate and --secs must be positive");
    anyhow::ensure!((0.0..=1.0).contains(&o.fault_rate), "--fault-rate must be in [0, 1]");
    Ok(Some(o))
}

/// Per-tenant outcome counters plus the completed-request latency sample.
#[derive(Default)]
struct Counts {
    ok: usize,
    quota_denied: usize,
    shed: usize,
    queue_full: usize,
    failed_other: usize,
    lat_us: Vec<f64>,
}

struct TenantReport {
    name: &'static str,
    hot: bool,
    sent: usize,
    counts: Counts,
    wall: Duration,
}

impl TenantReport {
    fn answered(&self) -> usize {
        let c = &self.counts;
        c.ok + c.quota_denied + c.shed + c.queue_full + c.failed_other
    }
}

/// Replay one tenant's trace: a writer on this thread paced by the
/// arrival schedule, a reader thread draining replies on a clone of the
/// same socket. Returns once every sent request has been answered.
fn drive_tenant(
    addr: &str,
    name: &'static str,
    hot: bool,
    trace: Vec<Request>,
    start: Instant,
) -> anyhow::Result<TenantReport> {
    let mut tx = WireClient::connect(addr)?;
    let mut rx = tx.try_clone()?;

    let planned = trace.len();
    // Submit instants, indexed by wire id - 1 (ids are assigned
    // monotonically from 1, in submission order). Pushed *before* the
    // submit so a fast reply can never observe a missing slot; on a
    // send failure `expect` is rolled back to the count actually sent.
    let send_times: Arc<Mutex<Vec<Instant>>> = Arc::new(Mutex::new(Vec::with_capacity(planned)));
    let expect = Arc::new(AtomicUsize::new(planned));

    let reader = {
        let send_times = Arc::clone(&send_times);
        let expect = Arc::clone(&expect);
        std::thread::spawn(move || -> anyhow::Result<Counts> {
            let mut c = Counts::default();
            let mut got = 0usize;
            while got < expect.load(Ordering::Acquire) {
                let Some(reply) = rx.recv()? else {
                    anyhow::bail!("server closed with {got} of {} replies received", planned)
                };
                got += 1;
                match reply {
                    WireReply::Completed { id, .. } => {
                        c.ok += 1;
                        let slot = (id as usize).checked_sub(1);
                        let sent_at =
                            slot.and_then(|s| send_times.lock().unwrap().get(s).copied());
                        if let Some(t) = sent_at {
                            c.lat_us.push(t.elapsed().as_micros() as f64);
                        }
                    }
                    WireReply::Failed { error, .. } => match error {
                        FabricError::QuotaExceeded { .. } => c.quota_denied += 1,
                        FabricError::Overloaded { .. } => c.shed += 1,
                        FabricError::QueueFull => c.queue_full += 1,
                        _ => c.failed_other += 1,
                    },
                    WireReply::MetricsText { .. } => {
                        anyhow::bail!("unexpected metrics reply on a load connection")
                    }
                }
            }
            Ok(c)
        })
    };

    let mut sent = 0usize;
    for req in &trace {
        let target = start + Duration::from_micros(req.arrival_us);
        let now = Instant::now();
        if target > now {
            std::thread::sleep(target - now);
        }
        send_times.lock().unwrap().push(Instant::now());
        if let Err(e) = tx.submit(&req.job) {
            eprintln!("loadgen: tenant {name}: send failed after {sent} requests: {e:#}");
            expect.store(sent, Ordering::Release);
            break;
        }
        sent += 1;
    }
    expect.store(sent, Ordering::Release);

    let counts = reader
        .join()
        .map_err(|_| anyhow::anyhow!("tenant {name}: reader thread panicked"))??;
    Ok(TenantReport { name, hot, sent, counts, wall: start.elapsed() })
}

/// Chaosgen per-tenant outcome counters. The accounting identity is
/// `sent == ok_first + ok_retried + typed_err` — every submitted job
/// ends in exactly one bucket, no hangs, no escaped panics.
#[derive(Default)]
struct ChaosCounts {
    sent: usize,
    ok_first: usize,
    ok_retried: usize,
    typed_err: usize,
}

/// One chaosgen tenant: a closed loop (submit, settle, next) through
/// the typed retry machinery against a fault-injecting server.
fn drive_chaos_tenant(
    addr: &str,
    name: &'static str,
    trace: Vec<Request>,
) -> anyhow::Result<ChaosCounts> {
    let mut client = WireClient::connect(addr)?;
    let policy = RetryPolicy::default().with_attempts(4);
    let mut c = ChaosCounts::default();
    for req in &trace {
        c.sent += 1;
        // First attempt by hand so first-try and retried completions
        // land in different buckets; the retry ladder takes over on any
        // retryable typed error or transport fault.
        match client.call(&req.job) {
            Ok(Ok(_)) => c.ok_first += 1,
            Ok(Err(e)) if !e.retryable() => c.typed_err += 1,
            first => {
                if first.is_err() {
                    client.reconnect()?;
                }
                match client.call_with_retry(&req.job, &policy) {
                    Ok(Ok(_)) => c.ok_retried += 1,
                    Ok(Err(_)) => c.typed_err += 1,
                    Err(_) => {
                        // Transport attempts exhausted: a typed outcome
                        // for the identity, and a fresh socket for the
                        // next request.
                        c.typed_err += 1;
                        client.reconnect()?;
                    }
                }
            }
        }
    }
    Ok(c)
}

/// The chaosgen mode: loopback server with every fault site armed,
/// closed-loop tenants driving the retry ladder, and a hard assertion
/// that the accounting identity closes.
fn run_chaos(o: &Opts, chaos_seed: u64) -> anyhow::Result<bool> {
    anyhow::ensure!(
        o.addr.is_none(),
        "--chaos drives an in-process loopback server; drop --addr"
    );
    let mut fabric =
        FabricConfig { sim_workers: o.workers, queue_cap: o.queue_cap, ..Default::default() };
    fabric.chaos = ChaosConfig::uniform(chaos_seed, o.fault_rate);
    let slo = SloConfig::for_queue_cap(o.queue_cap);
    let plane = ServePlane::start(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        fabric,
        quota: QuotaConfig::default(),
        slo,
        ..Default::default()
    })?;
    let addr = plane.local_addr().to_string();

    let per_tenant = if o.quick { 40 } else { (o.rate * o.secs).round().max(1.0) as usize };
    println!(
        "chaosgen: {} tenants x {per_tenant} jobs over {addr}, \
         chaos seed {chaos_seed}, fault rate {}",
        o.tenants, o.fault_rate
    );

    let handles: Vec<_> = (0..o.tenants)
        .map(|i| {
            let name: &'static str = Box::leak(format!("t{i}").into_boxed_str());
            let cfg = TraceConfig {
                seed: o.seed.wrapping_add(i as u64),
                num_requests: per_tenant,
                mean_gap_us: 100,
                mass_fraction: 0.5,
                mass_len: (16, 64),
                program_len: (1, 8),
                high_priority_fraction: 0.1,
                deadline: Some(Duration::from_secs(5)),
                client: Some(name),
            };
            let trace = TraceGen::new(cfg).generate();
            let addr = addr.clone();
            std::thread::spawn(move || (name, drive_chaos_tenant(&addr, name, trace)))
        })
        .collect();

    let mut pass = true;
    let mut check = |ok: bool, msg: String| {
        if !ok {
            pass = false;
            eprintln!("chaosgen: FAIL: {msg}");
        }
    };
    for h in handles {
        // A panicked tenant thread is itself an identity violation.
        let Ok((name, result)) = h.join() else {
            check(false, "tenant thread panicked".to_string());
            continue;
        };
        match result {
            Ok(c) => {
                println!(
                    "tenant {name}: sent={} ok_first={} ok_retried={} typed_err={}",
                    c.sent, c.ok_first, c.ok_retried, c.typed_err
                );
                check(
                    c.sent == per_tenant && c.sent == c.ok_first + c.ok_retried + c.typed_err,
                    format!(
                        "tenant {name}: identity open: sent={} != {}+{}+{}",
                        c.sent, c.ok_first, c.ok_retried, c.typed_err
                    ),
                );
            }
            Err(e) => check(false, format!("tenant {name}: driver error: {e:#}")),
        }
    }

    // Server-side view: the chaos/retry metric lines plus the fault
    // plan the seed produced (rerunning the same seed replays it).
    let metrics = WireClient::connect(&addr).and_then(|mut c| c.metrics());
    match metrics {
        Ok(text) => println!("server metrics:\n{text}"),
        Err(e) => eprintln!("chaosgen: metrics fetch failed: {e:#}"),
    }
    if let Some(engine) = plane.fabric().chaos() {
        println!(
            "chaos plan: {} ({} faults injected)",
            engine.plan().summary(),
            engine.total_injected()
        );
    }
    plane.shutdown();
    if pass {
        println!("chaosgen: PASS (accounting identity closed)");
    }
    Ok(pass)
}

fn run(args: Vec<String>) -> anyhow::Result<bool> {
    let Some(o) = parse(args)? else { return Ok(true) };
    if let Some(chaos_seed) = o.chaos {
        return run_chaos(&o, chaos_seed);
    }

    // Server-side quota default: between the base rate and the hot rate,
    // so plain tenants fit and the hot one visibly does not.
    let (qrate, qburst) = o.quota.unwrap_or((o.rate * 1.5, 16.0));
    let plane = match &o.addr {
        Some(_) => None,
        None => {
            let fabric =
                FabricConfig { sim_workers: o.workers, queue_cap: o.queue_cap, ..Default::default() };
            let slo = SloConfig::for_queue_cap(o.queue_cap);
            let quota = QuotaConfig::uniform(qrate, qburst);
            Some(ServePlane::start(ServeConfig {
                addr: "127.0.0.1:0".to_string(),
                fabric,
                quota,
                slo,
                ..Default::default()
            })?)
        }
    };
    let addr = match (&o.addr, &plane) {
        (Some(a), _) => a.clone(),
        (None, Some(p)) => p.local_addr().to_string(),
        (None, None) => unreachable!(),
    };

    println!(
        "loadgen: {} tenants over {addr}, window {:.1}s, base rate {:.0}/s \
         (tenant t0 hot at x{:.0}), server quota {qrate:.0}:{qburst:.0}",
        o.tenants, o.secs, o.rate, o.hot_factor
    );

    // Per-tenant traces: tenant 0 arrives hot_factor times faster AND
    // sends proportionally more requests over the same wall window.
    let traces: Vec<(&'static str, bool, Vec<Request>)> = (0..o.tenants)
        .map(|i| {
            let name: &'static str = Box::leak(format!("t{i}").into_boxed_str());
            let hot = i == 0 && o.tenants > 1;
            let rate = if hot { o.rate * o.hot_factor } else { o.rate };
            let cfg = TraceConfig {
                seed: o.seed.wrapping_add(i as u64),
                num_requests: (rate * o.secs).round() as usize,
                mean_gap_us: (1e6 / rate) as u64,
                mass_fraction: 0.5,
                mass_len: if o.quick { (16, 64) } else { (64, 512) },
                program_len: if o.quick { (1, 8) } else { (1, 24) },
                high_priority_fraction: 0.1,
                deadline: None,
                client: Some(name),
            };
            (name, hot, TraceGen::new(cfg).generate())
        })
        .collect();

    let start = Instant::now();
    let handles: Vec<_> = traces
        .into_iter()
        .map(|(name, hot, trace)| {
            let addr = addr.clone();
            std::thread::spawn(move || drive_tenant(&addr, name, hot, trace, start))
        })
        .collect();
    let mut reports = Vec::new();
    for h in handles {
        reports.push(h.join().map_err(|_| anyhow::anyhow!("tenant thread panicked"))??);
    }
    reports.sort_by_key(|r| r.name);

    for r in &reports {
        let c = &r.counts;
        let lat = Summary::of(&c.lat_us);
        let goodput = c.ok as f64 / r.wall.as_secs_f64();
        println!(
            "tenant {}{}: sent={} ok={} quota_denied={} shed={} queue_full={} failed={}",
            r.name,
            if r.hot { " (hot)" } else { "" },
            r.sent,
            c.ok,
            c.quota_denied,
            c.shed,
            c.queue_full,
            c.failed_other
        );
        println!("  latency_us: {lat}");
        println!("  goodput: {goodput:.1} req/s over {:.2}s", r.wall.as_secs_f64());
    }

    // Server-side view, over the wire like any other client.
    let metrics = WireClient::connect(&addr).and_then(|mut c| c.metrics());
    match metrics {
        Ok(text) => println!("server metrics:\n{text}"),
        Err(e) => eprintln!("loadgen: metrics fetch failed: {e:#}"),
    }
    if let Some(p) = plane {
        p.shutdown();
    }

    if !o.quick {
        return Ok(true);
    }
    // Timing-robust invariants only: exact latencies and deny ratios
    // vary with load, but accounting must always close.
    let mut pass = true;
    let mut check = |ok: bool, msg: String| {
        if !ok {
            pass = false;
            eprintln!("loadgen --quick: FAIL: {msg}");
        }
    };
    for r in &reports {
        check(
            r.answered() == r.sent,
            format!("tenant {}: {} answered of {} sent", r.name, r.answered(), r.sent),
        );
        if r.hot {
            check(
                r.counts.quota_denied > 0,
                format!("hot tenant {} saw no quota denials", r.name),
            );
        } else {
            check(r.counts.ok >= 1, format!("in-SLO tenant {} completed nothing", r.name));
            // Loose liveness bound, not a performance assertion.
            let lat = Summary::of(&r.counts.lat_us);
            check(
                lat.p99 < 10_000_000.0,
                format!("tenant {}: p99 {:.0}us exceeds 10s liveness bound", r.name, lat.p99),
            );
        }
    }
    if pass {
        println!("loadgen --quick: PASS");
    }
    Ok(pass)
}
