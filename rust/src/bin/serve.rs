//! `serve` — the EMPA fabric's TCP front door as a standalone binary.
//!
//! Binds a [`ServePlane`] (wire protocol + per-tenant quotas + SLO
//! governor over the fabric) and runs until the configured duration
//! elapses (or forever with `--secs 0`), then prints the fabric metrics
//! and the live SLO playbook. Hand-rolled flag parsing — the offline
//! image has no clap.
//!
//! ```text
//! serve [--addr HOST:PORT] [--workers N] [--sim-threads N]
//!       [--sim-span-batch N] [--queue-cap N]
//!       [--quota RATE[:BURST]] [--tenant TAG=RATE[:BURST]]...
//!       [--max-frame BYTES] [--secs S]
//!       [--auth-token TOKEN] [--chaos SEED] [--fault-rate P]
//! ```
//!
//! `--quota` sets the default token-bucket shape for every tenant;
//! `--tenant` overrides one tag. Omitted burst defaults to the rate
//! (a one-second burst window). `--sim-threads N` steps each worker's
//! simulated processor with N host threads (`StepMode::ParallelA`);
//! 1 (the default) keeps the serial event-horizon scheduler.
//! `--sim-span-batch N` caps how many consecutive clocks a parallel
//! span may batch (1 disables batching; only meaningful with
//! `--sim-threads >= 2`). `--auth-token` requires every submit to carry
//! the same shared secret. `--chaos SEED` arms deterministic fault
//! injection across every site at `--fault-rate` (default 0.1).

use empa::coordinator::FabricConfig;
use empa::serve::{QuotaConfig, ServeConfig, ServePlane, SloConfig, MAX_FRAME};
use std::process::ExitCode;
use std::time::Duration;

fn main() -> ExitCode {
    match run(std::env::args().skip(1).collect()) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("serve: {e:#}");
            ExitCode::FAILURE
        }
    }
}

/// `RATE[:BURST]` — burst defaults to the rate.
fn parse_shape(s: &str) -> anyhow::Result<(f64, f64)> {
    let (rate, burst) = match s.split_once(':') {
        Some((r, b)) => (r.parse::<f64>()?, b.parse::<f64>()?),
        None => {
            let r = s.parse::<f64>()?;
            (r, r)
        }
    };
    anyhow::ensure!(rate >= 0.0 && burst >= 0.0, "quota shape must be non-negative");
    Ok((rate, burst))
}

fn run(args: Vec<String>) -> anyhow::Result<()> {
    let mut addr = "127.0.0.1:0".to_string();
    let mut workers = 4usize;
    let mut sim_threads = 1usize;
    let mut sim_span_batch: Option<usize> = None;
    let mut queue_cap = 256usize;
    let mut quota = QuotaConfig::default();
    let mut max_frame = MAX_FRAME;
    let mut secs = 0u64;
    let mut auth_token: Option<String> = None;
    let mut chaos_seed: Option<u64> = None;
    let mut fault_rate = 0.1f64;

    let mut it = args.into_iter();
    while let Some(flag) = it.next() {
        let mut val = || {
            it.next().ok_or_else(|| anyhow::anyhow!("flag `{flag}` needs a value"))
        };
        match flag.as_str() {
            "--addr" => addr = val()?,
            "--workers" => workers = val()?.parse()?,
            "--sim-threads" => sim_threads = val()?.parse()?,
            "--sim-span-batch" => sim_span_batch = Some(val()?.parse()?),
            "--queue-cap" => queue_cap = val()?.parse()?,
            "--quota" => {
                let (r, b) = parse_shape(&val()?)?;
                quota.default_rate = r;
                quota.default_burst = b;
            }
            "--tenant" => {
                let spec = val()?;
                let (tag, shape) = spec
                    .split_once('=')
                    .ok_or_else(|| anyhow::anyhow!("--tenant wants TAG=RATE[:BURST]"))?;
                let (r, b) = parse_shape(shape)?;
                quota = quota.with_override(tag, r, b);
            }
            "--max-frame" => max_frame = val()?.parse()?,
            "--secs" => secs = val()?.parse()?,
            "--auth-token" => auth_token = Some(val()?),
            "--chaos" => chaos_seed = Some(val()?.parse()?),
            "--fault-rate" => fault_rate = val()?.parse()?,
            "--help" | "-h" => {
                println!(
                    "serve [--addr HOST:PORT] [--workers N] [--sim-threads N] \
                     [--sim-span-batch N] [--queue-cap N] \
                     [--quota RATE[:BURST]] [--tenant TAG=RATE[:BURST]]... \
                     [--max-frame BYTES] [--secs S (0 = forever)] \
                     [--auth-token TOKEN] [--chaos SEED] [--fault-rate P]"
                );
                return Ok(());
            }
            other => anyhow::bail!("unknown flag `{other}`; try --help"),
        }
    }

    let mut fabric = FabricConfig { sim_workers: workers, queue_cap, ..Default::default() };
    if sim_threads >= 2 {
        fabric.empa.step = empa::empa::StepMode::ParallelA { threads: sim_threads };
    }
    if let Some(batch) = sim_span_batch {
        anyhow::ensure!(batch >= 1, "--sim-span-batch must be >= 1 (1 disables batching)");
        fabric.empa.span_batch = batch;
    }
    if let Some(seed) = chaos_seed {
        anyhow::ensure!((0.0..=1.0).contains(&fault_rate), "--fault-rate must be in [0, 1]");
        fabric.chaos = empa::chaos::ChaosConfig::uniform(seed, fault_rate);
        println!("serve: chaos armed (seed {seed}, fault rate {fault_rate})");
    }
    let slo = SloConfig::for_queue_cap(queue_cap);
    let plane = ServePlane::start(ServeConfig { addr, fabric, quota, slo, max_frame, auth_token })?;
    println!("serve: listening on {}", plane.local_addr());

    if secs == 0 {
        // Run until killed.
        loop {
            std::thread::sleep(Duration::from_secs(3600));
        }
    }
    std::thread::sleep(Duration::from_secs(secs));
    println!("{}", plane.metrics().render());
    println!("{}", plane.governor().render());
    if let Some(engine) = plane.fabric().chaos() {
        println!("chaos plan: {} ({} faults)", engine.plan().summary(), engine.total_injected());
    }
    plane.shutdown();
    Ok(())
}
