//! Fair-share staging for the supervisor's overflow tier: deficit
//! round-robin (DRR) across tenant tags, priority-ordered within each
//! tenant.
//!
//! The seed's overflow tier was one global priority heap: under
//! contention, a tenant that floods the fabric with `High` jobs owns the
//! heap's head and starves everyone else. [`FairStage`] replaces it with
//! one priority heap *per tenant tag* and a DRR ring across the tenants
//! that currently have staged work. Each turn of the ring a tenant earns
//! a `quantum` of unit-cost job credits and drains up to that many of its
//! best jobs; then the next tenant gets its turn. The composition rule:
//!
//! - **across tenants**: round-robin — a hot tenant's backlog waits its
//!   turn like everyone else's;
//! - **within a tenant**: the existing priority order — `High` overtakes
//!   `Normal` overtakes `Low`, FIFO inside a priority level.
//!
//! Fairness engages only at this staging tier, i.e. only under
//! contention: while the dispatch plane has room, jobs bypass staging
//! entirely and arrival order rules (the uncontended fabric behaves
//! exactly as before this layer existed). Untagged jobs form one
//! implicit tenant (`None`), so anonymous traffic competes as a single
//! party rather than bypassing fairness.

use crate::api::Priority;
use std::collections::{BinaryHeap, HashMap, VecDeque};
use std::sync::Arc;

/// One staged entry: the per-tenant heap's ordering key plus the item.
struct FairEntry<T> {
    priority: Priority,
    seq: u64,
    item: T,
}

impl<T> PartialEq for FairEntry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.priority == other.priority && self.seq == other.seq
    }
}
impl<T> Eq for FairEntry<T> {}
impl<T> PartialOrd for FairEntry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<T> Ord for FairEntry<T> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // max-heap: higher priority first, then earlier submission
        self.priority.cmp(&other.priority).then_with(|| other.seq.cmp(&self.seq))
    }
}

/// A job handed back by [`FairStage::pop`] — carries everything needed
/// to [`FairStage::requeue`] it unchanged if placement fails.
pub(crate) struct Popped<T> {
    pub tag: Option<Arc<str>>,
    pub priority: Priority,
    pub seq: u64,
    pub item: T,
}

/// DRR staging across tenant tags (see the module docs for the policy).
pub(crate) struct FairStage<T> {
    /// Per-tenant priority heap. Invariant: a key is present iff its
    /// heap is non-empty and the tag sits in `ring` exactly once.
    queues: HashMap<Option<Arc<str>>, BinaryHeap<FairEntry<T>>>,
    /// Tenants awaiting their DRR turn, front = next served.
    ring: VecDeque<Option<Arc<str>>>,
    /// Unspent job credits for the tenant currently at the ring's front.
    deficit: HashMap<Option<Arc<str>>, u64>,
    /// Job credits a tenant earns per ring turn (unit cost per job).
    quantum: u64,
    len: usize,
}

impl<T> FairStage<T> {
    pub fn new(quantum: u64) -> FairStage<T> {
        FairStage {
            queues: HashMap::new(),
            ring: VecDeque::new(),
            deficit: HashMap::new(),
            quantum: quantum.max(1),
            len: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Stage a job under its tenant tag. A tenant staging its first job
    /// joins the back of the ring — it cannot jump an ongoing rotation.
    pub fn push(&mut self, tag: Option<Arc<str>>, priority: Priority, seq: u64, item: T) {
        let newly_active = !self.queues.contains_key(&tag);
        self.queues
            .entry(tag.clone())
            .or_default()
            .push(FairEntry { priority, seq, item });
        if newly_active {
            self.ring.push_back(tag);
        }
        self.len += 1;
    }

    /// The next job under the DRR policy: the front tenant's best entry,
    /// rotating the ring when its quantum is spent (or its heap empties).
    pub fn pop(&mut self) -> Option<Popped<T>> {
        loop {
            let tag = self.ring.front()?.clone();
            let Some(q) = self.queues.get_mut(&tag) else {
                // Stale ring slot (tenant drained via an earlier path).
                self.ring.pop_front();
                self.deficit.remove(&tag);
                continue;
            };
            let d = self.deficit.entry(tag.clone()).or_insert(0);
            if *d == 0 {
                // New visit: the tenant earns its quantum.
                *d = self.quantum;
            }
            *d -= 1;
            let turn_over = *d == 0;
            let e = q.pop().expect("queues holds only non-empty heaps");
            self.len -= 1;
            let emptied = q.is_empty();
            if emptied {
                self.queues.remove(&tag);
            }
            if emptied || turn_over {
                self.ring.pop_front();
                self.deficit.remove(&tag);
                if !emptied {
                    self.ring.push_back(tag.clone());
                }
            }
            return Some(Popped { tag, priority: e.priority, seq: e.seq, item: e.item });
        }
    }

    /// Put a popped job back unchanged (placement failed): the tenant
    /// returns to the ring's *front* with a one-job credit, so the retry
    /// serves this same job first — the failed attempt costs the tenant
    /// nothing and preserves FIFO within its priority level.
    pub fn requeue(&mut self, p: Popped<T>) {
        let newly_active = !self.queues.contains_key(&p.tag);
        self.queues
            .entry(p.tag.clone())
            .or_default()
            .push(FairEntry { priority: p.priority, seq: p.seq, item: p.item });
        if newly_active {
            self.ring.push_front(p.tag.clone());
        }
        *self.deficit.entry(p.tag).or_insert(0) += 1;
        self.len += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tag(s: &str) -> Option<Arc<str>> {
        Some(Arc::from(s))
    }

    fn drain(f: &mut FairStage<u32>) -> Vec<u32> {
        let mut out = Vec::new();
        while let Some(p) = f.pop() {
            out.push(p.item);
        }
        out
    }

    #[test]
    fn hot_tenant_cannot_starve_the_rest() {
        // A stages 10 jobs before B stages 2 — DRR still interleaves, so
        // B's second job goes out 4th, not 11th.
        let mut f = FairStage::new(1);
        for i in 0..10 {
            f.push(tag("a"), Priority::Normal, i, 100 + i as u32);
        }
        f.push(tag("b"), Priority::Normal, 10, 200);
        f.push(tag("b"), Priority::Normal, 11, 201);
        assert_eq!(f.len(), 12);
        let order = drain(&mut f);
        assert_eq!(&order[..4], &[100, 200, 101, 201], "B interleaves from its first turn");
        assert_eq!(&order[4..], &[102, 103, 104, 105, 106, 107, 108, 109]);
        assert!(f.is_empty());
    }

    #[test]
    fn priority_overtakes_within_a_tenant_only() {
        let mut f = FairStage::new(1);
        f.push(tag("a"), Priority::Low, 0, 1);
        f.push(tag("a"), Priority::High, 1, 2);
        f.push(tag("b"), Priority::Normal, 2, 3);
        // A's High beats A's earlier Low; B's Normal is not overtaken by
        // A's High — fairness is cross-tenant, priority is intra-tenant.
        assert_eq!(drain(&mut f), vec![2, 3, 1]);
    }

    #[test]
    fn quantum_drains_bursts_per_turn() {
        let mut f = FairStage::new(2);
        for i in 0..4 {
            f.push(tag("a"), Priority::Normal, i, 10 + i as u32);
        }
        for i in 4..8 {
            f.push(tag("b"), Priority::Normal, i, 20 + (i - 4) as u32);
        }
        assert_eq!(drain(&mut f), vec![10, 11, 20, 21, 12, 13, 22, 23]);
    }

    #[test]
    fn untagged_jobs_form_one_implicit_tenant() {
        let mut f = FairStage::new(1);
        f.push(None, Priority::Normal, 0, 1);
        f.push(None, Priority::Normal, 1, 2);
        f.push(tag("a"), Priority::Normal, 2, 3);
        assert_eq!(drain(&mut f), vec![1, 3, 2], "anonymous traffic is a single party");
    }

    #[test]
    fn requeue_retries_the_same_job_first() {
        let mut f = FairStage::new(1);
        f.push(tag("a"), Priority::Normal, 0, 1);
        f.push(tag("b"), Priority::Normal, 1, 2);
        let p = f.pop().unwrap();
        assert_eq!(p.item, 1);
        f.requeue(p);
        assert_eq!(f.len(), 2);
        // the failed placement costs A nothing: same job, same turn
        assert_eq!(f.pop().unwrap().item, 1);
        assert_eq!(f.pop().unwrap().item, 2);
        assert!(f.pop().is_none());
    }

    #[test]
    fn reactivated_tenant_rejoins_at_the_back() {
        let mut f = FairStage::new(1);
        f.push(tag("a"), Priority::Normal, 0, 1);
        assert_eq!(f.pop().unwrap().item, 1);
        assert!(f.is_empty());
        // A went idle; B arrives, then A again — B is served first.
        f.push(tag("b"), Priority::Normal, 1, 2);
        f.push(tag("a"), Priority::Normal, 2, 3);
        assert_eq!(drain(&mut f), vec![2, 3]);
    }
}
