//! Execution backends and the multi-backend registry.
//!
//! The §3.8 link makes any compute substrate that speaks "signals and
//! data" attachable to an EMPA processor; the [`Backend`] trait is the
//! fabric-side generalisation: the simulated EMPA pool (`sim`), the
//! native mass-op loops (`native`), and the XLA/Pallas accelerator
//! (`xla`) all implement one interface and register by name in a
//! [`BackendRegistry`].
//!
//! Registration order is failover order within a class: when a factory
//! fails to initialise (e.g. the XLA runtime is absent), the worker
//! degrades to the next entry instead of erroring every batch, and the
//! failure is visible in the per-backend metrics.

use crate::accel::{Accelerator, MassRequest, MassResult, NativeAccel};
use crate::api::{FabricError, RequestKind};
use crate::empa::{EmpaConfig, EmpaProcessor};
use crate::isa::assemble;
use crate::workload::sumup::{self, Mode};
use std::sync::Arc;

/// Which job class a backend serves.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendClass {
    /// Scalar program jobs (`RequestKind::RunProgram`).
    Program,
    /// Batched mass operations (`MassSum` / `MassDot`).
    Mass,
}

/// One unit of work handed to a backend.
pub enum BackendJob<'a> {
    Program { mode: Mode, values: &'a [i32] },
    Mass(&'a MassRequest),
}

/// What a backend hands back.
#[derive(Debug, Clone, PartialEq)]
pub enum BackendReply {
    Program { eax: i32, clocks: u64, cores: usize },
    Mass(MassResult),
}

/// A named execution substrate. Implementations need not be `Send`: the
/// fabric invokes the *factory* on the worker thread that will own the
/// backend (PJRT handles are thread-affine), mirroring the paper's point
/// that the SV sees only signals and data, never internals.
pub trait Backend {
    /// Registry name (`sim`, `native`, `xla`, ...).
    fn name(&self) -> &str;
    /// Execute one job synchronously.
    fn execute(&self, job: BackendJob) -> Result<BackendReply, FabricError>;
}

/// Constructs a backend on the owning worker thread. Invoked once per
/// worker (the sim pool builds one instance per worker).
pub type BackendFactory = Box<dyn Fn() -> anyhow::Result<Box<dyn Backend>> + Send + Sync>;

/// One registry row.
pub struct BackendEntry {
    pub name: String,
    pub class: BackendClass,
    factory: BackendFactory,
}

impl BackendEntry {
    /// Run the factory (on the calling thread).
    pub fn instantiate(&self) -> anyhow::Result<Box<dyn Backend>> {
        (self.factory)()
    }
}

impl std::fmt::Debug for BackendEntry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("BackendEntry")
            .field("name", &self.name)
            .field("class", &self.class)
            .finish_non_exhaustive()
    }
}

/// Named, ordered collection of backend factories the fabric boots from.
#[derive(Debug, Default)]
pub struct BackendRegistry {
    entries: Vec<Arc<BackendEntry>>,
}

impl BackendRegistry {
    pub fn new() -> Self {
        BackendRegistry { entries: Vec::new() }
    }

    /// Register a backend; order within a class is failover preference.
    pub fn register(
        mut self,
        name: impl Into<String>,
        class: BackendClass,
        factory: BackendFactory,
    ) -> Self {
        self.entries.push(Arc::new(BackendEntry { name: name.into(), class, factory }));
        self
    }

    /// Register a mass backend from a plain [`Accelerator`] factory (the
    /// pre-registry `AccelFactory` shape becomes a registry entry).
    pub fn register_accel<F>(self, name: impl Into<String>, factory: F) -> Self
    where
        F: Fn() -> anyhow::Result<Box<dyn Accelerator>> + Send + Sync + 'static,
    {
        let name = name.into();
        let entry_name = name.clone();
        self.register(
            name,
            BackendClass::Mass,
            Box::new(move || {
                let accel = factory()?;
                Ok(Box::new(AccelBackend { name: entry_name.clone(), inner: accel })
                    as Box<dyn Backend>)
            }),
        )
    }

    /// The default local registry: simulated EMPA pool + native mass ops.
    pub fn local(empa: EmpaConfig) -> Self {
        BackendRegistry::new()
            .register(
                "sim",
                BackendClass::Program,
                Box::new(move || Ok(Box::new(SimBackend::new(empa.clone())) as Box<dyn Backend>)),
            )
            .register_accel("native", || Ok(Box::new(NativeAccel) as Box<dyn Accelerator>))
    }

    /// The production shape: `sim` for programs; `xla` preferred for mass
    /// ops with `native` as the failover when the XLA runtime is absent.
    pub fn with_xla(empa: EmpaConfig, artifact_dir: impl Into<String>) -> Self {
        let dir = artifact_dir.into();
        BackendRegistry::new()
            .register(
                "sim",
                BackendClass::Program,
                Box::new(move || Ok(Box::new(SimBackend::new(empa.clone())) as Box<dyn Backend>)),
            )
            .register_accel("xla", move || {
                let rt = crate::runtime::Runtime::load_dir(&dir)?;
                Ok(Box::new(crate::accel::XlaAccel::new(rt)) as Box<dyn Accelerator>)
            })
            .register_accel("native", || Ok(Box::new(NativeAccel) as Box<dyn Accelerator>))
    }

    /// Entries of one class, in registration (= failover) order.
    pub fn chain(&self, class: BackendClass) -> Vec<Arc<BackendEntry>> {
        self.entries.iter().filter(|e| e.class == class).cloned().collect()
    }

    /// All registered names, in order.
    pub fn names(&self) -> Vec<&str> {
        self.entries.iter().map(|e| e.name.as_str()).collect()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// Map a request kind to the backend class that can serve it.
pub fn class_of(kind: &RequestKind) -> BackendClass {
    match kind {
        RequestKind::RunProgram { .. } => BackendClass::Program,
        RequestKind::MassSum { .. } | RequestKind::MassDot { .. } => BackendClass::Mass,
    }
}

// ----------------------------------------------------------------------
// the simulated EMPA pool as a backend
// ----------------------------------------------------------------------

/// One simulated EMPA processor slot: assembles the sumup program for the
/// requested mode and runs it cycle-stepped.
pub struct SimBackend {
    cfg: EmpaConfig,
}

impl SimBackend {
    pub fn new(cfg: EmpaConfig) -> Self {
        SimBackend { cfg }
    }
}

impl Backend for SimBackend {
    fn name(&self) -> &str {
        "sim"
    }

    fn execute(&self, job: BackendJob) -> Result<BackendReply, FabricError> {
        match job {
            BackendJob::Program { mode, values } => {
                let (src, _) = sumup::program(mode, values);
                let prog = assemble(&src).map_err(|e| FabricError::GuestFault(e.to_string()))?;
                let r = EmpaProcessor::new(&prog.image, &self.cfg).run();
                match r.fault {
                    None => Ok(BackendReply::Program {
                        eax: r.eax(),
                        clocks: r.clocks,
                        cores: r.max_occupied,
                    }),
                    Some(f) => Err(FabricError::GuestFault(f)),
                }
            }
            // Mass work lands here as scattered shards of oversized ops
            // (and, defensively, whole ops): serve it with the native
            // loops — a sim core is a conventional core too.
            BackendJob::Mass(req) => NativeAccel
                .execute(req)
                .map(BackendReply::Mass)
                .map_err(|e| FabricError::Backend { name: "sim".into(), msg: e.to_string() }),
        }
    }
}

// ----------------------------------------------------------------------
// accelerators as backends
// ----------------------------------------------------------------------

/// Adapter: any [`Accelerator`] (the §3.8 link trait) is a mass-class
/// backend under its registry name.
pub struct AccelBackend {
    name: String,
    inner: Box<dyn Accelerator>,
}

impl AccelBackend {
    pub fn new(name: impl Into<String>, inner: Box<dyn Accelerator>) -> Self {
        AccelBackend { name: name.into(), inner }
    }
}

impl Backend for AccelBackend {
    fn name(&self) -> &str {
        &self.name
    }

    fn execute(&self, job: BackendJob) -> Result<BackendReply, FabricError> {
        match job {
            BackendJob::Mass(req) => self
                .inner
                .execute(req)
                .map(BackendReply::Mass)
                .map_err(|e| FabricError::Backend { name: self.name.clone(), msg: e.to_string() }),
            BackendJob::Program { .. } => Err(FabricError::Backend {
                name: self.name.clone(),
                msg: "program jobs are not servable by a mass backend".into(),
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn local_registry_has_sim_and_native() {
        let reg = BackendRegistry::local(EmpaConfig::default());
        assert_eq!(reg.names(), vec!["sim", "native"]);
        assert_eq!(reg.chain(BackendClass::Program).len(), 1);
        assert_eq!(reg.chain(BackendClass::Mass).len(), 1);
    }

    #[test]
    fn registration_order_is_failover_order() {
        let reg = BackendRegistry::new()
            .register_accel("xla", || anyhow::bail!("no device"))
            .register_accel("native", || Ok(Box::new(NativeAccel) as Box<dyn Accelerator>));
        let chain = reg.chain(BackendClass::Mass);
        assert_eq!(chain[0].name, "xla");
        assert_eq!(chain[1].name, "native");
        assert!(chain[0].instantiate().is_err());
        assert!(chain[1].instantiate().is_ok());
    }

    #[test]
    fn sim_backend_runs_programs_and_reports_guest_faults() {
        let b = SimBackend::new(EmpaConfig::default());
        let r = b
            .execute(BackendJob::Program { mode: Mode::Sumup, values: &[1, 2, 3, 4] })
            .unwrap();
        assert_eq!(r, BackendReply::Program { eax: 10, clocks: 36, cores: 5 });
    }

    #[test]
    fn accel_backend_maps_errors_to_named_backend_variant() {
        struct Broken;
        impl Accelerator for Broken {
            fn name(&self) -> &str {
                "broken"
            }
            fn execute(&self, _req: &MassRequest) -> anyhow::Result<MassResult> {
                anyhow::bail!("simulated failure")
            }
        }
        let b = AccelBackend::new("broken", Box::new(Broken));
        let req = MassRequest::sumup(vec![vec![1.0]]);
        match b.execute(BackendJob::Mass(&req)) {
            Err(FabricError::Backend { name, msg }) => {
                assert_eq!(name, "broken");
                assert!(msg.contains("simulated"));
            }
            other => panic!("want Backend error, got {other:?}"),
        }
    }

    #[test]
    fn native_backend_answers_mass_jobs() {
        let b = AccelBackend::new("native", Box::new(NativeAccel));
        let req = MassRequest::sumup(vec![vec![1.0, 2.0, 3.0]]);
        let BackendReply::Mass(MassResult::Scalars(v)) = b.execute(BackendJob::Mass(&req)).unwrap()
        else {
            panic!("scalars expected")
        };
        assert_eq!(v, vec![6.0]);
    }

    #[test]
    fn class_of_partitions_request_kinds() {
        assert_eq!(
            class_of(&RequestKind::RunProgram { mode: Mode::No, values: vec![] }),
            BackendClass::Program
        );
        assert_eq!(class_of(&RequestKind::MassSum { values: vec![] }), BackendClass::Mass);
        assert_eq!(class_of(&RequestKind::MassDot { a: vec![], b: vec![] }), BackendClass::Mass);
    }
}
