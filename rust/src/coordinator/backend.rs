//! Execution backends and the multi-backend registry.
//!
//! The §3.8 link makes any compute substrate that speaks "signals and
//! data" attachable to an EMPA processor; the [`Backend`] trait is the
//! fabric-side generalisation: the simulated EMPA pool (`sim`), the
//! native mass-op loops (`native`), and the XLA/Pallas accelerator
//! (`xla`) all implement one interface and register by name in a
//! [`BackendRegistry`].
//!
//! Registration order is failover order within a class: when a factory
//! fails to initialise (e.g. the XLA runtime is absent), the worker
//! degrades to the next entry instead of erroring every batch, and the
//! failure is visible in the per-backend metrics.

use super::metrics::FabricMetrics;
use crate::accel::{Accelerator, MassRequest, MassResult, NativeAccel};
use crate::api::{FabricError, RequestKind};
use crate::empa::{EmpaConfig, EmpaProcessor};
use crate::isa::{assemble, Program};
use crate::workload::family::{family_impl, Family, Params};
use crate::workload::sumup::Mode;
use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::sync::Arc;

/// Which job class a backend serves.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendClass {
    /// Scalar program jobs (`RequestKind::RunProgram`).
    Program,
    /// Batched mass operations (`MassSum` / `MassDot`).
    Mass,
}

/// One unit of work handed to a backend.
pub enum BackendJob<'a> {
    Program { family: Family, mode: Mode, params: &'a Params },
    Mass(&'a MassRequest),
}

/// What a backend hands back.
#[derive(Debug, Clone, PartialEq)]
pub enum BackendReply {
    Program { eax: i32, clocks: u64, cores: usize, data: Vec<i32> },
    Mass(MassResult),
}

/// A named execution substrate. Implementations need not be `Send`: the
/// fabric invokes the *factory* on the worker thread that will own the
/// backend (PJRT handles are thread-affine), mirroring the paper's point
/// that the SV sees only signals and data, never internals.
pub trait Backend {
    /// Registry name (`sim`, `native`, `xla`, ...).
    fn name(&self) -> &str;
    /// Execute one job synchronously.
    fn execute(&self, job: BackendJob) -> Result<BackendReply, FabricError>;
    /// Attach the fabric's shared metrics after instantiation, so a
    /// backend can publish its internal counters (the sim pipeline's
    /// template-cache and processor-reuse stats). Default: no-op.
    fn attach_metrics(&mut self, _metrics: Arc<FabricMetrics>) {}
    /// Attach the fabric's chaos engine after instantiation, so a
    /// backend can host injection sites deeper than its `execute`
    /// boundary (the sim pool's guest-fault hook). Default: no-op —
    /// backends without internal sites ignore it.
    fn attach_chaos(&mut self, _chaos: Arc<crate::chaos::ChaosEngine>) {}
}

/// Constructs a backend on the owning worker thread. Invoked once per
/// worker (the sim pool builds one instance per worker).
pub type BackendFactory = Box<dyn Fn() -> anyhow::Result<Box<dyn Backend>> + Send + Sync>;

/// One registry row.
pub struct BackendEntry {
    pub name: String,
    pub class: BackendClass,
    factory: BackendFactory,
}

impl BackendEntry {
    /// Build an entry from parts. Used by the chaos plane to rebuild a
    /// chain with wrapped factories; normal registration goes through
    /// [`BackendRegistry::register`].
    pub fn new(name: impl Into<String>, class: BackendClass, factory: BackendFactory) -> Self {
        BackendEntry { name: name.into(), class, factory }
    }

    /// Run the factory (on the calling thread).
    pub fn instantiate(&self) -> anyhow::Result<Box<dyn Backend>> {
        (self.factory)()
    }
}

impl std::fmt::Debug for BackendEntry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("BackendEntry")
            .field("name", &self.name)
            .field("class", &self.class)
            .finish_non_exhaustive()
    }
}

/// Named, ordered collection of backend factories the fabric boots from.
#[derive(Debug, Default)]
pub struct BackendRegistry {
    entries: Vec<Arc<BackendEntry>>,
}

impl BackendRegistry {
    pub fn new() -> Self {
        BackendRegistry { entries: Vec::new() }
    }

    /// Register a backend; order within a class is failover preference.
    pub fn register(
        mut self,
        name: impl Into<String>,
        class: BackendClass,
        factory: BackendFactory,
    ) -> Self {
        self.entries.push(Arc::new(BackendEntry { name: name.into(), class, factory }));
        self
    }

    /// Register a mass backend from a plain [`Accelerator`] factory (the
    /// pre-registry `AccelFactory` shape becomes a registry entry).
    pub fn register_accel<F>(self, name: impl Into<String>, factory: F) -> Self
    where
        F: Fn() -> anyhow::Result<Box<dyn Accelerator>> + Send + Sync + 'static,
    {
        let name = name.into();
        let entry_name = name.clone();
        self.register(
            name,
            BackendClass::Mass,
            Box::new(move || {
                let accel = factory()?;
                Ok(Box::new(AccelBackend { name: entry_name.clone(), inner: accel })
                    as Box<dyn Backend>)
            }),
        )
    }

    /// The `sim` program-backend factory, validating the fabric's
    /// simulator configuration at init: a bad config is a typed factory
    /// failure (visible as `init_failures` and a failover), never a
    /// panic inside the serving process.
    fn sim_factory(empa: EmpaConfig) -> BackendFactory {
        Box::new(move || {
            empa.validate().map_err(anyhow::Error::new)?;
            Ok(Box::new(SimBackend::new(empa.clone())) as Box<dyn Backend>)
        })
    }

    /// The default local registry: simulated EMPA pool + native mass ops.
    pub fn local(empa: EmpaConfig) -> Self {
        BackendRegistry::new()
            .register("sim", BackendClass::Program, Self::sim_factory(empa))
            .register_accel("native", || Ok(Box::new(NativeAccel) as Box<dyn Accelerator>))
    }

    /// The production shape: `sim` for programs; `xla` preferred for mass
    /// ops with `native` as the failover when the XLA runtime is absent.
    pub fn with_xla(empa: EmpaConfig, artifact_dir: impl Into<String>) -> Self {
        let dir = artifact_dir.into();
        BackendRegistry::new()
            .register("sim", BackendClass::Program, Self::sim_factory(empa))
            .register_accel("xla", move || {
                let rt = crate::runtime::Runtime::load_dir(&dir)?;
                Ok(Box::new(crate::accel::XlaAccel::new(rt)) as Box<dyn Accelerator>)
            })
            .register_accel("native", || Ok(Box::new(NativeAccel) as Box<dyn Accelerator>))
    }

    /// Entries of one class, in registration (= failover) order.
    pub fn chain(&self, class: BackendClass) -> Vec<Arc<BackendEntry>> {
        self.entries.iter().filter(|e| e.class == class).cloned().collect()
    }

    /// All registered names, in order.
    pub fn names(&self) -> Vec<&str> {
        self.entries.iter().map(|e| e.name.as_str()).collect()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// Map a request kind to the backend class that can serve it.
pub fn class_of(kind: &RequestKind) -> BackendClass {
    match kind {
        RequestKind::RunProgram { .. } => BackendClass::Program,
        RequestKind::MassSum { .. } | RequestKind::MassDot { .. } => BackendClass::Mass,
    }
}

// ----------------------------------------------------------------------
// the simulated EMPA pool as a backend: the compile-once pipeline
// ----------------------------------------------------------------------

/// Template-cache capacity per sim worker. Size-classes are exact
/// element counts, so the working set is `family-mode combos ×
/// length distribution`: the default serving trace (lengths 1..=32 over
/// 9 family/mode combos) needs ~288 distinct keys — 512 holds all of
/// them with headroom, so steady-state serving misses only on first
/// touch. Templates are a few hundred bytes each; the worst-case cache
/// is well under a megabyte per worker.
const TEMPLATE_CACHE_CAP: usize = 512;

type TemplateKey = (Family, Mode, u32);

/// An LRU over assembled program templates: hash-map lookups, eviction
/// by least-recent stamp (an O(cap) scan, paid only when the cache is
/// full — far below the cost of the reassembly a hit avoids).
struct TemplateCache {
    cap: usize,
    tick: u64,
    entries: HashMap<TemplateKey, (u64, Arc<Program>)>,
}

impl TemplateCache {
    fn new(cap: usize) -> Self {
        TemplateCache { cap: cap.max(1), tick: 0, entries: HashMap::new() }
    }

    fn get(&mut self, key: TemplateKey) -> Option<Arc<Program>> {
        self.tick += 1;
        let e = self.entries.get_mut(&key)?;
        e.0 = self.tick;
        Some(Arc::clone(&e.1))
    }

    fn put(&mut self, key: TemplateKey, prog: Arc<Program>) {
        self.tick += 1;
        if self.entries.len() >= self.cap && !self.entries.contains_key(&key) {
            if let Some(lru) = self
                .entries
                .iter()
                .min_by_key(|(_, (stamp, _))| *stamp)
                .map(|(k, _)| *k)
            {
                self.entries.remove(&lru);
            }
        }
        self.entries.insert(key, (self.tick, prog));
    }

    fn len(&self) -> usize {
        self.entries.len()
    }
}

/// Local (per-backend) pipeline counters, mirrored into the shared
/// [`FabricMetrics`] when attached — directly inspectable in unit tests
/// and when the backend is used standalone.
#[derive(Debug, Default)]
pub struct PipelineStats {
    pub template_hits: Cell<u64>,
    pub template_misses: Cell<u64>,
    pub proc_reuses: Cell<u64>,
    pub proc_rebuilds: Cell<u64>,
    /// Jobs served by patching data spans into the already-loaded
    /// template image (no image copy, no memory reload).
    pub image_reuses: Cell<u64>,
    /// Scheduler iterations executed across served jobs (see
    /// [`crate::empa::RunReport::events_processed`]).
    pub sim_events: Cell<u64>,
    /// Clocks the event-horizon scheduler skipped across served jobs.
    pub sim_clocks_skipped: Cell<u64>,
    /// Decode-cache hits/misses across served jobs (host-perf; the
    /// code-limit boundary keeps data stores from poisoning the cache).
    pub icache_hits: Cell<u64>,
    pub icache_misses: Cell<u64>,
    /// Host threads stepping this worker's processor (gauge; 1 = serial).
    pub host_threads: Cell<u64>,
    /// Parallel phase-A spans / speculated retirements / conflict
    /// re-executions across served jobs (`StepMode::ParallelA`).
    pub parallel_spans: Cell<u64>,
    pub parallel_cores: Cell<u64>,
    pub span_conflicts: Cell<u64>,
    /// Clocks advanced through multi-clock span batches across served
    /// jobs (subset of `sim_clocks_skipped`).
    pub batched_clocks: Cell<u64>,
    /// Batched clocks advanced under a ported (non-ideal) bus — windows
    /// whose fetch charges were replayed in lockstep grant order.
    pub batched_ported_clocks: Cell<u64>,
    /// Batched windows truncated by a stalled replayed bus charge.
    pub bus_replay_truncations: Cell<u64>,
    /// Batched clocks advanced while a mass engine was mid-flight.
    pub engine_batched_clocks: Cell<u64>,
}

/// One simulated EMPA processor slot, built as a **compile-once
/// pipeline** with a zero-copy data plane: program jobs name a
/// `(family, mode, params)` triple; the code template for
/// `(family, mode, size-class)` is assembled once and cached (LRU); the
/// worker's `EmpaProcessor` is *reset*, not rebuilt — cores, memory,
/// bus and decode cache are reused across jobs. The template image is
/// **never cloned per run**: a job of a new template reloads guest
/// memory straight from the cached image, and consecutive jobs of the
/// *same* template restore only the bytes the previous run dirtied,
/// then patch just the per-request data spans (`Program::patch_mem`).
pub struct SimBackend {
    cfg: EmpaConfig,
    templates: RefCell<TemplateCache>,
    proc: RefCell<Option<EmpaProcessor>>,
    /// The template whose image the live processor's memory holds
    /// (pointer identity decides full reload vs dirty-window restore).
    live: RefCell<Option<Arc<Program>>>,
    stats: PipelineStats,
    metrics: Option<Arc<FabricMetrics>>,
    /// Guest-site injection (`Site::Guest`): when armed, selected clean
    /// runs are flipped into fault outcomes. `None` in normal service.
    chaos: Option<Arc<crate::chaos::ChaosEngine>>,
}

impl SimBackend {
    pub fn new(cfg: EmpaConfig) -> Self {
        SimBackend {
            cfg,
            templates: RefCell::new(TemplateCache::new(TEMPLATE_CACHE_CAP)),
            proc: RefCell::new(None),
            live: RefCell::new(None),
            stats: PipelineStats::default(),
            metrics: None,
            chaos: None,
        }
    }

    /// Local pipeline counters (tests, standalone use).
    pub fn pipeline_stats(&self) -> &PipelineStats {
        &self.stats
    }

    /// Cached templates (tests).
    pub fn cached_templates(&self) -> usize {
        self.templates.borrow().len()
    }

    fn count(&self, local: &Cell<u64>, shared: impl Fn(&FabricMetrics) -> &std::sync::atomic::AtomicU64) {
        self.count_by(local, 1, shared);
    }

    fn count_by(
        &self,
        local: &Cell<u64>,
        n: u64,
        shared: impl Fn(&FabricMetrics) -> &std::sync::atomic::AtomicU64,
    ) {
        local.set(local.get() + n);
        if let Some(m) = &self.metrics {
            shared(m).fetch_add(n, std::sync::atomic::Ordering::Relaxed);
        }
    }

    /// Fetch (or assemble and cache) the template for a size-class.
    fn template(
        &self,
        family: Family,
        mode: Mode,
        size_class: u32,
    ) -> Result<Arc<Program>, FabricError> {
        let key = (family, mode, size_class);
        if let Some(tpl) = self.templates.borrow_mut().get(key) {
            self.count(&self.stats.template_hits, |m| &m.template_hits);
            return Ok(tpl);
        }
        self.count(&self.stats.template_misses, |m| &m.template_misses);
        let src = family_impl(family)
            .template(mode, size_class)
            .map_err(FabricError::GuestFault)?;
        let prog =
            Arc::new(assemble(&src).map_err(|e| FabricError::GuestFault(e.to_string()))?);
        self.templates.borrow_mut().put(key, Arc::clone(&prog));
        Ok(prog)
    }

    fn run_program(
        &self,
        family: Family,
        mode: Mode,
        params: &Params,
    ) -> Result<BackendReply, FabricError> {
        // Same rule set as client-side admission — defence in depth for a
        // directly driven backend, with identical typed errors.
        crate::api::validate_program(family, mode, params)?;
        let fam = family_impl(family);
        let size_class = fam.size_class(params).map_err(FabricError::GuestFault)?;
        let tpl = self.template(family, mode, size_class)?;
        let data = fam.data_image(params).map_err(FabricError::GuestFault)?;
        // Load (or restore) the template image, then patch only the
        // per-request data spans into the live guest memory — the
        // result is byte-identical to regenerating, reassembling and
        // reloading the full source, with no image clone anywhere.
        let mut guard = self.proc.borrow_mut();
        let mut live = self.live.borrow_mut();
        if let Some(p) = guard.as_mut() {
            self.count(&self.stats.proc_reuses, |m| &m.proc_reuses);
            if live.as_ref().is_some_and(|l| Arc::ptr_eq(l, &tpl)) {
                // Same template as the previous run: roll back only the
                // dirty bytes; the decode cache stays warm.
                self.count(&self.stats.image_reuses, |m| &m.image_reuses);
                p.reset_reusing(&tpl.image);
            } else {
                p.reset_with(&tpl.image);
                *live = Some(Arc::clone(&tpl));
            }
        } else {
            *guard = Some(
                EmpaProcessor::try_new(&tpl.image, &self.cfg)
                    .map_err(|e| FabricError::InvalidConfig(e.to_string()))?,
            );
            *live = Some(Arc::clone(&tpl));
            self.count(&self.stats.proc_rebuilds, |m| &m.proc_rebuilds);
        }
        let proc = guard.as_mut().expect("constructed above");
        // Data stores above the code boundary must not poison the
        // decode cache (set before patching, so the patches themselves
        // are invisible to it too).
        proc.set_code_limit(tpl.code_end);
        for (symbol, words) in data {
            tpl.patch_mem(&mut proc.mem, symbol, &words)
                .map_err(|e| FabricError::GuestFault(e.to_string()))?;
        }
        let r = proc.run_report();
        // Event-horizon scheduler economics, visible as the fabric's
        // `sim engine:` metrics line.
        self.count_by(&self.stats.sim_events, r.events_processed, |m| &m.sim_events);
        self.count_by(&self.stats.sim_clocks_skipped, r.clocks_skipped, |m| &m.sim_clocks_skipped);
        self.count_by(&self.stats.icache_hits, r.icache_hits, |m| &m.icache_hits);
        self.count_by(&self.stats.icache_misses, r.icache_misses, |m| &m.icache_misses);
        // Host-parallel stepping economics (the `host parallel:` line).
        // The thread count is a gauge — the shared metric keeps the max
        // any worker reported, not a sum over jobs.
        let threads = r.host_threads as u64;
        self.stats.host_threads.set(self.stats.host_threads.get().max(threads));
        if let Some(m) = &self.metrics {
            m.host_threads.fetch_max(threads, std::sync::atomic::Ordering::Relaxed);
            for (slot, n) in m.span_hist.iter().zip(r.span_hist) {
                if n > 0 {
                    slot.fetch_add(n, std::sync::atomic::Ordering::Relaxed);
                }
            }
            for (slot, n) in m.span_batch_hist.iter().zip(r.span_batch_hist) {
                if n > 0 {
                    slot.fetch_add(n, std::sync::atomic::Ordering::Relaxed);
                }
            }
        }
        self.count_by(&self.stats.parallel_spans, r.parallel_spans, |m| &m.parallel_spans);
        self.count_by(&self.stats.parallel_cores, r.parallel_cores, |m| &m.parallel_cores);
        self.count_by(&self.stats.span_conflicts, r.span_conflicts, |m| &m.span_conflicts);
        self.count_by(&self.stats.batched_clocks, r.batched_clocks, |m| &m.batched_clocks);
        self.count_by(
            &self.stats.batched_ported_clocks,
            r.batched_ported_clocks,
            |m| &m.batched_ported_clocks,
        );
        self.count_by(
            &self.stats.bus_replay_truncations,
            r.bus_replay_truncations,
            |m| &m.bus_replay_truncations,
        );
        self.count_by(
            &self.stats.engine_batched_clocks,
            r.engine_batched_clocks,
            |m| &m.engine_batched_clocks,
        );
        if let Some(f) = r.fault {
            return Err(FabricError::GuestFault(f));
        }
        // Guest-site chaos: flip a cleanly finished run into the fault
        // outcome the supervisor would report had the guest trapped —
        // real faults above take precedence so injected ones never mask
        // them. Same typed error, same caller-visible path.
        if let Some(engine) = &self.chaos {
            if engine.decide(crate::chaos::Site::Guest)
                == Some(crate::chaos::FaultKind::GuestFault)
            {
                if let Some(m) = &self.metrics {
                    m.chaos_guest_faults.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                }
                return Err(FabricError::GuestFault(
                    "chaos: injected guest fault (clean run flipped)".into(),
                ));
            }
        }
        // Memory-resident results (scale's output array) are read back
        // before the processor is reset by the next job.
        let data = match fam.readback(params) {
            Some((symbol, words)) => {
                crate::workload::family::read_span(&tpl, &proc.mem, symbol, words)
                    .map_err(FabricError::GuestFault)?
            }
            None => Vec::new(),
        };
        Ok(BackendReply::Program { eax: r.eax(), clocks: r.clocks, cores: r.max_occupied, data })
    }
}

impl Backend for SimBackend {
    fn name(&self) -> &str {
        "sim"
    }

    fn execute(&self, job: BackendJob) -> Result<BackendReply, FabricError> {
        match job {
            BackendJob::Program { family, mode, params } => {
                self.run_program(family, mode, params)
            }
            // Mass work lands here as scattered shards of oversized ops
            // (and, defensively, whole ops): serve it with the native
            // loops — a sim core is a conventional core too.
            BackendJob::Mass(req) => NativeAccel
                .execute(req)
                .map(BackendReply::Mass)
                .map_err(|e| FabricError::Backend { name: "sim".into(), msg: e.to_string() }),
        }
    }

    fn attach_metrics(&mut self, metrics: Arc<FabricMetrics>) {
        self.metrics = Some(metrics);
    }

    fn attach_chaos(&mut self, chaos: Arc<crate::chaos::ChaosEngine>) {
        self.chaos = Some(chaos);
    }
}

// ----------------------------------------------------------------------
// accelerators as backends
// ----------------------------------------------------------------------

/// Adapter: any [`Accelerator`] (the §3.8 link trait) is a mass-class
/// backend under its registry name.
pub struct AccelBackend {
    name: String,
    inner: Box<dyn Accelerator>,
}

impl AccelBackend {
    pub fn new(name: impl Into<String>, inner: Box<dyn Accelerator>) -> Self {
        AccelBackend { name: name.into(), inner }
    }
}

impl Backend for AccelBackend {
    fn name(&self) -> &str {
        &self.name
    }

    fn execute(&self, job: BackendJob) -> Result<BackendReply, FabricError> {
        match job {
            BackendJob::Mass(req) => self
                .inner
                .execute(req)
                .map(BackendReply::Mass)
                .map_err(|e| FabricError::Backend { name: self.name.clone(), msg: e.to_string() }),
            BackendJob::Program { .. } => Err(FabricError::Backend {
                name: self.name.clone(),
                msg: "program jobs are not servable by a mass backend".into(),
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn local_registry_has_sim_and_native() {
        let reg = BackendRegistry::local(EmpaConfig::default());
        assert_eq!(reg.names(), vec!["sim", "native"]);
        assert_eq!(reg.chain(BackendClass::Program).len(), 1);
        assert_eq!(reg.chain(BackendClass::Mass).len(), 1);
    }

    #[test]
    fn registration_order_is_failover_order() {
        let reg = BackendRegistry::new()
            .register_accel("xla", || anyhow::bail!("no device"))
            .register_accel("native", || Ok(Box::new(NativeAccel) as Box<dyn Accelerator>));
        let chain = reg.chain(BackendClass::Mass);
        assert_eq!(chain[0].name, "xla");
        assert_eq!(chain[1].name, "native");
        assert!(chain[0].instantiate().is_err());
        assert!(chain[1].instantiate().is_ok());
    }

    #[test]
    fn sim_backend_runs_programs_and_reports_guest_faults() {
        let b = SimBackend::new(EmpaConfig::default());
        let params = Params::Sumup { values: vec![1, 2, 3, 4] };
        let r = b
            .execute(BackendJob::Program { family: Family::Sumup, mode: Mode::Sumup, params: &params })
            .unwrap();
        // clocks/cores identical to the pre-pipeline direct assembly:
        // the patched template is byte-for-byte the same program.
        assert_eq!(
            r,
            BackendReply::Program { eax: 10, clocks: 36, cores: 5, data: vec![] }
        );
    }

    #[test]
    fn invalid_empa_config_fails_backend_init_not_the_process() {
        let bad = EmpaConfig { num_cores: 0, ..Default::default() };
        let reg = BackendRegistry::local(bad.clone());
        let chain = reg.chain(BackendClass::Program);
        let err = chain[0].instantiate().expect_err("factory rejects the config");
        assert!(err.to_string().contains("num_cores=0"), "{err}");
        // defence in depth: a directly driven backend refuses per job too
        let b = SimBackend::new(bad);
        let params = Params::Sumup { values: vec![1] };
        let err = b
            .execute(BackendJob::Program { family: Family::Sumup, mode: Mode::Sumup, params: &params })
            .unwrap_err();
        assert!(matches!(err, FabricError::InvalidConfig(ref m) if m.contains("num_cores=0")), "{err}");
        assert_eq!(b.pipeline_stats().proc_rebuilds.get(), 0, "no processor was built");
    }

    #[test]
    fn sim_backend_publishes_event_horizon_stats() {
        let b = SimBackend::new(EmpaConfig::default());
        let params = Params::Sumup { values: (0..64).collect() };
        b.execute(BackendJob::Program { family: Family::Sumup, mode: Mode::No, params: &params })
            .unwrap();
        let s = b.pipeline_stats();
        assert!(s.sim_events.get() > 0, "events counted");
        assert!(
            s.sim_clocks_skipped.get() > s.sim_events.get(),
            "NO-mode serving skips most clocks: {} events, {} skipped",
            s.sim_events.get(),
            s.sim_clocks_skipped.get()
        );
        // a lockstep pool publishes zero skips
        let lock = SimBackend::new(EmpaConfig {
            step: crate::empa::StepMode::Lockstep,
            ..Default::default()
        });
        lock.execute(BackendJob::Program { family: Family::Sumup, mode: Mode::No, params: &params })
            .unwrap();
        assert_eq!(lock.pipeline_stats().sim_clocks_skipped.get(), 0);
        assert!(lock.pipeline_stats().sim_events.get() > b.pipeline_stats().sim_events.get());
    }

    #[test]
    fn sim_backend_publishes_host_parallel_stats() {
        let b = SimBackend::new(EmpaConfig {
            step: crate::empa::StepMode::ParallelA { threads: 2 },
            ..Default::default()
        });
        let params = Params::Sumup { values: (0..64).collect() };
        b.execute(BackendJob::Program { family: Family::Sumup, mode: Mode::Sumup, params: &params })
            .unwrap();
        let s = b.pipeline_stats();
        assert_eq!(s.host_threads.get(), 2);
        assert!(s.parallel_spans.get() > 0, "staggered SUMUP children overlap");
        assert!(s.parallel_cores.get() >= 2 * s.parallel_spans.get());

        // a serial pool reports threads=1, never spans, and never batches
        let serial = SimBackend::new(EmpaConfig::default());
        serial
            .execute(BackendJob::Program { family: Family::Sumup, mode: Mode::Sumup, params: &params })
            .unwrap();
        assert_eq!(serial.pipeline_stats().host_threads.get(), 1);
        assert_eq!(serial.pipeline_stats().parallel_spans.get(), 0);
        assert_eq!(serial.pipeline_stats().batched_clocks.get(), 0);
    }

    #[test]
    fn sim_backend_caches_templates_and_reuses_the_processor() {
        let b = SimBackend::new(EmpaConfig::default());
        let run = |values: Vec<i32>| {
            let params = Params::Sumup { values };
            let r = b
                .execute(BackendJob::Program {
                    family: Family::Sumup,
                    mode: Mode::Sumup,
                    params: &params,
                })
                .unwrap();
            match r {
                BackendReply::Program { eax, .. } => eax,
                other => panic!("program reply expected, got {other:?}"),
            }
        };
        assert_eq!(run(vec![1, 2, 3, 4]), 10);
        assert_eq!(run(vec![5, 5, 5, 5]), 20, "same size-class, different data");
        assert_eq!(run(vec![7; 9]), 63, "different size-class");
        let s = b.pipeline_stats();
        assert_eq!(s.template_misses.get(), 2, "one template per size-class");
        assert_eq!(s.template_hits.get(), 1, "second N=4 job hit the cache");
        assert_eq!(s.proc_rebuilds.get(), 1, "one processor per worker");
        assert_eq!(s.proc_reuses.get(), 2);
        assert_eq!(b.cached_templates(), 2);
    }

    #[test]
    fn same_template_jobs_patch_in_place_with_a_warm_icache() {
        let b = SimBackend::new(EmpaConfig::default());
        let run = |values: Vec<i32>| {
            let params = Params::Sumup { values };
            match b
                .execute(BackendJob::Program {
                    family: Family::Sumup,
                    mode: Mode::Sumup,
                    params: &params,
                })
                .unwrap()
            {
                BackendReply::Program { eax, clocks, .. } => (eax, clocks),
                other => panic!("program reply expected, got {other:?}"),
            }
        };
        let (eax1, clocks1) = run(vec![1, 2, 3, 4]);
        assert_eq!(eax1, 10);
        let misses_after_first = b.pipeline_stats().icache_misses.get();
        assert!(misses_after_first > 0, "cold cache decodes once");

        // Same (family, mode, size-class): the image is *patched*, not
        // reloaded — and the decode cache survives, so the second run
        // re-decodes only the few boundary-band fetches (instructions
        // within 6 bytes of `code_end` always bypass the cache).
        let (eax2, clocks2) = run(vec![5, 6, 7, 8]);
        assert_eq!(eax2, 26, "new data served through the patched spans");
        assert_eq!(clocks1, clocks2, "cycle-identical to a full reload");
        let s = b.pipeline_stats();
        assert_eq!(s.image_reuses.get(), 1, "second job reused the loaded image");
        let second_run_misses = s.icache_misses.get() - misses_after_first;
        assert!(
            second_run_misses <= 4,
            "data patching must not invalidate cached decodes: {second_run_misses} new misses"
        );
        assert!(s.icache_hits.get() > 0);

        // A different size-class reloads (different template) but still
        // without cloning the image.
        let (eax3, _) = run(vec![7; 9]);
        assert_eq!(eax3, 63);
        assert_eq!(s.image_reuses.get(), 1, "different template: full reload path");
    }

    #[test]
    fn sim_backend_serves_every_family_and_reads_back_memory_results() {
        let b = SimBackend::new(EmpaConfig::default());
        // dotprod
        let params = Params::Dotprod { a: vec![1, 2, 3], b: vec![4, 5, 6] };
        let r = b
            .execute(BackendJob::Program { family: Family::Dotprod, mode: Mode::For, params: &params })
            .unwrap();
        assert!(matches!(r, BackendReply::Program { eax: 32, .. }));
        // scale: the result is the read-back output array, not %eax
        let params = Params::Scale { x: vec![2, -3, 4], c: 10 };
        let r = b
            .execute(BackendJob::Program { family: Family::Scale, mode: Mode::For, params: &params })
            .unwrap();
        let BackendReply::Program { data, .. } = r else { panic!("program reply") };
        assert_eq!(data, vec![20, -30, 40]);
        // traces
        use crate::workload::traces::{TraceOp, TraceOpKind};
        let params = Params::Traces {
            ops: vec![
                TraceOp::new(TraceOpKind::Add, 7),
                TraceOp::new(TraceOpKind::Sub, 2),
                TraceOp::new(TraceOpKind::Xor, 1),
            ],
        };
        let r = b
            .execute(BackendJob::Program { family: Family::Traces, mode: Mode::No, params: &params })
            .unwrap();
        assert!(matches!(r, BackendReply::Program { eax: 4, .. }));
    }

    #[test]
    fn sim_backend_rejects_bad_program_requests_with_typed_errors() {
        let b = SimBackend::new(EmpaConfig::default());
        let params = Params::Scale { x: vec![1], c: 2 };
        assert_eq!(
            b.execute(BackendJob::Program {
                family: Family::Scale,
                mode: Mode::Sumup,
                params: &params
            })
            .unwrap_err(),
            FabricError::UnsupportedMode { family: Family::Scale, mode: Mode::Sumup }
        );
        let params = Params::Sumup { values: vec![1] };
        assert_eq!(
            b.execute(BackendJob::Program {
                family: Family::Dotprod,
                mode: Mode::No,
                params: &params
            })
            .unwrap_err(),
            FabricError::FamilyMismatch { family: Family::Dotprod, params: Family::Sumup }
        );
    }

    #[test]
    fn template_cache_evicts_least_recently_used() {
        let mut c = TemplateCache::new(2);
        let p = Arc::new(Program::default());
        c.put((Family::Sumup, Mode::No, 1), Arc::clone(&p));
        c.put((Family::Sumup, Mode::No, 2), Arc::clone(&p));
        assert!(c.get((Family::Sumup, Mode::No, 1)).is_some(), "touch 1 → 2 is LRU");
        c.put((Family::Sumup, Mode::No, 3), Arc::clone(&p));
        assert_eq!(c.len(), 2);
        assert!(c.get((Family::Sumup, Mode::No, 2)).is_none(), "2 evicted");
        assert!(c.get((Family::Sumup, Mode::No, 1)).is_some());
        assert!(c.get((Family::Sumup, Mode::No, 3)).is_some());
    }

    #[test]
    fn accel_backend_maps_errors_to_named_backend_variant() {
        struct Broken;
        impl Accelerator for Broken {
            fn name(&self) -> &str {
                "broken"
            }
            fn execute(&self, _req: &MassRequest) -> anyhow::Result<MassResult> {
                anyhow::bail!("simulated failure")
            }
        }
        let b = AccelBackend::new("broken", Box::new(Broken));
        let req = MassRequest::sumup(vec![vec![1.0]]);
        match b.execute(BackendJob::Mass(&req)) {
            Err(FabricError::Backend { name, msg }) => {
                assert_eq!(name, "broken");
                assert!(msg.contains("simulated"));
            }
            other => panic!("want Backend error, got {other:?}"),
        }
    }

    #[test]
    fn native_backend_answers_mass_jobs() {
        let b = AccelBackend::new("native", Box::new(NativeAccel));
        let req = MassRequest::sumup(vec![vec![1.0, 2.0, 3.0]]);
        let BackendReply::Mass(MassResult::Scalars(v)) = b.execute(BackendJob::Mass(&req)).unwrap()
        else {
            panic!("scalars expected")
        };
        assert_eq!(v, vec![6.0]);
    }

    #[test]
    fn class_of_partitions_request_kinds() {
        assert_eq!(
            class_of(&RequestKind::sumup(Mode::No, vec![])),
            BackendClass::Program
        );
        assert_eq!(
            class_of(&RequestKind::traces(vec![])),
            BackendClass::Program
        );
        assert_eq!(class_of(&RequestKind::mass_sum(Vec::<f32>::new())), BackendClass::Mass);
        assert_eq!(
            class_of(&RequestKind::mass_dot(Vec::<f32>::new(), Vec::<f32>::new())),
            BackendClass::Mass
        );
    }
}
