//! The fabric client: a cheaply-cloneable submission handle.
//!
//! A [`FabricClient`] is the caller-facing half of the service API: it
//! turns a [`JobRequest`] into a queued job and a [`Job`] handle. Clones
//! share the fabric's bounded ingress queue (an `Arc` bump plus a channel
//! clone), so every request thread, connection handler, or load generator
//! can hold its own.

use super::{JobCtx, Msg};
use crate::api::{Completion, FabricError, Job, JobRequest, RequestKind, RetryPolicy};
use crate::coordinator::FabricMetrics;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, SyncSender, TrySendError};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Cloneable submission handle onto a running fabric.
#[derive(Clone)]
pub struct FabricClient {
    tx: SyncSender<Msg>,
    next_id: Arc<AtomicU64>,
    metrics: Arc<FabricMetrics>,
    /// Default client tag stamped onto requests that carry none.
    tag: Option<Arc<str>>,
    /// Shared stop flag: lets the supervisor notice shutdown without
    /// first chewing through the ingress backlog.
    stop: Arc<AtomicBool>,
}

impl FabricClient {
    pub(crate) fn new(
        tx: SyncSender<Msg>,
        metrics: Arc<FabricMetrics>,
        stop: Arc<AtomicBool>,
    ) -> Self {
        FabricClient { tx, next_id: Arc::new(AtomicU64::new(0)), metrics, tag: None, stop }
    }

    /// A clone that stamps `tag` onto untagged requests (per-client
    /// accounting in [`FabricMetrics`]).
    pub fn tagged(&self, tag: impl Into<Arc<str>>) -> FabricClient {
        FabricClient { tag: Some(tag.into()), ..self.clone() }
    }

    /// Shared fabric metrics.
    pub fn metrics(&self) -> &FabricMetrics {
        &self.metrics
    }

    /// Submit a job; blocks while the ingress queue is full
    /// (backpressure the caller can feel).
    pub fn submit(&self, req: impl Into<JobRequest>) -> Result<Job, FabricError> {
        let req = req.into();
        validate(&req)?;
        let (msg, job, tag) = self.prepare(req);
        self.tx.send(msg).map_err(|_| FabricError::Shutdown)?;
        self.account(tag.as_deref());
        Ok(job)
    }

    /// Non-blocking submit (admission control): a full ingress queue is a
    /// [`FabricError::QueueFull`] the caller observes immediately instead
    /// of a stalled thread.
    pub fn try_submit(&self, req: impl Into<JobRequest>) -> Result<Job, FabricError> {
        let req = req.into();
        validate(&req)?;
        let (msg, job, tag) = self.prepare(req);
        match self.tx.try_send(msg) {
            Ok(()) => {
                self.account(tag.as_deref());
                Ok(job)
            }
            Err(TrySendError::Full(_)) => {
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                Err(FabricError::QueueFull)
            }
            Err(TrySendError::Disconnected(_)) => Err(FabricError::Shutdown),
        }
    }

    /// Submit-and-wait with typed retry/backoff — the first rung of the
    /// degradation ladder (retry → backend failover → shed). Only errors
    /// whose [`FabricError::retryable`] says the capacity picture may
    /// have changed are retried, with the policy's capped exponential
    /// backoff between attempts; terminal errors (validation, guest
    /// faults, cancellation) surface immediately. With
    /// [`RetryPolicy::hedge_after`] set, a submission left unresolved
    /// that long gets a duplicate in flight and the first resolution
    /// wins (the loser is cancelled). Retries, exhaustions, and hedges
    /// are all published through [`FabricMetrics`], globally and on the
    /// tenant's ledger row.
    pub fn call_with_retry(
        &self,
        req: impl Into<JobRequest>,
        policy: &RetryPolicy,
    ) -> Result<Completion, FabricError> {
        let template = req.into();
        let tag = template.client.clone().or_else(|| self.tag.clone());
        let mut attempt = 1u32;
        loop {
            let outcome = match self.try_submit(template.clone()) {
                Ok(job) => self.settle(job, &template, policy),
                Err(e) => Err(e),
            };
            match outcome {
                Ok(c) => return Ok(c),
                Err(e) if e.retryable() && attempt < policy.max_attempts => {
                    self.metrics.retries.fetch_add(1, Ordering::Relaxed);
                    if let Some(t) = tag.as_deref() {
                        self.metrics.client(t).retries.fetch_add(1, Ordering::Relaxed);
                    }
                    std::thread::sleep(policy.backoff(attempt));
                    attempt += 1;
                }
                Err(e) => {
                    if e.retryable() {
                        self.metrics.retry_exhausted.fetch_add(1, Ordering::Relaxed);
                    }
                    return Err(e);
                }
            }
        }
    }

    /// Wait for a submitted job; once `hedge_after` elapses unresolved,
    /// put a duplicate in flight and take whichever resolves first.
    fn settle(
        &self,
        job: Job,
        template: &JobRequest,
        policy: &RetryPolicy,
    ) -> Result<Completion, FabricError> {
        let Some(after) = policy.hedge_after else { return job.wait() };
        let mut primary = job;
        if let Some(r) = primary.wait_timeout(after) {
            return r;
        }
        // The hedge is best-effort: if admission refuses it (queue full,
        // quota), just keep waiting on the primary.
        let Ok(mut hedge) = self.try_submit(template.clone()) else {
            return primary.wait();
        };
        self.metrics.hedges.fetch_add(1, Ordering::Relaxed);
        loop {
            if let Some(r) = primary.try_wait() {
                hedge.cancel();
                return r;
            }
            if let Some(r) = hedge.try_wait() {
                primary.cancel();
                return r;
            }
            std::thread::sleep(Duration::from_micros(200));
        }
    }

    /// Vectorized submit: one call, one handle per request, in order.
    /// Blocks on backpressure like [`FabricClient::submit`]; on shutdown
    /// mid-batch the already-queued prefix still completes (their handles
    /// are dropped with the error).
    pub fn submit_batch(
        &self,
        reqs: impl IntoIterator<Item = JobRequest>,
    ) -> Result<Vec<Job>, FabricError> {
        let mut jobs = Vec::new();
        for req in reqs {
            jobs.push(self.submit(req)?);
        }
        Ok(jobs)
    }

    /// Ask the supervisor to stop (used by `Fabric::shutdown`). The flag
    /// lets it notice even while the ingress backlog is deep; the
    /// sentinel message marks where accepted work ends and wakes a
    /// blocked receive.
    pub(crate) fn shutdown_signal(&self) -> Result<(), FabricError> {
        self.stop.store(true, Ordering::Release);
        self.tx.send(Msg::Shutdown).map_err(|_| FabricError::Shutdown)
    }

    fn account(&self, tag: Option<&str>) {
        self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        if let Some(t) = tag {
            self.metrics.client(t).submitted.fetch_add(1, Ordering::Relaxed);
        }
    }

    fn prepare(&self, mut req: JobRequest) -> (Msg, Job, Option<Arc<str>>) {
        if req.client.is_none() {
            req.client = self.tag.clone();
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed) + 1;
        let (reply_tx, reply_rx) = mpsc::channel();
        let submitted = Instant::now();
        let cancel = Arc::new(AtomicBool::new(false));
        let tag = req.client.clone();
        let ctx = JobCtx {
            id,
            priority: req.priority,
            deadline: req.deadline,
            submitted,
            cancel: Arc::clone(&cancel),
            reply: reply_tx,
            client: tag.clone(),
        };
        let job = Job::new(id, submitted, cancel, reply_rx);
        (Msg::Job { kind: req.kind, ctx }, job, tag)
    }
}

/// Reject malformed requests before they reach any queue. A mismatched
/// mass-dot used to be silently truncated by `iter().zip()` downstream —
/// a wrong answer instead of an error; program requests go through the
/// shared [`crate::api::validate_program`] rule set so a backend never
/// sees an unservable job.
fn validate(req: &JobRequest) -> Result<(), FabricError> {
    match &req.kind {
        RequestKind::MassDot { a, b } => {
            if a.len() != b.len() {
                return Err(FabricError::ShapeMismatch { a: a.len(), b: b.len() });
            }
            Ok(())
        }
        RequestKind::RunProgram { family, mode, params } => {
            crate::api::validate_program(*family, *mode, params)
        }
        RequestKind::MassSum { .. } => Ok(()),
    }
}
