//! Fabric-level metrics: lock-free global counters, per-backend counters,
//! and per-client accounting.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Counters for one named backend (`sim`, `native`, `xla`, ...).
#[derive(Debug, Default)]
pub struct BackendStats {
    /// Successful factory initialisations (sim pool: one per worker).
    pub init_ok: AtomicU64,
    /// Factory failures (each one is a failover to the next entry).
    pub init_failures: AtomicU64,
    /// Jobs answered by this backend.
    pub jobs: AtomicU64,
    /// Accelerator batches executed (mass backends).
    pub batches: AtomicU64,
    /// Rows across those batches.
    pub rows: AtomicU64,
    /// Jobs failed by this backend.
    pub errors: AtomicU64,
}

/// Counters shared across the fabric threads.
#[derive(Debug, Default)]
pub struct FabricMetrics {
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    pub errors: AtomicU64,
    /// `try_submit` rejections (admission control).
    pub rejected: AtomicU64,
    /// Jobs resolved `Cancelled` before dispatch.
    pub cancelled: AtomicU64,
    /// Jobs resolved `DeadlineExceeded` before dispatch.
    pub deadline_missed: AtomicU64,
    /// Backend initialisation failovers (registry degraded to a later entry).
    pub failovers: AtomicU64,
    pub routed_sim: AtomicU64,
    pub routed_inline: AtomicU64,
    pub routed_accel: AtomicU64,
    pub accel_batches: AtomicU64,
    pub accel_rows: AtomicU64,
    pub deadline_flushes: AtomicU64,
    /// High-priority mass jobs that forced an immediate batch flush.
    pub priority_flushes: AtomicU64,
    backends: Mutex<HashMap<String, Arc<BackendStats>>>,
    clients: Mutex<HashMap<String, Arc<AtomicU64>>>,
}

impl FabricMetrics {
    /// Per-backend counters, created on first touch.
    pub fn backend(&self, name: &str) -> Arc<BackendStats> {
        let mut g = self.backends.lock().unwrap();
        Arc::clone(g.entry(name.to_string()).or_default())
    }

    /// Names of all backends that have reported, sorted.
    pub fn backend_names(&self) -> Vec<String> {
        let g = self.backends.lock().unwrap();
        let mut v: Vec<String> = g.keys().cloned().collect();
        v.sort();
        v
    }

    /// Per-client submission counter, created on first touch.
    pub fn client(&self, tag: &str) -> Arc<AtomicU64> {
        let mut g = self.clients.lock().unwrap();
        Arc::clone(g.entry(tag.to_string()).or_default())
    }

    /// Mean rows per accelerator batch (batching effectiveness).
    pub fn mean_batch_rows(&self) -> f64 {
        let b = self.accel_batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.accel_rows.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    /// Render a summary: one global line plus one line per backend.
    pub fn render(&self) -> String {
        let g = |a: &AtomicU64| a.load(Ordering::Relaxed);
        let mut out = format!(
            "submitted={} completed={} errors={} rejected={} cancelled={} deadline_missed={} | sim={} inline={} accel={} | batches={} rows={} (mean {:.1}/batch, {} deadline, {} priority) failovers={}",
            g(&self.submitted),
            g(&self.completed),
            g(&self.errors),
            g(&self.rejected),
            g(&self.cancelled),
            g(&self.deadline_missed),
            g(&self.routed_sim),
            g(&self.routed_inline),
            g(&self.routed_accel),
            g(&self.accel_batches),
            g(&self.accel_rows),
            self.mean_batch_rows(),
            g(&self.deadline_flushes),
            g(&self.priority_flushes),
            g(&self.failovers),
        );
        for name in self.backend_names() {
            let b = self.backend(&name);
            out.push_str(&format!(
                "\n  backend {name}: init_ok={} init_failures={} jobs={} batches={} rows={} errors={}",
                g(&b.init_ok),
                g(&b.init_failures),
                g(&b.jobs),
                g(&b.batches),
                g(&b.rows),
                g(&b.errors),
            ));
        }
        let clients = self.clients.lock().unwrap();
        if !clients.is_empty() {
            let mut tags: Vec<&String> = clients.keys().collect();
            tags.sort();
            out.push_str("\n  clients:");
            for t in tags {
                out.push_str(&format!(" {t}={}", clients[t].load(Ordering::Relaxed)));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_batch_rows_handles_zero() {
        let m = FabricMetrics::default();
        assert_eq!(m.mean_batch_rows(), 0.0);
        m.accel_batches.store(2, Ordering::Relaxed);
        m.accel_rows.store(10, Ordering::Relaxed);
        assert_eq!(m.mean_batch_rows(), 5.0);
    }

    #[test]
    fn render_contains_counters() {
        let m = FabricMetrics::default();
        m.submitted.store(7, Ordering::Relaxed);
        assert!(m.render().contains("submitted=7"));
    }

    #[test]
    fn backend_stats_are_shared_and_rendered() {
        let m = FabricMetrics::default();
        m.backend("native").batches.fetch_add(3, Ordering::Relaxed);
        m.backend("native").batches.fetch_add(1, Ordering::Relaxed);
        m.backend("xla").init_failures.fetch_add(1, Ordering::Relaxed);
        assert_eq!(m.backend("native").batches.load(Ordering::Relaxed), 4);
        assert_eq!(m.backend_names(), vec!["native".to_string(), "xla".to_string()]);
        let r = m.render();
        assert!(r.contains("backend native"));
        assert!(r.contains("init_failures=1"));
    }

    #[test]
    fn client_counters_accumulate() {
        let m = FabricMetrics::default();
        m.client("tenant-a").fetch_add(2, Ordering::Relaxed);
        m.client("tenant-a").fetch_add(1, Ordering::Relaxed);
        assert_eq!(m.client("tenant-a").load(Ordering::Relaxed), 3);
        assert!(m.render().contains("tenant-a=3"));
    }
}
