//! Fabric-level metrics: lock-free global counters, per-backend and
//! per-worker counters, and per-client accounting.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Counters for one sim worker's lane in the dispatch plane.
#[derive(Debug, Default)]
pub struct WorkerStats {
    /// Current staged depth of this worker's deque (gauge).
    pub depth: AtomicU64,
    /// Jobs the supervisor placed on this worker's deque.
    pub placements: AtomicU64,
    /// Jobs this worker stole from a neighbour's deque.
    pub steals: AtomicU64,
    /// Tasks this worker executed (own or stolen).
    pub executed: AtomicU64,
}

/// Per-tenant accounting: the full admission ledger for one client tag.
/// `submitted = accepted-and-resolved + still-in-flight + quota_denied +
/// shed + fabric rejections` — the serve plane's acceptance test checks
/// that every request a tenant sent is accounted for in exactly one of
/// these buckets.
#[derive(Debug, Default)]
pub struct ClientStats {
    /// Requests carrying this tag that reached admission (in-process
    /// `submit` or the serve plane's front door).
    pub submitted: AtomicU64,
    /// Jobs that were admitted *and* completed successfully.
    pub accepted: AtomicU64,
    /// Requests shed by an SLO rule before reaching the fabric.
    pub shed: AtomicU64,
    /// Requests denied by this tenant's token-bucket quota.
    pub quota_denied: AtomicU64,
    /// Retry attempts made on this tenant's behalf (each resubmission of
    /// a retryable failure counts once; the original attempt does not).
    pub retries: AtomicU64,
    /// Submits refused because the tenant's auth token was missing or
    /// wrong (serve plane with `--auth-token`).
    pub unauthorized: AtomicU64,
}

/// Counters for one named backend (`sim`, `native`, `xla`, ...).
#[derive(Debug, Default)]
pub struct BackendStats {
    /// Successful factory initialisations (sim pool: one per worker).
    pub init_ok: AtomicU64,
    /// Factory failures (each one is a failover to the next entry).
    pub init_failures: AtomicU64,
    /// Jobs answered by this backend.
    pub jobs: AtomicU64,
    /// Accelerator batches executed (mass backends).
    pub batches: AtomicU64,
    /// Rows across those batches.
    pub rows: AtomicU64,
    /// Jobs failed by this backend.
    pub errors: AtomicU64,
}

/// Counters shared across the fabric threads.
#[derive(Debug, Default)]
pub struct FabricMetrics {
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    pub errors: AtomicU64,
    /// `try_submit` rejections (admission control).
    pub rejected: AtomicU64,
    /// Jobs resolved `Cancelled` before dispatch.
    pub cancelled: AtomicU64,
    /// Jobs resolved `DeadlineExceeded` before dispatch.
    pub deadline_missed: AtomicU64,
    /// Backend initialisation failovers (registry degraded to a later entry).
    pub failovers: AtomicU64,
    pub routed_sim: AtomicU64,
    pub routed_inline: AtomicU64,
    pub routed_accel: AtomicU64,
    /// Oversized mass ops scattered across the sim pool.
    pub routed_split: AtomicU64,
    /// Shards those split ops fanned out to (mean = shards / split ops).
    pub split_shards: AtomicU64,
    pub accel_batches: AtomicU64,
    pub accel_rows: AtomicU64,
    /// Bytes of row payload copied into batcher tile arenas — the **one**
    /// copy of the batched data plane (everything else borrows shared
    /// `Arc` operands). `tile_bytes / completed` is the throughput
    /// bench's bytes-copied-per-job figure.
    pub tile_bytes: AtomicU64,
    pub deadline_flushes: AtomicU64,
    /// High-priority mass jobs that forced an immediate batch flush.
    pub priority_flushes: AtomicU64,
    /// Program jobs served from a cached `(family, mode, size-class)`
    /// template (no source regeneration, no reassembly).
    pub template_hits: AtomicU64,
    /// Program jobs whose template had to be generated and assembled.
    pub template_misses: AtomicU64,
    /// Program jobs served by resetting a worker's existing
    /// `EmpaProcessor` (cores/memory/icache reused).
    pub proc_reuses: AtomicU64,
    /// Program jobs that had to construct a fresh `EmpaProcessor`
    /// (first job on a worker).
    pub proc_rebuilds: AtomicU64,
    /// Scheduler iterations (full simulator ticks) executed across all
    /// served program jobs — the event-horizon scheduler's "events".
    pub sim_events: AtomicU64,
    /// Simulated clocks the event-horizon scheduler advanced without a
    /// full tick (dead-clock skips + single-core bursts), summed across
    /// served program jobs. 0 when the pool runs in lockstep.
    pub sim_clocks_skipped: AtomicU64,
    /// Decode-cache hits across served program jobs (host-perf: the
    /// code-limit boundary keeps guest data stores from invalidating
    /// cached decodes).
    pub icache_hits: AtomicU64,
    /// Decode-cache misses across served program jobs.
    pub icache_misses: AtomicU64,
    /// Program jobs served by patching data spans into the worker's
    /// already-loaded template image (no image copy, no memory reload).
    pub image_reuses: AtomicU64,
    /// Host threads stepping one simulated processor (gauge: the maximum
    /// any worker reported; 1 = serial stepping everywhere).
    pub host_threads: AtomicU64,
    /// Simulator ticks whose phase A fanned out over the worker pool,
    /// summed across served program jobs (`StepMode::ParallelA`).
    pub parallel_spans: AtomicU64,
    /// Core retirements speculated inside those spans (mean span width =
    /// `parallel_cores / parallel_spans`).
    pub parallel_cores: AtomicU64,
    /// Speculations that conflicted with an earlier same-clock store and
    /// were re-executed serially.
    pub span_conflicts: AtomicU64,
    /// Span-size histogram: buckets 2, 3, 4, 5–8, 9–16, 17+ cores.
    pub span_hist: [AtomicU64; 6],
    /// Simulated clocks advanced through multi-clock span batches
    /// (subset of `sim_clocks_skipped`), summed across program jobs.
    pub batched_clocks: AtomicU64,
    /// Batched clocks advanced under a ported (non-ideal) bus — windows
    /// whose fetch charges were replayed in lockstep grant order rather
    /// than charged serially.
    pub batched_ported_clocks: AtomicU64,
    /// Batched windows truncated by a stalled replayed bus charge.
    pub bus_replay_truncations: AtomicU64,
    /// Batched clocks advanced while a mass engine was mid-flight.
    pub engine_batched_clocks: AtomicU64,
    /// Batch-length histogram in clocks: buckets 1–2, 3, 4, 5–8, 9–16,
    /// 17+; one entry per batched span.
    pub span_batch_hist: [AtomicU64; 6],
    /// Serve plane: requests denied by a tenant token-bucket quota
    /// (summed over tenants; the per-tenant split is in `client(tag)`).
    pub quota_denied: AtomicU64,
    /// Serve plane: requests shed by a tripped SLO rule (per-rule split
    /// in the SLO governor's own render).
    pub slo_shed: AtomicU64,
    /// Serve plane: submits refused for a missing/invalid auth token
    /// (summed over tenants; per-tenant split in `client(tag)`).
    pub unauthorized: AtomicU64,
    /// Backend `execute` panics caught by a sim-pool worker and
    /// converted into typed `FabricError::Backend` completions — the
    /// lane survives, the job resolves, and this counter is the audit
    /// trail. Nonzero outside chaos runs means a real backend bug.
    pub worker_panics: AtomicU64,
    /// Chaos plane: faults injected per site (`empa::chaos`). All zero
    /// — and the `chaos:` render line absent — unless a seeded
    /// `ChaosConfig` armed the fabric.
    pub chaos_backend_faults: AtomicU64,
    pub chaos_worker_stalls: AtomicU64,
    pub chaos_guest_faults: AtomicU64,
    pub chaos_wire_faults: AtomicU64,
    /// Retry layer: resubmissions of retryable failures, policies that
    /// ran out of attempts, and hedged duplicate submissions.
    pub retries: AtomicU64,
    pub retry_exhausted: AtomicU64,
    pub hedges: AtomicU64,
    backends: Mutex<HashMap<String, Arc<BackendStats>>>,
    clients: Mutex<HashMap<String, Arc<ClientStats>>>,
    workers: Mutex<Vec<Arc<WorkerStats>>>,
}

impl FabricMetrics {
    /// Per-backend counters, created on first touch.
    pub fn backend(&self, name: &str) -> Arc<BackendStats> {
        let mut g = self.backends.lock().unwrap();
        Arc::clone(g.entry(name.to_string()).or_default())
    }

    /// Names of all backends that have reported, sorted.
    pub fn backend_names(&self) -> Vec<String> {
        let g = self.backends.lock().unwrap();
        let mut v: Vec<String> = g.keys().cloned().collect();
        v.sort();
        v
    }

    /// Per-worker dispatch-plane counters, created on first touch.
    pub fn worker(&self, idx: usize) -> Arc<WorkerStats> {
        let mut g = self.workers.lock().unwrap();
        while g.len() <= idx {
            g.push(Arc::default());
        }
        Arc::clone(&g[idx])
    }

    /// Number of workers that have reported dispatch-plane counters.
    pub fn worker_count(&self) -> usize {
        self.workers.lock().unwrap().len()
    }

    /// Total neighbour steals across the dispatch plane.
    pub fn total_steals(&self) -> u64 {
        let g = self.workers.lock().unwrap();
        g.iter().map(|w| w.steals.load(Ordering::Relaxed)).sum()
    }

    /// Total supervisor placements across the dispatch plane.
    pub fn total_placements(&self) -> u64 {
        let g = self.workers.lock().unwrap();
        g.iter().map(|w| w.placements.load(Ordering::Relaxed)).sum()
    }

    /// Staged depth summed over every worker's deque (gauge).
    pub fn total_queue_depth(&self) -> u64 {
        let g = self.workers.lock().unwrap();
        g.iter().map(|w| w.depth.load(Ordering::Relaxed)).sum()
    }

    /// Per-tenant counters, created on first touch.
    pub fn client(&self, tag: &str) -> Arc<ClientStats> {
        let mut g = self.clients.lock().unwrap();
        Arc::clone(g.entry(tag.to_string()).or_default())
    }

    /// Mean rows per accelerator batch (batching effectiveness).
    pub fn mean_batch_rows(&self) -> f64 {
        let b = self.accel_batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.accel_rows.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    /// Mean shards per split mass op (scatter effectiveness).
    pub fn mean_split_shards(&self) -> f64 {
        let s = self.routed_split.load(Ordering::Relaxed);
        if s == 0 {
            0.0
        } else {
            self.split_shards.load(Ordering::Relaxed) as f64 / s as f64
        }
    }

    /// Template-cache hit rate of the compile-once program pipeline
    /// (0 when no program job was served).
    pub fn template_hit_rate(&self) -> f64 {
        let h = self.template_hits.load(Ordering::Relaxed);
        let m = self.template_misses.load(Ordering::Relaxed);
        if h + m == 0 {
            0.0
        } else {
            h as f64 / (h + m) as f64
        }
    }

    /// Decode-cache hit rate across served program jobs (0 when no
    /// fetch has been decoded).
    pub fn icache_hit_rate(&self) -> f64 {
        let h = self.icache_hits.load(Ordering::Relaxed);
        let m = self.icache_misses.load(Ordering::Relaxed);
        if h + m == 0 {
            0.0
        } else {
            h as f64 / (h + m) as f64
        }
    }

    /// Mean fan-out width of the parallel phase-A spans across served
    /// program jobs (0 when phase A never fanned out).
    pub fn cores_per_span(&self) -> f64 {
        let s = self.parallel_spans.load(Ordering::Relaxed);
        if s == 0 {
            0.0
        } else {
            self.parallel_cores.load(Ordering::Relaxed) as f64 / s as f64
        }
    }

    /// Effective simulated clocks per scheduler iteration across all
    /// served program jobs (1.0 ≙ lockstep; higher = dead clocks
    /// skipped). 0 when no program job has been simulated.
    pub fn sim_clocks_per_event(&self) -> f64 {
        let e = self.sim_events.load(Ordering::Relaxed);
        let s = self.sim_clocks_skipped.load(Ordering::Relaxed);
        if e == 0 {
            0.0
        } else {
            (e + s) as f64 / e as f64
        }
    }

    /// Render a summary: one global line plus one line per backend.
    pub fn render(&self) -> String {
        let g = |a: &AtomicU64| a.load(Ordering::Relaxed);
        let mut out = format!(
            "submitted={} completed={} errors={} rejected={} cancelled={} deadline_missed={} \
             | sim={} inline={} accel={} split={} (mean {:.1} shards) \
             | batches={} rows={} tile_bytes={} (mean {:.1}/batch, {} deadline, {} priority) failovers={}",
            g(&self.submitted),
            g(&self.completed),
            g(&self.errors),
            g(&self.rejected),
            g(&self.cancelled),
            g(&self.deadline_missed),
            g(&self.routed_sim),
            g(&self.routed_inline),
            g(&self.routed_accel),
            g(&self.routed_split),
            self.mean_split_shards(),
            g(&self.accel_batches),
            g(&self.accel_rows),
            g(&self.tile_bytes),
            self.mean_batch_rows(),
            g(&self.deadline_flushes),
            g(&self.priority_flushes),
            g(&self.failovers),
        );
        if g(&self.worker_panics) > 0 {
            out.push_str(&format!(" worker_panics={}", g(&self.worker_panics)));
        }
        if g(&self.template_hits) + g(&self.template_misses) > 0 {
            out.push_str(&format!(
                "\n  program pipeline: template hits={} misses={} ({:.0}% hit) proc reuses={} rebuilds={} image reuses={}",
                g(&self.template_hits),
                g(&self.template_misses),
                100.0 * self.template_hit_rate(),
                g(&self.proc_reuses),
                g(&self.proc_rebuilds),
                g(&self.image_reuses),
            ));
        }
        if g(&self.sim_events) > 0 {
            out.push_str(&format!(
                "\n  sim engine: events={} clocks_skipped={} ({:.1} clocks/event) icache hits={} misses={} ({:.0}% hit)",
                g(&self.sim_events),
                g(&self.sim_clocks_skipped),
                self.sim_clocks_per_event(),
                g(&self.icache_hits),
                g(&self.icache_misses),
                100.0 * self.icache_hit_rate(),
            ));
        }
        if g(&self.host_threads) > 1 || g(&self.parallel_spans) > 0 {
            let h = &self.span_hist;
            let b = &self.span_batch_hist;
            out.push_str(&format!(
                "\n  host parallel: threads={} spans={} cores={} (mean {:.1}/span) conflicts={} \
                 hist [2]={} [3]={} [4]={} [5-8]={} [9-16]={} [17+]={} \
                 batched_clocks={} batch_hist [1-2]={} [3]={} [4]={} [5-8]={} [9-16]={} [17+]={} \
                 batched_ported={} replay_truncs={} engine_batched={}",
                g(&self.host_threads),
                g(&self.parallel_spans),
                g(&self.parallel_cores),
                self.cores_per_span(),
                g(&self.span_conflicts),
                g(&h[0]),
                g(&h[1]),
                g(&h[2]),
                g(&h[3]),
                g(&h[4]),
                g(&h[5]),
                g(&self.batched_clocks),
                g(&b[0]),
                g(&b[1]),
                g(&b[2]),
                g(&b[3]),
                g(&b[4]),
                g(&b[5]),
                g(&self.batched_ported_clocks),
                g(&self.bus_replay_truncations),
                g(&self.engine_batched_clocks),
            ));
        }
        {
            let workers = self.workers.lock().unwrap();
            if !workers.is_empty() {
                out.push_str("\n  dispatch plane:");
                for (i, w) in workers.iter().enumerate() {
                    out.push_str(&format!(
                        " w{i}[depth={} placed={} steals={} executed={}]",
                        g(&w.depth),
                        g(&w.placements),
                        g(&w.steals),
                        g(&w.executed),
                    ));
                }
            }
        }
        for name in self.backend_names() {
            let b = self.backend(&name);
            out.push_str(&format!(
                "\n  backend {name}: init_ok={} init_failures={} jobs={} batches={} rows={} errors={}",
                g(&b.init_ok),
                g(&b.init_failures),
                g(&b.jobs),
                g(&b.batches),
                g(&b.rows),
                g(&b.errors),
            ));
        }
        if g(&self.quota_denied) + g(&self.slo_shed) + g(&self.unauthorized) > 0 {
            out.push_str(&format!(
                "\n  serve plane: quota_denied={} slo_shed={}",
                g(&self.quota_denied),
                g(&self.slo_shed),
            ));
            if g(&self.unauthorized) > 0 {
                out.push_str(&format!(" unauthorized={}", g(&self.unauthorized)));
            }
        }
        let chaos_total = g(&self.chaos_backend_faults)
            + g(&self.chaos_worker_stalls)
            + g(&self.chaos_guest_faults)
            + g(&self.chaos_wire_faults);
        if chaos_total > 0 {
            out.push_str(&format!(
                "\n  chaos: backend={} stalls={} guest={} wire={} (total {})",
                g(&self.chaos_backend_faults),
                g(&self.chaos_worker_stalls),
                g(&self.chaos_guest_faults),
                g(&self.chaos_wire_faults),
                chaos_total,
            ));
        }
        if g(&self.retries) + g(&self.retry_exhausted) + g(&self.hedges) > 0 {
            out.push_str(&format!(
                "\n  retry: retries={} exhausted={} hedges={}",
                g(&self.retries),
                g(&self.retry_exhausted),
                g(&self.hedges),
            ));
        }
        let clients = self.clients.lock().unwrap();
        if !clients.is_empty() {
            let mut tags: Vec<&String> = clients.keys().collect();
            tags.sort();
            out.push_str("\n  tenants:");
            for t in tags {
                let c = &clients[t];
                out.push_str(&format!(
                    " {t}[submitted={} accepted={} shed={} quota_denied={}",
                    g(&c.submitted),
                    g(&c.accepted),
                    g(&c.shed),
                    g(&c.quota_denied),
                ));
                // Newer per-tenant counters render only when nonzero, so
                // the long-standing bracket format (asserted verbatim in
                // the serve-plane tests) is unchanged for quiet tenants.
                if g(&c.retries) > 0 {
                    out.push_str(&format!(" retries={}", g(&c.retries)));
                }
                if g(&c.unauthorized) > 0 {
                    out.push_str(&format!(" unauthorized={}", g(&c.unauthorized)));
                }
                out.push(']');
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_batch_rows_handles_zero() {
        let m = FabricMetrics::default();
        assert_eq!(m.mean_batch_rows(), 0.0);
        m.accel_batches.store(2, Ordering::Relaxed);
        m.accel_rows.store(10, Ordering::Relaxed);
        assert_eq!(m.mean_batch_rows(), 5.0);
    }

    #[test]
    fn render_contains_counters() {
        let m = FabricMetrics::default();
        m.submitted.store(7, Ordering::Relaxed);
        assert!(m.render().contains("submitted=7"));
    }

    #[test]
    fn backend_stats_are_shared_and_rendered() {
        let m = FabricMetrics::default();
        m.backend("native").batches.fetch_add(3, Ordering::Relaxed);
        m.backend("native").batches.fetch_add(1, Ordering::Relaxed);
        m.backend("xla").init_failures.fetch_add(1, Ordering::Relaxed);
        assert_eq!(m.backend("native").batches.load(Ordering::Relaxed), 4);
        assert_eq!(m.backend_names(), vec!["native".to_string(), "xla".to_string()]);
        let r = m.render();
        assert!(r.contains("backend native"));
        assert!(r.contains("init_failures=1"));
    }

    #[test]
    fn worker_stats_grow_on_demand_and_aggregate() {
        let m = FabricMetrics::default();
        m.worker(2).steals.fetch_add(3, Ordering::Relaxed);
        m.worker(0).placements.fetch_add(5, Ordering::Relaxed);
        m.worker(0).depth.store(2, Ordering::Relaxed);
        assert_eq!(m.worker_count(), 3);
        assert_eq!(m.total_steals(), 3);
        assert_eq!(m.total_placements(), 5);
        assert_eq!(m.total_queue_depth(), 2);
        let r = m.render();
        assert!(r.contains("dispatch plane"), "{r}");
        assert!(r.contains("w2[depth=0 placed=0 steals=3 executed=0]"), "{r}");
    }

    #[test]
    fn mean_split_shards_handles_zero() {
        let m = FabricMetrics::default();
        assert_eq!(m.mean_split_shards(), 0.0);
        m.routed_split.store(2, Ordering::Relaxed);
        m.split_shards.store(7, Ordering::Relaxed);
        assert_eq!(m.mean_split_shards(), 3.5);
    }

    #[test]
    fn program_pipeline_counters_render_and_rate() {
        let m = FabricMetrics::default();
        assert_eq!(m.template_hit_rate(), 0.0);
        assert!(!m.render().contains("program pipeline"), "line hidden before any program job");
        m.template_hits.store(3, Ordering::Relaxed);
        m.template_misses.store(1, Ordering::Relaxed);
        m.proc_reuses.store(3, Ordering::Relaxed);
        m.proc_rebuilds.store(1, Ordering::Relaxed);
        assert_eq!(m.template_hit_rate(), 0.75);
        let r = m.render();
        assert!(r.contains("program pipeline: template hits=3 misses=1 (75% hit)"), "{r}");
        assert!(r.contains("proc reuses=3 rebuilds=1"), "{r}");
    }

    #[test]
    fn sim_engine_counters_render_and_rate() {
        let m = FabricMetrics::default();
        assert_eq!(m.sim_clocks_per_event(), 0.0);
        assert!(!m.render().contains("sim engine"), "line hidden before any simulation");
        m.sim_events.store(4, Ordering::Relaxed);
        m.sim_clocks_skipped.store(36, Ordering::Relaxed);
        assert_eq!(m.sim_clocks_per_event(), 10.0);
        let r = m.render();
        assert!(r.contains("sim engine: events=4 clocks_skipped=36 (10.0 clocks/event)"), "{r}");
    }

    #[test]
    fn host_parallel_line_is_hidden_until_threads_or_spans() {
        let m = FabricMetrics::default();
        assert_eq!(m.cores_per_span(), 0.0);
        assert!(!m.render().contains("host parallel"), "hidden while serial");
        m.host_threads.store(4, Ordering::Relaxed);
        m.parallel_spans.store(2, Ordering::Relaxed);
        m.parallel_cores.store(7, Ordering::Relaxed);
        m.span_conflicts.store(1, Ordering::Relaxed);
        m.span_hist[0].store(1, Ordering::Relaxed);
        m.span_hist[3].store(1, Ordering::Relaxed);
        assert_eq!(m.cores_per_span(), 3.5);
        let r = m.render();
        assert!(r.contains("host parallel: threads=4 spans=2 cores=7 (mean 3.5/span)"), "{r}");
        assert!(r.contains("conflicts=1"), "{r}");
        assert!(r.contains("hist [2]=1 [3]=0 [4]=0 [5-8]=1 [9-16]=0 [17+]=0"), "{r}");
        assert!(r.contains("batched_clocks=0"), "{r}");
        m.batched_clocks.store(40, Ordering::Relaxed);
        m.span_batch_hist[4].store(3, Ordering::Relaxed);
        m.batched_ported_clocks.store(25, Ordering::Relaxed);
        m.bus_replay_truncations.store(2, Ordering::Relaxed);
        m.engine_batched_clocks.store(8, Ordering::Relaxed);
        let r = m.render();
        assert!(
            r.contains("batched_clocks=40 batch_hist [1-2]=0 [3]=0 [4]=0 [5-8]=0 [9-16]=3 [17+]=0"),
            "{r}"
        );
        assert!(r.contains("batched_ported=25 replay_truncs=2 engine_batched=8"), "{r}");
        // a parallel pool that never spanned still shows its thread count
        let m = FabricMetrics::default();
        m.host_threads.store(2, Ordering::Relaxed);
        assert!(m.render().contains("host parallel: threads=2 spans=0"));
    }

    #[test]
    fn icache_and_tile_counters_render() {
        let m = FabricMetrics::default();
        assert_eq!(m.icache_hit_rate(), 0.0);
        m.sim_events.store(1, Ordering::Relaxed);
        m.icache_hits.store(9, Ordering::Relaxed);
        m.icache_misses.store(1, Ordering::Relaxed);
        m.image_reuses.store(2, Ordering::Relaxed);
        m.template_hits.store(1, Ordering::Relaxed);
        m.tile_bytes.store(4096, Ordering::Relaxed);
        assert_eq!(m.icache_hit_rate(), 0.9);
        let r = m.render();
        assert!(r.contains("icache hits=9 misses=1 (90% hit)"), "{r}");
        assert!(r.contains("image reuses=2"), "{r}");
        assert!(r.contains("tile_bytes=4096"), "{r}");
    }

    #[test]
    fn client_counters_accumulate() {
        let m = FabricMetrics::default();
        m.client("tenant-a").submitted.fetch_add(2, Ordering::Relaxed);
        m.client("tenant-a").submitted.fetch_add(1, Ordering::Relaxed);
        m.client("tenant-a").accepted.fetch_add(2, Ordering::Relaxed);
        m.client("tenant-b").quota_denied.fetch_add(4, Ordering::Relaxed);
        m.client("tenant-b").shed.fetch_add(1, Ordering::Relaxed);
        assert_eq!(m.client("tenant-a").submitted.load(Ordering::Relaxed), 3);
        let r = m.render();
        assert!(r.contains("tenants:"), "{r}");
        assert!(r.contains("tenant-a[submitted=3 accepted=2 shed=0 quota_denied=0]"), "{r}");
        assert!(r.contains("tenant-b[submitted=0 accepted=0 shed=1 quota_denied=4]"), "{r}");
        let a = r.find("tenant-a").unwrap();
        let b = r.find("tenant-b").unwrap();
        assert!(a < b, "tenants render sorted by tag");
    }

    #[test]
    fn serve_plane_line_is_hidden_until_a_denial_or_shed() {
        let m = FabricMetrics::default();
        assert!(!m.render().contains("serve plane"), "hidden while zero");
        m.quota_denied.fetch_add(3, Ordering::Relaxed);
        m.slo_shed.fetch_add(1, Ordering::Relaxed);
        let r = m.render();
        assert!(r.contains("serve plane: quota_denied=3 slo_shed=1"), "{r}");
        assert!(!r.contains("unauthorized"), "hidden until an auth refusal");
        m.unauthorized.fetch_add(2, Ordering::Relaxed);
        let r = m.render();
        assert!(r.contains("serve plane: quota_denied=3 slo_shed=1 unauthorized=2"), "{r}");
    }

    #[test]
    fn chaos_and_retry_lines_are_hidden_until_nonzero() {
        let m = FabricMetrics::default();
        let r = m.render();
        assert!(!r.contains("chaos:"), "{r}");
        assert!(!r.contains("retry:"), "{r}");
        assert!(!r.contains("worker_panics"), "{r}");
        m.chaos_backend_faults.fetch_add(2, Ordering::Relaxed);
        m.chaos_wire_faults.fetch_add(1, Ordering::Relaxed);
        m.retries.fetch_add(4, Ordering::Relaxed);
        m.hedges.fetch_add(1, Ordering::Relaxed);
        m.worker_panics.fetch_add(1, Ordering::Relaxed);
        let r = m.render();
        assert!(r.contains("chaos: backend=2 stalls=0 guest=0 wire=1 (total 3)"), "{r}");
        assert!(r.contains("retry: retries=4 exhausted=0 hedges=1"), "{r}");
        assert!(r.contains("worker_panics=1"), "{r}");
    }

    #[test]
    fn per_tenant_retry_and_unauthorized_render_only_when_nonzero() {
        let m = FabricMetrics::default();
        m.client("quiet").submitted.fetch_add(1, Ordering::Relaxed);
        m.client("noisy").submitted.fetch_add(2, Ordering::Relaxed);
        m.client("noisy").retries.fetch_add(3, Ordering::Relaxed);
        m.client("noisy").unauthorized.fetch_add(1, Ordering::Relaxed);
        let r = m.render();
        assert!(
            r.contains("quiet[submitted=1 accepted=0 shed=0 quota_denied=0]"),
            "quiet tenants keep the original bracket format: {r}"
        );
        assert!(
            r.contains("noisy[submitted=2 accepted=0 shed=0 quota_denied=0 retries=3 unauthorized=1]"),
            "{r}"
        );
    }
}
