//! Fabric-level metrics (lock-free counters + latency summaries).

use std::sync::atomic::{AtomicU64, Ordering};

/// Counters shared across the fabric threads.
#[derive(Debug, Default)]
pub struct FabricMetrics {
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    pub errors: AtomicU64,
    pub routed_sim: AtomicU64,
    pub routed_inline: AtomicU64,
    pub routed_accel: AtomicU64,
    pub accel_batches: AtomicU64,
    pub accel_rows: AtomicU64,
    pub deadline_flushes: AtomicU64,
}

impl FabricMetrics {
    /// Mean rows per accelerator batch (batching effectiveness).
    pub fn mean_batch_rows(&self) -> f64 {
        let b = self.accel_batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.accel_rows.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    /// Render a one-line summary.
    pub fn render(&self) -> String {
        let g = |a: &AtomicU64| a.load(Ordering::Relaxed);
        format!(
            "submitted={} completed={} errors={} | sim={} inline={} accel={} | batches={} rows={} (mean {:.1}/batch, {} deadline)",
            g(&self.submitted),
            g(&self.completed),
            g(&self.errors),
            g(&self.routed_sim),
            g(&self.routed_inline),
            g(&self.routed_accel),
            g(&self.accel_batches),
            g(&self.accel_rows),
            self.mean_batch_rows(),
            g(&self.deadline_flushes),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_batch_rows_handles_zero() {
        let m = FabricMetrics::default();
        assert_eq!(m.mean_batch_rows(), 0.0);
        m.accel_batches.store(2, Ordering::Relaxed);
        m.accel_rows.store(10, Ordering::Relaxed);
        assert_eq!(m.mean_batch_rows(), 5.0);
    }

    #[test]
    fn render_contains_counters() {
        let m = FabricMetrics::default();
        m.submitted.store(7, Ordering::Relaxed);
        assert!(m.render().contains("submitted=7"));
    }
}
