//! The EMPA fabric coordinator — the paper's supervisor idea lifted to a
//! service (L3): a leader routes incoming jobs either to a pool of
//! simulated EMPA processors (scalar/control QTs) or — through the §3.8
//! accelerator link — to a chain of mass-processing backends, with
//! dynamic batching into bucket-shaped tiles, priority staging,
//! per-job deadlines/cancellation, and bounded-queue backpressure.
//!
//! Topology (all std threads; the binary is self-contained, Python never
//! runs here):
//!
//! ```text
//!  FabricClient ── submit / try_submit / submit_batch ──► router (leader)
//!   (cloneable)        bounded ingress queue               │
//!                                                          ├ RunProgram: priority-staged
//!                                                          │      ▼
//!                                                 sim worker pool ("sim" backends,
//!                                                   one instance per worker)
//!                                                          │
//!                                                          ├ small mass op: inline
//!                                                          │
//!                                                          └ Mass*: per-op Batcher
//!                                                                 ▼ (size/deadline/priority)
//!                                                          mass worker — backend chain
//!                                                          ("xla" → "native" failover)
//! ```
//!
//! The public vocabulary (requests, errors, handles, completions) lives
//! in [`crate::api`]; backends and their registry in [`backend`]; this
//! module owns the threads and queues between them.

pub mod backend;
pub mod client;
pub mod metrics;
pub mod router;

pub use crate::api::{
    Completion, FabricError, Job, JobRequest, JobResult, Output, Priority, RequestKind, Route,
};
pub use backend::{
    AccelBackend, Backend, BackendClass, BackendEntry, BackendFactory, BackendJob, BackendReply,
    BackendRegistry, SimBackend,
};
pub use client::FabricClient;
pub use metrics::{BackendStats, FabricMetrics};
pub use router::RoutePolicy;

use crate::accel::{batch::PendingRow, Batcher, BatcherConfig, MassOp, MassRequest, MassResult};
use crate::empa::EmpaConfig;
use crate::workload::Request;
use std::collections::{BinaryHeap, HashMap};
use std::sync::atomic::{AtomicBool, Ordering::Relaxed};
use std::sync::mpsc::{
    self, sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender, TrySendError,
};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Fabric configuration.
#[derive(Debug, Clone)]
pub struct FabricConfig {
    /// Simulation worker threads.
    pub sim_workers: usize,
    /// EMPA processor configuration used by the sim workers.
    pub empa: EmpaConfig,
    /// Dynamic batching policy for mass ops.
    pub batcher: BatcherConfig,
    /// Routing policy (accelerator threshold etc.).
    pub route: RoutePolicy,
    /// Bounded queue depth (ingress and sim pool — backpressure).
    pub queue_cap: usize,
}

impl Default for FabricConfig {
    fn default() -> Self {
        FabricConfig {
            sim_workers: 4,
            empa: EmpaConfig::default(),
            batcher: BatcherConfig::default(),
            route: RoutePolicy::default(),
            queue_cap: 256,
        }
    }
}

// ----------------------------------------------------------------------
// deprecated compatibility shim
// ----------------------------------------------------------------------

/// Pre-registry reply enum, kept only so downstream code migrating to the
/// typed API can convert at the boundary. New code matches on
/// [`Output`] / [`FabricError`] instead.
#[deprecated(note = "match on `api::Output` and `api::FabricError` via `Job::wait`")]
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Program { eax: i32, clocks: u64, cores: usize },
    Scalars(Vec<f32>),
    Rows(Vec<Vec<f32>>),
    Error(String),
}

#[allow(deprecated)]
impl Response {
    /// Flatten a typed job result into the legacy shape.
    pub fn from_result(res: &JobResult) -> Response {
        match res {
            Ok(c) => match &c.output {
                Output::Program { eax, clocks, cores } => {
                    Response::Program { eax: *eax, clocks: *clocks, cores: *cores }
                }
                Output::Scalars(v) => Response::Scalars(v.clone()),
                Output::Rows(r) => Response::Rows(r.clone()),
            },
            Err(e) => Response::Error(e.to_string()),
        }
    }
}

// ----------------------------------------------------------------------
// internal wire types
// ----------------------------------------------------------------------

/// Per-job context carried through queues to whichever thread resolves
/// the job. Replies flow through `reply`; latencies are derived from
/// `submitted`.
pub(crate) struct JobCtx {
    #[allow(dead_code)] // diagnostic identity; replies ride the per-job channel
    pub id: u64,
    pub priority: Priority,
    pub deadline: Option<Duration>,
    pub submitted: Instant,
    pub cancel: Arc<AtomicBool>,
    pub reply: Sender<JobResult>,
}

impl JobCtx {
    fn cancelled(&self) -> bool {
        self.cancel.load(std::sync::atomic::Ordering::Acquire)
    }

    fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| now.saturating_duration_since(self.submitted) > d)
    }

    /// Pre-dispatch gate: resolves the job if it was cancelled or its
    /// deadline passed; returns whether it should still run.
    fn admit(&self, metrics: &FabricMetrics) -> bool {
        if self.cancelled() {
            self.fail(metrics, FabricError::Cancelled);
            return false;
        }
        if self.expired(Instant::now()) {
            self.fail(metrics, FabricError::DeadlineExceeded);
            return false;
        }
        true
    }

    fn complete(
        &self,
        metrics: &FabricMetrics,
        output: Output,
        route: Route,
        backend: &str,
        batch_rows: usize,
        dispatched: Instant,
    ) {
        metrics.completed.fetch_add(1, Relaxed);
        let now = Instant::now();
        let _ = self.reply.send(Ok(Completion {
            output,
            route,
            backend: backend.to_string(),
            batch_rows,
            queue_latency: dispatched.saturating_duration_since(self.submitted),
            latency: now.saturating_duration_since(self.submitted),
        }));
    }

    fn fail(&self, metrics: &FabricMetrics, err: FabricError) {
        match err {
            FabricError::Cancelled => metrics.cancelled.fetch_add(1, Relaxed),
            FabricError::DeadlineExceeded => metrics.deadline_missed.fetch_add(1, Relaxed),
            _ => metrics.errors.fetch_add(1, Relaxed),
        };
        let _ = self.reply.send(Err(err));
    }
}

pub(crate) enum Msg {
    Job { kind: RequestKind, ctx: JobCtx },
    Shutdown,
}

enum SimMsg {
    Run { kind: RequestKind, ctx: JobCtx },
}

struct MassJob {
    ctx: JobCtx,
}

enum AccelMsg {
    Batch { op: MassOp, rows: Vec<PendingRow<MassJob>>, scale_bias: [f32; 2] },
}

/// Program job parked in the router, ordered by (priority, FIFO).
struct Staged {
    priority: Priority,
    seq: u64,
    kind: RequestKind,
    ctx: JobCtx,
}

impl PartialEq for Staged {
    fn eq(&self, other: &Self) -> bool {
        self.priority == other.priority && self.seq == other.seq
    }
}
impl Eq for Staged {}
impl PartialOrd for Staged {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Staged {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // max-heap: higher priority first, then earlier submission
        self.priority.cmp(&other.priority).then_with(|| other.seq.cmp(&self.seq))
    }
}

// ----------------------------------------------------------------------
// the fabric
// ----------------------------------------------------------------------

/// The running fabric.
pub struct Fabric {
    client: FabricClient,
    pub metrics: Arc<FabricMetrics>,
    threads: Mutex<Vec<JoinHandle<()>>>,
}

impl Fabric {
    /// Start the fabric over a backend registry. Backends are constructed
    /// *on* their worker threads (PJRT handles are thread-affine) in
    /// registration order, failing over to later entries of the same
    /// class.
    pub fn start(cfg: FabricConfig, registry: BackendRegistry) -> Arc<Fabric> {
        let metrics = Arc::new(FabricMetrics::default());
        let (tx, rx) = sync_channel::<Msg>(cfg.queue_cap);
        let mut threads = Vec::new();
        let program_chain = registry.chain(BackendClass::Program);
        let mass_chain = registry.chain(BackendClass::Mass);

        // --- sim worker pool -------------------------------------------
        // Shallow channel: the backlog lives in the router's priority
        // heap, so High jobs overtake instead of queueing FIFO.
        let (sim_tx, sim_rx) = sync_channel::<SimMsg>(cfg.sim_workers.max(1) * 2);
        let sim_rx = Arc::new(Mutex::new(sim_rx));
        for w in 0..cfg.sim_workers.max(1) {
            let rx = Arc::clone(&sim_rx);
            let chain = program_chain.clone();
            let m = Arc::clone(&metrics);
            threads.push(
                std::thread::Builder::new()
                    .name(format!("empa-sim-{w}"))
                    .spawn(move || sim_worker(rx, chain, m))
                    .expect("spawn sim worker"),
            );
        }

        // --- mass worker (accelerator chain) ---------------------------
        let (acc_tx, acc_rx) = mpsc::channel::<AccelMsg>();
        {
            let m = Arc::clone(&metrics);
            threads.push(
                std::thread::Builder::new()
                    .name("fabric-mass".into())
                    .spawn(move || mass_worker(acc_rx, mass_chain, m))
                    .expect("spawn mass worker"),
            );
        }

        // --- router / leader -------------------------------------------
        {
            let m = Arc::clone(&metrics);
            let cfg2 = cfg.clone();
            threads.push(
                std::thread::Builder::new()
                    .name("fabric-router".into())
                    .spawn(move || router_loop(rx, sim_tx, acc_tx, cfg2, m))
                    .expect("spawn router"),
            );
        }

        let client = FabricClient::new(tx, Arc::clone(&metrics));
        Arc::new(Fabric { client, metrics, threads: Mutex::new(threads) })
    }

    /// Start with the default local registry (`sim` + `native`).
    pub fn start_local(cfg: FabricConfig) -> Arc<Fabric> {
        let registry = BackendRegistry::local(cfg.empa.clone());
        Fabric::start(cfg, registry)
    }

    /// A new cheaply-cloneable client onto this fabric.
    pub fn client(&self) -> FabricClient {
        self.client.clone()
    }

    /// Submit a job; blocks when the ingress queue is full (backpressure).
    pub fn submit(&self, req: impl Into<JobRequest>) -> Result<Job, FabricError> {
        self.client.submit(req)
    }

    /// Non-blocking submit; see [`FabricClient::try_submit`].
    pub fn try_submit(&self, req: impl Into<JobRequest>) -> Result<Job, FabricError> {
        self.client.try_submit(req)
    }

    /// Submit a full trace and wait for all responses; returns per-request
    /// (request-id, result). Submission failure (e.g. shutdown mid-trace)
    /// propagates instead of panicking.
    pub fn run_trace(&self, trace: Vec<Request>) -> Result<Vec<(u64, JobResult)>, FabricError> {
        let mut jobs = Vec::with_capacity(trace.len());
        for r in trace {
            jobs.push((r.id, self.submit(r.job)?));
        }
        Ok(jobs.into_iter().map(|(rid, j)| (rid, j.wait())).collect())
    }

    /// Stop all threads (idempotent; pending jobs are completed first).
    pub fn shutdown(&self) {
        let _ = self.client.shutdown_signal();
        let mut g = self.threads.lock().unwrap();
        for t in g.drain(..) {
            let _ = t.join();
        }
    }
}

// ----------------------------------------------------------------------
// threads
// ----------------------------------------------------------------------

/// How long the router waits for new work while program jobs are staged
/// for a full sim pool (it retries the pool on every wake-up).
const STAGED_RETRY: Duration = Duration::from_micros(200);

fn router_loop(
    rx: Receiver<Msg>,
    sim_tx: SyncSender<SimMsg>,
    acc_tx: mpsc::Sender<AccelMsg>,
    cfg: FabricConfig,
    metrics: Arc<FabricMetrics>,
) {
    // One batcher per mass op kind (rows of one flush share an artifact).
    let mut batchers: HashMap<MassOp, Batcher<MassJob>> = HashMap::new();
    // Program jobs waiting for a sim pool slot, highest priority first.
    // Bounded: past this the router stops ingesting, making the ingress
    // queue the caller-visible backpressure signal.
    let mut staged: BinaryHeap<Staged> = BinaryHeap::new();
    let staged_cap = cfg.queue_cap.max(1);
    let mut seq = 0u64;
    let inline_stats = metrics.backend("inline");
    let flush = |op: MassOp, rows: Vec<PendingRow<MassJob>>, acc_tx: &mpsc::Sender<AccelMsg>| {
        let _ = acc_tx.send(AccelMsg::Batch { op, rows, scale_bias: [0.0; 2] });
    };
    loop {
        // Drain staged program jobs into the pool without blocking.
        while let Some(s) = staged.pop() {
            if !s.ctx.admit(&metrics) {
                continue;
            }
            let (pr, sq) = (s.priority, s.seq);
            match sim_tx.try_send(SimMsg::Run { kind: s.kind, ctx: s.ctx }) {
                Ok(()) => {}
                Err(TrySendError::Full(SimMsg::Run { kind, ctx })) => {
                    staged.push(Staged { priority: pr, seq: sq, kind, ctx });
                    break;
                }
                Err(TrySendError::Disconnected(SimMsg::Run { ctx, .. })) => {
                    ctx.fail(&metrics, FabricError::Shutdown);
                }
            }
        }

        // Wait bounded by the earliest batch deadline / staged backlog.
        let batch_deadline = batchers.values().filter_map(|b| b.next_deadline()).min();
        let staged_retry =
            if staged.is_empty() { None } else { Some(Instant::now() + STAGED_RETRY) };
        let wake = match (batch_deadline, staged_retry) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        };
        let msg = if staged.len() >= staged_cap {
            // Backpressure: the program backlog is at capacity, so stop
            // ingesting and let the bounded ingress queue fill — that is
            // what `try_submit` observes as QueueFull. Wake soon to retry
            // the pool and honour batch deadlines.
            let until = wake.unwrap_or_else(|| Instant::now() + STAGED_RETRY);
            std::thread::sleep(
                until.saturating_duration_since(Instant::now()).min(STAGED_RETRY),
            );
            None
        } else {
            match wake {
                Some(d) => {
                    let wait = d.saturating_duration_since(Instant::now());
                    match rx.recv_timeout(wait) {
                        Ok(m) => Some(m),
                        Err(RecvTimeoutError::Timeout) => None,
                        Err(RecvTimeoutError::Disconnected) => break,
                    }
                }
                None => match rx.recv() {
                    Ok(m) => Some(m),
                    Err(_) => break,
                },
            }
        };
        // Deadline flushes first (they are due).
        let now = Instant::now();
        for (op, b) in batchers.iter_mut() {
            if let Some(rows) = b.poll(now) {
                metrics.deadline_flushes.fetch_add(1, Relaxed);
                flush(*op, rows, &acc_tx);
            }
        }
        let Some(msg) = msg else { continue };
        match msg {
            Msg::Shutdown => break,
            Msg::Job { kind, ctx } => {
                if !ctx.admit(&metrics) {
                    continue;
                }
                match router::route(&kind, &cfg.route) {
                    Route::Simulator => {
                        metrics.routed_sim.fetch_add(1, Relaxed);
                        seq += 1;
                        staged.push(Staged { priority: ctx.priority, seq, kind, ctx });
                    }
                    Route::Inline => {
                        // Small mass op: not worth the accelerator round
                        // trip (the §2.4 offset-time argument).
                        metrics.routed_inline.fetch_add(1, Relaxed);
                        let dispatched = Instant::now();
                        match inline_mass(&kind) {
                            Ok(out) => {
                                inline_stats.jobs.fetch_add(1, Relaxed);
                                ctx.complete(&metrics, out, Route::Inline, "inline", 1, dispatched);
                            }
                            Err(e) => {
                                inline_stats.errors.fetch_add(1, Relaxed);
                                ctx.fail(&metrics, e);
                            }
                        }
                    }
                    Route::Accelerator => {
                        metrics.routed_accel.fetch_add(1, Relaxed);
                        let high = ctx.priority == Priority::High;
                        let (op, row, row2) = match kind {
                            RequestKind::MassSum { values } => (MassOp::Sumup, values, None),
                            RequestKind::MassDot { a, b } => (MassOp::Dot, a, Some(b)),
                            RequestKind::RunProgram { .. } => unreachable!("router"),
                        };
                        let b = batchers
                            .entry(op)
                            .or_insert_with(|| Batcher::new(cfg.batcher.clone()));
                        if let Some(rows) = b.push(MassJob { ctx }, row, row2, Instant::now()) {
                            flush(op, rows, &acc_tx);
                        } else if high {
                            // High priority refuses to wait out the batch
                            // window: take whatever is pending now.
                            if let Some(rows) = b.drain() {
                                metrics.priority_flushes.fetch_add(1, Relaxed);
                                flush(op, rows, &acc_tx);
                            }
                        }
                    }
                }
            }
        }
    }
    // Shutdown drain: staged programs to the pool (blocking — workers are
    // still up), pending batches to the mass worker.
    while let Some(s) = staged.pop() {
        if !s.ctx.admit(&metrics) {
            continue;
        }
        if let Err(mpsc::SendError(SimMsg::Run { ctx, .. })) =
            sim_tx.send(SimMsg::Run { kind: s.kind, ctx: s.ctx })
        {
            ctx.fail(&metrics, FabricError::Shutdown);
        }
    }
    for (op, mut b) in batchers {
        if let Some(rows) = b.drain() {
            flush(op, rows, &acc_tx);
        }
    }
    // Per-worker stop: dropping the senders disconnects each worker's
    // recv loop once it has drained the queue — no counted Stop
    // broadcast, so any pool size shuts down cleanly.
    drop(sim_tx);
    drop(acc_tx);
}

fn inline_mass(kind: &RequestKind) -> Result<Output, FabricError> {
    match kind {
        RequestKind::MassSum { values } => Ok(Output::Scalars(vec![values.iter().sum()])),
        RequestKind::MassDot { a, b } => {
            Ok(Output::Scalars(vec![a.iter().zip(b).map(|(x, y)| x * y).sum()]))
        }
        RequestKind::RunProgram { .. } => Err(FabricError::Backend {
            name: "inline".into(),
            msg: "program routed inline".into(),
        }),
    }
}

/// Instantiate the first healthy backend of a chain on this thread,
/// recording init successes/failures per backend.
fn instantiate_chain(
    chain: &[Arc<BackendEntry>],
    metrics: &FabricMetrics,
) -> Result<Box<dyn Backend>, FabricError> {
    let mut last: Option<FabricError> = None;
    for (i, entry) in chain.iter().enumerate() {
        match entry.instantiate() {
            Ok(b) => {
                metrics.backend(&entry.name).init_ok.fetch_add(1, Relaxed);
                return Ok(b);
            }
            Err(e) => {
                metrics.backend(&entry.name).init_failures.fetch_add(1, Relaxed);
                if i + 1 < chain.len() {
                    metrics.failovers.fetch_add(1, Relaxed);
                }
                last = Some(FabricError::Backend {
                    name: entry.name.clone(),
                    msg: format!("init: {e:#}"),
                });
            }
        }
    }
    Err(last.unwrap_or(FabricError::Backend {
        name: "registry".into(),
        msg: "no backend registered for this class".into(),
    }))
}

fn single_row_output(res: MassResult) -> Output {
    match res {
        MassResult::Scalars(v) => Output::Scalars(v),
        MassResult::Rows(r) => Output::Rows(r),
        MassResult::Stats { sum, .. } => Output::Scalars(sum),
    }
}

fn sim_worker(
    rx: Arc<Mutex<Receiver<SimMsg>>>,
    chain: Vec<Arc<BackendEntry>>,
    metrics: Arc<FabricMetrics>,
) {
    let active = instantiate_chain(&chain, &metrics);
    let stats = active.as_ref().ok().map(|b| metrics.backend(b.name()));
    loop {
        let msg = {
            let g = rx.lock().unwrap();
            g.recv()
        };
        let Ok(SimMsg::Run { kind, ctx }) = msg else { break };
        if !ctx.admit(&metrics) {
            continue;
        }
        let dispatched = Instant::now();
        let backend = match &active {
            Ok(b) => b,
            Err(e) => {
                ctx.fail(&metrics, e.clone());
                continue;
            }
        };
        let stats = stats.as_ref().expect("stats exist when backend does");
        let reply = match &kind {
            RequestKind::RunProgram { mode, values } => {
                backend.execute(BackendJob::Program { mode: *mode, values })
            }
            // Mass jobs are not routed here, but a sim slot can still
            // serve one (a conventional core doing the mass op).
            RequestKind::MassSum { values } => {
                let req = MassRequest::sumup(vec![values.clone()]);
                backend.execute(BackendJob::Mass(&req))
            }
            RequestKind::MassDot { a, b } => {
                let req = MassRequest::dot(vec![a.clone()], vec![b.clone()]);
                backend.execute(BackendJob::Mass(&req))
            }
        };
        match reply {
            Ok(BackendReply::Program { eax, clocks, cores }) => {
                stats.jobs.fetch_add(1, Relaxed);
                ctx.complete(
                    &metrics,
                    Output::Program { eax, clocks, cores },
                    Route::Simulator,
                    backend.name(),
                    1,
                    dispatched,
                );
            }
            Ok(BackendReply::Mass(res)) => {
                stats.jobs.fetch_add(1, Relaxed);
                ctx.complete(
                    &metrics,
                    single_row_output(res),
                    Route::Simulator,
                    backend.name(),
                    1,
                    dispatched,
                );
            }
            Err(e) => {
                stats.errors.fetch_add(1, Relaxed);
                ctx.fail(&metrics, e);
            }
        }
    }
}

/// One mass-chain slot: the entry's backend, instantiated on first use.
enum Slot {
    Untried,
    /// Initialisation failed — permanently skipped (init failure is a
    /// backend-level fact, unlike a per-batch execute error).
    Dead,
    Ready(Box<dyn Backend>, Arc<BackendStats>),
}

/// The mass-backend chain with per-batch failover: each batch tries the
/// entries in registration order, so an execute error on the preferred
/// backend (which may be specific to that one request, e.g. an oversized
/// bucket) degrades only that batch — the preferred backend stays first
/// in line for the next one. Init failures mark the slot dead for good.
struct MassChain {
    entries: Vec<Arc<BackendEntry>>,
    slots: Vec<Slot>,
}

impl MassChain {
    fn new(entries: Vec<Arc<BackendEntry>>) -> Self {
        let slots = entries.iter().map(|_| Slot::Untried).collect();
        MassChain { entries, slots }
    }

    /// Execute one batch, walking the chain until a backend answers.
    fn run(
        &mut self,
        req: &MassRequest,
        metrics: &FabricMetrics,
    ) -> Result<(MassResult, String), FabricError> {
        let rows = req.rows.len() as u64;
        let mut last_err: Option<FabricError> = None;
        let n = self.entries.len();
        for i in 0..n {
            if matches!(self.slots[i], Slot::Untried) {
                let entry = &self.entries[i];
                match entry.instantiate() {
                    Ok(b) => {
                        let stats = metrics.backend(&entry.name);
                        stats.init_ok.fetch_add(1, Relaxed);
                        self.slots[i] = Slot::Ready(b, stats);
                    }
                    Err(e) => {
                        metrics.backend(&entry.name).init_failures.fetch_add(1, Relaxed);
                        if i + 1 < n {
                            metrics.failovers.fetch_add(1, Relaxed);
                        }
                        self.slots[i] = Slot::Dead;
                        last_err = Some(FabricError::Backend {
                            name: entry.name.clone(),
                            msg: format!("init: {e:#}"),
                        });
                    }
                }
            }
            let Slot::Ready(backend, stats) = &self.slots[i] else { continue };
            match backend.execute(BackendJob::Mass(req)) {
                Ok(BackendReply::Mass(res)) => {
                    stats.jobs.fetch_add(rows, Relaxed);
                    stats.batches.fetch_add(1, Relaxed);
                    stats.rows.fetch_add(rows, Relaxed);
                    return Ok((res, backend.name().to_string()));
                }
                Ok(BackendReply::Program { .. }) => {
                    stats.errors.fetch_add(rows, Relaxed);
                    last_err = Some(FabricError::Backend {
                        name: backend.name().to_string(),
                        msg: "mass request answered with a program reply".into(),
                    });
                }
                Err(e) => {
                    stats.errors.fetch_add(rows, Relaxed);
                    last_err = Some(e);
                }
            }
            // Falling through to a later entry is a (per-batch) failover.
            if i + 1 < n {
                metrics.failovers.fetch_add(1, Relaxed);
            }
        }
        Err(last_err.unwrap_or(FabricError::Backend {
            name: "registry".into(),
            msg: "no mass backend registered".into(),
        }))
    }
}

fn mass_worker(rx: Receiver<AccelMsg>, chain: Vec<Arc<BackendEntry>>, metrics: Arc<FabricMetrics>) {
    let mut exec = MassChain::new(chain);
    while let Ok(AccelMsg::Batch { op, rows, scale_bias }) = rx.recv() {
        // Admission per row: cancelled/expired jobs resolve here instead
        // of padding the accelerator batch. Rows move into the request
        // (no copies on the hot path); contexts stay behind for replies.
        let mut ctxs = Vec::with_capacity(rows.len());
        let mut batch_rows = Vec::with_capacity(rows.len());
        let mut batch_rows2 = Vec::new();
        for p in rows {
            if !p.tag.ctx.admit(&metrics) {
                continue;
            }
            batch_rows.push(p.row);
            if let Some(r2) = p.row2 {
                batch_rows2.push(r2);
            }
            ctxs.push(p.tag.ctx);
        }
        if ctxs.is_empty() {
            continue;
        }
        let req = MassRequest { op, rows: batch_rows, rows2: batch_rows2, scale_bias };
        let dispatched = Instant::now();
        let n = ctxs.len();
        match exec.run(&req, &metrics) {
            Ok((result, name)) => {
                let got = match &result {
                    MassResult::Scalars(v) => v.len(),
                    MassResult::Rows(r) => r.len(),
                    MassResult::Stats { sum, .. } => sum.len(),
                };
                if got < n {
                    // A short answer must not silently drop the tail
                    // (dropped reply senders would read as Shutdown).
                    let err = FabricError::Backend {
                        name: name.clone(),
                        msg: format!("returned {got} results for {n} rows"),
                    };
                    for ctx in ctxs {
                        ctx.fail(&metrics, err.clone());
                    }
                    continue;
                }
                metrics.accel_batches.fetch_add(1, Relaxed);
                metrics.accel_rows.fetch_add(n as u64, Relaxed);
                match result {
                    MassResult::Scalars(vals) => {
                        for (ctx, v) in ctxs.into_iter().zip(vals) {
                            ctx.complete(
                                &metrics,
                                Output::Scalars(vec![v]),
                                Route::Accelerator,
                                &name,
                                n,
                                dispatched,
                            );
                        }
                    }
                    MassResult::Rows(out) => {
                        for (ctx, r) in ctxs.into_iter().zip(out) {
                            ctx.complete(
                                &metrics,
                                Output::Rows(vec![r]),
                                Route::Accelerator,
                                &name,
                                n,
                                dispatched,
                            );
                        }
                    }
                    MassResult::Stats { sum, .. } => {
                        for (ctx, v) in ctxs.into_iter().zip(sum) {
                            ctx.complete(
                                &metrics,
                                Output::Scalars(vec![v]),
                                Route::Accelerator,
                                &name,
                                n,
                                dispatched,
                            );
                        }
                    }
                }
            }
            Err(e) => {
                for ctx in ctxs {
                    ctx.fail(&metrics, e.clone());
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::sumup::Mode;

    fn small_fabric() -> Arc<Fabric> {
        let cfg = FabricConfig {
            sim_workers: 2,
            batcher: BatcherConfig { max_rows: 4, max_wait: Duration::from_millis(2) },
            ..Default::default()
        };
        Fabric::start_local(cfg)
    }

    #[test]
    fn program_jobs_round_trip() {
        let f = small_fabric();
        let h = f
            .submit(RequestKind::RunProgram { mode: Mode::Sumup, values: vec![1, 2, 3, 4] })
            .unwrap();
        let c = h.wait().unwrap();
        assert_eq!(c.output, Output::Program { eax: 10, clocks: 36, cores: 5 });
        assert_eq!(c.route, Route::Simulator);
        assert_eq!(c.backend, "sim");
        assert!(c.queue_latency <= c.latency);
        f.shutdown();
    }

    #[test]
    fn mass_ops_batched_and_answered() {
        let f = small_fabric();
        let hs: Vec<Job> = (0..8)
            .map(|i| f.submit(RequestKind::MassSum { values: vec![i as f32; 200] }).unwrap())
            .collect();
        for (i, h) in hs.into_iter().enumerate() {
            let c = h.wait().unwrap();
            assert_eq!(c.output, Output::Scalars(vec![(i * 200) as f32]));
            assert_eq!(c.route, Route::Accelerator);
            assert_eq!(c.backend, "native");
            assert!(c.batch_rows >= 1);
        }
        assert!(f.metrics.accel_batches.load(Relaxed) >= 2);
        f.shutdown();
    }

    #[test]
    fn small_mass_ops_computed_inline() {
        let f = small_fabric();
        let h = f.submit(RequestKind::MassSum { values: vec![1.0, 2.0] }).unwrap();
        let c = h.wait().unwrap();
        assert_eq!(c.output, Output::Scalars(vec![3.0]));
        assert_eq!((c.route, c.backend.as_str(), c.batch_rows), (Route::Inline, "inline", 1));
        assert_eq!(f.metrics.routed_inline.load(Relaxed), 1);
        assert_eq!(f.metrics.routed_accel.load(Relaxed), 0);
        f.shutdown();
    }

    #[test]
    fn deadline_flush_completes_partial_batches() {
        // 3 rows < max_rows=4: only the deadline can flush them.
        let f = small_fabric();
        let hs: Vec<Job> = (0..3)
            .map(|_| f.submit(RequestKind::MassSum { values: vec![1.0; 128] }).unwrap())
            .collect();
        for h in hs {
            assert_eq!(h.wait().unwrap().output, Output::Scalars(vec![128.0]));
        }
        f.shutdown();
    }

    #[test]
    fn mixed_trace_all_complete() {
        let f = small_fabric();
        let trace = crate::workload::TraceGen::new(crate::workload::TraceConfig {
            num_requests: 64,
            ..Default::default()
        })
        .generate();
        let results = f.run_trace(trace).unwrap();
        assert_eq!(results.len(), 64);
        assert!(results.iter().all(|(_, r)| r.is_ok()));
        f.shutdown();
    }

    #[test]
    fn high_priority_mass_jobs_flush_immediately() {
        let cfg = FabricConfig {
            sim_workers: 1,
            // Size/deadline triggers effectively disabled: only priority
            // (or shutdown) can flush.
            batcher: BatcherConfig { max_rows: 1000, max_wait: Duration::from_secs(30) },
            ..Default::default()
        };
        let f = Fabric::start_local(cfg);
        let req = JobRequest::new(RequestKind::MassSum { values: vec![2.0; 128] })
            .with_priority(Priority::High);
        let h = f.submit(req).unwrap();
        let c = h.wait().unwrap();
        assert_eq!(c.output, Output::Scalars(vec![256.0]));
        assert_eq!(f.metrics.priority_flushes.load(Relaxed), 1);
        f.shutdown();
    }

    #[test]
    fn submit_after_shutdown_is_a_typed_error() {
        let f = small_fabric();
        f.shutdown();
        let err = f.submit(RequestKind::MassSum { values: vec![1.0] }).unwrap_err();
        assert_eq!(err, FabricError::Shutdown);
        // run_trace propagates instead of panicking
        let trace = crate::workload::TraceGen::new(crate::workload::TraceConfig {
            num_requests: 4,
            ..Default::default()
        })
        .generate();
        assert_eq!(f.run_trace(trace).unwrap_err(), FabricError::Shutdown);
    }

    #[test]
    #[allow(deprecated)]
    fn legacy_response_shim_flattens_results() {
        let ok: JobResult = Ok(Completion {
            output: Output::Scalars(vec![1.0]),
            route: Route::Inline,
            backend: "inline".into(),
            batch_rows: 1,
            queue_latency: Duration::ZERO,
            latency: Duration::ZERO,
        });
        assert_eq!(Response::from_result(&ok), Response::Scalars(vec![1.0]));
        let err: JobResult = Err(FabricError::QueueFull);
        let flat = Response::from_result(&err);
        assert!(
            !matches!(flat, Response::Scalars(_) | Response::Rows(_) | Response::Program { .. }),
            "errors flatten to the legacy error variant: {flat:?}"
        );
    }
}
