//! The EMPA fabric coordinator — the paper's supervisor idea lifted to a
//! service (L3): a supervisor routes incoming jobs either to a pool of
//! simulated EMPA processors (scalar/control QTs) or — through the §3.8
//! accelerator link — to a chain of mass-processing backends, with
//! dynamic batching into bucket-shaped tiles, priority staging,
//! per-job deadlines/cancellation, and bounded-queue backpressure.
//!
//! Topology (all std threads; the binary is self-contained, Python never
//! runs here):
//!
//! ```text
//!  FabricClient ── submit / try_submit / submit_batch ──► supervisor
//!   (cloneable)        bounded ingress queue               │
//!                                                          ├ RunProgram: least-loaded
//!                                                          │   placement (overflow heap
//!                                                          │   when the plane is full)
//!                                                          │      ▼
//!                                                 dispatch plane: one bounded
//!                                                 deque per sim worker, idle
//!                                                 workers steal neighbours'
//!                                                 staged work
//!                                                          │
//!                                                          ├ small mass op: inline
//!                                                          │
//!                                                          ├ oversized mass op: scatter
//!                                                          │   into shards across idle
//!                                                          │   sim workers, gathered by
//!                                                          │   a parent-side accumulator
//!                                                          │
//!                                                          └ Mass*: per-op Batcher
//!                                                                 ▼ (size/deadline/priority)
//!                                                          mass worker — backend chain
//!                                                          ("xla" → "native" failover)
//! ```
//!
//! The public vocabulary (requests, errors, handles, completions) lives
//! in [`crate::api`]; backends and their registry in [`backend`]; the
//! per-worker deques in [`dispatch`]; this module owns the threads and
//! the supervisor between them.

pub mod backend;
pub mod client;
pub mod dispatch;
pub(crate) mod fairshare;
pub mod metrics;
pub mod router;

pub use crate::api::{
    Completion, FabricError, Job, JobRequest, JobResult, Output, Priority, RequestKind, Route,
};
pub use backend::{
    AccelBackend, Backend, BackendClass, BackendEntry, BackendFactory, BackendJob, BackendReply,
    BackendRegistry, PipelineStats, SimBackend,
};
pub use client::FabricClient;
pub use dispatch::DispatchPlane;
pub use metrics::{BackendStats, ClientStats, FabricMetrics, WorkerStats};
pub use router::RoutePolicy;

use crate::accel::{Batch, Batcher, BatcherConfig, MassOp, MassRequest, MassResult, TilePool};
use crate::empa::EmpaConfig;
use crate::kernels;
use crate::workload::Request;
use fairshare::{FairStage, Popped};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering::AcqRel, Ordering::Relaxed};
use std::sync::mpsc::{self, sync_channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Fabric configuration.
#[derive(Debug, Clone)]
pub struct FabricConfig {
    /// Simulation worker threads.
    pub sim_workers: usize,
    /// EMPA processor configuration used by the sim workers.
    pub empa: EmpaConfig,
    /// Dynamic batching policy for mass ops.
    pub batcher: BatcherConfig,
    /// Routing policy (accelerator / split thresholds etc.).
    pub route: RoutePolicy,
    /// Bounded queue depth — ingress, the dispatch plane's summed lane
    /// caps, and the supervisor's overflow heap each get this much.
    pub queue_cap: usize,
    /// Seeded fault injection (`empa::chaos`). Off by default; when
    /// armed, registry backends are wrapped in `ChaosBackend`, sim
    /// workers may stall between tasks, and the guest hook is attached.
    pub chaos: crate::chaos::ChaosConfig,
}

impl Default for FabricConfig {
    fn default() -> Self {
        FabricConfig {
            sim_workers: 4,
            empa: EmpaConfig::default(),
            batcher: BatcherConfig::default(),
            route: RoutePolicy::default(),
            queue_cap: 256,
            chaos: crate::chaos::ChaosConfig::off(),
        }
    }
}

// ----------------------------------------------------------------------
// deprecated compatibility shim
// ----------------------------------------------------------------------

/// Pre-registry reply enum, kept only so downstream code migrating to the
/// typed API can convert at the boundary. New code matches on
/// [`Output`] / [`FabricError`] instead. Nothing inside this crate uses
/// the shim anymore — its only remaining references are its own
/// compatibility tests (`legacy_response_shim_flattens_results` below).
#[deprecated(note = "match on `api::Output` and `api::FabricError` via `Job::wait`")]
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Program { eax: i32, clocks: u64, cores: usize },
    Scalars(Vec<f32>),
    Rows(Vec<Vec<f32>>),
    Error(String),
}

#[allow(deprecated)]
impl Response {
    /// Flatten a typed job result into the legacy shape. The modern
    /// `Output` borrows from shared `Arc` buffers; this shim is the one
    /// place the data plane materialises owned `Vec`s — legacy callers
    /// pay the conversion at the boundary, the serving path never does.
    pub fn from_result(res: &JobResult) -> Response {
        match res {
            Ok(c) => match &c.output {
                Output::Program { eax, clocks, cores, data: _ } => {
                    Response::Program { eax: *eax, clocks: *clocks, cores: *cores }
                }
                Output::Scalars(v) => Response::Scalars(v.to_vec()),
                Output::Rows(r) => Response::Rows(r.iter().map(|row| row.to_vec()).collect()),
            },
            Err(e) => Response::Error(e.to_string()),
        }
    }
}

// ----------------------------------------------------------------------
// internal wire types
// ----------------------------------------------------------------------

/// Per-job context carried through queues to whichever thread resolves
/// the job. Replies flow through `reply`; latencies are derived from
/// `submitted`.
pub(crate) struct JobCtx {
    #[allow(dead_code)] // diagnostic identity; replies ride the per-job channel
    pub id: u64,
    pub priority: Priority,
    pub deadline: Option<Duration>,
    pub submitted: Instant,
    pub cancel: Arc<AtomicBool>,
    pub reply: Sender<JobResult>,
    /// Tenant tag: keys fair-share staging and per-tenant accounting.
    pub client: Option<Arc<str>>,
}

impl JobCtx {
    fn cancelled(&self) -> bool {
        self.cancel.load(std::sync::atomic::Ordering::Acquire)
    }

    fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| now.saturating_duration_since(self.submitted) > d)
    }

    /// Pre-dispatch gate: resolves the job if it was cancelled or its
    /// deadline passed; returns whether it should still run.
    fn admit(&self, metrics: &FabricMetrics) -> bool {
        if self.cancelled() {
            self.fail(metrics, FabricError::Cancelled);
            return false;
        }
        if self.expired(Instant::now()) {
            self.fail(metrics, FabricError::DeadlineExceeded);
            return false;
        }
        true
    }

    #[allow(clippy::too_many_arguments)]
    fn complete(
        &self,
        metrics: &FabricMetrics,
        output: Output,
        route: Route,
        backend: &str,
        batch_rows: usize,
        shards: usize,
        dispatched: Instant,
    ) {
        metrics.completed.fetch_add(1, Relaxed);
        if let Some(t) = &self.client {
            metrics.client(t).accepted.fetch_add(1, Relaxed);
        }
        let now = Instant::now();
        let _ = self.reply.send(Ok(Completion {
            output,
            route,
            backend: backend.to_string(),
            batch_rows,
            shards,
            queue_latency: dispatched.saturating_duration_since(self.submitted),
            latency: now.saturating_duration_since(self.submitted),
        }));
    }

    fn fail(&self, metrics: &FabricMetrics, err: FabricError) {
        match err {
            FabricError::Cancelled => metrics.cancelled.fetch_add(1, Relaxed),
            FabricError::DeadlineExceeded => metrics.deadline_missed.fetch_add(1, Relaxed),
            _ => metrics.errors.fetch_add(1, Relaxed),
        };
        let _ = self.reply.send(Err(err));
    }
}

pub(crate) enum Msg {
    Job { kind: RequestKind, ctx: JobCtx },
    Shutdown,
}

/// One unit of work staged on a sim worker's deque.
pub(crate) enum SimTask {
    /// A routed job (program, or a mass op a sim slot serves whole).
    Run { kind: RequestKind, ctx: JobCtx },
    /// One chunk of a scattered oversized mass op.
    Shard(ShardTask),
}

/// A contiguous chunk of an oversized mass op, bound for one sim worker.
/// Zero-copy: the operands live in the shared [`ShardGather`]; the shard
/// carries only its range.
pub(crate) struct ShardTask {
    gather: Arc<ShardGather>,
    lo: usize,
    hi: usize,
}

/// Parent-side accumulator for a scattered mass op: it holds the
/// *submitted* operand buffers (shared `Arc`s — the scatter moves the
/// client's allocation here, no copy), shards place the canonical
/// block partials of their slice, and the last one to land folds them
/// and completes the job (the §5.2 SUMUP engine's merge step, lifted
/// to the service layer).
pub(crate) struct ShardGather {
    a: Arc<[f32]>,
    /// Second operand (dot only); slicing is bounded by the shorter side.
    b: Option<Arc<[f32]>>,
    ctx: Mutex<Option<JobCtx>>,
    /// One `kernels::BLOCK`-sized partial per block of the full operand,
    /// placed by global block index. Shard boundaries are block-aligned
    /// (see [`scatter`](MassRouter::scatter)), so the slots line up with
    /// the whole-slice block grid and the final fold is bit-identical to
    /// the inline `kernels::sum`/`dot` — regardless of shard completion
    /// order. This replaces an order-dependent running f64 sum that made
    /// the split route drift from the inline route.
    partials: Mutex<Vec<f32>>,
    /// Sticky cancel/deadline verdict (see [`ShardGather::check_dead`]).
    dead: AtomicBool,
    remaining: AtomicUsize,
    shards: usize,
    dispatched: Instant,
}

impl ShardGather {
    /// Pre-compute admission, mirroring the other lanes' gates: a
    /// cancelled or expired parent stops burning cores on its remaining
    /// shards. Sticky once observed.
    fn check_dead(&self) -> bool {
        if self.dead.load(Relaxed) {
            return true;
        }
        let g = self.ctx.lock().unwrap();
        let dead = g.as_ref().is_some_and(|c| c.cancelled() || c.expired(Instant::now()));
        if dead {
            self.dead.store(true, Relaxed);
        }
        dead
    }

    /// This worker's slice of the mass op — a conventional core doing
    /// the arithmetic itself (no backend required) — reduced to the
    /// canonical per-block partials of the shared kernels. `lo` is a
    /// `kernels::BLOCK` multiple by the scatter contract.
    fn compute(&self, lo: usize, hi: usize) -> Vec<f32> {
        let mut out = Vec::new();
        match &self.b {
            Some(b) => kernels::dot_block_partials(&self.a[lo..hi], &b[lo..hi], &mut out),
            None => kernels::sum_block_partials(&self.a[lo..hi], &mut out),
        }
        out
    }

    fn absorb(
        &self,
        lo: usize,
        partial: Vec<f32>,
        backend: &str,
        stats: Option<&BackendStats>,
        metrics: &FabricMetrics,
    ) {
        {
            let mut slots = self.partials.lock().unwrap();
            let base = lo / kernels::BLOCK;
            for (i, p) in partial.into_iter().enumerate() {
                if let Some(s) = slots.get_mut(base + i) {
                    *s = p;
                }
            }
        }
        if self.remaining.fetch_sub(1, AcqRel) != 1 {
            return;
        }
        let ctx = self.ctx.lock().unwrap().take().expect("gather completes exactly once");
        // The admission gate resolves a cancelled/expired job with its
        // typed error (cancel is sticky and deadlines are monotonic, so
        // this cannot disagree with `check_dead`'s verdict for long —
        // and if it somehow passes, completing is the safe fallback).
        if self.dead.load(Relaxed) && !ctx.admit(metrics) {
            return;
        }
        // One backend job per completed split op (not per shard), so the
        // per-backend jobs counter stays in step with completions.
        if let Some(s) = stats {
            s.jobs.fetch_add(1, Relaxed);
        }
        let total = kernels::fold_partials(&self.partials.lock().unwrap());
        ctx.complete(
            metrics,
            Output::Scalars(vec![total].into()),
            Route::Split,
            backend,
            1,
            self.shards,
            self.dispatched,
        );
    }
}

struct MassJob {
    ctx: JobCtx,
}

enum AccelMsg {
    Batch { op: MassOp, batch: Batch<MassJob>, scale_bias: [f32; 2] },
}

// ----------------------------------------------------------------------
// the fabric
// ----------------------------------------------------------------------

/// The running fabric.
pub struct Fabric {
    client: FabricClient,
    pub metrics: Arc<FabricMetrics>,
    threads: Mutex<Vec<JoinHandle<()>>>,
    /// `Some` only when `FabricConfig::chaos` armed fault injection; the
    /// serve plane shares this engine for its wire-site decisions.
    chaos: Option<Arc<crate::chaos::ChaosEngine>>,
}

impl Fabric {
    /// Start the fabric over a backend registry. Backends are constructed
    /// *on* their worker threads (PJRT handles are thread-affine) in
    /// registration order, failing over to later entries of the same
    /// class.
    pub fn start(cfg: FabricConfig, registry: BackendRegistry) -> Arc<Fabric> {
        let metrics = Arc::new(FabricMetrics::default());
        let stop = Arc::new(AtomicBool::new(false));
        let (tx, rx) = sync_channel::<Msg>(cfg.queue_cap);
        let mut threads = Vec::new();
        // Chaos is an engine only when armed; a `None` here means every
        // path below is byte-for-byte the pre-chaos fabric (no wrapper
        // backends, no per-task decision points).
        let chaos = cfg.chaos.engine();
        let program_chain = chaos_wrap_chain(registry.chain(BackendClass::Program), chaos.as_ref());
        let mass_chain = chaos_wrap_chain(registry.chain(BackendClass::Mass), chaos.as_ref());

        // --- sim worker pool over the dispatch plane -------------------
        // Each worker owns a bounded deque; the supervisor places on the
        // least-loaded one and idle workers steal from neighbours — no
        // shared-receiver lock convoy on the hot path.
        let plane = DispatchPlane::new(cfg.sim_workers.max(1), cfg.queue_cap, &metrics);
        for w in 0..plane.workers() {
            let plane = Arc::clone(&plane);
            let chain = program_chain.clone();
            let m = Arc::clone(&metrics);
            let ch = chaos.clone();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("empa-sim-{w}"))
                    .spawn(move || sim_worker(w, plane, chain, m, ch))
                    .expect("spawn sim worker"),
            );
        }

        // --- mass worker (accelerator chain) ---------------------------
        let (acc_tx, acc_rx) = mpsc::channel::<AccelMsg>();
        {
            let m = Arc::clone(&metrics);
            threads.push(
                std::thread::Builder::new()
                    .name("fabric-mass".into())
                    .spawn(move || mass_worker(acc_rx, mass_chain, m))
                    .expect("spawn mass worker"),
            );
        }

        // --- supervisor ------------------------------------------------
        {
            let m = Arc::clone(&metrics);
            let stop = Arc::clone(&stop);
            let plane = Arc::clone(&plane);
            let cfg2 = cfg.clone();
            threads.push(
                std::thread::Builder::new()
                    .name("fabric-supervisor".into())
                    .spawn(move || Supervisor::new(plane, acc_tx, cfg2, m).run(rx, stop))
                    .expect("spawn supervisor"),
            );
        }

        let client = FabricClient::new(tx, Arc::clone(&metrics), stop);
        Arc::new(Fabric { client, metrics, threads: Mutex::new(threads), chaos })
    }

    /// The shared chaos engine, when `FabricConfig::chaos` armed one —
    /// the serve plane draws its wire-site decisions (and its fault-plan
    /// rendering) from the same engine the backends use.
    pub fn chaos(&self) -> Option<Arc<crate::chaos::ChaosEngine>> {
        self.chaos.clone()
    }

    /// Start with the default local registry (`sim` + `native`).
    pub fn start_local(cfg: FabricConfig) -> Arc<Fabric> {
        let registry = BackendRegistry::local(cfg.empa.clone());
        Fabric::start(cfg, registry)
    }

    /// A new cheaply-cloneable client onto this fabric.
    pub fn client(&self) -> FabricClient {
        self.client.clone()
    }

    /// Submit a job; blocks when the ingress queue is full (backpressure).
    pub fn submit(&self, req: impl Into<JobRequest>) -> Result<Job, FabricError> {
        self.client.submit(req)
    }

    /// Non-blocking submit; see [`FabricClient::try_submit`].
    pub fn try_submit(&self, req: impl Into<JobRequest>) -> Result<Job, FabricError> {
        self.client.try_submit(req)
    }

    /// Submit a full trace and wait for all responses; returns per-request
    /// (request-id, result). Submission failure (e.g. shutdown mid-trace)
    /// propagates instead of panicking.
    pub fn run_trace(&self, trace: Vec<Request>) -> Result<Vec<(u64, JobResult)>, FabricError> {
        let mut jobs = Vec::with_capacity(trace.len());
        for r in trace {
            jobs.push((r.id, self.submit(r.job)?));
        }
        Ok(jobs.into_iter().map(|(rid, j)| (rid, j.wait())).collect())
    }

    /// Stop all threads (idempotent; pending jobs are completed first).
    pub fn shutdown(&self) {
        let _ = self.client.shutdown_signal();
        let mut g = self.threads.lock().unwrap();
        for t in g.drain(..) {
            let _ = t.join();
        }
    }
}

// ----------------------------------------------------------------------
// the supervisor
// ----------------------------------------------------------------------

/// How long the supervisor waits before retrying the dispatch plane while
/// program jobs are parked in the overflow heap.
const STAGED_RETRY: Duration = Duration::from_micros(200);

/// The supervisor thread's state: the dispatch plane it feeds, the mass
/// lane's batchers, and the bounded fair-share stage that holds program
/// jobs when every lane is full ([`FairStage`]: deficit-round-robin
/// across tenant tags, priority-ordered within each tenant — so a hot
/// tenant's backlog cannot starve the rest, while `High` still overtakes
/// within its own tenant).
///
/// Backpressure is tiered: jobs stage on the plane's per-worker deques
/// first (total `queue_cap`), then in the fair stage (another
/// `queue_cap`); only when **both** are full does the supervisor pause
/// ingestion, which callers observe as `QueueFull` on the bounded ingress
/// queue. Inline and accelerator jobs keep flowing until that point —
/// the seed's single staged heap instead slept with the backlog at
/// `queue_cap`, head-of-line-blocking every lane behind the program one.
struct Supervisor {
    plane: Arc<DispatchPlane<SimTask>>,
    acc_tx: mpsc::Sender<AccelMsg>,
    cfg: FabricConfig,
    metrics: Arc<FabricMetrics>,
    batchers: HashMap<MassOp, Batcher<MassJob>>,
    staged: FairStage<(RequestKind, JobCtx)>,
    staged_cap: usize,
    seq: u64,
    inline_stats: Arc<BackendStats>,
}

impl Supervisor {
    fn new(
        plane: Arc<DispatchPlane<SimTask>>,
        acc_tx: mpsc::Sender<AccelMsg>,
        cfg: FabricConfig,
        metrics: Arc<FabricMetrics>,
    ) -> Self {
        let staged_cap = cfg.queue_cap.max(1);
        let inline_stats = metrics.backend("inline");
        Supervisor {
            plane,
            acc_tx,
            cfg,
            metrics,
            batchers: HashMap::new(),
            staged: FairStage::new(1),
            staged_cap,
            seq: 0,
            inline_stats,
        }
    }

    fn run(mut self, rx: Receiver<Msg>, stop: Arc<AtomicBool>) {
        loop {
            if stop.load(std::sync::atomic::Ordering::Acquire) {
                // Shutdown was signalled: ingest what was already
                // accepted (the sentinel message marks the end), then
                // fall into the drain. The flag — unlike the sentinel —
                // is seen even when ingestion is paused on a full
                // backlog, so shutdown never queues behind program jobs.
                while let Ok(Msg::Job { kind, ctx }) = rx.try_recv() {
                    if ctx.admit(&self.metrics) {
                        self.ingest(kind, ctx);
                    }
                }
                break;
            }
            self.refill_plane();

            // Wait bounded by the earliest batch deadline / overflow retry.
            let batch_deadline = self.batchers.values().filter_map(|b| b.next_deadline()).min();
            let staged_retry =
                if self.staged.is_empty() { None } else { Some(Instant::now() + STAGED_RETRY) };
            let wake = match (batch_deadline, staged_retry) {
                (Some(a), Some(b)) => Some(a.min(b)),
                (a, b) => a.or(b),
            };
            let msg = if self.staged.len() >= self.staged_cap {
                // Both backlog tiers are full: pause ingestion and let
                // the bounded ingress queue fill — that is what
                // `try_submit` observes as QueueFull. Wake soon to retry
                // the plane and honour batch deadlines.
                let until = wake.unwrap_or_else(|| Instant::now() + STAGED_RETRY);
                std::thread::sleep(
                    until.saturating_duration_since(Instant::now()).min(STAGED_RETRY),
                );
                None
            } else {
                match wake {
                    Some(d) => {
                        let wait = d.saturating_duration_since(Instant::now());
                        match rx.recv_timeout(wait) {
                            Ok(m) => Some(m),
                            Err(RecvTimeoutError::Timeout) => None,
                            Err(RecvTimeoutError::Disconnected) => break,
                        }
                    }
                    None => match rx.recv() {
                        Ok(m) => Some(m),
                        Err(_) => break,
                    },
                }
            };
            self.poll_batchers();
            match msg {
                None => continue,
                Some(Msg::Shutdown) => break,
                Some(Msg::Job { kind, ctx }) => {
                    if ctx.admit(&self.metrics) {
                        self.ingest(kind, ctx);
                    }
                }
            }
        }
        self.shutdown_drain();
    }

    /// Move staged program jobs onto the plane (in DRR order) while lanes
    /// have room.
    fn refill_plane(&mut self) {
        while let Some(p) = self.staged.pop() {
            let (kind, ctx) = p.item;
            if !ctx.admit(&self.metrics) {
                continue;
            }
            let (tag, priority, seq) = (p.tag, p.priority, p.seq);
            match self.plane.try_place(priority, SimTask::Run { kind, ctx }) {
                Ok(_) => {}
                Err(SimTask::Run { kind, ctx }) => {
                    // Placement failed: hand the job back unchanged — the
                    // tenant retries it first, at no DRR cost.
                    self.staged.requeue(Popped { tag, priority, seq, item: (kind, ctx) });
                    break;
                }
                Err(SimTask::Shard(_)) => unreachable!("the stage holds only Run tasks"),
            }
        }
    }

    /// Route one admitted job onto its lane.
    fn ingest(&mut self, kind: RequestKind, ctx: JobCtx) {
        match router::route(&kind, &self.cfg.route) {
            Route::Simulator => {
                self.metrics.routed_sim.fetch_add(1, Relaxed);
                self.seq += 1;
                let seq = self.seq;
                // FIFO within a priority: bypass the fair stage only
                // when it is empty (fairness engages under contention).
                if self.staged.is_empty() {
                    match self.plane.try_place(ctx.priority, SimTask::Run { kind, ctx }) {
                        Ok(_) => {}
                        Err(SimTask::Run { kind, ctx }) => {
                            self.staged.push(ctx.client.clone(), ctx.priority, seq, (kind, ctx));
                        }
                        Err(SimTask::Shard(_)) => unreachable!("placed a Run task"),
                    }
                } else {
                    self.staged.push(ctx.client.clone(), ctx.priority, seq, (kind, ctx));
                }
            }
            Route::Inline => {
                // Small mass op: not worth any queue round trip (the
                // §2.4 offset-time argument).
                self.metrics.routed_inline.fetch_add(1, Relaxed);
                let dispatched = Instant::now();
                match inline_mass(&kind) {
                    Ok(out) => {
                        self.inline_stats.jobs.fetch_add(1, Relaxed);
                        ctx.complete(&self.metrics, out, Route::Inline, "inline", 1, 1, dispatched);
                    }
                    Err(e) => {
                        self.inline_stats.errors.fetch_add(1, Relaxed);
                        ctx.fail(&self.metrics, e);
                    }
                }
            }
            Route::Split => {
                // Scatter pays only when neighbours are free to help
                // (the §2.4 offset-time argument applied to the pool
                // itself). With every lane busy it would also bypass the
                // plane's bounds, so the batcher lane is the fallback.
                if self.plane.idle_lanes() == 0 {
                    self.metrics.routed_accel.fetch_add(1, Relaxed);
                    self.enqueue_accel(kind, ctx);
                } else {
                    self.metrics.routed_split.fetch_add(1, Relaxed);
                    self.scatter(kind, ctx);
                }
            }
            Route::Accelerator => {
                self.metrics.routed_accel.fetch_add(1, Relaxed);
                self.enqueue_accel(kind, ctx);
            }
        }
    }

    /// Stage a mass op on its per-op batcher, flushing on size (or
    /// immediately for High priority). The operand `Arc`s move into the
    /// batcher as-is — staging copies nothing.
    fn enqueue_accel(&mut self, kind: RequestKind, ctx: JobCtx) {
        let high = ctx.priority == Priority::High;
        let (op, row, row2) = match kind {
            RequestKind::MassSum { values } => (MassOp::Sumup, values, None),
            RequestKind::MassDot { a, b } => (MassOp::Dot, a, Some(b)),
            RequestKind::RunProgram { .. } => unreachable!("router"),
        };
        let mut priority_flush = false;
        let flushed = {
            let b = self
                .batchers
                .entry(op)
                .or_insert_with(|| Batcher::new(self.cfg.batcher.clone()));
            if let Some(batch) = b.push(MassJob { ctx }, row, row2, Instant::now()) {
                Some(batch)
            } else if high {
                // High priority refuses to wait out the batch window:
                // take whatever is pending now.
                priority_flush = true;
                b.drain()
            } else {
                None
            }
        };
        if let Some(batch) = flushed {
            if priority_flush {
                self.metrics.priority_flushes.fetch_add(1, Relaxed);
            }
            self.flush(op, batch);
        }
    }

    /// Scatter an oversized mass op into contiguous shards across the
    /// dispatch plane — the supervisor "using the help of" neighbouring
    /// cores. The submitted operand buffers move into the gather whole
    /// (they are already shared `Arc`s), the fan-out is sized by the
    /// lanes actually idle, and each shard is an `Arc` clone plus a
    /// range: one allocation per control tick (§4.1.3), zero payload
    /// copies. The gather side lives in [`ShardGather`].
    fn scatter(&self, kind: RequestKind, ctx: JobCtx) {
        let (a, b) = match kind {
            RequestKind::MassSum { values } => (values, None),
            RequestKind::MassDot { a, b } => (a, Some(b)),
            RequestKind::RunProgram { .. } => unreachable!("only mass ops route to Split"),
        };
        // Defence in depth for a mismatched dot that slipped past
        // submission validation: chunk by the shorter side so the shard
        // slices can never go out of bounds.
        let len = b.as_ref().map_or(a.len(), |bv| a.len().min(bv.len()));
        let min = self.cfg.route.split_min_len.max(1);
        // Two shards at the threshold, growing with length, capped by
        // the idle lanes available to help (>= 1, checked by the caller).
        let idle = self.plane.idle_lanes().max(1);
        let want = (2 * len / min).clamp(1, idle);
        // Fix the chunk size first, then re-derive the count from it, so
        // every shard is non-empty and the last range cannot run past
        // `len` (ceil(len / ceil(len / want)) <= want always holds).
        // Chunks round up to the kernel block grid: shard partials then
        // land on the whole-slice block grid, making the gathered fold
        // bit-identical to the inline kernel reduction.
        let chunk = len.div_ceil(want).max(1).div_ceil(kernels::BLOCK) * kernels::BLOCK;
        let shards = len.div_ceil(chunk).max(1);
        let priority = ctx.priority;
        let gather = Arc::new(ShardGather {
            a,
            b,
            ctx: Mutex::new(Some(ctx)),
            partials: Mutex::new(vec![0.0; len.div_ceil(kernels::BLOCK)]),
            dead: AtomicBool::new(false),
            remaining: AtomicUsize::new(shards),
            shards,
            dispatched: Instant::now(),
        });
        self.metrics.split_shards.fetch_add(shards as u64, Relaxed);
        for i in 0..shards {
            let lo = i * chunk;
            let hi = ((i + 1) * chunk).min(len);
            let task = ShardTask { gather: Arc::clone(&gather), lo, hi };
            // Uncapped place: fan-out is bounded by the idle-lane count,
            // and the least-loaded pick lands shards on those lanes.
            self.plane.place(priority, SimTask::Shard(task));
        }
    }

    fn flush(&self, op: MassOp, batch: Batch<MassJob>) {
        // Zero-copy handoff: the batch carries the submitters' operand
        // handles; the mass worker builds the flat tiles post-admission.
        let _ = self.acc_tx.send(AccelMsg::Batch { op, batch, scale_bias: [0.0; 2] });
    }

    /// Deadline flushes (they are due).
    fn poll_batchers(&mut self) {
        let now = Instant::now();
        let mut due: Vec<(MassOp, Batch<MassJob>)> = Vec::new();
        for (op, b) in self.batchers.iter_mut() {
            if let Some(batch) = b.poll(now) {
                due.push((*op, batch));
            }
        }
        for (op, batch) in due {
            self.metrics.deadline_flushes.fetch_add(1, Relaxed);
            self.flush(op, batch);
        }
    }

    /// Shutdown drain: staged programs onto the plane (uncapped —
    /// workers are still up and will finish the backlog), pending batches
    /// to the mass worker, then close the plane. Dropping `acc_tx` with
    /// `self` disconnects the mass worker once it has drained.
    fn shutdown_drain(mut self) {
        while let Some(p) = self.staged.pop() {
            let (kind, ctx) = p.item;
            if !ctx.admit(&self.metrics) {
                continue;
            }
            self.plane.place(p.priority, SimTask::Run { kind, ctx });
        }
        let batchers = std::mem::take(&mut self.batchers);
        for (op, mut b) in batchers {
            if let Some(batch) = b.drain() {
                self.flush(op, batch);
            }
        }
        self.plane.close();
    }
}

/// Compute a mass op directly over the submitted (shared) operand
/// buffers — the inline lane, and the sim pool's defensive whole-op
/// path. Borrows; never copies.
fn inline_mass(kind: &RequestKind) -> Result<Output, FabricError> {
    // Through the shared fixed-order kernels, so the inline answer is
    // bit-identical to the split and accelerator routes for the same job.
    match kind {
        RequestKind::MassSum { values } => {
            Ok(Output::Scalars(vec![kernels::sum(values)].into()))
        }
        RequestKind::MassDot { a, b } => {
            // Submission validation rejects mismatches; never let one
            // that slips through zip-truncate into a wrong answer.
            if a.len() != b.len() {
                return Err(FabricError::ShapeMismatch { a: a.len(), b: b.len() });
            }
            Ok(Output::Scalars(vec![kernels::dot(a, b)].into()))
        }
        RequestKind::RunProgram { .. } => Err(FabricError::Backend {
            name: "inline".into(),
            msg: "program routed inline".into(),
        }),
    }
}

/// Rebuild a registry chain with every entry's backend wrapped in a
/// [`crate::chaos::ChaosBackend`] (and handed the engine for deeper
/// sites via `attach_chaos`). Identity when chaos is off: the original
/// entries pass through untouched, so the disabled configuration keeps
/// the exact pre-chaos factories.
fn chaos_wrap_chain(
    chain: Vec<Arc<BackendEntry>>,
    engine: Option<&Arc<crate::chaos::ChaosEngine>>,
) -> Vec<Arc<BackendEntry>> {
    let Some(engine) = engine else { return chain };
    chain
        .into_iter()
        .map(|entry| {
            let eng = Arc::clone(engine);
            let inner = Arc::clone(&entry);
            Arc::new(BackendEntry::new(
                entry.name.clone(),
                entry.class,
                Box::new(move || {
                    let mut b = inner.instantiate()?;
                    b.attach_chaos(Arc::clone(&eng));
                    Ok(Box::new(crate::chaos::ChaosBackend::new(b, Arc::clone(&eng)))
                        as Box<dyn Backend>)
                }),
            ))
        })
        .collect()
}

/// Human-readable payload of a caught panic (`panic!` carries `&str` or
/// `String`; anything else renders opaquely).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    payload
        .downcast_ref::<&str>()
        .copied()
        .or_else(|| payload.downcast_ref::<String>().map(String::as_str))
        .unwrap_or("opaque panic payload")
}

/// Instantiate the first healthy backend of a chain on this thread,
/// recording init successes/failures per backend. A failover is counted
/// only when a later entry actually takes over — if every entry fails,
/// nothing failed *over*, it just failed.
fn instantiate_chain(
    chain: &[Arc<BackendEntry>],
    metrics: &Arc<FabricMetrics>,
) -> Result<Box<dyn Backend>, FabricError> {
    let mut last: Option<FabricError> = None;
    let mut failed_ahead = 0u64;
    for entry in chain.iter() {
        match entry.instantiate() {
            Ok(mut b) => {
                b.attach_metrics(Arc::clone(metrics));
                metrics.backend(&entry.name).init_ok.fetch_add(1, Relaxed);
                if failed_ahead > 0 {
                    metrics.failovers.fetch_add(failed_ahead, Relaxed);
                }
                return Ok(b);
            }
            Err(e) => {
                metrics.backend(&entry.name).init_failures.fetch_add(1, Relaxed);
                failed_ahead += 1;
                last = Some(FabricError::Backend {
                    name: entry.name.clone(),
                    msg: format!("init: {e:#}"),
                });
            }
        }
    }
    Err(last.unwrap_or(FabricError::Backend {
        name: "registry".into(),
        msg: "no backend registered for this class".into(),
    }))
}

/// One sim worker: pops its own deque on the dispatch plane, steals from
/// neighbours when idle, and serves program jobs and mass-op shards on
/// its thread-owned backend. A panicking backend must not kill the
/// worker — its lane would strand every staged job (nobody pops it, and
/// `least_loaded` keeps feeding its empty deque) — so each task is
/// served under `catch_unwind`: the in-flight job's reply sender drops
/// with the unwound state (its caller observes `FabricError::Shutdown`)
/// and the worker keeps draining.
fn sim_worker(
    w: usize,
    plane: Arc<DispatchPlane<SimTask>>,
    chain: Vec<Arc<BackendEntry>>,
    metrics: Arc<FabricMetrics>,
    chaos: Option<Arc<crate::chaos::ChaosEngine>>,
) {
    let active = instantiate_chain(&chain, &metrics);
    let stats = active.as_ref().ok().map(|b| metrics.backend(b.name()));
    let wstats = metrics.worker(w);
    while let Some(task) = plane.next(w) {
        // Dispatch-site chaos: stall this worker before it serves the
        // task. The job still completes (late) — stalls exercise the
        // work-stealing and deadline paths, not the error paths.
        if let Some(engine) = &chaos {
            if let Some(crate::chaos::FaultKind::WorkerStall { ms }) =
                engine.decide(crate::chaos::Site::Dispatch)
            {
                metrics.chaos_worker_stalls.fetch_add(1, Relaxed);
                std::thread::sleep(Duration::from_millis(ms));
            }
        }
        let served = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            serve_sim_task(task, &active, stats.as_deref(), &wstats, &metrics)
        }));
        if served.is_err() {
            metrics.errors.fetch_add(1, Relaxed);
        }
    }
}

/// Serve one dispatch-plane task on this worker's backend.
fn serve_sim_task(
    task: SimTask,
    active: &Result<Box<dyn Backend>, FabricError>,
    stats: Option<&BackendStats>,
    wstats: &WorkerStats,
    metrics: &FabricMetrics,
) {
    match task {
        SimTask::Run { kind, ctx } => {
            if !ctx.admit(metrics) {
                return;
            }
            wstats.executed.fetch_add(1, Relaxed);
            let dispatched = Instant::now();
            // Mass jobs are not routed here, but a sim slot can still
            // serve one — a conventional core doing the arithmetic,
            // borrowing the submitted buffers in place (no request
            // rebuild, no operand clone).
            if matches!(kind, RequestKind::MassSum { .. } | RequestKind::MassDot { .. }) {
                let name = active.as_ref().map(|b| b.name()).unwrap_or("sim-pool");
                match inline_mass(&kind) {
                    Ok(out) => {
                        if let Some(s) = stats {
                            s.jobs.fetch_add(1, Relaxed);
                        }
                        ctx.complete(metrics, out, Route::Simulator, name, 1, 1, dispatched);
                    }
                    Err(e) => {
                        if let Some(s) = stats {
                            s.errors.fetch_add(1, Relaxed);
                        }
                        ctx.fail(metrics, e);
                    }
                }
                return;
            }
            let backend = match active {
                Ok(b) => b,
                Err(e) => {
                    ctx.fail(metrics, e.clone());
                    return;
                }
            };
            let stats = stats.expect("stats exist when backend does");
            let reply = match &kind {
                RequestKind::RunProgram { family, mode, params } => {
                    // Catch panics at the execute boundary, not just the
                    // outer task loop: here the JobCtx is still in hand,
                    // so the caller gets a typed `Backend` error instead
                    // of watching its reply sender vanish (`Shutdown`).
                    let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        backend.execute(BackendJob::Program {
                            family: *family,
                            mode: *mode,
                            params,
                        })
                    }));
                    match run {
                        Ok(r) => r,
                        Err(payload) => {
                            metrics.worker_panics.fetch_add(1, Relaxed);
                            Err(FabricError::Backend {
                                name: backend.name().to_string(),
                                msg: format!("panicked: {}", panic_message(payload.as_ref())),
                            })
                        }
                    }
                }
                RequestKind::MassSum { .. } | RequestKind::MassDot { .. } => {
                    unreachable!("mass ops served above")
                }
            };
            match reply {
                Ok(BackendReply::Program { eax, clocks, cores, data }) => {
                    stats.jobs.fetch_add(1, Relaxed);
                    ctx.complete(
                        metrics,
                        Output::Program { eax, clocks, cores, data },
                        Route::Simulator,
                        backend.name(),
                        1,
                        1,
                        dispatched,
                    );
                }
                Ok(BackendReply::Mass(_)) => {
                    stats.errors.fetch_add(1, Relaxed);
                    ctx.fail(
                        metrics,
                        FabricError::Backend {
                            name: backend.name().to_string(),
                            msg: "program request answered with a mass reply".into(),
                        },
                    );
                }
                Err(e) => {
                    stats.errors.fetch_add(1, Relaxed);
                    ctx.fail(metrics, e);
                }
            }
        }
        SimTask::Shard(task) => {
            wstats.executed.fetch_add(1, Relaxed);
            let name = active.as_ref().ok().map(|b| b.name());
            run_shard(task, name, stats, metrics);
        }
    }
}

/// Serve one shard of a scattered mass op: compute this worker's slice
/// (plain arithmetic — a core needs no backend to be a conventional
/// core) and feed the partial result to the parent-side accumulator.
fn run_shard(
    task: ShardTask,
    backend: Option<&str>,
    stats: Option<&BackendStats>,
    metrics: &FabricMetrics,
) {
    let ShardTask { gather, lo, hi } = task;
    if gather.check_dead() {
        // Cancelled or past its deadline while staged: contribute
        // nothing; the last shard resolves the job with its typed error.
        gather.absorb(lo, Vec::new(), backend.unwrap_or("sim-pool"), stats, metrics);
        return;
    }
    let partial = gather.compute(lo, hi);
    gather.absorb(lo, partial, backend.unwrap_or("sim-pool"), stats, metrics);
}

/// One mass-chain slot: the entry's backend, instantiated on first use.
enum Slot {
    Untried,
    /// Initialisation failed — permanently skipped (init failure is a
    /// backend-level fact, unlike a per-batch execute error).
    Dead,
    Ready(Box<dyn Backend>, Arc<BackendStats>),
}

/// The mass-backend chain with per-batch failover: each batch tries the
/// entries in registration order, so an execute error on the preferred
/// backend (which may be specific to that one request, e.g. an oversized
/// bucket) degrades only that batch — the preferred backend stays first
/// in line for the next one. Init failures mark the slot dead for good.
struct MassChain {
    entries: Vec<Arc<BackendEntry>>,
    slots: Vec<Slot>,
}

impl MassChain {
    fn new(entries: Vec<Arc<BackendEntry>>) -> Self {
        let slots = entries.iter().map(|_| Slot::Untried).collect();
        MassChain { entries, slots }
    }

    /// Execute one batch, walking the chain until a backend answers. A
    /// failover is counted per entry that failed *this batch* before a
    /// later entry answered it — an all-entries-failed batch is an error,
    /// not a failover.
    fn run(
        &mut self,
        req: &MassRequest,
        metrics: &Arc<FabricMetrics>,
    ) -> Result<(MassResult, String), FabricError> {
        let rows = req.rows.len() as u64;
        let mut last_err: Option<FabricError> = None;
        let mut failed_ahead = 0u64;
        let n = self.entries.len();
        for i in 0..n {
            if matches!(self.slots[i], Slot::Untried) {
                let entry = &self.entries[i];
                match entry.instantiate() {
                    Ok(mut b) => {
                        b.attach_metrics(Arc::clone(metrics));
                        let stats = metrics.backend(&entry.name);
                        stats.init_ok.fetch_add(1, Relaxed);
                        self.slots[i] = Slot::Ready(b, stats);
                    }
                    Err(e) => {
                        metrics.backend(&entry.name).init_failures.fetch_add(1, Relaxed);
                        self.slots[i] = Slot::Dead;
                        failed_ahead += 1;
                        last_err = Some(FabricError::Backend {
                            name: entry.name.clone(),
                            msg: format!("init: {e:#}"),
                        });
                    }
                }
            }
            let Slot::Ready(backend, stats) = &self.slots[i] else { continue };
            // Same panic boundary as the sim workers: a backend that
            // panics mid-batch must not unwind through the single
            // `fabric-mass` thread — treat it as a per-batch failure and
            // let the rest of the chain take the batch.
            let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                backend.execute(BackendJob::Mass(req))
            }))
            .unwrap_or_else(|payload| {
                metrics.worker_panics.fetch_add(1, Relaxed);
                Err(FabricError::Backend {
                    name: backend.name().to_string(),
                    msg: format!("panicked: {}", panic_message(payload.as_ref())),
                })
            });
            match run {
                Ok(BackendReply::Mass(res)) => {
                    stats.jobs.fetch_add(rows, Relaxed);
                    stats.batches.fetch_add(1, Relaxed);
                    stats.rows.fetch_add(rows, Relaxed);
                    if failed_ahead > 0 {
                        metrics.failovers.fetch_add(failed_ahead, Relaxed);
                    }
                    return Ok((res, backend.name().to_string()));
                }
                Ok(BackendReply::Program { .. }) => {
                    stats.errors.fetch_add(rows, Relaxed);
                    failed_ahead += 1;
                    last_err = Some(FabricError::Backend {
                        name: backend.name().to_string(),
                        msg: "mass request answered with a program reply".into(),
                    });
                }
                Err(e) => {
                    stats.errors.fetch_add(rows, Relaxed);
                    failed_ahead += 1;
                    last_err = Some(e);
                }
            }
        }
        Err(last_err.unwrap_or(FabricError::Backend {
            name: "registry".into(),
            msg: "no mass backend registered".into(),
        }))
    }
}

fn mass_worker(rx: Receiver<AccelMsg>, chain: Vec<Arc<BackendEntry>>, metrics: Arc<FabricMetrics>) {
    let mut exec = MassChain::new(chain);
    // The tile arena lives with the one thread that builds and frees
    // tiles; buffers recycle across batches (grown, never shrunk).
    let pool = TilePool::default();
    while let Ok(AccelMsg::Batch { op, mut batch, scale_bias }) = rx.recv() {
        // Admission per row: cancelled/expired jobs resolve here, before
        // any copy — dead rows are never tiled at all.
        let keep: Vec<bool> = batch.tags.iter().map(|t| t.ctx.admit(&metrics)).collect();
        if keep.iter().any(|&k| !k) {
            batch.retain(&keep);
        }
        if batch.is_empty() {
            continue;
        }
        let Batch { tags, rows, rows2 } = batch;
        let ctxs: Vec<JobCtx> = tags.into_iter().map(|t| t.ctx).collect();
        // Build the flat tiles — the batched path's single copy, into
        // recycled arena buffers — and account it for the throughput
        // bench's bytes-copied-per-job figure.
        let tile = crate::accel::Tile::build(&rows, pool.take());
        let tile2 = (!rows2.is_empty()).then(|| crate::accel::Tile::build(&rows2, pool.take()));
        let bytes = tile.filled_bytes() + tile2.as_ref().map_or(0, |t| t.filled_bytes());
        metrics.tile_bytes.fetch_add(bytes, Relaxed);
        // The request shares the submitted buffers (identity preserved
        // for the chain) and carries the arena tiles for flat execution.
        let req = MassRequest { op, rows, rows2, scale_bias, tile: Some(tile), tile2 };
        let dispatched = Instant::now();
        let n = ctxs.len();
        match exec.run(&req, &metrics) {
            Ok((result, name)) => {
                let got = match &result {
                    MassResult::Scalars(v) => v.len(),
                    MassResult::Rows(r) => r.len(),
                    MassResult::Stats { sum, .. } => sum.len(),
                };
                if got < n {
                    // A short answer must not silently drop the tail
                    // (dropped reply senders would read as Shutdown).
                    let err = FabricError::Backend {
                        name: name.clone(),
                        msg: format!("returned {got} results for {n} rows"),
                    };
                    for ctx in ctxs {
                        ctx.fail(&metrics, err.clone());
                    }
                    req.recycle(&pool);
                    continue;
                }
                metrics.accel_batches.fetch_add(1, Relaxed);
                metrics.accel_rows.fetch_add(n as u64, Relaxed);
                match result {
                    MassResult::Scalars(vals) => {
                        for (ctx, v) in ctxs.into_iter().zip(vals) {
                            ctx.complete(
                                &metrics,
                                Output::Scalars(vec![v].into()),
                                Route::Accelerator,
                                &name,
                                n,
                                1,
                                dispatched,
                            );
                        }
                    }
                    MassResult::Rows(out) => {
                        for (ctx, r) in ctxs.into_iter().zip(out) {
                            ctx.complete(
                                &metrics,
                                Output::Rows(vec![r.into()]),
                                Route::Accelerator,
                                &name,
                                n,
                                1,
                                dispatched,
                            );
                        }
                    }
                    MassResult::Stats { sum, .. } => {
                        for (ctx, v) in ctxs.into_iter().zip(sum) {
                            ctx.complete(
                                &metrics,
                                Output::Scalars(vec![v].into()),
                                Route::Accelerator,
                                &name,
                                n,
                                1,
                                dispatched,
                            );
                        }
                    }
                }
                req.recycle(&pool);
            }
            Err(e) => {
                for ctx in ctxs {
                    ctx.fail(&metrics, e.clone());
                }
                req.recycle(&pool);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::sumup::Mode;

    fn small_fabric() -> Arc<Fabric> {
        let cfg = FabricConfig {
            sim_workers: 2,
            batcher: BatcherConfig { max_rows: 4, max_wait: Duration::from_millis(2) },
            ..Default::default()
        };
        Fabric::start_local(cfg)
    }

    #[test]
    fn program_jobs_round_trip() {
        let f = small_fabric();
        let h = f.submit(RequestKind::sumup(Mode::Sumup, vec![1, 2, 3, 4])).unwrap();
        let c = h.wait().unwrap();
        assert_eq!(c.output, Output::Program { eax: 10, clocks: 36, cores: 5, data: vec![] });
        assert_eq!(c.route, Route::Simulator);
        assert_eq!(c.backend, "sim");
        assert_eq!(c.shards, 1);
        assert!(c.queue_latency <= c.latency);
        assert_eq!(f.metrics.proc_rebuilds.load(Relaxed) + f.metrics.proc_reuses.load(Relaxed), 1);
        assert_eq!(f.metrics.template_misses.load(Relaxed), 1);
        f.shutdown();
    }

    #[test]
    fn mass_ops_batched_and_answered() {
        let f = small_fabric();
        let hs: Vec<Job> = (0..8)
            .map(|i| f.submit(RequestKind::mass_sum(vec![i as f32; 200])).unwrap())
            .collect();
        for (i, h) in hs.into_iter().enumerate() {
            let c = h.wait().unwrap();
            assert_eq!(c.output, Output::Scalars(vec![(i * 200) as f32].into()));
            assert_eq!(c.route, Route::Accelerator);
            assert_eq!(c.backend, "native");
            assert!(c.batch_rows >= 1);
        }
        assert!(f.metrics.accel_batches.load(Relaxed) >= 2);
        f.shutdown();
    }

    #[test]
    fn small_mass_ops_computed_inline() {
        let f = small_fabric();
        let h = f.submit(RequestKind::mass_sum(vec![1.0, 2.0])).unwrap();
        let c = h.wait().unwrap();
        assert_eq!(c.output, Output::Scalars(vec![3.0].into()));
        assert_eq!((c.route, c.backend.as_str(), c.batch_rows), (Route::Inline, "inline", 1));
        assert_eq!(f.metrics.routed_inline.load(Relaxed), 1);
        assert_eq!(f.metrics.routed_accel.load(Relaxed), 0);
        f.shutdown();
    }

    #[test]
    fn deadline_flush_completes_partial_batches() {
        // 3 rows < max_rows=4: only the deadline can flush them.
        let f = small_fabric();
        let hs: Vec<Job> = (0..3)
            .map(|_| f.submit(RequestKind::mass_sum(vec![1.0; 128])).unwrap())
            .collect();
        for h in hs {
            assert_eq!(h.wait().unwrap().output, Output::Scalars(vec![128.0].into()));
        }
        f.shutdown();
    }

    #[test]
    fn mixed_trace_all_complete() {
        let f = small_fabric();
        let trace = crate::workload::TraceGen::new(crate::workload::TraceConfig {
            num_requests: 64,
            ..Default::default()
        })
        .generate();
        let results = f.run_trace(trace).unwrap();
        assert_eq!(results.len(), 64);
        assert!(results.iter().all(|(_, r)| r.is_ok()));
        f.shutdown();
    }

    #[test]
    fn high_priority_mass_jobs_flush_immediately() {
        let cfg = FabricConfig {
            sim_workers: 1,
            // Size/deadline triggers effectively disabled: only priority
            // (or shutdown) can flush.
            batcher: BatcherConfig { max_rows: 1000, max_wait: Duration::from_secs(30) },
            ..Default::default()
        };
        let f = Fabric::start_local(cfg);
        let req = JobRequest::new(RequestKind::mass_sum(vec![2.0; 128]))
            .with_priority(Priority::High);
        let h = f.submit(req).unwrap();
        let c = h.wait().unwrap();
        assert_eq!(c.output, Output::Scalars(vec![256.0].into()));
        assert_eq!(f.metrics.priority_flushes.load(Relaxed), 1);
        f.shutdown();
    }

    #[test]
    fn submit_after_shutdown_is_a_typed_error() {
        let f = small_fabric();
        f.shutdown();
        let err = f.submit(RequestKind::mass_sum(vec![1.0])).unwrap_err();
        assert_eq!(err, FabricError::Shutdown);
        // run_trace propagates instead of panicking
        let trace = crate::workload::TraceGen::new(crate::workload::TraceConfig {
            num_requests: 4,
            ..Default::default()
        })
        .generate();
        assert_eq!(f.run_trace(trace).unwrap_err(), FabricError::Shutdown);
    }

    #[test]
    fn oversized_mass_op_scatters_and_gathers() {
        let cfg = FabricConfig {
            sim_workers: 4,
            route: RoutePolicy { accel_min_len: 64, split_min_len: 256 },
            ..Default::default()
        };
        let f = Fabric::start_local(cfg);
        let vals: Vec<f32> = (0..1024).map(|i| (i % 7) as f32 * 0.25).collect();
        let want: f32 = vals.iter().sum();
        let h = f.submit(RequestKind::mass_sum(vals)).unwrap();
        let c = h.wait().unwrap();
        assert_eq!(c.route, Route::Split);
        assert!(c.shards >= 2 && c.shards <= 4, "fan-out: {}", c.shards);
        assert_eq!(c.backend, "sim");
        let got = c.output.scalar().unwrap();
        assert!((got - want).abs() < 1e-2 * (1.0 + want.abs()), "{got} vs {want}");
        assert_eq!(f.metrics.routed_split.load(Relaxed), 1);
        assert!(f.metrics.split_shards.load(Relaxed) >= 2);
        f.shutdown();
    }

    /// Magnitude-diverse values so f32 summation order actually matters:
    /// if any route deviated from the canonical kernel reduction order,
    /// the bit-equality below would catch it.
    fn noisy(n: usize, seed: u64) -> Vec<f32> {
        let mut s = seed | 1;
        (0..n)
            .map(|_| {
                s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                (((s >> 33) & 0xffff) as f32) * 2f32.powi(((s >> 49) % 29) as i32 - 14)
            })
            .collect()
    }

    /// Run `values` through the split lane exactly as `scatter` would —
    /// block-aligned chunks — but absorbing the shards in *reverse*
    /// completion order, and return the gathered scalar.
    fn split_scalar(a: Arc<[f32]>, b: Option<Arc<[f32]>>, chunks: usize) -> f32 {
        let metrics = FabricMetrics::default();
        let (tx, rx) = mpsc::channel();
        let ctx = JobCtx {
            id: 1,
            priority: Priority::Normal,
            deadline: None,
            submitted: Instant::now(),
            cancel: Arc::new(AtomicBool::new(false)),
            reply: tx,
            client: None,
        };
        let len = b.as_ref().map_or(a.len(), |bv| a.len().min(bv.len()));
        let chunk = len.div_ceil(chunks).max(1).div_ceil(kernels::BLOCK) * kernels::BLOCK;
        let shards = len.div_ceil(chunk).max(1);
        let gather = Arc::new(ShardGather {
            a,
            b,
            ctx: Mutex::new(Some(ctx)),
            partials: Mutex::new(vec![0.0; len.div_ceil(kernels::BLOCK)]),
            dead: AtomicBool::new(false),
            remaining: AtomicUsize::new(shards),
            shards,
            dispatched: Instant::now(),
        });
        for i in (0..shards).rev() {
            let (lo, hi) = (i * chunk, ((i + 1) * chunk).min(len));
            run_shard(
                ShardTask { gather: Arc::clone(&gather), lo, hi },
                Some("sim"),
                None,
                &metrics,
            );
        }
        let Ok(Ok(c)) = rx.try_recv() else { panic!("gather did not complete") };
        c.output.scalar().expect("split mass ops return one scalar")
    }

    #[test]
    fn inline_split_and_batched_routes_agree_bitwise() {
        use crate::accel::{Accelerator, MassRequest, MassResult, NativeAccel};
        // split_min_len boundary shapes: below, at, just above, a
        // multiple, and a multiple plus a ragged block tail.
        let min = 256usize;
        for n in [min - 1, min, min + 1, 2 * min, 2 * min + 63] {
            let vals = noisy(n, n as u64);
            let a: Arc<[f32]> = vals.into();
            let Ok(Output::Scalars(v)) =
                inline_mass(&RequestKind::MassSum { values: Arc::clone(&a) })
            else {
                panic!("inline sum failed")
            };
            let inline = v[0];
            let Ok(MassResult::Scalars(v)) =
                NativeAccel.execute(&MassRequest::sumup([Arc::clone(&a)]))
            else {
                panic!("batched sum failed")
            };
            let batched = v[0];
            for chunks in [2, 3, 5] {
                let split = split_scalar(Arc::clone(&a), None, chunks);
                assert_eq!(split.to_bits(), inline.to_bits(), "sum n={n} chunks={chunks}");
            }
            assert_eq!(batched.to_bits(), inline.to_bits(), "sum n={n}");
        }
        // Dot: same contract through the second operand.
        let n = 2 * min + 63;
        let a: Arc<[f32]> = noisy(n, 7).into();
        let b: Arc<[f32]> = noisy(n, 13).into();
        let Ok(Output::Scalars(v)) =
            inline_mass(&RequestKind::MassDot { a: Arc::clone(&a), b: Arc::clone(&b) })
        else {
            panic!("inline dot failed")
        };
        let inline = v[0];
        let Ok(MassResult::Scalars(v)) =
            NativeAccel.execute(&MassRequest::dot([Arc::clone(&a)], [Arc::clone(&b)]))
        else {
            panic!("batched dot failed")
        };
        assert_eq!(v[0].to_bits(), inline.to_bits(), "dot batched");
        let split = split_scalar(a, Some(b), 3);
        assert_eq!(split.to_bits(), inline.to_bits(), "dot split");
    }

    #[test]
    fn shard_gather_honours_cancellation_while_staged() {
        // Drive the gather directly: the second shard observes the
        // cancel flag, so the job resolves Cancelled, not Ok.
        let metrics = FabricMetrics::default();
        let (tx, rx) = mpsc::channel();
        let cancel = Arc::new(AtomicBool::new(false));
        let ctx = JobCtx {
            id: 1,
            priority: Priority::Normal,
            deadline: None,
            submitted: Instant::now(),
            cancel: Arc::clone(&cancel),
            reply: tx,
            client: None,
        };
        let gather = Arc::new(ShardGather {
            a: vec![1.0; 8].into(),
            b: None,
            ctx: Mutex::new(Some(ctx)),
            partials: Mutex::new(vec![0.0; 1]),
            dead: AtomicBool::new(false),
            remaining: AtomicUsize::new(2),
            shards: 2,
            dispatched: Instant::now(),
        });
        let first = ShardTask { gather: Arc::clone(&gather), lo: 0, hi: 4 };
        run_shard(first, Some("sim"), None, &metrics);
        cancel.store(true, std::sync::atomic::Ordering::Release);
        run_shard(ShardTask { gather, lo: 4, hi: 8 }, Some("sim"), None, &metrics);
        assert_eq!(rx.try_recv().unwrap(), Err(FabricError::Cancelled));
        assert_eq!(metrics.cancelled.load(Relaxed), 1);
        assert_eq!(metrics.completed.load(Relaxed), 0);
    }

    #[test]
    fn inline_mass_borrows_the_submitted_allocation() {
        // The inline lane computes straight over the client's buffer:
        // the request still holds the only other handle afterwards — no
        // hidden clones anywhere on the path.
        let buf: Arc<[f32]> = vec![1.0, 2.0, 3.0].into();
        let kind = RequestKind::MassSum { values: Arc::clone(&buf) };
        assert_eq!(inline_mass(&kind).unwrap(), Output::Scalars(vec![6.0].into()));
        assert_eq!(Arc::strong_count(&buf), 2, "no copies of the operand exist");
        let b: Arc<[f32]> = vec![4.0, 5.0, 6.0].into();
        let kind = RequestKind::MassDot { a: Arc::clone(&buf), b: Arc::clone(&b) };
        assert_eq!(inline_mass(&kind).unwrap(), Output::Scalars(vec![32.0].into()));
        assert_eq!(Arc::strong_count(&buf), 2);
        drop(kind);
        assert_eq!(Arc::strong_count(&buf), 1);
    }

    #[test]
    #[allow(deprecated)]
    fn legacy_response_shim_flattens_results() {
        let ok: JobResult = Ok(Completion {
            output: Output::Scalars(vec![1.0].into()),
            route: Route::Inline,
            backend: "inline".into(),
            batch_rows: 1,
            shards: 1,
            queue_latency: Duration::ZERO,
            latency: Duration::ZERO,
        });
        assert_eq!(Response::from_result(&ok), Response::Scalars(vec![1.0]));
        let prog: JobResult = Ok(Completion {
            output: Output::Program { eax: 3, clocks: 9, cores: 1, data: vec![4] },
            route: Route::Simulator,
            backend: "sim".into(),
            batch_rows: 1,
            shards: 1,
            queue_latency: Duration::ZERO,
            latency: Duration::ZERO,
        });
        assert_eq!(
            Response::from_result(&prog),
            Response::Program { eax: 3, clocks: 9, cores: 1 },
            "legacy shim drops the read-back data"
        );
        let err: JobResult = Err(FabricError::QueueFull);
        let flat = Response::from_result(&err);
        assert!(
            !matches!(flat, Response::Scalars(_) | Response::Rows(_) | Response::Program { .. }),
            "errors flatten to the legacy error variant: {flat:?}"
        );
    }
}
