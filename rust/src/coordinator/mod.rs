//! The EMPA fabric coordinator — the paper's supervisor idea lifted to a
//! service (L3): a leader routes incoming jobs either to a pool of
//! simulated EMPA processors (scalar/control QTs) or — through the §3.8
//! accelerator link — to the XLA mass-processing accelerator, with
//! dynamic batching into bucket-shaped tiles and bounded-queue
//! backpressure.
//!
//! Topology (all std threads; the binary is self-contained, Python never
//! runs here):
//!
//! ```text
//!  clients ── submit ──► router (leader)
//!                          │ RunProgram            │ Mass*
//!                          ▼                       ▼
//!                 sim worker pool          per-op Batcher ──► accel worker
//!                 (EmpaProcessor)          (size/deadline)    (dyn Accelerator)
//! ```

pub mod metrics;
pub mod router;

pub use metrics::FabricMetrics;
pub use router::{RoutePolicy, Target};

use crate::accel::{AccelFactory, Batcher, BatcherConfig, MassOp, MassRequest, MassResult};
use crate::empa::{EmpaConfig, EmpaProcessor};
use crate::isa::assemble;
use crate::workload::{Request, RequestKind};
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Fabric configuration.
#[derive(Debug, Clone)]
pub struct FabricConfig {
    /// Simulation worker threads.
    pub sim_workers: usize,
    /// EMPA processor configuration used by the sim workers.
    pub empa: EmpaConfig,
    /// Dynamic batching policy for mass ops.
    pub batcher: BatcherConfig,
    /// Routing policy (accelerator threshold etc.).
    pub route: RoutePolicy,
    /// Bounded queue depth towards the sim pool (backpressure).
    pub queue_cap: usize,
}

impl Default for FabricConfig {
    fn default() -> Self {
        FabricConfig {
            sim_workers: 4,
            empa: EmpaConfig::default(),
            batcher: BatcherConfig::default(),
            route: RoutePolicy::default(),
            queue_cap: 256,
        }
    }
}

/// Fabric reply for one request.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    /// Program simulated: final %eax, clocks, cores used.
    Program { eax: i32, clocks: u64, cores: usize },
    /// Mass op scalar result for this request's row(s).
    Scalars(Vec<f32>),
    /// Mass op row results.
    Rows(Vec<Vec<f32>>),
    /// Failure.
    Error(String),
}

/// A submitted job awaiting its response.
pub struct JobHandle {
    pub id: u64,
    rx: Receiver<(u64, Response, Instant)>,
    submitted: Instant,
}

impl JobHandle {
    /// Block until the response arrives; returns (response, latency).
    pub fn wait(self) -> (Response, Duration) {
        match self.rx.recv() {
            Ok((_, resp, done)) => (resp, done.duration_since(self.submitted)),
            Err(_) => (Response::Error("fabric shut down".into()), self.submitted.elapsed()),
        }
    }
}

enum Msg {
    Job { id: u64, kind: RequestKind, reply: Sender<(u64, Response, Instant)> },
    Shutdown,
}

enum SimMsg {
    Run { id: u64, kind: RequestKind, reply: Sender<(u64, Response, Instant)> },
    Stop,
}

struct MassJob {
    id: u64,
    reply: Sender<(u64, Response, Instant)>,
}

enum AccelMsg {
    Batch { op: MassOp, rows: Vec<crate::accel::batch::PendingRow<MassJob>>, scale_bias: [f32; 2] },
    Stop,
}

/// The running fabric.
pub struct Fabric {
    tx: SyncSender<Msg>,
    next_id: Mutex<u64>,
    pub metrics: Arc<FabricMetrics>,
    threads: Mutex<Vec<JoinHandle<()>>>,
}

impl Fabric {
    /// Start the fabric; `accel` is constructed on the accelerator worker
    /// thread (PJRT handles are thread-affine) behind the §3.8 link.
    pub fn start(cfg: FabricConfig, accel: AccelFactory) -> Arc<Fabric> {
        let metrics = Arc::new(FabricMetrics::default());
        let (tx, rx) = sync_channel::<Msg>(cfg.queue_cap);
        let mut threads = Vec::new();

        // --- sim worker pool -------------------------------------------
        let (sim_tx, sim_rx) = sync_channel::<SimMsg>(cfg.queue_cap);
        let sim_rx = Arc::new(Mutex::new(sim_rx));
        for w in 0..cfg.sim_workers.max(1) {
            let rx = Arc::clone(&sim_rx);
            let empa_cfg = cfg.empa.clone();
            let m = Arc::clone(&metrics);
            threads.push(
                std::thread::Builder::new()
                    .name(format!("empa-sim-{w}"))
                    .spawn(move || sim_worker(rx, empa_cfg, m))
                    .expect("spawn sim worker"),
            );
        }

        // --- accelerator worker ----------------------------------------
        let (acc_tx, acc_rx) = mpsc::channel::<AccelMsg>();
        {
            let m = Arc::clone(&metrics);
            threads.push(
                std::thread::Builder::new()
                    .name("accel".into())
                    .spawn(move || accel_worker(acc_rx, accel, m))
                    .expect("spawn accel worker"),
            );
        }

        // --- router / leader -------------------------------------------
        {
            let m = Arc::clone(&metrics);
            let cfg2 = cfg.clone();
            threads.push(
                std::thread::Builder::new()
                    .name("fabric-router".into())
                    .spawn(move || router_loop(rx, sim_tx, acc_tx, cfg2, m))
                    .expect("spawn router"),
            );
        }

        Arc::new(Fabric { tx, next_id: Mutex::new(0), metrics, threads: Mutex::new(threads) })
    }

    /// Submit a job; blocks when the fabric queue is full (backpressure).
    pub fn submit(&self, kind: RequestKind) -> Result<JobHandle> {
        let id = {
            let mut g = self.next_id.lock().unwrap();
            *g += 1;
            *g
        };
        let (reply_tx, reply_rx) = mpsc::channel();
        let submitted = Instant::now();
        self.metrics.submitted.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.tx
            .send(Msg::Job { id, kind, reply: reply_tx })
            .map_err(|_| anyhow!("fabric is shut down"))?;
        Ok(JobHandle { id, rx: reply_rx, submitted })
    }

    /// Submit a full trace and wait for all responses; returns per-request
    /// (request-id, response, latency).
    pub fn run_trace(&self, trace: Vec<Request>) -> Vec<(u64, Response, Duration)> {
        let handles: Vec<(u64, JobHandle)> = trace
            .into_iter()
            .map(|r| (r.id, self.submit(r.kind).expect("submit")))
            .collect();
        handles
            .into_iter()
            .map(|(rid, h)| {
                let (resp, lat) = h.wait();
                (rid, resp, lat)
            })
            .collect()
    }

    /// Stop all threads (idempotent; pending jobs are completed first).
    pub fn shutdown(&self) {
        let _ = self.tx.send(Msg::Shutdown);
        let mut g = self.threads.lock().unwrap();
        for t in g.drain(..) {
            let _ = t.join();
        }
    }
}

// ----------------------------------------------------------------------
// threads
// ----------------------------------------------------------------------

fn router_loop(
    rx: Receiver<Msg>,
    sim_tx: SyncSender<SimMsg>,
    acc_tx: mpsc::Sender<AccelMsg>,
    cfg: FabricConfig,
    metrics: Arc<FabricMetrics>,
) {
    use std::sync::atomic::Ordering::Relaxed;
    // One batcher per mass op kind (rows of one flush share an artifact).
    let mut batchers: HashMap<MassOp, Batcher<MassJob>> = HashMap::new();
    let flush = |op: MassOp, rows: Vec<crate::accel::batch::PendingRow<MassJob>>, acc_tx: &mpsc::Sender<AccelMsg>| {
        let _ = acc_tx.send(AccelMsg::Batch { op, rows, scale_bias: [0.0; 2] });
    };
    loop {
        // Wait bounded by the earliest batch deadline.
        let deadline = batchers
            .values()
            .filter_map(|b| b.next_deadline())
            .min();
        let msg = match deadline {
            Some(d) => {
                let now = Instant::now();
                let wait = d.saturating_duration_since(now);
                match rx.recv_timeout(wait) {
                    Ok(m) => Some(m),
                    Err(RecvTimeoutError::Timeout) => None,
                    Err(RecvTimeoutError::Disconnected) => break,
                }
            }
            None => match rx.recv() {
                Ok(m) => Some(m),
                Err(_) => break,
            },
        };
        // Deadline flushes first (they are due).
        let now = Instant::now();
        for (op, b) in batchers.iter_mut() {
            if let Some(rows) = b.poll(now) {
                metrics.deadline_flushes.fetch_add(1, Relaxed);
                flush(*op, rows, &acc_tx);
            }
        }
        let Some(msg) = msg else { continue };
        match msg {
            Msg::Shutdown => break,
            Msg::Job { id, kind, reply } => match router::route(&kind, &cfg.route) {
                Target::Simulator => {
                    metrics.routed_sim.fetch_add(1, Relaxed);
                    let _ = sim_tx.send(SimMsg::Run { id, kind, reply });
                }
                Target::Inline => {
                    // Small mass op: not worth the accelerator round trip
                    // (the §2.4 offset-time argument); compute natively.
                    metrics.routed_inline.fetch_add(1, Relaxed);
                    let resp = inline_mass(&kind);
                    let _ = reply.send((id, resp, Instant::now()));
                }
                Target::Accelerator => {
                    metrics.routed_accel.fetch_add(1, Relaxed);
                    let (op, row, row2) = match kind {
                        RequestKind::MassSum { values } => (MassOp::Sumup, values, None),
                        RequestKind::MassDot { a, b } => (MassOp::Dot, a, Some(b)),
                        RequestKind::RunProgram { .. } => unreachable!("router"),
                    };
                    let b = batchers
                        .entry(op)
                        .or_insert_with(|| Batcher::new(cfg.batcher.clone()));
                    if let Some(rows) = b.push(MassJob { id, reply }, row, row2, Instant::now()) {
                        flush(op, rows, &acc_tx);
                    }
                }
            },
        }
    }
    // drain remaining batches, stop workers
    for (op, mut b) in batchers {
        if let Some(rows) = b.drain() {
            flush(op, rows, &acc_tx);
        }
    }
    for _ in 0..64 {
        let _ = sim_tx.send(SimMsg::Stop);
    }
    let _ = acc_tx.send(AccelMsg::Stop);
}

fn inline_mass(kind: &RequestKind) -> Response {
    match kind {
        RequestKind::MassSum { values } => Response::Scalars(vec![values.iter().sum()]),
        RequestKind::MassDot { a, b } => {
            Response::Scalars(vec![a.iter().zip(b).map(|(x, y)| x * y).sum()])
        }
        RequestKind::RunProgram { .. } => Response::Error("program routed inline".into()),
    }
}

fn sim_worker(rx: Arc<Mutex<Receiver<SimMsg>>>, cfg: EmpaConfig, metrics: Arc<FabricMetrics>) {
    loop {
        let msg = {
            let g = rx.lock().unwrap();
            g.recv()
        };
        match msg {
            Ok(SimMsg::Run { id, kind, reply }) => {
                let resp = match kind {
                    RequestKind::RunProgram { mode, values } => {
                        let (src, _) = crate::workload::sumup::program(mode, &values);
                        match assemble(&src) {
                            Ok(p) => {
                                let r = EmpaProcessor::new(&p.image, &cfg).run();
                                match r.fault {
                                    None => Response::Program {
                                        eax: r.eax(),
                                        clocks: r.clocks,
                                        cores: r.max_occupied,
                                    },
                                    Some(f) => Response::Error(f),
                                }
                            }
                            Err(e) => Response::Error(e.to_string()),
                        }
                    }
                    other => inline_mass(&other),
                };
                metrics.completed.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                let _ = reply.send((id, resp, Instant::now()));
            }
            Ok(SimMsg::Stop) | Err(_) => break,
        }
    }
}

fn accel_worker(rx: Receiver<AccelMsg>, accel: AccelFactory, metrics: Arc<FabricMetrics>) {
    use std::sync::atomic::Ordering::Relaxed;
    let accel = match accel() {
        Ok(a) => a,
        Err(e) => {
            // Answer every batch with the construction error.
            while let Ok(msg) = rx.recv() {
                match msg {
                    AccelMsg::Stop => return,
                    AccelMsg::Batch { rows, .. } => {
                        for p in rows {
                            metrics.errors.fetch_add(1, Relaxed);
                            let _ = p.tag.reply.send((
                                p.tag.id,
                                Response::Error(format!("accelerator init: {e}")),
                                Instant::now(),
                            ));
                        }
                    }
                }
            }
            return;
        }
    };
    while let Ok(msg) = rx.recv() {
        match msg {
            AccelMsg::Stop => break,
            AccelMsg::Batch { op, rows, scale_bias } => {
                metrics.accel_batches.fetch_add(1, Relaxed);
                metrics.accel_rows.fetch_add(rows.len() as u64, Relaxed);
                let req = MassRequest {
                    op,
                    rows: rows.iter().map(|p| p.row.clone()).collect(),
                    rows2: rows.iter().filter_map(|p| p.row2.clone()).collect(),
                    scale_bias,
                };
                let done = Instant::now();
                match accel.execute(&req) {
                    Ok(MassResult::Scalars(vals)) => {
                        for (p, v) in rows.into_iter().zip(vals) {
                            metrics.completed.fetch_add(1, Relaxed);
                            let _ = p.tag.reply.send((p.tag.id, Response::Scalars(vec![v]), done));
                        }
                    }
                    Ok(MassResult::Rows(out)) => {
                        for (p, r) in rows.into_iter().zip(out) {
                            metrics.completed.fetch_add(1, Relaxed);
                            let _ = p.tag.reply.send((p.tag.id, Response::Rows(vec![r]), done));
                        }
                    }
                    Ok(MassResult::Stats { sum, .. }) => {
                        for (p, v) in rows.into_iter().zip(sum) {
                            metrics.completed.fetch_add(1, Relaxed);
                            let _ = p.tag.reply.send((p.tag.id, Response::Scalars(vec![v]), done));
                        }
                    }
                    Err(e) => {
                        let msg = e.to_string();
                        for p in rows {
                            metrics.errors.fetch_add(1, Relaxed);
                            let _ = p.tag.reply.send((p.tag.id, Response::Error(msg.clone()), done));
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::NativeAccel;
    use crate::workload::sumup::Mode;

    fn small_fabric() -> Arc<Fabric> {
        let cfg = FabricConfig {
            sim_workers: 2,
            batcher: BatcherConfig { max_rows: 4, max_wait: Duration::from_millis(2) },
            ..Default::default()
        };
        Fabric::start(cfg, Box::new(|| Ok(Box::new(NativeAccel) as Box<dyn crate::accel::Accelerator>)))
    }

    #[test]
    fn program_jobs_round_trip() {
        let f = small_fabric();
        let h = f
            .submit(RequestKind::RunProgram { mode: Mode::Sumup, values: vec![1, 2, 3, 4] })
            .unwrap();
        let (resp, _lat) = h.wait();
        assert_eq!(resp, Response::Program { eax: 10, clocks: 36, cores: 5 });
        f.shutdown();
    }

    #[test]
    fn mass_ops_batched_and_answered() {
        let f = small_fabric();
        let hs: Vec<JobHandle> = (0..8)
            .map(|i| {
                f.submit(RequestKind::MassSum { values: vec![i as f32; 200] }).unwrap()
            })
            .collect();
        for (i, h) in hs.into_iter().enumerate() {
            let (resp, _) = h.wait();
            assert_eq!(resp, Response::Scalars(vec![(i * 200) as f32]));
        }
        assert!(f.metrics.accel_batches.load(std::sync::atomic::Ordering::Relaxed) >= 2);
        f.shutdown();
    }

    #[test]
    fn small_mass_ops_computed_inline() {
        let f = small_fabric();
        let h = f.submit(RequestKind::MassSum { values: vec![1.0, 2.0] }).unwrap();
        let (resp, _) = h.wait();
        assert_eq!(resp, Response::Scalars(vec![3.0]));
        assert_eq!(f.metrics.routed_inline.load(std::sync::atomic::Ordering::Relaxed), 1);
        assert_eq!(f.metrics.routed_accel.load(std::sync::atomic::Ordering::Relaxed), 0);
        f.shutdown();
    }

    #[test]
    fn deadline_flush_completes_partial_batches() {
        // 3 rows < max_rows=4: only the deadline can flush them.
        let f = small_fabric();
        let hs: Vec<JobHandle> = (0..3)
            .map(|_| f.submit(RequestKind::MassSum { values: vec![1.0; 128] }).unwrap())
            .collect();
        for h in hs {
            let (resp, _) = h.wait();
            assert_eq!(resp, Response::Scalars(vec![128.0]));
        }
        f.shutdown();
    }

    #[test]
    fn mixed_trace_all_complete() {
        let f = small_fabric();
        let trace = crate::workload::TraceGen::new(crate::workload::TraceConfig {
            num_requests: 64,
            ..Default::default()
        })
        .generate();
        let results = f.run_trace(trace);
        assert_eq!(results.len(), 64);
        assert!(results.iter().all(|(_, r, _)| !matches!(r, Response::Error(_))));
        f.shutdown();
    }
}
