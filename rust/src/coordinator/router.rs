//! Routing policy: which execution lane serves a request.
//!
//! The policy encodes the paper's §2.4 offset-time argument: connecting
//! work to an external accelerator is "only worth it for activities long
//! enough to be not disproportional with that offset time". Short mass
//! ops are computed inline by the leader; long ones go through the §3.8
//! link to the mass-backend chain; program jobs always go to the
//! program-class backends (the simulated EMPA pool).

use crate::api::{RequestKind, Route};

/// Routing policy knobs.
#[derive(Debug, Clone)]
pub struct RoutePolicy {
    /// Minimum vector length for the accelerator to pay off.
    pub accel_min_len: usize,
}

impl Default for RoutePolicy {
    fn default() -> Self {
        RoutePolicy { accel_min_len: 64 }
    }
}

/// Route one request.
pub fn route(kind: &RequestKind, policy: &RoutePolicy) -> Route {
    match kind {
        RequestKind::RunProgram { .. } => Route::Simulator,
        RequestKind::MassSum { values } => {
            if values.len() >= policy.accel_min_len {
                Route::Accelerator
            } else {
                Route::Inline
            }
        }
        RequestKind::MassDot { a, .. } => {
            if a.len() >= policy.accel_min_len {
                Route::Accelerator
            } else {
                Route::Inline
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::sumup::Mode;

    #[test]
    fn programs_always_simulate() {
        let p = RoutePolicy::default();
        let k = RequestKind::RunProgram { mode: Mode::No, values: vec![1] };
        assert_eq!(route(&k, &p), Route::Simulator);
    }

    #[test]
    fn threshold_splits_mass_ops() {
        let p = RoutePolicy { accel_min_len: 10 };
        assert_eq!(route(&RequestKind::MassSum { values: vec![0.0; 9] }, &p), Route::Inline);
        assert_eq!(route(&RequestKind::MassSum { values: vec![0.0; 10] }, &p), Route::Accelerator);
        assert_eq!(
            route(&RequestKind::MassDot { a: vec![0.0; 10], b: vec![0.0; 10] }, &p),
            Route::Accelerator
        );
        assert_eq!(
            route(&RequestKind::MassDot { a: vec![0.0; 2], b: vec![0.0; 2] }, &p),
            Route::Inline
        );
    }
}
