//! Routing policy: which execution lane serves a request.
//!
//! The policy encodes the paper's §2.4 offset-time argument: connecting
//! work to an external accelerator is "only worth it for activities long
//! enough to be not disproportional with that offset time". Short mass
//! ops are computed inline by the leader; long ones go through the §3.8
//! link to the mass-backend chain; *oversized* ones are scattered across
//! the sim pool's dispatch plane and gathered by a parent-side
//! accumulator; program jobs always go to the program-class backends
//! (the simulated EMPA pool).

use crate::api::{RequestKind, Route};

/// Routing policy knobs.
#[derive(Debug, Clone)]
pub struct RoutePolicy {
    /// Minimum vector length for the accelerator to pay off.
    pub accel_min_len: usize,
    /// Minimum vector length for scatter/gather across the sim pool to
    /// pay off (oversized ops are chunked instead of batched whole).
    pub split_min_len: usize,
}

impl Default for RoutePolicy {
    fn default() -> Self {
        RoutePolicy { accel_min_len: 64, split_min_len: 8192 }
    }
}

fn mass_route(len: usize, policy: &RoutePolicy) -> Route {
    if len >= policy.split_min_len {
        Route::Split
    } else if len >= policy.accel_min_len {
        Route::Accelerator
    } else {
        Route::Inline
    }
}

/// Route one request.
pub fn route(kind: &RequestKind, policy: &RoutePolicy) -> Route {
    match kind {
        RequestKind::RunProgram { .. } => Route::Simulator,
        RequestKind::MassSum { values } => mass_route(values.len(), policy),
        // Mismatched operands are rejected at submission
        // (`FabricError::ShapeMismatch`); routing by the shorter side is
        // defence in depth — a mismatch can never widen the lane.
        RequestKind::MassDot { a, b } => mass_route(a.len().min(b.len()), policy),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::sumup::Mode;

    #[test]
    fn programs_always_simulate() {
        let p = RoutePolicy::default();
        assert_eq!(route(&RequestKind::sumup(Mode::No, vec![1]), &p), Route::Simulator);
        assert_eq!(
            route(&RequestKind::dotprod(Mode::For, vec![1], vec![2]), &p),
            Route::Simulator
        );
        assert_eq!(route(&RequestKind::scale(Mode::For, vec![1], 2), &p), Route::Simulator);
        assert_eq!(route(&RequestKind::traces(vec![]), &p), Route::Simulator);
    }

    #[test]
    fn threshold_splits_mass_ops() {
        let p = RoutePolicy { accel_min_len: 10, ..Default::default() };
        assert_eq!(route(&RequestKind::mass_sum(vec![0.0; 9]), &p), Route::Inline);
        assert_eq!(route(&RequestKind::mass_sum(vec![0.0; 10]), &p), Route::Accelerator);
        assert_eq!(
            route(&RequestKind::mass_dot(vec![0.0; 10], vec![0.0; 10]), &p),
            Route::Accelerator
        );
        assert_eq!(
            route(&RequestKind::mass_dot(vec![0.0; 2], vec![0.0; 2]), &p),
            Route::Inline
        );
    }

    #[test]
    fn oversized_mass_ops_route_to_split() {
        let p = RoutePolicy { accel_min_len: 10, split_min_len: 100 };
        assert_eq!(route(&RequestKind::mass_sum(vec![0.0; 99]), &p), Route::Accelerator);
        assert_eq!(route(&RequestKind::mass_sum(vec![0.0; 100]), &p), Route::Split);
        assert_eq!(
            route(&RequestKind::mass_dot(vec![0.0; 256], vec![0.0; 256]), &p),
            Route::Split
        );
    }

    #[test]
    fn dot_routes_by_the_shorter_operand() {
        // Mismatches are rejected at submission; the router must still
        // never let the long side widen the lane.
        let p = RoutePolicy { accel_min_len: 10, split_min_len: 100 };
        assert_eq!(
            route(&RequestKind::mass_dot(vec![0.0; 500], vec![0.0; 4]), &p),
            Route::Inline
        );
    }
}
