//! The supervisor dispatch plane: per-worker bounded deques with
//! neighbour work-stealing.
//!
//! The paper's central mechanism is that a core, "using the help of the
//! supervisor", outsources part of its job to a neighbouring core. The
//! seed fabric approximated the sim pool with one shared
//! `Arc<Mutex<Receiver>>` queue — a lock convoy the supervisor layer
//! exists to avoid. This module replaces it with the distributed shape
//! the EMPA-parallelism companion work describes:
//!
//! - every worker owns a **bounded deque** (its staged backlog);
//! - the supervisor **places** each job on the least-loaded deque
//!   (§4.1.3's one-allocation-per-control-tick pacing);
//! - an idle worker first drains its own deque, then **steals** the
//!   highest-priority staged entry from a neighbour's deque (ring
//!   order), so a busy worker's backlog is redistributed instead of
//!   serialising behind it — and priority order holds no matter which
//!   worker ends up serving;
//! - per-worker depth gauges plus placement/steal counters are published
//!   through [`FabricMetrics`](super::FabricMetrics) so the
//!   redistribution is observable.
//!
//! The plane is generic over the task type: the coordinator instantiates
//! it with `SimTask` (program jobs and mass-op shards), the unit tests
//! with plain integers.

use super::metrics::{FabricMetrics, WorkerStats};
use crate::api::Priority;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// One staged entry: the task plus the priority that ordered it.
struct Entry<T> {
    priority: Priority,
    item: T,
}

/// One worker's bounded deque. The lane's depth gauge lives in its
/// [`WorkerStats`] (single source for placement decisions and metrics).
struct Lane<T> {
    queue: Mutex<VecDeque<Entry<T>>>,
    /// Whether the lane's owner is mid-task (a worker executing has an
    /// empty deque but is *not* idle — the scatter path cares).
    busy: AtomicBool,
}

/// Backstop for a parked worker's wait. Placements notify under the park
/// lock (and workers re-check under it before waiting), so no wakeup can
/// be missed — this only bounds the damage if that invariant ever broke.
const PARK: Duration = Duration::from_millis(250);

/// The dispatch plane: per-worker deques, least-loaded placement,
/// neighbour stealing. See the module docs for the shape.
pub struct DispatchPlane<T> {
    lanes: Vec<Lane<T>>,
    /// Bounded backlog per lane (`try_place` refuses past this).
    lane_cap: usize,
    /// Parking lot for idle workers. Placements notify under this lock
    /// (and workers re-check depths under it), so no wakeup is missed.
    park: Mutex<()>,
    work: Condvar,
    /// Workers currently waiting on `work` (SeqCst, see `push`): lets a
    /// placement skip the park lock entirely when nobody is parked.
    parked: AtomicUsize,
    closed: AtomicBool,
    stats: Vec<Arc<WorkerStats>>,
}

impl<T> DispatchPlane<T> {
    /// A plane of `workers` lanes whose caps sum to at least `total_cap`.
    pub fn new(workers: usize, total_cap: usize, metrics: &FabricMetrics) -> Arc<Self> {
        let workers = workers.max(1);
        let lane_cap = total_cap.div_ceil(workers).max(1);
        let lanes = (0..workers)
            .map(|_| Lane { queue: Mutex::new(VecDeque::new()), busy: AtomicBool::new(false) })
            .collect();
        let stats = (0..workers).map(|w| metrics.worker(w)).collect();
        Arc::new(DispatchPlane {
            lanes,
            lane_cap,
            park: Mutex::new(()),
            work: Condvar::new(),
            parked: AtomicUsize::new(0),
            closed: AtomicBool::new(false),
            stats,
        })
    }

    /// Number of worker lanes.
    pub fn workers(&self) -> usize {
        self.lanes.len()
    }

    /// SeqCst store: pairs with the SeqCst `parked` handshake in
    /// `push`/`next` so a depth a placer published before reading
    /// `parked == 0` is visible to any worker that parks afterwards.
    fn set_depth(&self, w: usize, depth: usize) {
        self.stats[w].depth.store(depth as u64, Ordering::SeqCst);
    }

    /// Staged depth of one lane (gauge; advisory between mutations).
    pub fn depth(&self, w: usize) -> usize {
        self.stats[w].depth.load(Ordering::Relaxed) as usize
    }

    /// Staged depth across all lanes (SeqCst: the park-path re-check
    /// relies on seeing any depth published before `parked` was read).
    pub fn total_depth(&self) -> usize {
        (0..self.lanes.len())
            .map(|w| self.stats[w].depth.load(Ordering::SeqCst) as usize)
            .sum()
    }

    /// Lanes whose deque is empty *and* whose worker is not mid-task —
    /// the neighbours actually free to help (the scatter path sizes its
    /// fan-out off this).
    pub fn idle_lanes(&self) -> usize {
        self.lanes
            .iter()
            .enumerate()
            .filter(|(w, l)| !l.busy.load(Ordering::Relaxed) && self.depth(*w) == 0)
            .count()
    }

    /// Least-loaded lane, preferring a lane whose worker is free over a
    /// mid-task worker's (equally shallow) lane — so placements and
    /// scatter shards land where they will be served soonest.
    fn least_loaded(&self) -> usize {
        let mut best = 0;
        let mut best_key = (usize::MAX, true);
        for (w, l) in self.lanes.iter().enumerate() {
            let key = (self.depth(w), l.busy.load(Ordering::Relaxed));
            if key < best_key {
                best = w;
                best_key = key;
            }
        }
        best
    }

    /// Insert keeping the lane ordered by priority, FIFO within a class.
    fn insert(queue: &mut VecDeque<Entry<T>>, entry: Entry<T>) {
        let at = queue
            .iter()
            .rposition(|e| e.priority >= entry.priority)
            .map_or(0, |i| i + 1);
        queue.insert(at, entry);
    }

    fn push(&self, w: usize, priority: Priority, item: T, capped: bool) -> Result<(), T> {
        {
            let mut q = self.lanes[w].queue.lock().unwrap();
            if capped && q.len() >= self.lane_cap {
                return Err(item);
            }
            Self::insert(&mut q, Entry { priority, item });
            self.set_depth(w, q.len());
            self.stats[w].placements.fetch_add(1, Ordering::Relaxed);
        }
        // Wake one parked worker, skipping the park lock when nobody is
        // parked (the common loaded case). The SeqCst pairing makes the
        // skip safe: if this load sees 0, any worker that parks later
        // incremented `parked` after it — and its depth re-check (also
        // SeqCst, under the park lock) then sees the depth stored above,
        // so it goes back to work instead of sleeping. One waiter
        // suffices — any worker can serve any task (own-lane pop or
        // steal) — and the park timeout backstops everything.
        if self.parked.load(Ordering::SeqCst) > 0 {
            let _g = self.park.lock().unwrap();
            self.work.notify_one();
        }
        Ok(())
    }

    /// Place on the least-loaded lane, refusing past the lane cap — the
    /// supervisor's backpressure signal. Returns the chosen lane.
    pub fn try_place(&self, priority: Priority, item: T) -> Result<usize, T> {
        let w = self.least_loaded();
        self.push(w, priority, item, true)?;
        Ok(w)
    }

    /// Place on the least-loaded lane unconditionally (shutdown drain and
    /// scatter shards, whose fan-out is already bounded by the idle-lane
    /// count).
    pub fn place(&self, priority: Priority, item: T) -> usize {
        let w = self.least_loaded();
        let Ok(()) = self.push(w, priority, item, false) else { unreachable!("uncapped push") };
        w
    }

    /// Place on a specific lane unconditionally (tests stage skew with it).
    #[cfg(test)]
    pub fn place_on(&self, w: usize, priority: Priority, item: T) {
        let Ok(()) = self.push(w, priority, item, false) else { unreachable!("uncapped push") };
    }

    fn pop_local(&self, w: usize) -> Option<T> {
        let mut q = self.lanes[w].queue.lock().unwrap();
        let e = q.pop_front()?;
        self.set_depth(w, q.len());
        Some(e.item)
    }

    /// Steal one task from the head (highest-priority end) of the first
    /// non-empty neighbour, scanning the ring from `w + 1`. Both ends sit
    /// under the same lane mutex, so taking the head costs nothing extra
    /// and keeps the High-overtakes contract intact no matter which
    /// worker ends up serving the entry.
    fn steal(&self, w: usize) -> Option<T> {
        let n = self.lanes.len();
        for off in 1..n {
            let v = (w + off) % n;
            let mut q = self.lanes[v].queue.lock().unwrap();
            if let Some(e) = q.pop_front() {
                self.set_depth(v, q.len());
                drop(q);
                self.stats[w].steals.fetch_add(1, Ordering::Relaxed);
                return Some(e.item);
            }
        }
        None
    }

    /// Next task for worker `w`: own lane first, then a neighbour's,
    /// parking when the whole plane is empty. Returns `None` once the
    /// plane is closed **and** fully drained, so pending work always
    /// completes before the worker exits. Marks the worker busy while it
    /// holds a task (see [`DispatchPlane::idle_lanes`]).
    pub fn next(&self, w: usize) -> Option<T> {
        self.lanes[w].busy.store(false, Ordering::Relaxed);
        loop {
            if let Some(t) = self.pop_local(w).or_else(|| self.steal(w)) {
                self.lanes[w].busy.store(true, Ordering::Relaxed);
                return Some(t);
            }
            let guard = self.park.lock().unwrap();
            // Register as parked BEFORE the depth re-check: a placer that
            // read `parked == 0` (and so skipped the notify) is then
            // ordered before this increment, which puts its depth store
            // before our re-check — one side always sees the other.
            self.parked.fetch_add(1, Ordering::SeqCst);
            if self.total_depth() > 0 {
                self.parked.fetch_sub(1, Ordering::SeqCst);
                continue; // placed between our scan and the park lock
            }
            if self.closed.load(Ordering::Acquire) {
                self.parked.fetch_sub(1, Ordering::SeqCst);
                return None;
            }
            let (guard, _) = self.work.wait_timeout(guard, PARK).unwrap();
            self.parked.fetch_sub(1, Ordering::SeqCst);
            drop(guard);
        }
    }

    /// Close the plane: workers finish the staged backlog, then exit.
    pub fn close(&self) {
        self.closed.store(true, Ordering::Release);
        let _g = self.park.lock().unwrap();
        self.work.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    fn plane(workers: usize, cap: usize) -> (Arc<DispatchPlane<u64>>, Arc<FabricMetrics>) {
        let metrics = Arc::new(FabricMetrics::default());
        let p = DispatchPlane::new(workers, cap, &metrics);
        (p, metrics)
    }

    #[test]
    fn placement_spreads_to_the_least_loaded_lane() {
        let (p, m) = plane(3, 30);
        for i in 0..6 {
            p.try_place(Priority::Normal, i).unwrap();
        }
        assert_eq!([p.depth(0), p.depth(1), p.depth(2)], [2, 2, 2]);
        assert_eq!(p.total_depth(), 6);
        assert_eq!(p.idle_lanes(), 0);
        for w in 0..3 {
            assert_eq!(m.worker(w).placements.load(Ordering::Relaxed), 2);
            assert_eq!(m.worker(w).depth.load(Ordering::Relaxed), 2);
        }
    }

    #[test]
    fn try_place_refuses_when_every_lane_is_full() {
        let (p, _m) = plane(2, 4); // 2 per lane
        for i in 0..4 {
            p.try_place(Priority::Normal, i).unwrap();
        }
        assert_eq!(p.try_place(Priority::Normal, 99).unwrap_err(), 99);
        // uncapped place still lands (scatter / shutdown drain path)
        p.place(Priority::Normal, 100);
        assert_eq!(p.total_depth(), 5);
    }

    #[test]
    fn high_priority_overtakes_within_a_lane() {
        let (p, _m) = plane(1, 16);
        p.place(Priority::Normal, 1);
        p.place(Priority::Low, 2);
        p.place(Priority::Normal, 3);
        p.place(Priority::High, 4);
        let order: Vec<u64> = (0..4).map(|_| p.pop_local(0).unwrap()).collect();
        assert_eq!(order, vec![4, 1, 3, 2], "High first, Low last, FIFO within a class");
    }

    #[test]
    fn a_mid_task_worker_is_not_idle_even_with_an_empty_deque() {
        let (p, _m) = plane(2, 8);
        assert_eq!(p.idle_lanes(), 2, "fresh plane: everyone idle");
        p.place_on(0, Priority::Normal, 7);
        assert_eq!(p.idle_lanes(), 1, "staged lane is not idle");
        let t = p.next(0).expect("own-lane pop");
        assert_eq!(t, 7);
        // Lane 0's deque is empty again, but its worker now holds a task.
        assert_eq!(p.depth(0), 0);
        assert_eq!(p.idle_lanes(), 1, "mid-task worker is busy, not idle");
    }

    #[test]
    fn idle_worker_steals_from_a_busy_neighbour() {
        // Everything is staged on worker 0's lane; worker 1 must clear it.
        let (p, m) = plane(2, 16);
        for i in 0..4 {
            p.place_on(0, Priority::Normal, i);
        }
        let got = Arc::new(AtomicU64::new(0));
        let done = {
            let p = Arc::clone(&p);
            let got = Arc::clone(&got);
            std::thread::spawn(move || {
                while let Some(v) = p.next(1) {
                    got.fetch_add(v + 1, Ordering::Relaxed);
                }
            })
        };
        // Spin until the thief drains the victim lane, then close.
        while p.total_depth() > 0 {
            std::thread::yield_now();
        }
        p.close();
        done.join().unwrap();
        assert_eq!(got.load(Ordering::Relaxed), 1 + 2 + 3 + 4);
        assert_eq!(m.worker(1).steals.load(Ordering::Relaxed), 4);
        assert_eq!(m.worker(0).depth.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn close_drains_staged_work_before_ending_the_lane() {
        let (p, _m) = plane(1, 8);
        for i in 0..3 {
            p.place(Priority::Normal, i);
        }
        p.close();
        assert_eq!(p.next(0), Some(0));
        assert_eq!(p.next(0), Some(1));
        assert_eq!(p.next(0), Some(2));
        assert_eq!(p.next(0), None);
    }

    #[test]
    fn steal_takes_the_highest_priority_head() {
        let (p, _m) = plane(2, 16);
        p.place_on(0, Priority::Low, 3);
        p.place_on(0, Priority::High, 1);
        p.place_on(0, Priority::Normal, 2);
        // Priority order holds no matter which worker serves: the thief
        // takes the High head, the owner then pops the Normal entry.
        assert_eq!(p.steal(1), Some(1), "steal the High head, not the Low tail");
        assert_eq!(p.pop_local(0), Some(2), "owner pops the next-highest entry");
        assert_eq!(p.pop_local(0), Some(3));
    }
}
