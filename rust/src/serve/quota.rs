//! Per-tenant admission quotas for the serve plane: classic token
//! buckets keyed by client tag.
//!
//! A tenant's bucket refills at `rate` tokens/second up to `burst`
//! capacity; each submitted job costs one token. Admission is decided
//! *before* the fabric is asked — a denied request costs the fabric
//! nothing, which is the point: quotas bound what a tenant can even
//! attempt, while the SLO governor (see [`crate::serve::slo`]) bounds
//! what the fabric as a whole will absorb.
//!
//! Time is passed in explicitly (`now: Instant`) rather than read from
//! the clock inside, so tests drive refill deterministically with
//! synthetic instants.

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Instant;

/// One tenant's refillable budget.
#[derive(Debug, Clone)]
pub struct TokenBucket {
    /// Tokens added per second. `f64::INFINITY` means unlimited.
    rate: f64,
    /// Maximum tokens the bucket holds (also the initial fill).
    burst: f64,
    tokens: f64,
    last: Instant,
}

impl TokenBucket {
    /// A bucket born full.
    pub fn new(rate: f64, burst: f64, now: Instant) -> TokenBucket {
        let burst = burst.max(0.0);
        TokenBucket { rate: rate.max(0.0), burst, tokens: burst, last: now }
    }

    /// Refill for the elapsed time, then try to spend one token.
    pub fn try_take(&mut self, now: Instant) -> bool {
        if self.rate.is_infinite() {
            return true;
        }
        let dt = now.saturating_duration_since(self.last).as_secs_f64();
        self.last = now;
        self.tokens = (self.tokens + dt * self.rate).min(self.burst);
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            true
        } else {
            false
        }
    }

    /// Tokens currently available (after a refill to `now`) — metrics
    /// only, does not spend.
    pub fn available(&mut self, now: Instant) -> f64 {
        if self.rate.is_infinite() {
            return f64::INFINITY;
        }
        let dt = now.saturating_duration_since(self.last).as_secs_f64();
        self.last = now;
        self.tokens = (self.tokens + dt * self.rate).min(self.burst);
        self.tokens
    }
}

/// Quota policy: a default bucket shape plus per-tenant overrides.
#[derive(Debug, Clone)]
pub struct QuotaConfig {
    /// Bucket shape for tenants without an override. The default is
    /// unlimited — quotas are opt-in per deployment.
    pub default_rate: f64,
    pub default_burst: f64,
    /// `(tag, rate, burst)` per-tenant overrides.
    pub overrides: Vec<(String, f64, f64)>,
}

impl Default for QuotaConfig {
    fn default() -> Self {
        QuotaConfig {
            default_rate: f64::INFINITY,
            default_burst: f64::INFINITY,
            overrides: Vec::new(),
        }
    }
}

impl QuotaConfig {
    /// Same default shape for everyone.
    pub fn uniform(rate: f64, burst: f64) -> QuotaConfig {
        QuotaConfig { default_rate: rate, default_burst: burst, overrides: Vec::new() }
    }

    /// Add a per-tenant override.
    pub fn with_override(mut self, tag: impl Into<String>, rate: f64, burst: f64) -> QuotaConfig {
        self.overrides.push((tag.into(), rate, burst));
        self
    }

    fn shape_for(&self, tag: &str) -> (f64, f64) {
        self.overrides
            .iter()
            .rev() // later overrides win
            .find(|(t, _, _)| t == tag)
            .map(|(_, r, b)| (*r, *b))
            .unwrap_or((self.default_rate, self.default_burst))
    }
}

/// The serve plane's admission table: one lazily-created bucket per
/// tenant tag. Untagged requests share the `""` bucket — anonymity is
/// not a way around the default quota.
pub struct QuotaTable {
    cfg: QuotaConfig,
    buckets: Mutex<HashMap<String, TokenBucket>>,
}

impl QuotaTable {
    pub fn new(cfg: QuotaConfig) -> QuotaTable {
        QuotaTable { cfg, buckets: Mutex::new(HashMap::new()) }
    }

    /// Spend one token from `tenant`'s bucket (creating it full on first
    /// sight). `true` = admitted.
    pub fn admit(&self, tenant: Option<&str>, now: Instant) -> bool {
        let tag = tenant.unwrap_or("");
        let mut g = self.buckets.lock().unwrap();
        g.entry(tag.to_string())
            .or_insert_with(|| {
                let (rate, burst) = self.cfg.shape_for(tag);
                TokenBucket::new(rate, burst, now)
            })
            .try_take(now)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn bucket_spends_burst_then_refills_at_rate() {
        let t0 = Instant::now();
        let mut b = TokenBucket::new(10.0, 2.0, t0);
        assert!(b.try_take(t0));
        assert!(b.try_take(t0));
        assert!(!b.try_take(t0), "burst of 2 is exhausted");
        // 100 ms at 10/s refills exactly one token
        let t1 = t0 + Duration::from_millis(100);
        assert!(b.try_take(t1));
        assert!(!b.try_take(t1));
        // refill never exceeds burst
        let t2 = t1 + Duration::from_secs(60);
        assert!((b.available(t2) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn zero_rate_admits_burst_then_nothing_ever() {
        let t0 = Instant::now();
        let mut b = TokenBucket::new(0.0, 1.0, t0);
        assert!(b.try_take(t0));
        assert!(!b.try_take(t0 + Duration::from_secs(3600)));
    }

    #[test]
    fn infinite_rate_never_denies() {
        let t0 = Instant::now();
        let mut b = TokenBucket::new(f64::INFINITY, f64::INFINITY, t0);
        for _ in 0..10_000 {
            assert!(b.try_take(t0));
        }
    }

    #[test]
    fn table_applies_overrides_and_pools_untagged() {
        let t0 = Instant::now();
        let cfg = QuotaConfig::uniform(0.0, 2.0).with_override("vip", f64::INFINITY, f64::INFINITY);
        let q = QuotaTable::new(cfg);
        // default shape: burst 2, no refill
        assert!(q.admit(Some("a"), t0));
        assert!(q.admit(Some("a"), t0));
        assert!(!q.admit(Some("a"), t0));
        // a different tenant has its own bucket
        assert!(q.admit(Some("b"), t0));
        // the override is unlimited
        for _ in 0..100 {
            assert!(q.admit(Some("vip"), t0));
        }
        // untagged requests share one bucket under the default shape
        assert!(q.admit(None, t0));
        assert!(q.admit(None, t0));
        assert!(!q.admit(None, t0), "anonymous traffic pools into one bucket");
    }

    #[test]
    fn later_override_wins() {
        let cfg = QuotaConfig::default()
            .with_override("t", 1.0, 1.0)
            .with_override("t", 5.0, 9.0);
        assert_eq!(cfg.shape_for("t"), (5.0, 9.0));
        assert_eq!(cfg.shape_for("other"), (f64::INFINITY, f64::INFINITY));
    }
}
