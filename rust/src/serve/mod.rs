//! `empa::serve` — the fabric's network front door.
//!
//! A [`ServePlane`] binds a TCP listener and speaks the hand-rolled
//! length-prefixed frame protocol in [`wire`]: requests map onto the
//! existing typed [`JobRequest`] admission path, replies carry the full
//! [`Completion`] / [`FabricError`](crate::api::FabricError) vocabulary
//! back to the client. Stacked in front of `try_submit` are the two
//! serve-plane policy layers:
//!
//! 1. **SLO governor** ([`slo`]) — playbook threshold rules over
//!    `FabricMetrics` that trip backpressure/shed; a refused request
//!    gets a typed `Overloaded { rule }` wire error and is counted per
//!    tenant and per rule.
//! 2. **Per-tenant quotas** ([`quota`]) — token buckets keyed by client
//!    tag; an exhausted bucket is a typed `QuotaExceeded { tenant }`
//!    error, again before the fabric is ever asked.
//!
//! Admitted jobs flow through the fabric's normal bounded-queue
//! admission (`QueueFull` is still possible) and the coordinator's
//! fair-share staging keyed by the same tenant tag, so one hot tenant
//! saturating its quota still cannot starve the others inside the
//! fabric.
//!
//! Threading: one nonblocking acceptor polling a stop flag, one blocking
//! reader thread per connection, and one detached waiter thread per
//! in-flight job (replies are written under a per-connection mutex, so
//! out-of-order completions interleave safely on the wire). Simple over
//! scalable — the fabric behind it is a simulator; the serve plane's job
//! is correctness of the admission story, not C10K.

pub mod client;
pub mod quota;
pub mod slo;
pub mod wire;

pub use client::WireClient;
pub use quota::{QuotaConfig, QuotaTable, TokenBucket};
pub use slo::{SloAction, SloConfig, SloGovernor, SloRule, SloSnapshot};
pub use wire::{CodecError, WireReply, WireRequest, MAX_FRAME, WIRE_VERSION};

use crate::api::FabricError;
use crate::coordinator::{Fabric, FabricConfig, FabricMetrics};
use anyhow::Context;
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Serve-plane configuration.
pub struct ServeConfig {
    /// Listen address; port 0 picks an ephemeral port (tests, loadgen).
    pub addr: String,
    /// The fabric to start behind the listener.
    pub fabric: FabricConfig,
    /// Per-tenant admission quotas (default: unlimited).
    pub quota: QuotaConfig,
    /// SLO playbook (default: scaled to the fabric's `queue_cap`).
    pub slo: SloConfig,
    /// Frame-size cap enforced on both directions.
    pub max_frame: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        let fabric = FabricConfig::default();
        let slo = SloConfig::for_queue_cap(fabric.queue_cap);
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            fabric,
            quota: QuotaConfig::default(),
            slo,
            max_frame: MAX_FRAME,
        }
    }
}

/// The running serve plane: listener + fabric + policy layers.
pub struct ServePlane {
    fabric: Arc<Fabric>,
    governor: Arc<SloGovernor>,
    local_addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    /// Registered connection streams, shut down to unblock readers.
    conns: Arc<Mutex<Vec<TcpStream>>>,
    threads: Mutex<Vec<JoinHandle<()>>>,
    /// Handler threads, registered by the acceptor as they spawn.
    handlers: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl ServePlane {
    /// Bind the listener, start the fabric, and begin accepting.
    pub fn start(cfg: ServeConfig) -> anyhow::Result<ServePlane> {
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("bind serve listener on {}", cfg.addr))?;
        let local_addr = listener.local_addr().context("serve listener local addr")?;
        listener.set_nonblocking(true).context("nonblocking serve listener")?;

        let fabric = Fabric::start_local(cfg.fabric);
        let governor = Arc::new(SloGovernor::new(cfg.slo));
        let quota = Arc::new(QuotaTable::new(cfg.quota));
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<TcpStream>>> = Arc::default();
        let handlers: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::default();

        let acceptor = {
            let fabric = Arc::clone(&fabric);
            let governor = Arc::clone(&governor);
            let stop = Arc::clone(&stop);
            let conns = Arc::clone(&conns);
            let handlers = Arc::clone(&handlers);
            let max_frame = cfg.max_frame;
            std::thread::Builder::new()
                .name("empa-serve-accept".into())
                .spawn(move || {
                    accept_loop(listener, fabric, governor, quota, stop, conns, handlers, max_frame)
                })
                .context("spawn serve acceptor")?
        };

        Ok(ServePlane {
            fabric,
            governor,
            local_addr,
            stop,
            conns,
            threads: Mutex::new(vec![acceptor]),
            handlers,
        })
    }

    /// The bound address (resolves the ephemeral port).
    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.local_addr
    }

    /// The fabric behind the listener.
    pub fn fabric(&self) -> &Arc<Fabric> {
        &self.fabric
    }

    /// Shared fabric metrics.
    pub fn metrics(&self) -> &FabricMetrics {
        &self.fabric.metrics
    }

    /// The SLO governor (its `render()` is the live playbook).
    pub fn governor(&self) -> &SloGovernor {
        &self.governor
    }

    /// Stop accepting, unblock and join every connection handler, then
    /// shut the fabric down (pending jobs complete first). Idempotent.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::Release);
        // Unblock handler reads: a blocking `read` on a shut-down socket
        // returns 0, which the codec reports as clean EOF.
        for s in self.conns.lock().unwrap().drain(..) {
            let _ = s.shutdown(Shutdown::Both);
        }
        for t in self.threads.lock().unwrap().drain(..) {
            let _ = t.join();
        }
        for t in self.handlers.lock().unwrap().drain(..) {
            let _ = t.join();
        }
        self.fabric.shutdown();
    }
}

/// How often the nonblocking acceptor polls the stop flag.
const ACCEPT_POLL: Duration = Duration::from_millis(5);

#[allow(clippy::too_many_arguments)]
fn accept_loop(
    listener: TcpListener,
    fabric: Arc<Fabric>,
    governor: Arc<SloGovernor>,
    quota: Arc<QuotaTable>,
    stop: Arc<AtomicBool>,
    conns: Arc<Mutex<Vec<TcpStream>>>,
    handlers: Arc<Mutex<Vec<JoinHandle<()>>>>,
    max_frame: usize,
) {
    while !stop.load(Ordering::Acquire) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                // The listener is nonblocking; the accepted stream must
                // not inherit that — handlers read blocking.
                if stream.set_nonblocking(false).is_err() {
                    continue;
                }
                let Ok(registered) = stream.try_clone() else { continue };
                conns.lock().unwrap().push(registered);
                let fabric = Arc::clone(&fabric);
                let governor = Arc::clone(&governor);
                let quota = Arc::clone(&quota);
                let spawned = std::thread::Builder::new()
                    .name("empa-serve-conn".into())
                    .spawn(move || handle_conn(stream, fabric, governor, quota, max_frame));
                if let Ok(h) = spawned {
                    handlers.lock().unwrap().push(h);
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(_) => std::thread::sleep(ACCEPT_POLL),
        }
    }
}

/// Write one reply frame under the connection's write lock (completions
/// from different waiter threads interleave frame-atomically).
fn send_reply(out: &Mutex<TcpStream>, reply: &WireReply, max_frame: usize) {
    let payload = wire::encode_reply(reply);
    let mut g = out.lock().unwrap();
    let _ = wire::write_frame(&mut *g, &payload, max_frame);
}

/// One connection: read frames until EOF/error, run each request through
/// the admission stack, spawn a waiter per accepted job.
fn handle_conn(
    mut stream: TcpStream,
    fabric: Arc<Fabric>,
    governor: Arc<SloGovernor>,
    quota: Arc<QuotaTable>,
    max_frame: usize,
) {
    let Ok(write_half) = stream.try_clone() else { return };
    let out = Arc::new(Mutex::new(write_half));
    loop {
        let payload = match wire::read_frame(&mut stream, max_frame) {
            Ok(Some(p)) => p,
            Ok(None) => return, // clean EOF
            Err(_) => return,   // transport error or oversized frame: drop the connection
        };
        let req = match wire::decode_request(&payload) {
            Ok(r) => r,
            Err(e) => {
                // Malformed payload: the stream itself still frames
                // correctly, so answer with a typed error (id 0 — the
                // real id may be part of what failed to decode) and
                // stop trusting the connection.
                let reply = WireReply::Failed {
                    id: 0,
                    error: FabricError::InvalidConfig(format!("bad request frame: {e}")),
                };
                send_reply(&out, &reply, max_frame);
                return;
            }
        };
        match req {
            WireRequest::Metrics { id } => {
                let text = format!("{}\n{}", fabric.metrics.render(), governor.render());
                send_reply(&out, &WireReply::MetricsText { id, text }, max_frame);
            }
            submit @ WireRequest::Submit { .. } => {
                let id = submit.id();
                let job_req = submit.into_job().expect("Submit carries a job");
                let tenant = job_req.client.clone();
                let metrics = &fabric.metrics;
                let tenant_stats = tenant.as_deref().map(|t| metrics.client(t));
                let now = Instant::now();

                // 1) SLO governor: policy shed before any queue.
                if let Some((rule, action)) = governor.decide(metrics, now) {
                    if action.refuses(job_req.priority) {
                        metrics.slo_shed.fetch_add(1, Ordering::Relaxed);
                        if let Some(s) = &tenant_stats {
                            s.submitted.fetch_add(1, Ordering::Relaxed);
                            s.shed.fetch_add(1, Ordering::Relaxed);
                        }
                        governor.note_shed(rule);
                        let error = FabricError::Overloaded { rule: rule.to_string() };
                        send_reply(&out, &WireReply::Failed { id, error }, max_frame);
                        continue;
                    }
                }

                // 2) Token-bucket quota: the tenant's own budget.
                if !quota.admit(tenant.as_deref(), now) {
                    metrics.quota_denied.fetch_add(1, Ordering::Relaxed);
                    if let Some(s) = &tenant_stats {
                        s.submitted.fetch_add(1, Ordering::Relaxed);
                        s.quota_denied.fetch_add(1, Ordering::Relaxed);
                    }
                    let error = FabricError::QuotaExceeded {
                        tenant: tenant.as_deref().unwrap_or("").to_string(),
                    };
                    send_reply(&out, &WireReply::Failed { id, error }, max_frame);
                    continue;
                }

                // 3) The fabric's own bounded admission. `try_submit`
                //    accounts per-tenant `submitted` on success; failures
                //    here still count toward the tenant's ledger.
                match fabric.try_submit(job_req) {
                    Ok(job) => {
                        let out = Arc::clone(&out);
                        // Detached waiter: resolves whenever the fabric
                        // does; the write lock orders frames.
                        let _ = std::thread::Builder::new()
                            .name("empa-serve-wait".into())
                            .spawn(move || {
                                let reply = match job.wait() {
                                    Ok(completion) => WireReply::Completed { id, completion },
                                    Err(error) => WireReply::Failed { id, error },
                                };
                                send_reply(&out, &reply, max_frame);
                            });
                    }
                    Err(error) => {
                        if let Some(s) = &tenant_stats {
                            s.submitted.fetch_add(1, Ordering::Relaxed);
                        }
                        send_reply(&out, &WireReply::Failed { id, error }, max_frame);
                    }
                }
            }
        }
    }
}
