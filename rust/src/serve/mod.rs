//! `empa::serve` — the fabric's network front door.
//!
//! A [`ServePlane`] binds a TCP listener and speaks the hand-rolled
//! length-prefixed frame protocol in [`wire`]: requests map onto the
//! existing typed [`JobRequest`] admission path, replies carry the full
//! [`Completion`] / [`FabricError`](crate::api::FabricError) vocabulary
//! back to the client. Stacked in front of `try_submit` are the two
//! serve-plane policy layers:
//!
//! 1. **SLO governor** ([`slo`]) — playbook threshold rules over
//!    `FabricMetrics` that trip backpressure/shed; a refused request
//!    gets a typed `Overloaded { rule }` wire error and is counted per
//!    tenant and per rule.
//! 2. **Per-tenant quotas** ([`quota`]) — token buckets keyed by client
//!    tag; an exhausted bucket is a typed `QuotaExceeded { tenant }`
//!    error, again before the fabric is ever asked.
//!
//! Admitted jobs flow through the fabric's normal bounded-queue
//! admission (`QueueFull` is still possible) and the coordinator's
//! fair-share staging keyed by the same tenant tag, so one hot tenant
//! saturating its quota still cannot starve the others inside the
//! fabric.
//!
//! Threading: one nonblocking acceptor polling a stop flag, one blocking
//! reader thread per connection, and a small bounded **completion pump**
//! that parks every accepted job and writes its reply when the fabric
//! resolves it (replies are written under a per-connection mutex, so
//! out-of-order completions interleave safely on the wire). The pump
//! replaces the old detached waiter-thread-per-job scheme: thread count
//! no longer scales with in-flight jobs, and shutdown joins the pump
//! workers instead of abandoning detached threads mid-write. Simple over
//! scalable — the fabric behind it is a simulator; the serve plane's job
//! is correctness of the admission story, not C10K.

pub mod client;
pub mod quota;
pub mod slo;
pub mod wire;

pub use client::WireClient;
pub use quota::{QuotaConfig, QuotaTable, TokenBucket};
pub use slo::{SloAction, SloConfig, SloGovernor, SloRule, SloSnapshot};
pub use wire::{CodecError, WireReply, WireRequest, MAX_FRAME, WIRE_VERSION};

use crate::api::{FabricError, Job};
use crate::coordinator::{Fabric, FabricConfig, FabricMetrics};
use anyhow::Context;
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Serve-plane configuration.
pub struct ServeConfig {
    /// Listen address; port 0 picks an ephemeral port (tests, loadgen).
    pub addr: String,
    /// The fabric to start behind the listener.
    pub fabric: FabricConfig,
    /// Per-tenant admission quotas (default: unlimited).
    pub quota: QuotaConfig,
    /// SLO playbook (default: scaled to the fabric's `queue_cap`).
    pub slo: SloConfig,
    /// Frame-size cap enforced on both directions.
    pub max_frame: usize,
    /// Optional shared-secret auth token. When set, every `Submit` must
    /// carry the same token or it is refused with a typed
    /// `Unauthorized { tenant }` before any policy layer runs. (Closes
    /// the "tenant tag is client-asserted" gap — the tag still names the
    /// ledger row, but an unauthenticated peer can no longer submit at
    /// all.)
    pub auth_token: Option<String>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        let fabric = FabricConfig::default();
        let slo = SloConfig::for_queue_cap(fabric.queue_cap);
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            fabric,
            quota: QuotaConfig::default(),
            slo,
            max_frame: MAX_FRAME,
            auth_token: None,
        }
    }
}

/// How many completion-pump workers a serve plane runs. The pump is a
/// poller, not a compute pool — two lanes keep a slow client write on
/// one lane from delaying every other tenant's replies.
const PUMP_WORKERS: usize = 2;

/// How long a pump worker waits for new intake while it has parked jobs
/// to poll (also its drain-poll interval during shutdown).
const PUMP_POLL: Duration = Duration::from_millis(1);

/// Wire-site chaos context threaded to every reply writer: the fabric's
/// shared engine (so wire decisions land in the same [`FaultPlan`] as
/// backend/dispatch/guest ones) plus the metrics to count injections.
///
/// [`FaultPlan`]: crate::chaos::FaultPlan
#[derive(Clone)]
struct WireChaos {
    engine: Arc<crate::chaos::ChaosEngine>,
    metrics: Arc<FabricMetrics>,
}

/// One accepted job parked in the completion pump until the fabric
/// resolves it.
struct PumpEntry {
    id: u64,
    job: Job,
    out: Arc<Mutex<TcpStream>>,
    max_frame: usize,
    chaos: Option<WireChaos>,
}

/// Bounded pool of reply writers: accepted jobs are parked here and
/// polled with [`Job::try_wait`], replacing the old detached
/// thread-per-job waiters (whose population scaled with in-flight jobs
/// and which shutdown could only abandon, never join).
///
/// Entries are dealt round-robin onto per-worker lanes; each worker
/// blocks while idle, and polls its parked set on a short tick while it
/// has any. Closing the lanes tells workers to drain: they keep polling
/// until every parked job resolves (fabric shutdown resolves all of
/// them), then exit.
struct CompletionPump {
    lanes: Mutex<Vec<mpsc::Sender<PumpEntry>>>,
    next: AtomicUsize,
    workers: Mutex<Vec<JoinHandle<()>>>,
}

impl CompletionPump {
    fn new(n: usize) -> CompletionPump {
        let mut lanes = Vec::new();
        let mut workers = Vec::new();
        for slot in 0..n.max(1) {
            let (tx, rx) = mpsc::channel::<PumpEntry>();
            lanes.push(tx);
            let h = std::thread::Builder::new()
                .name(format!("empa-serve-pump-{slot}"))
                .spawn(move || pump_loop(rx))
                .expect("spawn serve completion pump");
            workers.push(h);
        }
        CompletionPump { lanes: Mutex::new(lanes), next: AtomicUsize::new(0), workers: Mutex::new(workers) }
    }

    /// Park a job. After [`CompletionPump::close_intake`] the entry is
    /// dropped — by then every connection handler has already exited, so
    /// nobody is left to park work.
    fn submit(&self, entry: PumpEntry) {
        let lanes = self.lanes.lock().unwrap();
        if lanes.is_empty() {
            return;
        }
        let lane = self.next.fetch_add(1, Ordering::Relaxed) % lanes.len();
        let _ = lanes[lane].send(entry);
    }

    /// Drop the senders: workers stop taking intake and begin draining
    /// their parked sets.
    fn close_intake(&self) {
        self.lanes.lock().unwrap().clear();
    }

    /// Join the workers. Call after the fabric has shut down, so every
    /// parked job has resolved and the drains cannot spin.
    fn join(&self) {
        for t in self.workers.lock().unwrap().drain(..) {
            let _ = t.join();
        }
    }
}

fn pump_loop(rx: mpsc::Receiver<PumpEntry>) {
    let mut pending: Vec<PumpEntry> = Vec::new();
    let mut open = true;
    loop {
        if open {
            // Intake: block while idle, bounded wait while jobs are
            // parked (they need polling), then sweep the lane dry.
            if pending.is_empty() {
                match rx.recv() {
                    Ok(e) => pending.push(e),
                    Err(_) => open = false,
                }
            } else {
                match rx.recv_timeout(PUMP_POLL) {
                    Ok(e) => pending.push(e),
                    Err(mpsc::RecvTimeoutError::Timeout) => {}
                    Err(mpsc::RecvTimeoutError::Disconnected) => open = false,
                }
            }
            while let Ok(e) = rx.try_recv() {
                pending.push(e);
            }
        }
        if !open && pending.is_empty() {
            return;
        }
        let mut i = 0;
        while i < pending.len() {
            match pending[i].job.try_wait() {
                Some(result) => {
                    let e = pending.swap_remove(i);
                    let reply = match result {
                        Ok(completion) => WireReply::Completed { id: e.id, completion },
                        Err(error) => WireReply::Failed { id: e.id, error },
                    };
                    send_reply(&e.out, &reply, e.max_frame, e.chaos.as_ref());
                }
                None => i += 1,
            }
        }
        if !open && !pending.is_empty() {
            // Lane closed but jobs still in flight: the fabric is being
            // shut down and resolves them all; pace the drain.
            std::thread::sleep(PUMP_POLL);
        }
    }
}

/// The running serve plane: listener + fabric + policy layers.
pub struct ServePlane {
    fabric: Arc<Fabric>,
    governor: Arc<SloGovernor>,
    local_addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    /// Registered connection streams, shut down to unblock readers.
    conns: Arc<Mutex<Vec<TcpStream>>>,
    threads: Mutex<Vec<JoinHandle<()>>>,
    /// Handler threads, registered by the acceptor as they spawn.
    handlers: Arc<Mutex<Vec<JoinHandle<()>>>>,
    /// Reply writers for accepted jobs.
    pump: Arc<CompletionPump>,
}

impl ServePlane {
    /// Bind the listener, start the fabric, and begin accepting.
    pub fn start(cfg: ServeConfig) -> anyhow::Result<ServePlane> {
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("bind serve listener on {}", cfg.addr))?;
        let local_addr = listener.local_addr().context("serve listener local addr")?;
        listener.set_nonblocking(true).context("nonblocking serve listener")?;

        let fabric = Fabric::start_local(cfg.fabric);
        let governor = Arc::new(SloGovernor::new(cfg.slo));
        let quota = Arc::new(QuotaTable::new(cfg.quota));
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<TcpStream>>> = Arc::default();
        let handlers: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::default();
        let pump = Arc::new(CompletionPump::new(PUMP_WORKERS));

        let auth = Arc::new(cfg.auth_token);

        let acceptor = {
            let fabric = Arc::clone(&fabric);
            let governor = Arc::clone(&governor);
            let stop = Arc::clone(&stop);
            let conns = Arc::clone(&conns);
            let handlers = Arc::clone(&handlers);
            let pump = Arc::clone(&pump);
            let auth = Arc::clone(&auth);
            let max_frame = cfg.max_frame;
            std::thread::Builder::new()
                .name("empa-serve-accept".into())
                .spawn(move || {
                    accept_loop(
                        listener, fabric, governor, quota, stop, conns, handlers, pump, auth,
                        max_frame,
                    )
                })
                .context("spawn serve acceptor")?
        };

        Ok(ServePlane {
            fabric,
            governor,
            local_addr,
            stop,
            conns,
            threads: Mutex::new(vec![acceptor]),
            handlers,
            pump,
        })
    }

    /// The bound address (resolves the ephemeral port).
    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.local_addr
    }

    /// The fabric behind the listener.
    pub fn fabric(&self) -> &Arc<Fabric> {
        &self.fabric
    }

    /// Shared fabric metrics.
    pub fn metrics(&self) -> &FabricMetrics {
        &self.fabric.metrics
    }

    /// The SLO governor (its `render()` is the live playbook).
    pub fn governor(&self) -> &SloGovernor {
        &self.governor
    }

    /// Stop accepting, unblock and join every connection handler, then
    /// shut the fabric down (pending jobs complete first). Idempotent.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::Release);
        // Unblock handler reads: a blocking `read` on a shut-down socket
        // returns 0, which the codec reports as clean EOF.
        for s in self.conns.lock().unwrap().drain(..) {
            let _ = s.shutdown(Shutdown::Both);
        }
        for t in self.threads.lock().unwrap().drain(..) {
            let _ = t.join();
        }
        for t in self.handlers.lock().unwrap().drain(..) {
            let _ = t.join();
        }
        // With every handler joined nothing feeds the pump: close its
        // intake, resolve every parked job by shutting the fabric down,
        // then join the drained pump workers.
        self.pump.close_intake();
        self.fabric.shutdown();
        self.pump.join();
    }
}

/// How often the nonblocking acceptor polls the stop flag.
const ACCEPT_POLL: Duration = Duration::from_millis(5);

#[allow(clippy::too_many_arguments)]
fn accept_loop(
    listener: TcpListener,
    fabric: Arc<Fabric>,
    governor: Arc<SloGovernor>,
    quota: Arc<QuotaTable>,
    stop: Arc<AtomicBool>,
    conns: Arc<Mutex<Vec<TcpStream>>>,
    handlers: Arc<Mutex<Vec<JoinHandle<()>>>>,
    pump: Arc<CompletionPump>,
    auth: Arc<Option<String>>,
    max_frame: usize,
) {
    while !stop.load(Ordering::Acquire) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                // The listener is nonblocking; the accepted stream must
                // not inherit that — handlers read blocking.
                if stream.set_nonblocking(false).is_err() {
                    continue;
                }
                let Ok(registered) = stream.try_clone() else { continue };
                conns.lock().unwrap().push(registered);
                let fabric = Arc::clone(&fabric);
                let governor = Arc::clone(&governor);
                let quota = Arc::clone(&quota);
                let pump = Arc::clone(&pump);
                let auth = Arc::clone(&auth);
                let spawned = std::thread::Builder::new()
                    .name("empa-serve-conn".into())
                    .spawn(move || {
                        handle_conn(stream, fabric, governor, quota, pump, auth, max_frame)
                    });
                if let Ok(h) = spawned {
                    handlers.lock().unwrap().push(h);
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(_) => std::thread::sleep(ACCEPT_POLL),
        }
    }
}

/// Write one reply frame under the connection's write lock (completions
/// from different waiter threads interleave frame-atomically).
///
/// This is the serve plane's wire-site chaos injection point: every
/// reply is one `Site::Wire` decision. `ConnDrop` tears the connection
/// down instead of carrying the frame, `PartialWrite` emits the length
/// prefix plus half the payload and then drops (the peer sees a typed
/// `Truncated`, never a panic), `DelayedRead` stalls the write so the
/// peer's read arrives late (exercising client read timeouts/retries).
fn send_reply(
    out: &Mutex<TcpStream>,
    reply: &WireReply,
    max_frame: usize,
    chaos: Option<&WireChaos>,
) {
    use std::io::Write;
    let payload = wire::encode_reply(reply);
    let mut g = out.lock().unwrap();
    if let Some(cx) = chaos {
        match cx.engine.decide(crate::chaos::Site::Wire) {
            Some(crate::chaos::FaultKind::ConnDrop) => {
                cx.metrics.chaos_wire_faults.fetch_add(1, Ordering::Relaxed);
                let _ = g.shutdown(Shutdown::Both);
                return;
            }
            Some(crate::chaos::FaultKind::PartialWrite) => {
                cx.metrics.chaos_wire_faults.fetch_add(1, Ordering::Relaxed);
                let _ = g.write_all(&(payload.len() as u32).to_le_bytes());
                let _ = g.write_all(&payload[..payload.len() / 2]);
                let _ = g.flush();
                let _ = g.shutdown(Shutdown::Both);
                return;
            }
            Some(crate::chaos::FaultKind::DelayedRead { ms }) => {
                cx.metrics.chaos_wire_faults.fetch_add(1, Ordering::Relaxed);
                std::thread::sleep(Duration::from_millis(ms));
            }
            _ => {}
        }
    }
    let _ = wire::write_frame(&mut *g, &payload, max_frame);
}

/// One connection: read frames until EOF/error, run each request through
/// the admission stack, park accepted jobs in the completion pump. When
/// the serve plane requires an auth token, unauthenticated submits are
/// refused with a typed `Unauthorized` before any policy layer runs.
fn handle_conn(
    mut stream: TcpStream,
    fabric: Arc<Fabric>,
    governor: Arc<SloGovernor>,
    quota: Arc<QuotaTable>,
    pump: Arc<CompletionPump>,
    auth: Arc<Option<String>>,
    max_frame: usize,
) {
    let Ok(write_half) = stream.try_clone() else { return };
    let out = Arc::new(Mutex::new(write_half));
    let chaos = fabric
        .chaos()
        .map(|engine| WireChaos { engine, metrics: Arc::clone(&fabric.metrics) });
    loop {
        let payload = match wire::read_frame(&mut stream, max_frame) {
            Ok(Some(p)) => p,
            Ok(None) => return, // clean EOF
            Err(_) => return,   // transport error or oversized frame: drop the connection
        };
        let req = match wire::decode_request(&payload) {
            Ok(r) => r,
            Err(e) => {
                // Malformed payload: the stream itself still frames
                // correctly, so answer with a typed error (id 0 — the
                // real id may be part of what failed to decode) and
                // stop trusting the connection.
                let reply = WireReply::Failed {
                    id: 0,
                    error: FabricError::InvalidConfig(format!("bad request frame: {e}")),
                };
                send_reply(&out, &reply, max_frame, chaos.as_ref());
                return;
            }
        };
        match req {
            WireRequest::Metrics { id } => {
                let text = format!("{}\n{}", fabric.metrics.render(), governor.render());
                send_reply(&out, &WireReply::MetricsText { id, text }, max_frame, chaos.as_ref());
            }
            submit @ WireRequest::Submit { .. } => {
                let id = submit.id();
                let token = match &submit {
                    WireRequest::Submit { token, .. } => token.clone(),
                    _ => None,
                };
                let job_req = submit.into_job().expect("Submit carries a job");
                let tenant = job_req.client.clone();
                let metrics = &fabric.metrics;
                let tenant_stats = tenant.as_deref().map(|t| metrics.client(t));
                let now = Instant::now();

                // 0) Auth gate: a server started with a token refuses
                //    everything that doesn't present it, before policy.
                if let Some(expected) = &*auth {
                    if token.as_deref() != Some(expected.as_str()) {
                        metrics.unauthorized.fetch_add(1, Ordering::Relaxed);
                        if let Some(s) = &tenant_stats {
                            s.submitted.fetch_add(1, Ordering::Relaxed);
                            s.unauthorized.fetch_add(1, Ordering::Relaxed);
                        }
                        let error = FabricError::Unauthorized {
                            tenant: tenant.as_deref().unwrap_or("").to_string(),
                        };
                        send_reply(&out, &WireReply::Failed { id, error }, max_frame, chaos.as_ref());
                        continue;
                    }
                }

                // 1) SLO governor: policy shed before any queue.
                if let Some((rule, action)) = governor.decide(metrics, now) {
                    if action.refuses(job_req.priority) {
                        metrics.slo_shed.fetch_add(1, Ordering::Relaxed);
                        if let Some(s) = &tenant_stats {
                            s.submitted.fetch_add(1, Ordering::Relaxed);
                            s.shed.fetch_add(1, Ordering::Relaxed);
                        }
                        governor.note_shed(rule);
                        let error = FabricError::Overloaded { rule: rule.to_string() };
                        send_reply(&out, &WireReply::Failed { id, error }, max_frame, chaos.as_ref());
                        continue;
                    }
                }

                // 2) Token-bucket quota: the tenant's own budget.
                if !quota.admit(tenant.as_deref(), now) {
                    metrics.quota_denied.fetch_add(1, Ordering::Relaxed);
                    if let Some(s) = &tenant_stats {
                        s.submitted.fetch_add(1, Ordering::Relaxed);
                        s.quota_denied.fetch_add(1, Ordering::Relaxed);
                    }
                    let error = FabricError::QuotaExceeded {
                        tenant: tenant.as_deref().unwrap_or("").to_string(),
                    };
                    send_reply(&out, &WireReply::Failed { id, error }, max_frame, chaos.as_ref());
                    continue;
                }

                // 3) The fabric's own bounded admission. `try_submit`
                //    accounts per-tenant `submitted` on success; failures
                //    here still count toward the tenant's ledger.
                match fabric.try_submit(job_req) {
                    Ok(job) => {
                        // Park in the pump: it replies whenever the
                        // fabric resolves; the write lock orders frames.
                        pump.submit(PumpEntry {
                            id,
                            job,
                            out: Arc::clone(&out),
                            max_frame,
                            chaos: chaos.clone(),
                        });
                    }
                    Err(error) => {
                        if let Some(s) = &tenant_stats {
                            s.submitted.fetch_add(1, Ordering::Relaxed);
                        }
                        send_reply(&out, &WireReply::Failed { id, error }, max_frame, chaos.as_ref());
                    }
                }
            }
        }
    }
}
