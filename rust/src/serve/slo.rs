//! SLO threshold rules over [`FabricMetrics`], in the ops-playbook
//! shape: every rule is a literal **Source → Query → Threshold →
//! Interpretation → Action** row, evaluated by a small governor that
//! trips backpressure / load-shed decisions with hysteresis.
//!
//! The playbook rows are data, not prose: `source` names the metrics
//! surface the rule reads, `query` computes the observed value from a
//! windowed pair of snapshots, `threshold`/`clear_below` bound the trip
//! with hysteresis (no flapping at the boundary), `interpretation` says
//! what a trip *means*, and `action` is what the serve plane does about
//! it. [`SloGovernor::render`] prints the live table, so the running
//! system shows its own playbook.
//!
//! Actions are graduated to preserve the paper's real-time emphasis:
//! [`SloAction::Backpressure`] sheds only `Low` priority work,
//! [`SloAction::Shed`] sheds `Low` and `Normal` but keeps admitting
//! `High` — the jobs with deadlines worth protecting are the last to be
//! turned away.

use crate::api::Priority;
use crate::coordinator::FabricMetrics;
use std::sync::atomic::Ordering::Relaxed;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// What a tripped rule makes the serve plane do.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum SloAction {
    /// Refuse `Low`-priority requests (soft brake).
    Backpressure,
    /// Refuse `Low` and `Normal`; only `High` is still admitted.
    Shed,
}

impl SloAction {
    /// Whether a request at `p` is refused under this action.
    pub fn refuses(self, p: Priority) -> bool {
        match self {
            SloAction::Backpressure => p == Priority::Low,
            SloAction::Shed => p != Priority::High,
        }
    }

    fn name(self) -> &'static str {
        match self {
            SloAction::Backpressure => "backpressure",
            SloAction::Shed => "shed",
        }
    }
}

/// A windowed view of the fabric counters (monotonic totals; the
/// governor differences consecutive snapshots for rate-shaped queries).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SloSnapshot {
    pub queue_depth: u64,
    pub submitted: u64,
    pub completed: u64,
    pub errors: u64,
    pub cancelled: u64,
    pub deadline_missed: u64,
}

impl SloSnapshot {
    pub fn take(m: &FabricMetrics) -> SloSnapshot {
        SloSnapshot {
            queue_depth: m.total_queue_depth(),
            submitted: m.submitted.load(Relaxed),
            completed: m.completed.load(Relaxed),
            errors: m.errors.load(Relaxed),
            cancelled: m.cancelled.load(Relaxed),
            deadline_missed: m.deadline_missed.load(Relaxed),
        }
    }

    /// Jobs accepted but not yet resolved (gauge derived from totals).
    pub fn inflight(&self) -> u64 {
        self.submitted.saturating_sub(
            self.completed + self.errors + self.cancelled + self.deadline_missed,
        )
    }
}

/// One playbook row. `query(cur, prev)` computes the observed value —
/// gauge rules read `cur` alone, rate rules difference the pair.
pub struct SloRule {
    /// Short name, echoed in the wire error a shed request receives.
    pub name: &'static str,
    /// Which metrics surface the query reads (playbook: Source).
    pub source: &'static str,
    /// Observed value from (current, previous) snapshots (playbook: Query).
    pub query: fn(&SloSnapshot, &SloSnapshot) -> f64,
    /// Trips at `observed > threshold` (playbook: Threshold) ...
    pub threshold: f64,
    /// ... and clears only at `observed < clear_below` (hysteresis).
    pub clear_below: f64,
    /// What a trip means (playbook: Interpretation).
    pub interpretation: &'static str,
    /// What the serve plane does while tripped (playbook: Action).
    pub action: SloAction,
}

/// Serve-plane SLO policy: the rule set plus the evaluation cadence.
pub struct SloConfig {
    pub rules: Vec<SloRule>,
    /// Re-evaluate at most this often (`Duration::ZERO` = every
    /// decision, which deterministic tests use).
    pub eval_every: Duration,
}

impl SloConfig {
    /// The default playbook, scaled to the fabric's `queue_cap`.
    pub fn for_queue_cap(queue_cap: usize) -> SloConfig {
        let cap = queue_cap.max(1) as f64;
        SloConfig {
            rules: vec![
                SloRule {
                    name: "staged-backlog",
                    source: "dispatch-plane depth gauge",
                    query: |cur, _| cur.queue_depth as f64,
                    threshold: 0.75 * cap,
                    clear_below: 0.25 * cap,
                    interpretation: "sim lanes are saturating; queue latency is about to grow",
                    action: SloAction::Backpressure,
                },
                SloRule {
                    name: "inflight-ceiling",
                    source: "fabric totals (submitted - resolved)",
                    query: |cur, _| cur.inflight() as f64,
                    threshold: 4.0 * cap,
                    clear_below: 2.0 * cap,
                    interpretation: "accepted work far exceeds drain rate; the fabric is overloaded",
                    action: SloAction::Shed,
                },
                SloRule {
                    name: "deadline-miss-burst",
                    source: "windowed deadline_missed / submitted deltas",
                    query: |cur, prev| {
                        let missed = cur.deadline_missed.saturating_sub(prev.deadline_missed);
                        let subs = cur.submitted.saturating_sub(prev.submitted);
                        missed as f64 / subs.max(1) as f64
                    },
                    threshold: 0.2,
                    clear_below: 0.05,
                    interpretation: "deadlines are being missed in bulk; admitted work is already late",
                    action: SloAction::Shed,
                },
            ],
            eval_every: Duration::from_millis(50),
        }
    }
}

impl Default for SloConfig {
    fn default() -> Self {
        SloConfig::for_queue_cap(256)
    }
}

/// Per-rule live state.
struct RuleState {
    tripped: bool,
    /// clear → tripped transitions.
    trips: u64,
    /// Requests refused while this rule was the strongest active one.
    shed: u64,
    /// Last observed query value (rendered).
    observed: f64,
}

struct GovState {
    prev: SloSnapshot,
    cur: SloSnapshot,
    last_eval: Option<Instant>,
    rules: Vec<RuleState>,
    /// Cached decision between evaluations.
    active: Option<(usize, SloAction)>,
}

/// Evaluates the rule set against live metrics and answers "may this
/// request pass?". Evaluation is rate-limited by `eval_every`; between
/// evaluations the last decision is reused (admission stays O(1)).
pub struct SloGovernor {
    cfg: SloConfig,
    state: Mutex<GovState>,
}

impl SloGovernor {
    pub fn new(cfg: SloConfig) -> SloGovernor {
        let rules = cfg
            .rules
            .iter()
            .map(|_| RuleState { tripped: false, trips: 0, shed: 0, observed: 0.0 })
            .collect();
        SloGovernor {
            cfg,
            state: Mutex::new(GovState {
                prev: SloSnapshot::default(),
                cur: SloSnapshot::default(),
                last_eval: None,
                rules,
                active: None,
            }),
        }
    }

    /// The strongest currently-active action, with the rule that demands
    /// it. Re-evaluates at most every `eval_every`.
    pub fn decide(&self, metrics: &FabricMetrics, now: Instant) -> Option<(&'static str, SloAction)> {
        let mut g = self.state.lock().unwrap();
        let due = match g.last_eval {
            None => true,
            Some(t) => now.saturating_duration_since(t) >= self.cfg.eval_every,
        };
        if due {
            g.prev = g.cur;
            g.cur = SloSnapshot::take(metrics);
            g.last_eval = Some(now);
            let (prev, cur) = (g.prev, g.cur);
            let mut strongest: Option<(usize, SloAction)> = None;
            for (i, rule) in self.cfg.rules.iter().enumerate() {
                let v = (rule.query)(&cur, &prev);
                let st = &mut g.rules[i];
                st.observed = v;
                if st.tripped {
                    if v < rule.clear_below {
                        st.tripped = false;
                    }
                } else if v > rule.threshold {
                    st.tripped = true;
                    st.trips += 1;
                }
                let stronger = match strongest {
                    None => true,
                    Some((_, a)) => rule.action > a,
                };
                if st.tripped && stronger {
                    strongest = Some((i, rule.action));
                }
            }
            g.active = strongest;
        }
        g.active.map(|(i, a)| (self.cfg.rules[i].name, a))
    }

    /// Count a refusal against the rule that caused it.
    pub fn note_shed(&self, rule: &str) {
        let mut g = self.state.lock().unwrap();
        if let Some(i) = self.cfg.rules.iter().position(|r| r.name == rule) {
            g.rules[i].shed += 1;
        }
    }

    /// The live playbook: one Source → Query → Threshold →
    /// Interpretation → Action row per rule, plus its current state.
    pub fn render(&self) -> String {
        let g = self.state.lock().unwrap();
        let mut out = String::from("slo playbook:");
        for (i, r) in self.cfg.rules.iter().enumerate() {
            let st = &g.rules[i];
            out.push_str(&format!(
                "\n  rule {name}: source={source} | observed={obs:.3} threshold={thr:.3} clear={clr:.3} \
                 | action={act} | {state} trips={trips} shed={shed}\n    interpretation: {interp}",
                name = r.name,
                source = r.source,
                obs = st.observed,
                thr = r.threshold,
                clr = r.clear_below,
                act = r.action.name(),
                state = if st.tripped { "TRIPPED" } else { "clear" },
                trips = st.trips,
                shed = st.shed,
                interp = r.interpretation,
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gov(rules: Vec<SloRule>) -> SloGovernor {
        SloGovernor::new(SloConfig { rules, eval_every: Duration::ZERO })
    }

    fn depth_rule(threshold: f64, clear: f64, action: SloAction) -> SloRule {
        SloRule {
            name: "depth",
            source: "queue gauge",
            query: |cur, _| cur.queue_depth as f64,
            threshold,
            clear_below: clear,
            interpretation: "test",
            action,
        }
    }

    #[test]
    fn actions_grade_by_priority() {
        assert!(SloAction::Backpressure.refuses(Priority::Low));
        assert!(!SloAction::Backpressure.refuses(Priority::Normal));
        assert!(SloAction::Shed.refuses(Priority::Normal));
        assert!(!SloAction::Shed.refuses(Priority::High), "High survives even shed");
        assert!(SloAction::Shed > SloAction::Backpressure, "shed is the stronger action");
    }

    #[test]
    fn rule_trips_and_clears_with_hysteresis() {
        let g = gov(vec![depth_rule(10.0, 4.0, SloAction::Backpressure)]);
        let m = FabricMetrics::default();
        let t = Instant::now();
        assert_eq!(g.decide(&m, t), None);
        m.worker(0).depth.store(11, Relaxed);
        assert_eq!(g.decide(&m, t), Some(("depth", SloAction::Backpressure)));
        // Back under the threshold but above clear_below: still tripped.
        m.worker(0).depth.store(7, Relaxed);
        assert_eq!(g.decide(&m, t), Some(("depth", SloAction::Backpressure)));
        // Under clear_below: clears.
        m.worker(0).depth.store(3, Relaxed);
        assert_eq!(g.decide(&m, t), None);
        // One full trip/clear cycle → exactly one trip counted.
        assert!(g.render().contains("trips=1"), "{}", g.render());
    }

    #[test]
    fn strongest_action_wins() {
        let mut soft = depth_rule(5.0, 1.0, SloAction::Backpressure);
        soft.name = "soft";
        let mut hard = depth_rule(10.0, 2.0, SloAction::Shed);
        hard.name = "hard";
        let g = gov(vec![soft, hard]);
        let m = FabricMetrics::default();
        let t = Instant::now();
        m.worker(0).depth.store(7, Relaxed);
        assert_eq!(g.decide(&m, t), Some(("soft", SloAction::Backpressure)));
        m.worker(0).depth.store(20, Relaxed);
        assert_eq!(g.decide(&m, t), Some(("hard", SloAction::Shed)));
    }

    #[test]
    fn windowed_query_differences_snapshots() {
        let g = gov(SloConfig::for_queue_cap(4).rules);
        let m = FabricMetrics::default();
        let t = Instant::now();
        assert_eq!(g.decide(&m, t), None);
        // 10 submissions this window, 5 deadline misses: 50% miss rate.
        m.submitted.store(10, Relaxed);
        m.deadline_missed.store(5, Relaxed);
        let d = g.decide(&m, t);
        assert_eq!(d, Some(("deadline-miss-burst", SloAction::Shed)), "{d:?}");
        // Next window: no new misses — the rate rule clears. Completions
        // keep the inflight gauge under its own (4×cap) ceiling.
        m.submitted.store(30, Relaxed);
        m.completed.store(25, Relaxed);
        assert_eq!(g.decide(&m, t), None);
    }

    #[test]
    fn eval_rate_limit_caches_the_decision() {
        let g = SloGovernor::new(SloConfig {
            rules: vec![depth_rule(10.0, 4.0, SloAction::Shed)],
            eval_every: Duration::from_secs(3600),
        });
        let m = FabricMetrics::default();
        let t = Instant::now();
        assert_eq!(g.decide(&m, t), None);
        // Depth explodes, but the next eval is an hour away: cached None.
        m.worker(0).depth.store(100, Relaxed);
        assert_eq!(g.decide(&m, t + Duration::from_millis(1)), None);
        // Past the cadence the trip is observed.
        assert!(g.decide(&m, t + Duration::from_secs(3601)).is_some());
    }

    #[test]
    fn render_is_the_playbook() {
        let g = gov(SloConfig::for_queue_cap(8).rules);
        let r = g.render();
        for needle in
            ["slo playbook:", "staged-backlog", "inflight-ceiling", "deadline-miss-burst",
             "source=", "threshold=", "interpretation:", "action="]
        {
            assert!(r.contains(needle), "missing {needle:?} in:\n{r}");
        }
    }

    #[test]
    fn note_shed_counts_per_rule() {
        let g = gov(vec![depth_rule(-1.0, -2.0, SloAction::Shed)]);
        let m = FabricMetrics::default();
        let (name, _) = g.decide(&m, Instant::now()).expect("always-trip rule");
        g.note_shed(name);
        g.note_shed(name);
        g.note_shed("unknown-rule-is-ignored");
        assert!(g.render().contains("shed=2"), "{}", g.render());
    }
}
