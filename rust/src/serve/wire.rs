//! The serve plane's wire protocol: a hand-rolled, length-prefixed
//! binary frame codec.
//!
//! The only crate dependency is `anyhow`, so there is no serde/bincode —
//! every message is encoded by explicit little-endian writers and decoded
//! by a bounds-checked cursor that returns typed [`CodecError`]s and
//! **never panics**, whatever bytes arrive. The framing is:
//!
//! ```text
//! frame   := len:u32le  payload[len]
//! payload := version:u8 (= WIRE_VERSION)  tag:u8  body
//! ```
//!
//! `len` counts payload bytes only and is capped ([`MAX_FRAME`] by
//! default, configurable per endpoint): an oversized header is rejected
//! *before* any allocation, so a hostile length field cannot OOM the
//! server. Every variable-length field inside the payload re-checks its
//! claimed count against the bytes actually remaining for the same
//! reason.
//!
//! Requests map onto [`JobRequest`] (priority / deadline / client tag all
//! survive the trip); replies map onto [`Completion`] / [`FabricError`].
//! Frames carry a client-chosen `id` so replies can be pipelined and
//! matched out of order.

use crate::api::{Completion, FabricError, JobRequest, Output, Priority, RequestKind, Route};
use crate::workload::family::Family;
use crate::workload::sumup::Mode;
use crate::workload::traces::{TraceOp, TraceOpKind};
use std::io::{Read, Write};
use std::sync::Arc;
use std::time::Duration;

/// Protocol version stamped on (and checked in) every payload.
/// Version 2 added the optional shared-secret auth token on `Submit`
/// and the `Unauthorized` error code.
pub const WIRE_VERSION: u8 = 2;

/// Default hard cap on a frame's payload length (16 MiB).
pub const MAX_FRAME: usize = 16 << 20;

// ----------------------------------------------------------------------
// typed codec errors
// ----------------------------------------------------------------------

/// Typed decode/framing failure. Malformed input is an error value,
/// never a panic.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CodecError {
    /// The stream ended inside a frame (header or payload).
    Truncated { need: usize, have: usize },
    /// The frame header claims more payload than the cap allows.
    Oversized { len: usize, cap: usize },
    /// The payload's version byte is not [`WIRE_VERSION`].
    BadVersion { got: u8 },
    /// An enum tag byte (message/kind/mode/route/...) is out of range.
    BadTag { what: &'static str, got: u8 },
    /// A field claims more elements than the remaining bytes could hold.
    BadLength { what: &'static str, claimed: usize, available: usize },
    /// A string field is not valid UTF-8.
    BadUtf8 { what: &'static str },
    /// Bytes were left over after a complete message was decoded.
    TrailingBytes { extra: usize },
    /// Transport error underneath the codec.
    Io { kind: std::io::ErrorKind, msg: String },
}

impl std::fmt::Display for CodecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CodecError::Truncated { need, have } => {
                write!(f, "truncated frame: need {need} bytes, have {have}")
            }
            CodecError::Oversized { len, cap } => {
                write!(f, "oversized frame: {len} bytes exceeds the {cap}-byte cap")
            }
            CodecError::BadVersion { got } => {
                write!(f, "unsupported wire version {got} (this end speaks {WIRE_VERSION})")
            }
            CodecError::BadTag { what, got } => write!(f, "bad {what} tag 0x{got:02x}"),
            CodecError::BadLength { what, claimed, available } => {
                write!(f, "{what} claims {claimed} elements but only {available} bytes remain")
            }
            CodecError::BadUtf8 { what } => write!(f, "{what} is not valid UTF-8"),
            CodecError::TrailingBytes { extra } => {
                write!(f, "{extra} trailing bytes after a complete message")
            }
            CodecError::Io { kind, msg } => write!(f, "i/o ({kind:?}): {msg}"),
        }
    }
}

impl std::error::Error for CodecError {}

impl From<std::io::Error> for CodecError {
    fn from(e: std::io::Error) -> Self {
        CodecError::Io { kind: e.kind(), msg: e.to_string() }
    }
}

// ----------------------------------------------------------------------
// messages
// ----------------------------------------------------------------------

/// Client → server messages.
#[derive(Debug, Clone, PartialEq)]
pub enum WireRequest {
    /// Submit one job. `id` is chosen by the client and echoed on the
    /// reply; `deadline_us` is the relative deadline in microseconds.
    /// `token` is the optional shared-secret auth token: when the server
    /// was started with one, submits that don't present it come back as
    /// [`FabricError::Unauthorized`].
    Submit {
        id: u64,
        tenant: Option<String>,
        token: Option<String>,
        priority: Priority,
        deadline_us: Option<u64>,
        kind: RequestKind,
    },
    /// Ask for the server's rendered `FabricMetrics` (plus the SLO
    /// governor's playbook state) as text.
    Metrics { id: u64 },
}

impl WireRequest {
    /// Build a `Submit` from a typed [`JobRequest`] (the loadgen path:
    /// `TraceGen` emits `JobRequest`s, the wire carries them).
    pub fn submit(id: u64, req: &JobRequest) -> WireRequest {
        WireRequest::submit_with_token(id, req, None)
    }

    /// Build a `Submit` carrying a shared-secret auth token.
    pub fn submit_with_token(id: u64, req: &JobRequest, token: Option<&str>) -> WireRequest {
        WireRequest::Submit {
            id,
            tenant: req.client.as_deref().map(str::to_string),
            token: token.map(str::to_string),
            priority: req.priority,
            deadline_us: req.deadline.map(|d| d.as_micros() as u64),
            kind: req.kind.clone(),
        }
    }

    /// The typed [`JobRequest`] this `Submit` carries (server side).
    /// `None` for non-submit messages.
    pub fn into_job(self) -> Option<JobRequest> {
        let WireRequest::Submit { tenant, priority, deadline_us, kind, .. } = self else {
            return None;
        };
        let mut job = JobRequest::new(kind).with_priority(priority);
        if let Some(us) = deadline_us {
            job = job.with_deadline(Duration::from_micros(us));
        }
        if let Some(t) = tenant {
            job = job.with_client(t);
        }
        Some(job)
    }

    /// The client-chosen correlation id.
    pub fn id(&self) -> u64 {
        match self {
            WireRequest::Submit { id, .. } | WireRequest::Metrics { id } => *id,
        }
    }
}

/// Server → client messages.
#[derive(Debug, Clone, PartialEq)]
pub enum WireReply {
    /// The job completed; the full [`Completion`] metadata survives the
    /// trip (latencies at microsecond precision).
    Completed { id: u64, completion: Completion },
    /// The job failed (admission, quota, shed, or execution) with its
    /// typed [`FabricError`].
    Failed { id: u64, error: FabricError },
    /// Answer to [`WireRequest::Metrics`].
    MetricsText { id: u64, text: String },
}

impl WireReply {
    /// The correlation id this reply answers.
    pub fn id(&self) -> u64 {
        match self {
            WireReply::Completed { id, .. }
            | WireReply::Failed { id, .. }
            | WireReply::MetricsText { id, .. } => *id,
        }
    }
}

// message tags
const TAG_SUBMIT: u8 = 0x01;
const TAG_METRICS: u8 = 0x02;
const TAG_COMPLETED: u8 = 0x81;
const TAG_FAILED: u8 = 0x82;
const TAG_METRICS_TEXT: u8 = 0x83;

// ----------------------------------------------------------------------
// framing
// ----------------------------------------------------------------------

/// Read one frame's payload. `Ok(None)` is a clean end-of-stream at a
/// frame boundary; inside a frame the same condition is
/// [`CodecError::Truncated`]. An over-cap header is rejected before any
/// payload allocation.
pub fn read_frame(r: &mut impl Read, cap: usize) -> Result<Option<Vec<u8>>, CodecError> {
    let mut len_buf = [0u8; 4];
    match read_full(r, &mut len_buf)? {
        0 => return Ok(None),
        4 => {}
        have => return Err(CodecError::Truncated { need: 4, have }),
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > cap {
        return Err(CodecError::Oversized { len, cap });
    }
    let mut payload = vec![0u8; len];
    let have = read_full(r, &mut payload)?;
    if have < len {
        return Err(CodecError::Truncated { need: len, have });
    }
    Ok(Some(payload))
}

/// Write one frame (length prefix + payload). The cap is enforced on the
/// sending side too, so a peer speaking the same config never sees an
/// oversized frame arrive.
pub fn write_frame(w: &mut impl Write, payload: &[u8], cap: usize) -> Result<(), CodecError> {
    if payload.len() > cap {
        return Err(CodecError::Oversized { len: payload.len(), cap });
    }
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Read until `buf` is full or EOF; returns bytes read. `Interrupted` is
/// retried, any other error propagates.
fn read_full(r: &mut impl Read, buf: &mut [u8]) -> Result<usize, CodecError> {
    let mut got = 0;
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) => break,
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e.into()),
        }
    }
    Ok(got)
}

// ----------------------------------------------------------------------
// encode
// ----------------------------------------------------------------------

struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    fn new(tag: u8) -> Enc {
        Enc { buf: vec![WIRE_VERSION, tag] }
    }
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn i32(&mut self, v: i32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }
    fn opt_str(&mut self, s: Option<&str>) {
        match s {
            None => self.u8(0),
            Some(s) => {
                self.u8(1);
                self.str(s);
            }
        }
    }
    fn f32s(&mut self, v: &[f32]) {
        self.u32(v.len() as u32);
        for x in v {
            self.f32(*x);
        }
    }
    fn i32s(&mut self, v: &[i32]) {
        self.u32(v.len() as u32);
        for x in v {
            self.i32(*x);
        }
    }
}

fn priority_tag(p: Priority) -> u8 {
    match p {
        Priority::Low => 0,
        Priority::Normal => 1,
        Priority::High => 2,
    }
}

fn mode_tag(m: Mode) -> u8 {
    match m {
        Mode::No => 0,
        Mode::For => 1,
        Mode::Sumup => 2,
    }
}

fn family_tag(f: Family) -> u8 {
    match f {
        Family::Sumup => 0,
        Family::Dotprod => 1,
        Family::Scale => 2,
        Family::Traces => 3,
    }
}

fn route_tag(r: Route) -> u8 {
    match r {
        Route::Simulator => 0,
        Route::Inline => 1,
        Route::Accelerator => 2,
        Route::Split => 3,
    }
}

// request-kind tags
const KIND_MASS_SUM: u8 = 0x01;
const KIND_MASS_DOT: u8 = 0x02;
const KIND_SUMUP: u8 = 0x03;
const KIND_DOTPROD: u8 = 0x04;
const KIND_SCALE: u8 = 0x05;
const KIND_TRACES: u8 = 0x06;

fn encode_kind(e: &mut Enc, kind: &RequestKind) {
    use crate::workload::family::Params;
    match kind {
        RequestKind::MassSum { values } => {
            e.u8(KIND_MASS_SUM);
            e.f32s(values);
        }
        RequestKind::MassDot { a, b } => {
            e.u8(KIND_MASS_DOT);
            e.f32s(a);
            e.f32s(b);
        }
        RequestKind::RunProgram { mode, params, .. } => match params {
            Params::Sumup { values } => {
                e.u8(KIND_SUMUP);
                e.u8(mode_tag(*mode));
                e.i32s(values);
            }
            Params::Dotprod { a, b } => {
                e.u8(KIND_DOTPROD);
                e.u8(mode_tag(*mode));
                e.i32s(a);
                e.i32s(b);
            }
            Params::Scale { x, c } => {
                e.u8(KIND_SCALE);
                e.u8(mode_tag(*mode));
                e.i32s(x);
                e.i32(*c);
            }
            Params::Traces { ops } => {
                e.u8(KIND_TRACES);
                e.u32(ops.len() as u32);
                for op in ops {
                    e.u8(match op.kind {
                        TraceOpKind::Add => 0,
                        TraceOpKind::Sub => 1,
                        TraceOpKind::Xor => 2,
                    });
                    e.i32(op.value);
                }
            }
        },
    }
}

// fabric-error codes
const ERR_QUEUE_FULL: u8 = 1;
const ERR_DEADLINE: u8 = 2;
const ERR_CANCELLED: u8 = 3;
const ERR_SHAPE: u8 = 4;
const ERR_UNSUPPORTED_MODE: u8 = 5;
const ERR_FAMILY_MISMATCH: u8 = 6;
const ERR_INVALID_CONFIG: u8 = 7;
const ERR_GUEST_FAULT: u8 = 8;
const ERR_BACKEND: u8 = 9;
const ERR_SHUTDOWN: u8 = 10;
const ERR_QUOTA: u8 = 11;
const ERR_OVERLOADED: u8 = 12;
const ERR_UNAUTHORIZED: u8 = 13;

fn encode_error(e: &mut Enc, err: &FabricError) {
    match err {
        FabricError::QueueFull => e.u8(ERR_QUEUE_FULL),
        FabricError::DeadlineExceeded => e.u8(ERR_DEADLINE),
        FabricError::Cancelled => e.u8(ERR_CANCELLED),
        FabricError::ShapeMismatch { a, b } => {
            e.u8(ERR_SHAPE);
            e.u64(*a as u64);
            e.u64(*b as u64);
        }
        FabricError::UnsupportedMode { family, mode } => {
            e.u8(ERR_UNSUPPORTED_MODE);
            e.u8(family_tag(*family));
            e.u8(mode_tag(*mode));
        }
        FabricError::FamilyMismatch { family, params } => {
            e.u8(ERR_FAMILY_MISMATCH);
            e.u8(family_tag(*family));
            e.u8(family_tag(*params));
        }
        FabricError::InvalidConfig(m) => {
            e.u8(ERR_INVALID_CONFIG);
            e.str(m);
        }
        FabricError::GuestFault(m) => {
            e.u8(ERR_GUEST_FAULT);
            e.str(m);
        }
        FabricError::Backend { name, msg } => {
            e.u8(ERR_BACKEND);
            e.str(name);
            e.str(msg);
        }
        FabricError::Shutdown => e.u8(ERR_SHUTDOWN),
        FabricError::QuotaExceeded { tenant } => {
            e.u8(ERR_QUOTA);
            e.str(tenant);
        }
        FabricError::Overloaded { rule } => {
            e.u8(ERR_OVERLOADED);
            e.str(rule);
        }
        FabricError::Unauthorized { tenant } => {
            e.u8(ERR_UNAUTHORIZED);
            e.str(tenant);
        }
    }
}

fn encode_output(e: &mut Enc, out: &Output) {
    match out {
        Output::Program { eax, clocks, cores, data } => {
            e.u8(0);
            e.i32(*eax);
            e.u64(*clocks);
            e.u64(*cores as u64);
            e.i32s(data);
        }
        Output::Scalars(v) => {
            e.u8(1);
            e.f32s(v);
        }
        Output::Rows(rows) => {
            e.u8(2);
            e.u32(rows.len() as u32);
            for r in rows {
                e.f32s(r);
            }
        }
    }
}

/// Encode a request message's payload (no length prefix; pair with
/// [`write_frame`]).
pub fn encode_request(req: &WireRequest) -> Vec<u8> {
    match req {
        WireRequest::Submit { id, tenant, token, priority, deadline_us, kind } => {
            let mut e = Enc::new(TAG_SUBMIT);
            e.u64(*id);
            e.opt_str(tenant.as_deref());
            e.opt_str(token.as_deref());
            e.u8(priority_tag(*priority));
            match deadline_us {
                None => e.u8(0),
                Some(us) => {
                    e.u8(1);
                    e.u64(*us);
                }
            }
            encode_kind(&mut e, kind);
            e.buf
        }
        WireRequest::Metrics { id } => {
            let mut e = Enc::new(TAG_METRICS);
            e.u64(*id);
            e.buf
        }
    }
}

/// Encode a reply message's payload.
pub fn encode_reply(rep: &WireReply) -> Vec<u8> {
    match rep {
        WireReply::Completed { id, completion } => {
            let mut e = Enc::new(TAG_COMPLETED);
            e.u64(*id);
            encode_output(&mut e, &completion.output);
            e.u8(route_tag(completion.route));
            e.str(&completion.backend);
            e.u64(completion.batch_rows as u64);
            e.u64(completion.shards as u64);
            e.u64(completion.queue_latency.as_micros() as u64);
            e.u64(completion.latency.as_micros() as u64);
            e.buf
        }
        WireReply::Failed { id, error } => {
            let mut e = Enc::new(TAG_FAILED);
            e.u64(*id);
            encode_error(&mut e, error);
            e.buf
        }
        WireReply::MetricsText { id, text } => {
            let mut e = Enc::new(TAG_METRICS_TEXT);
            e.u64(*id);
            e.str(text);
            e.buf
        }
    }
}

// ----------------------------------------------------------------------
// decode
// ----------------------------------------------------------------------

/// Bounds-checked little-endian cursor. Every read returns a typed error
/// instead of slicing out of range.
struct Cur<'a> {
    b: &'a [u8],
    off: usize,
}

impl<'a> Cur<'a> {
    fn new(b: &'a [u8]) -> Cur<'a> {
        Cur { b, off: 0 }
    }

    fn remaining(&self) -> usize {
        self.b.len() - self.off
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], CodecError> {
        if self.remaining() < n {
            return Err(CodecError::Truncated { need: n, have: self.remaining() });
        }
        let s = &self.b[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, CodecError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, CodecError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4-byte slice")))
    }

    fn u64(&mut self) -> Result<u64, CodecError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8-byte slice")))
    }

    fn i32(&mut self) -> Result<i32, CodecError> {
        Ok(i32::from_le_bytes(self.take(4)?.try_into().expect("4-byte slice")))
    }

    fn f32(&mut self) -> Result<f32, CodecError> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().expect("4-byte slice")))
    }

    /// A claimed element count, validated against the bytes remaining
    /// (`elem_size` each) *before* anything is allocated.
    fn count(&mut self, what: &'static str, elem_size: usize) -> Result<usize, CodecError> {
        let n = self.u32()? as usize;
        let need = n.checked_mul(elem_size).unwrap_or(usize::MAX);
        if need > self.remaining() {
            return Err(CodecError::BadLength { what, claimed: n, available: self.remaining() });
        }
        Ok(n)
    }

    fn str(&mut self, what: &'static str) -> Result<String, CodecError> {
        let n = self.count(what, 1)?;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| CodecError::BadUtf8 { what })
    }

    fn opt_str(&mut self, what: &'static str) -> Result<Option<String>, CodecError> {
        match self.u8()? {
            0 => Ok(None),
            1 => Ok(Some(self.str(what)?)),
            got => Err(CodecError::BadTag { what: "option", got }),
        }
    }

    fn f32s(&mut self, what: &'static str) -> Result<Vec<f32>, CodecError> {
        let n = self.count(what, 4)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.f32()?);
        }
        Ok(v)
    }

    fn i32s(&mut self, what: &'static str) -> Result<Vec<i32>, CodecError> {
        let n = self.count(what, 4)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.i32()?);
        }
        Ok(v)
    }

    fn finish(&self) -> Result<(), CodecError> {
        if self.remaining() > 0 {
            return Err(CodecError::TrailingBytes { extra: self.remaining() });
        }
        Ok(())
    }
}

fn decode_priority(c: &mut Cur) -> Result<Priority, CodecError> {
    match c.u8()? {
        0 => Ok(Priority::Low),
        1 => Ok(Priority::Normal),
        2 => Ok(Priority::High),
        got => Err(CodecError::BadTag { what: "priority", got }),
    }
}

fn decode_mode(c: &mut Cur) -> Result<Mode, CodecError> {
    match c.u8()? {
        0 => Ok(Mode::No),
        1 => Ok(Mode::For),
        2 => Ok(Mode::Sumup),
        got => Err(CodecError::BadTag { what: "mode", got }),
    }
}

fn decode_family(b: u8) -> Result<Family, CodecError> {
    match b {
        0 => Ok(Family::Sumup),
        1 => Ok(Family::Dotprod),
        2 => Ok(Family::Scale),
        3 => Ok(Family::Traces),
        got => Err(CodecError::BadTag { what: "family", got }),
    }
}

fn decode_route(c: &mut Cur) -> Result<Route, CodecError> {
    match c.u8()? {
        0 => Ok(Route::Simulator),
        1 => Ok(Route::Inline),
        2 => Ok(Route::Accelerator),
        3 => Ok(Route::Split),
        got => Err(CodecError::BadTag { what: "route", got }),
    }
}

fn decode_kind(c: &mut Cur) -> Result<RequestKind, CodecError> {
    match c.u8()? {
        KIND_MASS_SUM => Ok(RequestKind::mass_sum(c.f32s("mass-sum values")?)),
        KIND_MASS_DOT => {
            let a = c.f32s("mass-dot a")?;
            let b = c.f32s("mass-dot b")?;
            Ok(RequestKind::mass_dot(a, b))
        }
        KIND_SUMUP => {
            let mode = decode_mode(c)?;
            Ok(RequestKind::sumup(mode, c.i32s("sumup values")?))
        }
        KIND_DOTPROD => {
            let mode = decode_mode(c)?;
            let a = c.i32s("dotprod a")?;
            let b = c.i32s("dotprod b")?;
            Ok(RequestKind::dotprod(mode, a, b))
        }
        KIND_SCALE => {
            let mode = decode_mode(c)?;
            let x = c.i32s("scale x")?;
            let k = c.i32()?;
            Ok(RequestKind::scale(mode, x, k))
        }
        KIND_TRACES => {
            let n = c.count("trace ops", 5)?;
            let mut ops = Vec::with_capacity(n);
            for _ in 0..n {
                let kind = match c.u8()? {
                    0 => TraceOpKind::Add,
                    1 => TraceOpKind::Sub,
                    2 => TraceOpKind::Xor,
                    got => return Err(CodecError::BadTag { what: "trace op", got }),
                };
                ops.push(TraceOp::new(kind, c.i32()?));
            }
            Ok(RequestKind::traces(ops))
        }
        got => Err(CodecError::BadTag { what: "request kind", got }),
    }
}

fn decode_error(c: &mut Cur) -> Result<FabricError, CodecError> {
    match c.u8()? {
        ERR_QUEUE_FULL => Ok(FabricError::QueueFull),
        ERR_DEADLINE => Ok(FabricError::DeadlineExceeded),
        ERR_CANCELLED => Ok(FabricError::Cancelled),
        ERR_SHAPE => {
            let a = c.u64()? as usize;
            let b = c.u64()? as usize;
            Ok(FabricError::ShapeMismatch { a, b })
        }
        ERR_UNSUPPORTED_MODE => {
            let family = decode_family(c.u8()?)?;
            let mode = decode_mode(c)?;
            Ok(FabricError::UnsupportedMode { family, mode })
        }
        ERR_FAMILY_MISMATCH => {
            let family = decode_family(c.u8()?)?;
            let params = decode_family(c.u8()?)?;
            Ok(FabricError::FamilyMismatch { family, params })
        }
        ERR_INVALID_CONFIG => Ok(FabricError::InvalidConfig(c.str("invalid-config msg")?)),
        ERR_GUEST_FAULT => Ok(FabricError::GuestFault(c.str("guest-fault msg")?)),
        ERR_BACKEND => {
            let name = c.str("backend name")?;
            let msg = c.str("backend msg")?;
            Ok(FabricError::Backend { name, msg })
        }
        ERR_SHUTDOWN => Ok(FabricError::Shutdown),
        ERR_QUOTA => Ok(FabricError::QuotaExceeded { tenant: c.str("quota tenant")? }),
        ERR_OVERLOADED => Ok(FabricError::Overloaded { rule: c.str("slo rule")? }),
        ERR_UNAUTHORIZED => Ok(FabricError::Unauthorized { tenant: c.str("auth tenant")? }),
        got => Err(CodecError::BadTag { what: "error code", got }),
    }
}

fn decode_output(c: &mut Cur) -> Result<Output, CodecError> {
    match c.u8()? {
        0 => {
            let eax = c.i32()?;
            let clocks = c.u64()?;
            let cores = c.u64()? as usize;
            let data = c.i32s("program data")?;
            Ok(Output::Program { eax, clocks, cores, data })
        }
        1 => Ok(Output::Scalars(c.f32s("scalars")?.into())),
        2 => {
            let n = c.count("rows", 4)?;
            let mut rows: Vec<Arc<[f32]>> = Vec::with_capacity(n);
            for _ in 0..n {
                rows.push(c.f32s("row")?.into());
            }
            Ok(Output::Rows(rows))
        }
        got => Err(CodecError::BadTag { what: "output", got }),
    }
}

/// Check the version byte and return the message tag.
fn header(c: &mut Cur) -> Result<u8, CodecError> {
    let version = c.u8()?;
    if version != WIRE_VERSION {
        return Err(CodecError::BadVersion { got: version });
    }
    c.u8()
}

/// Decode a request payload (as produced by [`encode_request`]).
pub fn decode_request(payload: &[u8]) -> Result<WireRequest, CodecError> {
    let mut c = Cur::new(payload);
    let msg = match header(&mut c)? {
        TAG_SUBMIT => {
            let id = c.u64()?;
            let tenant = c.opt_str("tenant")?;
            let token = c.opt_str("token")?;
            let priority = decode_priority(&mut c)?;
            let deadline_us = match c.u8()? {
                0 => None,
                1 => Some(c.u64()?),
                got => return Err(CodecError::BadTag { what: "deadline option", got }),
            };
            let kind = decode_kind(&mut c)?;
            WireRequest::Submit { id, tenant, token, priority, deadline_us, kind }
        }
        TAG_METRICS => WireRequest::Metrics { id: c.u64()? },
        got => return Err(CodecError::BadTag { what: "request message", got }),
    };
    c.finish()?;
    Ok(msg)
}

/// Decode a reply payload (as produced by [`encode_reply`]).
pub fn decode_reply(payload: &[u8]) -> Result<WireReply, CodecError> {
    let mut c = Cur::new(payload);
    let msg = match header(&mut c)? {
        TAG_COMPLETED => {
            let id = c.u64()?;
            let output = decode_output(&mut c)?;
            let route = decode_route(&mut c)?;
            let backend = c.str("backend")?;
            let batch_rows = c.u64()? as usize;
            let shards = c.u64()? as usize;
            let queue_latency = Duration::from_micros(c.u64()?);
            let latency = Duration::from_micros(c.u64()?);
            WireReply::Completed {
                id,
                completion: Completion {
                    output,
                    route,
                    backend,
                    batch_rows,
                    shards,
                    queue_latency,
                    latency,
                },
            }
        }
        TAG_FAILED => {
            let id = c.u64()?;
            WireReply::Failed { id, error: decode_error(&mut c)? }
        }
        TAG_METRICS_TEXT => {
            let id = c.u64()?;
            WireReply::MetricsText { id, text: c.str("metrics text")? }
        }
        got => return Err(CodecError::BadTag { what: "reply message", got }),
    };
    c.finish()?;
    Ok(msg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn submit_round_trips_through_job_request() {
        let req = JobRequest::new(RequestKind::mass_sum(vec![1.0, 2.5]))
            .with_priority(Priority::High)
            .with_deadline(Duration::from_micros(1500))
            .with_client("tenant-a");
        let wire = WireRequest::submit(9, &req);
        let decoded = decode_request(&encode_request(&wire)).unwrap();
        assert_eq!(decoded, wire);
        assert_eq!(decoded.id(), 9);
        let job = decoded.into_job().unwrap();
        assert_eq!(job, req);
    }

    #[test]
    fn submit_token_survives_the_round_trip() {
        let req = JobRequest::new(RequestKind::mass_sum(vec![1.0])).with_client("tenant-a");
        let wire = WireRequest::submit_with_token(3, &req, Some("s3cret"));
        let decoded = decode_request(&encode_request(&wire)).unwrap();
        assert_eq!(decoded, wire);
        let WireRequest::Submit { token, .. } = decoded else { panic!("not a submit") };
        assert_eq!(token.as_deref(), Some("s3cret"));
    }

    #[test]
    fn frame_cap_is_enforced_on_both_sides() {
        let mut out = Vec::new();
        let err = write_frame(&mut out, &[0u8; 64], 16).unwrap_err();
        assert_eq!(err, CodecError::Oversized { len: 64, cap: 16 });
        // hostile header: huge claimed length, no allocation
        let mut hdr = Vec::new();
        hdr.extend_from_slice(&u32::MAX.to_le_bytes());
        let err = read_frame(&mut hdr.as_slice(), 1024).unwrap_err();
        assert_eq!(err, CodecError::Oversized { len: u32::MAX as usize, cap: 1024 });
    }

    #[test]
    fn clean_eof_is_none_but_mid_frame_eof_is_truncated() {
        assert_eq!(read_frame(&mut (&[][..]), MAX_FRAME).unwrap(), None);
        // 2 of 4 header bytes
        let err = read_frame(&mut (&[1u8, 0][..]), MAX_FRAME).unwrap_err();
        assert_eq!(err, CodecError::Truncated { need: 4, have: 2 });
        // full header, short payload
        let mut b = Vec::new();
        b.extend_from_slice(&8u32.to_le_bytes());
        b.extend_from_slice(&[1, 2, 3]);
        let err = read_frame(&mut b.as_slice(), MAX_FRAME).unwrap_err();
        assert_eq!(err, CodecError::Truncated { need: 8, have: 3 });
    }

    #[test]
    fn bad_version_is_typed() {
        let mut p = encode_request(&WireRequest::Metrics { id: 1 });
        p[0] = 9;
        assert_eq!(decode_request(&p).unwrap_err(), CodecError::BadVersion { got: 9 });
    }

    #[test]
    fn hostile_counts_cannot_allocate() {
        // a Submit whose vector claims u32::MAX floats
        let mut e = Enc::new(TAG_SUBMIT);
        e.u64(1);
        e.u8(0); // no tenant
        e.u8(0); // no token
        e.u8(1); // Normal
        e.u8(0); // no deadline
        e.u8(KIND_MASS_SUM);
        e.u32(u32::MAX);
        let err = decode_request(&e.buf).unwrap_err();
        assert!(
            matches!(err, CodecError::BadLength { what: "mass-sum values", .. }),
            "{err:?}"
        );
    }

    #[test]
    fn trailing_bytes_are_rejected() {
        let mut p = encode_request(&WireRequest::Metrics { id: 1 });
        p.push(0xaa);
        assert_eq!(decode_request(&p).unwrap_err(), CodecError::TrailingBytes { extra: 1 });
    }
}
