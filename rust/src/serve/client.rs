//! A minimal blocking client for the serve plane's wire protocol —
//! used by the `loadgen` binary, the `fabric_serve` example, and the
//! loopback integration tests.
//!
//! The client is deliberately dumb: it frames and unframes, nothing
//! more. Correlation is by the caller-visible request id ([`WireClient`]
//! assigns them monotonically), so a caller can pipeline submissions on
//! one socket and match replies out of order — or use [`WireClient::call`]
//! for the simple submit-and-wait shape.

use super::wire::{
    decode_reply, encode_request, read_frame, write_frame, WireReply, WireRequest, MAX_FRAME,
};
use crate::api::{JobRequest, JobResult, RetryPolicy};
use anyhow::{bail, Context};
use std::net::TcpStream;

/// Blocking wire-protocol client over one TCP connection.
pub struct WireClient {
    stream: TcpStream,
    /// Resolved peer address, kept so [`WireClient::call_with_retry`]
    /// can reconnect after a dropped connection.
    addr: std::net::SocketAddr,
    next_id: u64,
    max_frame: usize,
    /// Shared-secret auth token stamped onto every submit.
    token: Option<String>,
}

impl WireClient {
    /// Connect to a serve plane.
    pub fn connect(addr: impl std::net::ToSocketAddrs + std::fmt::Debug) -> anyhow::Result<WireClient> {
        let stream =
            TcpStream::connect(&addr).with_context(|| format!("connect to serve plane {addr:?}"))?;
        let addr = stream.peer_addr().context("serve plane peer addr")?;
        Ok(WireClient { stream, addr, next_id: 0, max_frame: MAX_FRAME, token: None })
    }

    /// Override the frame cap (must match the server's to be useful).
    pub fn with_max_frame(mut self, cap: usize) -> WireClient {
        self.max_frame = cap;
        self
    }

    /// Present a shared-secret auth token on every submit (required when
    /// the server was started with one).
    pub fn with_token(mut self, token: impl Into<String>) -> WireClient {
        self.token = Some(token.into());
        self
    }

    /// A second handle on the same socket (shared kernel stream). The
    /// intended split: one side only writes (submit), the other only
    /// reads (recv) — e.g. loadgen's per-tenant sender/receiver pair.
    pub fn try_clone(&self) -> anyhow::Result<WireClient> {
        Ok(WireClient {
            stream: self.stream.try_clone().context("clone wire stream")?,
            addr: self.addr,
            next_id: self.next_id,
            max_frame: self.max_frame,
            token: self.token.clone(),
        })
    }

    /// Submit one job; returns the request id its reply will carry.
    pub fn submit(&mut self, req: &JobRequest) -> anyhow::Result<u64> {
        self.next_id += 1;
        let id = self.next_id;
        let payload =
            encode_request(&WireRequest::submit_with_token(id, req, self.token.as_deref()));
        write_frame(&mut self.stream, &payload, self.max_frame).context("write submit frame")?;
        Ok(id)
    }

    /// Read the next reply frame. `Ok(None)` means the server closed the
    /// connection cleanly.
    pub fn recv(&mut self) -> anyhow::Result<Option<WireReply>> {
        match read_frame(&mut self.stream, self.max_frame).context("read reply frame")? {
            None => Ok(None),
            Some(p) => Ok(Some(decode_reply(&p).context("decode reply")?)),
        }
    }

    /// Submit one job and block for *its* reply (single-in-flight use;
    /// replies to other outstanding ids would be misordered — pipeline
    /// with [`WireClient::submit`]/[`WireClient::recv`] instead).
    pub fn call(&mut self, req: &JobRequest) -> anyhow::Result<JobResult> {
        let id = self.submit(req)?;
        loop {
            let Some(reply) = self.recv()? else {
                bail!("server closed the connection before replying to request {id}")
            };
            match reply {
                WireReply::Completed { id: rid, completion } if rid == id => {
                    return Ok(Ok(completion))
                }
                WireReply::Failed { id: rid, error } if rid == id => return Ok(Err(error)),
                WireReply::MetricsText { .. } => bail!("unexpected metrics reply to a submit"),
                other => bail!("reply for id {} while waiting for {id}", other.id()),
            }
        }
    }

    /// [`WireClient::call`] with typed retry/backoff and transparent
    /// reconnection — the client half of the chaos story. Two failure
    /// classes are retried, up to the policy's attempt budget and with
    /// its capped exponential backoff between attempts:
    ///
    /// - **transport faults** (connection dropped mid-frame, partial
    ///   frame, refused write): the client reconnects to the same peer
    ///   and resubmits — a job orphaned on the old connection still runs
    ///   to completion server-side (the pump reaps its reply into a dead
    ///   socket);
    /// - **typed retryable errors** ([`FabricError::retryable`]:
    ///   queue-full, backend, quota, overloaded) carried in a `Failed`
    ///   reply.
    ///
    /// Terminal typed errors return immediately; transport faults with
    /// no attempts left surface as the underlying `anyhow` error.
    ///
    /// [`FabricError::retryable`]: crate::api::FabricError::retryable
    pub fn call_with_retry(
        &mut self,
        req: &JobRequest,
        policy: &RetryPolicy,
    ) -> anyhow::Result<JobResult> {
        let mut attempt = 1u32;
        loop {
            match self.call(req) {
                Ok(Ok(completion)) => return Ok(Ok(completion)),
                Ok(Err(e)) if e.retryable() && attempt < policy.max_attempts => {
                    std::thread::sleep(policy.backoff(attempt));
                    attempt += 1;
                }
                Ok(Err(e)) => return Ok(Err(e)),
                Err(transport) if attempt < policy.max_attempts => {
                    std::thread::sleep(policy.backoff(attempt));
                    attempt += 1;
                    self.reconnect().with_context(|| {
                        format!("reconnect after transport fault: {transport:#}")
                    })?;
                }
                Err(transport) => return Err(transport),
            }
        }
    }

    /// Replace the connection with a fresh one to the same peer. Request
    /// ids stay monotonic across reconnects, so late replies from an old
    /// connection can never be confused with new ones.
    pub fn reconnect(&mut self) -> anyhow::Result<()> {
        self.stream = TcpStream::connect(self.addr)
            .with_context(|| format!("reconnect to serve plane {}", self.addr))?;
        Ok(())
    }

    /// Fetch the server's rendered metrics + SLO playbook.
    pub fn metrics(&mut self) -> anyhow::Result<String> {
        self.next_id += 1;
        let id = self.next_id;
        let payload = encode_request(&WireRequest::Metrics { id });
        write_frame(&mut self.stream, &payload, self.max_frame).context("write metrics frame")?;
        loop {
            let Some(reply) = self.recv()? else {
                bail!("server closed the connection before the metrics reply")
            };
            match reply {
                WireReply::MetricsText { id: rid, text } if rid == id => return Ok(text),
                // A straggling completion from earlier pipelined work is
                // not an error here; skip it.
                WireReply::Completed { .. } | WireReply::Failed { .. } => continue,
                other => bail!("mismatched metrics reply id {}", other.id()),
            }
        }
    }
}
