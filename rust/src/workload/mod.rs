//! Workload generators: the paper's program families (§5) — `sumup`,
//! `dotprod`, `scale` and the `traces` replay interpreter — unified
//! behind the [`family::WorkloadFamily`] trait (code template + data
//! image + oracle, the compile-once split), plus synthetic request
//! traces for the fabric coordinator.
//!
//! Workloads *generate* [`crate::api::JobRequest`]s; the request and
//! response vocabulary itself belongs to the `api` module
//! (`RequestKind` is re-exported here for convenience).

pub mod dotprod;
pub mod family;
pub mod scale;
pub mod sumup;
pub mod traces;

pub use crate::api::RequestKind;
pub use family::{family_impl, Expected, Family, Params, WorkloadFamily, ALL_FAMILIES};
pub use sumup::{for_mode_program, no_mode_program, sumup_mode_program, Mode};
pub use traces::{Request, TraceConfig, TraceGen, TraceOp, TraceOpKind};
