//! Workload generators: the paper's `asumup` program family (§5) in all
//! three modes, plus synthetic request traces for the fabric coordinator.
//!
//! Workloads *generate* [`crate::api::JobRequest`]s; the request and
//! response vocabulary itself belongs to the `api` module
//! (`RequestKind` is re-exported here for convenience).

pub mod dotprod;
pub mod scale;
pub mod sumup;
pub mod traces;

pub use crate::api::RequestKind;
pub use sumup::{for_mode_program, no_mode_program, sumup_mode_program, Mode};
pub use traces::{Request, TraceConfig, TraceGen};
