//! The `asumup` program family (§5): summing up elements of a vector,
//! generated for an arbitrary vector in each of the three operating modes
//! of Table 1.
//!
//! The EMPA variants follow §5.1/§5.2: the compiler (here: this
//! generator) cuts the loop kernel `mrmovl + addl` into a QT, preallocates
//! cores — `min(N, 30)` in SUMUP mode, per §6.2's compiler rule: "it
//! should not allocate more than that number of cores" — and emits the
//! mass-processing metainstructions.

use std::fmt::Write;

/// Table 1 operating modes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Mode {
    /// Conventional programming, no EMPA acceleration (Listing 1).
    No,
    /// §5.1: control instructions replaced by SV activity.
    For,
    /// §5.2: obsolete read/write-back stages also eliminated.
    Sumup,
}

impl Mode {
    pub fn name(self) -> &'static str {
        match self {
            Mode::No => "NO",
            Mode::For => "FOR",
            Mode::Sumup => "SUMUP",
        }
    }
}

/// Maximum useful SUMUP children (§6.2: the 30-clock rent period).
pub const SUMUP_MAX_CHILDREN: u32 = 30;

/// Emit the labelled data section for `values` (`.long` per element; one
/// zero placeholder keeps the label addressable when empty).
fn emit_vector(src: &mut String, values: &[i32]) {
    src.push_str("    .align 4\narray:\n");
    for v in values {
        let _ = writeln!(src, "    .long {v}");
    }
    if values.is_empty() {
        // keep the label addressable
        src.push_str("    .long 0\n");
    }
}

/// Zero-filled data section at capacity `n` — the template's placeholder
/// segment, patched per request through the assembled program's data
/// layout (same shape `emit_vector` produces, so a patched template image
/// is byte-identical to a directly generated one).
fn emit_placeholder(src: &mut String, n: usize) {
    src.push_str("    .align 4\narray:\n");
    for _ in 0..n.max(1) {
        src.push_str("    .long 0\n");
    }
}

fn checked_sum(values: &[i32]) -> i32 {
    values.iter().fold(0i32, |a, &b| a.wrapping_add(b))
}

/// Code section for (mode, element count): everything *except* the data
/// segment. The emitted bytes depend only on `(mode, n)` — this is what
/// makes a compiled template reusable across requests of the same
/// size-class with only the data words patched.
pub(crate) fn code(mode: Mode, n: usize) -> String {
    let mut s = String::new();
    match mode {
        Mode::No => {
            let _ = writeln!(s, "# asumup, conventional coding (Listing 1), N={n}");
            s.push_str("    .pos 0\n");
            let _ = writeln!(s, "    irmovl ${n}, %edx      # No of items to sum");
            s.push_str("    irmovl array, %ecx   # Array address\n");
            s.push_str("    xorl %eax, %eax      # sum = 0\n");
            s.push_str("    andl %edx, %edx      # Set condition codes\n");
            s.push_str("    je End\n");
            s.push_str("Loop:\n");
            s.push_str("    mrmovl (%ecx), %esi  # get *Start\n");
            s.push_str("    addl %esi, %eax      # add to sum\n");
            s.push_str("    irmovl $4, %ebx\n");
            s.push_str("    addl %ebx, %ecx      # Start++\n");
            s.push_str("    irmovl $-1, %ebx\n");
            s.push_str("    addl %ebx, %edx      # Count--\n");
            s.push_str("    jne Loop             # Stop when 0\n");
            s.push_str("End:\n");
            s.push_str("    halt\n");
        }
        Mode::For => {
            let _ = writeln!(s, "# asumup, EMPA FOR mode (§5.1), N={n}");
            s.push_str("    .pos 0\n");
            let _ = writeln!(s, "    irmovl ${n}, %edx      # No of items to sum");
            s.push_str("    irmovl array, %ecx   # Array address\n");
            s.push_str("    xorl %eax, %eax      # sum = 0\n");
            s.push_str("    qprealloc $1         # guarantee a helper core\n");
            s.push_str("    qmassfor Body        # SV drives the loop\n");
            s.push_str("    halt\n");
            s.push_str("Body:\n");
            s.push_str("    mrmovl (%ecx), %esi  # get *Start (payload)\n");
            s.push_str("    addl %esi, %eax      # add to sum (payload)\n");
            s.push_str("    qterm %eax           # clone the partial sum back\n");
        }
        Mode::Sumup => {
            let prealloc = (n as u32).min(SUMUP_MAX_CHILDREN);
            let _ = writeln!(s, "# asumup, EMPA SUMUP mode (§5.2), N={n}");
            s.push_str("    .pos 0\n");
            let _ = writeln!(s, "    irmovl ${n}, %edx      # No of items to sum");
            s.push_str("    irmovl array, %ecx   # Array address\n");
            s.push_str("    xorl %eax, %eax      # sum = 0\n");
            let _ = writeln!(s, "    qprealloc ${prealloc}       # compiler rule: min(N, 30)");
            s.push_str("    qmasssum Body        # SV engine + parent adder\n");
            s.push_str("    halt\n");
            s.push_str("Body:\n");
            s.push_str("    mrmovl (%ecx), %esi  # get my element\n");
            s.push_str("    addl %esi, %pp       # stream summand to parent adder\n");
            s.push_str("    qterm                # one-shot QT\n");
        }
    }
    s
}

/// Data-independent template source for the compile-once pipeline: code
/// for `(mode, n)` plus a zeroed `array` segment of capacity `n`.
pub fn template_source(mode: Mode, n: usize) -> String {
    let mut s = code(mode, n);
    emit_placeholder(&mut s, n);
    s
}

/// Listing 1, generalised to an arbitrary vector. Returns the source and
/// the expected sum.
pub fn no_mode_program(values: &[i32]) -> (String, i32) {
    let mut s = code(Mode::No, values.len());
    emit_vector(&mut s, values);
    (s, checked_sum(values))
}

/// §5.1 FOR mode: lines 9–10 of Listing 1 become a QT executed by one
/// preallocated child; the SV takes over loop organisation.
pub fn for_mode_program(values: &[i32]) -> (String, i32) {
    let mut s = code(Mode::For, values.len());
    emit_vector(&mut s, values);
    (s, checked_sum(values))
}

/// §5.2 SUMUP mode: staggered children stream summands through `%pp`
/// into the parent-side adder.
pub fn sumup_mode_program(values: &[i32]) -> (String, i32) {
    let mut s = code(Mode::Sumup, values.len());
    emit_vector(&mut s, values);
    (s, checked_sum(values))
}

/// Program source for (mode, vector).
pub fn program(mode: Mode, values: &[i32]) -> (String, i32) {
    match mode {
        Mode::No => no_mode_program(values),
        Mode::For => for_mode_program(values),
        Mode::Sumup => sumup_mode_program(values),
    }
}

/// The paper's example vector from Listing 1.
pub fn paper_vector() -> Vec<i32> {
    vec![0xd, 0xc0, 0xb00, 0xa000]
}

/// A deterministic pseudo-random vector of length `n` (tests, sweeps).
pub fn synth_vector(n: usize, seed: u64) -> Vec<i32> {
    // xorshift64*, truncated: deterministic across platforms.
    let mut state = seed.wrapping_mul(2685821657736338717).max(1);
    (0..n)
        .map(|_| {
            state ^= state >> 12;
            state ^= state << 25;
            state ^= state >> 27;
            (state.wrapping_mul(0x2545F4914F6CDD1D) >> 40) as i32 - (1 << 23)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::assemble;

    #[test]
    fn generated_sources_assemble() {
        for mode in [Mode::No, Mode::For, Mode::Sumup] {
            for n in [0usize, 1, 2, 4, 6, 31, 100] {
                let v = synth_vector(n, 7);
                let (src, _) = program(mode, &v);
                assemble(&src).unwrap_or_else(|e| panic!("{mode:?} N={n}: {e}"));
            }
        }
    }

    #[test]
    fn expected_sum_wraps() {
        let (_, sum) = no_mode_program(&[i32::MAX, 1]);
        assert_eq!(sum, i32::MIN);
    }

    #[test]
    fn prealloc_respects_compiler_cap() {
        let (src, _) = sumup_mode_program(&synth_vector(100, 1));
        assert!(src.contains("qprealloc $30"));
        let (src, _) = sumup_mode_program(&synth_vector(7, 1));
        assert!(src.contains("qprealloc $7"));
    }

    #[test]
    fn synth_vector_is_deterministic() {
        assert_eq!(synth_vector(16, 3), synth_vector(16, 3));
        assert_ne!(synth_vector(16, 3), synth_vector(16, 4));
    }
}
