//! Elementwise-scale workload family: `y[i] = c * x[i]` — the pure
//! FOR-mode shape (§5.1): no cross-iteration dependency at all, so the SV
//! loop engine removes *all* control instructions and the child does only
//! payload (load, multiply, store).
//!
//! Output array placed at a fixed displacement from the input, same
//! single-address-register discipline as the dot-product family.
//!
//! The scale factor is a *data* word (`cval`), loaded through a register
//! in the prologue — it used to be an `irmovl` immediate, which baked
//! per-request data into the code bytes and defeated template reuse.

use super::sumup::Mode;
use std::fmt::Write;

fn emit_data(src: &mut String, x: &[i32], c: i32) {
    src.push_str("    .align 4\ncval:\n");
    let _ = writeln!(src, "    .long {c}");
    src.push_str("arrayX:\n");
    for v in x {
        let _ = writeln!(src, "    .long {v}");
    }
    if x.is_empty() {
        src.push_str("    .long 0\n");
    }
    src.push_str("arrayY:\n");
    for _ in 0..x.len().max(1) {
        src.push_str("    .long 0\n");
    }
}

/// Zeroed `cval`/`arrayX`/`arrayY` segments at capacity `n` — the
/// template placeholder, patched per request (same layout as
/// `emit_data`).
fn emit_placeholder(src: &mut String, n: usize) {
    src.push_str("    .align 4\ncval:\n");
    src.push_str("    .long 0\n");
    src.push_str("arrayX:\n");
    for _ in 0..n.max(1) {
        src.push_str("    .long 0\n");
    }
    src.push_str("arrayY:\n");
    for _ in 0..n.max(1) {
        src.push_str("    .long 0\n");
    }
}

pub(crate) fn expected(x: &[i32], c: i32) -> Vec<i32> {
    x.iter().map(|v| v.wrapping_mul(c)).collect()
}

fn offset(n: usize) -> usize {
    4 * n.max(1)
}

/// Code section for (mode, element count); bytes depend only on
/// `(mode, n)` — the scale factor is read from the `cval` data word.
pub(crate) fn code(mode: Mode, n: usize) -> String {
    let off = offset(n);
    let mut s = String::new();
    match mode {
        Mode::No => {
            let _ = writeln!(s, "# ascale, conventional coding, N={n}");
            s.push_str("    .pos 0\n");
            let _ = writeln!(s, "    irmovl ${n}, %edx");
            s.push_str("    irmovl arrayX, %ecx\n");
            s.push_str("    irmovl cval, %ebx\n");
            s.push_str("    mrmovl (%ebx), %ebp  # scale factor (data word)\n");
            s.push_str("    andl %edx, %edx\n");
            s.push_str("    je End\n");
            s.push_str("Loop:\n");
            s.push_str("    mrmovl (%ecx), %esi\n");
            s.push_str("    mull %ebp, %esi\n");
            let _ = writeln!(s, "    rmmovl %esi, {off}(%ecx)");
            s.push_str("    irmovl $4, %ebx\n");
            s.push_str("    addl %ebx, %ecx\n");
            s.push_str("    irmovl $-1, %ebx\n");
            s.push_str("    addl %ebx, %edx\n");
            s.push_str("    jne Loop\n");
            s.push_str("End:\n    halt\n");
        }
        Mode::For => {
            let _ = writeln!(s, "# ascale, EMPA FOR mode, N={n}");
            s.push_str("    .pos 0\n");
            let _ = writeln!(s, "    irmovl ${n}, %edx");
            s.push_str("    irmovl arrayX, %ecx\n");
            s.push_str("    irmovl cval, %ebx\n");
            s.push_str("    mrmovl (%ebx), %ebp  # scale factor (data word)\n");
            s.push_str("    qprealloc $1\n");
            s.push_str("    qmassfor Body\n");
            s.push_str("    halt\n");
            s.push_str("Body:\n");
            s.push_str("    mrmovl (%ecx), %esi\n");
            s.push_str("    mull %ebp, %esi\n");
            let _ = writeln!(s, "    rmmovl %esi, {off}(%ecx)");
            s.push_str("    qterm\n");
        }
        Mode::Sumup => unreachable!("scale has no reduction; callers check the mode first"),
    }
    s
}

/// Data-independent template source: code for `(mode, n)` plus zeroed
/// `cval`/`arrayX`/`arrayY` segments of capacity `n`. `None` for SUMUP
/// (no reduction), mirroring [`program`] — a data-only "program" that
/// halts on the zeroed `cval` word would be a silent wrong answer.
pub fn template_source(mode: Mode, n: usize) -> Option<String> {
    if mode == Mode::Sumup {
        return None;
    }
    let mut s = code(mode, n);
    emit_placeholder(&mut s, n);
    Some(s)
}

/// Conventional loop.
pub fn no_mode(x: &[i32], c: i32) -> (String, Vec<i32>) {
    let mut s = code(Mode::No, x.len());
    emit_data(&mut s, x, c);
    (s, expected(x, c))
}

/// FOR mode: pure-payload child, loop control fully absorbed by the SV.
pub fn for_mode(x: &[i32], c: i32) -> (String, Vec<i32>) {
    let mut s = code(Mode::For, x.len());
    emit_data(&mut s, x, c);
    (s, expected(x, c))
}

/// Program source for (mode, x, c); SUMUP does not apply (no reduction).
pub fn program(mode: Mode, x: &[i32], c: i32) -> Option<(String, Vec<i32>)> {
    match mode {
        Mode::No => Some(no_mode(x, c)),
        Mode::For => Some(for_mode(x, c)),
        Mode::Sumup => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::empa::{EmpaConfig, EmpaProcessor, TimingConfig};
    use crate::isa::assemble;
    use crate::workload::sumup::synth_vector;

    fn run_and_read_y(src: &str, n: usize) -> (crate::empa::RunReport, Vec<i32>) {
        let p = assemble(src).unwrap();
        let y_addr = p.symbol("arrayY").unwrap();
        let proc = EmpaProcessor::new(&p.image, &EmpaConfig::default());
        // run to completion, then read back the output array
        let mut proc = proc;
        for _ in 0..1_000_000 {
            proc.tick();
            if matches!(proc.cores[0].run, crate::empa::RunState::Halted) {
                break;
            }
        }
        let y: Vec<i32> =
            (0..n).map(|i| proc.mem.read_u32(y_addr + 4 * i as u32).unwrap() as i32).collect();
        let report_clocks = proc.clock;
        // cheap report substitute: we only need memory + halt state here
        let report = crate::empa::RunReport {
            clocks: report_clocks,
            status: crate::isa::Status::Hlt,
            regs: proc.cores[0].regs.clone(),
            max_occupied: 0,
            distinct_cores: 0,
            retired: 0,
            bus: Default::default(),
            sv_ops: 0,
            events_processed: 0,
            clocks_skipped: 0,
            icache_hits: 0,
            icache_misses: 0,
            host_threads: 1,
            parallel_spans: 0,
            parallel_cores: 0,
            span_conflicts: 0,
            span_hist: [0; 6],
            fault: None,
            trace: Default::default(),
        };
        (report, y)
    }

    #[test]
    fn both_modes_write_the_scaled_array() {
        for n in [1usize, 2, 7, 23] {
            let x: Vec<i32> = synth_vector(n, 3).iter().map(|v| v % 1000).collect();
            for mode in [Mode::No, Mode::For] {
                let (src, want) = program(mode, &x, 3).unwrap();
                let (_, y) = run_and_read_y(&src, n);
                assert_eq!(y, want, "{mode:?} N={n}");
            }
        }
    }

    #[test]
    fn sumup_mode_is_rejected() {
        assert!(program(Mode::Sumup, &[1, 2], 3).is_none());
        assert!(template_source(Mode::Sumup, 2).is_none(), "no data-only pseudo-template");
        assert!(template_source(Mode::For, 2).is_some());
    }

    #[test]
    fn for_mode_removes_all_control_cost() {
        // FOR per-iteration = payload only (load+mul+store); NO adds the
        // 15-clock control tail. Derived from TimingConfig, not hardcoded.
        let t = TimingConfig::paper();
        let payload = t.mrmov + t.mul + t.rmmov;
        let control = t.irmov + t.alu + t.irmov + t.alu + t.jump;
        let run_clocks = |src: &str| {
            let p = assemble(src).unwrap();
            EmpaProcessor::new(&p.image, &EmpaConfig::default()).run().clocks
        };
        for n in [2usize, 9, 30] {
            let x = synth_vector(n, 4);
            let t_no = run_clocks(&no_mode(&x, 5).0);
            let t_for = run_clocks(&for_mode(&x, 5).0);
            let diff = t_no - t_for;
            // per-iteration saving is exactly the control cost, modulo the
            // different prologues (constant in N).
            let diff2 = {
                let x2 = synth_vector(n + 1, 4);
                (run_clocks(&no_mode(&x2, 5).0) - run_clocks(&for_mode(&x2, 5).0)) - diff
            };
            assert_eq!(diff2 as u64, control, "N={n}: per-iter saving");
            assert!(t_for < t_no);
            let _ = payload;
        }
    }
}
