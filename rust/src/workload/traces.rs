//! The trace-replay workload family, plus synthetic request traces for
//! the EMPA fabric coordinator (E9).
//!
//! **Replay family** (`atrace`): a control-heavy interpreter kernel. The
//! *code* is a fixed dispatch loop; the *trace* — a stream of
//! `(opcode, operand)` records folded into the accumulator — is pure
//! data. This is the extreme point of the code/data split the
//! compile-once pipeline exploits: every request shares one template and
//! differs only in the patched record stream.
//!
//! **Request traces**: a trace mixes scalar QT jobs (programs from every
//! workload family on a simulated EMPA processor) with mass operations
//! (batched vector reductions eligible for the §3.8 accelerator link),
//! with exponential arrivals. The request *types* live in [`crate::api`];
//! this module only generates them — a workload is a producer of
//! [`JobRequest`]s, not a definer of the service vocabulary.

use super::sumup::{self, Mode};
use crate::api::{JobRequest, Priority, RequestKind};
use crate::util::Rng;
use std::fmt::Write;
use std::time::Duration;

// ----------------------------------------------------------------------
// the trace-replay program family
// ----------------------------------------------------------------------

/// One replay record's operation on the accumulator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceOpKind {
    /// `acc += v` (opcode 0)
    Add,
    /// `acc -= v` (opcode 1)
    Sub,
    /// `acc ^= v` (opcode 2)
    Xor,
}

impl TraceOpKind {
    fn opcode(self) -> i32 {
        match self {
            TraceOpKind::Add => 0,
            TraceOpKind::Sub => 1,
            TraceOpKind::Xor => 2,
        }
    }
}

/// One `(opcode, operand)` replay record.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceOp {
    pub kind: TraceOpKind,
    pub value: i32,
}

impl TraceOp {
    pub fn new(kind: TraceOpKind, value: i32) -> Self {
        TraceOp { kind, value }
    }
}

/// Flatten a record stream into the data words the interpreter reads
/// (two words per record: opcode, operand).
pub fn encode_ops(ops: &[TraceOp]) -> Vec<i32> {
    let mut words = Vec::with_capacity(2 * ops.len());
    for op in ops {
        words.push(op.kind.opcode());
        words.push(op.value);
    }
    words
}

/// Expected accumulator after replaying `ops` (the family oracle).
pub fn fold_ops(ops: &[TraceOp]) -> i32 {
    ops.iter().fold(0i32, |acc, op| match op.kind {
        TraceOpKind::Add => acc.wrapping_add(op.value),
        TraceOpKind::Sub => acc.wrapping_sub(op.value),
        TraceOpKind::Xor => acc ^ op.value,
    })
}

/// Interpreter code for `n` records; bytes depend only on `n`. The
/// dispatch chain is straight Y86 control flow — this family only runs
/// conventionally (`Mode::No`): its payload *is* control.
pub(crate) fn code(n: usize) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "# atrace, replay interpreter, N={n} records");
    s.push_str("    .pos 0\n");
    let _ = writeln!(s, "    irmovl ${n}, %edx    # record count");
    s.push_str("    irmovl trace, %ecx   # record stream\n");
    s.push_str("    xorl %eax, %eax      # accumulator\n");
    s.push_str("    andl %edx, %edx\n");
    s.push_str("    je End\n");
    s.push_str("Loop:\n");
    s.push_str("    mrmovl (%ecx), %ebx  # opcode\n");
    s.push_str("    mrmovl 4(%ecx), %esi # operand\n");
    s.push_str("    andl %ebx, %ebx\n");
    s.push_str("    je DoAdd\n");
    s.push_str("    irmovl $-1, %edi\n");
    s.push_str("    addl %edi, %ebx\n");
    s.push_str("    je DoSub\n");
    s.push_str("    xorl %esi, %eax      # opcode 2: xor\n");
    s.push_str("    jmp Next\n");
    s.push_str("DoAdd:\n");
    s.push_str("    addl %esi, %eax\n");
    s.push_str("    jmp Next\n");
    s.push_str("DoSub:\n");
    s.push_str("    subl %esi, %eax\n");
    s.push_str("Next:\n");
    s.push_str("    irmovl $8, %edi\n");
    s.push_str("    addl %edi, %ecx      # next record\n");
    s.push_str("    irmovl $-1, %edi\n");
    s.push_str("    addl %edi, %edx\n");
    s.push_str("    jne Loop\n");
    s.push_str("End:\n");
    s.push_str("    halt\n");
    s
}

fn emit_trace(src: &mut String, ops: &[TraceOp]) {
    src.push_str("    .align 4\ntrace:\n");
    for w in encode_ops(ops) {
        let _ = writeln!(src, "    .long {w}");
    }
    if ops.is_empty() {
        src.push_str("    .long 0\n    .long 0\n");
    }
}

/// Data-independent template source: interpreter code plus a zeroed
/// record stream of capacity `n`.
pub fn template_source(n: usize) -> String {
    let mut s = code(n);
    s.push_str("    .align 4\ntrace:\n");
    for _ in 0..2 * n.max(1) {
        s.push_str("    .long 0\n");
    }
    s
}

/// Full replay program for `ops`. Returns the source and the expected
/// accumulator.
pub fn replay_program(ops: &[TraceOp]) -> (String, i32) {
    let mut s = code(ops.len());
    emit_trace(&mut s, ops);
    (s, fold_ops(ops))
}

/// A deterministic pseudo-random record stream (tests, trace generation).
pub fn synth_ops(n: usize, seed: u64) -> Vec<TraceOp> {
    let mut rng = Rng::seed_from_u64(seed ^ 0x7ace);
    (0..n)
        .map(|_| {
            let kind = match rng.below(3) {
                0 => TraceOpKind::Add,
                1 => TraceOpKind::Sub,
                _ => TraceOpKind::Xor,
            };
            TraceOp::new(kind, (rng.next_u64() as u32 as i32) >> 8)
        })
        .collect()
}

/// One generated request with its arrival offset.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub id: u64,
    /// Arrival time offset from trace start, microseconds.
    pub arrival_us: u64,
    pub job: JobRequest,
}

/// Trace generator parameters.
#[derive(Debug, Clone)]
pub struct TraceConfig {
    pub seed: u64,
    pub num_requests: usize,
    /// Mean inter-arrival gap in microseconds.
    pub mean_gap_us: u64,
    /// Fraction of requests that are mass ops (0..=1).
    pub mass_fraction: f64,
    /// Vector length range for mass ops.
    pub mass_len: (usize, usize),
    /// Vector length range for program runs.
    pub program_len: (usize, usize),
    /// Fraction of requests submitted at `Priority::High` (0..=1).
    pub high_priority_fraction: f64,
    /// Relative deadline stamped on every request (None: no deadlines).
    pub deadline: Option<Duration>,
    /// Client tag stamped on every request (per-client accounting).
    pub client: Option<&'static str>,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            seed: 42,
            num_requests: 256,
            mean_gap_us: 200,
            mass_fraction: 0.6,
            mass_len: (64, 1024),
            program_len: (1, 32),
            high_priority_fraction: 0.0,
            deadline: None,
            client: None,
        }
    }
}

/// Deterministic trace generator.
pub struct TraceGen {
    rng: Rng,
    cfg: TraceConfig,
}

impl TraceGen {
    pub fn new(cfg: TraceConfig) -> Self {
        TraceGen { rng: Rng::seed_from_u64(cfg.seed), cfg }
    }

    /// Generate the full trace, sorted by arrival.
    pub fn generate(&mut self) -> Vec<Request> {
        let mut t = 0u64;
        let mut out = Vec::with_capacity(self.cfg.num_requests);
        for id in 0..self.cfg.num_requests as u64 {
            t += self.rng.exp(self.cfg.mean_gap_us as f64) as u64;
            let kind = if self.rng.bool(self.cfg.mass_fraction) {
                let len = self.rng.range_usize(self.cfg.mass_len.0, self.cfg.mass_len.1);
                if self.rng.bool(0.5) {
                    RequestKind::MassSum {
                        values: (0..len).map(|_| self.rng.range_f32(-1.0, 1.0)).collect(),
                    }
                } else {
                    RequestKind::MassDot {
                        a: (0..len).map(|_| self.rng.range_f32(-1.0, 1.0)).collect(),
                        b: (0..len).map(|_| self.rng.range_f32(-1.0, 1.0)).collect(),
                    }
                }
            } else {
                let len = self.rng.range_usize(self.cfg.program_len.0, self.cfg.program_len.1);
                let seed = self.cfg.seed ^ id;
                let mode = match self.rng.below(3) {
                    0 => Mode::No,
                    1 => Mode::For,
                    _ => Mode::Sumup,
                };
                // Every program family is fabric-servable; sample them all.
                match self.rng.below(4) {
                    0 => RequestKind::sumup(mode, sumup::synth_vector(len, seed)),
                    1 => RequestKind::dotprod(
                        mode,
                        sumup::synth_vector(len, seed),
                        sumup::synth_vector(len, seed.wrapping_add(1)),
                    ),
                    2 => RequestKind::scale(
                        // scale has no reduction: SUMUP does not apply
                        if mode == Mode::Sumup { Mode::For } else { mode },
                        sumup::synth_vector(len, seed),
                        (seed % 97) as i32 - 48,
                    ),
                    _ => RequestKind::traces(synth_ops(len, seed)),
                }
            };
            let mut job = JobRequest::new(kind);
            if self.rng.bool(self.cfg.high_priority_fraction) {
                job = job.with_priority(Priority::High);
            }
            if let Some(d) = self.cfg.deadline {
                job = job.with_deadline(d);
            }
            if let Some(c) = self.cfg.client {
                job = job.with_client(c);
            }
            out.push(Request { id, arrival_us: t, job });
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn traces_are_deterministic_and_sorted() {
        let cfg = TraceConfig::default();
        let a = TraceGen::new(cfg.clone()).generate();
        let b = TraceGen::new(cfg).generate();
        assert_eq!(a.len(), 256);
        assert_eq!(a, b);
        assert!(a.windows(2).all(|w| w[0].arrival_us <= w[1].arrival_us));
    }

    #[test]
    fn mass_fraction_respected_roughly() {
        let cfg = TraceConfig { num_requests: 1000, mass_fraction: 0.8, ..Default::default() };
        let t = TraceGen::new(cfg).generate();
        let mass = t
            .iter()
            .filter(|r| {
                matches!(r.job.kind, RequestKind::MassSum { .. } | RequestKind::MassDot { .. })
            })
            .count();
        assert!((700..900).contains(&mass), "mass count {mass}");
    }

    #[test]
    fn mass_lengths_within_bounds() {
        let cfg = TraceConfig { num_requests: 200, mass_len: (16, 32), ..Default::default() };
        for r in TraceGen::new(cfg).generate() {
            if let RequestKind::MassSum { values } = &r.job.kind {
                assert!((16..=32).contains(&values.len()));
            }
        }
    }

    #[test]
    fn program_requests_use_all_modes_and_families() {
        use crate::workload::family::Family;
        let cfg = TraceConfig { num_requests: 600, mass_fraction: 0.0, ..Default::default() };
        let t = TraceGen::new(cfg).generate();
        let mut modes = [false; 3];
        let mut families = [false; 4];
        for r in &t {
            if let RequestKind::RunProgram { family, mode, .. } = &r.job.kind {
                modes[match mode {
                    Mode::No => 0,
                    Mode::For => 1,
                    Mode::Sumup => 2,
                }] = true;
                families[match family {
                    Family::Sumup => 0,
                    Family::Dotprod => 1,
                    Family::Scale => 2,
                    Family::Traces => 3,
                }] = true;
            }
        }
        assert_eq!(modes, [true; 3]);
        assert_eq!(families, [true; 4]);
    }

    #[test]
    fn replay_program_matches_fold_oracle() {
        use crate::empa::{EmpaConfig, EmpaProcessor};
        use crate::isa::assemble;
        for n in [0usize, 1, 2, 9, 30] {
            let ops = synth_ops(n, 5);
            let (src, want) = replay_program(&ops);
            let p = assemble(&src).unwrap_or_else(|e| panic!("N={n}: {e}"));
            let r = EmpaProcessor::new(&p.image, &EmpaConfig::default()).run();
            assert_eq!(r.fault, None, "N={n}");
            assert_eq!(r.eax(), want, "N={n}");
        }
    }

    #[test]
    fn replay_ops_cover_all_kinds_and_wrap() {
        let ops = vec![
            TraceOp::new(TraceOpKind::Add, i32::MAX),
            TraceOp::new(TraceOpKind::Add, 1), // wraps
            TraceOp::new(TraceOpKind::Sub, 5),
            TraceOp::new(TraceOpKind::Xor, -1),
        ];
        let want = i32::MAX
            .wrapping_add(1)
            .wrapping_sub(5) ^ -1;
        assert_eq!(fold_ops(&ops), want);
        assert_eq!(encode_ops(&ops).len(), 8);
        assert_eq!(encode_ops(&ops)[..2], [0, i32::MAX]);
    }

    #[test]
    fn contract_fields_stamped_when_configured() {
        let cfg = TraceConfig {
            num_requests: 100,
            high_priority_fraction: 1.0,
            deadline: Some(Duration::from_millis(50)),
            client: Some("trace"),
            ..Default::default()
        };
        for r in TraceGen::new(cfg).generate() {
            assert_eq!(r.job.priority, Priority::High);
            assert_eq!(r.job.deadline, Some(Duration::from_millis(50)));
            assert_eq!(r.job.client.as_deref(), Some("trace"));
        }
    }

    #[test]
    fn defaults_leave_contract_neutral() {
        for r in TraceGen::new(TraceConfig { num_requests: 20, ..Default::default() }).generate() {
            assert_eq!(r.job.priority, Priority::Normal);
            assert_eq!(r.job.deadline, None);
            assert_eq!(r.job.client, None);
        }
    }
}
