//! Synthetic request traces for the EMPA fabric coordinator (E9).
//!
//! A trace mixes scalar QT jobs (run a sumup program on a simulated EMPA
//! processor) with mass operations (batched vector reductions eligible for
//! the §3.8 accelerator link), with exponential arrivals. The request
//! *types* live in [`crate::api`]; this module only generates them — a
//! workload is a producer of [`JobRequest`]s, not a definer of the
//! service vocabulary.

use super::sumup::{self, Mode};
use crate::api::{JobRequest, Priority, RequestKind};
use crate::util::Rng;
use std::time::Duration;

/// One generated request with its arrival offset.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub id: u64,
    /// Arrival time offset from trace start, microseconds.
    pub arrival_us: u64,
    pub job: JobRequest,
}

/// Trace generator parameters.
#[derive(Debug, Clone)]
pub struct TraceConfig {
    pub seed: u64,
    pub num_requests: usize,
    /// Mean inter-arrival gap in microseconds.
    pub mean_gap_us: u64,
    /// Fraction of requests that are mass ops (0..=1).
    pub mass_fraction: f64,
    /// Vector length range for mass ops.
    pub mass_len: (usize, usize),
    /// Vector length range for program runs.
    pub program_len: (usize, usize),
    /// Fraction of requests submitted at `Priority::High` (0..=1).
    pub high_priority_fraction: f64,
    /// Relative deadline stamped on every request (None: no deadlines).
    pub deadline: Option<Duration>,
    /// Client tag stamped on every request (per-client accounting).
    pub client: Option<&'static str>,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            seed: 42,
            num_requests: 256,
            mean_gap_us: 200,
            mass_fraction: 0.6,
            mass_len: (64, 1024),
            program_len: (1, 32),
            high_priority_fraction: 0.0,
            deadline: None,
            client: None,
        }
    }
}

/// Deterministic trace generator.
pub struct TraceGen {
    rng: Rng,
    cfg: TraceConfig,
}

impl TraceGen {
    pub fn new(cfg: TraceConfig) -> Self {
        TraceGen { rng: Rng::seed_from_u64(cfg.seed), cfg }
    }

    /// Generate the full trace, sorted by arrival.
    pub fn generate(&mut self) -> Vec<Request> {
        let mut t = 0u64;
        let mut out = Vec::with_capacity(self.cfg.num_requests);
        for id in 0..self.cfg.num_requests as u64 {
            t += self.rng.exp(self.cfg.mean_gap_us as f64) as u64;
            let kind = if self.rng.bool(self.cfg.mass_fraction) {
                let len = self.rng.range_usize(self.cfg.mass_len.0, self.cfg.mass_len.1);
                if self.rng.bool(0.5) {
                    RequestKind::MassSum {
                        values: (0..len).map(|_| self.rng.range_f32(-1.0, 1.0)).collect(),
                    }
                } else {
                    RequestKind::MassDot {
                        a: (0..len).map(|_| self.rng.range_f32(-1.0, 1.0)).collect(),
                        b: (0..len).map(|_| self.rng.range_f32(-1.0, 1.0)).collect(),
                    }
                }
            } else {
                let len = self.rng.range_usize(self.cfg.program_len.0, self.cfg.program_len.1);
                let mode = match self.rng.below(3) {
                    0 => Mode::No,
                    1 => Mode::For,
                    _ => Mode::Sumup,
                };
                RequestKind::RunProgram {
                    mode,
                    values: sumup::synth_vector(len, self.cfg.seed ^ id),
                }
            };
            let mut job = JobRequest::new(kind);
            if self.rng.bool(self.cfg.high_priority_fraction) {
                job = job.with_priority(Priority::High);
            }
            if let Some(d) = self.cfg.deadline {
                job = job.with_deadline(d);
            }
            if let Some(c) = self.cfg.client {
                job = job.with_client(c);
            }
            out.push(Request { id, arrival_us: t, job });
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn traces_are_deterministic_and_sorted() {
        let cfg = TraceConfig::default();
        let a = TraceGen::new(cfg.clone()).generate();
        let b = TraceGen::new(cfg).generate();
        assert_eq!(a.len(), 256);
        assert_eq!(a, b);
        assert!(a.windows(2).all(|w| w[0].arrival_us <= w[1].arrival_us));
    }

    #[test]
    fn mass_fraction_respected_roughly() {
        let cfg = TraceConfig { num_requests: 1000, mass_fraction: 0.8, ..Default::default() };
        let t = TraceGen::new(cfg).generate();
        let mass = t
            .iter()
            .filter(|r| {
                matches!(r.job.kind, RequestKind::MassSum { .. } | RequestKind::MassDot { .. })
            })
            .count();
        assert!((700..900).contains(&mass), "mass count {mass}");
    }

    #[test]
    fn mass_lengths_within_bounds() {
        let cfg = TraceConfig { num_requests: 200, mass_len: (16, 32), ..Default::default() };
        for r in TraceGen::new(cfg).generate() {
            if let RequestKind::MassSum { values } = &r.job.kind {
                assert!((16..=32).contains(&values.len()));
            }
        }
    }

    #[test]
    fn program_requests_use_all_modes() {
        let cfg = TraceConfig { num_requests: 600, mass_fraction: 0.0, ..Default::default() };
        let t = TraceGen::new(cfg).generate();
        let mut seen = [false; 3];
        for r in &t {
            if let RequestKind::RunProgram { mode, .. } = &r.job.kind {
                seen[match mode {
                    Mode::No => 0,
                    Mode::For => 1,
                    Mode::Sumup => 2,
                }] = true;
            }
        }
        assert_eq!(seen, [true; 3]);
    }

    #[test]
    fn contract_fields_stamped_when_configured() {
        let cfg = TraceConfig {
            num_requests: 100,
            high_priority_fraction: 1.0,
            deadline: Some(Duration::from_millis(50)),
            client: Some("trace"),
            ..Default::default()
        };
        for r in TraceGen::new(cfg).generate() {
            assert_eq!(r.job.priority, Priority::High);
            assert_eq!(r.job.deadline, Some(Duration::from_millis(50)));
            assert_eq!(r.job.client.as_deref(), Some("trace"));
        }
    }

    #[test]
    fn defaults_leave_contract_neutral() {
        for r in TraceGen::new(TraceConfig { num_requests: 20, ..Default::default() }).generate() {
            assert_eq!(r.job.priority, Priority::Normal);
            assert_eq!(r.job.deadline, None);
            assert_eq!(r.job.client, None);
        }
    }
}
