//! The workload-family abstraction behind the compile-once program
//! pipeline.
//!
//! The EMPA-programming companion work (arXiv:1608.07155) frames
//! SUMUP/FOR/dot-product as a *family* of parallelization shapes rather
//! than unrelated programs. [`WorkloadFamily`] captures that: each family
//! emits a **code template** whose bytes depend only on
//! `(mode, size-class)` and a separate **data image** (the per-request
//! words patched into the assembled template's data segment), plus an
//! expected-result oracle for verification.
//!
//! The split is what makes caching possible: the fabric's `sim` backend
//! assembles a template once per `(family, mode, size-class)` and serves
//! every subsequent request of that class by patching data words into a
//! copy of the cached image — no source regeneration, no reassembly. A
//! size-class is the exact element count: the count is an immediate in
//! the code bytes, which keeps the served programs byte-identical to the
//! directly generated ones (and the Table 1 clock counts exact); a
//! coarser bucketing would need a data-resident count.

use super::sumup::Mode;
use super::traces::TraceOp;
use super::{dotprod, scale, sumup, traces};
use crate::isa::Program;
use crate::mem::Memory;

/// The program families servable by the fabric.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Family {
    /// `asumup` — vector reduction (§5, all three Table 1 modes).
    Sumup,
    /// `adotprod` — two-operand reduction (§3.7 mass operating mode).
    Dotprod,
    /// `ascale` — elementwise map, output written back to memory (§5.1).
    Scale,
    /// `atrace` — control-heavy replay interpreter over a record stream.
    Traces,
}

/// Every family, in a fixed order (tests and sweeps).
pub const ALL_FAMILIES: [Family; 4] =
    [Family::Sumup, Family::Dotprod, Family::Scale, Family::Traces];

impl Family {
    pub fn name(self) -> &'static str {
        match self {
            Family::Sumup => "sumup",
            Family::Dotprod => "dotprod",
            Family::Scale => "scale",
            Family::Traces => "traces",
        }
    }
}

/// Per-request parameters — the *data* half of the code/data split. The
/// variant determines the family ([`Params::family`]).
#[derive(Debug, Clone, PartialEq)]
pub enum Params {
    Sumup { values: Vec<i32> },
    Dotprod { a: Vec<i32>, b: Vec<i32> },
    Scale { x: Vec<i32>, c: i32 },
    Traces { ops: Vec<TraceOp> },
}

impl Params {
    /// The family these parameters belong to.
    pub fn family(&self) -> Family {
        match self {
            Params::Sumup { .. } => Family::Sumup,
            Params::Dotprod { .. } => Family::Dotprod,
            Params::Scale { .. } => Family::Scale,
            Params::Traces { .. } => Family::Traces,
        }
    }
}

/// What a family's oracle predicts for a run.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Expected {
    /// Final `%eax` of the root core (the reduction families).
    Eax(i32),
    /// Words of the family's read-back span (`Output::Program::data`).
    Data(Vec<i32>),
}

impl Expected {
    /// Check a run's observables against the prediction.
    pub fn matches(&self, eax: i32, data: &[i32]) -> bool {
        match self {
            Expected::Eax(want) => *want == eax,
            Expected::Data(want) => want == data,
        }
    }
}

/// A parallelizable program family: code template + data image + oracle.
///
/// Invariant (checked by the unit tests below): assembling
/// `template(mode, size_class(params))` and patching `data_image(params)`
/// into its data segment yields an image **byte-identical** to assembling
/// the directly generated program for `params`.
pub trait WorkloadFamily {
    fn family(&self) -> Family;

    /// Operating modes this family supports (scale has no reduction, the
    /// replay interpreter's payload *is* control flow).
    fn modes(&self) -> &'static [Mode];

    /// Template cache key component: the element count class.
    fn size_class(&self, params: &Params) -> Result<u32, String>;

    /// Data-independent source for `(mode, size_class)`.
    fn template(&self, mode: Mode, size_class: u32) -> Result<String, String>;

    /// `(symbol, words)` pairs to patch into the template's data segment.
    fn data_image(&self, params: &Params) -> Result<Vec<(&'static str, Vec<i32>)>, String>;

    /// Expected result for verification.
    fn oracle(&self, params: &Params) -> Result<Expected, String>;

    /// Memory span `(symbol, words)` to read back into the reply after
    /// the run (families whose result lives in memory, not `%eax`).
    fn readback(&self, _params: &Params) -> Option<(&'static str, u32)> {
        None
    }
}

fn wrong_params(fam: Family, params: &Params) -> String {
    format!("{} family given {} params", fam.name(), params.family().name())
}

fn check_mode(fam: &dyn WorkloadFamily, mode: Mode) -> Result<(), String> {
    if fam.modes().contains(&mode) {
        Ok(())
    } else {
        Err(format!("{} family does not support {} mode", fam.family().name(), mode.name()))
    }
}

// ----------------------------------------------------------------------
// the four families
// ----------------------------------------------------------------------

pub struct SumupFamily;

impl WorkloadFamily for SumupFamily {
    fn family(&self) -> Family {
        Family::Sumup
    }

    fn modes(&self) -> &'static [Mode] {
        &[Mode::No, Mode::For, Mode::Sumup]
    }

    fn size_class(&self, params: &Params) -> Result<u32, String> {
        match params {
            Params::Sumup { values } => Ok(values.len() as u32),
            other => Err(wrong_params(Family::Sumup, other)),
        }
    }

    fn template(&self, mode: Mode, size_class: u32) -> Result<String, String> {
        check_mode(self, mode)?;
        Ok(sumup::template_source(mode, size_class as usize))
    }

    fn data_image(&self, params: &Params) -> Result<Vec<(&'static str, Vec<i32>)>, String> {
        match params {
            Params::Sumup { values } => Ok(vec![("array", values.clone())]),
            other => Err(wrong_params(Family::Sumup, other)),
        }
    }

    fn oracle(&self, params: &Params) -> Result<Expected, String> {
        match params {
            Params::Sumup { values } => {
                Ok(Expected::Eax(values.iter().fold(0i32, |a, &b| a.wrapping_add(b))))
            }
            other => Err(wrong_params(Family::Sumup, other)),
        }
    }
}

pub struct DotprodFamily;

impl WorkloadFamily for DotprodFamily {
    fn family(&self) -> Family {
        Family::Dotprod
    }

    fn modes(&self) -> &'static [Mode] {
        &[Mode::No, Mode::For, Mode::Sumup]
    }

    fn size_class(&self, params: &Params) -> Result<u32, String> {
        match params {
            Params::Dotprod { a, b } => {
                if a.len() != b.len() {
                    return Err(format!(
                        "dotprod operands disagree in length: a has {}, b has {}",
                        a.len(),
                        b.len()
                    ));
                }
                Ok(a.len() as u32)
            }
            other => Err(wrong_params(Family::Dotprod, other)),
        }
    }

    fn template(&self, mode: Mode, size_class: u32) -> Result<String, String> {
        check_mode(self, mode)?;
        Ok(dotprod::template_source(mode, size_class as usize))
    }

    fn data_image(&self, params: &Params) -> Result<Vec<(&'static str, Vec<i32>)>, String> {
        match params {
            Params::Dotprod { a, b } => Ok(vec![("arrayA", a.clone()), ("arrayB", b.clone())]),
            other => Err(wrong_params(Family::Dotprod, other)),
        }
    }

    fn oracle(&self, params: &Params) -> Result<Expected, String> {
        match params {
            Params::Dotprod { a, b } => Ok(Expected::Eax(dotprod::expected(a, b))),
            other => Err(wrong_params(Family::Dotprod, other)),
        }
    }
}

pub struct ScaleFamily;

impl WorkloadFamily for ScaleFamily {
    fn family(&self) -> Family {
        Family::Scale
    }

    fn modes(&self) -> &'static [Mode] {
        // No reduction: SUMUP does not apply.
        &[Mode::No, Mode::For]
    }

    fn size_class(&self, params: &Params) -> Result<u32, String> {
        match params {
            Params::Scale { x, .. } => Ok(x.len() as u32),
            other => Err(wrong_params(Family::Scale, other)),
        }
    }

    fn template(&self, mode: Mode, size_class: u32) -> Result<String, String> {
        check_mode(self, mode)?;
        scale::template_source(mode, size_class as usize)
            .ok_or_else(|| "scale family does not support sumup mode".to_string())
    }

    fn data_image(&self, params: &Params) -> Result<Vec<(&'static str, Vec<i32>)>, String> {
        match params {
            Params::Scale { x, c } => Ok(vec![("cval", vec![*c]), ("arrayX", x.clone())]),
            other => Err(wrong_params(Family::Scale, other)),
        }
    }

    fn oracle(&self, params: &Params) -> Result<Expected, String> {
        match params {
            Params::Scale { x, c } => Ok(Expected::Data(scale::expected(x, *c))),
            other => Err(wrong_params(Family::Scale, other)),
        }
    }

    fn readback(&self, params: &Params) -> Option<(&'static str, u32)> {
        match params {
            Params::Scale { x, .. } => Some(("arrayY", x.len() as u32)),
            _ => None,
        }
    }
}

pub struct TracesFamily;

impl WorkloadFamily for TracesFamily {
    fn family(&self) -> Family {
        Family::Traces
    }

    fn modes(&self) -> &'static [Mode] {
        // The interpreter's payload is its control flow; there is nothing
        // for the SV loop engines to absorb.
        &[Mode::No]
    }

    fn size_class(&self, params: &Params) -> Result<u32, String> {
        match params {
            Params::Traces { ops } => Ok(ops.len() as u32),
            other => Err(wrong_params(Family::Traces, other)),
        }
    }

    fn template(&self, mode: Mode, size_class: u32) -> Result<String, String> {
        check_mode(self, mode)?;
        Ok(traces::template_source(size_class as usize))
    }

    fn data_image(&self, params: &Params) -> Result<Vec<(&'static str, Vec<i32>)>, String> {
        match params {
            Params::Traces { ops } => Ok(vec![("trace", traces::encode_ops(ops))]),
            other => Err(wrong_params(Family::Traces, other)),
        }
    }

    fn oracle(&self, params: &Params) -> Result<Expected, String> {
        match params {
            Params::Traces { ops } => Ok(Expected::Eax(traces::fold_ops(ops))),
            other => Err(wrong_params(Family::Traces, other)),
        }
    }
}

/// Static dispatch table: the implementation behind a [`Family`] tag.
pub fn family_impl(f: Family) -> &'static dyn WorkloadFamily {
    match f {
        Family::Sumup => &SumupFamily,
        Family::Dotprod => &DotprodFamily,
        Family::Scale => &ScaleFamily,
        Family::Traces => &TracesFamily,
    }
}

/// Read a family's read-back span out of simulated memory. The single
/// implementation shared by the sim backend and the verification tests,
/// so the product and test paths stay provably identical.
pub fn read_span(
    prog: &Program,
    mem: &Memory,
    symbol: &str,
    words: u32,
) -> Result<Vec<i32>, String> {
    let addr = prog
        .symbol(symbol)
        .ok_or_else(|| format!("readback symbol `{symbol}` missing"))?;
    (0..words)
        .map(|i| {
            mem.read_u32(addr + 4 * i)
                .map(|w| w as i32)
                .map_err(|e| format!("readback at `{symbol}`+{i}: {e:?}"))
        })
        .collect()
}

/// Deterministic per-family parameter synthesis (tests, sweeps): `n`
/// elements, reproducible from `seed`. The single constructor the
/// fuzz/integration/unit tests share, so adding a family means updating
/// one match.
pub fn synth_params(family: Family, n: usize, seed: u64) -> Params {
    match family {
        Family::Sumup => Params::Sumup { values: sumup::synth_vector(n, seed) },
        Family::Dotprod => Params::Dotprod {
            a: sumup::synth_vector(n, seed),
            b: sumup::synth_vector(n, seed.wrapping_add(1)),
        },
        Family::Scale => Params::Scale {
            x: sumup::synth_vector(n, seed),
            c: (seed % 31) as i32 - 15,
        },
        Family::Traces => Params::Traces { ops: traces::synth_ops(n, seed) },
    }
}

/// Directly generated source for `params` (the pre-pipeline path: data
/// baked into the text). Used by tests to prove the patched-template
/// image is byte-identical.
pub fn direct_source(mode: Mode, params: &Params) -> Result<String, String> {
    match params {
        Params::Sumup { values } => Ok(sumup::program(mode, values).0),
        Params::Dotprod { a, b } => {
            if a.len() != b.len() {
                return Err("dotprod operand mismatch".into());
            }
            Ok(dotprod::program(mode, a, b).0)
        }
        Params::Scale { x, c } => scale::program(mode, x, *c)
            .map(|(s, _)| s)
            .ok_or_else(|| "scale does not support SUMUP".into()),
        Params::Traces { ops } => {
            if mode != Mode::No {
                return Err("traces only runs conventionally".into());
            }
            Ok(traces::replay_program(ops).0)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::empa::{EmpaConfig, EmpaProcessor};
    use crate::isa::assemble;

    fn params_for(f: Family, n: usize, seed: u64) -> Params {
        synth_params(f, n, seed)
    }

    #[test]
    fn templates_assemble_for_every_mode_and_size() {
        for f in ALL_FAMILIES {
            let fam = family_impl(f);
            for &mode in fam.modes() {
                for sc in [0u32, 1, 2, 7, 31] {
                    let src = fam.template(mode, sc).unwrap();
                    assemble(&src).unwrap_or_else(|e| {
                        panic!("{} {mode:?} size-class {sc}: {e}", f.name())
                    });
                }
            }
        }
    }

    #[test]
    fn unsupported_modes_are_errors_not_panics() {
        assert!(family_impl(Family::Scale).template(Mode::Sumup, 4).is_err());
        assert!(family_impl(Family::Traces).template(Mode::For, 4).is_err());
        assert!(family_impl(Family::Traces).template(Mode::Sumup, 4).is_err());
    }

    #[test]
    fn wrong_params_variant_is_an_error() {
        let p = Params::Sumup { values: vec![1] };
        assert!(family_impl(Family::Dotprod).size_class(&p).is_err());
        assert!(family_impl(Family::Scale).data_image(&p).is_err());
        assert!(family_impl(Family::Traces).oracle(&p).is_err());
        assert_eq!(p.family(), Family::Sumup);
    }

    #[test]
    fn patched_template_image_is_byte_identical_to_direct_assembly() {
        for f in ALL_FAMILIES {
            let fam = family_impl(f);
            for &mode in fam.modes() {
                for n in [0usize, 1, 2, 6, 13] {
                    let params = params_for(f, n, 0x5EED ^ n as u64);
                    let sc = fam.size_class(&params).unwrap();
                    let tpl = assemble(&fam.template(mode, sc).unwrap()).unwrap();
                    let mut image = tpl.image.clone();
                    for (sym, words) in fam.data_image(&params).unwrap() {
                        tpl.patch_into(&mut image, sym, &words).unwrap_or_else(|e| {
                            panic!("{} {mode:?} N={n} patch {sym}: {e}", f.name())
                        });
                    }
                    let direct = assemble(&direct_source(mode, &params).unwrap()).unwrap();
                    assert_eq!(image, direct.image, "{} {mode:?} N={n}", f.name());
                }
            }
        }
    }

    #[test]
    fn oracles_match_simulation_through_the_patched_template() {
        let cfg = EmpaConfig::default();
        for f in ALL_FAMILIES {
            let fam = family_impl(f);
            for &mode in fam.modes() {
                for n in [0usize, 1, 5] {
                    let params = params_for(f, n, 0xACE ^ n as u64);
                    let sc = fam.size_class(&params).unwrap();
                    let tpl = assemble(&fam.template(mode, sc).unwrap()).unwrap();
                    let mut image = tpl.image.clone();
                    for (sym, words) in fam.data_image(&params).unwrap() {
                        tpl.patch_into(&mut image, sym, &words).unwrap();
                    }
                    let mut proc = EmpaProcessor::new(&image, &cfg);
                    let r = proc.run_report();
                    assert_eq!(r.fault, None, "{} {mode:?} N={n}", f.name());
                    let data: Vec<i32> = match fam.readback(&params) {
                        Some((sym, words)) => read_span(&tpl, &proc.mem, sym, words).unwrap(),
                        None => Vec::new(),
                    };
                    let want = fam.oracle(&params).unwrap();
                    assert!(
                        want.matches(r.eax(), &data),
                        "{} {mode:?} N={n}: want {want:?}, got eax={} data={data:?}",
                        f.name(),
                        r.eax()
                    );
                }
            }
        }
    }

    #[test]
    fn dotprod_shape_mismatch_is_an_error() {
        let p = Params::Dotprod { a: vec![1, 2, 3], b: vec![1] };
        assert!(family_impl(Family::Dotprod).size_class(&p).is_err());
    }
}
