//! Dot-product workload family: `sum_i a[i]*b[i]` — §3.7's "mass
//! operating mode" over *two* operand streams (the paper's parent "can
//! sum up summands provided by its children, in frame of a machine
//! instruction"; here each child provides a product).
//!
//! Both arrays are laid out back to back, so the child body reaches the
//! second operand at a fixed displacement from `%ecx` — the same
//! single-address-register discipline the SV's FOR/SUMUP engines advance.

use super::sumup::{Mode, SUMUP_MAX_CHILDREN};
use std::fmt::Write;

fn emit_arrays(src: &mut String, a: &[i32], b: &[i32]) {
    src.push_str("    .align 4\narrayA:\n");
    for v in a {
        let _ = writeln!(src, "    .long {v}");
    }
    if a.is_empty() {
        src.push_str("    .long 0\n");
    }
    src.push_str("arrayB:\n");
    for v in b {
        let _ = writeln!(src, "    .long {v}");
    }
    if b.is_empty() {
        src.push_str("    .long 0\n");
    }
}

/// Zero-filled `arrayA`/`arrayB` segments at capacity `n` — the template
/// placeholder, patched per request (same layout as `emit_arrays`).
fn emit_placeholder(src: &mut String, n: usize) {
    src.push_str("    .align 4\narrayA:\n");
    for _ in 0..n.max(1) {
        src.push_str("    .long 0\n");
    }
    src.push_str("arrayB:\n");
    for _ in 0..n.max(1) {
        src.push_str("    .long 0\n");
    }
}

pub(crate) fn expected(a: &[i32], b: &[i32]) -> i32 {
    a.iter().zip(b).fold(0i32, |s, (&x, &y)| s.wrapping_add(x.wrapping_mul(y)))
}

/// Displacement from an `arrayA` element to its `arrayB` partner.
fn offset(n: usize) -> usize {
    4 * n.max(1)
}

/// Code section for (mode, element count); bytes depend only on
/// `(mode, n)` (the count immediate and the A→B displacement), never on
/// the operand values — the compile-once invariant.
pub(crate) fn code(mode: Mode, n: usize) -> String {
    let off = offset(n);
    let mut s = String::new();
    match mode {
        Mode::No => {
            let _ = writeln!(s, "# adotprod, conventional coding, N={n}");
            s.push_str("    .pos 0\n");
            let _ = writeln!(s, "    irmovl ${n}, %edx");
            s.push_str("    irmovl arrayA, %ecx\n");
            s.push_str("    xorl %eax, %eax\n");
            s.push_str("    andl %edx, %edx\n");
            s.push_str("    je End\n");
            s.push_str("Loop:\n");
            s.push_str("    mrmovl (%ecx), %esi   # a[i]\n");
            let _ = writeln!(s, "    mrmovl {off}(%ecx), %edi # b[i]");
            s.push_str("    mull %edi, %esi       # a[i]*b[i]\n");
            s.push_str("    addl %esi, %eax\n");
            s.push_str("    irmovl $4, %ebx\n");
            s.push_str("    addl %ebx, %ecx\n");
            s.push_str("    irmovl $-1, %ebx\n");
            s.push_str("    addl %ebx, %edx\n");
            s.push_str("    jne Loop\n");
            s.push_str("End:\n    halt\n");
        }
        Mode::For => {
            let _ = writeln!(s, "# adotprod, EMPA FOR mode, N={n}");
            s.push_str("    .pos 0\n");
            let _ = writeln!(s, "    irmovl ${n}, %edx");
            s.push_str("    irmovl arrayA, %ecx\n");
            s.push_str("    xorl %eax, %eax\n");
            s.push_str("    qprealloc $1\n");
            s.push_str("    qmassfor Body\n");
            s.push_str("    halt\n");
            s.push_str("Body:\n");
            s.push_str("    mrmovl (%ecx), %esi\n");
            let _ = writeln!(s, "    mrmovl {off}(%ecx), %edi");
            s.push_str("    mull %edi, %esi\n");
            s.push_str("    addl %esi, %eax\n");
            s.push_str("    qterm %eax\n");
        }
        Mode::Sumup => {
            let prealloc = (n as u32).min(SUMUP_MAX_CHILDREN);
            let _ = writeln!(s, "# adotprod, EMPA SUMUP mode, N={n}");
            s.push_str("    .pos 0\n");
            let _ = writeln!(s, "    irmovl ${n}, %edx");
            s.push_str("    irmovl arrayA, %ecx\n");
            s.push_str("    xorl %eax, %eax\n");
            let _ = writeln!(s, "    qprealloc ${prealloc}");
            s.push_str("    qmasssum Body\n");
            s.push_str("    halt\n");
            s.push_str("Body:\n");
            s.push_str("    mrmovl (%ecx), %esi\n");
            let _ = writeln!(s, "    mrmovl {off}(%ecx), %edi");
            s.push_str("    mull %edi, %esi\n");
            s.push_str("    addl %esi, %pp       # stream the product\n");
            s.push_str("    qterm\n");
        }
    }
    s
}

/// Data-independent template source: code for `(mode, n)` plus zeroed
/// `arrayA`/`arrayB` segments of capacity `n`.
pub fn template_source(mode: Mode, n: usize) -> String {
    let mut s = code(mode, n);
    emit_placeholder(&mut s, n);
    s
}

/// Conventional loop (baseline).
pub fn no_mode(a: &[i32], b: &[i32]) -> (String, i32) {
    assert_eq!(a.len(), b.len());
    let mut s = code(Mode::No, a.len());
    emit_arrays(&mut s, a, b);
    (s, expected(a, b))
}

/// FOR mode: the product+accumulate kernel as a re-launched child QT.
pub fn for_mode(a: &[i32], b: &[i32]) -> (String, i32) {
    assert_eq!(a.len(), b.len());
    let mut s = code(Mode::For, a.len());
    emit_arrays(&mut s, a, b);
    (s, expected(a, b))
}

/// SUMUP mode: each child streams one product into the parent adder.
pub fn sumup_mode(a: &[i32], b: &[i32]) -> (String, i32) {
    assert_eq!(a.len(), b.len());
    let mut s = code(Mode::Sumup, a.len());
    emit_arrays(&mut s, a, b);
    (s, expected(a, b))
}

/// Program source for (mode, a, b).
pub fn program(mode: Mode, a: &[i32], b: &[i32]) -> (String, i32) {
    match mode {
        Mode::No => no_mode(a, b),
        Mode::For => for_mode(a, b),
        Mode::Sumup => sumup_mode(a, b),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::empa::{EmpaConfig, EmpaProcessor, TimingConfig};
    use crate::isa::assemble;
    use crate::workload::sumup::synth_vector;

    fn run(src: &str) -> crate::empa::RunReport {
        let p = assemble(src).unwrap();
        EmpaProcessor::new(&p.image, &EmpaConfig::default()).run()
    }

    #[test]
    fn all_modes_compute_the_dot_product() {
        for n in [0usize, 1, 2, 5, 17, 40] {
            let a = synth_vector(n, 11).iter().map(|v| v % 1000).collect::<Vec<_>>();
            let b = synth_vector(n, 22).iter().map(|v| v % 1000).collect::<Vec<_>>();
            for mode in [Mode::No, Mode::For, Mode::Sumup] {
                let (src, want) = program(mode, &a, &b);
                let r = run(&src);
                assert_eq!(r.fault, None, "{mode:?} N={n}");
                assert_eq!(r.eax(), want, "{mode:?} N={n}");
            }
        }
    }

    #[test]
    fn timings_follow_the_instruction_cost_laws() {
        // Closed forms derived from TimingConfig (not hardcoded): the same
        // derivation style as Table 1, with the heavier loop kernel.
        let t = TimingConfig::paper();
        let body_no = 2 * t.mrmov + t.mul + t.alu // payload
            + t.irmov + t.alu + t.irmov + t.alu + t.jump; // loop control
        let body_child = 2 * t.mrmov + t.mul + t.alu;
        for n in [1usize, 3, 8, 20] {
            let a = synth_vector(n, 1);
            let b = synth_vector(n, 2);
            let (src, _) = no_mode(&a, &b);
            let r = run(&src);
            let prologue = 2 * t.irmov + 2 * t.alu + t.jump + t.halt;
            assert_eq!(r.clocks, prologue + body_no * n as u64, "NO N={n}");
            let (src, _) = for_mode(&a, &b);
            let r = run(&src);
            // setup(11) + qprealloc(2) + qmassfor(3) + first-launch stagger
            // + N*child + halt(3)
            let setup = 2 * t.irmov + t.alu
                + t.meta_dispatch + t.sv_prealloc
                + t.meta_dispatch + t.sv_mass_setup_for
                + t.sv_stagger
                + t.halt;
            assert_eq!(r.clocks, setup + body_child * n as u64, "FOR N={n}");
        }
    }

    #[test]
    fn sumup_dot_still_one_element_per_clock() {
        // The adder consumes 1 product/clock regardless of the heavier
        // child body — the pipe is just longer (same §5.2 argument).
        let mk = |n: usize| {
            let a = synth_vector(n, 5);
            let b = synth_vector(n, 6);
            run(&sumup_mode(&a, &b).0).clocks
        };
        let t10 = mk(10);
        let t20 = mk(20);
        assert_eq!(t20 - t10, 10, "1 clock per extra element");
    }

    #[test]
    fn sumup_dot_uses_more_children_than_plain_sumup() {
        // Child rent = work(25) + overhead(19) = 44 clocks at 1/clock
        // stagger, so concurrency saturates at min(N, 30 prealloc'd).
        let n = 60;
        let a = synth_vector(n, 7);
        let b = synth_vector(n, 8);
        let r = run(&sumup_mode(&a, &b).0);
        assert_eq!(r.fault, None);
        assert_eq!(r.max_occupied, 31, "prealloc cap still rules");
    }
}
