//! Dynamic batcher: groups per-request vectors into bucket-shaped
//! batches for the accelerator, bounded by batch size and a deadline
//! window — the serving-side analogue of the SV collecting child QTs for
//! mass processing before triggering the engine.

use std::time::{Duration, Instant};

/// Batcher policy.
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Flush when this many rows are pending (use the largest bucket B).
    pub max_rows: usize,
    /// Flush when the oldest pending row has waited this long.
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_rows: 32, max_wait: Duration::from_micros(500) }
    }
}

/// A pending row with its owner request id.
#[derive(Debug, Clone)]
pub struct PendingRow<T> {
    pub tag: T,
    pub row: Vec<f32>,
    pub row2: Option<Vec<f32>>,
    pub enqueued: Instant,
}

/// Rows grouped per operation, flushed as one accelerator call.
#[derive(Debug)]
pub struct Batcher<T> {
    cfg: BatcherConfig,
    pending: Vec<PendingRow<T>>,
    /// Completed flush statistics.
    pub flushes: u64,
    pub flushed_rows: u64,
    pub deadline_flushes: u64,
}

impl<T> Batcher<T> {
    pub fn new(cfg: BatcherConfig) -> Self {
        Batcher { cfg, pending: Vec::new(), flushes: 0, flushed_rows: 0, deadline_flushes: 0 }
    }

    /// Queue a row; returns a full batch when the size trigger fires.
    pub fn push(&mut self, tag: T, row: Vec<f32>, row2: Option<Vec<f32>>, now: Instant) -> Option<Vec<PendingRow<T>>> {
        self.pending.push(PendingRow { tag, row, row2, enqueued: now });
        if self.pending.len() >= self.cfg.max_rows {
            self.flushes += 1;
            self.flushed_rows += self.pending.len() as u64;
            Some(std::mem::take(&mut self.pending))
        } else {
            None
        }
    }

    /// Deadline check: flush when the oldest row exceeded `max_wait`.
    pub fn poll(&mut self, now: Instant) -> Option<Vec<PendingRow<T>>> {
        let oldest = self.pending.first()?;
        if now.duration_since(oldest.enqueued) >= self.cfg.max_wait {
            self.flushes += 1;
            self.deadline_flushes += 1;
            self.flushed_rows += self.pending.len() as u64;
            Some(std::mem::take(&mut self.pending))
        } else {
            None
        }
    }

    /// Force out whatever is pending (shutdown path).
    pub fn drain(&mut self) -> Option<Vec<PendingRow<T>>> {
        if self.pending.is_empty() {
            None
        } else {
            self.flushes += 1;
            self.flushed_rows += self.pending.len() as u64;
            Some(std::mem::take(&mut self.pending))
        }
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// Next deadline, for scheduling the poll.
    pub fn next_deadline(&self) -> Option<Instant> {
        self.pending.first().map(|p| p.enqueued + self.cfg.max_wait)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(rows: usize, wait_us: u64) -> BatcherConfig {
        BatcherConfig { max_rows: rows, max_wait: Duration::from_micros(wait_us) }
    }

    #[test]
    fn size_trigger_flushes_exactly_at_max() {
        let mut b: Batcher<u64> = Batcher::new(cfg(3, 1_000_000));
        let t = Instant::now();
        assert!(b.push(1, vec![1.0], None, t).is_none());
        assert!(b.push(2, vec![2.0], None, t).is_none());
        let batch = b.push(3, vec![3.0], None, t).expect("flush at 3");
        assert_eq!(batch.len(), 3);
        assert_eq!(b.pending_len(), 0);
        assert_eq!(b.flushes, 1);
        assert_eq!(b.deadline_flushes, 0);
    }

    #[test]
    fn deadline_trigger() {
        let mut b: Batcher<u64> = Batcher::new(cfg(100, 0));
        let t = Instant::now();
        assert!(b.push(1, vec![1.0], None, t).is_none());
        let batch = b.poll(t + Duration::from_micros(1)).expect("deadline flush");
        assert_eq!(batch.len(), 1);
        assert_eq!(b.deadline_flushes, 1);
    }

    #[test]
    fn poll_before_deadline_keeps_pending() {
        let mut b: Batcher<u64> = Batcher::new(cfg(100, 1_000_000));
        let t = Instant::now();
        b.push(1, vec![1.0], None, t);
        assert!(b.poll(t).is_none());
        assert_eq!(b.pending_len(), 1);
        assert!(b.next_deadline().is_some());
    }

    #[test]
    fn drain_flushes_remainder() {
        let mut b: Batcher<u64> = Batcher::new(cfg(100, 1_000_000));
        assert!(b.drain().is_none());
        b.push(1, vec![1.0], None, Instant::now());
        b.push(2, vec![2.0], Some(vec![3.0]), Instant::now());
        let batch = b.drain().unwrap();
        assert_eq!(batch.len(), 2);
        assert!(batch[1].row2.is_some());
        assert_eq!(b.flushed_rows, 2);
    }

    #[test]
    fn order_preserved_within_batch() {
        let mut b: Batcher<u64> = Batcher::new(cfg(4, 1_000_000));
        let t = Instant::now();
        for i in 0..3 {
            b.push(i, vec![i as f32], None, t);
        }
        let batch = b.push(3, vec![3.0], None, t).unwrap();
        let tags: Vec<u64> = batch.iter().map(|p| p.tag).collect();
        assert_eq!(tags, vec![0, 1, 2, 3]);
    }
}
