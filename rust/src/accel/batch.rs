//! Dynamic batcher and the flat tile arena: groups per-request vectors
//! into bucket-shaped batches for the accelerator, bounded by batch size
//! and a deadline window — the serving-side analogue of the SV
//! collecting child QTs for mass processing before triggering the
//! engine.
//!
//! Operands arrive as shared `Arc<[f32]>` buffers and are **never
//! copied while staged or flushed** — a [`Batch`] carries the
//! submitters' handles. The mass worker, after its per-row admission
//! gate, appends the surviving rows once into a [`Tile`] — a flat,
//! zero-padded `(B, L)` buffer drawn from a recycled [`TilePool`] arena
//! (grown, never shrunk) — so the backends receive contiguous,
//! already-shaped data instead of a `Vec<Vec<f32>>` they would have to
//! re-pack per flush, the supervisor's routing loop never pays a
//! memcpy, and cancelled rows are never tiled at all.

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Batcher policy.
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Flush when this many rows are pending (use the largest bucket B).
    pub max_rows: usize,
    /// Flush when the oldest pending row has waited this long.
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_rows: 32, max_wait: Duration::from_micros(500) }
    }
}

// ----------------------------------------------------------------------
// the flat tile arena
// ----------------------------------------------------------------------

/// A flat, zero-padded `(B, L)` tile: `rows() * stride()` floats, row
/// `i` occupying `data[i*stride .. i*stride + len(i)]` with zero
/// padding up to the stride. The stride is bucketed to the next power
/// of two of the longest row, so recycled buffers stabilise at a few
/// shapes instead of reallocating per flush.
#[derive(Debug, Clone, PartialEq)]
pub struct Tile {
    data: Vec<f32>,
    lens: Vec<u32>,
    stride: usize,
}

impl Tile {
    /// Flatten `rows` into `buf` (typically a recycled arena buffer —
    /// its capacity is kept, its contents replaced). This is the **one**
    /// copy of the batched data plane: everything before it shares the
    /// submitters' allocations, everything after it reads this tile.
    pub fn build(rows: &[Arc<[f32]>], mut buf: Vec<f32>) -> Tile {
        let max = rows.iter().map(|r| r.len()).max().unwrap_or(0);
        let stride = max.next_power_of_two().max(1);
        buf.clear();
        buf.resize(rows.len() * stride, 0.0);
        let mut lens = Vec::with_capacity(rows.len());
        for (i, r) in rows.iter().enumerate() {
            buf[i * stride..i * stride + r.len()].copy_from_slice(r);
            lens.push(r.len() as u32);
        }
        Tile { data: buf, lens, stride }
    }

    pub fn rows(&self) -> usize {
        self.lens.len()
    }

    pub fn stride(&self) -> usize {
        self.stride
    }

    /// Row `i` without its padding.
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.stride..i * self.stride + self.lens[i] as usize]
    }

    /// The whole `rows * stride` flat buffer (padding included).
    pub fn flat(&self) -> &[f32] {
        &self.data
    }

    /// Bytes of row payload copied into this tile (excludes padding) —
    /// the data plane's bytes-copied-per-flush accounting.
    pub fn filled_bytes(&self) -> u64 {
        4 * self.lens.iter().map(|&l| l as u64).sum::<u64>()
    }

    /// Surrender the backing buffer for recycling (see [`TilePool`]).
    pub fn into_buffer(self) -> Vec<f32> {
        self.data
    }
}

/// Free-list of tile buffers: whoever builds tiles (the fabric's mass
/// worker) takes a buffer per tile and returns it once the batch
/// completed. Buffers keep their capacity across trips — the
/// steady-state batch allocates nothing.
#[derive(Debug, Clone, Default)]
pub struct TilePool {
    free: Arc<Mutex<Vec<Vec<f32>>>>,
}

/// Buffers retained per pool; beyond this, returned buffers are dropped
/// (bounds idle memory after a burst).
const POOL_CAP: usize = 32;

impl TilePool {
    /// A buffer to build the next tile into (recycled, or fresh-empty).
    pub fn take(&self) -> Vec<f32> {
        self.free.lock().unwrap().pop().unwrap_or_default()
    }

    /// Return a tile buffer after its batch completed.
    pub fn give(&self, mut buf: Vec<f32>) {
        buf.clear();
        let mut g = self.free.lock().unwrap();
        if g.len() < POOL_CAP {
            g.push(buf);
        }
    }

    /// Buffers currently parked in the pool (tests).
    pub fn idle(&self) -> usize {
        self.free.lock().unwrap().len()
    }
}

// ----------------------------------------------------------------------
// the batcher
// ----------------------------------------------------------------------

/// A pending row: the caller's tag plus shared handles onto the
/// submitted operand buffers (no copies while staged).
#[derive(Debug, Clone)]
struct PendingRow<T> {
    tag: T,
    row: Arc<[f32]>,
    row2: Option<Arc<[f32]>>,
    enqueued: Instant,
}

/// One flushed batch: per-row tags (in push order) and the shared
/// operand handles. Rows are still the submitters' `Arc`s — the flat
/// tiles are built later, by the mass worker, *after* its per-row
/// admission gate, so the supervisor's routing loop never pays a copy
/// and cancelled rows are never tiled at all.
#[derive(Debug)]
pub struct Batch<T> {
    pub tags: Vec<T>,
    pub rows: Vec<Arc<[f32]>>,
    /// Second operand (dot only; empty otherwise). Row-aligned with
    /// `tags` — rows without a second operand are padded with an empty
    /// `Arc` — so [`Batch::retain`]'s flags apply positionally.
    pub rows2: Vec<Arc<[f32]>>,
}

impl<T> Batch<T> {
    pub fn len(&self) -> usize {
        self.tags.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tags.is_empty()
    }

    /// Drop the rows whose `keep` flag is false from every aligned
    /// container.
    pub fn retain(&mut self, keep: &[bool]) {
        debug_assert!(
            self.rows2.is_empty() || self.rows2.len() == self.tags.len(),
            "rows2 must stay row-aligned with tags"
        );
        let mut it = keep.iter();
        self.tags.retain(|_| *it.next().unwrap());
        let mut it = keep.iter();
        self.rows.retain(|_| *it.next().unwrap());
        if !self.rows2.is_empty() {
            let mut it = keep.iter();
            self.rows2.retain(|_| *it.next().unwrap());
        }
    }
}

/// Rows grouped per operation, flushed as one accelerator call.
#[derive(Debug)]
pub struct Batcher<T> {
    cfg: BatcherConfig,
    pending: Vec<PendingRow<T>>,
    /// Completed flush statistics.
    pub flushes: u64,
    pub flushed_rows: u64,
    pub deadline_flushes: u64,
}

impl<T> Batcher<T> {
    pub fn new(cfg: BatcherConfig) -> Self {
        Batcher { cfg, pending: Vec::new(), flushes: 0, flushed_rows: 0, deadline_flushes: 0 }
    }

    /// Queue a row; returns a full batch when the size trigger fires.
    pub fn push(
        &mut self,
        tag: T,
        row: Arc<[f32]>,
        row2: Option<Arc<[f32]>>,
        now: Instant,
    ) -> Option<Batch<T>> {
        self.pending.push(PendingRow { tag, row, row2, enqueued: now });
        if self.pending.len() >= self.cfg.max_rows {
            self.flushes += 1;
            self.flushed_rows += self.pending.len() as u64;
            Some(self.flush_pending())
        } else {
            None
        }
    }

    /// Deadline check: flush when the oldest row exceeded `max_wait`.
    pub fn poll(&mut self, now: Instant) -> Option<Batch<T>> {
        let oldest = self.pending.first()?;
        if now.duration_since(oldest.enqueued) >= self.cfg.max_wait {
            self.flushes += 1;
            self.deadline_flushes += 1;
            self.flushed_rows += self.pending.len() as u64;
            Some(self.flush_pending())
        } else {
            None
        }
    }

    /// Force out whatever is pending (priority and shutdown paths).
    pub fn drain(&mut self) -> Option<Batch<T>> {
        if self.pending.is_empty() {
            None
        } else {
            self.flushes += 1;
            self.flushed_rows += self.pending.len() as u64;
            Some(self.flush_pending())
        }
    }

    /// Hand the staged rows over — `Arc` moves only, no copies. When any
    /// staged row carries a second operand, `rows2` is padded with empty
    /// rows so it stays **aligned** with `tags`/`rows` (in practice a
    /// batcher is per-op, so batches are all-or-none on `row2`).
    fn flush_pending(&mut self) -> Batch<T> {
        let pending = std::mem::take(&mut self.pending);
        let mut tags = Vec::with_capacity(pending.len());
        let mut rows = Vec::with_capacity(pending.len());
        let mut rows2: Vec<Arc<[f32]>> = Vec::new();
        let any_row2 = pending.iter().any(|p| p.row2.is_some());
        for p in pending {
            tags.push(p.tag);
            rows.push(p.row);
            if any_row2 {
                rows2.push(p.row2.unwrap_or_else(|| Vec::new().into()));
            }
        }
        Batch { tags, rows, rows2 }
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// Next deadline, for scheduling the poll.
    pub fn next_deadline(&self) -> Option<Instant> {
        self.pending.first().map(|p| p.enqueued + self.cfg.max_wait)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(rows: usize, wait_us: u64) -> BatcherConfig {
        BatcherConfig { max_rows: rows, max_wait: Duration::from_micros(wait_us) }
    }

    fn batcher(rows: usize, wait_us: u64) -> Batcher<u64> {
        Batcher::new(cfg(rows, wait_us))
    }

    fn arc(v: Vec<f32>) -> Arc<[f32]> {
        v.into()
    }

    #[test]
    fn size_trigger_flushes_exactly_at_max() {
        let mut b = batcher(3, 1_000_000);
        let t = Instant::now();
        assert!(b.push(1, arc(vec![1.0]), None, t).is_none());
        assert!(b.push(2, arc(vec![2.0]), None, t).is_none());
        let batch = b.push(3, arc(vec![3.0]), None, t).expect("flush at 3");
        assert_eq!(batch.len(), 3);
        assert_eq!(b.pending_len(), 0);
        assert_eq!(b.flushes, 1);
        assert_eq!(b.deadline_flushes, 0);
    }

    #[test]
    fn deadline_trigger() {
        let mut b = batcher(100, 0);
        let t = Instant::now();
        assert!(b.push(1, arc(vec![1.0]), None, t).is_none());
        let batch = b.poll(t + Duration::from_micros(1)).expect("deadline flush");
        assert_eq!(batch.len(), 1);
        assert_eq!(b.deadline_flushes, 1);
    }

    #[test]
    fn poll_before_deadline_keeps_pending() {
        let mut b = batcher(100, 1_000_000);
        let t = Instant::now();
        b.push(1, arc(vec![1.0]), None, t);
        assert!(b.poll(t).is_none());
        assert_eq!(b.pending_len(), 1);
        assert!(b.next_deadline().is_some());
    }

    #[test]
    fn drain_flushes_remainder() {
        let mut b = batcher(100, 1_000_000);
        assert!(b.drain().is_none());
        b.push(1, arc(vec![1.0]), None, Instant::now());
        b.push(2, arc(vec![2.0]), Some(arc(vec![3.0])), Instant::now());
        let batch = b.drain().unwrap();
        assert_eq!(batch.len(), 2);
        // rows2 is padded to stay row-aligned with tags, so retain's
        // positional flags can never skew a mixed batch
        assert_eq!(batch.rows2.len(), 2);
        assert!(batch.rows2[0].is_empty());
        assert_eq!(&batch.rows2[1][..], &[3.0]);
        assert_eq!(b.flushed_rows, 2);
    }

    #[test]
    fn retain_keeps_mixed_second_operands_aligned() {
        let mut b = batcher(100, 1_000_000);
        let t = Instant::now();
        b.push(1, arc(vec![1.0]), None, t);
        b.push(2, arc(vec![2.0]), Some(arc(vec![5.0])), t);
        b.push(3, arc(vec![3.0]), None, t);
        let mut batch = b.drain().unwrap();
        batch.retain(&[false, true, true]);
        assert_eq!(batch.tags, vec![2, 3]);
        assert_eq!(&batch.rows[0][..], &[2.0]);
        assert_eq!(&batch.rows2[0][..], &[5.0], "tag 2 keeps its second operand");
        assert!(batch.rows2[1].is_empty());
    }

    #[test]
    fn order_preserved_within_batch() {
        let mut b = batcher(4, 1_000_000);
        let t = Instant::now();
        for i in 0..3 {
            b.push(i, arc(vec![i as f32]), None, t);
        }
        let batch = b.push(3, arc(vec![3.0]), None, t).unwrap();
        assert_eq!(batch.tags, vec![0, 1, 2, 3]);
        for i in 0..4 {
            assert_eq!(&batch.rows[i][..], &[i as f32][..]);
        }
    }

    #[test]
    fn staged_rows_share_the_submitted_allocation() {
        let mut b = batcher(2, 1_000_000);
        let buf = arc(vec![1.0, 2.0, 3.0]);
        b.push(1, Arc::clone(&buf), None, Instant::now());
        let batch = b.push(2, arc(vec![4.0]), None, Instant::now()).unwrap();
        assert!(Arc::ptr_eq(&batch.rows[0], &buf), "zero-copy while staged and flushed");
        let tile = Tile::build(&batch.rows, Vec::new());
        assert_eq!(tile.row(0), &[1.0, 2.0, 3.0][..], "the tile copy happens post-flush");
    }

    #[test]
    fn tile_is_zero_padded_to_a_bucketed_stride() {
        let rows = vec![arc(vec![1.0, 2.0, 3.0]), arc(vec![4.0])];
        let tile = Tile::build(&rows, Vec::new());
        assert_eq!(tile.rows(), 2);
        assert_eq!(tile.stride(), 4, "next power of two of the longest row");
        assert_eq!(tile.flat(), &[1.0, 2.0, 3.0, 0.0, 4.0, 0.0, 0.0, 0.0]);
        assert_eq!(tile.row(1), &[4.0][..]);
        assert_eq!(tile.filled_bytes(), 16, "4 payload floats");
        // degenerate shapes stay well-formed
        let empty = Tile::build(&[], Vec::new());
        assert_eq!((empty.rows(), empty.stride()), (0, 1));
        let zero_len = Tile::build(&[arc(vec![])], Vec::new());
        assert_eq!((zero_len.rows(), zero_len.stride()), (1, 1));
        assert_eq!(zero_len.row(0), &[] as &[f32]);
    }

    #[test]
    fn pool_recycles_buffers_with_their_capacity() {
        let pool = TilePool::default();
        let rows = vec![arc(vec![1.0; 100]); 8];
        let tile = Tile::build(&rows, pool.take());
        let cap = tile.flat().len();
        pool.give(tile.into_buffer());
        assert_eq!(pool.idle(), 1);
        let reused = pool.take();
        assert!(reused.capacity() >= cap, "grown, never shrunk");
        assert!(reused.is_empty(), "recycled buffers come back clean");
        assert_eq!(pool.idle(), 0);
    }
}
