//! Accelerator link (§3.8).
//!
//! "For the SV a core is represented as a source and destination of
//! signals and data ... EMPA provides an extremely simple interface for
//! linking any kind of external accelerator." The [`Accelerator`] trait is
//! exactly that interface: a mass operation request goes in (data +
//! operation signal), results come back; the SV never sees the
//! accelerator's internals.
//!
//! Two implementations:
//! - [`NativeAccel`] — straightforward rust loops (the "conventional
//!   core" doing the mass op; baseline for the E8 crossover bench);
//! - [`XlaAccel`] — the L2/L1 JAX+Pallas graph via the PJRT [`Runtime`]
//!   (the "special accelerator" the paper envisions linking).

use crate::runtime::{Runtime, Tensor};
use anyhow::{anyhow, Result};

pub mod batch;

pub use batch::{Batcher, BatcherConfig};

/// A mass operation the fabric can route to an accelerator.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MassOp {
    /// Per-row sum (§5.2 SUMUP).
    Sumup,
    /// Elementwise scale*x + bias (§5.1 FOR).
    For,
    /// Per-row dot product (§3.7 mass operating mode).
    Dot,
    /// Per-row prefix sums.
    Prefix,
    /// Fused per-row (sum, mean, l2norm).
    SumupStats,
}

impl MassOp {
    /// L2 entry-point name (must match `python/compile/model.py`).
    pub fn entry(self) -> &'static str {
        match self {
            MassOp::Sumup => "sumup",
            MassOp::For => "mass_for",
            MassOp::Dot => "dot",
            MassOp::Prefix => "prefix",
            MassOp::SumupStats => "sumup_stats",
        }
    }

    /// Number of (B, L) operands.
    pub fn arity(self) -> usize {
        match self {
            MassOp::Dot => 2,
            _ => 1,
        }
    }
}

/// One mass-operation request: `rows` vectors of equal length, plus the
/// scalar latch values (FOR's scale/bias) where the op needs them.
#[derive(Debug, Clone)]
pub struct MassRequest {
    pub op: MassOp,
    /// First operand rows (each of length `l`).
    pub rows: Vec<Vec<f32>>,
    /// Second operand rows (Dot only).
    pub rows2: Vec<Vec<f32>>,
    /// FOR: [scale, bias] latch.
    pub scale_bias: [f32; 2],
}

impl MassRequest {
    pub fn sumup(rows: Vec<Vec<f32>>) -> Self {
        MassRequest { op: MassOp::Sumup, rows, rows2: Vec::new(), scale_bias: [0.0; 2] }
    }

    pub fn dot(rows: Vec<Vec<f32>>, rows2: Vec<Vec<f32>>) -> Self {
        MassRequest { op: MassOp::Dot, rows, rows2, scale_bias: [0.0; 2] }
    }

    pub fn for_op(rows: Vec<Vec<f32>>, scale: f32, bias: f32) -> Self {
        MassRequest { op: MassOp::For, rows, rows2: Vec::new(), scale_bias: [scale, bias] }
    }
}

/// Per-row results: scalar ops give one value per row; FOR/Prefix give a
/// full row back; SumupStats gives three scalars per row.
#[derive(Debug, Clone, PartialEq)]
pub enum MassResult {
    Scalars(Vec<f32>),
    Rows(Vec<Vec<f32>>),
    Stats { sum: Vec<f32>, mean: Vec<f32>, l2: Vec<f32> },
}

/// §3.8's interface: "any circuit, being able to handle data and signals
/// shown in Fig. 2, can be linked to an EMPA processor with ease."
///
/// Implementations need not be `Send`: the fabric constructs the
/// accelerator *on* its dedicated worker thread (PJRT executables hold
/// thread-affine raw handles), mirroring the paper's point that the SV
/// sees only signals and data — never the accelerator's internals.
pub trait Accelerator {
    /// Human-readable identity (metrics, logs).
    fn name(&self) -> &str;
    /// Execute one mass request synchronously.
    fn execute(&self, req: &MassRequest) -> Result<MassResult>;
}

/// Factory for a mass-op accelerator; invoked on the worker thread that
/// will own the instance. Register one as a named fabric backend via
/// `coordinator::BackendRegistry::register_accel` (the fabric may call it
/// once per failover attempt, hence `Fn`, not `FnOnce`).
pub type AccelFactory = Box<dyn Fn() -> Result<Box<dyn Accelerator>> + Send + Sync>;

// ----------------------------------------------------------------------
// Native baseline
// ----------------------------------------------------------------------

/// Plain-rust mass ops: what a conventional core would do, and the
/// numerical oracle for [`XlaAccel`] parity tests.
pub struct NativeAccel;

impl Accelerator for NativeAccel {
    fn name(&self) -> &str {
        "native"
    }

    fn execute(&self, req: &MassRequest) -> Result<MassResult> {
        match req.op {
            MassOp::Sumup => Ok(MassResult::Scalars(
                req.rows.iter().map(|r| r.iter().sum()).collect(),
            )),
            MassOp::Dot => {
                if req.rows.len() != req.rows2.len() {
                    return Err(anyhow!("dot: operand row counts differ"));
                }
                Ok(MassResult::Scalars(
                    req.rows
                        .iter()
                        .zip(&req.rows2)
                        .map(|(a, b)| a.iter().zip(b).map(|(x, y)| x * y).sum())
                        .collect(),
                ))
            }
            MassOp::For => {
                let [s, c] = req.scale_bias;
                Ok(MassResult::Rows(
                    req.rows.iter().map(|r| r.iter().map(|x| x * s + c).collect()).collect(),
                ))
            }
            MassOp::Prefix => Ok(MassResult::Rows(
                req.rows
                    .iter()
                    .map(|r| {
                        let mut acc = 0.0f32;
                        r.iter()
                            .map(|x| {
                                acc += x;
                                acc
                            })
                            .collect()
                    })
                    .collect(),
            )),
            MassOp::SumupStats => {
                let sum: Vec<f32> = req.rows.iter().map(|r| r.iter().sum()).collect();
                let mean: Vec<f32> =
                    req.rows.iter().zip(&sum).map(|(r, s)| s / r.len().max(1) as f32).collect();
                let l2: Vec<f32> = req
                    .rows
                    .iter()
                    .map(|r| r.iter().map(|x| x * x).sum::<f32>().sqrt())
                    .collect();
                Ok(MassResult::Stats { sum, mean, l2 })
            }
        }
    }
}

// ----------------------------------------------------------------------
// XLA-backed accelerator
// ----------------------------------------------------------------------

/// The special accelerator of §3.8: the AOT-compiled JAX/Pallas graph.
///
/// Requests are padded into the smallest bucket that fits (zero padding —
/// the identity of the reductions; FOR/Prefix results are sliced back).
pub struct XlaAccel {
    rt: Runtime,
}

impl XlaAccel {
    pub fn new(rt: Runtime) -> Self {
        XlaAccel { rt }
    }

    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    /// Pick the smallest bucket fitting (rows, len); errors when the
    /// request exceeds every bucket (the batcher must split first).
    fn pick_bucket(&self, entry: &str, rows: usize, len: usize) -> Result<(usize, usize)> {
        self.rt
            .buckets(entry)
            .into_iter()
            .find(|&(b, l)| rows <= b && len <= l)
            .ok_or_else(|| anyhow!("{entry}: ({rows}, {len}) exceeds all buckets"))
    }

    fn pack(rows: &[Vec<f32>], b: usize, l: usize) -> Tensor {
        let mut data = vec![0.0f32; b * l];
        for (i, r) in rows.iter().enumerate() {
            data[i * l..i * l + r.len()].copy_from_slice(r);
        }
        Tensor::matrix(b, l, data)
    }
}

impl Accelerator for XlaAccel {
    fn name(&self) -> &str {
        "xla"
    }

    fn execute(&self, req: &MassRequest) -> Result<MassResult> {
        let rows = req.rows.len();
        let len = req.rows.iter().map(|r| r.len()).max().unwrap_or(0);
        let (b, l) = self.pick_bucket(req.op.entry(), rows, len)?;
        let name = self
            .rt
            .find(req.op.entry(), b, l)
            .ok_or_else(|| anyhow!("missing artifact {} b{b} l{l}", req.op.entry()))?
            .to_string();
        let x = Self::pack(&req.rows, b, l);
        let outs = match req.op {
            MassOp::Dot => {
                let y = Self::pack(&req.rows2, b, l);
                self.rt.execute(&name, &[x, y])?
            }
            MassOp::For => {
                let sb = Tensor::vector(vec![req.scale_bias[0], req.scale_bias[1]]);
                self.rt.execute(&name, &[x, sb])?
            }
            _ => self.rt.execute(&name, &[x])?,
        };
        match req.op {
            MassOp::Sumup | MassOp::Dot => Ok(MassResult::Scalars(outs[0].data[..rows].to_vec())),
            MassOp::For | MassOp::Prefix => Ok(MassResult::Rows(
                req.rows
                    .iter()
                    .enumerate()
                    .map(|(i, r)| outs[0].data[i * l..i * l + r.len()].to_vec())
                    .collect(),
            )),
            MassOp::SumupStats => {
                // mean over the padded bucket length must be rescaled to
                // the true row length (padding contributed zeros).
                let sum = outs[0].data[..rows].to_vec();
                let mean = req
                    .rows
                    .iter()
                    .zip(&sum)
                    .map(|(r, s)| s / r.len().max(1) as f32)
                    .collect();
                let l2 = outs[2].data[..rows].to_vec();
                Ok(MassResult::Stats { sum, mean, l2 })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_sumup_and_dot() {
        let a = NativeAccel;
        let r = a.execute(&MassRequest::sumup(vec![vec![1.0, 2.0, 3.0], vec![4.0]])).unwrap();
        assert_eq!(r, MassResult::Scalars(vec![6.0, 4.0]));
        let r = a
            .execute(&MassRequest::dot(vec![vec![1.0, 2.0]], vec![vec![3.0, 4.0]]))
            .unwrap();
        assert_eq!(r, MassResult::Scalars(vec![11.0]));
    }

    #[test]
    fn native_for_and_prefix() {
        let a = NativeAccel;
        let r = a.execute(&MassRequest::for_op(vec![vec![1.0, 2.0]], 2.0, 1.0)).unwrap();
        assert_eq!(r, MassResult::Rows(vec![vec![3.0, 5.0]]));
        let req = MassRequest {
            op: MassOp::Prefix,
            rows: vec![vec![1.0, 2.0, 3.0]],
            rows2: vec![],
            scale_bias: [0.0; 2],
        };
        assert_eq!(a.execute(&req).unwrap(), MassResult::Rows(vec![vec![1.0, 3.0, 6.0]]));
    }

    #[test]
    fn native_stats() {
        let a = NativeAccel;
        let req = MassRequest {
            op: MassOp::SumupStats,
            rows: vec![vec![3.0, 4.0]],
            rows2: vec![],
            scale_bias: [0.0; 2],
        };
        let MassResult::Stats { sum, mean, l2 } = a.execute(&req).unwrap() else {
            panic!("wrong variant")
        };
        assert_eq!(sum, vec![7.0]);
        assert_eq!(mean, vec![3.5]);
        assert!((l2[0] - 5.0).abs() < 1e-6);
    }

    #[test]
    fn dot_mismatched_rows_is_error() {
        let a = NativeAccel;
        assert!(a.execute(&MassRequest::dot(vec![vec![1.0]], vec![])).is_err());
    }

    #[test]
    fn op_entry_names_match_model() {
        assert_eq!(MassOp::Sumup.entry(), "sumup");
        assert_eq!(MassOp::For.entry(), "mass_for");
        assert_eq!(MassOp::Dot.arity(), 2);
        assert_eq!(MassOp::Sumup.arity(), 1);
    }
}
