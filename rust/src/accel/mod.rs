//! Accelerator link (§3.8).
//!
//! "For the SV a core is represented as a source and destination of
//! signals and data ... EMPA provides an extremely simple interface for
//! linking any kind of external accelerator." The [`Accelerator`] trait is
//! exactly that interface: a mass operation request goes in (data +
//! operation signal), results come back; the SV never sees the
//! accelerator's internals.
//!
//! The data plane is zero-copy up to the accelerator boundary: a
//! [`MassRequest`] carries shared `Arc<[f32]>` operand handles — the
//! very allocations the clients submitted — plus, on the batched path,
//! the flat [`Tile`]s the batcher's recycled arena built (one copy,
//! into pooled memory). Backends read the contiguous tile when present
//! and fall back to the shared rows otherwise.
//!
//! Two implementations:
//! - [`NativeAccel`] — straightforward rust loops (the "conventional
//!   core" doing the mass op; baseline for the E8 crossover bench);
//! - [`XlaAccel`] — the L2/L1 JAX+Pallas graph via the PJRT [`Runtime`]
//!   (the "special accelerator" the paper envisions linking).

use crate::kernels;
use crate::runtime::{Runtime, Tensor};
use anyhow::{anyhow, Result};
use std::sync::Arc;

pub mod batch;

pub use batch::{Batch, Batcher, BatcherConfig, Tile, TilePool};

/// A mass operation the fabric can route to an accelerator.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MassOp {
    /// Per-row sum (§5.2 SUMUP).
    Sumup,
    /// Elementwise scale*x + bias (§5.1 FOR).
    For,
    /// Per-row dot product (§3.7 mass operating mode).
    Dot,
    /// Per-row prefix sums.
    Prefix,
    /// Fused per-row (sum, mean, l2norm).
    SumupStats,
}

impl MassOp {
    /// L2 entry-point name (must match `python/compile/model.py`).
    pub fn entry(self) -> &'static str {
        match self {
            MassOp::Sumup => "sumup",
            MassOp::For => "mass_for",
            MassOp::Dot => "dot",
            MassOp::Prefix => "prefix",
            MassOp::SumupStats => "sumup_stats",
        }
    }

    /// Number of (B, L) operands.
    pub fn arity(self) -> usize {
        match self {
            MassOp::Dot => 2,
            _ => 1,
        }
    }
}

/// One mass-operation request: shared operand rows, the scalar latch
/// values (FOR's scale/bias) where the op needs them, and — when the
/// batcher staged this request — the pre-flattened tiles.
#[derive(Debug, Clone)]
pub struct MassRequest {
    pub op: MassOp,
    /// First-operand rows: shared handles onto the submitters' buffers.
    pub rows: Vec<Arc<[f32]>>,
    /// Second operand rows (Dot only).
    pub rows2: Vec<Arc<[f32]>>,
    /// FOR: [scale, bias] latch.
    pub scale_bias: [f32; 2],
    /// Flat `(B, L)` layout of `rows`, built once by the batcher arena.
    /// `None` for requests constructed directly from rows.
    pub tile: Option<Tile>,
    /// Flat layout of `rows2` (Dot only).
    pub tile2: Option<Tile>,
}

impl MassRequest {
    /// Build from owned or shared rows (`Vec<f32>` and `Arc<[f32]>` both
    /// work — shared rows are adopted without copying).
    pub fn new<R: Into<Arc<[f32]>>, S: Into<Arc<[f32]>>>(
        op: MassOp,
        rows: impl IntoIterator<Item = R>,
        rows2: impl IntoIterator<Item = S>,
        scale_bias: [f32; 2],
    ) -> Self {
        MassRequest {
            op,
            rows: rows.into_iter().map(Into::into).collect(),
            rows2: rows2.into_iter().map(Into::into).collect(),
            scale_bias,
            tile: None,
            tile2: None,
        }
    }

    pub fn sumup<R: Into<Arc<[f32]>>>(rows: impl IntoIterator<Item = R>) -> Self {
        Self::new(MassOp::Sumup, rows, none_rows(), [0.0; 2])
    }

    pub fn dot<R: Into<Arc<[f32]>>, S: Into<Arc<[f32]>>>(
        rows: impl IntoIterator<Item = R>,
        rows2: impl IntoIterator<Item = S>,
    ) -> Self {
        Self::new(MassOp::Dot, rows, rows2, [0.0; 2])
    }

    pub fn for_op<R: Into<Arc<[f32]>>>(
        rows: impl IntoIterator<Item = R>,
        scale: f32,
        bias: f32,
    ) -> Self {
        Self::new(MassOp::For, rows, none_rows(), [scale, bias])
    }

    /// Number of rows in the batch.
    pub fn batch_rows(&self) -> usize {
        self.rows.len()
    }

    /// Row `i` of the first operand — from the flat tile when present
    /// (contiguous), else the shared submitted buffer.
    pub fn row(&self, i: usize) -> &[f32] {
        match &self.tile {
            Some(t) => t.row(i),
            None => &self.rows[i],
        }
    }

    /// Row `i` of the second operand (Dot).
    pub fn row2(&self, i: usize) -> &[f32] {
        match &self.tile2 {
            Some(t) => t.row(i),
            None => &self.rows2[i],
        }
    }

    /// Longest first-operand row.
    pub fn max_len(&self) -> usize {
        self.rows.iter().map(|r| r.len()).max().unwrap_or(0)
    }

    /// Return the tile buffers to the arena after the batch completed.
    pub fn recycle(self, pool: &TilePool) {
        if let Some(t) = self.tile {
            pool.give(t.into_buffer());
        }
        if let Some(t) = self.tile2 {
            pool.give(t.into_buffer());
        }
    }
}

/// Type-inference helper: an empty `rows2` has no element type of its
/// own, so give it one.
fn none_rows() -> std::iter::Empty<Arc<[f32]>> {
    std::iter::empty()
}

/// Per-row results: scalar ops give one value per row; FOR/Prefix give a
/// full row back; SumupStats gives three scalars per row.
#[derive(Debug, Clone, PartialEq)]
pub enum MassResult {
    Scalars(Vec<f32>),
    Rows(Vec<Vec<f32>>),
    Stats { sum: Vec<f32>, mean: Vec<f32>, l2: Vec<f32> },
}

/// §3.8's interface: "any circuit, being able to handle data and signals
/// shown in Fig. 2, can be linked to an EMPA processor with ease."
///
/// Implementations need not be `Send`: the fabric constructs the
/// accelerator *on* its dedicated worker thread (PJRT executables hold
/// thread-affine raw handles), mirroring the paper's point that the SV
/// sees only signals and data — never the accelerator's internals.
pub trait Accelerator {
    /// Human-readable identity (metrics, logs).
    fn name(&self) -> &str;
    /// Execute one mass request synchronously.
    fn execute(&self, req: &MassRequest) -> Result<MassResult>;
}

/// Factory for a mass-op accelerator; invoked on the worker thread that
/// will own the instance. Register one as a named fabric backend via
/// `coordinator::BackendRegistry::register_accel` (the fabric may call it
/// once per failover attempt, hence `Fn`, not `FnOnce`).
pub type AccelFactory = Box<dyn Fn() -> Result<Box<dyn Accelerator>> + Send + Sync>;

// ----------------------------------------------------------------------
// Native baseline
// ----------------------------------------------------------------------

/// Plain-rust mass ops: what a conventional core would do, and the
/// numerical oracle for [`XlaAccel`] parity tests. On the batched path
/// it reads the flat tile — contiguous rows, no per-row pointer chase.
pub struct NativeAccel;

impl Accelerator for NativeAccel {
    fn name(&self) -> &str {
        "native"
    }

    fn execute(&self, req: &MassRequest) -> Result<MassResult> {
        // Reductions go through `crate::kernels` — the shared fixed-order
        // f32 kernels — so the accelerator/batched route is bit-identical
        // to the inline and scatter/gather routes (and SIMD-accelerated
        // where the host supports it). `row(i)` reads the flat tile when
        // one was staged, so the tile path is covered by the same kernels.
        let n = req.batch_rows();
        match req.op {
            MassOp::Sumup => {
                Ok(MassResult::Scalars((0..n).map(|i| kernels::sum(req.row(i))).collect()))
            }
            MassOp::Dot => {
                if n != req.rows2.len() {
                    return Err(anyhow!("dot: operand row counts differ"));
                }
                Ok(MassResult::Scalars(
                    (0..n).map(|i| kernels::dot(req.row(i), req.row2(i))).collect(),
                ))
            }
            MassOp::For => {
                let [s, c] = req.scale_bias;
                Ok(MassResult::Rows(
                    (0..n).map(|i| kernels::scale(req.row(i), s, c)).collect(),
                ))
            }
            MassOp::Prefix => Ok(MassResult::Rows(
                (0..n)
                    .map(|i| {
                        // Inherently sequential; stays scalar.
                        let mut acc = 0.0f32;
                        req.row(i)
                            .iter()
                            .map(|x| {
                                acc += x;
                                acc
                            })
                            .collect()
                    })
                    .collect(),
            )),
            MassOp::SumupStats => {
                let sum: Vec<f32> = (0..n).map(|i| kernels::sum(req.row(i))).collect();
                let mean: Vec<f32> = (0..n)
                    .map(|i| sum[i] / req.row(i).len().max(1) as f32)
                    .collect();
                let l2: Vec<f32> = (0..n)
                    .map(|i| kernels::dot(req.row(i), req.row(i)).sqrt())
                    .collect();
                Ok(MassResult::Stats { sum, mean, l2 })
            }
        }
    }
}

// ----------------------------------------------------------------------
// XLA-backed accelerator
// ----------------------------------------------------------------------

/// The special accelerator of §3.8: the AOT-compiled JAX/Pallas graph.
///
/// Requests are padded into the smallest bucket that fits (zero padding —
/// the identity of the reductions; FOR/Prefix results are sliced back).
/// When the batcher's flat tile already has the bucket's stride, the
/// bucket tensor is one bulk copy of the tile instead of a row-by-row
/// re-pack.
pub struct XlaAccel {
    rt: Runtime,
}

impl XlaAccel {
    pub fn new(rt: Runtime) -> Self {
        XlaAccel { rt }
    }

    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    /// Pick the smallest bucket fitting (rows, len); errors when the
    /// request exceeds every bucket (the batcher must split first).
    fn pick_bucket(&self, entry: &str, rows: usize, len: usize) -> Result<(usize, usize)> {
        self.rt
            .buckets(entry)
            .into_iter()
            .find(|&(b, l)| rows <= b && len <= l)
            .ok_or_else(|| anyhow!("{entry}: ({rows}, {len}) exceeds all buckets"))
    }

    /// Pack one operand into the (b, l) bucket tensor: a single bulk
    /// copy of the flat tile when its stride matches the bucket, a
    /// row-by-row pack otherwise.
    fn pack(req: &MassRequest, second: bool, b: usize, l: usize) -> Tensor {
        let mut data = vec![0.0f32; b * l];
        let tile = if second { &req.tile2 } else { &req.tile };
        match tile {
            Some(t) if t.stride() == l => {
                data[..t.flat().len()].copy_from_slice(t.flat());
            }
            _ => {
                let n = if second { req.rows2.len() } else { req.rows.len() };
                for i in 0..n {
                    let r = if second { req.row2(i) } else { req.row(i) };
                    data[i * l..i * l + r.len()].copy_from_slice(r);
                }
            }
        }
        Tensor::matrix(b, l, data)
    }
}

impl Accelerator for XlaAccel {
    fn name(&self) -> &str {
        "xla"
    }

    fn execute(&self, req: &MassRequest) -> Result<MassResult> {
        let rows = req.batch_rows();
        let len = req.max_len();
        let (b, l) = self.pick_bucket(req.op.entry(), rows, len)?;
        let name = self
            .rt
            .find(req.op.entry(), b, l)
            .ok_or_else(|| anyhow!("missing artifact {} b{b} l{l}", req.op.entry()))?
            .to_string();
        let x = Self::pack(req, false, b, l);
        let outs = match req.op {
            MassOp::Dot => {
                let y = Self::pack(req, true, b, l);
                self.rt.execute(&name, &[x, y])?
            }
            MassOp::For => {
                let sb = Tensor::vector(vec![req.scale_bias[0], req.scale_bias[1]]);
                self.rt.execute(&name, &[x, sb])?
            }
            _ => self.rt.execute(&name, &[x])?,
        };
        match req.op {
            MassOp::Sumup | MassOp::Dot => Ok(MassResult::Scalars(outs[0].data[..rows].to_vec())),
            MassOp::For | MassOp::Prefix => Ok(MassResult::Rows(
                (0..rows)
                    .map(|i| outs[0].data[i * l..i * l + req.row(i).len()].to_vec())
                    .collect(),
            )),
            MassOp::SumupStats => {
                // mean over the padded bucket length must be rescaled to
                // the true row length (padding contributed zeros).
                let sum = outs[0].data[..rows].to_vec();
                let mean = (0..rows)
                    .map(|i| sum[i] / req.row(i).len().max(1) as f32)
                    .collect();
                let l2 = outs[2].data[..rows].to_vec();
                Ok(MassResult::Stats { sum, mean, l2 })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_sumup_and_dot() {
        let a = NativeAccel;
        let r = a.execute(&MassRequest::sumup(vec![vec![1.0, 2.0, 3.0], vec![4.0]])).unwrap();
        assert_eq!(r, MassResult::Scalars(vec![6.0, 4.0]));
        let r = a
            .execute(&MassRequest::dot(vec![vec![1.0, 2.0]], vec![vec![3.0, 4.0]]))
            .unwrap();
        assert_eq!(r, MassResult::Scalars(vec![11.0]));
    }

    #[test]
    fn native_for_and_prefix() {
        let a = NativeAccel;
        let r = a.execute(&MassRequest::for_op(vec![vec![1.0, 2.0]], 2.0, 1.0)).unwrap();
        assert_eq!(r, MassResult::Rows(vec![vec![3.0, 5.0]]));
        let req = MassRequest::new(
            MassOp::Prefix,
            vec![vec![1.0, 2.0, 3.0]],
            Vec::<Vec<f32>>::new(),
            [0.0; 2],
        );
        assert_eq!(a.execute(&req).unwrap(), MassResult::Rows(vec![vec![1.0, 3.0, 6.0]]));
    }

    #[test]
    fn native_stats() {
        let a = NativeAccel;
        let req = MassRequest::new(
            MassOp::SumupStats,
            vec![vec![3.0, 4.0]],
            Vec::<Vec<f32>>::new(),
            [0.0; 2],
        );
        let MassResult::Stats { sum, mean, l2 } = a.execute(&req).unwrap() else {
            panic!("wrong variant")
        };
        assert_eq!(sum, vec![7.0]);
        assert_eq!(mean, vec![3.5]);
        assert!((l2[0] - 5.0).abs() < 1e-6);
    }

    #[test]
    fn dot_mismatched_rows_is_error() {
        let a = NativeAccel;
        assert!(a
            .execute(&MassRequest::dot(vec![vec![1.0]], Vec::<Vec<f32>>::new()))
            .is_err());
    }

    #[test]
    fn constructors_adopt_shared_rows_without_copying() {
        let buf: Arc<[f32]> = vec![1.0, 2.0, 3.0].into();
        let req = MassRequest::sumup(vec![Arc::clone(&buf)]);
        assert!(Arc::ptr_eq(&req.rows[0], &buf), "the handle is adopted, not copied");
        assert_eq!(req.row(0), &[1.0, 2.0, 3.0][..]);
        assert!(req.tile.is_none(), "direct requests carry no tile");
    }

    #[test]
    fn tiled_and_row_requests_agree() {
        let rows: Vec<Arc<[f32]>> =
            vec![vec![1.0, 2.0, 3.0].into(), vec![4.0, 5.0].into(), vec![6.0].into()];
        let plain = MassRequest::sumup(rows.clone());
        let tiled = MassRequest {
            tile: Some(Tile::build(&rows, Vec::new())),
            ..MassRequest::sumup(rows)
        };
        let a = NativeAccel;
        assert_eq!(a.execute(&plain).unwrap(), a.execute(&tiled).unwrap());
        assert_eq!(tiled.row(1), &[4.0, 5.0][..], "tile rows slice without padding");
    }

    #[test]
    fn op_entry_names_match_model() {
        assert_eq!(MassOp::Sumup.entry(), "sumup");
        assert_eq!(MassOp::For.entry(), "mass_for");
        assert_eq!(MassOp::Dot.arity(), 2);
        assert_eq!(MassOp::Sumup.arity(), 1);
    }
}
