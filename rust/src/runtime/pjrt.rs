//! The real PJRT-backed runtime (`--features xla-runtime`): compiles the
//! HLO-text artifacts with the vendored `xla` crate and executes them on
//! the CPU PJRT client.

use super::{parse_manifest, ArtifactMeta, Tensor};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A compiled executable plus its metadata.
pub struct LoadedExec {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

fn to_literal(t: &Tensor) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(&t.data).reshape(&t.dims)?)
}

/// The PJRT runtime: one CPU client, one compiled executable per artifact.
pub struct Runtime {
    #[allow(dead_code)] // owns the PJRT client the executables run on
    client: xla::PjRtClient,
    execs: HashMap<String, LoadedExec>,
    dir: PathBuf,
}

impl Runtime {
    /// Load every artifact listed in `<dir>/manifest.tsv` and compile it.
    pub fn load_dir(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} — run `make artifacts` first"))?;
        let metas = parse_manifest(&text)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e}"))?;
        let mut execs = HashMap::new();
        for meta in metas {
            let path = dir.join(format!("{}.hlo.txt", meta.name));
            let exe = Self::compile_file(&client, &path)?;
            execs.insert(meta.name.clone(), LoadedExec { meta, exe });
        }
        Ok(Runtime { client, execs, dir })
    }

    fn compile_file(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let path_str = path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .map_err(|e| anyhow!("parsing {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        client.compile(&comp).map_err(|e| anyhow!("compiling {path:?}: {e}"))
    }

    /// Artifact directory this runtime was loaded from.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Names of all loaded artifacts.
    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.execs.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    /// Metadata of one artifact.
    pub fn meta(&self, name: &str) -> Option<&ArtifactMeta> {
        self.execs.get(name).map(|e| &e.meta)
    }

    /// Look up the artifact for (entry, bucket).
    pub fn find(&self, entry: &str, b: usize, l: usize) -> Option<&str> {
        self.execs
            .values()
            .find(|e| e.meta.entry == entry && e.meta.b == b && e.meta.l == l)
            .map(|e| e.meta.name.as_str())
    }

    /// Buckets available for an entry, sorted ascending by (B, L).
    pub fn buckets(&self, entry: &str) -> Vec<(usize, usize)> {
        let mut v: Vec<(usize, usize)> = self
            .execs
            .values()
            .filter(|e| e.meta.entry == entry)
            .map(|e| (e.meta.b, e.meta.l))
            .collect();
        v.sort();
        v
    }

    /// Execute one artifact on f32 inputs; returns the output tensors
    /// (the module root is a tuple of `out_arity` arrays).
    pub fn execute(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let le = self.execs.get(name).ok_or_else(|| anyhow!("unknown artifact {name}"))?;
        if inputs.len() != le.meta.arity {
            bail!("{name}: want {} inputs, got {}", le.meta.arity, inputs.len());
        }
        let literals: Vec<xla::Literal> =
            inputs.iter().map(to_literal).collect::<Result<_>>()?;
        let result = le
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {name}: {e}"))?;
        let root = result[0][0].to_literal_sync().map_err(|e| anyhow!("fetch: {e}"))?;
        let parts = root.to_tuple().map_err(|e| anyhow!("untuple: {e}"))?;
        if parts.len() != le.meta.out_arity {
            bail!("{name}: manifest says {} outputs, got {}", le.meta.out_arity, parts.len());
        }
        parts
            .into_iter()
            .map(|lit| {
                let shape = lit.array_shape().map_err(|e| anyhow!("shape: {e}"))?;
                let dims: Vec<i64> = shape.dims().to_vec();
                let data = lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e}"))?;
                Ok(Tensor { dims, data })
            })
            .collect()
    }
}
