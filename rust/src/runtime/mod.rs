//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//!
//! Python never runs here — this module is the *only* bridge between the
//! rust coordinator and the L2/L1 compute graph, and it works entirely
//! from the `artifacts/` directory built once by `make artifacts`.
//!
//! The PJRT bindings (the `xla` crate) are optional: build with
//! `--features xla-runtime` where they are vendored. Without the feature
//! this module compiles a stub whose [`Runtime::load_dir`] always errors,
//! so the fabric's `xla` backend fails initialisation and the registry
//! fails over to `native` — the service degrades instead of the build
//! breaking.

use anyhow::{bail, Context, Result};

#[cfg(feature = "xla-runtime")]
mod pjrt;
#[cfg(feature = "xla-runtime")]
pub use pjrt::{LoadedExec, Runtime};

#[cfg(not(feature = "xla-runtime"))]
mod stub;
#[cfg(not(feature = "xla-runtime"))]
pub use stub::Runtime;

/// Metadata of one artifact, parsed from `manifest.tsv`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactMeta {
    /// File stem, e.g. `sumup_b8_l256`.
    pub name: String,
    /// Model entry point, e.g. `sumup`.
    pub entry: String,
    /// Bucket batch size.
    pub b: usize,
    /// Bucket vector length.
    pub l: usize,
    /// Number of inputs.
    pub arity: usize,
    /// Number of outputs (the module root is a tuple).
    pub out_arity: usize,
}

/// Parse `manifest.tsv` (written by aot.py).
pub fn parse_manifest(text: &str) -> Result<Vec<ArtifactMeta>> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let f: Vec<&str> = line.split('\t').collect();
        if f.len() != 6 {
            bail!("manifest line {}: want 6 fields, got {}", i + 1, f.len());
        }
        out.push(ArtifactMeta {
            name: f[0].to_string(),
            entry: f[1].to_string(),
            b: f[2].parse().context("B")?,
            l: f[3].parse().context("L")?,
            arity: f[4].parse().context("arity")?,
            out_arity: f[5].parse().context("out_arity")?,
        });
    }
    Ok(out)
}

/// An f32 tensor in row-major layout (the runtime's exchange type).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub dims: Vec<i64>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(dims: Vec<i64>, data: Vec<f32>) -> Self {
        debug_assert_eq!(dims.iter().product::<i64>() as usize, data.len());
        Tensor { dims, data }
    }

    /// A (B, L) matrix.
    pub fn matrix(b: usize, l: usize, data: Vec<f32>) -> Self {
        Tensor::new(vec![b as i64, l as i64], data)
    }

    /// A length-n vector.
    pub fn vector(data: Vec<f32>) -> Self {
        Tensor { dims: vec![data.len() as i64], data }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses_and_rejects() {
        let text = "# header\nsumup_b8_l256\tsumup\t8\t256\t1\t1\n";
        let m = parse_manifest(text).unwrap();
        assert_eq!(m.len(), 1);
        assert_eq!(m[0].entry, "sumup");
        assert_eq!((m[0].b, m[0].l, m[0].arity, m[0].out_arity), (8, 256, 1, 1));
        assert!(parse_manifest("a\tb\tc\n").is_err());
        assert!(parse_manifest("a\tb\tx\t256\t1\t1\n").is_err());
        assert!(parse_manifest("").unwrap().is_empty());
    }

    #[test]
    fn tensor_builders() {
        let t = Tensor::matrix(2, 3, vec![0.0; 6]);
        assert_eq!(t.dims, vec![2, 3]);
        let v = Tensor::vector(vec![1.0, 2.0]);
        assert_eq!(v.dims, vec![2]);
    }

    #[cfg(not(feature = "xla-runtime"))]
    #[test]
    fn stub_runtime_errors_at_load_with_actionable_message() {
        let err = Runtime::load_dir("artifacts").unwrap_err().to_string();
        assert!(err.contains("xla-runtime"), "{err}");
    }
}
