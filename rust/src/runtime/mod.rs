//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//!
//! Python never runs here — this module is the *only* bridge between the
//! rust coordinator and the L2/L1 compute graph, and it works entirely
//! from the `artifacts/` directory built once by `make artifacts`.

use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Metadata of one artifact, parsed from `manifest.tsv`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactMeta {
    /// File stem, e.g. `sumup_b8_l256`.
    pub name: String,
    /// Model entry point, e.g. `sumup`.
    pub entry: String,
    /// Bucket batch size.
    pub b: usize,
    /// Bucket vector length.
    pub l: usize,
    /// Number of inputs.
    pub arity: usize,
    /// Number of outputs (the module root is a tuple).
    pub out_arity: usize,
}

/// Parse `manifest.tsv` (written by aot.py).
pub fn parse_manifest(text: &str) -> Result<Vec<ArtifactMeta>> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let f: Vec<&str> = line.split('\t').collect();
        if f.len() != 6 {
            bail!("manifest line {}: want 6 fields, got {}", i + 1, f.len());
        }
        out.push(ArtifactMeta {
            name: f[0].to_string(),
            entry: f[1].to_string(),
            b: f[2].parse().context("B")?,
            l: f[3].parse().context("L")?,
            arity: f[4].parse().context("arity")?,
            out_arity: f[5].parse().context("out_arity")?,
        });
    }
    Ok(out)
}

/// A compiled executable plus its metadata.
pub struct LoadedExec {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

/// An f32 tensor in row-major layout (the runtime's exchange type).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub dims: Vec<i64>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(dims: Vec<i64>, data: Vec<f32>) -> Self {
        debug_assert_eq!(dims.iter().product::<i64>() as usize, data.len());
        Tensor { dims, data }
    }

    /// A (B, L) matrix.
    pub fn matrix(b: usize, l: usize, data: Vec<f32>) -> Self {
        Tensor::new(vec![b as i64, l as i64], data)
    }

    /// A length-n vector.
    pub fn vector(data: Vec<f32>) -> Self {
        Tensor { dims: vec![data.len() as i64], data }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        Ok(xla::Literal::vec1(&self.data).reshape(&self.dims)?)
    }
}

/// The PJRT runtime: one CPU client, one compiled executable per artifact.
pub struct Runtime {
    #[allow(dead_code)] // owns the PJRT client the executables run on
    client: xla::PjRtClient,
    execs: HashMap<String, LoadedExec>,
    dir: PathBuf,
}

impl Runtime {
    /// Load every artifact listed in `<dir>/manifest.tsv` and compile it.
    pub fn load_dir(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} — run `make artifacts` first"))?;
        let metas = parse_manifest(&text)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e}"))?;
        let mut execs = HashMap::new();
        for meta in metas {
            let path = dir.join(format!("{}.hlo.txt", meta.name));
            let exe = Self::compile_file(&client, &path)?;
            execs.insert(meta.name.clone(), LoadedExec { meta, exe });
        }
        Ok(Runtime { client, execs, dir })
    }

    fn compile_file(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let path_str = path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .map_err(|e| anyhow!("parsing {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        client.compile(&comp).map_err(|e| anyhow!("compiling {path:?}: {e}"))
    }

    /// Artifact directory this runtime was loaded from.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Names of all loaded artifacts.
    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.execs.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    /// Metadata of one artifact.
    pub fn meta(&self, name: &str) -> Option<&ArtifactMeta> {
        self.execs.get(name).map(|e| &e.meta)
    }

    /// Look up the artifact for (entry, bucket).
    pub fn find(&self, entry: &str, b: usize, l: usize) -> Option<&str> {
        self.execs
            .values()
            .find(|e| e.meta.entry == entry && e.meta.b == b && e.meta.l == l)
            .map(|e| e.meta.name.as_str())
    }

    /// Buckets available for an entry, sorted ascending by (B, L).
    pub fn buckets(&self, entry: &str) -> Vec<(usize, usize)> {
        let mut v: Vec<(usize, usize)> = self
            .execs
            .values()
            .filter(|e| e.meta.entry == entry)
            .map(|e| (e.meta.b, e.meta.l))
            .collect();
        v.sort();
        v
    }

    /// Execute one artifact on f32 inputs; returns the output tensors
    /// (the module root is a tuple of `out_arity` arrays).
    pub fn execute(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let le = self.execs.get(name).ok_or_else(|| anyhow!("unknown artifact {name}"))?;
        if inputs.len() != le.meta.arity {
            bail!("{name}: want {} inputs, got {}", le.meta.arity, inputs.len());
        }
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let result = le
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {name}: {e}"))?;
        let root = result[0][0].to_literal_sync().map_err(|e| anyhow!("fetch: {e}"))?;
        let parts = root.to_tuple().map_err(|e| anyhow!("untuple: {e}"))?;
        if parts.len() != le.meta.out_arity {
            bail!("{name}: manifest says {} outputs, got {}", le.meta.out_arity, parts.len());
        }
        parts
            .into_iter()
            .map(|lit| {
                let shape = lit.array_shape().map_err(|e| anyhow!("shape: {e}"))?;
                let dims: Vec<i64> = shape.dims().to_vec();
                let data = lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e}"))?;
                Ok(Tensor { dims, data })
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses_and_rejects() {
        let text = "# header\nsumup_b8_l256\tsumup\t8\t256\t1\t1\n";
        let m = parse_manifest(text).unwrap();
        assert_eq!(m.len(), 1);
        assert_eq!(m[0].entry, "sumup");
        assert_eq!((m[0].b, m[0].l, m[0].arity, m[0].out_arity), (8, 256, 1, 1));
        assert!(parse_manifest("a\tb\tc\n").is_err());
        assert!(parse_manifest("a\tb\tx\t256\t1\t1\n").is_err());
        assert!(parse_manifest("").unwrap().is_empty());
    }

    #[test]
    fn tensor_builders() {
        let t = Tensor::matrix(2, 3, vec![0.0; 6]);
        assert_eq!(t.dims, vec![2, 3]);
        let v = Tensor::vector(vec![1.0, 2.0]);
        assert_eq!(v.dims, vec![2]);
    }
}
