//! Stub runtime compiled when the `xla-runtime` feature is off: the PJRT
//! bindings are absent, so loading artifacts is impossible by
//! construction. [`Runtime`] is uninhabited — every method other than
//! [`Runtime::load_dir`] is statically unreachable — which lets all
//! PJRT-consuming code (e.g. `accel::XlaAccel`) typecheck unchanged while
//! the fabric's `xla` backend fails initialisation and the registry fails
//! over to `native`.

use super::{ArtifactMeta, Tensor};
use anyhow::{anyhow, Result};
use std::path::Path;

/// Uninhabited placeholder for the PJRT runtime.
pub enum Runtime {}

impl Runtime {
    /// Always errors: the crate was built without the `xla-runtime`
    /// feature, so there is nothing to load artifacts with.
    pub fn load_dir(dir: impl AsRef<Path>) -> Result<Runtime> {
        Err(anyhow!(
            "PJRT runtime unavailable: built without the `xla-runtime` feature \
             (artifacts at {:?} cannot be loaded; vendor the `xla` crate and \
             rebuild with `--features xla-runtime`)",
            dir.as_ref()
        ))
    }

    pub fn dir(&self) -> &Path {
        match *self {}
    }

    pub fn names(&self) -> Vec<&str> {
        match *self {}
    }

    pub fn meta(&self, _name: &str) -> Option<&ArtifactMeta> {
        match *self {}
    }

    pub fn find(&self, _entry: &str, _b: usize, _l: usize) -> Option<&str> {
        match *self {}
    }

    pub fn buckets(&self, _entry: &str) -> Vec<(usize, usize)> {
        match *self {}
    }

    pub fn execute(&self, _name: &str, _inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        match *self {}
    }
}
