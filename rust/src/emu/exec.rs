//! Shared Y86 instruction semantics.
//!
//! [`execute`] implements the architectural effect of one non-meta
//! instruction. Pseudo-register traffic (§4.6) is delegated to a
//! [`PseudoPort`], so the same function drives both the conventional CPU
//! (which denies pseudo-registers) and the EMPA cores (which map them to
//! their latch registers).

use crate::isa::{CondCodes, Insn, Reg, Status};
#[cfg(test)]
use crate::isa::OpFn;
use crate::mem::DataPort;
#[cfg(test)]
use crate::mem::Memory;

/// Architectural register file + condition codes ("glue" in the paper's
/// terminology — the state cloned to a child on QT creation, §3.2).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct CoreRegs {
    pub file: [i32; 8],
    pub cc: CondCodes,
}

impl CoreRegs {
    /// Read an architectural register (not a pseudo-register).
    pub fn get(&self, r: Reg) -> Option<i32> {
        r.file_index().map(|i| self.file[i])
    }

    /// Write an architectural register.
    pub fn set(&mut self, r: Reg, v: i32) -> Option<()> {
        r.file_index().map(|i| self.file[i] = v)
    }
}

/// Where pseudo-register reads/writes go. The conventional CPU denies
/// them; an EMPA core wires them to its latch registers under SV control.
pub trait PseudoPort {
    /// Read the latch behind pseudo-register `r`; `None` = architectural
    /// fault (conventional CPU) — EMPA cores may instead *block*, which is
    /// handled above this layer.
    fn read(&mut self, r: Reg) -> Option<i32>;
    /// Write the latch behind pseudo-register `r`.
    fn write(&mut self, r: Reg, v: i32) -> Option<()>;
}

/// [`PseudoPort`] for the conventional machine: any pseudo access faults.
pub struct DenyPseudo;

impl PseudoPort for DenyPseudo {
    fn read(&mut self, _r: Reg) -> Option<i32> {
        None
    }
    fn write(&mut self, _r: Reg, _v: i32) -> Option<()> {
        None
    }
}

/// Result of executing one instruction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecEffect {
    /// Keep running from `next_pc`.
    Continue { next_pc: u32 },
    /// Machine stopped with the given status.
    Stop(Status),
}

fn read_any(r: Reg, regs: &CoreRegs, pseudo: &mut dyn PseudoPort) -> Option<i32> {
    if r.is_pseudo() {
        pseudo.read(r)
    } else {
        regs.get(r)
    }
}

fn write_any(r: Reg, v: i32, regs: &mut CoreRegs, pseudo: &mut dyn PseudoPort) -> Option<()> {
    if r.is_pseudo() {
        pseudo.write(r, v)
    } else {
        regs.set(r, v)
    }
}

/// Execute one non-meta instruction at `pc`.
///
/// Metainstructions must be intercepted by the caller (the core's
/// pre-fetch raises `Meta` and the SV executes them, §4.5); passing one
/// here returns `Stop(Ins)` like any invalid opcode would on a
/// conventional machine.
///
/// Data traffic goes through a [`DataPort`]: the live memory when
/// stepping serially, or a staging record over a read-only view when a
/// parallel phase A speculates the instruction on a worker thread. No
/// Y86 instruction both loads *and* stores data memory (loads: `mrmovl`,
/// `ret`, `popl`; stores: `rmmovl`, `call`, `pushl`), which is what
/// makes single-address effect records sufficient for conflict
/// detection.
pub fn execute<M: DataPort>(
    insn: &Insn,
    pc: u32,
    regs: &mut CoreRegs,
    mem: &mut M,
    pseudo: &mut dyn PseudoPort,
) -> ExecEffect {
    let next = pc + insn.len() as u32;
    let cont = ExecEffect::Continue { next_pc: next };
    let fault = |s: Status| ExecEffect::Stop(s);
    match *insn {
        Insn::Halt => fault(Status::Hlt),
        Insn::Nop => cont,
        Insn::CMov { cond, ra, rb } => {
            let Some(v) = read_any(ra, regs, pseudo) else { return fault(Status::Ins) };
            if regs.cc.eval(cond) {
                if write_any(rb, v, regs, pseudo).is_none() {
                    return fault(Status::Ins);
                }
            }
            cont
        }
        Insn::IrMov { imm, rb } => {
            if write_any(rb, imm, regs, pseudo).is_none() {
                return fault(Status::Ins);
            }
            cont
        }
        Insn::RmMov { ra, rb, disp } => {
            let (Some(v), Some(base)) = (read_any(ra, regs, pseudo), read_any(rb, regs, pseudo)) else {
                return fault(Status::Ins);
            };
            let addr = base.wrapping_add(disp) as u32;
            match mem.store(addr, v as u32) {
                Ok(()) => cont,
                Err(_) => fault(Status::Adr),
            }
        }
        Insn::MrMov { ra, rb, disp } => {
            let Some(base) = read_any(rb, regs, pseudo) else { return fault(Status::Ins) };
            let addr = base.wrapping_add(disp) as u32;
            match mem.load(addr) {
                Ok(v) => {
                    if write_any(ra, v as i32, regs, pseudo).is_none() {
                        return fault(Status::Ins);
                    }
                    cont
                }
                Err(_) => fault(Status::Adr),
            }
        }
        Insn::Op { op, ra, rb } => {
            let (Some(a), Some(b)) = (read_any(ra, regs, pseudo), read_any(rb, regs, pseudo)) else {
                return fault(Status::Ins);
            };
            let (r, of) = op.apply(a, b);
            regs.cc = CondCodes { zf: r == 0, sf: r < 0, of };
            if write_any(rb, r, regs, pseudo).is_none() {
                return fault(Status::Ins);
            }
            cont
        }
        Insn::Jump { cond, dest } => {
            if regs.cc.eval(cond) {
                ExecEffect::Continue { next_pc: dest }
            } else {
                cont
            }
        }
        Insn::Call { dest } => {
            let sp = regs.file[Reg::Esp as usize].wrapping_sub(4);
            if mem.store(sp as u32, next).is_err() {
                return fault(Status::Adr);
            }
            regs.file[Reg::Esp as usize] = sp;
            ExecEffect::Continue { next_pc: dest }
        }
        Insn::Ret => {
            let sp = regs.file[Reg::Esp as usize];
            match mem.load(sp as u32) {
                Ok(ra) => {
                    regs.file[Reg::Esp as usize] = sp.wrapping_add(4);
                    ExecEffect::Continue { next_pc: ra }
                }
                Err(_) => fault(Status::Adr),
            }
        }
        Insn::Push { ra } => {
            let Some(v) = read_any(ra, regs, pseudo) else { return fault(Status::Ins) };
            let sp = regs.file[Reg::Esp as usize].wrapping_sub(4);
            if mem.store(sp as u32, v as u32).is_err() {
                return fault(Status::Adr);
            }
            regs.file[Reg::Esp as usize] = sp;
            cont
        }
        Insn::Pop { ra } => {
            let sp = regs.file[Reg::Esp as usize];
            match mem.load(sp as u32) {
                Ok(v) => {
                    // Y86: increment before write so `popl %esp` gets the value.
                    regs.file[Reg::Esp as usize] = sp.wrapping_add(4);
                    if write_any(ra, v as i32, regs, pseudo).is_none() {
                        return fault(Status::Ins);
                    }
                    cont
                }
                Err(_) => fault(Status::Adr),
            }
        }
        Insn::Meta { .. } => fault(Status::Ins),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::CondFn;

    fn setup() -> (CoreRegs, Memory, DenyPseudo) {
        (CoreRegs::default(), Memory::new(256), DenyPseudo)
    }

    #[test]
    fn alu_sets_flags() {
        let (mut regs, mut mem, mut p) = setup();
        regs.file[0] = 5;
        regs.file[3] = 5;
        let i = Insn::Op { op: OpFn::Sub, ra: Reg::Eax, rb: Reg::Ebx };
        execute(&i, 0, &mut regs, &mut mem, &mut p);
        assert_eq!(regs.file[3], 0);
        assert!(regs.cc.zf && !regs.cc.sf && !regs.cc.of);
    }

    #[test]
    fn sub_overflow_flag() {
        let (mut regs, mut mem, mut p) = setup();
        regs.file[0] = 1;
        regs.file[3] = i32::MIN;
        let i = Insn::Op { op: OpFn::Sub, ra: Reg::Eax, rb: Reg::Ebx };
        execute(&i, 0, &mut regs, &mut mem, &mut p);
        assert_eq!(regs.file[3], i32::MAX);
        assert!(regs.cc.of);
    }

    #[test]
    fn jump_taken_and_not() {
        let (mut regs, mut mem, mut p) = setup();
        regs.cc.zf = true;
        let i = Insn::Jump { cond: CondFn::E, dest: 0x40 };
        assert_eq!(execute(&i, 0, &mut regs, &mut mem, &mut p), ExecEffect::Continue { next_pc: 0x40 });
        regs.cc.zf = false;
        assert_eq!(execute(&i, 0, &mut regs, &mut mem, &mut p), ExecEffect::Continue { next_pc: 5 });
    }

    #[test]
    fn mem_roundtrip_through_insns() {
        let (mut regs, mut mem, mut p) = setup();
        regs.file[1] = 0x20; // %ecx
        regs.file[6] = 1234; // %esi
        execute(&Insn::RmMov { ra: Reg::Esi, rb: Reg::Ecx, disp: 4 }, 0, &mut regs, &mut mem, &mut p);
        execute(&Insn::MrMov { ra: Reg::Edi, rb: Reg::Ecx, disp: 4 }, 0, &mut regs, &mut mem, &mut p);
        assert_eq!(regs.file[7], 1234);
    }

    #[test]
    fn pseudo_denied_faults() {
        let (mut regs, mut mem, mut p) = setup();
        let i = Insn::Op { op: OpFn::Add, ra: Reg::Eax, rb: Reg::PseudoP };
        assert_eq!(execute(&i, 0, &mut regs, &mut mem, &mut p), ExecEffect::Stop(Status::Ins));
    }

    #[test]
    fn pop_esp_semantics() {
        let (mut regs, mut mem, mut p) = setup();
        regs.file[4] = 0x10;
        mem.write_u32(0x10, 0x77).unwrap();
        execute(&Insn::Pop { ra: Reg::Esp }, 0, &mut regs, &mut mem, &mut p);
        assert_eq!(regs.file[4], 0x77); // popped value wins over increment
    }
}
