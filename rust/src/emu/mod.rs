//! Sequential Y86 emulator — the conventional single-processor baseline
//! ("NO EMPA acceleration" rows of Table 1).
//!
//! The instruction semantics live in [`exec`] and are shared with the EMPA
//! cores (which differ only in the handling of pseudo-registers and
//! metainstructions — §4.1.2: "the cores in an EMPA processor are mostly
//! similar to the present single-core processor, with some extra
//! functionality").

pub mod exec;

pub use exec::{execute, CoreRegs, DenyPseudo, ExecEffect, PseudoPort};

use crate::empa::timing::TimingConfig;
use crate::isa::{Insn, Status};
use crate::mem::{bus::MemoryBus, MemConfig, Memory};

/// A conventional sequential Y86 machine with cycle accounting.
pub struct Cpu {
    pub regs: CoreRegs,
    pub pc: u32,
    pub status: Status,
    pub mem: Memory,
    pub bus: MemoryBus,
    pub timing: TimingConfig,
    /// Total clocks elapsed.
    pub clock: u64,
    /// Instructions retired.
    pub retired: u64,
}

impl Cpu {
    /// Build a CPU with the program image loaded at address 0.
    pub fn new(image: &[u8], timing: TimingConfig, mem_cfg: &MemConfig) -> Self {
        Cpu {
            regs: CoreRegs::default(),
            pc: 0,
            status: Status::Aok,
            mem: Memory::with_image(mem_cfg.size, image),
            bus: MemoryBus::new(mem_cfg),
            timing,
            clock: 0,
            retired: 0,
        }
    }

    /// Convenience constructor with paper timing and ideal memory.
    pub fn with_image(image: &[u8]) -> Self {
        Cpu::new(image, TimingConfig::paper(), &MemConfig::ideal())
    }

    /// Execute one instruction; returns false when the machine stopped.
    pub fn step(&mut self) -> bool {
        if !self.status.running() {
            return false;
        }
        let Some((insn, _len)) = Insn::decode(self.mem.fetch_window(self.pc)) else {
            self.status = Status::Ins;
            return false;
        };
        // The conventional processor has no supervisor: a metainstruction
        // is an invalid opcode here.
        if insn.is_meta() {
            self.status = Status::Ins;
            return false;
        }
        let base = self.timing.insn_cost(&insn);
        // Memory instructions contend for the bus.
        let stall = if matches!(insn, Insn::MrMov { .. } | Insn::RmMov { .. }) {
            self.bus.access(self.clock)
        } else {
            0
        };
        let mut deny = DenyPseudo;
        let effect = execute(&insn, self.pc, &mut self.regs, &mut self.mem, &mut deny);
        self.clock += base + stall;
        self.retired += 1;
        match effect {
            ExecEffect::Continue { next_pc } => {
                self.pc = next_pc;
                true
            }
            ExecEffect::Stop(status) => {
                self.status = status;
                false
            }
        }
    }

    /// Run to completion (or until `max_steps` instructions, a runaway
    /// guard for tests). Returns the final status.
    pub fn run(&mut self, max_steps: u64) -> Status {
        let mut steps = 0;
        while self.step() {
            steps += 1;
            if steps >= max_steps {
                break;
            }
        }
        self.status
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::assemble;

    fn run_src(src: &str) -> Cpu {
        let p = assemble(src).unwrap();
        let mut cpu = Cpu::with_image(&p.image);
        cpu.run(100_000);
        cpu
    }

    #[test]
    fn listing1_sums_the_paper_vector_in_52_plus_90_clocks() {
        // Listing 1 with N=4: expected time 142 clocks (Table 1 row N=4 NO).
        let cpu = run_src(crate::isa::asm::LISTING1);
        assert_eq!(cpu.status, Status::Hlt);
        assert_eq!(cpu.regs.file[0], 0xd + 0xc0 + 0xb00 + 0xa000); // %eax
        assert_eq!(cpu.clock, 142);
    }

    #[test]
    fn zero_length_vector_skips_loop() {
        let src = "\
    irmovl $0, %edx
    irmovl $64, %ecx
    xorl %eax, %eax
    andl %edx, %edx
    je End
Loop:
    mrmovl (%ecx), %esi
    addl %esi, %eax
    jne Loop
End:
    halt
";
        let cpu = run_src(src);
        assert_eq!(cpu.status, Status::Hlt);
        assert_eq!(cpu.regs.file[0], 0);
        // prologue (19) + halt (3)
        assert_eq!(cpu.clock, 22);
    }

    #[test]
    fn call_ret_push_pop() {
        let src = "\
    irmovl $256, %esp
    irmovl $7, %eax
    call Double
    halt
Double:
    pushl %eax
    addl %eax, %eax
    popl %ebx
    ret
";
        let cpu = run_src(src);
        assert_eq!(cpu.status, Status::Hlt);
        assert_eq!(cpu.regs.file[0], 14); // %eax doubled
        assert_eq!(cpu.regs.file[3], 7); // %ebx = pushed copy
        assert_eq!(cpu.regs.file[4], 256); // %esp balanced
    }

    #[test]
    fn meta_is_invalid_on_conventional_cpu() {
        let cpu = run_src("qterm\n");
        assert_eq!(cpu.status, Status::Ins);
    }

    #[test]
    fn bad_address_sets_adr() {
        let cpu = run_src("irmovl $0xFFFFF0, %ecx\nmrmovl (%ecx), %eax\nhalt\n");
        assert_eq!(cpu.status, Status::Adr);
    }

    #[test]
    fn cmov_variants() {
        let src = "\
    irmovl $5, %eax
    irmovl $3, %ebx
    subl %ebx, %eax     # eax = 2, positive
    irmovl $111, %ecx
    cmovg %ecx, %edx    # taken
    cmovl %ecx, %esi    # not taken
    halt
";
        let cpu = run_src(src);
        assert_eq!(cpu.regs.file[2], 111);
        assert_eq!(cpu.regs.file[6], 0);
    }

    #[test]
    fn runaway_guard_stops() {
        let mut cpu = Cpu::with_image(&assemble("Loop: jmp Loop\n").unwrap().image);
        cpu.run(10);
        assert_eq!(cpu.status, Status::Aok); // still running, guard tripped
        assert!(cpu.retired >= 10);
    }
}
